"""Observability overhead benchmark: tracing-on vs tracing-off throughput.

The tracing layer's contract is "off is free, on is cheap": every
instrumentation site is a single None-check when tracing is off, and one
small append under an uncontended lock when it is on.  This benchmark holds
the layer to that contract on the serve_load open-loop trace: the SAME
arrival schedule is fired at a runtime with tracing off and one with
tracing fully on (sample=1.0, periodic reporter attached), interleaved
best-of-N so host drift lands on both sides, and the run RAISES (failing
the CI bench-smoke lane) unless

  * tracing-on throughput >= 0.97x tracing-off (the <= 3% overhead budget),
  * every traced request span is well-formed — exactly one terminal event,
    monotonic timestamps (`repro.serve.obs.trace_problems`),
  * the per-request stage breakdown sums to the measured e2e latency within
    tolerance (median unattributed residual <= 25% of e2e), and
  * the run exports a Chrome-trace JSON that round-trips through `json`
    with the same per-request stage sums — the artifact an operator would
    actually load into Perfetto.

Rows (printed by benchmarks/run.py as name,us_per_call,derived):
  obs/tracing_{off,on} : us = p95 latency; note = throughput + trace volume.
  obs/overhead         : note = on/off throughput ratio + budget.
"""

from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from benchmarks.serve_load import BUCKETS, _make_clouds, _open_loop

MAX_BATCH = 4
MIN_RATIO = 0.97  # tracing-on must keep >= 97% of tracing-off throughput
MAX_RESIDUAL_FRAC = 0.25  # median unattributed residual vs e2e


def _measure(cfg, params, clouds, arrivals, rt_cfg):
    """One open-loop rep against a fresh runtime; returns (thr, p95, rt)."""
    from repro.serve import ServingRuntime

    rt = ServingRuntime(cfg, params, rt_cfg)
    rt.warmup()
    with rt:
        lat, _rej, wall = _open_loop(rt.submit, clouds, arrivals)
    thr = len(lat) / wall if wall > 0 else 0.0
    p95 = float(np.percentile(lat, 95)) if lat else float("nan")
    return thr, p95, rt


def _check_trace_quality(rt, n_requests):
    """Assert span well-formedness + stage-sum-vs-e2e on one traced runtime."""
    from repro.serve import request_timelines, trace_problems

    events = rt.tracer.events()
    problems = trace_problems(events)
    if problems:
        raise RuntimeError(f"obs_overhead: malformed traces: {problems[:5]}")
    timelines = request_timelines(events)
    if len(timelines) != n_requests:
        raise RuntimeError(
            f"obs_overhead: {len(timelines)} spans for {n_requests} requests"
        )
    completed = [tl for tl in timelines.values() if tl.completed]
    if not completed:
        raise RuntimeError("obs_overhead: no completed spans to attribute")
    fracs = [tl.residual_s / tl.e2e_s for tl in completed if tl.e2e_s > 0]
    med = float(np.median(fracs))
    if med > MAX_RESIDUAL_FRAC:
        raise RuntimeError(
            f"obs_overhead: median unattributed residual {med:.1%} of e2e "
            f"exceeds {MAX_RESIDUAL_FRAC:.0%} — stage edges drifted"
        )
    return events, med


def _check_export(events):
    """Export Chrome-trace JSON; re-validate stage sums from the file itself."""
    from repro.serve import write_chrome_trace

    fd, path = tempfile.mkstemp(suffix=".json", prefix="pc2im_trace_")
    os.close(fd)
    try:
        n = write_chrome_trace(path, events)
        doc = json.loads(open(path).read())
        if len(doc["traceEvents"]) != n:
            raise RuntimeError("obs_overhead: export round-trip lost events")
        # per-request "X" slices carry their stage breakdown in args; the
        # stages must sum to the slice duration within tolerance — checked
        # from the FILE, since that is what an operator loads into Perfetto
        checked = 0
        for ev in doc["traceEvents"]:
            if ev.get("ph") != "X" or ev.get("pid") != 1:
                continue
            stages = {
                k: v for k, v in ev.get("args", {}).items() if k != "batch_id"
            }
            if not stages or ev["dur"] <= 0:
                continue
            frac = abs(ev["dur"] - sum(stages.values()) * 1e6) / ev["dur"]
            if frac > MAX_RESIDUAL_FRAC + 0.10:  # per-request, laxer than median
                raise RuntimeError(
                    f"obs_overhead: exported slice stage sum off by {frac:.1%}"
                )
            checked += 1
        if checked == 0:
            raise RuntimeError("obs_overhead: export contains no request slices")
        return n
    finally:
        os.unlink(path)


def run(smoke: bool = False, seed: int = 0) -> list[dict]:
    """Tracing-on vs tracing-off on the serve_load open-loop trace.

    Interleaved best-of-N reps, retried up to 3 times before the throughput
    budget raises (a single descheduled batch on a shared host moves an
    open-loop throughput by more than the 3% budget under test); the trace
    well-formedness and export checks are deterministic and assert on every
    attempt.
    """
    import jax

    from repro.configs.base import get_config
    from repro.core.accelerator import get_accelerator
    from repro.serve import RuntimeConfig, TraceConfig

    cfg = get_config("pointnet2-cls", smoke=True)
    width = 3 + cfg.in_features
    accel = get_accelerator(cfg)
    params = accel.init(jax.random.PRNGKey(seed))

    n_requests = 48 if smoke else 96
    n_reps = 5
    clouds = _make_clouds(n_requests, width, seed)

    # calibrate offered load to THIS host: per-request service time through
    # the fused B=MAX_BATCH artifact (min of 5 — stable vs scheduler noise),
    # then offer 2x that capacity so throughput is server-bound and any
    # per-request tracing cost must surface in it
    warm = np.zeros((MAX_BATCH, max(BUCKETS), width), np.float32)
    jax.block_until_ready(accel.infer(params, warm))
    times = []
    for _ in range(5):
        t = time.perf_counter()
        jax.block_until_ready(accel.infer(params, warm))
        times.append(time.perf_counter() - t)
    s_req = min(times) / MAX_BATCH
    rate = 2.0 / s_req

    def rt_cfg(trace):
        return RuntimeConfig(
            max_batch=MAX_BATCH,
            max_wait_s=min(0.02, 4 * s_req * MAX_BATCH),
            max_queue=max(64, n_requests),
            buckets=BUCKETS,
            trace=trace,
            # the reporter thread is part of the measured "tracing on" cost
            report_interval_s=0.25 if trace is not None else None,
        )

    configs = (("off", None), ("on", TraceConfig(sample=1.0)))
    last_err = None
    for attempt in range(3):
        rng = np.random.default_rng(seed + 31 * attempt)
        arrivals_by_rep = [
            np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
            for _ in range(n_reps)
        ]
        best = {}  # tag -> (thr, p95)
        traced_rt = None
        for arrivals in arrivals_by_rep:
            # off/on interleave inside each rep: drift lands on both sides
            for tag, trace in configs:
                thr, p95, rt = _measure(cfg, params, clouds, arrivals, rt_cfg(trace))
                if tag not in best or thr > best[tag][0]:
                    best[tag] = (thr, p95)
                    if tag == "on":
                        traced_rt = rt

        # deterministic span/export contracts: asserted on every attempt
        events, residual_med = _check_trace_quality(traced_rt, n_requests)
        n_exported = _check_export(events)

        ratio = best["on"][0] / best["off"][0] if best["off"][0] else 0.0
        if ratio >= MIN_RATIO:
            break
        last_err = RuntimeError(
            f"obs_overhead: tracing-on throughput {best['on'][0]:.1f}/s is "
            f"{ratio:.3f}x tracing-off {best['off'][0]:.1f}/s "
            f"(budget {MIN_RATIO}x)"
        )
    else:
        raise last_err

    tracer = traced_rt.tracer
    rows = []
    for tag, _ in configs:
        thr, p95 = best[tag]
        extra = ""
        if tag == "on":
            extra = (
                f" events={tracer.emitted} dropped={tracer.dropped}"
                f" residual_med={residual_med:.1%} exported={n_exported}"
            )
        rows.append({
            "name": f"obs/tracing_{tag}",
            "us": p95 * 1e6,
            "note": (
                f"{thr:.1f} req/s best-of-{n_reps} (rate {rate:.1f}/s;"
                f" p95 {p95 * 1e3:.1f}ms){extra}"
            ),
        })
    rows.append({
        "name": "obs/overhead",
        "us": float("nan"),
        "note": (
            f"on/off throughput {ratio:.3f}x >= {MIN_RATIO}x budget;"
            f" attempt {attempt + 1}/3"
        ),
    })
    return rows
