"""Fig 12(b): data-preprocessing energy — baseline-1 / baseline-2 / PC2IM.

Analytic access-count model (core/energy.py) with CIM constants calibrated
to the paper's two headline claims; the table reports model-vs-claim."""

from __future__ import annotations

from repro.core import energy as E


def run() -> list[dict]:
    const, rep = E.calibrate_cim()
    rows = [
        {"name": "fig12b/fitted_e_cim_dist_pj", "value": rep["fitted_e_cim_dist_pj"],
         "claim": "calibrated (0.2-0.6x SRAM read)"},
        {"name": "fig12b/fitted_e_cam_td_pj", "value": rep["fitted_e_cam_td_pj"],
         "claim": "calibrated"},
    ]
    for wname, w in E.WORKLOADS.items():
        e1 = E.preproc_energy_baseline1(w)["total_pj"]
        e2 = E.preproc_energy_baseline2(w)["total_pj"]
        ep = E.preproc_energy_pc2im(w, const)["total_pj"]
        rows.append({"name": f"fig12b/{wname}/reduction_vs_b1", "value": 1 - ep / e1,
                     "claim": "up to 0.979 (large PCs)"})
        rows.append({"name": f"fig12b/{wname}/reduction_vs_b2", "value": 1 - ep / e2,
                     "claim": "0.734"})
        rows.append({"name": f"fig12b/{wname}/pc2im_uJ", "value": ep * 1e-6, "claim": ""})
    return rows
