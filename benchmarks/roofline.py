"""§Roofline: three-term analysis per (arch x shape) from the dry-run JSONs.

    compute term    = HLO_FLOPs / peak_FLOP/s            (per chip)
    memory term     = HLO_bytes / HBM_bw                 (per chip)
    collective term = collective_bytes / (links x link_bw)

HLO_FLOPs/bytes come from repro.launch.hlo_analysis (trip-count-correct walk
of the optimized HLO); collective bytes are per-device operand bytes.  The
dominant term is the bottleneck; MODEL_FLOPS/HLO_FLOPs catches remat and
redundancy waste.  v5e constants per the assignment."""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
ICI_LINKS = 4  # usable links/chip on a 2D torus axis-pair
LINK_BW = 50e9  # B/s per link


def load_cells(dryrun_dir: str = "results/dryrun", mesh: str = "single", policy: str = "fsdp_tp"):
    cells = {}
    for path in sorted(glob.glob(os.path.join(dryrun_dir, f"*__{mesh}__{policy}.json"))):
        with open(path) as f:
            r = json.load(f)
        cells[(r["arch"], r["shape"])] = r
    return cells


def roofline_row(r: dict) -> dict | None:
    if r.get("status") != "ok":
        return {
            "arch": r["arch"], "shape": r["shape"], "status": r.get("status"),
            "reason": r.get("reason", r.get("error", ""))[:80],
        }
    ha = r["hlo_analysis"]
    compute_s = ha["flops"] / PEAK_FLOPS
    memory_s = ha["bytes"] / HBM_BW
    coll_s = ha["collective_bytes_total"] / (ICI_LINKS * LINK_BW)
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dom = max(terms, key=terms.get)
    try:  # recompute (model_flops may predate fixes); fall back to stored
        from repro.configs.base import get_config
        from repro.launch.shapes import model_flops

        mf = model_flops(get_config(r["arch"]), r["shape"])
    except Exception:
        mf = r["model_flops"]
    model_per_dev = mf / r["n_devices"]
    bound = max(terms.values())
    return {
        "arch": r["arch"],
        "shape": r["shape"],
        "status": "ok",
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dom,
        "model_hlo_ratio": model_per_dev / max(ha["flops"], 1.0),
        "roofline_fraction": compute_s / max(bound, 1e-12),
        "step_bound_s": bound,
        "temp_gb": r.get("memory_analysis", {}).get("temp_size_in_bytes", 0) / 1e9,
        "compile_s": r.get("compile_s"),
    }


def run(dryrun_dir: str = "results/dryrun") -> list[dict]:
    rows = []
    for (arch, shape), r in load_cells(dryrun_dir).items():
        row = roofline_row(r)
        if row is None:
            continue
        if row.get("status") == "ok":
            rows.append({
                "name": f"roofline/{arch}/{shape}",
                "value": round(row["roofline_fraction"], 4),
                "claim": f"dom={row['dominant']} c={row['compute_s']:.3g}s m={row['memory_s']:.3g}s x={row['collective_s']:.3g}s",
            })
        else:
            rows.append({"name": f"roofline/{arch}/{shape}", "value": -1.0,
                         "claim": row.get("reason", "")})
    return rows


def table(dryrun_dir: str = "results/dryrun", mesh: str = "single", policy: str = "fsdp_tp"):
    """Full markdown table for EXPERIMENTS.md."""
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | MODEL/HLO | roofline frac | temp GB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), r in load_cells(dryrun_dir, mesh, policy).items():
        row = roofline_row(r)
        if row.get("status") != "ok":
            lines.append(f"| {arch} | {shape} | — | — | — | {row.get('status')} ({row.get('reason','')[:40]}) | — | — | — |")
            continue
        lines.append(
            f"| {arch} | {shape} | {row['compute_s']:.3g} | {row['memory_s']:.3g} | "
            f"{row['collective_s']:.3g} | **{row['dominant']}** | {row['model_hlo_ratio']:.2f} | "
            f"{row['roofline_fraction']:.3f} | {row['temp_gb']:.1f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    import sys

    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    policy = sys.argv[2] if len(sys.argv) > 2 else "fsdp_tp"
    print(table(mesh=mesh, policy=policy))
