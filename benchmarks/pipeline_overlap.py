"""Preprocess/feature overlap benchmark: pipelined vs sequential execution.

The PC2IM accelerator's dataflow win is stage overlap: the CAM half updates
temporary distances while search proceeds, and the SC-CIM feature engine
consumes neighborhoods as they stream in.  The software mirror is
`PipelinedExecutor`: micro-batch k+1's preprocessing (MSP + FPS + lattice
query — the params-free half) runs while micro-batch k is still inside the
feature MLPs.  This lane measures that overlap head to head over one stream
of identical micro-batches:

  * sequential — the plain serving path: one fused `accel.infer` per
    micro-batch, blocked on before the next one starts (exactly what a
    non-pipelined replica does);
  * pipelined  — `accel.infer_pipelined` over the same batches: two jitted
    sub-artifacts, double-buffered hand-off, no block between stages.

Both paths produce bitwise-identical logits (pinned by
tests/test_pipelined_accelerator.py); only the schedule differs.  Rows
(printed by benchmarks/run.py as name,us_per_call,derived):

  pipeline/stage_costs       : per-micro-batch preprocess vs feature wall time
                               (the balance bounds the attainable overlap)
  pipeline/sequential_bBxK   : us = wall time for the whole stream, note =
                               clouds/s
  pipeline/pipelined_bBxK    : same, through the PipelinedExecutor
  pipeline/overlap_bBxK      : derived = pipelined/sequential throughput ratio
                               (>= 1.15x is the acceptance bar for the smoke
                               lane; the ideal is (t_pre+t_feat)/max(...))

Wall times are best-of-`trials` (the stream is deterministic; best-of
suppresses scheduler noise on small shared hosts).  The fp32 policy is used
because its stages are comparably sized on CPU; the SC integer matmul path
is feature-dominated off-TPU and pipelines to ~1x (see docs/BENCHMARKS.md).
"""

from __future__ import annotations

import time

import numpy as np


def _best_of(fn, trials: int) -> float:
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(smoke: bool = False, seed: int = 0) -> list[dict]:
    import jax

    from repro.configs.base import get_config
    from repro.core.accelerator import get_accelerator
    from repro.core.policy import ExecutionPolicy
    from repro.data.pointclouds import sample_batch

    cfg = get_config("pointnet2-cls", smoke=True)
    b = 8
    k = 10 if smoke else 16
    trials = 3 if smoke else 5

    accel_seq = get_accelerator(cfg, ExecutionPolicy())
    accel_pipe = get_accelerator(cfg, ExecutionPolicy(pipeline="pipelined"))
    params = accel_seq.init(jax.random.PRNGKey(seed))
    batches = [
        np.asarray(sample_batch(jax.random.PRNGKey(seed + 1 + i), b, cfg.n_points)[0])
        for i in range(k)
    ]

    def sequential():
        return [
            np.asarray(jax.block_until_ready(accel_seq.infer(params, x)))
            for x in batches
        ]

    def pipelined():
        return [np.asarray(x) for x in accel_pipe.infer_pipelined(params, batches)]

    sequential()  # compile the fused artifact
    pipelined()  # compile both sub-artifacts

    # stage balance: how much overlap is there to win?
    pre = accel_pipe.preprocess_stage(batches[0])
    jax.block_until_ready(pre)
    t0 = time.perf_counter()
    for _ in range(trials * 2):
        jax.block_until_ready(accel_pipe.preprocess_stage(batches[0]))
    t_pre = (time.perf_counter() - t0) / (trials * 2)
    t0 = time.perf_counter()
    for _ in range(trials * 2):
        jax.block_until_ready(accel_pipe.feature_stage(params, batches[0], pre))
    t_feat = (time.perf_counter() - t0) / (trials * 2)

    wall_s = _best_of(sequential, trials)
    wall_p = _best_of(pipelined, trials)
    thr_s = b * k / wall_s
    thr_p = b * k / wall_p
    ideal = (t_pre + t_feat) / max(t_pre, t_feat)

    tag = f"b{b}x{k}"
    return [
        {
            "name": "pipeline/stage_costs",
            "us": float("nan"),
            "note": (
                f"pre {t_pre * 1e3:.1f}ms feat {t_feat * 1e3:.1f}ms per batch"
                f" (ideal overlap {ideal:.2f}x)"
            ),
        },
        {
            "name": f"pipeline/sequential_{tag}",
            "us": wall_s * 1e6,
            "note": f"{thr_s:.1f} clouds/s (fused infer, blocking per batch)",
        },
        {
            "name": f"pipeline/pipelined_{tag}",
            "us": wall_p * 1e6,
            "note": f"{thr_p:.1f} clouds/s (two-stage double-buffered)",
        },
        {
            "name": f"pipeline/overlap_{tag}",
            "us": float("nan"),
            "note": f"pipelined/sequential throughput {thr_p / thr_s:.2f}x",
        },
    ]
