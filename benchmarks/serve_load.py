"""Open-loop load benchmark: dynamic-batching runtime vs naive per-request serving.

Poisson arrivals (seeded, open-loop: the generator never waits for the
server, so queueing delay is measured honestly) of mixed-size clouds drawn
from data/pointclouds, fired at several arrival rates against

  * naive   — the synchronous per-request path: one worker thread calling
    `make_pointcloud_serve_fns(batch_size=1)["serve_batch"]` per request
    (every request pays a full B=1 artifact call); and
  * runtime — `ServingRuntime` with shape buckets + dynamic micro-batching
    over the same params and compiled-artifact cache.

Rates are calibrated to the measured naive service time on THIS host
(multiples of the naive capacity 1/s_naive), so the comparison is
machine-independent: below capacity both paths keep up and latencies are
comparable; above it the naive path's queue grows without bound while the
batcher amortises the fixed per-call cost over up to `max_batch` clouds.

Rows (printed by benchmarks/run.py as name,us_per_call,derived):
  serve/{path}_r{mult}x : us = p95 latency; derived = throughput + detail.

`run_cache` is the cross-request preprocess-cache benchmark: a
temporally-correlated sweep trace (a pool of static scenes visited
cyclically, duplicate fraction configurable) fired at a cached and an
uncached ServingRuntime.  It ASSERTS hit-rate > 0 on the duplicate trace
and bitwise parity of every response against an uncached direct
recomputation — a failed assertion fails the CI bench-smoke lane.
  serve_cache/{path}_d{dup} : us = p95 latency; derived = throughput + cache detail.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

CLOUD_SIZES = (160, 256, 320)  # mixed ragged sizes (pad / exact / subsample)
BUCKETS = (192, 256)


def _make_clouds(n_requests: int, width: int, seed: int = 0) -> list[np.ndarray]:
    import jax

    from repro.data.pointclouds import sample_batch

    pts, _, _ = sample_batch(jax.random.PRNGKey(seed), n_requests, max(CLOUD_SIZES))
    pts = np.asarray(pts, np.float32)
    if width > 3:
        pts = np.concatenate(
            [pts, np.zeros((*pts.shape[:2], width - 3), np.float32)], axis=-1
        )
    return [pts[i, : CLOUD_SIZES[i % len(CLOUD_SIZES)]] for i in range(n_requests)]


def _open_loop(submit_fn, clouds, arrivals_s):
    """Fire clouds at their arrival instants; returns (latencies, n_rejected,
    wall_s).  Latency = completion - arrival (queueing included), recorded in
    each future's done-callback so slow waiters don't distort it."""
    lock = threading.Lock()
    latencies: list[float] = []
    rejected = 0
    pending = []
    t0 = time.perf_counter()
    for cloud, at in zip(clouds, arrivals_s):
        wait = (t0 + at) - time.perf_counter()
        if wait > 0:
            time.sleep(wait)
        t_arr = time.perf_counter()

        def _record(fut, t_arr=t_arr):
            if fut.exception() is None:
                with lock:
                    latencies.append(time.perf_counter() - t_arr)

        try:
            fut = submit_fn(cloud)
        except Exception:  # noqa: BLE001 — admission backpressure (QueueFull)
            rejected += 1
            continue
        fut.add_done_callback(_record)
        pending.append(fut)
    for fut in pending:
        try:
            fut.result(timeout=600)
        except Exception:  # noqa: BLE001 — failed requests drop out of latency
            pass
    return latencies, rejected, time.perf_counter() - t0


def run(smoke: bool = False, seed: int = 0) -> list[dict]:
    import jax

    from repro.configs.base import get_config
    from repro.core.accelerator import get_accelerator
    from repro.serve import (
        PointCloudServeConfig,
        RuntimeConfig,
        ServingRuntime,
        make_pointcloud_serve_fns,
    )

    cfg = get_config("pointnet2-cls", smoke=True)
    width = 3 + cfg.in_features
    accel = get_accelerator(cfg)
    params = accel.init(jax.random.PRNGKey(seed))

    n_requests = 40 if smoke else 96
    rate_mults = (3.0,) if smoke else (0.8, 2.0, 4.0)
    clouds = _make_clouds(n_requests, width, seed)

    # naive per-request path (B=1 artifact), one worker thread
    naive = make_pointcloud_serve_fns(cfg, PointCloudServeConfig(batch_size=1))

    def naive_one(cloud):
        return naive["serve_batch"](params, [cloud])[0]

    naive_one(clouds[0])  # warm the B=1 artifact
    t = time.perf_counter()
    for c in clouds[:4]:
        naive_one(c)
    s_naive = (time.perf_counter() - t) / 4  # measured service time -> capacity

    # max_batch=4: the occupancy/latency sweet spot on small hosts — B=4
    # roughly halves the per-cloud cost vs B=1 while a partial flush stays
    # cheap; max_wait ~ a few service times bounds the added latency.
    rt_cfg = RuntimeConfig(
        max_batch=4,
        max_wait_s=min(0.02, 4 * s_naive),
        max_queue=max(64, n_requests),
        buckets=BUCKETS,
    )
    rows = []
    for mult in rate_mults:
        rate = mult / s_naive
        rng = np.random.default_rng(seed + int(mult * 10))
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))

        with ThreadPoolExecutor(max_workers=1) as ex:
            lat_n, rej_n, wall_n = _open_loop(
                lambda c: ex.submit(naive_one, c), clouds, arrivals
            )
        runtime = ServingRuntime(cfg, params, rt_cfg)
        runtime.warmup()
        with runtime:
            lat_r, rej_r, wall_r = _open_loop(runtime.submit, clouds, arrivals)
        snap = runtime.metrics.snapshot()

        for tag, lat, rej, wall, extra in (
            ("naive", lat_n, rej_n, wall_n, ""),
            ("runtime", lat_r, rej_r, wall_r, f" occ={snap.mean_occupancy:.2f}"),
        ):
            thr = len(lat) / wall if wall > 0 else 0.0
            p95 = float(np.percentile(lat, 95)) if lat else float("nan")
            rows.append({
                "name": f"serve/{tag}_r{mult:g}x",
                "us": p95 * 1e6,
                "note": (
                    f"{thr:.1f} req/s (rate {rate:.1f}/s; p95 {p95 * 1e3:.1f}ms;"
                    f" rej {rej}){extra}"
                ),
            })
        thr_n = len(lat_n) / wall_n if wall_n else 0.0
        thr_r = len(lat_r) / wall_r if wall_r else 0.0
        rows.append({
            "name": f"serve/speedup_r{mult:g}x",
            "us": float("nan"),
            "note": f"runtime/naive throughput {thr_r / thr_n:.2f}x" if thr_n else "n/a",
        })
    return rows


def _sweep_trace(n_requests: int, dup_frac: float, n_points: int, width: int, seed: int):
    """Temporally-correlated sweep trace over a pool of static scenes.

    `n_unique = n_requests * (1 - dup_frac)` distinct scenes are visited
    cyclically — the multi-camera static-rig pattern where every pass after
    the first re-observes scenes already served.  Scenes are snapped to the
    content-hash lattice, so repeats are exact duplicates and EVERY response
    (hit or miss) must be bitwise-equal to the scene's uncached
    recomputation; sub-step sensor jitter keying identically is pinned by
    tests/test_hashing.py.  Returns (scenes, visit order).
    """
    import jax

    from repro.data.pointclouds import sample_batch
    from repro.serve.hashing import DEFAULT_QUANT_STEP

    n_unique = max(1, int(round(n_requests * (1.0 - dup_frac))))
    pts, _, _ = sample_batch(jax.random.PRNGKey(seed), n_unique, n_points)
    pts = np.asarray(pts, np.float64)
    if width > 3:
        pts = np.concatenate(
            [pts, np.zeros((*pts.shape[:2], width - 3), np.float64)], axis=-1
        )
    step = DEFAULT_QUANT_STEP
    scenes = [
        (np.round(pts[i] / step) * step).astype(np.float32) for i in range(n_unique)
    ]
    return scenes, [i % n_unique for i in range(n_requests)]


class _IndexedSubmit:
    """submit_fn wrapper keeping (trace index, future) pairs for parity checks."""

    def __init__(self, runtime):
        self.runtime = runtime
        self.i = -1
        self.futs: list[tuple] = []

    def __call__(self, cloud):
        self.i += 1  # counts every attempt, so indices survive rejections
        fut = self.runtime.submit(cloud)
        self.futs.append((self.i, fut))
        return fut


def run_cache(smoke: bool = False, seed: int = 0) -> list[dict]:
    """Preprocess-cache benchmark: cached vs uncached runtime on sweep traces.

    The >= 50%-duplicate trace is where the cache earns its place (all-hit
    micro-batches skip the preprocess stage outright); the 0%-duplicate
    trace checks the cache-aware path costs nothing measurable when nothing
    repeats.  Raises RuntimeError when the duplicate trace records no hits
    or any response differs bitwise from its scene's uncached recomputation.

    Each (trace, runtime) pair is measured best-of-N: a 48-request open loop
    on a shared host has large run-to-run noise (one descheduled batch moves
    throughput ~20%), and the best rep is the closest observation of what
    each configuration can actually sustain.  Correctness (bitwise parity,
    hits recorded) is asserted on EVERY rep, not just the reported one.
    """
    import jax

    from repro.configs.base import get_config
    from repro.core.accelerator import get_accelerator
    from repro.serve import RuntimeConfig, ServingRuntime

    cfg = get_config("pointnet2-cls", smoke=True)
    width = 3 + cfg.in_features
    n_points = cfg.n_points
    accel = get_accelerator(cfg)
    params = accel.init(jax.random.PRNGKey(seed))

    n_requests = 64 if smoke else 120
    dup_fracs = (0.6, 0.0) if smoke else (0.75, 0.5, 0.0)
    max_batch = 4

    # calibrate the arrival rate to THIS host's uncached capacity: per-request
    # service time at B=max_batch through the fused artifact (min of 5 — the
    # floor is far more stable run-to-run than a small-sample mean, and the
    # rate must not swing with scheduler noise)
    warm = np.zeros((max_batch, n_points, width), np.float32)
    jax.block_until_ready(accel.infer(params, warm))
    times = []
    for _ in range(5):
        t = time.perf_counter()
        jax.block_until_ready(accel.infer(params, warm))
        times.append(time.perf_counter() - t)
    s_req = min(times) / max_batch
    rate = 1.5 / s_req  # above uncached capacity: backlog unless work shrinks

    n_reps = 5
    rows = []
    for dup in dup_fracs:
        scenes, order = _sweep_trace(n_requests, dup, n_points, width, seed)
        trace = [scenes[s] for s in order]
        # rep k of BOTH configurations replays the same arrival schedule, so
        # each rep is a paired comparison under identical offered load
        arrivals_by_rep = [
            np.cumsum(
                np.random.default_rng(seed + int(dup * 100) + 7919 * r)
                .exponential(1.0 / rate, size=n_requests)
            )
            for r in range(n_reps)
        ]

        # uncached direct reference, one per scene (bitwise target for BOTH
        # paths: scenes are lattice-snapped so hits serve the same bytes)
        refs = []
        for scene in scenes:
            batch = np.zeros((max_batch, n_points, width), np.float32)
            batch[0] = scene
            refs.append(np.asarray(accel.infer(params, batch))[0])

        # reps INTERLEAVE the two configurations (uncached then cached within
        # each rep) so host drift — turbo decay, noisy neighbors — lands on
        # both sides of every pair instead of on whichever ran second
        best = {}  # tag -> (thr, p95, rej, snap, stats) of the best-thr rep
        best_p95 = {}
        for arrivals in arrivals_by_rep:
            for tag, cache_bytes in (("uncached", 0), ("cached", 64 * 2**20)):
                rt = ServingRuntime(cfg, params, RuntimeConfig(
                    max_batch=max_batch,
                    max_wait_s=min(0.02, 4 * s_req * max_batch),
                    max_queue=max(64, n_requests),
                    buckets=(n_points,),
                    cache_max_bytes=cache_bytes,
                ))
                rt.warmup()
                submit = _IndexedSubmit(rt)
                with rt:
                    lat, rej, wall = _open_loop(submit, trace, arrivals)
                snap = rt.metrics.snapshot()
                stats = rt.cache_stats()

                mismatches = 0
                for i, fut in submit.futs:
                    if fut.exception() is not None:
                        continue
                    if not np.array_equal(fut.result(), refs[order[i]]):
                        mismatches += 1
                if mismatches:
                    raise RuntimeError(
                        f"serve_cache d{dup:g} {tag}: {mismatches} responses "
                        "differ bitwise from uncached recomputation"
                    )
                if tag == "cached" and dup > 0 and (stats is None or stats.hits == 0):
                    raise RuntimeError(
                        f"serve_cache d{dup:g}: duplicate trace recorded no "
                        f"cache hits ({stats})"
                    )

                thr = len(lat) / wall if wall > 0 else 0.0
                p95 = float(np.percentile(lat, 95)) if lat else float("nan")
                best_p95[tag] = min(best_p95.get(tag, float("inf")), p95)
                if tag not in best or thr > best[tag][0]:
                    best[tag] = (thr, p95, rej, snap, stats)

        results = {}
        for tag in ("uncached", "cached"):
            thr, _, rej, snap, stats = best[tag]
            p95 = best_p95[tag]
            results[tag] = (thr, p95)

            extra = ""
            if tag == "cached":
                extra = (
                    f" hit={snap.cache_hit_rate:.2f} skip={snap.preprocess_skipped}"
                    f" saved={snap.cache_saved_s * 1e3:.0f}ms"
                    f" resident={stats.bytes // 1024}KiB"
                )
            rows.append({
                "name": f"serve_cache/{tag}_d{int(dup * 100)}",
                "us": p95 * 1e6,
                "note": (
                    f"{thr:.1f} req/s best-of-{n_reps} (rate {rate:.1f}/s;"
                    f" p95 {p95 * 1e3:.1f}ms; rej {rej}){extra}"
                ),
            })

        (thr_u, p95_u), (thr_c, p95_c) = results["uncached"], results["cached"]
        rows.append({
            "name": f"serve_cache/speedup_d{int(dup * 100)}",
            "us": float("nan"),
            "note": (
                f"cached/uncached throughput {thr_c / thr_u:.2f}x, "
                f"p95 {p95_u / p95_c:.2f}x lower" if thr_u and p95_c else "n/a"
            ),
        })
    return rows
