"""Open-loop load benchmark: dynamic-batching runtime vs naive per-request serving.

Poisson arrivals (seeded, open-loop: the generator never waits for the
server, so queueing delay is measured honestly) of mixed-size clouds drawn
from data/pointclouds, fired at several arrival rates against

  * naive   — the synchronous per-request path: one worker thread calling
    `make_pointcloud_serve_fns(batch_size=1)["serve_batch"]` per request
    (every request pays a full B=1 artifact call); and
  * runtime — `ServingRuntime` with shape buckets + dynamic micro-batching
    over the same params and compiled-artifact cache.

Rates are calibrated to the measured naive service time on THIS host
(multiples of the naive capacity 1/s_naive), so the comparison is
machine-independent: below capacity both paths keep up and latencies are
comparable; above it the naive path's queue grows without bound while the
batcher amortises the fixed per-call cost over up to `max_batch` clouds.

Rows (printed by benchmarks/run.py as name,us_per_call,derived):
  serve/{path}_r{mult}x : us = p95 latency; derived = throughput + detail.

`run_cache` is the cross-request preprocess-cache benchmark: a
temporally-correlated sweep trace (a pool of static scenes visited
cyclically, duplicate fraction configurable) fired at a cached and an
uncached ServingRuntime.  It ASSERTS hit-rate > 0 on the duplicate trace
and bitwise parity of every response against an uncached direct
recomputation — a failed assertion fails the CI bench-smoke lane.
  serve_cache/{path}_d{dup} : us = p95 latency; derived = throughput + cache detail.

`run_slo` is the SLO control-plane benchmark: a two-class (interactive /
bulk) trace offered ABOVE the pool's measured capacity, with replica 1
chaos-killed mid-run and the autoscaler rejoining it warm.  It ASSERTS the
load-shedding and recovery contracts — the interactive class sheds and
expires nothing and holds its p95 inside the deadline budget, the bulk
class absorbs ALL shedding, and post-rejoin throughput recovers to within
10% of the pre-kill rate — so a regression in the control plane fails the
CI bench-smoke lane, not just a dashboard.
  serve_slo/{class} : us = p95 latency; derived = per-class counts + detail.

`run_adapt` is the adaptive control-plane benchmark: a shifted
size-distribution trace offered above the static runtime's measured
capacity, static knobs vs the AdaptiveController retuning them mid-trace
through the pause-free warm-then-swap path.  It ASSERTS the controller
actuated with logged evidence, bitwise per-request parity vs the direct
accelerator reference across the live swap, zero lost/duplicated
requests, adapted >= static in throughput or p95, and — on a saturating
two-class burst — the DRR weight-share floor for bulk with zero
interactive deadline expiries.
  serve_adapt/{static,adaptive,gain,drr} : us = p95; derived = detail.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

CLOUD_SIZES = (160, 256, 320)  # mixed ragged sizes (pad / exact / subsample)
BUCKETS = (192, 256)


def _make_clouds(n_requests: int, width: int, seed: int = 0) -> list[np.ndarray]:
    import jax

    from repro.data.pointclouds import sample_batch

    pts, _, _ = sample_batch(jax.random.PRNGKey(seed), n_requests, max(CLOUD_SIZES))
    pts = np.asarray(pts, np.float32)
    if width > 3:
        pts = np.concatenate(
            [pts, np.zeros((*pts.shape[:2], width - 3), np.float32)], axis=-1
        )
    return [pts[i, : CLOUD_SIZES[i % len(CLOUD_SIZES)]] for i in range(n_requests)]


def _open_loop(submit_fn, clouds, arrivals_s):
    """Fire clouds at their arrival instants; returns (latencies, n_rejected,
    wall_s).  Latency = completion - arrival (queueing included), recorded in
    each future's done-callback so slow waiters don't distort it."""
    lock = threading.Lock()
    latencies: list[float] = []
    rejected = 0
    pending = []
    t0 = time.perf_counter()
    for cloud, at in zip(clouds, arrivals_s):
        wait = (t0 + at) - time.perf_counter()
        if wait > 0:
            time.sleep(wait)
        t_arr = time.perf_counter()

        def _record(fut, t_arr=t_arr):
            if fut.exception() is None:
                with lock:
                    latencies.append(time.perf_counter() - t_arr)

        try:
            fut = submit_fn(cloud)
        except Exception:  # noqa: BLE001 — admission backpressure (QueueFull)
            rejected += 1
            continue
        fut.add_done_callback(_record)
        pending.append(fut)
    for fut in pending:
        try:
            fut.result(timeout=600)
        except Exception:  # noqa: BLE001 — failed requests drop out of latency
            pass
    return latencies, rejected, time.perf_counter() - t0


def run(smoke: bool = False, seed: int = 0) -> list[dict]:
    import jax

    from repro.configs.base import get_config
    from repro.core.accelerator import get_accelerator
    from repro.serve import (
        PointCloudServeConfig,
        RuntimeConfig,
        ServingRuntime,
        make_pointcloud_serve_fns,
    )

    cfg = get_config("pointnet2-cls", smoke=True)
    width = 3 + cfg.in_features
    accel = get_accelerator(cfg)
    params = accel.init(jax.random.PRNGKey(seed))

    n_requests = 40 if smoke else 96
    rate_mults = (3.0,) if smoke else (0.8, 2.0, 4.0)
    clouds = _make_clouds(n_requests, width, seed)

    # naive per-request path (B=1 artifact), one worker thread
    naive = make_pointcloud_serve_fns(cfg, PointCloudServeConfig(batch_size=1))

    def naive_one(cloud):
        return naive["serve_batch"](params, [cloud])[0]

    naive_one(clouds[0])  # warm the B=1 artifact
    t = time.perf_counter()
    for c in clouds[:4]:
        naive_one(c)
    s_naive = (time.perf_counter() - t) / 4  # measured service time -> capacity

    # max_batch=4: the occupancy/latency sweet spot on small hosts — B=4
    # roughly halves the per-cloud cost vs B=1 while a partial flush stays
    # cheap; max_wait ~ a few service times bounds the added latency.
    rt_cfg = RuntimeConfig(
        max_batch=4,
        max_wait_s=min(0.02, 4 * s_naive),
        max_queue=max(64, n_requests),
        buckets=BUCKETS,
    )
    rows = []
    for mult in rate_mults:
        rate = mult / s_naive
        rng = np.random.default_rng(seed + int(mult * 10))
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))

        with ThreadPoolExecutor(max_workers=1) as ex:
            lat_n, rej_n, wall_n = _open_loop(
                lambda c: ex.submit(naive_one, c), clouds, arrivals
            )
        runtime = ServingRuntime(cfg, params, rt_cfg)
        runtime.warmup()
        with runtime:
            lat_r, rej_r, wall_r = _open_loop(runtime.submit, clouds, arrivals)
        snap = runtime.metrics.snapshot()

        for tag, lat, rej, wall, extra in (
            ("naive", lat_n, rej_n, wall_n, ""),
            ("runtime", lat_r, rej_r, wall_r, f" occ={snap.mean_occupancy:.2f}"),
        ):
            thr = len(lat) / wall if wall > 0 else 0.0
            p95 = float(np.percentile(lat, 95)) if lat else float("nan")
            rows.append({
                "name": f"serve/{tag}_r{mult:g}x",
                "us": p95 * 1e6,
                "note": (
                    f"{thr:.1f} req/s (rate {rate:.1f}/s; p95 {p95 * 1e3:.1f}ms;"
                    f" rej {rej}){extra}"
                ),
            })
        thr_n = len(lat_n) / wall_n if wall_n else 0.0
        thr_r = len(lat_r) / wall_r if wall_r else 0.0
        rows.append({
            "name": f"serve/speedup_r{mult:g}x",
            "us": float("nan"),
            "note": f"runtime/naive throughput {thr_r / thr_n:.2f}x" if thr_n else "n/a",
        })
    return rows


def _sweep_trace(n_requests: int, dup_frac: float, n_points: int, width: int, seed: int):
    """Temporally-correlated sweep trace over a pool of static scenes.

    `n_unique = n_requests * (1 - dup_frac)` distinct scenes are visited
    cyclically — the multi-camera static-rig pattern where every pass after
    the first re-observes scenes already served.  Scenes are snapped to the
    content-hash lattice, so repeats are exact duplicates and EVERY response
    (hit or miss) must be bitwise-equal to the scene's uncached
    recomputation; sub-step sensor jitter keying identically is pinned by
    tests/test_hashing.py.  Returns (scenes, visit order).
    """
    import jax

    from repro.data.pointclouds import sample_batch
    from repro.serve.hashing import DEFAULT_QUANT_STEP

    n_unique = max(1, int(round(n_requests * (1.0 - dup_frac))))
    pts, _, _ = sample_batch(jax.random.PRNGKey(seed), n_unique, n_points)
    pts = np.asarray(pts, np.float64)
    if width > 3:
        pts = np.concatenate(
            [pts, np.zeros((*pts.shape[:2], width - 3), np.float64)], axis=-1
        )
    step = DEFAULT_QUANT_STEP
    scenes = [
        (np.round(pts[i] / step) * step).astype(np.float32) for i in range(n_unique)
    ]
    return scenes, [i % n_unique for i in range(n_requests)]


class _IndexedSubmit:
    """submit_fn wrapper keeping (trace index, future) pairs for parity checks."""

    def __init__(self, runtime):
        self.runtime = runtime
        self.i = -1
        self.futs: list[tuple] = []

    def __call__(self, cloud):
        self.i += 1  # counts every attempt, so indices survive rejections
        fut = self.runtime.submit(cloud)
        self.futs.append((self.i, fut))
        return fut


def run_cache(smoke: bool = False, seed: int = 0) -> list[dict]:
    """Preprocess-cache benchmark: cached vs uncached runtime on sweep traces.

    The >= 50%-duplicate trace is where the cache earns its place (all-hit
    micro-batches skip the preprocess stage outright); the 0%-duplicate
    trace checks the cache-aware path costs nothing measurable when nothing
    repeats.  Raises RuntimeError when the duplicate trace records no hits
    or any response differs bitwise from its scene's uncached recomputation.

    Each (trace, runtime) pair is measured best-of-N: a 48-request open loop
    on a shared host has large run-to-run noise (one descheduled batch moves
    throughput ~20%), and the best rep is the closest observation of what
    each configuration can actually sustain.  Correctness (bitwise parity,
    hits recorded) is asserted on EVERY rep, not just the reported one.
    """
    import jax

    from repro.configs.base import get_config
    from repro.core.accelerator import get_accelerator
    from repro.serve import RuntimeConfig, ServingRuntime

    cfg = get_config("pointnet2-cls", smoke=True)
    width = 3 + cfg.in_features
    n_points = cfg.n_points
    accel = get_accelerator(cfg)
    params = accel.init(jax.random.PRNGKey(seed))

    n_requests = 64 if smoke else 120
    dup_fracs = (0.6, 0.0) if smoke else (0.75, 0.5, 0.0)
    max_batch = 4

    # calibrate the arrival rate to THIS host's uncached capacity: per-request
    # service time at B=max_batch through the fused artifact (min of 5 — the
    # floor is far more stable run-to-run than a small-sample mean, and the
    # rate must not swing with scheduler noise)
    warm = np.zeros((max_batch, n_points, width), np.float32)
    jax.block_until_ready(accel.infer(params, warm))
    times = []
    for _ in range(5):
        t = time.perf_counter()
        jax.block_until_ready(accel.infer(params, warm))
        times.append(time.perf_counter() - t)
    s_req = min(times) / max_batch
    rate = 1.5 / s_req  # above uncached capacity: backlog unless work shrinks

    n_reps = 5
    rows = []
    for dup in dup_fracs:
        scenes, order = _sweep_trace(n_requests, dup, n_points, width, seed)
        trace = [scenes[s] for s in order]
        # rep k of BOTH configurations replays the same arrival schedule, so
        # each rep is a paired comparison under identical offered load
        arrivals_by_rep = [
            np.cumsum(
                np.random.default_rng(seed + int(dup * 100) + 7919 * r)
                .exponential(1.0 / rate, size=n_requests)
            )
            for r in range(n_reps)
        ]

        # uncached direct reference, one per scene (bitwise target for BOTH
        # paths: scenes are lattice-snapped so hits serve the same bytes)
        refs = []
        for scene in scenes:
            batch = np.zeros((max_batch, n_points, width), np.float32)
            batch[0] = scene
            refs.append(np.asarray(accel.infer(params, batch))[0])

        # reps INTERLEAVE the two configurations (uncached then cached within
        # each rep) so host drift — turbo decay, noisy neighbors — lands on
        # both sides of every pair instead of on whichever ran second
        best = {}  # tag -> (thr, p95, rej, snap, stats) of the best-thr rep
        best_p95 = {}
        for arrivals in arrivals_by_rep:
            for tag, cache_bytes in (("uncached", 0), ("cached", 64 * 2**20)):
                rt = ServingRuntime(cfg, params, RuntimeConfig(
                    max_batch=max_batch,
                    max_wait_s=min(0.02, 4 * s_req * max_batch),
                    max_queue=max(64, n_requests),
                    buckets=(n_points,),
                    cache_max_bytes=cache_bytes,
                ))
                rt.warmup()
                submit = _IndexedSubmit(rt)
                with rt:
                    lat, rej, wall = _open_loop(submit, trace, arrivals)
                snap = rt.metrics.snapshot()
                stats = rt.cache_stats()

                mismatches = 0
                for i, fut in submit.futs:
                    if fut.exception() is not None:
                        continue
                    if not np.array_equal(fut.result(), refs[order[i]]):
                        mismatches += 1
                if mismatches:
                    raise RuntimeError(
                        f"serve_cache d{dup:g} {tag}: {mismatches} responses "
                        "differ bitwise from uncached recomputation"
                    )
                if tag == "cached" and dup > 0 and (stats is None or stats.hits == 0):
                    raise RuntimeError(
                        f"serve_cache d{dup:g}: duplicate trace recorded no "
                        f"cache hits ({stats})"
                    )

                thr = len(lat) / wall if wall > 0 else 0.0
                p95 = float(np.percentile(lat, 95)) if lat else float("nan")
                best_p95[tag] = min(best_p95.get(tag, float("inf")), p95)
                if tag not in best or thr > best[tag][0]:
                    best[tag] = (thr, p95, rej, snap, stats)

        results = {}
        for tag in ("uncached", "cached"):
            thr, _, rej, snap, stats = best[tag]
            p95 = best_p95[tag]
            results[tag] = (thr, p95)

            extra = ""
            if tag == "cached":
                extra = (
                    f" hit={snap.cache_hit_rate:.2f} skip={snap.preprocess_skipped}"
                    f" saved={snap.cache_saved_s * 1e3:.0f}ms"
                    f" resident={stats.bytes // 1024}KiB"
                )
            rows.append({
                "name": f"serve_cache/{tag}_d{int(dup * 100)}",
                "us": p95 * 1e6,
                "note": (
                    f"{thr:.1f} req/s best-of-{n_reps} (rate {rate:.1f}/s;"
                    f" p95 {p95 * 1e3:.1f}ms; rej {rej}){extra}"
                ),
            })

        (thr_u, p95_u), (thr_c, p95_c) = results["uncached"], results["cached"]
        rows.append({
            "name": f"serve_cache/speedup_d{int(dup * 100)}",
            "us": float("nan"),
            "note": (
                f"cached/uncached throughput {thr_c / thr_u:.2f}x, "
                f"p95 {p95_u / p95_c:.2f}x lower" if thr_u and p95_c else "n/a"
            ),
        })
    return rows


def _slo_attempt(cfg, params, s_req, *, n_requests, rate, high, low, seed):
    """One serve_slo trace: overload + mid-run kill; returns measurements.

    Drives a 2-replica runtime with shedding and the autoscaler attached,
    kills replica 1 at its `at_batch`-th real batch via the chaos injector,
    and records per-completion (class, arrival, done) stamps on
    time.monotonic() — the same clock the chaos/autoscaler events use, so
    the pre-kill and post-rejoin throughput windows line up exactly.
    """
    from repro.serve import (
        AutoscalerConfig,
        ChaosInjector,
        Fault,
        RuntimeConfig,
        ServingRuntime,
        Shed,
    )

    max_batch = 4
    s_batch = s_req * max_batch
    rt = ServingRuntime(cfg, params, RuntimeConfig(
        max_batch=max_batch,
        max_wait_s=min(0.02, 2 * s_batch),
        max_queue=max(48, n_requests // 4),
        buckets=(cfg.n_points,),
        n_replicas=2,
        shed_threshold=max(24, n_requests // 8),
        # rejoin-only autoscaler: depth thresholds out of reach, so the only
        # actions are fault rejoins — the axis this benchmark measures
        autoscaler=AutoscalerConfig(
            poll_interval_s=0.02,
            rejoin_delay_s=0.15,
            scale_up_depth=1e9,
            scale_down_depth=0.0,
            scale_down_ticks=10**9,
            cooldown_s=600.0,
        ),
    ))
    rt.warmup()
    # kill replica 1 roughly a third into its share of the trace: late
    # enough for a stable pre-kill window, early enough that the post-rejoin
    # window still sees plenty of traffic
    at_batch = max(2, n_requests // (max_batch * 2 * 3))
    chaos = ChaosInjector([Fault(replica_id=1, at_batch=at_batch, kind="kill")])
    chaos.attach(rt.pool)

    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    width = 3 + cfg.in_features
    cloud = np.zeros((cfg.n_points, width), np.float32)
    clouds = [
        (cloud + rng.standard_normal(cloud.shape).astype(np.float32))
        for _ in range(8)
    ]

    lock = threading.Lock()
    done = []  # (slo_name, t_arrival, t_done) of successful completions
    shed_by = {high.name: 0, low.name: 0}
    pending = []
    t0 = time.monotonic()
    with rt:
        for i in range(n_requests):
            wait = (t0 + arrivals[i]) - time.monotonic()
            if wait > 0:
                time.sleep(wait)
            slo = high if i % 3 == 0 else low
            t_arr = time.monotonic()

            def _record(fut, name=slo.name, t_arr=t_arr):
                if fut.exception() is None:
                    with lock:
                        done.append((name, t_arr, time.monotonic()))

            try:
                fut = rt.submit(clouds[i % len(clouds)], slo=slo)
            except Shed:
                shed_by[slo.name] += 1
                continue
            except Exception:  # noqa: BLE001 — queue-full backpressure
                continue
            fut.add_done_callback(_record)
            pending.append(fut)
        for fut in pending:
            try:
                fut.result(timeout=600)
            except Exception:  # noqa: BLE001 — shed/expired futures
                pass
        # hold the runtime open until the rejoin lands (bounded)
        deadline = time.monotonic() + 30
        while rt.metrics.rejoins < 1 and time.monotonic() < deadline:
            time.sleep(0.02)
    snap = rt.metrics.snapshot()
    kills = chaos.fired("kill")
    rejoins = [e for e in rt.autoscaler.events if e.action == "rejoin"]
    return {
        "snap": snap,
        "done": done,
        "shed_by": shed_by,
        "t_kill": kills[0].t if kills else None,
        "t_rejoin": rejoins[0].t if rejoins else None,
        "s_batch": s_batch,
    }


def _window_rate(done, t_lo, t_hi):
    """Completions/s inside [t_lo, t_hi]; (rate, count)."""
    n = sum(1 for _, _, t in done if t_lo <= t <= t_hi)
    span = t_hi - t_lo
    return (n / span if span > 0 else 0.0), n


def _probe_capacity(cfg, params, s_req, *, n_probe=48):
    """Closed-loop capacity probe: completions/s through a real 2-replica runtime.

    The overload trace must be calibrated against what the serving stack can
    actually sustain, not against n_replicas / s_infer: on a host where both
    replicas share one core (CI runners), two replicas do NOT double
    throughput, and an analytic rate would overload even the non-sheddable
    interactive share — the p95 assertion would then measure the host, not
    the control plane.  A closed-loop burst (submit everything, wait for
    completion) through the same runtime shape as the trace measures the
    true end-to-end rate, batching and scheduler overhead included.
    """
    from repro.serve import RuntimeConfig, ServingRuntime

    max_batch = 4
    s_batch = s_req * max_batch
    rt = ServingRuntime(cfg, params, RuntimeConfig(
        max_batch=max_batch,
        max_wait_s=min(0.02, 2 * s_batch),
        max_queue=2 * n_probe,
        buckets=(cfg.n_points,),
        n_replicas=2,
    ))
    rt.warmup()
    rng = np.random.default_rng(7)
    width = 3 + cfg.in_features
    clouds = [
        rng.standard_normal((cfg.n_points, width)).astype(np.float32)
        for _ in range(4)
    ]
    with rt:
        t0 = time.perf_counter()
        futs = [rt.submit(clouds[i % len(clouds)]) for i in range(n_probe)]
        for f in futs:
            f.result(timeout=600)
        wall = time.perf_counter() - t0
    return n_probe / wall


def run_slo(smoke: bool = False, seed: int = 0) -> list[dict]:
    """SLO control-plane benchmark: two-class overload + mid-run replica kill.

    One third of the trace is a non-sheddable interactive class with a
    deadline, the rest a sheddable bulk class, offered at 1.5x the measured
    2-replica capacity so the runtime MUST shed.  Replica 1 is killed
    mid-trace; the autoscaler rejoins it warm.  Self-asserting (raises
    RuntimeError, failing CI) on the control-plane contracts:

      * interactive: shed == 0, expired == 0, p95 <= the deadline budget;
      * bulk absorbs ALL shedding (and some shedding happened);
      * exactly one kill, at least one warm rejoin, and post-rejoin
        throughput >= 90% of the pre-kill rate.

    The throughput-recovery check compares completion rates in the
    [start, kill] and [rejoin + margin, end] windows on one shared host —
    an open loop this short is noisy, so the trace is retried up to 3 times
    and only a run that fails on its last attempt raises.  The class
    contracts (shed/expired/parity of counts) are asserted on EVERY
    attempt — they are deterministic and never excused by noise.
    """
    import jax

    from repro.configs.base import get_config
    from repro.core.accelerator import get_accelerator
    from repro.serve import SLOClass

    cfg = get_config("pointnet2-cls", smoke=True)
    width = 3 + cfg.in_features
    n_points = cfg.n_points
    accel = get_accelerator(cfg)
    params = accel.init(jax.random.PRNGKey(seed))

    max_batch = 4
    warm = np.zeros((max_batch, n_points, width), np.float32)
    jax.block_until_ready(accel.infer(params, warm))
    times = []
    for _ in range(5):
        t = time.perf_counter()
        jax.block_until_ready(accel.infer(params, warm))
        times.append(time.perf_counter() - t)
    s_req = min(times) / max_batch
    # 1.5x the MEASURED closed-loop capacity: sustained overload, so shedding
    # is guaranteed, while the interactive third (0.5x capacity) stays
    # servable — _probe_capacity explains why the rate cannot be derived
    # analytically from s_req and the replica count
    capacity = _probe_capacity(cfg, params, s_req)
    rate = 1.5 * capacity
    trace_s = 2.5 if smoke else 5.0
    n_requests = int(min(600 if smoke else 1200, max(96, rate * trace_s)))

    # deadline budget: generous on absolute terms AND in measured batch
    # units, so a slow host doesn't fail on calibration noise; the assertion
    # is against the p95 budget, the class deadline is 2x that (expired==0
    # is strict)
    s_eff = max_batch / capacity  # end-to-end batch time under serving
    p95_budget = max(0.3, 25 * s_eff)
    high = SLOClass(
        "interactive", priority=10, deadline_s=2 * p95_budget,
        sheddable=False, max_wait_s=min(0.005, s_eff),
    )
    low = SLOClass("bulk", priority=-10, deadline_s=None, sheddable=True)

    last_err = None
    for attempt in range(3):
        m = _slo_attempt(
            cfg, params, s_req,
            n_requests=n_requests, rate=rate, high=high, low=low,
            seed=seed + 101 * attempt,
        )
        snap, done = m["snap"], m["done"]
        hi_cls = snap.for_class(high.name)
        lo_cls = snap.for_class(low.name)
        lat_hi = [t1 - t_arr for name, t_arr, t1 in done if name == high.name]
        lat_lo = [t1 - t_arr for name, t_arr, t1 in done if name == low.name]
        p95_hi = float(np.percentile(lat_hi, 95)) if lat_hi else float("nan")
        p95_lo = float(np.percentile(lat_lo, 95)) if lat_lo else float("nan")

        # deterministic class contracts: asserted on every attempt
        if hi_cls is None or hi_cls.shed != 0 or hi_cls.expired != 0:
            raise RuntimeError(
                f"serve_slo: interactive class was shed/expired ({hi_cls})"
            )
        if snap.shed == 0 or lo_cls is None or lo_cls.shed != snap.shed:
            raise RuntimeError(
                "serve_slo: bulk did not absorb all shedding "
                f"(total {snap.shed}, bulk {lo_cls and lo_cls.shed})"
            )
        if snap.evictions < 1:
            raise RuntimeError("serve_slo: chaos kill did not evict")

        # noise-prone contracts: retried
        try:
            if not np.isfinite(p95_hi) or p95_hi > p95_budget:
                raise RuntimeError(
                    f"serve_slo: interactive p95 {p95_hi * 1e3:.1f}ms over "
                    f"budget {p95_budget * 1e3:.1f}ms"
                )
            if m["t_kill"] is None or m["t_rejoin"] is None or snap.rejoins < 1:
                raise RuntimeError(
                    f"serve_slo: kill/rejoin cycle incomplete "
                    f"(kill={m['t_kill']}, rejoin={m['t_rejoin']})"
                )
            t_first = min(t_arr for _, t_arr, _ in done)
            t_last = max(t1 for _, _, t1 in done)
            thr_pre, n_pre = _window_rate(done, t_first, m["t_kill"])
            thr_post, n_post = _window_rate(
                done, m["t_rejoin"] + 2 * m["s_batch"], t_last
            )
            if n_pre < 8 or n_post < 8:
                raise RuntimeError(
                    f"serve_slo: windows too thin (pre {n_pre}, post {n_post})"
                )
            if thr_post < 0.9 * thr_pre:
                raise RuntimeError(
                    f"serve_slo: post-rejoin throughput {thr_post:.1f}/s < 90% "
                    f"of pre-kill {thr_pre:.1f}/s"
                )
        except RuntimeError as e:
            last_err = e
            continue

        recovery_ms = (m["t_rejoin"] - m["t_kill"]) * 1e3
        return [
            {
                "name": "serve_slo/interactive",
                "us": p95_hi * 1e6,
                "note": (
                    f"completed={hi_cls.completed} shed=0 expired=0 "
                    f"p95 {p95_hi * 1e3:.1f}ms <= budget {p95_budget * 1e3:.0f}ms"
                ),
            },
            {
                "name": "serve_slo/bulk",
                "us": p95_lo * 1e6,
                "note": (
                    f"completed={lo_cls.completed} shed={lo_cls.shed} "
                    f"(absorbed 100% of shedding; rate {rate:.1f}/s = 1.5x cap)"
                ),
            },
            {
                "name": "serve_slo/recovery",
                "us": float("nan"),
                "note": (
                    f"kill->rejoin {recovery_ms:.0f}ms; thr pre {thr_pre:.1f}/s"
                    f" post {thr_post:.1f}/s ({thr_post / thr_pre:.2f}x);"
                    f" attempt {attempt + 1}/3"
                ),
            },
        ]
    raise RuntimeError(f"serve_slo: failed after 3 attempts: {last_err}")


# -- sharded mesh-replica lane ------------------------------------------------

# Child script for `run_shard`: runs under 4 FORCED host devices, which must
# be configured via XLA_FLAGS before jax initialises its backend — hence a
# subprocess, mirroring the tests/_multidev.py isolation rule.  Serves the
# same closed-loop trace through 1-device replicas (unsharded baseline) and
# 2-device mesh replicas in both sharding modes, self-asserting every
# response is bitwise-equal to the single-device reference before reporting
# any number (fp32 forward is batch-size independent bitwise, so B=1
# references are exact).  Rows come back as JSON via PC2IM_SHARD_OUT.
_SHARD_CHILD = """\
import json, os, time

import jax, numpy as np
from repro.configs.base import get_config
from repro.core.accelerator import get_accelerator
from repro.core.policy import ExecutionPolicy
from repro.serve import RuntimeConfig, ServingRuntime

smoke = bool(int(os.environ["PC2IM_SHARD_SMOKE"]))
seed = int(os.environ["PC2IM_SHARD_SEED"])
n_requests = 24 if smoke else 64

cfg = get_config("pointnet2-cls", smoke=True)
width = 3 + cfg.in_features
base = get_accelerator(cfg)
params = base.init(jax.random.PRNGKey(seed))
rng = np.random.default_rng(seed)
clouds = [
    rng.standard_normal((cfg.n_points, width)).astype(np.float32)
    for _ in range(n_requests)
]
refs = [np.asarray(base.infer(params, c[None]))[0] for c in clouds]

rows = []
for mode in (None, "batch", "tensor"):
    pol = ExecutionPolicy(sharding=mode)
    per = 1 if mode is None else 2
    rt = ServingRuntime(
        cfg,
        params,
        RuntimeConfig(
            max_batch=4, devices_per_replica=per, max_queue=max(64, n_requests)
        ),
        policy=pol,
    )
    rt.warmup((pol,))
    lats, outs = [], []
    t0 = time.perf_counter()
    with rt:
        futs = [(time.perf_counter(), rt.submit(c)) for c in clouds]
        for t_sub, f in futs:
            outs.append(f.result(timeout=600))
            lats.append(time.perf_counter() - t_sub)
    wall = time.perf_counter() - t0
    for o, r in zip(outs, refs):
        assert np.array_equal(o, r), (
            f"serve_shard: sharding={mode} response != single-device bits"
        )
    n_rep = len(rt.pool.replicas)
    tag = mode or "unsharded"
    rows.append({
        "name": f"serve_shard/{tag}",
        "us": float(np.percentile(lats, 95)) * 1e6,
        "note": (
            f"{len(outs) / wall:.1f} req/s over {n_rep}x{per}-device replicas"
            f" (forced host devices); parity bitwise-ok"
        ),
    })

with open(os.environ["PC2IM_SHARD_OUT"], "w") as f:
    json.dump(rows, f)
"""


def run_shard(smoke: bool = False, seed: int = 0) -> list[dict]:
    """Mesh-sharded replica lane: 2-device replicas vs 1-device replicas.

    Runs in a subprocess with ``xla_force_host_platform_device_count=4``
    (the parent process must keep its single-device view) and SELF-ASSERTS
    bitwise parity of every sharded response against the single-device
    reference before any throughput number is reported — a parity break
    fails the lane, not just a dashboard.

    Forced host devices timeshare one CPU, so the throughput columns here
    measure dispatch/overhead plumbing, not real multi-chip scaling.
      serve_shard/{mode} : us = p95 latency; derived = throughput + parity.
    """
    import json
    import os
    import subprocess
    import sys
    import tempfile

    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with tempfile.TemporaryDirectory() as td:
        out = os.path.join(td, "rows.json")
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        env["PYTHONPATH"] = "src"
        env["PC2IM_SHARD_OUT"] = out
        env["PC2IM_SHARD_SMOKE"] = str(int(smoke))
        env["PC2IM_SHARD_SEED"] = str(seed)
        res = subprocess.run(
            [sys.executable, "-c", _SHARD_CHILD],
            capture_output=True,
            text=True,
            timeout=1800,
            env=env,
            cwd=repo_root,
        )
        if res.returncode != 0:
            raise RuntimeError(
                f"serve_shard child failed (rc={res.returncode})\n"
                f"--- stdout tail ---\n{res.stdout[-2000:]}\n"
                f"--- stderr tail ---\n{res.stderr[-4000:]}"
            )
        with open(out) as f:
            return json.load(f)


# -- adaptive control-plane lane ----------------------------------------------


def _adapt_scene_pool(width: int, seed: int):
    """Shifted size distribution: clouds clustered well below the static
    256 bucket, so a static runtime pays heavy padding on every batch while
    the controller can re-bucket to the observed sizes.  A small pool of
    distinct scenes (4 per size) keeps the bitwise parity check cheap:
    references are computed per (scene, candidate bucket), not per request.
    """
    rng = np.random.default_rng(seed)
    sizes = (96, 128, 160)
    scenes = [
        rng.standard_normal((n, width)).astype(np.float32)
        for n in sizes
        for _ in range(4)
    ]
    return scenes


def _adapt_attempt(cfg, params, accel, scenes, order, arrivals, ad_cfg):
    """One paired static-vs-adaptive run; returns per-path measurements."""
    from repro.serve import RuntimeConfig, ServingRuntime

    trace = [scenes[s] for s in order]
    out = {}
    for tag in ("static", "adaptive"):
        rt = ServingRuntime(cfg, params, RuntimeConfig(
            max_batch=2,  # the deliberately conservative static default
            max_wait_s=0.005,
            max_queue=len(trace) + 64,
            buckets=(cfg.n_points,),
            adaptive=ad_cfg if tag == "adaptive" else None,
        ))
        rt.warmup()
        submit = _IndexedSubmit(rt)
        with rt:
            lat, rej, wall = _open_loop(submit, trace, arrivals)
        snap = rt.metrics.snapshot()

        # -- deterministic contracts, asserted on every attempt -----------
        # (1) no request lost or duplicated across any swap: every submit
        # produced a future that resolved exactly once, and the books match
        n_ok = sum(1 for _, f in submit.futs if f.exception() is None)
        n_err = sum(1 for _, f in submit.futs if f.exception() is not None)
        assert all(f.done() for _, f in submit.futs)
        if n_ok != len(lat) or n_ok + n_err + rej != len(trace):
            raise RuntimeError(
                f"serve_adapt {tag}: accounting broke — {n_ok} ok + {n_err} "
                f"failed + {rej} rejected != {len(trace)} offered "
                f"({len(lat)} latencies)"
            )
        if snap.completed != n_ok:
            raise RuntimeError(
                f"serve_adapt {tag}: metrics completed {snap.completed} != "
                f"{n_ok} resolved futures (lost or double-counted requests)"
            )

        # (2) bitwise parity: a mid-swap request may have been bucketed
        # under ANY bucket set that was ever active, so its response must
        # equal the direct accelerator reference at one candidate bucket
        from repro.serve import bucket_for, pad_cloud

        decisions = (
            rt.controller.decisions.all() if rt.controller is not None else ()
        )
        bucket_sets = [(cfg.n_points,)] + [
            tuple(d.value) for d in decisions if d.kind == "buckets" and d.applied
        ]
        ref_cache = {}

        def _ref(scene_id, bucket):
            key = (scene_id, bucket)
            if key not in ref_cache:
                scene = scenes[scene_id]
                batch = np.zeros((4, bucket, scene.shape[1]), np.float32)
                batch[0] = pad_cloud(scene, bucket)[0]
                ref_cache[key] = np.asarray(accel.infer(params, batch))[0]
            return ref_cache[key]

        for i, fut in submit.futs:
            if fut.exception() is not None:
                continue
            sid = order[i]
            n = scenes[sid].shape[0]
            candidates = {bucket_for(n, bs) for bs in bucket_sets}
            if not any(
                np.array_equal(fut.result(), _ref(sid, b)) for b in candidates
            ):
                raise RuntimeError(
                    f"serve_adapt {tag}: request {i} (n={n}) matches no "
                    f"candidate-bucket reference {sorted(candidates)}"
                )

        thr = len(lat) / wall if wall > 0 else 0.0
        p95 = float(np.percentile(lat, 95)) if lat else float("nan")
        out[tag] = {
            "thr": thr, "p95": p95, "rej": rej, "snap": snap,
            "decisions": decisions, "buckets": rt.buckets,
            "max_batch": rt.scheduler.config.max_batch,
        }

    # (3) the controller converged: at least one actuation, with evidence
    applied = [d for d in out["adaptive"]["decisions"] if d.applied]
    if not applied:
        raise RuntimeError(
            "serve_adapt: controller applied no reconfiguration "
            f"({len(out['adaptive']['decisions'])} decisions, none actuated)"
        )
    for d in applied:
        if not d.evidence or d.version < 1 or not d.reason:
            raise RuntimeError(
                f"serve_adapt: actuated decision lacks evidence: {d}"
            )
    return out


def _drr_attempt(cfg, params, s_batch, *, n_inter, n_bulk):
    """Saturating two-class burst through a DRR-weighted queue.

    Both lanes are fully backlogged from the start, so the completion
    stream directly exposes the drain shares; returns per-class completion
    stamps and the metrics snapshot.
    """
    from repro.serve import RuntimeConfig, ServingRuntime, SLOClass

    # generous absolute + measured budget: the deadline contract must
    # assert weighted fairness, not host speed
    deadline_s = max(20.0, 60 * s_batch) * (n_inter + n_bulk) / 72
    high = SLOClass("interactive", priority=10, deadline_s=deadline_s,
                    sheddable=False)
    low = SLOClass("bulk", priority=-10, deadline_s=None, sheddable=True)
    rt = ServingRuntime(cfg, params, RuntimeConfig(
        max_batch=4,
        max_wait_s=0.005,
        max_queue=2 * (n_inter + n_bulk),
        buckets=(cfg.n_points,),
        class_weights=(("interactive", 4.0), ("bulk", 1.0)),
    ))
    rt.warmup()
    rng = np.random.default_rng(11)
    clouds = [
        rng.standard_normal((cfg.n_points, 3 + cfg.in_features)).astype(np.float32)
        for _ in range(8)
    ]
    lock = threading.Lock()
    done = []  # (class name, completion t) in completion order
    with rt:
        futs = []
        i = b = 0
        for k in range(n_inter + n_bulk):
            # 2:1 interleave keeps both lanes backlogged from the first drain
            slo = high if (k % 3 < 2 and i < n_inter) or b >= n_bulk else low
            if slo is high:
                i += 1
            else:
                b += 1

            def _rec(fut, name=slo.name):
                if fut.exception() is None:
                    with lock:
                        done.append((name, time.monotonic()))

            fut = rt.submit(clouds[k % len(clouds)], slo=slo)
            fut.add_done_callback(_rec)
            futs.append(fut)
        for f in futs:
            try:
                f.result(timeout=600)
            except Exception:  # noqa: BLE001 — expiry counted via metrics
                pass
    return done, rt.metrics.snapshot(), high


def run_adapt(smoke: bool = False, seed: int = 0) -> list[dict]:
    """Adaptive control-plane benchmark: feedback-tuned knobs vs static.

    A shifted size distribution (clouds clustered at 96-160 points, well
    below the 256-point bucket) is offered ABOVE the static runtime's
    measured capacity to a runtime pinned at a conservative max_batch=2
    and to an identical runtime with the AdaptiveController attached.  The
    controller observes full batches + a growing backlog and doubles
    max_batch through the pause-free warm-then-swap reconfiguration path
    mid-trace, amortizing the per-batch serving overhead the static
    defaults keep paying.  (Bucket tuning is deliberately off in THIS lane:
    on this backend the model's native 256-point shape is the fastest
    compiled artifact, so re-bucketing to the observed sizes cannot win
    compute here — the quantile/waste proposal math is pinned by unit
    tests instead.)  Self-asserting (raises RuntimeError, failing the CI
    bench-smoke lane):

      * the controller applied >= 1 reconfiguration, every actuated
        decision carrying evidence and a scheduler-config version;
      * every response is bitwise-equal to a direct accelerator reference
        at one of the candidate buckets (a mid-swap request may have been
        legitimately bucketed under the old or the new set);
      * no request lost or duplicated across the swap: resolved futures +
        failures + rejections == offered, and metrics agree;
      * the adapted runtime beats static in throughput OR p95 (retried
        3x — a paired open loop on a shared host is noisy; the structural
        contracts above are asserted on every attempt);
      * DRR section: under a saturating two-class burst with weights
        interactive:bulk = 4:1, the bulk class's completion share over the
        both-backlogged window is >= 0.8x its 1/5 weight share and no
        interactive deadline expires.

      serve_adapt/{static,adaptive} : us = p95; derived = thr + knob trail.
      serve_adapt/drr : us = nan; derived = measured shares vs weights.
    """
    import jax

    from repro.configs.base import get_config
    from repro.core.accelerator import get_accelerator
    from repro.serve import AdaptiveConfig

    cfg = get_config("pointnet2-cls", smoke=True)
    width = 3 + cfg.in_features
    n_points = cfg.n_points
    accel = get_accelerator(cfg)
    params = accel.init(jax.random.PRNGKey(seed))

    # batch-time calibration (for the DRR deadline budget below)
    warm = np.zeros((4, n_points, width), np.float32)
    jax.block_until_ready(accel.infer(params, warm))
    times = []
    for _ in range(5):
        t = time.perf_counter()
        jax.block_until_ready(accel.infer(params, warm))
        times.append(time.perf_counter() - t)
    s_batch = min(times)
    # pre-trace the shapes the controller's max_batch ladder will warm
    # mid-run — pool.warmup then hits the process-wide jit cache, so the
    # swap cost measured in-trace is the control path, not XLA compile time
    for b in (2, 8):
        jax.block_until_ready(
            accel.infer(params, np.zeros((b, n_points, width), np.float32))
        )

    scenes = _adapt_scene_pool(width, seed)
    # closed-loop burst probe at the STATIC knobs: the offered rate is a
    # multiple of measured end-to-end capacity (not infer time alone, which
    # undercounts the per-batch serving overhead this lane is about)
    from repro.serve import RuntimeConfig, ServingRuntime

    probe_rt = ServingRuntime(cfg, params, RuntimeConfig(
        max_batch=2, max_wait_s=0.005, max_queue=512, buckets=(n_points,),
    ))
    probe_rt.warmup()
    with probe_rt:
        t0 = time.perf_counter()
        futs = [probe_rt.submit(scenes[i % len(scenes)]) for i in range(200)]
        for f in futs:
            f.result(timeout=600)
        cap = 200 / (time.perf_counter() - t0)

    rate = 1.25 * cap  # above static capacity: backlog must build
    n_requests = int(min(4000, max(192, rate * (2.5 if smoke else 5.0))))
    order = [i % len(scenes) for i in range(n_requests)]
    ad_cfg = AdaptiveConfig(
        poll_interval_s=0.05,
        min_samples=48,
        tune_buckets=False,  # native shape is fastest here; see docstring
        tune_max_batch=True,
        max_batch_bounds=(2, 8),
        min_batch_records=8,
        tune_wait=False,
        observe_s=0.3,
        rollback_factor=3.0,  # only a real regression reverts mid-benchmark
        cooldown_s=0.2,
        min_window_completions=8,
    )

    last_err = None
    for attempt in range(3):
        arrivals = np.cumsum(
            np.random.default_rng(seed + 311 * attempt)
            .exponential(1.0 / rate, size=n_requests)
        )
        m = _adapt_attempt(cfg, params, accel, scenes, order, arrivals, ad_cfg)
        st, ad = m["static"], m["adaptive"]
        try:
            if not (ad["thr"] >= st["thr"] or ad["p95"] <= st["p95"]):
                raise RuntimeError(
                    f"serve_adapt: adapted knobs beat static in neither "
                    f"throughput ({ad['thr']:.1f} vs {st['thr']:.1f} req/s) "
                    f"nor p95 ({ad['p95'] * 1e3:.1f} vs {st['p95'] * 1e3:.1f}ms)"
                )
        except RuntimeError as e:
            last_err = e
            continue
        break
    else:
        raise RuntimeError(f"serve_adapt: failed after 3 attempts: {last_err}")

    n_applied = sum(1 for d in ad["decisions"] if d.applied)
    first = next(d for d in ad["decisions"] if d.applied)
    rows = [
        {
            "name": "serve_adapt/static",
            "us": st["p95"] * 1e6,
            "note": (
                f"{st['thr']:.1f} req/s (rate {rate:.1f}/s; p95 "
                f"{st['p95'] * 1e3:.1f}ms; rej {st['rej']}) max_batch=2 fixed"
            ),
        },
        {
            "name": "serve_adapt/adaptive",
            "us": ad["p95"] * 1e6,
            "note": (
                f"{ad['thr']:.1f} req/s (p95 {ad['p95'] * 1e3:.1f}ms; rej "
                f"{ad['rej']}) {n_applied} actuations -> max_batch="
                f"{ad['max_batch']} (first: {first.kind} {first.previous}->"
                f"{first.value}, occ {first.evidence.get('occupancy', 0):.2f}, "
                f"depth {first.evidence.get('queue_depth', 0)}); "
                f"parity bitwise-ok"
            ),
        },
        {
            "name": "serve_adapt/gain",
            "us": float("nan"),
            "note": (
                f"adaptive/static throughput {ad['thr'] / st['thr']:.2f}x, "
                f"p95 {st['p95'] / ad['p95']:.2f}x lower"
                if st["thr"] and ad["p95"] else "n/a"
            ),
        },
    ]

    # -- weighted-fair drain under saturation ---------------------------------
    n_inter, n_bulk = (48, 24) if smoke else (96, 48)
    for attempt in range(3):
        done, snap, high = _drr_attempt(
            cfg, params, s_batch, n_inter=n_inter, n_bulk=n_bulk
        )
        # both lanes stay backlogged until the interactive lane drains at
        # ~(n_inter + n_inter/4) completions; measure inside that window
        window = int(n_inter * 1.05)
        n_bulk_done = sum(1 for name, _ in done[:window] if name == "bulk")
        share = n_bulk_done / window
        hi_cls = snap.for_class(high.name)
        try:
            if hi_cls is None or hi_cls.expired or hi_cls.completed != n_inter:
                raise RuntimeError(
                    f"serve_adapt/drr: interactive deadline contract broke "
                    f"({hi_cls})"
                )
            if share < 0.8 * (1.0 / 5.0):
                raise RuntimeError(
                    f"serve_adapt/drr: bulk share {share:.2f} < 0.8x its "
                    f"1/5 weight share over the backlogged window"
                )
        except RuntimeError as e:
            last_err = e
            continue
        rows.append({
            "name": "serve_adapt/drr",
            "us": float("nan"),
            "note": (
                f"weights 4:1 -> bulk share {share:.2f} of first {window} "
                f"completions (>= {0.8 / 5:.2f}); interactive expired=0 "
                f"({n_inter}+{n_bulk} burst); attempt {attempt + 1}/3"
            ),
        })
        break
    else:
        raise RuntimeError(f"serve_adapt/drr: failed after 3 attempts: {last_err}")
    return rows
