"""Open-loop load benchmark: dynamic-batching runtime vs naive per-request serving.

Poisson arrivals (seeded, open-loop: the generator never waits for the
server, so queueing delay is measured honestly) of mixed-size clouds drawn
from data/pointclouds, fired at several arrival rates against

  * naive   — the synchronous per-request path: one worker thread calling
    `make_pointcloud_serve_fns(batch_size=1)["serve_batch"]` per request
    (every request pays a full B=1 artifact call); and
  * runtime — `ServingRuntime` with shape buckets + dynamic micro-batching
    over the same params and compiled-artifact cache.

Rates are calibrated to the measured naive service time on THIS host
(multiples of the naive capacity 1/s_naive), so the comparison is
machine-independent: below capacity both paths keep up and latencies are
comparable; above it the naive path's queue grows without bound while the
batcher amortises the fixed per-call cost over up to `max_batch` clouds.

Rows (printed by benchmarks/run.py as name,us_per_call,derived):
  serve/{path}_r{mult}x : us = p95 latency; derived = throughput + detail.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

CLOUD_SIZES = (160, 256, 320)  # mixed ragged sizes (pad / exact / subsample)
BUCKETS = (192, 256)


def _make_clouds(n_requests: int, width: int, seed: int = 0) -> list[np.ndarray]:
    import jax

    from repro.data.pointclouds import sample_batch

    pts, _, _ = sample_batch(jax.random.PRNGKey(seed), n_requests, max(CLOUD_SIZES))
    pts = np.asarray(pts, np.float32)
    if width > 3:
        pts = np.concatenate(
            [pts, np.zeros((*pts.shape[:2], width - 3), np.float32)], axis=-1
        )
    return [pts[i, : CLOUD_SIZES[i % len(CLOUD_SIZES)]] for i in range(n_requests)]


def _open_loop(submit_fn, clouds, arrivals_s):
    """Fire clouds at their arrival instants; returns (latencies, n_rejected,
    wall_s).  Latency = completion - arrival (queueing included), recorded in
    each future's done-callback so slow waiters don't distort it."""
    lock = threading.Lock()
    latencies: list[float] = []
    rejected = 0
    pending = []
    t0 = time.perf_counter()
    for cloud, at in zip(clouds, arrivals_s):
        wait = (t0 + at) - time.perf_counter()
        if wait > 0:
            time.sleep(wait)
        t_arr = time.perf_counter()

        def _record(fut, t_arr=t_arr):
            if fut.exception() is None:
                with lock:
                    latencies.append(time.perf_counter() - t_arr)

        try:
            fut = submit_fn(cloud)
        except Exception:  # noqa: BLE001 — admission backpressure (QueueFull)
            rejected += 1
            continue
        fut.add_done_callback(_record)
        pending.append(fut)
    for fut in pending:
        try:
            fut.result(timeout=600)
        except Exception:  # noqa: BLE001 — failed requests drop out of latency
            pass
    return latencies, rejected, time.perf_counter() - t0


def run(smoke: bool = False, seed: int = 0) -> list[dict]:
    import jax

    from repro.configs.base import get_config
    from repro.core.accelerator import get_accelerator
    from repro.serve import (
        PointCloudServeConfig,
        RuntimeConfig,
        ServingRuntime,
        make_pointcloud_serve_fns,
    )

    cfg = get_config("pointnet2-cls", smoke=True)
    width = 3 + cfg.in_features
    accel = get_accelerator(cfg)
    params = accel.init(jax.random.PRNGKey(seed))

    n_requests = 40 if smoke else 96
    rate_mults = (3.0,) if smoke else (0.8, 2.0, 4.0)
    clouds = _make_clouds(n_requests, width, seed)

    # naive per-request path (B=1 artifact), one worker thread
    naive = make_pointcloud_serve_fns(cfg, PointCloudServeConfig(batch_size=1))

    def naive_one(cloud):
        return naive["serve_batch"](params, [cloud])[0]

    naive_one(clouds[0])  # warm the B=1 artifact
    t = time.perf_counter()
    for c in clouds[:4]:
        naive_one(c)
    s_naive = (time.perf_counter() - t) / 4  # measured service time -> capacity

    # max_batch=4: the occupancy/latency sweet spot on small hosts — B=4
    # roughly halves the per-cloud cost vs B=1 while a partial flush stays
    # cheap; max_wait ~ a few service times bounds the added latency.
    rt_cfg = RuntimeConfig(
        max_batch=4,
        max_wait_s=min(0.02, 4 * s_naive),
        max_queue=max(64, n_requests),
        buckets=BUCKETS,
    )
    rows = []
    for mult in rate_mults:
        rate = mult / s_naive
        rng = np.random.default_rng(seed + int(mult * 10))
        arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))

        with ThreadPoolExecutor(max_workers=1) as ex:
            lat_n, rej_n, wall_n = _open_loop(
                lambda c: ex.submit(naive_one, c), clouds, arrivals
            )
        runtime = ServingRuntime(cfg, params, rt_cfg)
        runtime.warmup()
        with runtime:
            lat_r, rej_r, wall_r = _open_loop(runtime.submit, clouds, arrivals)
        snap = runtime.metrics.snapshot()

        for tag, lat, rej, wall, extra in (
            ("naive", lat_n, rej_n, wall_n, ""),
            ("runtime", lat_r, rej_r, wall_r, f" occ={snap.mean_occupancy:.2f}"),
        ):
            thr = len(lat) / wall if wall > 0 else 0.0
            p95 = float(np.percentile(lat, 95)) if lat else float("nan")
            rows.append({
                "name": f"serve/{tag}_r{mult:g}x",
                "us": p95 * 1e6,
                "note": (
                    f"{thr:.1f} req/s (rate {rate:.1f}/s; p95 {p95 * 1e3:.1f}ms;"
                    f" rej {rej}){extra}"
                ),
            })
        thr_n = len(lat_n) / wall_n if wall_n else 0.0
        thr_r = len(lat_r) / wall_r if wall_r else 0.0
        rows.append({
            "name": f"serve/speedup_r{mult:g}x",
            "us": float("nan"),
            "note": f"runtime/naive throughput {thr_r / thr_n:.2f}x" if thr_n else "n/a",
        })
    return rows
