"""Fig 13: system-level speedup + energy efficiency vs TiPU / baseline-1 / GPU."""

from __future__ import annotations

from repro.core import energy as E


def run() -> list[dict]:
    sc, rep = E.calibrate_system()
    claims = {
        "speedup_vs_baseline2_tipu": "1.5x (abstract)",
        "speedup_vs_baseline1": "6.0x",
        "speedup_vs_gpu": "3.5x",
        "energy_eff_vs_baseline2_tipu": "2.7x",
        "energy_eff_vs_gpu": "1518.9x",
    }
    rows = [{"name": "fig13/pc2im_ms_per_frame", "value": rep["pc2im_ms"], "claim": ""}]
    for k, claim in claims.items():
        if k in rep:
            rows.append({"name": f"fig13/{k}", "value": rep[k], "claim": claim})
    # per-dataset speedups (Fig 13a sweeps datasets)
    for n, seg, nm in [(1024, False, "modelnet_1k"), (4096, True, "s3dis_4k"), (16384, True, "kitti_16k")]:
        w = E.make_pcn_workload(n, seg)
        t_pc = E.system_latency_s(w, "pc2im", sc)["total_s"]
        t_b2 = E.system_latency_s(w, "baseline2_tipu", sc)["total_s"]
        rows.append({"name": f"fig13/{nm}/speedup_vs_tipu", "value": t_b2 / t_pc,
                     "claim": "up to 1.5x"})
    return rows
