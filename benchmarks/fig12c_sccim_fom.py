"""Fig 12(c): SC-CIM vs BS-CIM vs BT-CIM FoM over storage-compute ratios,
plus the functional SC kernel's plane-count cycle model."""

from __future__ import annotations

from repro.core import energy as E


def run() -> list[dict]:
    rows = []
    for scr in [8, 16, 32, 64, 128, 256]:
        f = {s: E.sccim_fom(scr, s)["fom2"] for s in ["bs_cim", "bt_cim", "sc_cim"]}
        rows.append({"name": f"fig12c/scr{scr}/fom_sc_over_bs", "value": f["sc_cim"] / f["bs_cim"],
                     "claim": "5.2x @SCR8 -> 9.9x"})
        rows.append({"name": f"fig12c/scr{scr}/fom_sc_over_bt", "value": f["sc_cim"] / f["bt_cim"],
                     "claim": "2.0x @SCR8 -> 2.8x"})
    # cycle counts per 16-bit input (the 4x headline)
    rows.append({"name": "fig12c/cycles_bs_cim", "value": 16, "claim": "bit-serial"})
    rows.append({"name": "fig12c/cycles_sc_cim", "value": 4, "claim": "4x fewer (C4)"})
    return rows
