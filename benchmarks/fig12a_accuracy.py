"""Fig 12(a) analogue: accuracy/quality of approximate sampling + 16-bit PTQ.

The paper validates that L1+MSP sampling and 16b quantization cost <2% and
<0.3% accuracy respectively.  Without ModelNet/S3DIS offline we measure:
  (1) sampling-quality metrics on procedural clouds — coverage-radius ratio
      (L1-FPS vs exact L2-FPS) and lattice-vs-ball neighbour recall;
  (2) 16-bit PTQ round-trip error on coordinates and MLP outputs;
  (3) (with --steps) end-to-end PointNet2 classification accuracy trained
      identically under each preprocessing variant.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fps as F
from repro.core import query as Q
from repro.core.quant import ptq_error
from repro.data.pointclouds import sample_batch


def sampling_quality(n_clouds: int = 8, n_points: int = 512, k: int = 128) -> list[dict]:
    rows = []
    cov_ratio, recall, sep_ratio = [], [], []
    for s in range(n_clouds):
        pts, _, _ = sample_batch(jax.random.PRNGKey(s), 1, n_points)
        pts = pts[0]
        i_l2 = F.fps(pts, k, metric="l2")
        i_l1 = F.fps(pts, k, metric="l1")
        cov_ratio.append(float(F.coverage_radius(pts, i_l1) / F.coverage_radius(pts, i_l2)))
        sep_ratio.append(
            float(F.min_pairwise_separation(pts, i_l1) / F.min_pairwise_separation(pts, i_l2))
        )
        c = jnp.take(pts, i_l2, axis=0)
        ball = Q.ball_query(pts, c, 0.3, nsample=n_points)
        lat = Q.lattice_query(pts, c, 0.3, nsample=n_points)
        bm, lm_, bi, li = (np.array(ball.mask), np.array(lat.mask), np.array(ball.idx), np.array(lat.idx))
        tot = cap = 0
        for m in range(k):
            bset = set(bi[m][bm[m]].tolist())
            lset = set(li[m][lm_[m]].tolist())
            tot += len(bset)
            cap += len(bset & lset)
        recall.append(cap / max(tot, 1))
    rows.append({"name": "fig12a/l1_vs_l2_coverage_ratio", "value": float(np.mean(cov_ratio)),
                 "claim": "~1.0 (no explicit loss)"})
    rows.append({"name": "fig12a/l1_vs_l2_separation_ratio", "value": float(np.mean(sep_ratio)),
                 "claim": "~1.0"})
    rows.append({"name": "fig12a/lattice_neighbor_recall", "value": float(np.mean(recall)),
                 "claim": ">=0.97 (1.6R covers the L2 ball)"})
    # PTQ error
    pts, _, _ = sample_batch(jax.random.PRNGKey(99), 1, 1024)
    rows.append({"name": "fig12a/ptq16_coord_rel_rms", "value": float(ptq_error(pts[0], 16)),
                 "claim": "<0.3% accuracy effect"})
    return rows


def train_accuracy_comparison(steps: int = 60, batch: int = 16, n_points: int = 256) -> list[dict]:
    """Train the same reduced PointNet2 under each preprocessing variant."""
    from repro.configs.base import get_config
    from repro.models import pointnet2 as PN
    from repro.optim import adamw_init, adamw_update
    import dataclasses

    rows = []
    base = get_config("pointnet2-cls", smoke=True)
    for variant in ["baseline1", "pc2im"]:
        cfg = dataclasses.replace(base, preproc=variant, quant="none")
        params = PN.init_params(jax.random.PRNGKey(1), cfg)
        state = adamw_init(params)

        @jax.jit
        def step_fn(params, state, pts, labels):
            (loss, aux), grads = jax.value_and_grad(PN.loss_fn, has_aux=True)(
                params, cfg, pts, labels
            )
            params, state, _ = adamw_update(grads, state, params, lr=2e-3, weight_decay=1e-4)
            return params, state, aux

        for i in range(steps):
            pts, cls, _ = sample_batch(jax.random.PRNGKey(1000 + i), batch, n_points)
            params, state, aux = step_fn(params, state, pts, cls)

        # eval on held-out seeds — fp and POST-TRAINING-quantized (the paper's
        # PTQ claim: quantize a trained net, measure the accuracy delta)
        evals = {"": cfg, "_ptq_w16a16": dataclasses.replace(cfg, quant="sc_w16a16")}
        if variant == "baseline1":
            evals.pop("_ptq_w16a16")
        for suffix, ecfg in evals.items():
            eval_acc = []
            for i in range(8):
                pts, cls, _ = sample_batch(jax.random.PRNGKey(777_000 + i), batch, n_points)
                logits = PN.forward(params, ecfg, pts)
                eval_acc.append(float((jnp.argmax(logits, -1) == cls).mean()))
            rows.append({
                "name": f"fig12a/eval_acc_{variant}{suffix}",
                "value": float(np.mean(eval_acc)),
                "claim": "pc2im within 2% of baseline; PTQ within 0.3%",
            })
    return rows


def run(steps: int = 0) -> list[dict]:
    rows = sampling_quality()
    if steps:
        rows += train_accuracy_comparison(steps=steps)
    return rows
