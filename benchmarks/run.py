"""Benchmark driver — one section per paper table/figure + kernel wall-times.

Prints ``name,us_per_call,derived`` CSV:
  * model-derived rows (fig12a/b/c, fig13, roofline): us_per_call empty,
    derived = model value (with the paper's claim inline);
  * microbenchmark rows: wall-clock us/call of the core ops on this host.
"""

from __future__ import annotations

import pathlib
import sys
import time

import jax
import jax.numpy as jnp

# make `import benchmarks.*` work when invoked as `python benchmarks/run.py`
_ROOT = str(pathlib.Path(__file__).resolve().parent.parent)
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)


def _timeit(fn, *args, iters: int = 5) -> float:
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def microbench() -> list[dict]:
    from repro.core import fps as F
    from repro.core import partition as P
    from repro.core import query as Q
    from repro.kernels.fps.ops import fps_tiles
    from repro.kernels.sc_matmul.ops import sc_matmul_op
    from repro.data.pointclouds import sample_batch

    pts, _, _ = sample_batch(jax.random.PRNGKey(0), 1, 2048)
    pts = pts[0]
    rows = []
    f_l2 = jax.jit(lambda p: F.fps(p, 512, metric="l2"))
    f_l1 = jax.jit(lambda p: F.fps(p, 512, metric="l1"))
    rows.append({"name": "micro/fps_l2_2048to512", "us": _timeit(f_l2, pts)})
    rows.append({"name": "micro/fps_l1_2048to512", "us": _timeit(f_l1, pts)})
    part = jax.jit(lambda p: P.median_partition(p, 3).tiles)
    rows.append({"name": "micro/msp_partition_2048_d3", "us": _timeit(part, pts)})
    tiles = P.median_partition(pts, 3)
    tiled = jnp.take(pts, tiles.tiles, axis=0)
    tiled_fps = jax.jit(lambda t: fps_tiles(t, 64, backend="xla"))
    rows.append({"name": "micro/tiled_fps_8x256to64", "us": _timeit(tiled_fps, tiled)})
    c = pts[:256]
    bq = jax.jit(lambda p, c: Q.ball_query(p, c, 0.3, 32).idx)
    lq = jax.jit(lambda p, c: Q.lattice_query(p, c, 0.3, 32).idx)
    rows.append({"name": "micro/ball_query_256x2048", "us": _timeit(bq, pts, c)})
    rows.append({"name": "micro/lattice_query_256x2048", "us": _timeit(lq, pts, c)})
    xq = jax.random.randint(jax.random.PRNGKey(1), (256, 512), -32768, 32768, jnp.int32)
    wq = jax.random.randint(jax.random.PRNGKey(2), (512, 256), -32768, 32768, jnp.int32)
    scm = jax.jit(lambda x, w: sc_matmul_op(x, w, backend="xla"))
    ref = jax.jit(lambda x, w: (x.astype(jnp.float32) @ w.astype(jnp.float32)))
    rows.append({"name": "micro/sc_matmul_256x512x256_w16a16", "us": _timeit(scm, xq, wq)})
    rows.append({"name": "micro/f32_matmul_256x512x256", "us": _timeit(ref, xq, wq)})
    return rows


def engine_bench(b: int = 8, n: int = 2048) -> list[dict]:
    """Batched PreprocessEngine vs a per-cloud python loop (same pipeline).

    Rows report us/call; derived = clouds/sec.  The batched engine folds the
    B clouds' MSP tiles into one kernel grid — one dispatch instead of B.
    """
    import functools

    from repro.core.engine import EngineConfig, PreprocessEngine
    from repro.core.preprocess import preprocess_pc2im
    from repro.data.pointclouds import sample_batch

    pts, _, _ = sample_batch(jax.random.PRNGKey(0), b, n)
    engine = PreprocessEngine(
        EngineConfig(pipeline="pc2im", n_centroids=512, radius=0.3, nsample=16, depth=3)
    )
    one = jax.jit(
        functools.partial(preprocess_pc2im, n_centroids=512, radius=0.3, nsample=16, depth=3)
    )

    def batched(x):
        return engine(x).centroid_idx

    def loop(x):
        return [one(x[i]).centroid_idx for i in range(b)]

    rows = []
    us_b = _timeit(batched, pts, iters=10)
    us_l = _timeit(loop, pts, iters=10)
    rows.append({"name": f"engine/pc2im_b{b}_{n}", "us": us_b, "derived": b / (us_b / 1e6)})
    rows.append({"name": f"engine/pc2im_loop{b}_{n}", "us": us_l, "derived": b / (us_l / 1e6)})
    return rows


def accelerator_bench(b: int = 8) -> list[dict]:
    """End-to-end PC2IMAccelerator forward: float vs SC W16A16 feature path.

    One compiled artifact per (config, policy); rows report us/call and
    derived clouds/sec, so the SC-CIM path shows up in the perf trajectory
    next to the preprocessing engine rows.
    """
    from repro.configs.base import get_config
    from repro.core.accelerator import get_accelerator
    from repro.core.policy import ExecutionPolicy
    from repro.data.pointclouds import sample_batch

    cfg = get_config("pointnet2-cls", smoke=True)
    pts, _, _ = sample_batch(jax.random.PRNGKey(0), b, cfg.n_points)
    accel_f = get_accelerator(cfg, ExecutionPolicy(quant="none"))
    accel_q = get_accelerator(cfg, ExecutionPolicy(quant="sc_w16a16"))
    params = accel_f.init(jax.random.PRNGKey(1))

    rows = []
    for tag, accel in (("fp32", accel_f), ("sc_w16a16", accel_q)):
        us = _timeit(lambda p, x, a=accel: a.infer(p, x), params, pts, iters=10)
        rows.append({
            "name": f"accelerator/pc2im_b{b}_{tag}",
            "us": us,
            "derived": b / (us / 1e6),
        })
    return rows


def serve_bench(smoke: bool = False) -> list[dict]:
    """Open-loop load benchmark: ServingRuntime vs naive per-request path
    (see benchmarks/serve_load.py).  Rows: us = p95 latency, derived = note."""
    from benchmarks import serve_load

    return serve_load.run(smoke=smoke)


def serve_cache_bench(smoke: bool = False) -> list[dict]:
    """Cross-request preprocess cache: cached vs uncached runtime on a
    temporally-correlated sweep trace (see benchmarks/serve_load.py).
    ASSERTS hit-rate > 0 on the duplicate trace and bitwise parity of every
    response vs the uncached path — failures raise and fail the lane."""
    from benchmarks import serve_load

    return serve_load.run_cache(smoke=smoke)


def pipeline_bench(smoke: bool = False) -> list[dict]:
    """Preprocess/feature overlap: PipelinedExecutor vs blocking sequential
    infer over one micro-batch stream (see benchmarks/pipeline_overlap.py)."""
    from benchmarks import pipeline_overlap

    return pipeline_overlap.run(smoke=smoke)


def serve_slo_bench(smoke: bool = False) -> list[dict]:
    """SLO control plane under overload + mid-run replica kill (see
    benchmarks/serve_load.run_slo).  ASSERTS the control-plane contracts —
    interactive sheds nothing and holds its p95 budget, bulk absorbs ALL
    shedding, and the autoscaler rejoins the killed replica with >= 90% of
    pre-kill throughput — failures raise and fail the lane."""
    from benchmarks import serve_load

    return serve_load.run_slo(smoke=smoke)


def serve_shard_bench(smoke: bool = False) -> list[dict]:
    """Mesh-sharded replicas vs 1-device replicas on a closed-loop trace
    (see benchmarks/serve_load.run_shard).  Runs in a forced-host-device
    subprocess and ASSERTS bitwise parity of every sharded response against
    the single-device reference — a parity break fails the lane."""
    from benchmarks import serve_load

    return serve_load.run_shard(smoke=smoke)


def obs_overhead_bench(smoke: bool = False) -> list[dict]:
    """Tracing-on vs tracing-off throughput on the serve_load open-loop trace
    (see benchmarks/obs_overhead.py).  ASSERTS tracing-on keeps >= 97% of
    tracing-off throughput, every span is well-formed (exactly one terminal,
    monotonic), the stage breakdown sums to the measured e2e latency, and
    the Chrome-trace JSON export round-trips — failures raise and fail the
    lane."""
    from benchmarks import obs_overhead

    return obs_overhead.run(smoke=smoke)


def serve_adapt_bench(smoke: bool = False) -> list[dict]:
    """Adaptive control plane: feedback-tuned knobs vs static defaults on a
    shifted size-distribution trace offered above the static capacity (see
    benchmarks/serve_load.run_adapt).  ASSERTS the controller applied >= 1
    reconfiguration with logged evidence, every response is bitwise-equal
    to the direct accelerator reference, no request is lost or duplicated
    across the live swap, adapted knobs beat static in throughput or p95,
    and DRR gives the bulk class >= 0.8x its weight share under a
    saturating two-class burst with zero interactive deadline expiries —
    failures raise and fail the lane."""
    from benchmarks import serve_load

    return serve_load.run_adapt(smoke=smoke)


def _print_rows(rows: list) -> None:
    """Print wall-clock rows as name,us,note CSV (one place for the format)."""
    import math

    for row in rows:
        us = "" if math.isnan(row["us"]) else f"{row['us']:.1f}"
        print(f"{row['name']},{us},{row['note']}")


def main() -> None:
    import importlib

    steps = 0
    smoke = "--smoke" in sys.argv[1:]
    for a in sys.argv[1:]:
        if a.startswith("--train-steps="):
            steps = int(a.split("=")[1])

    print("name,us_per_call,derived")
    if smoke:
        # CI lane: the serving-runtime load benchmark, the correlated-sweep
        # preprocess-cache benchmark (asserting hit-rate > 0 and bitwise
        # parity vs the uncached path), the pipelined-overlap lane, the SLO
        # control-plane lane (two-class overload trace with a mid-run replica
        # kill, asserting shed isolation, the interactive p95 budget and warm
        # rejoin recovery) + the observability-overhead lane (tracing-on vs
        # tracing-off, asserting the <= 3% throughput budget and span/export
        # well-formedness) + the sharded mesh-replica lane (forced-host-device
        # subprocess asserting bitwise parity of sharded vs single-device
        # responses) + the adaptive control-plane lane (feedback-tuned knobs
        # vs static defaults, asserting convergence with logged evidence,
        # bitwise parity across the live reconfiguration, the adapted-beats-
        # static contract and the DRR weight-share floor), reduced size —
        # keeps the open-loop path, the cache hot path, the stage-overlap
        # speedup, the control plane, the tracing layer, the sharded dispatch
        # path and the adaptation loop exercised on every push without the
        # full paper-table sweep.
        _print_rows(serve_bench(smoke=True))
        _print_rows(serve_cache_bench(smoke=True))
        _print_rows(pipeline_bench(smoke=True))
        _print_rows(serve_slo_bench(smoke=True))
        _print_rows(serve_shard_bench(smoke=True))
        _print_rows(obs_overhead_bench(smoke=True))
        _print_rows(serve_adapt_bench(smoke=True))
        return
    for mod_name, kwargs in [
        ("benchmarks.fig12b_preproc_energy", {}),
        ("benchmarks.fig12c_sccim_fom", {}),
        ("benchmarks.fig13_system", {}),
        ("benchmarks.fig12a_accuracy", {"steps": steps}),
        ("benchmarks.roofline", {}),
    ]:
        try:
            mod = importlib.import_module(mod_name)
            for row in mod.run(**kwargs):
                claim = f" (claim: {row['claim']})" if row.get("claim") else ""
                print(f"{row['name']},,{row['value']:.6g}{claim}")
        except Exception as e:  # noqa: BLE001
            print(f"{mod_name},,ERROR {type(e).__name__}: {e}")
    for row in microbench():
        print(f"{row['name']},{row['us']:.1f},")
    for row in engine_bench():
        print(f"{row['name']},{row['us']:.1f},{row['derived']:.1f} clouds/s")
    for row in accelerator_bench():
        print(f"{row['name']},{row['us']:.1f},{row['derived']:.1f} clouds/s")
    _print_rows(serve_bench())
    _print_rows(serve_cache_bench())
    _print_rows(pipeline_bench())
    _print_rows(serve_slo_bench())
    _print_rows(serve_shard_bench())
    _print_rows(obs_overhead_bench())
    _print_rows(serve_adapt_bench())


if __name__ == "__main__":
    main()
