"""PreprocessEngine: batched-vs-per-cloud equivalence, registry dispatch,
grid-partition edge cases, and the FPS empty-slot-0 seeding regression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fps as F
from repro.core import partition as P
from repro.core import preprocess as PP
from repro.core.engine import EngineConfig, PreprocessEngine, clamp_depth, get_engine
from repro.kernels import registry

jax.config.update("jax_platform_name", "cpu")

BACKENDS = [("xla", None), ("pallas", True)]  # (backend, interpret)


def _clouds(b, n, seed=0):
    return jax.random.uniform(
        jax.random.PRNGKey(seed), (b, n, 3), minval=-1.0, maxval=1.0
    )


def _assert_results_equal(got, ref):
    for g, r, name in zip(
        jax.tree.leaves(got), jax.tree.leaves(ref), ("cidx", "cxyz", "nidx", "nmask", "cvalid")
    ):
        np.testing.assert_array_equal(np.array(g), np.array(r), err_msg=name)


class TestEngineEquivalence:
    """Acceptance: engine(B clouds) == stack([preprocess_*(c) for c in clouds])
    bitwise, for all three pipelines, on both backends."""

    @pytest.mark.parametrize("backend,interpret", BACKENDS)
    @pytest.mark.parametrize("pipeline", ["baseline1", "baseline2", "pc2im"])
    def test_batched_matches_per_cloud_loop(self, pipeline, backend, interpret):
        pts = _clouds(3, 256, seed=hash(pipeline) % 100)
        # depth/grid match the per-cloud pipeline defaults (pc2im: depth=3)
        eng = PreprocessEngine(EngineConfig(
            pipeline=pipeline, n_centroids=32, radius=0.4, nsample=8, depth=3,
            backend=backend, interpret=interpret,
        ))
        got = eng(pts)
        per_cloud = [PP.PIPELINES[pipeline](pts[b], 32, 0.4, 8) for b in range(3)]
        ref = jax.tree.map(lambda *xs: jnp.stack(xs), *per_cloud)
        _assert_results_equal(got, ref)

    @pytest.mark.parametrize("backend,interpret", BACKENDS)
    def test_pc2im_depth3_larger_cloud(self, backend, interpret):
        pts = _clouds(2, 1024, seed=7)
        eng = PreprocessEngine(EngineConfig(
            pipeline="pc2im", n_centroids=128, radius=0.3, nsample=16, depth=3,
            backend=backend, interpret=interpret,
        ))
        got = eng(pts)
        ref = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[PP.preprocess_pc2im(pts[b], 128, 0.3, 16, depth=3) for b in range(2)],
        )
        _assert_results_equal(got, ref)

    def test_single_cloud_promotes(self):
        pts = _clouds(1, 256, seed=3)[0]
        eng = PreprocessEngine(EngineConfig(
            pipeline="pc2im", n_centroids=32, radius=0.4, nsample=8, depth=2))
        got = eng(pts)
        ref = PP.preprocess_pc2im(pts, 32, 0.4, 8, depth=2)
        assert got.centroid_idx.shape == (32,)
        _assert_results_equal(got, ref)

    def test_engine_is_jit_stable_across_batch_sizes(self):
        eng = PreprocessEngine(EngineConfig(
            pipeline="pc2im", n_centroids=16, radius=0.4, nsample=4, depth=1))
        for b in (1, 2, 5):
            res = eng(_clouds(b, 64, seed=b))
            assert res.centroid_idx.shape == (b, 16)
            assert res.neighbors.idx.shape == (b, 16, 4)

    def test_mixed_query_override_matches_tiled_ball(self):
        """MSP tiles + ball query (ablation config) == per-cloud _tiled_common."""
        pts = _clouds(2, 256, seed=11)
        eng = PreprocessEngine(EngineConfig(
            pipeline="pc2im", n_centroids=32, radius=0.4, nsample=8, depth=2,
            metric="l2", query="ball",
        ))
        got = eng(pts)

        def one(p):
            part = P.median_partition(p, 2)
            return PP._tiled_common(p, part, 32, 0.4, 8, "l2", "ball")

        ref = jax.tree.map(lambda *xs: jnp.stack(xs), *[one(pts[b]) for b in range(2)])
        _assert_results_equal(got, ref)


class TestEngineValidation:
    def test_bad_pipeline_raises(self):
        with pytest.raises(ValueError):
            PreprocessEngine(EngineConfig(pipeline="nope"))

    def test_indivisible_centroids_raises(self):
        with pytest.raises(ValueError, match="divisible"):
            PreprocessEngine(EngineConfig(pipeline="pc2im", n_centroids=30, depth=2))

    def test_indivisible_points_raises(self):
        eng = PreprocessEngine(EngineConfig(pipeline="pc2im", n_centroids=32, depth=2))
        with pytest.raises(ValueError, match="divisible"):
            eng(_clouds(2, 250))

    def test_bad_rank_raises(self):
        eng = PreprocessEngine(EngineConfig(pipeline="baseline1", n_centroids=8))
        with pytest.raises(ValueError):
            eng(jnp.zeros((4, 64, 2)))

    @pytest.mark.parametrize("width", [2, 4, 6])
    def test_single_cloud_bad_width_raises(self, width):
        """Regression: the 2-D promotion branch used to accept (N, F != 3)
        silently, preprocessing feature columns as coordinates."""
        eng = PreprocessEngine(EngineConfig(pipeline="baseline1", n_centroids=8))
        with pytest.raises(ValueError, match="got"):
            eng(jnp.zeros((64, width)))

    def test_clamp_depth(self):
        assert clamp_depth(1024, 128, 3) == 3
        assert clamp_depth(64, 16, 3) == 3  # 8-pt tiles, 2 samples each: ok
        assert clamp_depth(64, 32, 3) == 0  # tile floor: P >= 4 * k_per_tile
        assert clamp_depth(100, 32, 3) == 0  # 100 not divisible by 2/4/8
        assert clamp_depth(256, 64, 0) == 0

    def test_get_engine_caches(self):
        cfg = EngineConfig(pipeline="pc2im", n_centroids=16, depth=1)
        assert get_engine(cfg) is get_engine(cfg)


class TestRegistry:
    def test_resolve_auto_off_tpu_is_xla_interpret(self):
        backend, interpret = registry.resolve_backend("auto", None)
        assert backend == ("pallas" if jax.default_backend() == "tpu" else "xla")
        assert interpret == (jax.default_backend() != "tpu")

    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError):
            registry.resolve_backend("cuda")

    def test_unknown_kernel_raises(self):
        with pytest.raises(KeyError):
            registry.get("not_a_kernel")

    def test_registered_kernel_names(self):
        import repro.kernels.fps.ops  # noqa: F401
        import repro.kernels.knn3.ops  # noqa: F401
        import repro.kernels.lattice.ops  # noqa: F401
        import repro.kernels.sc_matmul.ops  # noqa: F401

        assert {"fps_tiles", "knn3", "lattice_query", "lattice_query_tiles",
                "sc_matmul"} <= set(registry.names())

    def test_force_backend_overrides_auto(self):
        with registry.force_backend("pallas"):
            assert registry.resolve_backend("auto", None)[0] == "pallas"
        assert registry.resolve_backend("auto", None)[0] != "pallas" or (
            jax.default_backend() == "tpu"
        )

    def test_pad_to_multiple(self):
        x = jnp.arange(6, dtype=jnp.float32).reshape(2, 3)
        padded, pad = registry.pad_to_multiple(x, axis=1, multiple=4)
        assert pad == 1 and padded.shape == (2, 4)
        np.testing.assert_allclose(np.array(padded[:, 3]), np.array(x[:, 0]))
        same, pad0 = registry.pad_to_multiple(x, axis=0, multiple=2)
        assert pad0 == 0 and same is x


class TestGridPartitionEdgeCases:
    def test_overflow_drops_points_beyond_capacity(self):
        # degenerate cloud: every point lands in cell 0 -> capacity 8 keeps 8
        pts = jnp.zeros((32, 3))
        part = P.grid_partition(pts, grid=2, capacity=8)
        valid = np.array(part.valid)
        assert valid.sum() == 8  # overflow dropped, not wrapped
        kept = np.array(part.tiles)[valid]
        assert len(np.unique(kept)) == 8

    def test_utilization_reflects_occupancy(self):
        pts = jax.random.uniform(jax.random.PRNGKey(0), (256, 3))
        part = P.grid_partition(pts, grid=2, capacity=64)
        util = float(part.utilization())
        assert 0.0 < util <= 256 / (8 * 64) + 1e-6

    def test_empty_cells_fully_masked(self):
        # two opposite-corner clusters: only cells (0,0,0) and (1,1,1) occupied
        a = jax.random.uniform(jax.random.PRNGKey(1), (32, 3)) * 0.05
        pts = jnp.concatenate([a, a + 0.95])
        part = P.grid_partition(pts, grid=2, capacity=64)
        valid = np.array(part.valid)
        assert valid.any(axis=1).sum() == 2  # 6 of 8 cells empty
        assert valid.sum() == 64  # nothing dropped: capacity covers occupancy

    def test_capacity_one(self):
        pts = jax.random.uniform(jax.random.PRNGKey(2), (64, 3))
        part = P.grid_partition(pts, grid=2, capacity=1)
        assert part.tiles.shape == (8, 1)
        valid = np.array(part.valid)
        # exactly one survivor per occupied cell
        c = np.array(pts)
        lo, hi = c.min(0), c.max(0)
        cell = np.clip(np.floor((c - lo) / np.maximum(hi - lo, 1e-12) * 2), 0, 1)
        occupied = len(np.unique(cell[:, 0] * 4 + cell[:, 1] * 2 + cell[:, 2]))
        assert valid.sum() == occupied


class TestFPSSeedRegression:
    """core.fps must never seed from a padded slot (grid tiles with an empty
    slot 0 used to sample a fake point)."""

    def test_seed_skips_invalid_slot0(self):
        pts = jnp.concatenate([jnp.full((4, 3), 50.0), _clouds(1, 28, seed=5)[0]])
        valid = jnp.arange(32) >= 4  # slots 0..3 are padding
        idx = np.array(F.fps(pts, 8, valid=valid))
        assert (idx >= 4).all()
        assert idx[0] == 4  # first valid slot seeds the sample

    def test_explicit_start_idx_still_respected(self):
        pts = _clouds(1, 32, seed=6)[0]
        idx = np.array(F.fps(pts, 4, start_idx=7))
        assert idx[0] == 7

    def test_all_valid_unchanged(self):
        pts = _clouds(1, 32, seed=7)[0]
        a = np.array(F.fps(pts, 8))
        b = np.array(F.fps(pts, 8, valid=jnp.ones(32, bool)))
        np.testing.assert_array_equal(a, b)

    def test_baseline2_with_sparse_occupancy(self):
        """End-to-end: clustered cloud -> grid tiles where high-id cells are
        empty; every reported-valid centroid must be a real point."""
        pts = jax.random.uniform(jax.random.PRNGKey(3), (128, 3)) * 0.2
        res = PP.preprocess_baseline2(pts, 32, radius=0.5, nsample=8, grid=2)
        ci = np.array(res.centroid_idx)
        cv = np.array(res.centroid_valid)
        assert cv.any()
        assert (ci[cv] < 128).all() and (ci[cv] >= 0).all()
