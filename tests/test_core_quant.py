"""Tests for core/quant.py (C4 — split-concatenate exact integer MACs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis import given, settings, st
from jax.experimental import enable_x64

from repro.core import quant as QT

jax.config.update("jax_platform_name", "cpu")


def _randint16(shape, seed):
    return np.array(
        jax.random.randint(jax.random.PRNGKey(seed), shape, -32768, 32768, dtype=jnp.int32)
    )


class TestPlaneSplit:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_roundtrip(self, seed):
        q = jnp.array(_randint16((64,), seed))
        planes = QT.split_planes(q)
        assert planes.shape == (4, 64)
        # low planes unsigned nibbles; top plane signed
        p = np.array(planes)
        assert (p[:3] >= 0).all() and (p[:3] <= 15).all()
        assert (p[3] >= -8).all() and (p[3] <= 7).all()
        np.testing.assert_array_equal(np.array(QT.combine_planes(planes)), np.array(q))

    def test_negative_edge_cases(self):
        q = jnp.array([-32768, -1, 0, 1, 32767, -4096, 4095], jnp.int32)
        np.testing.assert_array_equal(
            np.array(QT.combine_planes(QT.split_planes(q))), np.array(q)
        )


class TestSCMatmul:
    @pytest.mark.parametrize("m,k,n", [(4, 8, 4), (16, 32, 8), (1, 128, 16)])
    def test_exact_int64(self, m, k, n):
        x = _randint16((m, k), 0)
        w = _randint16((k, n), 1)
        with enable_x64():
            got = np.array(QT.sc_matmul(jnp.array(x), jnp.array(w), combine="int64"))
        ref = x.astype(np.int64) @ w.astype(np.int64)
        np.testing.assert_array_equal(got, ref)

    def test_f32_combine_close(self):
        x = _randint16((8, 64), 2)
        w = _randint16((64, 8), 3)
        got = np.array(QT.sc_matmul(jnp.array(x), jnp.array(w), combine="f32"))
        ref = (x.astype(np.int64) @ w.astype(np.int64)).astype(np.float64)
        np.testing.assert_allclose(got, ref, rtol=1e-6)

    def test_plane_dots_fit_int32(self):
        # worst case magnitudes: |plane| <= 15 -> |dot| <= 225*K
        k = 4096
        assert 225 * k < 2**31


class TestQuantizedLinear:
    def test_w16a16_accuracy(self):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (32, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 32)) * 0.1
        y = QT.quantized_linear(x, w, bits=16)
        ref = x @ w
        rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
        assert rel < 3e-4  # paper: 16-bit PTQ <0.3% accuracy effect

    def test_w8a8_coarser(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 32))
        w = jax.random.normal(jax.random.PRNGKey(1), (32, 16)) * 0.1
        y = QT.quantized_linear(x, w, bits=8)
        ref = x @ w
        rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
        assert rel < 2e-2

    def test_ptq_error_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(5), (1024,))
        assert float(QT.ptq_error(x, 16)) < 3e-4


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 100_000), k=st.integers(1, 64))
def test_property_sc_matmul_exact(seed, k):
    """Property: plane-decomposed matmul is EXACTLY the int matmul, any shapes/values."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.randint(key, (3, k), -32768, 32768, dtype=jnp.int32)
    w = jax.random.randint(jax.random.PRNGKey(seed + 1), (k, 5), -32768, 32768, dtype=jnp.int32)
    with enable_x64():
        got = np.array(QT.sc_matmul(x, w, combine="int64"))
    ref = np.array(x, np.int64) @ np.array(w, np.int64)
    np.testing.assert_array_equal(got, ref)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 100_000))
def test_property_plane_split_roundtrip(seed):
    q = jax.random.randint(jax.random.PRNGKey(seed), (17,), -32768, 32768, dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.array(QT.combine_planes(QT.split_planes(q))), np.array(q)
    )
