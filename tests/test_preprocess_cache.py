"""Preprocess-cache subsystem tests.

Three layers: PreprocessCache unit behavior (byte-budgeted LRU, explicit
eviction, stats), the core.engine result-tree helpers the cache is built on
(row slice / stack / splice / serialization round-trips), and the serving
integration — cache-hit responses bitwise-equal to uncached recomputation,
mixed hit/miss micro-batches preserving miss parity, the all-hit
preprocess skip on both the sequential and pipelined execution paths, and
the runtime-level hit-rate / saved-latency counters.
"""

import concurrent.futures
import threading
import time
import typing

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.accelerator import get_accelerator
from repro.core.engine import (
    deserialize_result,
    result_nbytes,
    result_row,
    result_set_row,
    result_stack,
    result_to_host,
    serialize_result,
)
from repro.core.policy import ExecutionPolicy, resolve_policy
from repro.serve import (
    CacheConfig,
    MicroBatch,
    PreprocessCache,
    RuntimeConfig,
    ServingRuntime,
    assemble_batch,
)
from repro.serve.pointcloud import pad_cloud
from repro.serve.queue import Request

jax.config.update("jax_platform_name", "cpu")

MAX_BATCH = 4
WAIT_S = 60
CACHE_BYTES = 64 * 2**20


@pytest.fixture(scope="module")
def cfg():
    return get_config("pointnet2-cls", smoke=True)  # n_points=256


@pytest.fixture(scope="module")
def params(cfg):
    return get_accelerator(cfg).init(jax.random.PRNGKey(0))


def _clouds(k, n=256, seed=0, width=3):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((n, width)).astype(np.float32) for _ in range(k)]


def _runtime(cfg, params, **kw):
    kw.setdefault("max_batch", MAX_BATCH)
    kw.setdefault("max_wait_s", 0.005)
    kw.setdefault("max_queue", 64)
    kw.setdefault("buckets", (cfg.n_points,))
    kw.setdefault("cache_max_bytes", CACHE_BYTES)
    return ServingRuntime(cfg, params, RuntimeConfig(**kw))


# -- PreprocessCache unit behavior --------------------------------------------


def _entry_payload(seed=0, n=10):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(n).astype(np.float32)


class TestPreprocessCacheLRU:
    def _key(self, i):
        return (256, ExecutionPolicy(), bytes([i]))

    def test_insert_lookup_roundtrip(self):
        cache = PreprocessCache(CacheConfig(max_bytes=1 << 20))
        row = np.ones((4, 3), np.float32)
        pre = _entry_payload()
        assert cache.lookup(self._key(1)) is None  # miss counted
        cache.insert(self._key(1), row, pre)
        ent = cache.lookup(self._key(1))
        assert ent is not None
        np.testing.assert_array_equal(ent.row, row)
        np.testing.assert_array_equal(ent.pre, pre)
        s = cache.stats()
        assert (s.hits, s.misses, s.insertions, s.entries) == (1, 1, 1, 1)
        assert s.bytes == ent.nbytes == row.nbytes + pre.nbytes
        assert s.hit_rate == 0.5

    def test_entries_are_detached_and_read_only(self):
        cache = PreprocessCache(CacheConfig(max_bytes=1 << 20))
        row = np.ones((4, 3), np.float32)
        pre = _entry_payload()
        cache.insert(self._key(1), row, pre)
        row[:] = 99.0  # caller mutates its buffers after insert
        pre[:] = 99.0
        ent = cache.lookup(self._key(1))
        assert float(ent.row[0, 0]) == 1.0  # copy, not a view
        with pytest.raises((ValueError, RuntimeError)):
            ent.row[0, 0] = 5.0  # canonical rows are immutable

    def test_byte_budget_evicts_lru(self):
        row = np.zeros((4, 3), np.float32)  # 48 B
        pre = np.zeros(10, np.float32)  # 40 B -> 88 B per entry
        cache = PreprocessCache(CacheConfig(max_bytes=2 * 88))
        cache.insert(self._key(1), row, pre)
        cache.insert(self._key(2), row, pre)
        assert cache.lookup(self._key(1)) is not None  # refresh 1: LRU is now 2
        cache.insert(self._key(3), row, pre)  # evicts 2, not 1
        assert cache.lookup(self._key(2)) is None
        assert cache.lookup(self._key(1)) is not None
        s = cache.stats()
        assert s.evictions == 1 and s.entries == 2 and s.bytes == 2 * 88

    def test_oversize_payload_refused(self):
        cache = PreprocessCache(CacheConfig(max_bytes=50))
        assert cache.insert(self._key(1), np.zeros((4, 3), np.float32),
                            np.zeros(10, np.float32)) is None
        s = cache.stats()
        assert s.oversize == 1 and s.entries == 0 and s.insertions == 0

    def test_reinsert_replaces_without_leaking_bytes(self):
        cache = PreprocessCache(CacheConfig(max_bytes=1 << 20))
        row = np.zeros((4, 3), np.float32)
        cache.insert(self._key(1), row, np.zeros(10, np.float32))
        cache.insert(self._key(1), row, np.zeros(20, np.float32))
        s = cache.stats()
        assert s.entries == 1
        assert s.bytes == row.nbytes + 80

    def test_explicit_evict_and_clear(self):
        cache = PreprocessCache(CacheConfig(max_bytes=1 << 20))
        row, pre = np.zeros((4, 3), np.float32), np.zeros(4, np.float32)
        cache.insert(self._key(1), row, pre)
        cache.insert(self._key(2), row, pre)
        assert cache.evict(self._key(1)) is True
        assert cache.evict(self._key(1)) is False  # already gone
        cache.clear()
        s = cache.stats()
        assert s.entries == 0 and s.bytes == 0 and s.evictions == 2
        assert len(cache) == 0

    def test_key_for_separates_policies_and_buckets(self, cfg):
        cache = PreprocessCache(CacheConfig(max_bytes=1 << 20))
        row = np.ones((8, 3), np.float32)
        a = resolve_policy(cfg, None)
        b = resolve_policy(cfg, ExecutionPolicy(quant="sc_w16a16"))
        assert cache.key_for(256, a, row) != cache.key_for(256, b, row)
        assert cache.key_for(256, a, row) != cache.key_for(512, a, row)
        assert cache.key_for(256, a, row) == cache.key_for(256, a, row.copy())

    def test_thread_safe_under_concurrent_churn(self):
        cache = PreprocessCache(CacheConfig(max_bytes=40 * 88))
        row = np.zeros((4, 3), np.float32)
        pre = np.zeros(10, np.float32)

        def churn(tid):
            for i in range(200):
                k = (256, tid, bytes([i % 60]))
                if cache.lookup(k) is None:
                    cache.insert(k, row, pre)

        threads = [threading.Thread(target=churn, args=(t,)) for t in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        s = cache.stats()
        assert s.bytes <= 40 * 88
        assert s.hits + s.misses == 4 * 200


# -- core.engine result-tree helpers ------------------------------------------


class _Pair(typing.NamedTuple):
    a: np.ndarray
    b: np.ndarray


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return _Pair(
        rng.standard_normal((4, 3)).astype(np.float32),
        rng.integers(0, 9, (4, 2)).astype(np.int32),
    )


class TestResultHelpers:
    def test_nbytes_counts_every_leaf(self):
        t = _tree()
        assert result_nbytes(t) == t.a.nbytes + t.b.nbytes

    def test_row_stack_roundtrip(self):
        t = _tree()
        rows = [result_row(t, i) for i in range(4)]
        back = result_stack(rows)
        np.testing.assert_array_equal(back.a, t.a)
        np.testing.assert_array_equal(back.b, t.b)

    def test_stack_pads_zero_filler_rows(self):
        t = _tree()
        out = result_stack([result_row(t, 0)], total=3)
        assert out.a.shape == (3, 3)
        np.testing.assert_array_equal(out.a[0], t.a[0])
        assert not out.a[1:].any() and not out.b[1:].any()

    def test_set_row_splices_in_place(self):
        t = _tree(seed=1)
        other = _tree(seed=2)
        result_set_row(t, 2, result_row(other, 0))
        np.testing.assert_array_equal(t.a[2], other.a[0])
        np.testing.assert_array_equal(t.b[2], other.b[0])
        np.testing.assert_array_equal(t.a[0], _tree(seed=1).a[0])  # others intact

    def test_to_host_is_writable(self):
        dev = _Pair(jnp.ones((2, 3)), jnp.zeros((2, 2), jnp.int32))
        host = result_to_host(dev)
        assert isinstance(host.a, np.ndarray) and host.a.flags.writeable
        host.a[0, 0] = 7.0  # must not raise

    def test_serialize_roundtrip_bitwise(self):
        t = _tree(seed=3)
        blob = serialize_result(t)
        assert isinstance(blob, bytes) and len(blob) > 0
        back = deserialize_result(blob, t)
        assert isinstance(back, _Pair)
        np.testing.assert_array_equal(back.a, t.a)
        np.testing.assert_array_equal(back.b, t.b)
        assert back.a.dtype == t.a.dtype and back.b.dtype == t.b.dtype


# -- serving integration ------------------------------------------------------


def _make_req(rt, cloud, i, policy=None):
    pol = resolve_policy(rt.model_cfg, policy)
    fitted = pad_cloud(cloud, 256)[0]
    return Request(
        id=i, cloud=cloud, n_orig=cloud.shape[0], bucket=256, policy=pol,
        deadline_t=None, submit_t=0.0, future=concurrent.futures.Future(),
        fitted=fitted, cache_key=rt.cache.key_for(256, pol, fitted),
    )


def _wait_insertions(rt, n, timeout_s=10.0):
    """Block until the cache holds n insertions (all-miss fills are async)."""
    deadline = time.monotonic() + timeout_s
    while rt.cache.stats().insertions < n:
        assert time.monotonic() < deadline, (
            f"cache never reached {n} insertions: {rt.cache.stats()}"
        )
        time.sleep(0.005)


def _mb(rt, reqs, entries=None):
    ents = (
        tuple(rt.cache.lookup(r.cache_key) for r in reqs)
        if entries is None
        else entries
    )
    rows = [e.row if e is not None else r.fitted for r, e in zip(reqs, ents)]
    batch = assemble_batch(reqs, 256, 3, MAX_BATCH, rows=rows)
    return MicroBatch(
        requests=tuple(reqs), bucket=256, policy=reqs[0].policy, batch=batch,
        cache=rt.cache, cache_entries=ents,
    )


class TestCachedDispatch:
    def test_mixed_and_allhit_batches_bitwise(self, cfg, params):
        """Deterministic micro-batch construction straight into the pool:
        all-miss, mixed hit/miss, and all-hit batches must each be
        bitwise-equal to the fused artifact on the same padded batch."""
        rt = _runtime(cfg, params)  # never started: pool driven directly
        try:
            accel = get_accelerator(cfg, rt.default_policy)
            clouds = _clouds(6, seed=10)

            # all-miss: populates the cache, miss parity vs fused infer
            mb1 = _mb(rt, [_make_req(rt, c, i) for i, c in enumerate(clouds[:4])])
            assert mb1.n_hits == 0 and not mb1.all_hit
            out1 = rt.pool.submit(mb1).result(timeout=WAIT_S)
            ref1 = np.asarray(accel.infer(params, jnp.asarray(mb1.batch)))
            np.testing.assert_array_equal(out1, ref1)
            _wait_insertions(rt, 4)  # all-miss fills land on the insert thread
            assert rt.cache.stats().insertions == 4

            # mixed: 2 duplicates (hits) + 2 fresh
            reqs2 = [_make_req(rt, c, i) for i, c in enumerate(
                [clouds[0], clouds[4], clouds[1], clouds[5]])]
            mb2 = _mb(rt, reqs2)
            assert mb2.n_hits == 2 and not mb2.all_hit
            out2 = rt.pool.submit(mb2).result(timeout=WAIT_S)
            ref2 = np.asarray(accel.infer(params, jnp.asarray(mb2.batch)))
            np.testing.assert_array_equal(out2, ref2)
            assert rt.cache.stats().insertions == 6  # both fresh rows inserted

            # all-hit: preprocess skipped, still bitwise
            mb3 = _mb(rt, [_make_req(rt, c, i) for i, c in enumerate(clouds[:4])])
            assert mb3.all_hit
            out3 = rt.pool.submit(mb3).result(timeout=WAIT_S)
            np.testing.assert_array_equal(out3, ref1)
            skipped = [b for b in rt.metrics.batch_records if b.preprocess_skipped]
            assert len(skipped) == 1 and skipped[0].n_real == 4
        finally:
            rt.stop(drain=False)

    def test_near_duplicate_hits_serve_canonical_response(self, cfg, params):
        """Sub-step noise collides by design: the hit's response is the
        CANONICAL (first-seen) cloud's response, bit for bit."""
        rt = _runtime(cfg, params)
        try:
            accel = get_accelerator(cfg, rt.default_policy)
            cloud = (np.round(_clouds(1, seed=11)[0] / 1e-3) * 1e-3).astype(np.float32)
            noisy = cloud + np.float32(1e-4)  # same lattice cells

            mb1 = _mb(rt, [_make_req(rt, cloud, 0)])
            out1 = rt.pool.submit(mb1).result(timeout=WAIT_S)
            _wait_insertions(rt, 1)
            mb2 = _mb(rt, [_make_req(rt, noisy, 1)])
            assert mb2.all_hit  # the noisy sweep collided on purpose
            out2 = rt.pool.submit(mb2).result(timeout=WAIT_S)
            np.testing.assert_array_equal(out1, out2)
            ref = np.asarray(accel.infer(params, jnp.asarray(mb1.batch)))
            np.testing.assert_array_equal(out2, ref)
        finally:
            rt.stop(drain=False)


class TestCachedRuntime:
    def test_hits_bitwise_equal_uncached(self, cfg, params):
        clouds = _clouds(4, seed=20)
        with _runtime(cfg, params, cache_max_bytes=0) as rt:
            ref = [rt.infer(c) for c in clouds]
            assert rt.cache is None and rt.cache_stats() is None
        with _runtime(cfg, params) as rt:
            first = [rt.infer(c) for c in clouds]
            second = [rt.infer(c) for c in clouds]
            snap = rt.metrics.snapshot()
            stats = rt.cache_stats()
        for r, a, b in zip(ref, first, second):
            np.testing.assert_array_equal(r, a)
            np.testing.assert_array_equal(r, b)
        assert stats.hits >= 4 and stats.entries == 4
        assert snap.cache_hits >= 4 and snap.preprocess_skipped >= 1
        assert 0.0 < snap.cache_hit_rate <= 1.0
        assert "hit=" in snap.format_row()

    def test_pipelined_policy_composes_with_cache(self, cfg, params):
        piped = ExecutionPolicy(pipeline="pipelined")
        clouds = _clouds(4, seed=21)
        with _runtime(cfg, params, cache_max_bytes=0) as rt:
            ref = [rt.infer(c, policy=piped) for c in clouds]
        with _runtime(cfg, params) as rt:
            first = [rt.infer(c, policy=piped) for c in clouds]
            second = [rt.infer(c, policy=piped) for c in clouds]
            stats = rt.cache_stats()
            skipped = [b for b in rt.metrics.batch_records if b.preprocess_skipped]
        for r, a, b in zip(ref, first, second):
            np.testing.assert_array_equal(r, a)
            np.testing.assert_array_equal(r, b)
        assert stats.hits >= 4
        assert skipped and all(b.policy_key[2] == "pipelined" for b in skipped)

    def test_saved_latency_counter_populates(self, cfg, params):
        cloud = _clouds(1, seed=22)[0]
        with _runtime(cfg, params) as rt:
            for _ in range(6):
                rt.infer(cloud)
            snap = rt.metrics.snapshot()
        assert snap.preprocess_skipped >= 1
        assert snap.cache_saved_s >= 0.0
