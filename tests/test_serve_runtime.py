"""Serving-runtime subsystem tests: queue backpressure, deadline expiry,
scheduler bitwise parity, mixed-policy isolation, replica health/eviction,
and accelerator-cache introspection under concurrent traffic.

Everything runs on the smoke config with ONE static shape family
(max_batch=4, bucket 256) so all tests share the same jit traces; the
threaded tests bound every wait with explicit future timeouts, so they fail
fast rather than hang on a bare environment (CI additionally runs pytest
under pytest-timeout).
"""

import concurrent.futures
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _timing import time_mult, wait_until

from repro.configs.base import get_config
from repro.core import accelerator as accel_mod
from repro.core.accelerator import cache_stats, clear_cache, get_accelerator
from repro.core.policy import ExecutionPolicy, resolve_policy
from repro.serve import (
    AdmissionQueue,
    DeadlineExceeded,
    MicroBatch,
    QueueFull,
    ReplicaPool,
    RuntimeConfig,
    ServeMetrics,
    ServingRuntime,
    assemble_batch,
    bucket_for,
    scatter_results,
)
from repro.serve.queue import Request

jax.config.update("jax_platform_name", "cpu")

MAX_BATCH = 4
# bound on every future/result wait: fail, never hang.  Scaled by
# PC2IM_TEST_TIME_MULT (tests/_timing.py) for saturated CI hosts.
WAIT_S = 60 * time_mult()


@pytest.fixture(scope="module")
def cfg():
    return get_config("pointnet2-cls", smoke=True)  # n_points=256


@pytest.fixture(scope="module")
def params(cfg):
    return get_accelerator(cfg).init(jax.random.PRNGKey(0))


def _clouds(k, sizes=(256,), seed=0, width=3):
    rng = np.random.default_rng(seed)
    return [
        rng.standard_normal((sizes[i % len(sizes)], width)).astype(np.float32)
        for i in range(k)
    ]


def _runtime(cfg, params, **kw):
    kw.setdefault("max_batch", MAX_BATCH)
    kw.setdefault("max_wait_s", 0.005)
    kw.setdefault("max_queue", 64)
    kw.setdefault("buckets", (cfg.n_points,))
    return ServingRuntime(cfg, params, RuntimeConfig(**kw))


class TestAdmissionQueue:
    def test_backpressure_rejects_with_reason(self):
        q = AdmissionQueue(max_depth=2)
        pol = ExecutionPolicy()
        cloud = np.zeros((8, 3), np.float32)
        q.submit(cloud, bucket=256, policy=pol)
        q.submit(cloud, bucket=256, policy=pol)
        with pytest.raises(QueueFull) as exc:
            q.submit(cloud, bucket=256, policy=pol)
        assert exc.value.reason == "queue_full"
        assert exc.value.depth == 2 and exc.value.max_depth == 2
        assert q.depth() == 2  # rejected request never entered

    def test_drain_fifo_and_close(self):
        q = AdmissionQueue(max_depth=8)
        pol = ExecutionPolicy()
        for i in range(3):
            q.submit(np.full((4, 3), i, np.float32), bucket=256, policy=pol)
        got = q.drain(max_items=2, timeout_s=0.01)
        assert [r.cloud[0, 0] for r in got] == [0.0, 1.0]
        left = q.close()
        assert [r.cloud[0, 0] for r in left] == [2.0]
        with pytest.raises(Exception, match="closed"):
            q.submit(np.zeros((4, 3), np.float32), bucket=256, policy=pol)
        assert q.drain(max_items=4, timeout_s=0.01) == []

    def test_runtime_backpressure_counts_rejections(self, cfg, params):
        rt = _runtime(cfg, params, max_queue=2)  # never started: queue fills
        try:
            rt.submit(_clouds(1)[0])
            rt.submit(_clouds(1)[0])
            with pytest.raises(QueueFull):
                rt.submit(_clouds(1)[0])
            assert rt.metrics.rejected == 1
            assert rt.metrics.submitted == 2
        finally:
            rt.stop(drain=False)
            rt.pool.shutdown()


class TestDeadlines:
    def test_expired_request_fails_future(self, cfg, params):
        rt = _runtime(cfg, params)
        # submit BEFORE starting the scheduler: the deadline (now+0) is
        # already past when the drain loop first sees the request
        fut_dead = rt.submit(_clouds(1)[0], timeout_s=0.0)
        fut_live = rt.submit(_clouds(1, seed=1)[0])  # no deadline
        with rt:
            out = fut_live.result(timeout=WAIT_S)
            with pytest.raises(DeadlineExceeded):
                fut_dead.result(timeout=WAIT_S)
        assert out.shape == (cfg.n_classes,)
        assert rt.metrics.expired == 1
        assert rt.metrics.completed == 1


class TestSchedulerParity:
    def test_bitwise_identical_to_direct_infer(self, cfg, params):
        """Scheduler output == direct accel.infer on the same padded batch,
        bitwise (the acceptance criterion for scheduler correctness)."""
        clouds = _clouds(3, sizes=(256, 150, 300), seed=2)
        rt = _runtime(cfg, params)
        futs = [rt.submit(c) for c in clouds]  # queued pre-start: one batch
        with rt:
            outs = [f.result(timeout=WAIT_S) for f in futs]

        accel = get_accelerator(cfg)
        reqs = [
            Request(id=i, cloud=c, n_orig=c.shape[0], bucket=256,
                    policy=rt.default_policy, deadline_t=None, submit_t=0.0,
                    future=None)
            for i, c in enumerate(clouds)
        ]
        batch = assemble_batch(reqs, bucket=256, width=3, max_batch=MAX_BATCH)
        direct = np.asarray(accel.infer(params, jnp.asarray(batch)))
        for i, out in enumerate(outs):
            np.testing.assert_array_equal(out, direct[i])
        # and it really was one micro-batch of 3 on one replica
        real = [b for b in rt.metrics.batch_records if b.n_real]
        assert len(real) == 1 and real[0].n_real == 3

    def test_bucketing_routes_to_smallest_fit(self):
        assert bucket_for(100, (192, 256)) == 192
        assert bucket_for(192, (192, 256)) == 192
        assert bucket_for(193, (192, 256)) == 256
        assert bucket_for(999, (192, 256)) == 256  # oversized -> largest

    def test_seg_scatter_maps_rows_back(self):
        """scatter_results drops padding rows and maps subsampled clouds back
        to every original row via the exact inverse."""
        from repro.serve.pointcloud import inverse_subsample_indices

        small = np.zeros((100, 3), np.float32)
        big = np.zeros((300, 3), np.float32)
        reqs = [
            Request(id=0, cloud=small, n_orig=100, bucket=256, policy=None,
                    deadline_t=None, submit_t=0.0, future=None),
            Request(id=1, cloud=big, n_orig=300, bucket=256, policy=None,
                    deadline_t=None, submit_t=0.0, future=None),
        ]
        mb = MicroBatch(requests=tuple(reqs), bucket=256, policy=None,
                        batch=np.zeros((4, 256, 3), np.float32))
        logits = np.arange(4 * 256, dtype=np.float32).reshape(4, 256)[..., None]
        outs = scatter_results("seg", logits, mb)
        np.testing.assert_array_equal(outs[0], logits[0, :100])
        np.testing.assert_array_equal(
            outs[1], logits[1, inverse_subsample_indices(300, 256)]
        )


class TestMixedPolicies:
    def test_batches_never_share_an_artifact(self, cfg, params):
        """Interleaved fp32 / SC W16A16 traffic: every executed micro-batch
        carries exactly one policy, results match that policy's direct
        artifact bitwise, and the accelerator cache holds one artifact per
        policy (no compile storm)."""
        clear_cache()
        quant = ExecutionPolicy(quant="sc_w16a16")
        clouds = _clouds(8, seed=3)
        rt = _runtime(cfg, params)
        futs = [
            rt.submit(c, policy=quant if i % 2 else None)
            for i, c in enumerate(clouds)
        ]
        with rt:
            outs = [f.result(timeout=WAIT_S) for f in futs]

        records = [b for b in rt.metrics.batch_records if b.n_real]
        assert len(records) == 2  # one full batch per policy, never mixed
        assert {r.policy_key[0] for r in records} == {"none", "sc_w16a16"}
        assert all(r.n_real == MAX_BATCH for r in records)

        stats = cache_stats()
        assert stats.size == 2
        assert sorted(q for _, q, *_ in stats.keys) == ["none", "sc_w16a16"]

        for pol, idxs in ((None, (0, 2, 4, 6)), (quant, (1, 3, 5, 7))):
            accel = get_accelerator(cfg, pol)
            reqs = [
                Request(id=i, cloud=clouds[i], n_orig=256, bucket=256,
                        policy=resolve_policy(cfg, pol), deadline_t=None,
                        submit_t=0.0, future=None)
                for i in idxs
            ]
            batch = assemble_batch(reqs, 256, 3, MAX_BATCH)
            direct = np.asarray(accel.infer(params, jnp.asarray(batch)))
            for j, i in enumerate(idxs):
                np.testing.assert_array_equal(outs[i], direct[j])

    def test_concurrent_submitters_one_artifact_per_policy(self, cfg, params):
        """8 submitter threads x 2 policies hammering one runtime: the cache
        must end at exactly 2 artifacts (construction is lock-serialised)."""
        clear_cache()
        quant = ExecutionPolicy(quant="sc_w16a16")
        rt = _runtime(cfg, params, max_queue=128)
        clouds = _clouds(32, seed=4)
        with rt:
            with concurrent.futures.ThreadPoolExecutor(max_workers=8) as ex:
                futs = list(ex.map(
                    lambda i: rt.submit(clouds[i], policy=quant if i % 2 else None),
                    range(32),
                ))
            outs = [f.result(timeout=WAIT_S) for f in futs]
        assert all(o.shape == (cfg.n_classes,) for o in outs)
        stats = cache_stats()
        assert stats.size == 2, stats
        assert stats.misses == 2, stats

    def test_preprocess_cache_isolated_per_policy(self, cfg, params):
        """The SAME cloud served under two policies must key two DIFFERENT
        preprocess-cache entries: a result cached under one (quant, backend,
        pipeline) key is never served to another policy, and each policy's
        hit stays bitwise-equal to that policy's own artifact."""
        quant = ExecutionPolicy(quant="sc_w16a16")
        clouds = _clouds(MAX_BATCH, seed=9)
        rt = _runtime(cfg, params, cache_max_bytes=64 * 2**20)
        with rt:
            fp32_1 = [rt.infer(c) for c in clouds]
            # same clouds, different policy: must MISS (not reuse fp32
            # neighborhoods computed under the fp32 artifact's backend)
            q_1 = [rt.infer(c, policy=quant) for c in clouds]
            fp32_2 = [rt.infer(c) for c in clouds]
            q_2 = [rt.infer(c, policy=quant) for c in clouds]
            stats = rt.cache_stats()

        assert stats.entries == 2 * len(clouds), stats  # one entry per policy
        assert stats.misses >= 2 * len(clouds), stats

        # direct reference with the SAME batch composition the blocking
        # serial submits produced (one real row + zero filler)
        for pol, outs in ((None, fp32_1 + fp32_2), (quant, q_1 + q_2)):
            accel = get_accelerator(cfg, pol)
            resolved = resolve_policy(cfg, pol)
            for i, out in enumerate(outs):
                req = Request(id=i, cloud=clouds[i % len(clouds)], n_orig=256,
                              bucket=256, policy=resolved, deadline_t=None,
                              submit_t=0.0, future=None)
                batch = assemble_batch([req], 256, 3, MAX_BATCH)
                direct = np.asarray(accel.infer(params, jnp.asarray(batch)))[0]
                np.testing.assert_array_equal(out, direct)
        # the two policies produce different logits on this traffic — if a
        # cached result ever crossed policies the equality above would fail,
        # but make the premise explicit
        assert not np.array_equal(fp32_1[0], q_1[0])


class TestReplicaPool:
    def _mb(self, cfg, policy=None):
        return MicroBatch(
            requests=(),
            bucket=cfg.n_points,
            policy=resolve_policy(cfg, policy),
            batch=np.zeros((MAX_BATCH, cfg.n_points, 3), np.float32),
        )

    def test_least_loaded_spreads_across_replicas(self, cfg, params):
        pool = ReplicaPool(cfg, params, n_replicas=2, metrics=ServeMetrics())
        try:
            futs = [pool.submit(self._mb(cfg)) for _ in range(4)]
            for f in futs:
                assert f.result(timeout=WAIT_S).shape == (MAX_BATCH, cfg.n_classes)
            used = {b.replica_id for b in pool.metrics.batch_records}
            assert used == {0, 1}
        finally:
            pool.shutdown()

    def test_eviction_on_dead_heartbeat_retries_inflight(self, cfg, params):
        """A wedged replica (simulated hung worker) misses heartbeats, gets
        evicted, and its in-flight batch is re-dispatched to the survivor."""
        metrics = ServeMetrics()
        pool = ReplicaPool(
            cfg, params, n_replicas=2, heartbeat_timeout_s=0.25,
            max_retries=2, metrics=metrics,
        )
        try:
            # wedge replica 0's single worker thread (ties inflight=0 break
            # toward the lowest id, so the next batch queues behind the hang)
            pool.replicas[0].submit(time.sleep, 2.0)
            fut = pool.submit(self._mb(cfg))
            out = fut.result(timeout=WAIT_S)  # completes via the survivor
            assert out.shape == (MAX_BATCH, cfg.n_classes)
            deadline = time.monotonic() + WAIT_S
            while pool.replicas[0].alive and time.monotonic() < deadline:
                time.sleep(0.01)
            assert not pool.replicas[0].alive
            assert pool.replicas[1].alive
            assert metrics.evictions == 1
            assert metrics.retries >= 1
            assert [b.replica_id for b in metrics.batch_records if b.n_real == 0] == [1]
            # pool keeps serving on the survivor
            assert pool.submit(self._mb(cfg)).result(timeout=WAIT_S) is not None
        finally:
            pool.shutdown()

    def test_all_replicas_dead_fails_future(self, cfg, params):
        pool = ReplicaPool(cfg, params, n_replicas=1, metrics=ServeMetrics())
        try:
            pool.evict(0, reason="test")
            fut = pool.submit(self._mb(cfg))
            with pytest.raises(Exception, match="replica"):
                fut.result(timeout=WAIT_S)
        finally:
            pool.shutdown()


class TestRobustness:
    """One bad request (or client) must never wedge the runtime for the
    good ones — regressions found in review."""

    def test_empty_cloud_rejected_at_submit(self, cfg, params):
        rt = _runtime(cfg, params)
        try:
            with pytest.raises(ValueError, match="n >= 1"):
                rt.submit(np.zeros((0, 3), np.float32))
            with pytest.raises(ValueError):
                rt.submit(np.zeros((4, 5), np.float32))  # wrong width
        finally:
            rt.stop(drain=False)

    def test_cancelled_future_does_not_kill_scheduler(self, cfg, params):
        rt = _runtime(cfg, params)
        fut_dead = rt.submit(_clouds(1)[0], timeout_s=0.0)
        assert fut_dead.cancel()  # client walks away while still queued
        fut_live = rt.submit(_clouds(1, seed=6)[0])
        with rt:
            out = fut_live.result(timeout=WAIT_S)  # scheduler survived
        assert out.shape == (cfg.n_classes,)
        assert rt.metrics.expired == 0  # cancelled, not expired

    def test_stop_without_start_cancels_and_closes(self, cfg, params):
        rt = _runtime(cfg, params)
        fut = rt.submit(_clouds(1)[0])
        rt.stop()  # never started: nothing could ever complete this
        assert fut.cancelled()
        with pytest.raises(Exception, match="closed"):
            rt.submit(_clouds(1)[0])

    def test_deadline_expiring_in_pending_is_shed(self, cfg, params):
        """A deadline that passes while the request waits in a partial batch
        fails with DeadlineExceeded at flush time, not a late success."""
        rt = _runtime(cfg, params, max_wait_s=0.4)
        with rt:
            fut = rt.submit(_clouds(1)[0], timeout_s=0.05)  # << max_wait_s
            with pytest.raises(DeadlineExceeded):
                fut.result(timeout=WAIT_S)
        assert rt.metrics.expired == 1
        assert rt.metrics.completed == 0

    def test_restart_after_stop_fails_fast(self, cfg, params):
        rt = _runtime(cfg, params)
        rt.start()
        rt.stop()
        with pytest.raises(RuntimeError, match="restarted"):
            rt.start()

    def test_snapshot_occupancy_excludes_warmup(self, cfg, params):
        rt = _runtime(cfg, params)
        rt.warmup()  # records n_real=0 batches
        futs = [rt.submit(c) for c in _clouds(MAX_BATCH, seed=7)]
        with rt:
            for f in futs:
                f.result(timeout=WAIT_S)
        snap = rt.metrics.snapshot()
        assert snap.mean_occupancy == 1.0  # one full batch; warmup excluded
        assert snap.batches == 1


class TestRuntimeLifecycle:
    def test_stop_drains_admitted_requests(self, cfg, params):
        rt = _runtime(cfg, params, max_wait_s=10.0)  # wait longer than test
        futs = [rt.submit(c) for c in _clouds(3, seed=5)]
        rt.start()
        # wait on the observable hand-off (scheduler drained the admission
        # queue into its pending partial batch), not a wall-clock guess
        wait_until(
            lambda: rt.queue.depth() == 0,
            desc="scheduler to drain the admission queue",
        )
        rt.stop()  # drain=True must flush the pending partial batch
        for f in futs:
            assert f.result(timeout=1).shape == (cfg.n_classes,)
        assert rt.metrics.completed == 3

    def test_threaded_submit_and_metrics_consistency(self, cfg, params):
        rt = _runtime(cfg, params, max_queue=128)
        n_threads, per_thread = 4, 8
        errors = []

        def client(tid):
            try:
                clouds = _clouds(per_thread, sizes=(256, 150), seed=10 + tid)
                outs = [
                    rt.submit(c).result(timeout=WAIT_S) for c in clouds
                ]
                assert all(o.shape == (cfg.n_classes,) for o in outs)
            except Exception as e:  # noqa: BLE001
                errors.append(e)

        with rt:
            threads = [
                threading.Thread(target=client, args=(t,)) for t in range(n_threads)
            ]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=WAIT_S)
        assert errors == []
        m = rt.metrics
        assert m.completed == n_threads * per_thread
        assert m.submitted == n_threads * per_thread
        snap = m.snapshot()
        assert snap.latency_p95_s >= snap.latency_p50_s >= 0
        assert 0 < snap.mean_occupancy <= 1
        assert sum(b.n_real for b in m.batch_records) == m.completed


class TestCacheIntrospection:
    def test_stats_and_clear(self, cfg):
        clear_cache()
        s0 = cache_stats()
        assert (s0.hits, s0.misses, s0.size) == (0, 0, 0)
        a = get_accelerator(cfg)
        b = get_accelerator(cfg)
        assert a is b
        s1 = cache_stats()
        assert (s1.hits, s1.misses, s1.size) == (1, 1, 1)
        assert s1.keys == ((cfg.name, "none", "auto", "sequential", None),)
        clear_cache()
        assert cache_stats().size == 0
        # fresh instance after clear (old one stays valid for holders)
        c = get_accelerator(cfg)
        assert c is not a

    def test_concurrent_misses_build_one_artifact(self, cfg):
        """The explicit lock closes the lru_cache race: N threads missing on
        the same key construct exactly one accelerator."""
        clear_cache()
        built = []
        orig = accel_mod.PC2IMAccelerator

        class Counting(orig):
            def __init__(self, *a, **kw):
                built.append(1)
                super().__init__(*a, **kw)

        accel_mod.PC2IMAccelerator = Counting
        try:
            with concurrent.futures.ThreadPoolExecutor(max_workers=8) as ex:
                accels = list(ex.map(lambda _: get_accelerator(cfg), range(16)))
        finally:
            accel_mod.PC2IMAccelerator = orig
        assert len(set(map(id, accels))) == 1
        assert sum(built) == 1
        clear_cache()  # drop the Counting-class artifact
