"""SLO control-plane tests: priority/EDF drain, load shedding, per-class
metrics, deterministic chaos injection, replica rejoin, and the autoscaler
control loop.

Layered like the subsystem: AdmissionQueue drain order and shedding are
pinned as pure properties (hypothesis cross-checks the drain order against
`slo.drain_key` on random traffic); ChaosInjector and Autoscaler units run
against fakes where determinism matters; and the integration tests drive a
real ServingRuntime through a kill -> rejoin -> recovery cycle and a
two-class overload that must shed ONLY the sheddable class.  All waits are
bounded (WAIT_S) so failures surface as assertions, never hangs.
"""

import threading
import time

import jax
import numpy as np
import pytest
from _hypothesis import given, settings, st
from _timing import time_mult

from repro.configs.base import get_config
from repro.core.accelerator import get_accelerator
from repro.core.engine import result_row, result_stack, result_to_host
from repro.core.policy import ExecutionPolicy, resolve_policy
from repro.serve import (
    BULK,
    DEFAULT,
    INTERACTIVE,
    AdmissionQueue,
    Autoscaler,
    AutoscalerConfig,
    ChaosInjector,
    Fault,
    MicroBatch,
    PreprocessCache,
    QueueFull,
    ReplicaPool,
    RuntimeConfig,
    ServeMetrics,
    ServingRuntime,
    Shed,
    SLOClass,
)
from repro.serve.preprocess_cache import CacheConfig
from repro.serve.queue import Request
from repro.serve.slo import drain_key

jax.config.update("jax_platform_name", "cpu")

MAX_BATCH = 4
# bound on every future/result wait: fail, never hang.  Scaled by
# PC2IM_TEST_TIME_MULT (tests/_timing.py) for saturated CI hosts.
WAIT_S = 60 * time_mult()


@pytest.fixture(scope="module")
def cfg():
    return get_config("pointnet2-cls", smoke=True)  # n_points=256


@pytest.fixture(scope="module")
def params(cfg):
    return get_accelerator(cfg).init(jax.random.PRNGKey(0))


def _clouds(k, n=256, seed=0, width=3):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((n, width)).astype(np.float32) for _ in range(k)]


def _runtime(cfg, params, **kw):
    kw.setdefault("max_batch", MAX_BATCH)
    kw.setdefault("max_wait_s", 0.005)
    kw.setdefault("max_queue", 64)
    kw.setdefault("buckets", (cfg.n_points,))
    return ServingRuntime(cfg, params, RuntimeConfig(**kw))


CLOUD = np.zeros((8, 3), np.float32)
POL = ExecutionPolicy()


def _submit(q, slo=None, timeout_s=None):
    return q.submit(CLOUD, bucket=256, policy=POL, slo=slo, timeout_s=timeout_s)


# -- SLOClass + drain order ---------------------------------------------------


class TestSLOClass:
    def test_validation(self):
        with pytest.raises(ValueError, match="name"):
            SLOClass("")
        with pytest.raises(ValueError, match="deadline_s"):
            SLOClass("x", deadline_s=-1.0)
        with pytest.raises(ValueError, match="max_wait_s"):
            SLOClass("x", max_wait_s=-0.1)

    def test_hashable_and_batch_key(self):
        req_a = Request(0, CLOUD, 8, 256, POL, None, 0.0, None, slo=INTERACTIVE)
        req_b = Request(1, CLOUD, 8, 256, POL, None, 0.0, None, slo=BULK)
        assert req_a.key != req_b.key  # classes never share a micro-batch
        assert req_a.key[:2] == req_b.key[:2]

    def test_drain_key_total_order(self):
        # priority beats deadline beats admission order
        assert drain_key(10, 99.0, 5) < drain_key(0, 1.0, 0)
        assert drain_key(0, 1.0, 9) < drain_key(0, 2.0, 0)
        assert drain_key(0, None, 9) > drain_key(0, 1e9, 0)  # None sorts last
        assert drain_key(0, None, 0) < drain_key(0, None, 1)


class TestQueueDrainOrder:
    def test_priority_order_across_classes(self):
        q = AdmissionQueue(16)
        futs = {
            "bulk": _submit(q, BULK),
            "default": _submit(q, None),
            "interactive": _submit(q, INTERACTIVE),
        }
        out = q.drain(16, timeout_s=1.0)
        assert [r.slo.name for r in out] == ["interactive", "default", "bulk"]
        assert [r.future for r in out] == [
            futs["interactive"], futs["default"], futs["bulk"],
        ]

    def test_edf_within_one_class(self):
        q = AdmissionQueue(16)
        _submit(q, None, timeout_s=10.0)
        _submit(q, None, timeout_s=1.0)
        _submit(q, None, timeout_s=5.0)
        out = q.drain(16, timeout_s=1.0)
        deadlines = [r.deadline_t for r in out]
        assert deadlines == sorted(deadlines)

    def test_single_class_degenerates_to_fifo(self):
        q = AdmissionQueue(16)
        futs = [_submit(q) for _ in range(5)]
        out = q.drain(16, timeout_s=1.0)
        assert [r.future for r in out] == futs

    @given(
        traffic=st.lists(
            st.tuples(
                st.integers(min_value=-2, max_value=2),  # priority
                st.one_of(st.none(), st.floats(0.001, 10.0)),  # timeout_s
            ),
            min_size=1,
            max_size=24,
        )
    )
    @settings(max_examples=40, deadline=None)
    def test_drain_matches_drain_key_sort(self, traffic):
        """Property: drain order == sorting admissions by slo.drain_key."""
        q = AdmissionQueue(64)
        for i, (prio, timeout) in enumerate(traffic):
            slo = SLOClass(f"p{prio}", priority=prio)
            _submit(q, slo, timeout_s=timeout)
        out = q.drain(64, timeout_s=1.0)
        assert len(out) == len(traffic)
        keys = [drain_key(r.slo.priority, r.deadline_t, r.id) for r in out]
        assert keys == sorted(keys)


# -- load shedding ------------------------------------------------------------


class TestLoadShedding:
    def test_shed_threshold_rejects_sheddable_only(self):
        q = AdmissionQueue(8, shed_threshold=2)
        _submit(q, BULK)
        _submit(q, BULK)
        with pytest.raises(Shed) as exc:
            _submit(q, BULK)  # over the budget and sheddable
        assert exc.value.reason == "shed"
        assert exc.value.slo_name == "bulk"
        _submit(q, INTERACTIVE)  # non-sheddable sails past the budget
        assert q.depth() == 3

    def test_full_queue_evicts_newest_lowest_class(self):
        shed_seen = []
        q = AdmissionQueue(2, on_shed=shed_seen.append)
        fut_old = _submit(q, BULK)
        fut_new = _submit(q, BULK)
        fut_hi = _submit(q, INTERACTIVE)  # full: evicts the NEWEST bulk
        assert q.depth() == 2
        with pytest.raises(Shed):
            fut_new.result(timeout=WAIT_S)
        assert not fut_old.done() and not fut_hi.done()
        assert [r.future for r in shed_seen] == [fut_new]
        out = q.drain(4, timeout_s=1.0)
        assert [r.slo.name for r in out] == ["interactive", "bulk"]

    def test_full_queue_without_victim_is_queue_full(self):
        q = AdmissionQueue(2)
        _submit(q, INTERACTIVE)
        _submit(q, INTERACTIVE)
        # equal priority is never preempted — and a SHEDDABLE incoming class
        # can't displace anything above it either
        with pytest.raises(QueueFull):
            _submit(q, INTERACTIVE)
        with pytest.raises(QueueFull):
            _submit(q, BULK)

    def test_depth_by_class(self):
        q = AdmissionQueue(8)
        _submit(q, BULK)
        _submit(q, BULK)
        _submit(q, INTERACTIVE)
        assert q.depth_by_class() == {"bulk": 2, "interactive": 1}

    def test_shed_threshold_validation(self):
        with pytest.raises(ValueError, match="shed_threshold"):
            AdmissionQueue(4, shed_threshold=5)
        with pytest.raises(ValueError, match="shed_threshold"):
            AdmissionQueue(4, shed_threshold=0)

    def test_runtime_sheds_only_lowest_class(self, cfg, params):
        """Two-class overload against a runtime whose scheduler never
        drains (not started): shedding must hit ONLY the sheddable class,
        deterministically."""
        rt = _runtime(cfg, params, max_queue=16, shed_threshold=8)
        try:
            clouds = _clouds(1)
            outcomes = {"bulk": 0, "interactive": 0}
            for i in range(24):
                slo = INTERACTIVE if i % 3 == 0 else BULK
                try:
                    rt.submit(clouds[0], slo=slo)
                except Shed:
                    outcomes[slo.name] += 1
            snap = rt.metrics.snapshot()
            assert outcomes["interactive"] == 0
            assert outcomes["bulk"] > 0
            assert snap.for_class("bulk").shed == outcomes["bulk"]
            assert snap.for_class("interactive").shed == 0
            assert snap.shed == outcomes["bulk"]
        finally:
            rt.stop(drain=False)


# -- per-class metrics --------------------------------------------------------


class TestPerClassMetrics:
    def test_breakdown_and_aggregate_agree(self):
        m = ServeMetrics()
        m.record_submitted("interactive")
        m.record_submitted("interactive")
        m.record_submitted("bulk")
        m.record_completed(0.010, "interactive")
        m.record_completed(0.030, "interactive")
        m.record_shed("bulk")
        m.record_expired("bulk")
        m.record_rejected()  # unclassed -> "default"
        snap = m.snapshot()
        inter = snap.for_class("interactive")
        bulk = snap.for_class("bulk")
        assert (inter.submitted, inter.completed, inter.shed) == (2, 2, 0)
        assert (bulk.submitted, bulk.shed, bulk.expired) == (1, 1, 1)
        assert snap.for_class("default").rejected == 1
        assert snap.for_class("missing") is None
        # aggregates stay the sums the pre-SLO runtime reported
        assert (snap.submitted, snap.completed, snap.shed) == (3, 2, 1)
        assert (snap.expired, snap.rejected, snap.rejoins) == (1, 1, 0)
        assert inter.latency_p50_s == pytest.approx(0.020)
        assert snap.latency_p50_s == pytest.approx(0.020)

    def test_format_rows_stable(self):
        m = ServeMetrics()
        m.record_submitted("interactive")
        m.record_completed(0.010, "interactive")
        snap = m.snapshot()
        # the aggregate one-liner keeps its pre-SLO shape
        assert snap.format_row().startswith("completed=1 rejected=0 expired=0")
        assert "[interactive]" in snap.format_class_rows()
        assert "shed=0" in snap.for_class("interactive").format_row()

    def test_per_class_sorted_by_name(self):
        m = ServeMetrics()
        for name in ("zeta", "alpha", "mid"):
            m.record_submitted(name)
        assert [c.name for c in m.snapshot().per_class] == ["alpha", "mid", "zeta"]


# -- chaos injector -----------------------------------------------------------


class _FakeRep:
    def __init__(self, rid):
        self.id = rid
        self.alive = True


class _FakePool:
    def __init__(self):
        self.evictions = []

    def evict(self, rid, *, reason):
        self.evictions.append((rid, reason))


class _FakeMB:
    n_real = 1


class TestChaosInjector:
    def test_fault_validation(self):
        with pytest.raises(ValueError, match="kind"):
            Fault(0, 0, kind="melt")
        with pytest.raises(ValueError, match="at_batch"):
            Fault(0, -1)
        with pytest.raises(ValueError, match="duration_s"):
            Fault(0, 0, kind="wedge")

    def test_kill_fires_once_at_exact_index(self):
        chaos = ChaosInjector([Fault(replica_id=1, at_batch=2, kind="kill")])
        pool, mb = _FakePool(), _FakeMB()
        rep0, rep1 = _FakeRep(0), _FakeRep(1)
        for _ in range(5):
            chaos.on_batch(pool, rep0, mb)  # wrong replica: never fires
        chaos.on_batch(pool, rep1, mb)  # index 0
        chaos.on_batch(pool, rep1, mb)  # index 1
        with pytest.raises(Exception, match="killed at batch 2"):
            chaos.on_batch(pool, rep1, mb)  # index 2: fires
        assert pool.evictions == [(1, "chaos-kill")]
        chaos.on_batch(pool, rep1, mb)  # at most once: index 3 passes
        events = chaos.fired("kill")
        assert len(events) == 1
        assert (events[0].replica_id, events[0].batch_index) == (1, 2)

    def test_slow_fault_delays_but_survives(self):
        chaos = ChaosInjector([Fault(0, 0, kind="slow", duration_s=0.05)])
        pool, rep = _FakePool(), _FakeRep(0)
        t0 = time.monotonic()
        chaos.on_batch(pool, rep, _FakeMB())
        assert time.monotonic() - t0 >= 0.05
        assert pool.evictions == []
        assert rep.alive

    def test_attach_installs_hook(self, cfg, params):
        pool = ReplicaPool(cfg, params, n_replicas=1, metrics=ServeMetrics())
        try:
            chaos = ChaosInjector().attach(pool)
            assert pool.chaos is chaos
        finally:
            pool.shutdown()


# -- replica rejoin + warm state ----------------------------------------------


def _mb(cfg, policy=None, requests=(), batch=None, cache=None):
    return MicroBatch(
        requests=tuple(requests),
        bucket=cfg.n_points,
        policy=resolve_policy(cfg, policy),
        batch=(
            batch
            if batch is not None
            else np.zeros((MAX_BATCH, cfg.n_points, 3), np.float32)
        ),
        cache=cache,
    )


class TestRejoin:
    def test_rejoin_restores_capacity_warm(self, cfg, params):
        metrics = ServeMetrics()
        pool = ReplicaPool(cfg, params, n_replicas=2, metrics=metrics)
        try:
            pool.warmup(_mb(cfg))  # registers the (bucket, policy) batch
            old = pool.replicas[1]
            pool.evict(1, reason="test")
            assert not pool.replicas[1].alive
            assert pool.replicas[1].evicted_t is not None
            assert pool.rejoin(1)
            fresh = pool.replicas[1]
            assert fresh is not old and fresh.alive and not fresh.retired
            assert metrics.rejoins == 1
            # the replay showed up as one more warmup batch on replica 1
            warm_rids = [
                b.replica_id for b in metrics.batch_records if b.n_real == 0
            ]
            assert warm_rids.count(1) == 2  # initial warmup + rejoin replay
            out = pool.submit(_mb(cfg)).result(timeout=WAIT_S)
            assert out.shape == (MAX_BATCH, cfg.n_classes)
        finally:
            pool.shutdown()

    def test_rejoin_alive_slot_is_noop(self, cfg, params):
        pool = ReplicaPool(cfg, params, n_replicas=1, metrics=ServeMetrics())
        try:
            assert not pool.rejoin(0)
        finally:
            pool.shutdown()

    def test_retire_marks_no_auto_rejoin(self, cfg, params):
        pool = ReplicaPool(cfg, params, n_replicas=2, metrics=ServeMetrics())
        try:
            assert pool.retire(1)
            assert pool.replicas[1].retired and not pool.replicas[1].alive
            assert not pool.retire(1)  # already dead
        finally:
            pool.shutdown()

    def test_add_replica_grows_pool(self, cfg, params):
        pool = ReplicaPool(cfg, params, n_replicas=1, metrics=ServeMetrics())
        try:
            rid = pool.add_replica()
            assert rid == 1 and pool.replicas[1].alive
            assert len(pool.alive_replicas()) == 2
        finally:
            pool.shutdown()

    def test_rejoin_prestages_hot_cache_entries(self, cfg, params):
        """A rejoined replica carries the cache's hottest entries staged on
        its device, and the staged device-side restack is bitwise-equal to
        the host restack path it replaces."""
        accel = get_accelerator(cfg)
        cache = PreprocessCache(CacheConfig(max_bytes=64 * 2**20))
        batch = np.stack(
            [c for c in _clouds(MAX_BATCH, n=cfg.n_points, seed=3)]
        )
        pre = result_to_host(accel.preprocess_stage(batch))
        keys = []
        for i in range(MAX_BATCH):
            key = cache.key_for(cfg.n_points, resolve_policy(cfg, None), batch[i])
            cache.insert(key, batch[i], result_row(pre, i))
            keys.append(key)
        for key in keys[:2]:  # make the first two entries the hottest
            cache.lookup(key)
        pool = ReplicaPool(
            cfg, params, n_replicas=1, metrics=ServeMetrics(),
            cache=cache, stage_top_k=2,
        )
        try:
            pool.evict(0, reason="test")
            assert pool.rejoin(0)
            rep = pool.replicas[0]
            assert len(rep.staged) == 2  # top-K bound respected
            entries = [cache.peek(k) for k in keys[:2]]
            assert all(e.key in rep.staged for e in entries)
            staged = pool._staged_stack(rep, entries, MAX_BATCH)
            host = result_stack([e.pre for e in entries], total=MAX_BATCH)
            jax.tree.map(
                lambda a, b: np.testing.assert_array_equal(np.asarray(a), b),
                result_to_host(staged),
                host,
            )
            # an unstaged entry forces the host fallback
            assert pool._staged_stack(rep, [cache.peek(keys[3])], MAX_BATCH) is None
        finally:
            pool.shutdown()


# -- autoscaler ---------------------------------------------------------------


class _FakeQueue:
    def __init__(self, depth=0):
        self._depth = depth

    def depth(self):
        return self._depth


class TestAutoscaler:
    def test_config_validation(self):
        with pytest.raises(ValueError, match="min_replicas"):
            AutoscalerConfig(min_replicas=0)
        with pytest.raises(ValueError, match="max_replicas"):
            AutoscalerConfig(min_replicas=2, max_replicas=1)
        with pytest.raises(ValueError, match="scale_down_depth"):
            AutoscalerConfig(scale_up_depth=1.0, scale_down_depth=2.0)

    def test_rejoins_fault_evicted_after_delay(self, cfg, params):
        pool = ReplicaPool(cfg, params, n_replicas=2, metrics=ServeMetrics())
        try:
            scaler = Autoscaler(
                pool, _FakeQueue(), AutoscalerConfig(rejoin_delay_s=60.0)
            )
            pool.evict(1, reason="test")
            scaler.poll_once()  # a 60s dwell cannot have elapsed in-test
            assert not pool.replicas[1].alive
            # rewind the eviction instant instead of sleeping out the dwell:
            # deterministic on any machine (see tests/_timing.py convention)
            pool.replicas[1].evicted_t -= 120.0
            scaler.poll_once()
            assert pool.replicas[1].alive
            assert [e.action for e in scaler.events] == ["rejoin"]
        finally:
            pool.shutdown()

    def test_retired_replicas_stay_down(self, cfg, params):
        pool = ReplicaPool(cfg, params, n_replicas=2, metrics=ServeMetrics())
        try:
            scaler = Autoscaler(
                pool, _FakeQueue(), AutoscalerConfig(rejoin_delay_s=0.0)
            )
            pool.retire(1)
            scaler.poll_once()
            assert not pool.replicas[1].alive
            assert scaler.events == []
        finally:
            pool.shutdown()

    def test_scale_up_revives_retired_slot_under_load(self, cfg, params):
        pool = ReplicaPool(cfg, params, n_replicas=2, metrics=ServeMetrics())
        try:
            queue = _FakeQueue(depth=0)
            scaler = Autoscaler(
                pool, queue,
                AutoscalerConfig(scale_up_depth=4.0, cooldown_s=0.0),
            )
            pool.retire(1)
            queue._depth = 8  # 8 deep on 1 alive replica -> scale up
            scaler.poll_once()
            assert pool.replicas[1].alive and not pool.replicas[1].retired
            assert [e.action for e in scaler.events] == ["scale_up"]
        finally:
            pool.shutdown()

    def test_scale_down_after_sustained_shallow(self, cfg, params):
        pool = ReplicaPool(cfg, params, n_replicas=2, metrics=ServeMetrics())
        try:
            scaler = Autoscaler(
                pool, _FakeQueue(depth=0),
                AutoscalerConfig(
                    scale_down_ticks=3, min_replicas=1, cooldown_s=0.0
                ),
            )
            scaler.poll_once()
            scaler.poll_once()
            assert len(pool.alive_replicas()) == 2  # not sustained yet
            scaler.poll_once()
            assert len(pool.alive_replicas()) == 1
            assert pool.replicas[1].retired  # highest id goes first
            # min_replicas floor holds no matter how long the queue is idle
            for _ in range(5):
                scaler.poll_once()
            assert len(pool.alive_replicas()) == 1
            assert [e.action for e in scaler.events] == ["scale_down"]
        finally:
            pool.shutdown()

    def test_max_replicas_none_caps_at_slot_count(self, cfg, params):
        pool = ReplicaPool(cfg, params, n_replicas=1, metrics=ServeMetrics())
        try:
            scaler = Autoscaler(
                pool, _FakeQueue(depth=100),
                AutoscalerConfig(scale_up_depth=1.0, cooldown_s=0.0),
            )
            scaler.poll_once()
            assert len(pool.replicas) == 1  # no new slots without max_replicas
            scaler.config = AutoscalerConfig(
                scale_up_depth=1.0, cooldown_s=0.0, max_replicas=2
            )
            scaler.poll_once()
            assert len(pool.replicas) == 2
        finally:
            pool.shutdown()


# -- integration: kill -> rejoin -> recovery ----------------------------------


class TestKillRejoinRecovery:
    def test_chaos_kill_recovers_and_completes_everything(self, cfg, params):
        """Replica 1 is killed mid-trace; the autoscaler rejoins it warm and
        every submitted request still completes exactly once."""
        rt = _runtime(
            cfg, params,
            n_replicas=2,
            autoscaler=AutoscalerConfig(
                poll_interval_s=0.02, rejoin_delay_s=0.05, cooldown_s=60.0
            ),
        )
        rt.warmup()
        chaos = ChaosInjector([Fault(replica_id=1, at_batch=1, kind="kill")])
        chaos.attach(rt.pool)
        clouds = _clouds(24, seed=11)
        with rt:
            futs = [rt.submit(c, slo=DEFAULT) for c in clouds]
            outs = [f.result(timeout=WAIT_S) for f in futs]
            # hold the runtime open until the rejoin lands
            deadline = time.monotonic() + WAIT_S
            while rt.metrics.rejoins < 1 and time.monotonic() < deadline:
                time.sleep(0.02)
        assert all(o.shape == (cfg.n_classes,) for o in outs)
        assert len(chaos.fired("kill")) == 1
        snap = rt.metrics.snapshot()
        assert snap.evictions >= 1
        assert snap.rejoins >= 1
        # exactly-once completion: every submit completed, none doubled
        assert snap.submitted == snap.completed == len(clouds)
        assert sum(b.n_real for b in rt.metrics.batch_records) == len(clouds)
        rejoined = [e for e in rt.autoscaler.events if e.action == "rejoin"]
        assert [e.replica_id for e in rejoined] == [1]

    def test_wedge_trips_heartbeat_then_rejoin(self, cfg, params):
        """A wedged worker thread is detected by the liveness monitor (not
        by the injector) and the autoscaler still brings the slot back."""
        rt = _runtime(
            cfg, params,
            n_replicas=2,
            heartbeat_timeout_s=0.25,
            autoscaler=AutoscalerConfig(poll_interval_s=0.02, rejoin_delay_s=0.05),
        )
        rt.warmup()
        ChaosInjector(
            [Fault(replica_id=0, at_batch=0, kind="wedge", duration_s=1.0)]
        ).attach(rt.pool)
        clouds = _clouds(8, seed=13)
        with rt:
            futs = [rt.submit(c) for c in clouds]
            outs = [f.result(timeout=WAIT_S) for f in futs]
            deadline = time.monotonic() + WAIT_S
            while rt.metrics.rejoins < 1 and time.monotonic() < deadline:
                time.sleep(0.02)
        assert all(o.shape == (cfg.n_classes,) for o in outs)
        snap = rt.metrics.snapshot()
        assert snap.evictions >= 1
        assert snap.rejoins >= 1
        assert snap.completed == len(clouds)


# -- runtime-level class isolation --------------------------------------------


class TestRuntimeClassIsolation:
    def test_mixed_class_traffic_completes_with_breakdown(self, cfg, params):
        rt = _runtime(cfg, params)
        clouds = _clouds(8, seed=17)
        with rt:
            futs = [
                rt.submit(c, slo=INTERACTIVE if i % 2 else BULK, timeout_s=WAIT_S)
                for i, c in enumerate(clouds)
            ]
            outs = [f.result(timeout=WAIT_S) for f in futs]
        assert all(o.shape == (cfg.n_classes,) for o in outs)
        snap = rt.metrics.snapshot()
        assert snap.for_class("interactive").completed == 4
        assert snap.for_class("bulk").completed == 4
        assert snap.for_class("interactive").latency_p95_s > 0

    def test_class_deadline_default_applies(self, cfg, params):
        """A class deadline is inherited when submit passes no timeout —
        an already-expired class deadline expires the request."""
        tight = SLOClass("tight", priority=5, deadline_s=0.0, sheddable=False)
        rt = _runtime(cfg, params, max_wait_s=0.2)
        with rt:
            fut = rt.submit(_clouds(1)[0], slo=tight)
            with pytest.raises(Exception):  # noqa: B017 — DeadlineExceeded
                fut.result(timeout=WAIT_S)
        snap = rt.metrics.snapshot()
        assert snap.for_class("tight").expired == 1

    def test_interleaved_submitters_threads(self, cfg, params):
        """Concurrent submitters on different classes: everything completes
        and per-class counts add up (no cross-class leakage)."""
        rt = _runtime(cfg, params)
        clouds = _clouds(6, seed=23)
        results = {}
        errors = []

        def client(name, slo):
            try:
                futs = [rt.submit(c, slo=slo, timeout_s=WAIT_S) for c in clouds]
                results[name] = [f.result(timeout=WAIT_S) for f in futs]
            except Exception as e:  # noqa: BLE001 — surfaced via assertion
                errors.append(e)

        with rt:
            threads = [
                threading.Thread(target=client, args=("hi", INTERACTIVE)),
                threading.Thread(target=client, args=("lo", BULK)),
            ]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=WAIT_S)
        assert not errors
        assert len(results["hi"]) == len(results["lo"]) == len(clouds)
        snap = rt.metrics.snapshot()
        assert snap.for_class("interactive").completed == len(clouds)
        assert snap.for_class("bulk").completed == len(clouds)
