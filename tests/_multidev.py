"""Reusable multi-device (forced host platform) subprocess substrate.

jax fixes the device count at first backend initialization, so a test that
wants N CPU devices must set ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
BEFORE importing jax — impossible in the main pytest process, which already
holds the single real CPU device (and must keep it: the dry-run isolation
rule).  The pattern, extracted from test_pipeline_multidev.py:

  * ``run_in_child(body, n_devices=8)`` runs a Python snippet in a child
    process whose jax sees N host devices.  The snippet is prefixed with the
    XLA_FLAGS export and an ``emit(name, array)`` helper; everything emitted
    comes back to the parent as a dict of numpy arrays (via an .npz file),
    so parity assertions can live in the TEST, next to the other asserts,
    instead of being squeezed into the child's stdout.
  * ``assert_bitwise(payload, a, b)`` — the standard check: two emitted
    arrays are bitwise-identical (exact equality, not allclose).

A child that raises exits nonzero and the parent surfaces its stderr tail.
"""

from __future__ import annotations

import os
import subprocess
import sys
import tempfile
import textwrap

import numpy as np

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Runs before the test body in the child: force the device count (before any
# jax import!), then expose emit().  The payload is flushed by an explicit
# call appended AFTER the body, so a failing child never ships half a payload.
_PRELUDE = """\
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
import numpy as np
_PAYLOAD = {}
def emit(name, value):
    _PAYLOAD[str(name)] = np.asarray(value)
def _flush_payload():
    out = os.environ.get("PC2IM_MULTIDEV_OUT")
    if out and _PAYLOAD:
        np.savez(out, **_PAYLOAD)
"""


def run_in_child(
    body: str, *, n_devices: int = 8, timeout_s: float = 600
) -> dict[str, np.ndarray]:
    """Run `body` in a subprocess with `n_devices` forced host CPU devices.

    Returns {name: array} for every emit(name, value) the body performed.
    Raises AssertionError (with the child's stderr tail) on nonzero exit.
    """
    with tempfile.TemporaryDirectory() as td:
        out = os.path.join(td, "payload.npz")
        script = _PRELUDE % n_devices + textwrap.dedent(body) + "\n_flush_payload()\n"
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        env["PC2IM_MULTIDEV_OUT"] = out
        res = subprocess.run(
            [sys.executable, "-c", script],
            capture_output=True,
            text=True,
            timeout=timeout_s,
            env=env,
            cwd=REPO_ROOT,
        )
        assert res.returncode == 0, (
            f"multi-device child failed (rc={res.returncode})\n"
            f"--- stdout tail ---\n{res.stdout[-2000:]}\n"
            f"--- stderr tail ---\n{res.stderr[-4000:]}"
        )
        payload: dict[str, np.ndarray] = {}
        if os.path.exists(out):
            with np.load(out) as z:
                payload = {k: z[k] for k in z.files}
        return payload


def assert_bitwise(payload: dict[str, np.ndarray], a: str, b: str) -> None:
    """Assert two emitted arrays are bitwise-identical (exact, not allclose)."""
    assert a in payload and b in payload, (
        f"payload missing {a!r} or {b!r}; has {sorted(payload)}"
    )
    np.testing.assert_array_equal(payload[a], payload[b])
