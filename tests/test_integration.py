"""Integration tests: end-to-end training behaviour, checkpoint-resume
equivalence, quantized-MLP mode, serving loop."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.data.tokens import token_stream
from repro.models.families import get_family_api
from repro.optim import adamw_init
from repro.train.step import make_train_step

jax.config.update("jax_platform_name", "cpu")


def _tiny_lm():
    cfg = get_config("stablelm-1.6b", smoke=True)
    return dataclasses.replace(cfg, n_layers=2, d_model=64, d_ff=128, vocab_size=64)


class TestLMTraining:
    def test_loss_decreases(self):
        cfg = _tiny_lm()
        api = get_family_api(cfg)
        params = api["init"](jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        step = jax.jit(make_train_step(cfg, peak_lr=3e-3, warmup_steps=5, total_steps=100))
        losses = []
        for s, batch in token_stream(0, 8, 32, cfg.vocab_size):
            if s >= 40:
                break
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.5, f"no learning: {losses[0]:.3f}->{losses[-1]:.3f}"

    def test_microbatched_grads_match(self):
        """grad accumulation over 4 microbatches == single big batch."""
        cfg = _tiny_lm()
        api = get_family_api(cfg)
        params = api["init"](jax.random.PRNGKey(0), cfg)
        batch = next(token_stream(3, 8, 32, cfg.vocab_size))[1]

        s1 = make_train_step(cfg, peak_lr=1e-3, microbatch=None)
        s4 = make_train_step(cfg, peak_lr=1e-3, microbatch=4)
        p1, _, m1 = s1(params, adamw_init(params), batch)
        p4, _, m4 = s4(params, adamw_init(params), batch)
        assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-5)

    def test_checkpoint_resume_matches_uninterrupted(self, tmp_path):
        from repro.checkpoint import load_checkpoint, save_checkpoint

        cfg = _tiny_lm()
        api = get_family_api(cfg)
        step = jax.jit(make_train_step(cfg, peak_lr=1e-3, warmup_steps=2, total_steps=20))

        def run(n_from, state=None):
            if state is None:
                params = api["init"](jax.random.PRNGKey(0), cfg)
                state = {"params": params, "opt": adamw_init(params)}
            for s, batch in token_stream(1, 4, 32, cfg.vocab_size, start_step=n_from):
                if s >= 10:
                    break
                state["params"], state["opt"], _ = step(state["params"], state["opt"], batch)
            return state

        # uninterrupted 10 steps
        full = run(0)
        # interrupted at 5 + checkpoint + resume
        # rerun: first 5
        params = api["init"](jax.random.PRNGKey(0), cfg)
        state = {"params": params, "opt": adamw_init(params)}
        for s, batch in token_stream(1, 4, 32, cfg.vocab_size):
            if s >= 5:
                break
            state["params"], state["opt"], _ = step(state["params"], state["opt"], batch)
        save_checkpoint(str(tmp_path), 5, state)
        restored, step_n, _ = load_checkpoint(str(tmp_path), state)
        assert step_n == 5
        resumed = run(5, restored)
        for a, b in zip(jax.tree.leaves(full["params"]), jax.tree.leaves(resumed["params"])):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=1e-5, atol=1e-6
            )

    def test_sc_quant_mode_close(self):
        """quant='sc_w16a16' (C4 applied to an LM) stays near the fp path."""
        cfg = _tiny_lm()
        api = get_family_api(cfg)
        params = api["init"](jax.random.PRNGKey(0), cfg)
        batch = next(token_stream(2, 4, 32, cfg.vocab_size))[1]
        l0, _ = api["train_loss"](params, cfg, batch)
        cfg_q = dataclasses.replace(cfg, quant="sc_w16a16")
        l1, _ = api["train_loss"](params, cfg_q, batch)
        assert abs(float(l0) - float(l1)) / abs(float(l0)) < 1e-2


class TestServing:
    def test_generate_loop(self):
        from repro.serve import make_serve_fns

        cfg = _tiny_lm()
        api = get_family_api(cfg)
        fns = make_serve_fns(cfg)
        params = api["init"](jax.random.PRNGKey(0), cfg)
        batch = {"tokens": jnp.ones((2, 8), jnp.int32)}
        out = fns["generate"](params, batch, steps=5, s_max=32)
        assert out.shape == (2, 5)
        assert bool((out >= 0).all()) and bool((out < cfg.vocab_size).all())

    def test_greedy_deterministic(self):
        from repro.serve import make_serve_fns

        cfg = _tiny_lm()
        api = get_family_api(cfg)
        fns = make_serve_fns(cfg)
        params = api["init"](jax.random.PRNGKey(0), cfg)
        batch = {"tokens": jnp.ones((1, 8), jnp.int32)}
        a = fns["generate"](params, batch, steps=4, s_max=24)
        b = fns["generate"](params, batch, steps=4, s_max=24)
        np.testing.assert_array_equal(np.array(a), np.array(b))


class TestMoEBehaviour:
    def test_capacity_drops_monotone(self):
        """Lower capacity_factor -> outputs move toward zero (dropped tokens)."""
        from repro.models.moe import moe_apply, moe_init

        cfg = dataclasses.replace(
            get_config("dbrx-132b", smoke=True), capacity_factor=8.0
        )
        p = moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
        full = moe_apply(p, cfg, x)
        tight = moe_apply(p, dataclasses.replace(cfg, capacity_factor=0.25), x)
        assert float(jnp.linalg.norm(tight)) < float(jnp.linalg.norm(full))

    def test_aux_loss_finite(self):
        from repro.models.moe import moe_aux_loss, moe_init

        cfg = get_config("granite-moe-3b-a800m", smoke=True)
        p = moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        aux = moe_aux_loss(p, cfg, x)
        assert bool(jnp.isfinite(aux)) and float(aux) >= 1.0 - 1e-3  # >=1 at balance
