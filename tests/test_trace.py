"""Request-lifecycle tracing tests: tracer unit behavior, the closed event
registry, terminal-outcome completeness on a real runtime (every way a
request can end yields exactly one terminal event on a monotonic span),
chaos/evict/retry paths, sampling, the stage-attribution reductions, the
Chrome-trace and Prometheus exporters, and the high-water-mark gauges.

The integration tests reuse the SLO control-plane fixtures (real
ServingRuntime on the smoke config); the reduction tests run on synthetic
event streams with hand-picked timestamps so stage math is pinned exactly.
"""

import json
import re
import threading
import time
from concurrent.futures import Future
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.accelerator import get_accelerator
from repro.serve import (
    BULK,
    EVENTS,
    INTERACTIVE,
    TERMINAL_EVENTS,
    AdmissionQueue,
    AutoscalerConfig,
    BatchRecord,
    ChaosInjector,
    Fault,
    Reporter,
    RuntimeConfig,
    ServeMetrics,
    ServingRuntime,
    Shed,
    TraceConfig,
    TraceEvent,
    Tracer,
    batch_crosscheck,
    prometheus_text,
    request_timelines,
    stage_breakdown,
    to_chrome_trace,
    trace_problems,
    write_chrome_trace,
)
from repro.serve.queue import AdmissionError

jax.config.update("jax_platform_name", "cpu")

MAX_BATCH = 4
WAIT_S = 60

SERVE_DIR = Path(__file__).resolve().parent.parent / "src" / "repro" / "serve"


@pytest.fixture(scope="module")
def cfg():
    return get_config("pointnet2-cls", smoke=True)  # n_points=256


@pytest.fixture(scope="module")
def params(cfg):
    return get_accelerator(cfg).init(jax.random.PRNGKey(0))


def _clouds(k, n=256, seed=0, width=3):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((n, width)).astype(np.float32) for _ in range(k)]


def _runtime(cfg, params, **kw):
    kw.setdefault("max_batch", MAX_BATCH)
    kw.setdefault("max_wait_s", 0.005)
    kw.setdefault("max_queue", 64)
    kw.setdefault("buckets", (cfg.n_points,))
    kw.setdefault("trace", TraceConfig())
    return ServingRuntime(cfg, params, RuntimeConfig(**kw))


def _by_trace(events):
    out = {}
    for ev in events:
        if ev.trace_id != -1:
            out.setdefault(ev.trace_id, []).append(ev)
    return out


def _assert_well_formed(events):
    """Every trace: exactly one terminal, monotonic time, no lint findings."""
    assert trace_problems(events) == []
    for tid, revs in _by_trace(events).items():
        terminals = [e.name for e in revs if e.name in TERMINAL_EVENTS]
        assert len(terminals) == 1, f"trace {tid}: terminals {terminals}"
        ts = [e.t for e in revs]
        assert ts == sorted(ts), f"trace {tid}: non-monotonic timestamps"


# -- tracer unit --------------------------------------------------------------


class TestTracerUnit:
    def test_emit_rejects_undeclared_names(self):
        tr = Tracer()
        with pytest.raises(ValueError, match="undeclared"):
            tr.emit("request.teleported")
        tr.emit("request.submit", trace_id=1)
        assert [e.name for e in tr.events()] == ["request.submit"]

    def test_ring_drops_oldest(self):
        tr = Tracer(TraceConfig(capacity=4))
        for i in range(10):
            tr.emit("request.submit", trace_id=i)
        assert len(tr) == 4
        assert tr.emitted == 10
        assert tr.dropped == 6
        assert [e.trace_id for e in tr.events()] == [6, 7, 8, 9]

    def test_clear_keeps_counting_ids(self):
        tr = Tracer()
        first = tr.new_trace()
        tr.emit("request.submit", trace_id=first)
        tr.clear()
        assert len(tr) == 0
        assert tr.new_trace() == first + 1

    def test_sampling_extremes(self):
        assert Tracer(TraceConfig(sample=0.0)).new_trace() is None
        tr = Tracer(TraceConfig(sample=1.0))
        assert [tr.new_trace() for _ in range(3)] == [1, 2, 3]

    def test_sampling_fraction_is_deterministic_and_proportional(self):
        tr_a = Tracer(TraceConfig(sample=0.5))
        tr_b = Tracer(TraceConfig(sample=0.5))
        kept_a = [tr_a.new_trace() for _ in range(400)]
        kept_b = [tr_b.new_trace() for _ in range(400)]
        assert kept_a == kept_b  # same ids -> same decisions
        frac = sum(t is not None for t in kept_a) / 400
        assert 0.3 < frac < 0.7

    def test_thread_safety_no_loss_under_capacity(self):
        tr = Tracer(TraceConfig(capacity=10_000))

        def worker(base):
            for i in range(500):
                tr.emit("request.submit", trace_id=base + i)

        threads = [threading.Thread(target=worker, args=(k * 1000,)) for k in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert tr.emitted == 2000
        assert tr.dropped == 0


# -- closed event-name registry ----------------------------------------------


class TestEventRegistry:
    """The event namespace is closed: grep-enforced in both directions."""

    _LIT = re.compile(
        r"""["']((?:request|batch|replica|scale|chaos|cache|adapt)\.[a-z_]+)["']"""
    )

    def _literals(self):
        used = {}
        # rglob: subpackages (serve/adapt/) emit into the same registry
        for path in sorted(SERVE_DIR.rglob("*.py")):
            for name in self._LIT.findall(path.read_text()):
                used.setdefault(name, set()).add(path.name)
        return used

    def test_every_emitted_name_is_declared(self):
        undeclared = {
            name: sorted(files)
            for name, files in self._literals().items()
            if name not in EVENTS
        }
        assert undeclared == {}, f"event literals not in trace.EVENTS: {undeclared}"

    def test_every_declared_name_is_emitted_somewhere(self):
        used = self._literals()
        orphans = [
            name for name in EVENTS if not (used.get(name, set()) - {"trace.py"})
        ]
        assert orphans == [], f"EVENTS entries never emitted: {orphans}"

    def test_registry_has_no_duplicates_and_terminals_are_requests(self):
        assert len(EVENTS) == len(set(EVENTS))
        assert TERMINAL_EVENTS <= set(EVENTS)
        assert all(name.startswith("request.") for name in TERMINAL_EVENTS)


# -- terminal outcomes on a real runtime --------------------------------------


class TestTerminalOutcomes:
    def test_completed_spans_are_well_formed(self, cfg, params):
        rt = _runtime(cfg, params)
        with rt:
            rt.warmup()
            futs = [rt.submit(c) for c in _clouds(8, seed=1)]
            for f in futs:
                f.result(timeout=WAIT_S)
        events = rt.tracer.events()
        _assert_well_formed(events)
        timelines = request_timelines(events)
        assert len(timelines) == 8
        for tl in timelines.values():
            assert tl.terminal == "request.completed"
            assert tl.batch_id != -1
            # the span walked the full lifecycle, in order
            names = [e.name for e in tl.events]
            assert names[0] == "request.submit"
            for a, b in (
                ("request.submit", "request.admitted"),
                ("request.admitted", "request.enqueued"),
                ("request.enqueued", "request.drained"),
                ("request.drained", "request.assembled"),
                ("request.assembled", "request.completed"),
            ):
                assert names.index(a) < names.index(b)

    def test_completed_e2e_matches_recorded_latency(self, cfg, params):
        """Acceptance: trace e2e equals the metrics latency by construction,
        and the per-stage breakdown sums to it within tolerance."""
        rt = _runtime(cfg, params)
        with rt:
            rt.warmup()
            futs = [rt.submit(c) for c in _clouds(8, seed=2)]
            for f in futs:
                f.result(timeout=WAIT_S)
        timelines = request_timelines(rt.tracer.events())
        e2es = sorted(tl.e2e_s for tl in timelines.values())
        # trace e2e starts at the runtime's request.submit emit, the metric
        # at the queue's Request.submit_t a few microseconds later; the
        # completion edge is shared by construction, so the two agree to
        # well under a millisecond
        assert np.median(e2es) == pytest.approx(
            rt.metrics.snapshot().latency_p50_s, abs=1e-3
        )
        for tl in timelines.values():
            assert tl.residual_s is not None
            # stages telescope: the unattributed residual is a small fraction
            assert tl.residual_s <= 0.25 * tl.e2e_s + 1e-3

    def test_rejected_span(self, cfg, params):
        rt = _runtime(cfg, params, max_queue=2)  # scheduler never started
        try:
            clouds = _clouds(1)
            rt.submit(clouds[0])
            rt.submit(clouds[0])
            with pytest.raises(AdmissionError):
                rt.submit(clouds[0])
            events = rt.tracer.events()
            _assert_well_formed([e for e in events if e.trace_id == 3])
            rejected = [e for e in events if e.name == "request.rejected"]
            assert len(rejected) == 1
            assert rejected[0].args["reason"] == "queue_full"
        finally:
            rt.stop(drain=False)

    def test_shed_at_admission_span(self, cfg, params):
        rt = _runtime(cfg, params, max_queue=16, shed_threshold=2)
        try:
            clouds = _clouds(1)
            rt.submit(clouds[0], slo=BULK)
            rt.submit(clouds[0], slo=BULK)
            with pytest.raises(Shed):
                rt.submit(clouds[0], slo=BULK)
            shed = [e for e in rt.tracer.events() if e.name == "request.shed"]
            assert len(shed) == 1
            assert shed[0].args["reason"] == "admission"
            assert shed[0].slo == "bulk"
        finally:
            rt.stop(drain=False)

    def test_shed_by_eviction_span(self, cfg, params):
        rt = _runtime(cfg, params, max_queue=2)
        try:
            clouds = _clouds(1)
            rt.submit(clouds[0], slo=BULK)
            victim = rt.submit(clouds[0], slo=BULK)
            rt.submit(clouds[0], slo=INTERACTIVE)  # full: evicts newest bulk
            with pytest.raises(Shed):
                victim.result(timeout=WAIT_S)
            events = rt.tracer.events()
            shed = [e for e in events if e.name == "request.shed"]
            assert len(shed) == 1
            assert shed[0].args["reason"] == "evicted"
            assert shed[0].trace_id == 2  # the second submit was the victim
            _assert_well_formed([e for e in events if e.trace_id == 2])
        finally:
            rt.stop(drain=False)

    def test_expired_span(self, cfg, params):
        rt = _runtime(cfg, params, max_wait_s=0.2)
        with rt:
            fut = rt.submit(_clouds(1)[0], timeout_s=0.0)
            with pytest.raises(Exception):  # noqa: B017 — DeadlineExceeded
                fut.result(timeout=WAIT_S)
        events = rt.tracer.events()
        _assert_well_formed(events)
        assert [e.name for e in events if e.name in TERMINAL_EVENTS] == [
            "request.expired"
        ]

    def test_failed_span(self, cfg, params):
        """A batch whose execution future fails ends every member span in
        exactly one request.failed (plus a batch.failed on the batch span)."""
        rt = _runtime(cfg, params)

        def failing_dispatch(mb):
            fut = Future()
            fut.set_exception(RuntimeError("device on fire"))
            return fut

        rt.scheduler.dispatch_fn = failing_dispatch
        with rt:
            futs = [rt.submit(c) for c in _clouds(3, seed=3)]
            for f in futs:
                with pytest.raises(RuntimeError, match="device on fire"):
                    f.result(timeout=WAIT_S)
        events = rt.tracer.events()
        _assert_well_formed(events)
        assert sum(e.name == "request.failed" for e in events) == 3
        assert sum(e.name == "batch.failed" for e in events) >= 1


# -- chaos / evict / retry paths ----------------------------------------------


class TestChaosAndRetryTracing:
    def test_kill_evict_retry_completes_all_spans(self, cfg, params):
        """Chaos kill mid-trace: the stream shows chaos.kill,
        replica.evicted, batch.retry and a rejoin — and every request span
        still ends in exactly one request.completed."""
        rt = _runtime(
            cfg, params,
            n_replicas=2,
            autoscaler=AutoscalerConfig(
                poll_interval_s=0.02, rejoin_delay_s=0.05, cooldown_s=60.0
            ),
        )
        rt.warmup()
        ChaosInjector([Fault(replica_id=1, at_batch=1, kind="kill")]).attach(rt.pool)
        with rt:
            futs = [rt.submit(c) for c in _clouds(24, seed=11)]
            for f in futs:
                f.result(timeout=WAIT_S)
            deadline = time.monotonic() + WAIT_S
            while rt.metrics.rejoins < 1 and time.monotonic() < deadline:
                time.sleep(0.02)
        events = rt.tracer.events()
        _assert_well_formed(events)
        names = [e.name for e in events]
        assert names.count("chaos.kill") == 1
        assert "replica.evicted" in names
        assert "batch.retry" in names
        assert "scale.rejoin" in names and "replica.rejoin" in names
        kill = next(e for e in events if e.name == "chaos.kill")
        assert kill.replica_id == 1 and kill.batch_id != -1
        # every span completed despite the fault
        terminals = [e.name for e in events if e.name in TERMINAL_EVENTS]
        assert set(terminals) == {"request.completed"}
        assert len(terminals) == 24

    def test_wedge_eviction_traced(self, cfg, params):
        rt = _runtime(
            cfg, params,
            n_replicas=2,
            heartbeat_timeout_s=0.25,
            autoscaler=AutoscalerConfig(poll_interval_s=0.02, rejoin_delay_s=0.05),
        )
        rt.warmup()
        ChaosInjector(
            [Fault(replica_id=0, at_batch=0, kind="wedge", duration_s=1.0)]
        ).attach(rt.pool)
        with rt:
            futs = [rt.submit(c) for c in _clouds(8, seed=13)]
            for f in futs:
                f.result(timeout=WAIT_S)
            deadline = time.monotonic() + WAIT_S
            while rt.metrics.rejoins < 1 and time.monotonic() < deadline:
                time.sleep(0.02)
        events = rt.tracer.events()
        _assert_well_formed(events)
        names = [e.name for e in events]
        assert "chaos.wedge" in names
        assert "replica.evicted" in names
        terminals = [e.name for e in events if e.name in TERMINAL_EVENTS]
        assert set(terminals) == {"request.completed"} and len(terminals) == 8


# -- cache-path stage events --------------------------------------------------


class TestCacheTracing:
    def test_hits_trace_cache_and_feature_stages(self, cfg, params):
        """Duplicate clouds: the repeat batch shows cache hit probes and an
        all-hit cache_end(skip=True) followed by a feature stage — the
        preprocess stage is absent, matching the skip the cache promises."""
        rt = _runtime(cfg, params, cache_max_bytes=64 * 2**20)
        clouds = _clouds(MAX_BATCH, seed=5)
        with rt:
            rt.warmup()
            for f in [rt.submit(c) for c in clouds]:  # cold: misses + insert
                f.result(timeout=WAIT_S)
            # the cache fill is a background insert on the replica thread;
            # wait for it so the warm round probes a populated cache
            deadline = time.monotonic() + WAIT_S
            while (
                rt.cache.stats().insertions < len(clouds)
                and time.monotonic() < deadline
            ):
                time.sleep(0.01)
            for f in [rt.submit(c) for c in clouds]:  # warm: all hits
                f.result(timeout=WAIT_S)
        events = rt.tracer.events()
        _assert_well_formed(events)
        names = [e.name for e in events]
        assert "cache.insert" in names
        lookups = [e for e in events if e.name == "request.cache_lookup"]
        assert any(e.args["hit"] for e in lookups)
        assert any(not e.args["hit"] for e in lookups)
        skips = [
            e for e in events
            if e.name == "batch.cache_end" and e.args and e.args.get("skip")
        ]
        assert skips, "no all-hit batch traced a cache_end(skip=True)"
        skip_bid = skips[0].batch_id
        batch_names = {e.name for e in events if e.batch_id == skip_bid}
        assert "batch.feature_start" in batch_names
        assert "batch.preprocess_start" not in batch_names


# -- sampling -----------------------------------------------------------------


class TestSampling:
    def test_sample_zero_keeps_batch_events_only(self, cfg, params):
        rt = _runtime(cfg, params, trace=TraceConfig(sample=0.0))
        with rt:
            rt.warmup()
            for f in [rt.submit(c) for c in _clouds(4, seed=7)]:
                f.result(timeout=WAIT_S)
        events = rt.tracer.events()
        assert events, "batch/control events must flow even at sample=0"
        assert all(not e.name.startswith("request.") for e in events)
        assert all(e.trace_id == -1 for e in events)
        # the batch frame of reference is intact
        assert any(e.name == "batch.assembled" for e in events)
        members = next(e for e in events if e.name == "batch.assembled").args[
            "members"
        ]
        assert members == []  # no sampled members to link

    def test_sample_one_traces_every_request(self, cfg, params):
        rt = _runtime(cfg, params, trace=TraceConfig(sample=1.0))
        with rt:
            rt.warmup()
            for f in [rt.submit(c) for c in _clouds(4, seed=7)]:
                f.result(timeout=WAIT_S)
        assert len(request_timelines(rt.tracer.events())) == 4


# -- high-water marks + straggler attribution ---------------------------------


class TestGauges:
    def test_queue_hwm_sees_bursts_between_drains(self):
        m = ServeMetrics()
        q = AdmissionQueue(16, metrics=m)
        clouds = np.zeros((8, 3), np.float32)
        from repro.core.policy import ExecutionPolicy

        for _ in range(5):
            q.submit(clouds, bucket=256, policy=ExecutionPolicy(), slo=BULK)
        q.drain(16, timeout_s=1.0)  # queue is empty again...
        snap = m.snapshot()
        assert snap.queue_depth_hwm == 5  # ...but the mark remembers the burst
        assert snap.for_class("bulk").depth_hwm == 5

    def test_inflight_hwm_monotonic(self):
        m = ServeMetrics()
        m.record_inflight(2)
        m.record_inflight(5)
        m.record_inflight(1)
        assert m.snapshot().inflight_hwm == 5

    def test_runtime_populates_hwms(self, cfg, params):
        rt = _runtime(cfg, params)
        with rt:
            rt.warmup()
            for f in [rt.submit(c) for c in _clouds(8, seed=9)]:
                f.result(timeout=WAIT_S)
        snap = rt.metrics.snapshot()
        assert snap.queue_depth_hwm >= 1
        assert snap.inflight_hwm >= 1

    def test_straggler_attribution(self):
        class _Ev:
            duration_s, median_s, ratio = 0.5, 0.1, 5.0

        m = ServeMetrics()
        m.record_straggler(_Ev(), replica_id=2)
        m.record_straggler(_Ev(), replica_id=2)
        m.record_straggler(_Ev(), replica_id=0)
        snap = m.snapshot()
        assert snap.straggler_events == 3
        assert snap.stragglers_by_replica == ((0, 1), (2, 2))

    def test_pool_straggler_hook_emits_event(self, cfg, params):
        from repro.serve import ReplicaPool

        class _Ev:
            duration_s, median_s, ratio = 0.5, 0.1, 5.0

        metrics = ServeMetrics()
        tracer = Tracer()
        pool = ReplicaPool(
            cfg, params, n_replicas=1, metrics=metrics, tracer=tracer
        )
        try:
            pool._on_straggler(0, _Ev())
        finally:
            pool.shutdown()
        assert metrics.snapshot().stragglers_by_replica == ((0, 1),)
        straggles = [e for e in tracer.events() if e.name == "replica.straggler"]
        assert len(straggles) == 1
        assert straggles[0].replica_id == 0
        assert straggles[0].args["ratio"] == 5.0


# -- reductions on synthetic streams ------------------------------------------


def _synthetic_stream():
    """One hand-timed request through every sequential stage."""
    t = {
        "submit": 1.00, "admitted": 1.001, "enqueued": 1.002, "drained": 1.10,
        "assembled": 1.15, "exec0": 1.20, "exec1": 1.70, "completed": 1.75,
    }
    return [
        TraceEvent("request.submit", t["submit"], trace_id=1, slo="default"),
        TraceEvent("request.admitted", t["admitted"], trace_id=1, slo="default"),
        TraceEvent("request.enqueued", t["enqueued"], trace_id=1, slo="default"),
        TraceEvent("request.drained", t["drained"], trace_id=1, slo="default"),
        TraceEvent("batch.assembled", t["assembled"], batch_id=7, args={"members": [1]}),
        TraceEvent("request.assembled", t["assembled"], trace_id=1, batch_id=7),
        TraceEvent("batch.dispatched", 1.16, batch_id=7, replica_id=0),
        TraceEvent("batch.execute_start", t["exec0"], batch_id=7),
        TraceEvent("batch.execute_end", t["exec1"], batch_id=7),
        TraceEvent("request.completed", t["completed"], trace_id=1, batch_id=7),
        TraceEvent("batch.completed", 1.76, batch_id=7),
    ]


class TestReductions:
    def test_stage_math_is_exact(self):
        tl = request_timelines(_synthetic_stream())[1]
        assert tl.terminal == "request.completed"
        assert tl.e2e_s == pytest.approx(0.75)
        assert tl.stages["queue"] == pytest.approx(0.10)
        assert tl.stages["assembly"] == pytest.approx(0.05)
        assert tl.stages["dispatch"] == pytest.approx(0.05)
        assert tl.stages["execute"] == pytest.approx(0.50)
        assert tl.stages["finalize"] == pytest.approx(0.05)
        assert tl.residual_s == pytest.approx(0.0)

    def test_trace_problems_flags_malformed(self):
        good = _synthetic_stream()
        assert trace_problems(good) == []
        no_terminal = [e for e in good if e.name != "request.completed"]
        assert trace_problems(no_terminal) == ["trace 1: no terminal event"]
        double = good + [TraceEvent("request.failed", 1.8, trace_id=1)]
        assert "multiple terminals" in trace_problems(double)[0]
        regressed = good[:1] + [TraceEvent("request.drained", 0.5, trace_id=1)]
        assert any("regressed" in p for p in trace_problems(regressed))

    def test_truncated_head_is_skipped(self):
        tail = [e for e in _synthetic_stream() if e.name != "request.submit"]
        assert trace_problems(tail) == []  # ring overflow is not a violation

    def test_stage_breakdown_percentiles(self):
        stream = _synthetic_stream()
        bd = stage_breakdown(stream)
        assert bd.counts == {"default": 1}
        p50, p95 = bd.per_class["default"]["execute"]
        assert p50 == pytest.approx(0.50) and p95 == pytest.approx(0.50)
        assert "execute" in bd.format_rows()

    def test_batch_crosscheck(self):
        rec = BatchRecord(
            bucket=256, policy_key=("fp32", "jax", "sequential"), n_real=1,
            batch_size=4, replica_id=0, duration_s=0.50, batch_id=7,
        )
        checks = batch_crosscheck(_synthetic_stream(), (rec,))
        assert len(checks) == 1
        assert checks[0].span_s == pytest.approx(0.50)
        assert checks[0].rel_err == pytest.approx(0.0)
        # records without a span (or untraced) are skipped, not crashed
        assert batch_crosscheck([], (rec,)) == []

    def test_crosscheck_on_real_run(self, cfg, params):
        """Acceptance: trace spans reconcile with the independently-timed
        BatchRecord wall clock on a live sequential run."""
        rt = _runtime(cfg, params)
        with rt:
            rt.warmup()
            for f in [rt.submit(c) for c in _clouds(8, seed=21)]:
                f.result(timeout=WAIT_S)
        checks = batch_crosscheck(rt.tracer.events(), rt.metrics.batch_records)
        assert checks, "no batch reconciled"
        assert all(c.rel_err < 0.5 for c in checks)


# -- exporters ----------------------------------------------------------------


class TestExporters:
    def test_chrome_trace_structure(self, tmp_path):
        stream = _synthetic_stream() + [
            TraceEvent("replica.evicted", 1.9, replica_id=1, args={"reason": "x"}),
        ]
        doc = to_chrome_trace(stream)
        assert doc["displayTimeUnit"] == "ms"
        evs = doc["traceEvents"]
        meta = [e for e in evs if e["ph"] == "M"]
        assert {m["args"]["name"] for m in meta} == {
            "requests", "batches", "control-plane",
        }
        slices = [e for e in evs if e["ph"] == "X"]
        req_slice = next(e for e in slices if e["pid"] == 1)
        assert req_slice["dur"] == pytest.approx(0.75 * 1e6)
        exec_slice = next(
            e for e in slices if e["pid"] == 2 and e["name"] == "execute"
        )
        assert exec_slice["dur"] == pytest.approx(0.50 * 1e6)
        control = [e for e in evs if e["pid"] == 3 and e["ph"] == "i"]
        assert [c["name"] for c in control] == ["replica.evicted"]
        # the file round-trips as JSON (Perfetto-loadable)
        path = tmp_path / "trace.json"
        n = write_chrome_trace(path, stream)
        loaded = json.loads(path.read_text())
        assert len(loaded["traceEvents"]) == n

    def test_prometheus_text_shape(self):
        m = ServeMetrics()
        m.record_submitted("interactive")
        m.record_completed(0.01, "interactive")
        m.record_straggler(None, replica_id=1)
        m.record_queue_hwm(7, "interactive", 7)
        text = prometheus_text(m.snapshot())
        assert text.endswith("\n")
        assert "pc2im_serve_submitted_total 1" in text
        assert 'pc2im_serve_latency_seconds{quantile="0.5"}' in text
        assert 'pc2im_serve_stragglers_total{replica="1"} 1' in text
        assert 'pc2im_serve_class_completed_total{slo="interactive"} 1' in text
        assert "pc2im_serve_queue_depth_hwm 7" in text
        # HELP/TYPE precede every family exactly once
        for line in text.splitlines():
            if line.startswith("pc2im_serve_submitted_total"):
                idx = text.splitlines().index(line)
                assert text.splitlines()[idx - 1].startswith("# TYPE")
                assert text.splitlines()[idx - 2].startswith("# HELP")
                break


# -- reporter -----------------------------------------------------------------


class TestReporter:
    def test_interval_validation(self):
        with pytest.raises(ValueError, match="interval_s"):
            Reporter(ServeMetrics(), 0.0)

    def test_report_once_and_sink(self):
        lines = []
        m = ServeMetrics()
        m.record_submitted()
        m.record_completed(0.01)
        rep = Reporter(m, 10.0, sink=lines.append, tracer=Tracer())
        line = rep.report_once()
        assert lines == [line]
        assert line.startswith("[serve] completed=1")
        assert "trace=0ev" in line
        assert rep.last_snapshot.completed == 1

    def test_thread_ticks_and_final_report(self):
        lines = []
        rep = Reporter(ServeMetrics(), 0.02, sink=lines.append)
        rep.start()
        time.sleep(0.1)
        rep.stop()
        assert rep.ticks >= 2  # periodic ticks plus the final flush
        assert len(lines) == rep.ticks

    def test_runtime_owns_reporter(self, cfg, params):
        rt = _runtime(cfg, params, report_interval_s=30.0)
        assert rt.reporter is not None
        with rt:
            rt.warmup()
            rt.submit(_clouds(1)[0]).result(timeout=WAIT_S)
        # stop() flushed a final tick with the end-state snapshot
        assert rt.reporter.last_snapshot is not None
        assert rt.reporter.last_snapshot.completed == 1


# -- off is off ---------------------------------------------------------------


class TestTracingOff:
    def test_no_tracer_anywhere_by_default(self, cfg, params):
        rt = ServingRuntime(
            cfg, params,
            RuntimeConfig(max_batch=MAX_BATCH, buckets=(cfg.n_points,)),
        )
        try:
            assert rt.tracer is None
            assert rt.queue.tracer is None
            assert rt.scheduler.tracer is None
            assert rt.pool.tracer is None
            assert rt.reporter is None
        finally:
            rt.stop(drain=False)

    def test_untraced_run_still_serves(self, cfg, params):
        rt = ServingRuntime(
            cfg, params,
            RuntimeConfig(max_batch=MAX_BATCH, buckets=(cfg.n_points,)),
        )
        with rt:
            rt.warmup()
            out = rt.submit(_clouds(1)[0]).result(timeout=WAIT_S)
        assert out.shape == (cfg.n_classes,)
