"""Tests for core/partition.py (C2) and core/query.py (C1 lattice query)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis import given, settings, st

from repro.core import partition as P
from repro.core import query as Q

jax.config.update("jax_platform_name", "cpu")


def _cloud(n, seed=0):
    return jax.random.uniform(jax.random.PRNGKey(seed), (n, 3), minval=-1.0, maxval=1.0)


class TestMedianPartition:
    @pytest.mark.parametrize("depth", [1, 2, 3])
    @pytest.mark.parametrize("axis_mode", ["cycle", "widest"])
    def test_equal_disjoint_cover(self, depth, axis_mode):
        pts = _cloud(256)
        part = P.median_partition(pts, depth, axis_mode=axis_mode)
        tiles = np.array(part.tiles)
        assert tiles.shape == (2**depth, 256 // 2**depth)
        # disjoint exact cover of all indices
        np.testing.assert_array_equal(np.sort(tiles.ravel()), np.arange(256))
        assert float(part.utilization()) == 1.0  # MSP: zero padding

    def test_split_is_spatial(self):
        # after one split on the widest axis, tile-0 coords <= tile-1 coords on that axis
        pts = _cloud(128)
        part = P.median_partition(pts, 1, axis_mode="widest")
        c = np.array(pts)
        ext = c.max(0) - c.min(0)
        ax = int(np.argmax(ext))
        t0 = c[np.array(part.tiles[0]), ax]
        t1 = c[np.array(part.tiles[1]), ax]
        assert t0.max() <= t1.min() + 1e-6

    def test_non_divisible_raises(self):
        with pytest.raises(ValueError):
            P.median_partition(_cloud(100), 3)

    def test_pad_points(self):
        pts = _cloud(100)
        padded, valid = P.pad_points(pts, 64)
        assert padded.shape == (128, 3)
        assert int(valid.sum()) == 100


class TestMortonGrid:
    def test_morton_equal_chunks(self):
        pts = _cloud(128)
        part = P.morton_partition(pts, 2)
        tiles = np.array(part.tiles)
        np.testing.assert_array_equal(np.sort(tiles.ravel()), np.arange(128))

    def test_grid_partition_masks_and_capacity(self):
        pts = _cloud(256)
        part = P.grid_partition(pts, grid=2, capacity=64)
        assert part.tiles.shape == (8, 64)
        valid = np.array(part.valid)
        tiles = np.array(part.tiles)
        # every real point appears at most once; padded slots masked
        real = tiles[valid]
        assert len(np.unique(real)) == len(real)
        # utilization < 1 (ragged occupancy) — the padding waste MSP removes
        assert float(part.utilization()) < 1.0

    def test_grid_points_in_right_cell(self):
        pts = _cloud(64)
        part = P.grid_partition(pts, grid=2, capacity=64)
        c = np.array(pts)
        lo, hi = c.min(0), c.max(0)
        cell = np.clip(np.floor((c - lo) / np.maximum(hi - lo, 1e-12) * 2), 0, 1).astype(int)
        tid = cell[:, 0] * 4 + cell[:, 1] * 2 + cell[:, 2]
        tiles, valid = np.array(part.tiles), np.array(part.valid)
        for t in range(8):
            for idx in tiles[t][valid[t]]:
                assert tid[idx] == t


class TestQueries:
    def test_ball_query_semantics(self):
        pts = _cloud(64)
        cxyz = pts[:4]
        r = 0.5
        res = Q.ball_query(pts, cxyz, r, nsample=8)
        idx, mask = np.array(res.idx), np.array(res.mask)
        d = np.sqrt(np.array(Q.pairwise_distance(cxyz, pts, "l2")))
        for m in range(4):
            hits = np.where(d[m] <= r)[0]
            expect = hits[:8]
            got = idx[m][mask[m]]
            np.testing.assert_array_equal(got, expect)
            # padded slots repeat the first hit
            if len(expect) > 0 and len(expect) < 8:
                assert (idx[m][~mask[m]] == expect[0]).all()

    def test_lattice_query_covers_ball(self):
        """paper C1: L1 lattice with L=1.6R must capture (almost) all L2-ball
        neighbours — 'no explicit information loss'."""
        pts = _cloud(512)
        cxyz = pts[:16]
        r = 0.4
        ball = Q.ball_query(pts, cxyz, r, nsample=512)
        lat = Q.lattice_query(pts, cxyz, r, nsample=512)
        bi, bm = np.array(ball.idx), np.array(ball.mask)
        li, lm = np.array(lat.idx), np.array(lat.mask)
        total, captured = 0, 0
        for m in range(16):
            bset = set(bi[m][bm[m]].tolist())
            lset = set(li[m][lm[m]].tolist())
            total += len(bset)
            captured += len(bset & lset)
        assert total > 0
        assert captured / total >= 0.97  # paper: empirical 1.6 factor, near-lossless

    def test_lattice_uses_l1_metric(self):
        pts = jnp.array([[0.0, 0, 0], [0.5, 0.5, 0.5], [1.2, 0, 0]])
        c = jnp.zeros((1, 3))
        res = Q.lattice_query(pts, c, radius=1.0, nsample=4)  # L = 1.6
        mask = np.array(res.mask)[0]
        # point1 L1=1.5<=1.6 in; point2 L1=1.2<=1.6 in
        assert mask[:3].sum() == 3

    def test_knn_matches_numpy(self):
        pts = _cloud(64)
        qs = _cloud(16, 1)
        idx, dist = Q.knn(qs, pts, 3)
        d = np.array(Q.pairwise_distance(qs, pts, "l2"))
        ref = np.argsort(d, axis=1)[:, :3]
        np.testing.assert_array_equal(np.array(idx), ref)
        np.testing.assert_allclose(np.array(dist), np.take_along_axis(d, ref, 1), rtol=1e-5)

    def test_three_nn_weights_normalised(self):
        _, dist = Q.knn(_cloud(8, 1), _cloud(64), 3)
        w = Q.three_nn_interpolate_weights(dist)
        np.testing.assert_allclose(np.array(w.sum(1)), 1.0, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), depth=st.integers(1, 3))
def test_property_msp_permutation_of_indices(seed, depth):
    """Property: MSP is always an exact permutation (equal-size, disjoint, total)."""
    pts = jax.random.normal(jax.random.PRNGKey(seed), (64, 3))
    part = P.median_partition(pts, depth)
    np.testing.assert_array_equal(np.sort(np.array(part.tiles).ravel()), np.arange(64))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_lattice_aggregate_recall(seed):
    """Property: lattice(1.6R) captures >=95% of ball(R) members in aggregate.

    NOT a strict superset: near-diagonal points need L = sqrt(3)R ~ 1.73R —
    the paper's 1.6 is an EMPIRICAL near-lossless factor (hypothesis found
    the boundary case at seed 4853), so the claim is aggregate recall."""
    pts = jax.random.uniform(jax.random.PRNGKey(seed), (128, 3))
    c = pts[:4]
    ball = Q.ball_query(pts, c, 0.3, nsample=128)
    lat = Q.lattice_query(pts, c, 0.3, nsample=128)
    n_ball = np.array(ball.mask).sum()
    n_lat = np.array(lat.mask).sum()
    assert n_lat >= n_ball * 0.95
