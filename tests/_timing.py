"""Shared timing helpers for timing-sensitive serving tests.

The convention (see ARCHITECTURE.md, testing notes): tests never gate
*liveness* on a bare wall-clock sleep — they wait on an observable
condition with a generous deadline, so a loaded machine makes the test
slower, never flakier.  Dwell/delay logic is made deterministic by
rewinding the recorded timestamp (e.g. `Replica.evicted_t`) instead of
sleeping the dwell out.  Every deadline is scaled by the
``PC2IM_TEST_TIME_MULT`` env var (default 1.0, floor 1.0) so a saturated
CI host can stretch every budget with one knob instead of per-test edits.
"""

from __future__ import annotations

import os
import time
from typing import Callable


def time_mult() -> float:
    """Global test-time budget multiplier from PC2IM_TEST_TIME_MULT (>= 1)."""
    try:
        return max(1.0, float(os.environ.get("PC2IM_TEST_TIME_MULT", "1")))
    except ValueError:
        return 1.0


def wait_until(
    pred: Callable,
    timeout_s: float = 10.0,
    interval_s: float = 0.005,
    desc: str = "condition",
):
    """Poll `pred` until truthy; raise AssertionError at the scaled deadline.

    Returns the final pred() value so callers can assert on it directly.
    """
    budget = timeout_s * time_mult()
    deadline = time.monotonic() + budget
    while True:
        val = pred()
        if val:
            return val
        if time.monotonic() >= deadline:
            raise AssertionError(
                f"timed out after {budget:.1f}s waiting for {desc}"
            )
        time.sleep(interval_s)
