"""Property tests for the SC-CIM quantized linear (`sc_quantized_linear`).

The quant path previously had no direct coverage: these tests bound the
w16a16 / w8a8 error against the f32 matmul across randomly drawn batched
shapes (hypothesis; skipped gracefully when not installed), and pin the
policy->backend piping — the Pallas (interpret) backend must agree with the
XLA reference bit for bit, since `nn.linear` forwards
`ExecutionPolicy.backend` straight into the registry dispatch.

Error model: symmetric per-tensor quantization has elementwise error
<= s/2 with s = max|.| / (2^(b-1) - 1), so the matmul's relative Frobenius
error is O(2^-(b-1)) for well-conditioned random operands — we assert a
conservative 10x slack on that (w16a16: 1e-3, w8a8: 5e-2).
"""

import jax
import numpy as np
import pytest
from _hypothesis import given, settings, st

from repro.core.policy import ExecutionPolicy
from repro.kernels.sc_matmul.ops import sc_quantized_linear
from repro.models import nn

jax.config.update("jax_platform_name", "cpu")

BOUNDS = {16: 1e-3, 8: 5e-2}


def _rel_err(got, ref):
    got, ref = np.asarray(got, np.float64), np.asarray(ref, np.float64)
    return np.linalg.norm(got - ref) / max(np.linalg.norm(ref), 1e-12)


def _operands(lead, k, n, seed, scale):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, tuple(lead) + (k,)) * scale
    w = jax.random.normal(kw, (k, n))
    return x, w


class TestQuantErrorBounds:
    @pytest.mark.parametrize("bits", [16, 8])
    @pytest.mark.parametrize("lead", [(4,), (2, 3), (2, 2, 5)])
    def test_error_bounded_fixed_shapes(self, bits, lead):
        x, w = _operands(lead, 32, 16, seed=bits, scale=1.0)
        got = sc_quantized_linear(x, w, bits=bits, backend="xla")
        assert got.shape == tuple(lead) + (16,)
        assert _rel_err(got, x @ w) < BOUNDS[bits]

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 6),
        s=st.integers(1, 8),
        k=st.integers(1, 48),
        n=st.integers(1, 24),
        bits=st.sampled_from([16, 8]),
        seed=st.integers(0, 2**16),
        log_scale=st.integers(-4, 4),
    )
    def test_error_bounded_property(self, b, s, k, n, bits, seed, log_scale):
        """w16a16/w8a8 stay within their bound vs f32 matmul for arbitrary
        batched shapes and operand magnitudes (scale invariance of the
        symmetric per-tensor scheme)."""
        x, w = _operands((b, s), k, n, seed=seed, scale=float(10.0**log_scale))
        got = sc_quantized_linear(x, w, bits=bits, backend="xla")
        assert got.shape == (b, s, n)
        assert _rel_err(got, x @ w) < BOUNDS[bits]

    @settings(max_examples=10, deadline=None)
    @given(bits=st.sampled_from([16, 8]), seed=st.integers(0, 2**16))
    def test_linear_policy_matches_op(self, bits, seed):
        """nn.linear under a policy == calling the op directly with the
        policy's backend/interpret — the piping adds nothing."""
        x, w = _operands((3, 4), 16, 8, seed=seed, scale=1.0)
        p = {"w": w}
        mode = {16: "sc_w16a16", 8: "sc_w8a8"}[bits]
        pol = ExecutionPolicy(quant=mode, backend="xla")
        got = nn.linear(p, x, policy=pol)
        ref = sc_quantized_linear(x, w, bits=bits, backend="xla").astype(x.dtype)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


class TestBackendPiping:
    def test_pallas_interpret_matches_xla(self):
        """The policy's backend reaches the registry: pallas (interpret on
        CPU) and xla produce identical results for the same policy quant."""
        x, w = _operands((4, 4), 32, 16, seed=3, scale=1.0)
        p = {"w": w}
        a = nn.linear(p, x, policy=ExecutionPolicy(quant="sc_w16a16", backend="xla"))
        b = nn.linear(
            p, x,
            policy=ExecutionPolicy(quant="sc_w16a16", backend="pallas", interpret=True),
        )
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)

    def test_bad_backend_rejected_at_policy(self):
        with pytest.raises(ValueError):
            ExecutionPolicy(quant="sc_w16a16", backend="rocm")
