"""Adaptive control-plane tests: DRR weighted-fair drain, pause-free
reconfiguration, the AdaptiveController feedback loop, cost-signal
autoscaling, config validation, and the live Prometheus endpoint.

Layered like the subsystem: the DRR drain and the knob-proposal math are
pinned as pure properties (hypothesis cross-checks the weighted-share and
EDF-within-class invariants on random traffic); the AdaptiveController units
run against a fake runtime so proposal/hysteresis/rollback logic is
deterministic; and the integration tests drive a real ServingRuntime
through a mid-stream `reconfigure` asserting zero loss and bitwise parity
against direct accelerator references.  All waits are bounded
(tests/_timing.py) so failures surface as assertions, never hangs.
"""

import dataclasses
import time
import urllib.error
import urllib.request
from types import SimpleNamespace

import jax
import numpy as np
import pytest
from _hypothesis import given, settings, st
from _timing import time_mult, wait_until

from repro.configs.base import get_config
from repro.core.accelerator import get_accelerator
from repro.core.policy import ExecutionPolicy
from repro.serve import (
    BULK,
    INTERACTIVE,
    AdaptiveConfig,
    AdaptiveController,
    AdmissionQueue,
    Autoscaler,
    AutoscalerConfig,
    Histogram,
    MetricsServer,
    RuntimeConfig,
    SchedulerConfig,
    ServeMetrics,
    ServingRuntime,
    SLOClass,
    interarrival_mean,
    pad_cloud,
    padding_waste,
    propose_buckets,
    propose_wait,
)
from repro.serve.metrics import BatchRecord

jax.config.update("jax_platform_name", "cpu")

WAIT_S = 60 * time_mult()
CLOUD = np.zeros((8, 3), np.float32)
POL = ExecutionPolicy()


@pytest.fixture(scope="module")
def cfg():
    return get_config("pointnet2-cls", smoke=True)  # n_points=256


@pytest.fixture(scope="module")
def params(cfg):
    return get_accelerator(cfg).init(jax.random.PRNGKey(0))


# -- proposal math (pure units) -----------------------------------------------


class TestHistogram:
    def test_counts_and_mean(self):
        h = Histogram()
        h.extend([100, 100, 200, 300])
        assert len(h) == 4
        assert h.mean() == pytest.approx(175.0)

    def test_quantile_reads_empirical_cdf(self):
        h = Histogram()
        h.add(10, count=9)
        h.add(1000)
        assert h.quantile(0.5) == 10
        assert h.quantile(0.9) == 10
        assert h.quantile(1.0) == 1000

    def test_rejects_nonpositive_and_empty(self):
        h = Histogram()
        with pytest.raises(ValueError, match="> 0"):
            h.add(0)
        with pytest.raises(ValueError, match="empty"):
            h.quantile(0.5)
        with pytest.raises(ValueError, match="q must be"):
            Histogram().quantile(1.5)
        assert h.mean() == 0.0


class TestProposalMath:
    def test_padding_waste_exact(self):
        # sizes 64 and 128 at a single 128 bucket: rows 64..127 are filler
        # for the first cloud, none for the second
        waste = padding_waste(np.array([64, 128]), (128,))
        assert waste == pytest.approx(((128 - 64) / 128 + 0.0) / 2)
        assert padding_waste(np.array([], np.int64), (128,)) == 0.0
        # oversized clouds subsample to the top bucket: no padding waste
        assert padding_waste(np.array([999]), (128,)) == 0.0

    def test_propose_buckets_quantiles_align_and_envelope(self):
        sizes = np.array([90] * 50 + [250] * 50)
        got = propose_buckets(sizes, 2, align=32, min_bucket=64, max_bucket=256)
        assert got[-1] == 256  # envelope always kept
        assert all(b % 32 == 0 for b in got)
        assert got[0] == 96  # ceil(90 / 32) * 32
        assert got == tuple(sorted(set(got)))

    def test_propose_buckets_clamps_and_validates(self):
        assert propose_buckets(np.array([5, 7]), 2, align=32,
                               min_bucket=64, max_bucket=256) == (64, 256)
        assert propose_buckets(np.array([], np.int64), 2, align=32,
                               min_bucket=64, max_bucket=256) == (256,)
        with pytest.raises(ValueError, match="n_buckets"):
            propose_buckets(np.array([1]), 0, min_bucket=1, max_bucket=2)
        with pytest.raises(ValueError, match="min_bucket"):
            propose_buckets(np.array([1]), 1, min_bucket=8, max_bucket=4)

    def test_interarrival_and_wait(self):
        assert interarrival_mean(np.array([1.0])) is None
        gap = interarrival_mean(np.array([0.0, 0.01, 0.02, 0.03]))
        assert gap == pytest.approx(0.01)
        # fill time for a batch of 4 at 10ms gaps = 30ms, clamped to 50ms cap
        assert propose_wait(gap, 4, bounds=(0.001, 0.05)) == pytest.approx(0.03)
        assert propose_wait(gap, 100, bounds=(0.001, 0.05)) == 0.05
        assert propose_wait(1e-9, 4, bounds=(0.001, 0.05)) == 0.001
        assert propose_wait(None, 4, bounds=(0.001, 0.05)) is None


# -- DRR weighted-fair drain --------------------------------------------------


def _fill(q, slo, k, timeout_s=None):
    for _ in range(k):
        q.submit(CLOUD, bucket=256, policy=POL, slo=slo, timeout_s=timeout_s)


class TestDRRDrain:
    def test_weight_validation(self):
        with pytest.raises(ValueError, match="class_weights"):
            AdmissionQueue(8, class_weights={"bulk": 0.0})
        with pytest.raises(ValueError, match="class_weights"):
            RuntimeConfig(class_weights=(("bulk", -1.0),))

    def test_share_tracks_weights_under_backlog(self):
        q = AdmissionQueue(512, class_weights={"interactive": 4.0, "bulk": 1.0})
        _fill(q, INTERACTIVE, 200)
        _fill(q, BULK, 200)
        drained = []
        while len(drained) < 100:
            got = q.drain(5, timeout_s=0.0)
            assert len(got) == 5  # work-conserving: full allowance every call
            drained.extend(got)
        n_inter = sum(1 for r in drained if r.slo is INTERACTIVE)
        n_bulk = len(drained) - n_inter
        # both lanes stayed backlogged for the whole window, so the shares
        # must converge to the 4:1 weights (classic DRR deviation bound:
        # within one quantum per lane of the ideal share)
        assert abs(n_inter - 80) <= 5
        assert abs(n_bulk - 20) <= 5

    def test_edf_order_within_class(self):
        q = AdmissionQueue(64, class_weights={"interactive": 2.0, "bulk": 1.0})
        rng = np.random.default_rng(0)
        for t in rng.permutation([5.0, 1.0, 9.0, 3.0, 7.0]):
            q.submit(CLOUD, bucket=256, policy=POL, slo=INTERACTIVE,
                     timeout_s=float(t))
        out = q.drain(5, timeout_s=0.0)
        deadlines = [r.deadline_t for r in out]
        assert deadlines == sorted(deadlines)

    def test_idle_lane_forfeits_deficit(self):
        q = AdmissionQueue(64, class_weights={"interactive": 8.0, "bulk": 1.0})
        _fill(q, INTERACTIVE, 2)
        _fill(q, BULK, 8)
        assert len(q.drain(10, timeout_s=0.0)) == 10  # nothing stranded
        # the interactive lane went idle after 2; its 6 unspent credits must
        # NOT persist: refill both lanes and check bulk still gets served
        _fill(q, INTERACTIVE, 16)
        _fill(q, BULK, 16)
        got = q.drain(9, timeout_s=0.0)
        assert sum(1 for r in got if r.slo is BULK) >= 1

    @settings(max_examples=25, deadline=None)
    @given(
        w_inter=st.integers(min_value=1, max_value=8),
        w_bulk=st.integers(min_value=1, max_value=8),
        chunk=st.integers(min_value=1, max_value=7),
        seed=st.integers(min_value=0, max_value=2**16),
    )
    def test_property_share_and_edf(self, w_inter, w_bulk, chunk, seed):
        """Share converges to weights and EDF holds within each class."""
        total = 60
        q = AdmissionQueue(
            1024,
            class_weights={"interactive": float(w_inter), "bulk": float(w_bulk)},
        )
        rng = np.random.default_rng(seed)
        for t in rng.uniform(1.0, 100.0, size=200):
            q.submit(CLOUD, bucket=256, policy=POL, slo=INTERACTIVE,
                     timeout_s=float(t))
        _fill(q, BULK, 200)
        drained = []
        while len(drained) < total:
            got = q.drain(min(chunk, total - len(drained)), timeout_s=0.0)
            assert got, "work-conserving: backlog present but drain empty"
            drained.extend(got)
        n_inter = sum(1 for r in drained if r.slo is INTERACTIVE)
        ideal = total * w_inter / (w_inter + w_bulk)
        # DRR deviation bound: one quantum per lane per round plus the
        # chunk-boundary effects; generous but weight-sensitive
        assert abs(n_inter - ideal) <= 2 * max(w_inter, w_bulk) + chunk
        inter_deadlines = [r.deadline_t for r in drained if r.slo is INTERACTIVE]
        assert inter_deadlines == sorted(inter_deadlines)
        bulk_ids = [r.id for r in drained if r.slo is BULK]
        assert bulk_ids == sorted(bulk_ids)  # no deadlines: admission order

    def test_starvation_bounded_under_extreme_weights(self):
        q = AdmissionQueue(512, class_weights={"interactive": 100.0, "bulk": 1.0})
        _fill(q, INTERACTIVE, 250)
        _fill(q, BULK, 20)
        drained = []
        while len(drained) < 210:
            drained.extend(q.drain(10, timeout_s=0.0))
        assert any(r.slo is BULK for r in drained)  # never fully starved


# -- RuntimeConfig validation + oversize policy --------------------------------


class TestRuntimeConfigValidation:
    @pytest.mark.parametrize(
        "buckets", [(256, 128), (128, 128), (0, 128), (-5,), (128.5,), ()]
    )
    def test_malformed_buckets_rejected(self, buckets):
        with pytest.raises(ValueError, match="buckets"):
            RuntimeConfig(buckets=buckets)

    def test_valid_buckets_kept_in_order(self):
        assert RuntimeConfig(buckets=(64, 128, 256)).buckets == (64, 128, 256)

    def test_oversize_value_checked(self):
        with pytest.raises(ValueError, match="oversize"):
            RuntimeConfig(oversize="drop")

    def test_prometheus_port_checked(self):
        with pytest.raises(ValueError, match="prometheus_port"):
            RuntimeConfig(prometheus_port=-1)

    def test_oversize_reject_names_buckets(self, cfg, params):
        rt = ServingRuntime(
            cfg, params,
            RuntimeConfig(buckets=(64, 128), oversize="reject"),
        )
        try:
            with pytest.raises(ValueError, match=r"buckets=\(64, 128\)"):
                rt.submit(np.zeros((200, 3), np.float32))
            # at or below the largest bucket admission still works
            rt.submit(np.zeros((128, 3), np.float32))
        finally:
            rt.stop()


# -- pause-free reconfiguration -----------------------------------------------


class TestSchedulerApplyConfig:
    def test_version_forced_monotonic(self):
        base = SchedulerConfig(max_batch=4)
        sched = SimpleNamespace(config=base)
        # exercise the real method against a bare holder object
        from repro.serve.scheduler import BatchScheduler

        applied = BatchScheduler.apply_config(
            sched, dataclasses.replace(base, max_batch=8)
        )
        assert applied.version == 1 and sched.config.max_batch == 8
        applied2 = BatchScheduler.apply_config(
            sched, dataclasses.replace(base, version=0)
        )
        assert applied2.version == 2  # stale version cannot rewind

    def test_wait_for_class(self):
        sc = SchedulerConfig(class_max_wait=(("interactive", 0.002),))
        assert sc.wait_for_class("interactive") == 0.002
        assert sc.wait_for_class("bulk") is None

    def test_flush_order_follows_drain_order_under_drr(self):
        # priority-first flush would re-starve the lanes DRR protected:
        # with class_weights set, keys must flush oldest-drained-first
        from repro.serve.scheduler import BatchScheduler

        hi, lo = SLOClass("hi", priority=10), SLOClass("lo", priority=-10)
        key_hi, key_lo = (256, (), hi), (256, (), lo)
        sched = SimpleNamespace(
            queue=SimpleNamespace(class_weights={"hi": 4.0, "lo": 1.0}),
            _pending={
                key_hi: [SimpleNamespace(id=7)],
                key_lo: [SimpleNamespace(id=3)],
            },
        )
        order = sorted(sched._pending, key=lambda k: BatchScheduler._key_order(sched, k))
        assert order == [key_lo, key_hi]  # id 3 drained before id 7
        sched.queue.class_weights = None  # strict-priority mode unchanged
        order = sorted(sched._pending, key=lambda k: BatchScheduler._key_order(sched, k))
        assert order == [key_hi, key_lo]


class TestRuntimeReconfigure:
    def test_validation(self, cfg, params):
        rt = ServingRuntime(cfg, params, RuntimeConfig(max_batch=4))
        try:
            with pytest.raises(ValueError, match="buckets"):
                rt.reconfigure(buckets=(256, 128))
            with pytest.raises(ValueError, match="max_batch"):
                rt.reconfigure(max_batch=0)
            with pytest.raises(ValueError, match="max_wait_s"):
                rt.reconfigure(max_wait_s=0.0)
            with pytest.raises(ValueError, match="class_max_wait"):
                rt.reconfigure(class_max_wait=(("bulk", -1.0),))
        finally:
            rt.stop()

    def test_midstream_swap_no_loss_bitwise_parity(self, cfg, params):
        """Reconfigure under live traffic: every future resolves exactly
        once and every response is bitwise-equal to a direct accelerator
        reference at one of the candidate buckets."""
        max_batch = 4
        rt = ServingRuntime(
            cfg, params,
            RuntimeConfig(max_batch=max_batch, max_wait_s=0.002,
                          max_queue=256, buckets=(256,)),
        )
        accel = get_accelerator(cfg)
        rng = np.random.default_rng(7)
        clouds = [
            rng.standard_normal((int(n), 3)).astype(np.float32)
            for n in rng.choice([128, 256], size=30)
        ]
        # a mid-swap 128-point cloud may legitimately land in either the
        # old 256 bucket (padded) or the new 128 bucket — precompute the
        # reference for every candidate (row-independent model: a zero
        # batch with the fitted cloud in row 0 gives that request's row)
        refs = []
        for c in clouds:
            per_bucket = {}
            for b in (128, 256):
                if c.shape[0] <= b:
                    batch = np.zeros((max_batch, b, 3), np.float32)
                    batch[0] = pad_cloud(c, b)[0]
                    per_bucket[b] = np.asarray(accel.infer(params, batch))[0]
            refs.append(per_bucket)
        try:
            rt.start()
            rt.warmup()
            futs = []
            version = None
            for i, c in enumerate(clouds):
                if i == len(clouds) // 2:
                    version = rt.reconfigure(buckets=(128, 256))
                futs.append(rt.submit(c))
                time.sleep(0.001)
            assert version is not None and version >= 1
            assert rt.buckets == (128, 256)
            results = [f.result(timeout=WAIT_S) for f in futs]
        finally:
            rt.stop()
        assert len(results) == len(clouds)  # nothing lost across the swap
        for res, per_bucket in zip(results, refs):
            assert any(
                np.array_equal(res, ref) for ref in per_bucket.values()
            ), "response does not match any candidate-bucket reference"
        snap = rt.metrics.snapshot()
        assert snap.completed == len(clouds)
        assert snap.rejected == snap.shed == 0


# -- AdaptiveController units (fake runtime) -----------------------------------


class _FakeScheduler:
    def __init__(self, config):
        self.config = config


class _FakeRuntime:
    """Just enough ServingRuntime surface for controller unit tests."""

    def __init__(self, buckets=(256,), max_batch=4, depth=0):
        self.metrics = ServeMetrics()
        self.buckets = tuple(buckets)
        self.scheduler = _FakeScheduler(SchedulerConfig(max_batch=max_batch))
        self.queue = SimpleNamespace(depth=lambda: depth)
        self.tracer = None
        self.calls = []
        self.fail_reconfigure = False

    def reconfigure(self, **kw):
        if self.fail_reconfigure:
            raise RuntimeError("injected reconfigure failure")
        self.calls.append(kw)
        if "buckets" in kw:
            self.buckets = tuple(kw["buckets"])
        cfg = self.scheduler.config
        self.scheduler.config = dataclasses.replace(
            cfg,
            version=cfg.version + 1,
            **{k: v for k, v in kw.items()
               if k in ("max_batch", "max_wait_s", "class_max_wait")},
        )
        return self.scheduler.config.version


def _feed_sizes(rt, sizes):
    for s in sizes:
        rt.metrics.record_arrival(int(s))


class TestAdaptiveConfigValidation:
    def test_bounds_checked(self):
        with pytest.raises(ValueError, match="occupancy"):
            AdaptiveConfig(occupancy_low=0.9, occupancy_high=0.5)
        with pytest.raises(ValueError, match="rollback_factor"):
            AdaptiveConfig(rollback_factor=1.0)
        with pytest.raises(ValueError, match="max_batch_bounds"):
            AdaptiveConfig(max_batch_bounds=(0, 4))
        with pytest.raises(ValueError, match="wait_bounds"):
            AdaptiveConfig(wait_bounds=(0.0, 0.1))
        with pytest.raises(ValueError, match="min_samples"):
            AdaptiveConfig(min_samples=0)


class TestAdaptiveController:
    def _ctrl(self, rt, **kw):
        kw.setdefault("min_samples", 32)
        kw.setdefault("min_bucket", 64)
        kw.setdefault("cooldown_s", 0.0)
        kw.setdefault("tune_max_batch", False)
        kw.setdefault("tune_wait", False)
        return AdaptiveController(rt, AdaptiveConfig(**kw))

    def test_silent_below_min_samples(self):
        rt = _FakeRuntime()
        ctrl = self._ctrl(rt)
        _feed_sizes(rt, [100] * 10)
        ctrl.poll_once()
        assert len(ctrl.decisions) == 0 and rt.calls == []

    def test_bucket_proposal_applied_with_evidence(self):
        rt = _FakeRuntime(buckets=(256,))
        ctrl = self._ctrl(rt)
        _feed_sizes(rt, [100] * 100)
        ctrl.poll_once()
        (d,) = ctrl.decisions.applied("buckets")
        assert rt.buckets == d.value and 128 in d.value and 256 in d.value
        assert d.previous == (256,)
        assert d.evidence["waste_current"] > d.evidence["waste_proposed"]
        assert d.version == 1
        assert rt.calls == [{"buckets": d.value}]

    def test_hysteresis_rejects_small_gain_once(self):
        rt = _FakeRuntime(buckets=(256,))
        ctrl = self._ctrl(rt, waste_improvement=10.0)  # unreachable gain
        _feed_sizes(rt, [100] * 100)
        ctrl.poll_once()
        ctrl.poll_once()  # identical rejection must not be re-logged
        assert rt.calls == []
        rejections = [d for d in ctrl.decisions.all() if not d.applied]
        assert len(rejections) == 1 and rejections[0].kind == "buckets"
        assert "hysteresis" in rejections[0].reason

    def test_verify_window_blocks_new_actuations(self):
        rt = _FakeRuntime(buckets=(256,))
        ctrl = self._ctrl(rt, observe_s=60.0)
        _feed_sizes(rt, [100] * 100)
        ctrl.poll_once()
        assert len(rt.calls) == 1
        ctrl.poll_once()  # inside the observation window: frozen
        assert len(rt.calls) == 1

    def test_rollback_on_p95_regression(self):
        rt = _FakeRuntime(buckets=(256,))
        ctrl = self._ctrl(rt, observe_s=0.5, rollback_factor=1.5,
                          min_window_completions=16)
        for _ in range(20):
            rt.metrics.record_completed(0.001)
        _feed_sizes(rt, [100] * 100)
        ctrl.poll_once()
        assert rt.buckets != (256,)
        for _ in range(30):
            rt.metrics.record_completed(0.1)  # the swap made things worse
        # expire the observation window by rewinding the applied timestamp
        t, revert, pre = ctrl._pending_verify
        ctrl._pending_verify = (t - 10.0, revert, pre)
        ctrl.poll_once()
        (rb,) = ctrl.decisions.applied("rollback")
        assert rb.evidence["post_p95_s"] > rb.evidence["pre_p95_s"]
        assert rt.buckets == (256,)  # knobs restored

    def test_verify_keeps_healthy_swap(self):
        rt = _FakeRuntime(buckets=(256,))
        ctrl = self._ctrl(rt, observe_s=0.5)
        for _ in range(20):
            rt.metrics.record_completed(0.001)
        _feed_sizes(rt, [100] * 100)
        ctrl.poll_once()
        for _ in range(30):
            rt.metrics.record_completed(0.001)  # post-swap p95 unchanged
        t, revert, pre = ctrl._pending_verify
        ctrl._pending_verify = (t - 10.0, revert, pre)
        ctrl.poll_once()
        assert ctrl.decisions.applied("rollback") == ()
        assert rt.buckets != (256,)  # swap survives

    def test_max_batch_grows_on_occupancy_and_backlog(self):
        rt = _FakeRuntime(buckets=(256,), max_batch=4, depth=16)
        ctrl = self._ctrl(rt, tune_max_batch=True, min_batch_records=8)
        _feed_sizes(rt, [256] * 64)  # sizes match the bucket: no bucket move
        for _ in range(10):
            rt.metrics.record_batch(
                BatchRecord(bucket=256, policy_key=(), n_real=4,
                            batch_size=4, replica_id=0, duration_s=0.01)
            )
        ctrl.poll_once()
        (d,) = ctrl.decisions.applied("max_batch")
        assert d.value == 8 and d.previous == 4
        assert rt.scheduler.config.max_batch == 8
        assert d.evidence["occupancy"] == pytest.approx(1.0)

    def test_max_batch_shrinks_on_low_occupancy(self):
        rt = _FakeRuntime(buckets=(256,), max_batch=8, depth=0)
        ctrl = self._ctrl(rt, tune_max_batch=True, min_batch_records=8)
        _feed_sizes(rt, [256] * 64)
        for _ in range(10):
            rt.metrics.record_batch(
                BatchRecord(bucket=256, policy_key=(), n_real=1,
                            batch_size=8, replica_id=0, duration_s=0.01)
            )
        ctrl.poll_once()
        (d,) = ctrl.decisions.applied("max_batch")
        assert d.value == 4 and rt.scheduler.config.max_batch == 4

    def test_wait_tuning_sets_class_override(self):
        rt = _FakeRuntime(buckets=(256,), max_batch=4)
        ctrl = self._ctrl(rt, tune_wait=True)
        for _ in range(64):
            rt.metrics.record_arrival(256, "interactive")
        ctrl.poll_once()
        (d,) = ctrl.decisions.applied("max_wait")
        overrides = dict(d.value)
        assert "interactive" in overrides
        assert rt.scheduler.config.wait_for_class("interactive") == pytest.approx(
            overrides["interactive"]
        )

    def test_errors_never_escape(self):
        rt = _FakeRuntime(buckets=(256,))
        rt.fail_reconfigure = True
        ctrl = self._ctrl(rt)
        _feed_sizes(rt, [100] * 100)
        ctrl.poll_once()  # must not raise
        (d,) = ctrl.decisions.all()
        assert d.kind == "error" and "injected" in d.reason


# -- cost-signal autoscaling ---------------------------------------------------


class _CostReplica:
    def __init__(self, rid):
        self.id = rid
        self.alive = True
        self.retired = False
        self.evicted_t = None


class _CostPool:
    def __init__(self, n=1):
        self.replicas = [_CostReplica(i) for i in range(n)]

    def alive_replicas(self):
        return [r for r in self.replicas if r.alive]

    def add_replica(self):
        rid = len(self.replicas)
        self.replicas.append(_CostReplica(rid))
        return rid

    def rejoin(self, rid):
        self.replicas[rid].alive = True
        self.replicas[rid].retired = False
        return True

    def retire(self, rid):
        self.replicas[rid].alive = False
        self.replicas[rid].retired = True
        return True


class _CostQueue:
    def __init__(self, depth=0, slack=None):
        self._depth = depth
        self._slack = slack or {}

    def depth(self):
        return self._depth

    def slack_by_class(self, now=None):
        return dict(self._slack)


class TestAutoscalerCostSignals:
    def test_config_validation(self):
        with pytest.raises(ValueError, match="slack_scale_up_s"):
            AutoscalerConfig(slack_scale_up_s=0.0)
        with pytest.raises(ValueError, match="shed_scale_up_rate"):
            AutoscalerConfig(shed_scale_up_rate=-1.0)

    def test_slack_pressure_scales_up_with_reason(self):
        pool = _CostPool(n=1)
        scaler = Autoscaler(
            pool,
            _CostQueue(depth=1, slack={"interactive": 0.01, "bulk": 5.0}),
            AutoscalerConfig(slack_scale_up_s=0.1, max_replicas=2),
        )
        scaler.poll_once()
        (e,) = scaler.events
        assert e.action == "scale_up" and e.reason == "slack:interactive"
        assert len(pool.alive_replicas()) == 2

    def test_shed_rate_pressure_scales_up(self):
        pool = _CostPool(n=1)
        metrics = ServeMetrics()
        scaler = Autoscaler(
            pool, _CostQueue(depth=0),
            AutoscalerConfig(shed_scale_up_rate=10.0, max_replicas=2),
            metrics=metrics,
        )
        scaler.poll_once()  # first poll only marks the shed counter
        assert scaler.events == []
        for _ in range(100):
            metrics.record_shed()
        # rewind the mark instead of dwelling: 100 sheds over 1s >> 10/s
        count, t = scaler._shed_mark
        scaler._shed_mark = (count, t - 1.0)
        scaler.poll_once()
        (e,) = scaler.events
        assert e.action == "scale_up" and e.reason == "shed"

    def test_depth_trigger_keeps_reason_and_wins(self):
        pool = _CostPool(n=1)
        scaler = Autoscaler(
            pool,
            _CostQueue(depth=64, slack={"interactive": 0.001}),
            AutoscalerConfig(slack_scale_up_s=0.1, max_replicas=2),
        )
        scaler.poll_once()
        (e,) = scaler.events
        assert e.action == "scale_up" and e.reason == "depth"

    def test_no_pressure_no_action(self):
        pool = _CostPool(n=1)
        scaler = Autoscaler(
            pool, _CostQueue(depth=0, slack={"interactive": 5.0}),
            AutoscalerConfig(slack_scale_up_s=0.1, shed_scale_up_rate=10.0,
                             max_replicas=2),
            metrics=ServeMetrics(),
        )
        scaler.poll_once()
        scaler.poll_once()
        assert scaler.events == []


# -- live Prometheus endpoint --------------------------------------------------


def _get(url, timeout=5.0):
    with urllib.request.urlopen(url, timeout=timeout) as resp:
        return resp.status, resp.read().decode()


class TestMetricsServer:
    def test_scrape_and_health(self):
        metrics = ServeMetrics()
        metrics.record_submitted()
        metrics.record_completed(0.01)
        server = MetricsServer(metrics, port=0).start()
        try:
            assert server.port != 0  # ephemeral port resolved at bind
            status, body = _get(server.url + "/metrics")
            assert status == 200
            assert "pc2im_serve_submitted_total 1" in body
            assert "pc2im_serve_completed_total 1" in body
            status, body = _get(server.url + "/healthz")
            assert status == 200 and body == "ok\n"
            with pytest.raises(urllib.error.HTTPError):
                _get(server.url + "/nope")
        finally:
            server.stop()
        with pytest.raises(OSError):
            _get(server.url + "/healthz", timeout=1.0)

    def test_runtime_lifecycle_owns_listener(self, cfg, params):
        rt = ServingRuntime(
            cfg, params, RuntimeConfig(max_batch=2, prometheus_port=0)
        )
        try:
            rt.start()
            wait_until(lambda: rt.metrics_server.port != 0, desc="listener bind")
            rt.submit(np.zeros((256, 3), np.float32)).result(timeout=WAIT_S)
            _, body = _get(rt.metrics_server.url + "/metrics")
            assert "pc2im_serve_submitted_total 1" in body
            url = rt.metrics_server.url
        finally:
            rt.stop()
        with pytest.raises(OSError):
            _get(url + "/healthz", timeout=1.0)
