"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes/metrics/dtypes as required for each kernel."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fps.ops import fps_tiles
from repro.kernels.fps.ref import fps_tiles_ref
from repro.kernels.knn3.ops import knn3
from repro.kernels.knn3.ref import knn3_ref
from repro.kernels.lattice.ops import lattice_query_fused
from repro.kernels.sc_matmul.ops import sc_matmul_op, sc_quantized_linear
from repro.kernels.sc_matmul.ref import sc_matmul_ref
from repro.core.query import lattice_query

jax.config.update("jax_platform_name", "cpu")


def _cloud(shape, seed=0, dtype=jnp.float32):
    return jax.random.uniform(
        jax.random.PRNGKey(seed), shape, minval=-1.0, maxval=1.0
    ).astype(dtype)


class TestFPSKernel:
    @pytest.mark.parametrize("metric", ["l1", "l2"])
    @pytest.mark.parametrize("t,p,k", [(1, 128, 8), (4, 256, 16), (2, 512, 32)])
    def test_matches_oracle(self, metric, t, p, k):
        pts = _cloud((t, p, 3), seed=t * 100 + k)
        got = np.array(fps_tiles(pts, k, metric=metric, backend="pallas", interpret=True))
        ref = np.array(fps_tiles_ref(pts.transpose(0, 2, 1), k, metric=metric))
        np.testing.assert_array_equal(got, ref)

    def test_non_lane_multiple_padding(self):
        pts = _cloud((3, 200, 3), seed=7)
        got = np.array(fps_tiles(pts, 12, backend="pallas", interpret=True))
        ref = np.array(fps_tiles(pts, 12, backend="xla"))
        np.testing.assert_array_equal(got, ref)

    def test_indices_unique_per_tile(self):
        pts = _cloud((2, 256, 3), seed=9)
        idx = np.array(fps_tiles(pts, 32, backend="pallas", interpret=True))
        for row in idx:
            assert len(np.unique(row)) == 32

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep(self, dtype):
        pts = _cloud((2, 128, 3), seed=3, dtype=dtype)
        got = np.array(fps_tiles(pts, 8, backend="pallas", interpret=True))
        ref = np.array(
            fps_tiles_ref(pts.astype(jnp.float32).transpose(0, 2, 1), 8, metric="l1")
        )
        np.testing.assert_array_equal(got, ref)


class TestSCMatmulKernel:
    @pytest.mark.parametrize("m,k,n", [(8, 64, 16), (32, 128, 32), (128, 512, 128)])
    def test_exact_vs_f32_oracle(self, m, k, n):
        x = jax.random.randint(jax.random.PRNGKey(0), (m, k), -32768, 32768, jnp.int32)
        w = jax.random.randint(jax.random.PRNGKey(1), (k, n), -32768, 32768, jnp.int32)
        got = np.array(sc_matmul_op(x, w, backend="pallas", interpret=True))
        oracle = np.array(sc_matmul_ref(x, w))
        np.testing.assert_array_equal(got, oracle)  # identical schedule -> bitwise

    def test_multi_k_step_accumulation(self):
        x = jax.random.randint(jax.random.PRNGKey(2), (128, 1024), -32768, 32768, jnp.int32)
        w = jax.random.randint(jax.random.PRNGKey(3), (1024, 128), -32768, 32768, jnp.int32)
        got = np.array(sc_matmul_op(x, w, backend="pallas", interpret=True))
        ref = np.array(x, np.int64) @ np.array(w, np.int64)
        rel = np.abs(got - ref).max() / np.abs(ref).max()
        assert rel < 1e-6  # f32 combine rounding only

    @pytest.mark.parametrize("bits", [8, 16])
    def test_bits_sweep_small_exact(self, bits):
        lim = 1 << (bits - 1)
        x = jax.random.randint(jax.random.PRNGKey(4), (16, 64), -lim, lim, jnp.int32)
        w = jax.random.randint(jax.random.PRNGKey(5), (64, 16), -lim, lim, jnp.int32)
        got = np.array(sc_matmul_op(x, w, bits=bits, backend="pallas", interpret=True))
        ref = np.array(x, np.int64) @ np.array(w, np.int64)
        if bits == 8:  # fits f32 exactly
            np.testing.assert_array_equal(got, ref)
        else:
            assert np.abs(got - ref).max() / np.abs(ref).max() < 1e-6

    def test_quantized_linear_accuracy(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (32, 128))
        w = jax.random.normal(jax.random.PRNGKey(1), (128, 64)) * 0.05
        y = sc_quantized_linear(x, w, backend="pallas", interpret=True)
        ref = x @ w
        rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
        assert rel < 3e-4  # 16-bit PTQ bound (paper Fig 12a)


class TestKNN3Kernel:
    @pytest.mark.parametrize("metric", ["l1", "l2"])
    @pytest.mark.parametrize("q,p", [(8, 128), (64, 256), (100, 200)])
    def test_matches_oracle(self, metric, q, p):
        qs = _cloud((q, 3), seed=q)
        pts = _cloud((p, 3), seed=p + 1)
        gi, gd = knn3(qs, pts, metric=metric, backend="pallas", interpret=True)
        ri, rd = knn3_ref(qs, pts.T, metric=metric)
        np.testing.assert_array_equal(np.array(gi), np.array(ri))
        np.testing.assert_allclose(np.array(gd), np.array(rd), rtol=1e-5)

    def test_k_sweep(self):
        qs, pts = _cloud((16, 3), 1), _cloud((128, 3), 2)
        for k in [1, 3, 5]:
            gi, _ = knn3(qs, pts, k=k, backend="pallas", interpret=True)
            ri, _ = knn3_ref(qs, pts.T, k=k)
            np.testing.assert_array_equal(np.array(gi), np.array(ri))

    @pytest.mark.parametrize(
        "q,p", [(1, 100), (5, 130), (7, 128), (13, 257), (261, 129), (300, 640)]
    )
    def test_odd_shapes_match_oracle(self, q, p):
        # regression: Q not a multiple of the sublane (8) used to require the
        # op wrapper to guess a divisible block; the kernel now pads queries
        # internally, so arbitrary Q/P go straight through
        qs = _cloud((q, 3), seed=q)
        pts = _cloud((p, 3), seed=p + 1)
        gi, gd = knn3(qs, pts, backend="pallas", interpret=True)
        ri, rd = knn3_ref(qs, pts.T)
        assert gi.shape == (q, 3) and gd.shape == (q, 3)
        np.testing.assert_array_equal(np.array(gi), np.array(ri))
        np.testing.assert_allclose(np.array(gd), np.array(rd), rtol=1e-5)

    def test_direct_kernel_bq_larger_than_q(self):
        # regression: bq > qn after clamping (the default bq=256 with a tiny
        # odd Q) must sublane-align and pad instead of failing the divisibility
        # check — and give the same answer as a fitted block
        from repro.kernels.knn3.kernel import knn3_pallas

        qs = _cloud((5, 3), seed=3)
        pts = _cloud((128, 3), seed=4).T
        i_default, d_default = knn3_pallas(qs, pts, bq=256, interpret=True)
        i_fit, d_fit = knn3_pallas(qs, pts, bq=8, interpret=True)
        assert i_default.shape == (5, 3)
        np.testing.assert_array_equal(np.array(i_default), np.array(i_fit))
        np.testing.assert_array_equal(np.array(d_default), np.array(d_fit))


class TestLatticeKernel:
    @pytest.mark.parametrize("m,p,ns", [(4, 128, 8), (16, 256, 16), (128, 512, 32)])
    def test_matches_oracle(self, m, p, ns):
        pts = _cloud((p, 3), seed=p)
        c = pts[:m]
        got = lattice_query_fused(pts, c, 0.4, ns, backend="pallas", interpret=True)
        ref = lattice_query(pts, c, 0.4, ns)
        np.testing.assert_array_equal(np.array(got.mask), np.array(ref.mask))
        np.testing.assert_array_equal(np.array(got.idx), np.array(ref.idx))

    def test_non_multiple_shapes(self):
        pts = _cloud((200, 3), seed=11)
        c = pts[:50]
        got = lattice_query_fused(pts, c, 0.5, 8, backend="pallas", interpret=True)
        ref = lattice_query(pts, c, 0.5, 8)
        np.testing.assert_array_equal(np.array(got.mask), np.array(ref.mask))
        np.testing.assert_array_equal(np.array(got.idx), np.array(ref.idx))
