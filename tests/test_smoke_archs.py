"""Per-arch smoke tests: reduced config of the same family, one forward +
one train step on CPU, asserting output shapes + finiteness (no NaNs).
The FULL configs are exercised only via the dry-run (no allocation)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.models.families import get_family_api
from repro.optim import adamw_init, adamw_update

jax.config.update("jax_platform_name", "cpu")

LM_ARCHS = [
    "stablelm-1.6b",
    "gemma3-12b",
    "command-r-plus-104b",
    "starcoder2-3b",
    "dbrx-132b",
    "granite-moe-3b-a800m",
    "mamba2-1.3b",
    "recurrentgemma-2b",
    "whisper-small",
    "internvl2-2b",
]


def _smoke_batch(cfg, b=2, s=16):
    key = jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model))
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(1), (b, cfg.n_patches, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    api = get_family_api(cfg)
    params = api["init"](jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(cfg)

    loss, metrics = api["train_loss"](params, cfg, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    # one full train step (grads + AdamW update), loss stays finite
    state = adamw_init(params)
    grads = jax.grad(lambda p: api["train_loss"](p, cfg, batch)[0])(params)
    for g in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(g))), f"{arch}: non-finite grad"
    new_params, state, m = adamw_update(grads, state, params, lr=1e-3)
    loss2, _ = api["train_loss"](new_params, cfg, batch)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_serve_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    api = get_family_api(cfg)
    params = api["init"](jax.random.PRNGKey(0), cfg)
    b, s = 2, 12
    batch = _smoke_batch(cfg, b, s)

    logits, state = api["prefill"](params, cfg, batch, s_max=s + cfg.n_patches + 8)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite prefill logits"

    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    logits2, state2 = api["decode_step"](params, cfg, state, {"token": tok})
    assert logits2.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all()), f"{arch}: non-finite decode logits"
    # a second decode step exercises cache_len advance
    logits3, _ = api["decode_step"](params, cfg, state2, {"token": tok})
    assert bool(jnp.isfinite(logits3).all())


@pytest.mark.parametrize("arch", ["pointnet2-cls", "pointnet2-seg"])
def test_smoke_pointnet2(arch):
    from repro.data.pointclouds import sample_batch
    from repro.models import pointnet2 as PN

    cfg = get_config(arch, smoke=True)
    params = PN.init_params(jax.random.PRNGKey(0), cfg)
    pts, cls, seg = sample_batch(jax.random.PRNGKey(1), 2, cfg.n_points)
    logits = PN.forward(params, cfg, pts)
    if cfg.task == "cls":
        assert logits.shape == (2, cfg.n_classes)
    else:
        assert logits.shape == (2, cfg.n_points, cfg.n_classes)
    assert bool(jnp.isfinite(logits).all())
    labels = cls if cfg.task == "cls" else seg
    grads = jax.grad(lambda p: PN.loss_fn(p, cfg, pts, labels)[0])(params)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))


def test_param_count_analytic_vs_actual():
    """The ModelConfig.param_count() estimate should track actual init sizes."""
    from repro.models.nn import count_params

    for arch in ["stablelm-1.6b", "gemma3-12b", "mamba2-1.3b"]:
        cfg = get_config(arch, smoke=True)
        api = get_family_api(cfg)
        params = api["init"](jax.random.PRNGKey(0), cfg)
        actual = count_params(params)
        est = cfg.param_count()
        assert 0.5 < est / actual < 2.0, f"{arch}: est {est} vs actual {actual}"


def test_full_config_param_counts():
    """Full configs roughly match their published sizes (name check)."""
    expect = {
        "stablelm-1.6b": 1.6e9,
        "gemma3-12b": 12e9,
        "command-r-plus-104b": 104e9,
        "starcoder2-3b": 3e9,
        "dbrx-132b": 132e9,
        "mamba2-1.3b": 1.3e9,
        "recurrentgemma-2b": 2.7e9,  # w/ untied-equivalent embeddings counted once
        "internvl2-2b": 2e9,
    }
    for arch, target in expect.items():
        n = get_config(arch).param_count()
        assert 0.5 < n / target < 2.0, f"{arch}: {n/1e9:.2f}B vs ~{target/1e9:.0f}B"
