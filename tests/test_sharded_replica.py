"""Mesh-sharded replicas: parity, carving, cache keys, chaos on a group.

The tentpole claim is bitwise: a replica that owns a device GROUP and runs
the sharded artifact (batch-sharded preprocess, or tensor-sharded feature
MLPs with concatenated partials — the paper's split-concatenate dataflow)
returns exactly the bits the single-device artifact returns, for fp32 AND
SC-quantized policies.  Host-side tests cover the pure math (group carving,
policy validation, cache-key isolation, assemble/scatter shard locality);
the multi-device proofs run in forced-host-device subprocesses via
tests/_multidev.py, with the parity asserts living HERE in the parent.
"""

import numpy as np
import pytest

from _hypothesis import given, settings, st
from _multidev import assert_bitwise, run_in_child

from repro.configs.base import get_config
from repro.core.accelerator import cache_stats, clear_cache, get_accelerator
from repro.core.policy import ExecutionPolicy
from repro.launch.mesh import carve_device_groups
from repro.serve.queue import Request
from repro.serve.scheduler import MicroBatch, assemble_batch, scatter_results


# -- device-group carving (pure math: works on plain ints) --------------------


class TestCarving:
    def test_exact_division(self):
        assert carve_device_groups([0, 1, 2, 3], 2) == [(0, 1), (2, 3)]

    def test_per_one_is_classic_replicas(self):
        assert carve_device_groups([0, 1, 2], 1) == [(0,), (1,), (2,)]

    def test_whole_fleet_is_one_group(self):
        assert carve_device_groups([0, 1, 2, 3], 4) == [(0, 1, 2, 3)]

    def test_leftover_devices_unused(self):
        # 4 devices / groups of 3: one group, the tail is left idle rather
        # than forming a ragged (differently-shaped, differently-traced) mesh
        assert carve_device_groups([0, 1, 2, 3], 3) == [(0, 1, 2)]

    def test_group_larger_than_fleet_raises(self):
        with pytest.raises(ValueError):
            carve_device_groups([0, 1], 3)

    def test_nonpositive_group_raises(self):
        with pytest.raises(ValueError):
            carve_device_groups([0, 1], 0)


# -- the ExecutionPolicy.sharding knob ----------------------------------------


class TestShardingKnob:
    def test_invalid_mode_rejected(self):
        with pytest.raises(ValueError, match="sharding"):
            ExecutionPolicy(sharding="bogus")

    def test_sharding_excludes_pipelined_schedule(self):
        # both knobs re-partition the same computation; composing them is
        # undefined and refused at construction, not at trace time
        with pytest.raises(ValueError, match="pipeline"):
            ExecutionPolicy(sharding="batch", pipeline="pipelined")

    def test_replica_specs_modes(self):
        from jax.sharding import PartitionSpec as P

        from repro.sharding.hints import REPLICA_AXIS
        from repro.sharding.policy import replica_specs

        for mode in ("batch", "tensor"):
            p_params, p_points, p_logits = replica_specs(mode)
            assert p_params == P()  # params replicated over the group
            assert p_points == P(REPLICA_AXIS)
            assert p_logits == P(REPLICA_AXIS)
        with pytest.raises(ValueError):
            replica_specs("bogus")

    def test_cache_key_isolation(self):
        """sharding hashes into the artifact cache exactly like pipeline
        does: unsharded / batch / tensor traffic get three artifacts."""
        clear_cache()
        cfg = get_config("pointnet2-cls", smoke=True)
        get_accelerator(cfg)
        get_accelerator(cfg, ExecutionPolicy(sharding="batch"))
        get_accelerator(cfg, ExecutionPolicy(sharding="tensor"))
        stats = cache_stats()
        assert stats.size == 3
        assert {k[4] for k in stats.keys} == {None, "batch", "tensor"}
        # repeat lookups hit, never re-trace
        get_accelerator(cfg, ExecutionPolicy(sharding="batch"))
        assert cache_stats().size == 3

    def test_mesh_artifacts_requires_sharded_policy(self):
        import jax

        clear_cache()
        cfg = get_config("pointnet2-cls", smoke=True)
        accel = get_accelerator(cfg)  # sharding=None
        with pytest.raises(ValueError, match="sharding"):
            accel.mesh_artifacts(jax.devices()[:1])


# -- assemble/scatter shard locality (hypothesis property) --------------------


WIDTH = 6  # 3 coords + 3 features; any fixed width works
N_CLASSES = 5


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_assemble_scatter_row_locality_under_any_split(data):
    """Batch-sharding correctness reduces to row locality: for ANY split of
    the static batch dim into contiguous chunks (ragged tails included),
    assembling each chunk's requests alone reproduces that chunk of the full
    assembly bitwise, and scattering each chunk's logits alone reproduces
    the full scatter — so a mesh shard that sees only its row block computes
    exactly what the unsharded batch would have handed it."""
    bucket = data.draw(st.sampled_from([32, 64]))
    n_req = data.draw(st.integers(min_value=1, max_value=6))
    # cloud sizes straddle the bucket: padded, exact, and subsampled rows
    sizes = data.draw(
        st.lists(
            st.integers(min_value=1, max_value=2 * bucket),
            min_size=n_req,
            max_size=n_req,
        )
    )
    max_batch = n_req + data.draw(st.integers(min_value=0, max_value=3))
    cuts = (
        data.draw(
            st.lists(
                st.integers(min_value=1, max_value=max_batch - 1),
                unique=True,
                max_size=3,
            )
        )
        if max_batch > 1
        else []
    )
    bounds = [0] + sorted(cuts) + [max_batch]
    task = data.draw(st.sampled_from(["cls", "seg"]))
    seed = data.draw(st.integers(min_value=0, max_value=2**31 - 1))

    rng = np.random.default_rng(seed)
    reqs = [
        Request(
            id=i,
            cloud=rng.standard_normal((n, WIDTH)).astype(np.float32),
            n_orig=n,
            bucket=bucket,
            policy=None,
            deadline_t=None,
            submit_t=0.0,
            future=None,
        )
        for i, n in enumerate(sizes)
    ]

    full = assemble_batch(reqs, bucket, WIDTH, max_batch)
    for lo, hi in zip(bounds, bounds[1:]):
        chunk = assemble_batch(reqs[lo:hi], bucket, WIDTH, hi - lo)
        np.testing.assert_array_equal(chunk, full[lo:hi])

    shape = (max_batch, bucket, N_CLASSES) if task == "seg" else (max_batch, N_CLASSES)
    logits = rng.standard_normal(shape).astype(np.float32)
    whole = scatter_results(
        task, logits, MicroBatch(tuple(reqs), bucket, None, full)
    )
    pieces = []
    for lo, hi in zip(bounds, bounds[1:]):
        sub = MicroBatch(tuple(reqs[lo:hi]), bucket, None, full[lo:hi])
        pieces.extend(scatter_results(task, logits[lo:hi], sub))
    assert len(whole) == len(pieces) == len(reqs)
    for a, b in zip(whole, pieces):
        np.testing.assert_array_equal(a, b)


# -- sharded-vs-single-device bitwise parity (8 forced host devices) ----------


def test_sharded_parity_all_modes_subprocess():
    """Every (mode x quant x group-size) sharded artifact is bitwise-equal
    to the single-device artifact of the same quant policy, on the same
    batch.  batch mode needs the pmax-globalized activation amax; tensor
    mode needs the full-weight (global-scale) quantization before the
    integer column slice — this test pins both."""
    payload = run_in_child(
        """
        import jax, numpy as np
        from repro.configs.base import get_config
        from repro.core.accelerator import get_accelerator
        from repro.core.policy import ExecutionPolicy

        cfg = get_config("pointnet2-cls", smoke=True)
        base = get_accelerator(cfg)
        params = base.init(jax.random.PRNGKey(0))
        pts = np.asarray(
            jax.random.normal(
                jax.random.PRNGKey(1), (8, cfg.n_points, 3 + cfg.in_features)
            ),
            np.float32,
        )
        for quant in ("none", "sc_w16a16"):
            ref = get_accelerator(cfg, ExecutionPolicy(quant=quant)).infer(
                params, pts
            )
            emit(f"ref_{quant}", ref)
            for mode in ("batch", "tensor"):
                accel = get_accelerator(
                    cfg, ExecutionPolicy(quant=quant, sharding=mode)
                )
                for g in (2, 8):
                    arts = accel.mesh_artifacts(jax.devices()[:g])
                    emit(f"out_{quant}_{mode}_{g}", arts.infer(params, pts))

        # seg head through tensor sharding: per-point logits concatenate the
        # same way, and the out-spec row slice round-trips (4 rows / 4 shards)
        seg = get_config("pointnet2-seg", smoke=True)
        sbase = get_accelerator(seg)
        sparams = sbase.init(jax.random.PRNGKey(2))
        spts = np.asarray(
            jax.random.normal(
                jax.random.PRNGKey(3), (4, seg.n_points, 3 + seg.in_features)
            ),
            np.float32,
        )
        emit("seg_ref", sbase.infer(sparams, spts))
        sarts = get_accelerator(
            seg, ExecutionPolicy(sharding="tensor")
        ).mesh_artifacts(jax.devices()[:4])
        emit("seg_out", sarts.infer(sparams, spts))
        """,
        n_devices=8,
    )
    for quant in ("none", "sc_w16a16"):
        for mode in ("batch", "tensor"):
            for g in (2, 8):
                assert_bitwise(payload, f"out_{quant}_{mode}_{g}", f"ref_{quant}")
    assert_bitwise(payload, "seg_out", "seg_ref")


# -- ReplicaPool over device groups: carving, warmup, chaos, warm rejoin ------


def test_mesh_replica_pool_chaos_subprocess():
    """ReplicaPool carves 4 devices into two 2-device mesh replicas; every
    (bucket x policy) warmup artifact is bitwise-correct on BOTH groups;
    chaos kill and heartbeat-detected wedge each evict a mesh replica, and
    rejoin reuses the cached per-group artifacts (warm: no re-trace)."""
    payload = run_in_child(
        """
        import time

        import jax, numpy as np
        from repro.configs.base import get_config
        from repro.core.accelerator import get_accelerator
        from repro.core.policy import ExecutionPolicy
        from repro.serve.chaos import ChaosInjector, Fault
        from repro.serve.runtime import RuntimeConfig, ServingRuntime

        cfg = get_config("pointnet2-cls", smoke=True)
        base = get_accelerator(cfg)
        params = base.init(jax.random.PRNGKey(0))
        width = 3 + cfg.in_features
        pol_b = ExecutionPolicy(sharding="batch")  # fp32, batch-sharded
        pol_t = ExecutionPolicy(quant="sc_w16a16", sharding="tensor")
        buckets = (192, cfg.n_points)

        rt = ServingRuntime(
            cfg,
            params,
            RuntimeConfig(max_batch=4, devices_per_replica=2, buckets=buckets),
            policy=pol_b,
        )
        devs = jax.devices()
        assert [r.devices for r in rt.pool.replicas] == [
            tuple(devs[:2]),
            tuple(devs[2:4]),
        ], rt.pool.replicas
        rt.warmup((pol_b, pol_t))

        # every (bucket x policy) warmup artifact, on every group, is
        # bitwise-equal to the single-device artifact of the same quant
        rng = np.random.default_rng(0)
        for pi, pol in enumerate((pol_b, pol_t)):
            accel = get_accelerator(cfg, pol)
            ref_accel = get_accelerator(cfg, ExecutionPolicy(quant=pol.quant))
            for bucket in buckets:
                batch = rng.standard_normal((4, bucket, width)).astype(np.float32)
                emit(f"warm_ref_{pi}_{bucket}", ref_accel.infer(params, batch))
                for rep in rt.pool.replicas:
                    arts = accel.mesh_artifacts(rep.devices)
                    emit(
                        f"warm_{pi}_{bucket}_{rep.id}",
                        arts.infer(rep.mesh_params, batch),
                    )

        # end-to-end submits through the sharded dispatch path (fp32 forward
        # is batch-size independent bitwise, so B=1 unsharded refs are exact)
        clouds = [
            rng.standard_normal((cfg.n_points, width)).astype(np.float32)
            for _ in range(12)
        ]
        with rt:
            outs = [
                f.result(timeout=120) for f in [rt.submit(c) for c in clouds]
            ]
        emit("live_out", np.stack(outs))
        emit("live_ref", np.stack(
            [np.asarray(base.infer(params, c[None]))[0] for c in clouds]
        ))

        # chaos kill on a mesh replica -> evict -> warm rejoin on same group
        accel_b = get_accelerator(cfg, pol_b)
        rt2 = ServingRuntime(
            cfg,
            params,
            RuntimeConfig(max_batch=4, devices_per_replica=2),
            policy=pol_b,
        )
        rt2.warmup((pol_b,))
        group1 = rt2.pool.replicas[1].devices
        arts_before = accel_b.mesh_artifacts(group1)
        ChaosInjector([Fault(replica_id=1, at_batch=0, kind="kill")]).attach(
            rt2.pool
        )
        with rt2:
            outs = [
                f.result(timeout=120) for f in [rt2.submit(c) for c in clouds[:8]]
            ]
            assert sum(1 for r in rt2.pool.replicas if r.alive) == 1
            assert rt2.pool.rejoin(1)
            rep1 = rt2.pool.replicas[1]
            assert rep1.alive and rep1.devices == group1
            # warm: the rejoined replica resolves the SAME cached per-group
            # artifacts object -> zero re-tracing on rejoin
            assert accel_b.mesh_artifacts(rep1.devices) is arts_before
            outs += [
                f.result(timeout=120) for f in [rt2.submit(c) for c in clouds[8:]]
            ]
        emit("kill_out", np.stack(outs))

        # wedge: the injector hangs a mesh replica's worker thread; the
        # heartbeat monitor (not the injector) detects it and evicts
        rt3 = ServingRuntime(
            cfg,
            params,
            RuntimeConfig(
                max_batch=4, devices_per_replica=2, heartbeat_timeout_s=0.25
            ),
            policy=pol_b,
        )
        rt3.warmup((pol_b,))
        ChaosInjector(
            [Fault(replica_id=0, at_batch=0, kind="wedge", duration_s=1.5)]
        ).attach(rt3.pool)
        with rt3:
            outs = [
                f.result(timeout=120) for f in [rt3.submit(c) for c in clouds[:8]]
            ]
            deadline = time.monotonic() + 60
            while (
                sum(1 for r in rt3.pool.replicas if r.alive) == 2
                and time.monotonic() < deadline
            ):
                time.sleep(0.02)
            assert sum(1 for r in rt3.pool.replicas if r.alive) == 1
            assert rt3.metrics.evictions >= 1
            assert rt3.pool.rejoin(0)
            outs += [
                f.result(timeout=120) for f in [rt3.submit(c) for c in clouds[8:]]
            ]
        emit("wedge_out", np.stack(outs))
        """,
        n_devices=4,
    )
    assert_bitwise(payload, "live_out", "live_ref")
    assert_bitwise(payload, "kill_out", "live_ref")
    assert_bitwise(payload, "wedge_out", "live_ref")
