"""Round-trip property tests for the ragged-cloud fit (satellite of the
serving-runtime PR): `pad_cloud` + `subsample_indices` must let seg callers
map per-point logits back to ORIGINAL rows exactly.

The old serve path re-derived the inverse from a second rounded linspace —
an approximation that happened to agree on small sizes but had no guarantee.
`inverse_subsample_indices` is built by searching the actual survivor set,
so these properties hold by construction and are pinned here:

  identity — a surviving row maps back to its own logit row (bitwise);
  nearest  — a dropped row maps to the survivor at minimal row distance;
  monotone — the inverse is sorted (spatial order is preserved).
"""

import numpy as np
import pytest

from _hypothesis import given, settings, st
from repro.serve.pointcloud import (
    inverse_subsample_indices,
    pad_cloud,
    subsample_indices,
)


def _check_properties(n: int, n_points: int):
    idx = subsample_indices(n, n_points)
    inv = inverse_subsample_indices(n, n_points)
    assert inv.shape == (n,) and inv.dtype == np.int64
    # identity: surviving rows map to their own slot
    np.testing.assert_array_equal(inv[idx], np.arange(n_points))
    # nearest: every row maps to a minimal-distance survivor
    dist = np.abs(idx[inv] - np.arange(n))
    best = np.min(np.abs(idx[None, :] - np.arange(n)[:, None]), axis=1)
    np.testing.assert_array_equal(dist, best)
    # monotone: mapping preserves row order
    assert np.all(np.diff(inv) >= 0)
    # in range
    assert inv.min() >= 0 and inv.max() <= n_points - 1


class TestInverseSubsampleGrid:
    """Exhaustive small-size grid + adversarial large sizes (no hypothesis
    needed — runs on bare environments too)."""

    def test_small_exhaustive(self):
        for n in range(2, 48):
            for n_points in range(1, n + 1):
                _check_properties(n, n_points)

    @pytest.mark.parametrize(
        "n,n_points",
        [(97, 13), (1000, 999), (1000, 7), (4097, 64), (50000, 1024), (12345, 677)],
    )
    def test_large(self, n, n_points):
        _check_properties(n, n_points)


class TestPadCloudRoundTrip:
    def test_oversized_uses_subsample_indices(self):
        """pad_cloud's oversized path IS subsample_indices (no second
        derivation that could drift)."""
        rng = np.random.default_rng(0)
        cloud = rng.standard_normal((300, 3)).astype(np.float32)
        fitted, n_orig = pad_cloud(cloud, 256)
        assert n_orig == 300
        np.testing.assert_array_equal(fitted, cloud[subsample_indices(300, 256)])

    def test_undersized_keeps_original_rows(self):
        rng = np.random.default_rng(1)
        cloud = rng.standard_normal((100, 3)).astype(np.float32)
        fitted, n_orig = pad_cloud(cloud, 256)
        assert n_orig == 100 and fitted.shape == (256, 3)
        np.testing.assert_array_equal(fitted[:100], cloud)
        # filler repeats the last point (collapses to one FPS candidate)
        np.testing.assert_array_equal(fitted[100:], np.broadcast_to(cloud[-1:], (156, 3)))

    def test_seg_logits_map_back_to_original_rows(self):
        """The full seg round trip: per-SURVIVOR logits -> per-original-row
        logits.  Row j gets its own score if it survived, else its nearest
        survivor's score."""
        n, n_points = 517, 128
        idx = subsample_indices(n, n_points)
        logits = np.arange(n_points, dtype=np.float32)[:, None]  # logit = slot id
        mapped = logits[inverse_subsample_indices(n, n_points)]
        # surviving rows: exact own score
        np.testing.assert_array_equal(mapped[idx, 0], np.arange(n_points))
        # dropped rows: score of a minimal-distance survivor
        for j in range(n):
            src = int(mapped[j, 0])
            assert abs(idx[src] - j) == np.min(np.abs(idx - j))


@settings(max_examples=200, deadline=None)
@given(n_points=st.integers(1, 512), extra=st.integers(1, 4096))
def test_inverse_properties_hypothesis(n_points, extra):
    _check_properties(n_points + extra, n_points)


@settings(max_examples=50, deadline=None)
@given(n_points=st.integers(2, 128), extra=st.integers(1, 512))
def test_pad_cloud_roundtrip_hypothesis(n_points, extra):
    """pad_cloud(oversized) then inverse-mapping reproduces each surviving
    row bitwise at its original position."""
    n = n_points + extra
    cloud = np.arange(n * 3, dtype=np.float32).reshape(n, 3)  # row-unique values
    fitted, n_orig = pad_cloud(cloud, n_points)
    assert n_orig == n
    idx = subsample_indices(n, n_points)
    back = fitted[inverse_subsample_indices(n, n_points)]  # (n, 3)
    np.testing.assert_array_equal(back[idx], cloud[idx])
