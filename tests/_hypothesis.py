"""Graceful hypothesis fallback for property tests.

`pip install -r requirements-dev.txt` gives the real hypothesis; on a bare
environment the property tests are SKIPPED (not collection errors) and every
non-property test in the same module still runs.  Import from here instead
of from hypothesis:

    from _hypothesis import given, settings, st
"""

from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade: property tests skip, the rest of the module runs
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # plain function (not a wraps/lambda): pytest collects it by the
            # original name and reports an explicit skip; *_a absorbs `self`
            # so class-based property tests degrade too
            def skipped(*_a, **_k):
                pytest.skip("hypothesis not installed")

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        """Stand-in for hypothesis.strategies: every attribute is a no-op."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()
