"""Substrate tests: optimizer, checkpointing (atomic/async/elastic), fault
tolerance (restart/straggler/heartbeat), gradient compression, data streams,
pipeline parallelism."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.checkpoint.store import latest_step
from repro.data.tokens import Prefetcher, synth_batch, token_stream
from repro.optim import adamw_init, adamw_update, cosine_warmup_schedule
from repro.optim.compression import compress_grads, decompress_grads, init_error_feedback
from repro.runtime import HeartbeatMonitor, StragglerMonitor, run_with_restarts

jax.config.update("jax_platform_name", "cpu")


class TestOptimizer:
    def _quad_setup(self):
        params = {"w": jnp.array([1.0, -2.0, 3.0]), "b": jnp.array(0.5)}
        def loss(p):
            return jnp.sum(p["w"] ** 2) + p["b"] ** 2

        return params, loss

    def test_adamw_descends(self):
        params, loss = self._quad_setup()
        state = adamw_init(params)
        l0 = float(loss(params))
        for _ in range(50):
            grads = jax.grad(loss)(params)
            params, state, _ = adamw_update(grads, state, params, lr=0.05)
        assert float(loss(params)) < l0 * 0.1

    def test_grad_clip_metric(self):
        params, loss = self._quad_setup()
        state = adamw_init(params)
        grads = jax.tree.map(lambda g: g * 1e6, jax.grad(loss)(params))
        _, _, m = adamw_update(grads, state, params, lr=0.1, max_grad_norm=1.0)
        assert float(m["grad_norm"]) > 1e5  # pre-clip norm reported

    def test_bf16_master_weights(self):
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        state = adamw_init(params)
        assert state.master is not None
        grads = {"w": jnp.full((4,), 1e-3, jnp.bfloat16)}
        new_params, state, _ = adamw_update(grads, state, params, lr=1e-4)
        # master accumulates below bf16 resolution
        assert state.master["w"].dtype == jnp.float32
        assert new_params["w"].dtype == jnp.bfloat16

    def test_schedule(self):
        lr0 = float(cosine_warmup_schedule(0, peak_lr=1.0, warmup_steps=10, total_steps=100))
        lr10 = float(cosine_warmup_schedule(10, peak_lr=1.0, warmup_steps=10, total_steps=100))
        lr100 = float(cosine_warmup_schedule(100, peak_lr=1.0, warmup_steps=10, total_steps=100))
        assert lr0 == 0.0 and abs(lr10 - 1.0) < 1e-6 and lr100 < 0.11


class TestCheckpoint:
    def _tree(self, seed=0):
        k = jax.random.PRNGKey(seed)
        return {
            "a": jax.random.normal(k, (8, 4)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32), "d": jnp.ones((3,), jnp.bfloat16)},
        }

    def test_roundtrip(self, tmp_path):
        t = self._tree()
        save_checkpoint(str(tmp_path), 7, t)
        out, step, _ = load_checkpoint(str(tmp_path), t)
        assert step == 7
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_save(self, tmp_path):
        t = self._tree()
        th = save_checkpoint(str(tmp_path), 3, t, blocking=False)
        th.join()
        assert latest_step(str(tmp_path)) == 3

    def test_atomicity_ignores_incomplete(self, tmp_path):
        t = self._tree()
        save_checkpoint(str(tmp_path), 1, t)
        # fake a crashed save: directory without COMPLETE marker
        os.makedirs(tmp_path / "step_000000000009")
        (tmp_path / "step_000000000009" / "data.msgpack.zst").write_bytes(b"junk")
        assert latest_step(str(tmp_path)) == 1

    def test_elastic_reshard_restore(self, tmp_path):
        """Save from one 'topology', restore onto explicit new shardings."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        t = self._tree()
        save_checkpoint(str(tmp_path), 2, t)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
        out, step, _ = load_checkpoint(str(tmp_path), t, shardings=sh)
        assert step == 2
        for leaf in jax.tree.leaves(out):
            assert isinstance(leaf.sharding, NamedSharding)

    def test_manager_gc_and_every(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, every=10)
        t = self._tree()
        for s in [10, 20, 30]:
            assert mgr.maybe_save(s, t)
        assert not mgr.maybe_save(35, t)
        mgr.wait()
        mgr._gc()
        assert latest_step(str(tmp_path)) == 30
        steps = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
        assert len(steps) == 2  # keep=2


class TestFaultTolerance:
    def test_restart_resumes_and_completes(self, tmp_path):
        """Simulated preemption at step 7 of 12: the driver restores from the
        step-5 checkpoint and the final state matches an uninterrupted run."""
        mgr = CheckpointManager(str(tmp_path), keep=3, every=5)
        crashed = {"done": False}

        def make_state():
            return {"x": jnp.zeros(()), "step": jnp.zeros((), jnp.int32)}

        def loop(state, start, crash_at=None):
            for step in range(start, 12):
                state = {"x": state["x"] + 1.0, "step": jnp.int32(step + 1)}
                mgr.maybe_save(step + 1, state, force=((step + 1) % 5 == 0))
                mgr.wait()
                if crash_at is not None and step + 1 == crash_at and not crashed["done"]:
                    crashed["done"] = True
                    raise RuntimeError("simulated preemption")
            return state, 12

        state, last, n_restarts = run_with_restarts(
            make_state, lambda s, st: loop(s, st, crash_at=7), ckpt_manager=mgr
        )
        assert n_restarts == 1
        assert int(state["step"]) == 12
        assert float(state["x"]) == 12.0  # exact (data replay is step-keyed)

    def test_straggler_detection(self):
        mon = StragglerMonitor(threshold=3.0, window=16)
        for i in range(12):
            mon.step_start()
            time.sleep(0.002)
            mon.step_end(i)
        mon.step_start()
        time.sleep(0.05)
        mon.step_end(99)
        assert mon.events and mon.events[-1].step == 99

    def test_heartbeat_fires(self):
        fired = []
        hb = HeartbeatMonitor(0.05, on_dead=lambda: fired.append(1)).start()
        time.sleep(0.2)
        hb.stop()
        assert fired

    def test_heartbeat_kept_alive(self):
        fired = []
        hb = HeartbeatMonitor(0.2, on_dead=lambda: fired.append(1)).start()
        for _ in range(6):
            time.sleep(0.05)
            hb.beat()
        hb.stop()
        assert not fired


class TestCompression:
    def test_int8_roundtrip_error_bounded(self):
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (128,))}
        err = init_error_feedback(g)
        c, err = compress_grads(g, err)
        out = decompress_grads(c)
        rel = float(
            jnp.linalg.norm(out["w"] - g["w"]) / jnp.linalg.norm(g["w"])
        )
        assert rel < 0.02  # int8 quantization noise
        assert c.q["w"].dtype == jnp.int8

    def test_error_feedback_unbiased_over_steps(self):
        """Accumulated compressed grads converge to accumulated true grads."""
        key = jax.random.PRNGKey(1)
        g_true = jax.random.normal(key, (64,)) * 0.1
        err = init_error_feedback({"w": g_true})
        acc_c = jnp.zeros_like(g_true)
        for _ in range(50):
            c, err = compress_grads({"w": g_true}, err)
            acc_c = acc_c + decompress_grads(c)["w"]
        rel = float(jnp.linalg.norm(acc_c / 50 - g_true) / jnp.linalg.norm(g_true))
        assert rel < 1e-3  # error feedback drives the bias to ~0


class TestData:
    def test_stream_restart_exact(self):
        a = [b for _, b in zip(range(3), (x[1] for x in token_stream(0, 4, 16, 97)))]
        b = list(x[1] for x in [next(token_stream(0, 4, 16, 97, start_step=2))])
        np.testing.assert_array_equal(np.array(a[2]["tokens"]), np.array(b[0]["tokens"]))

    def test_shards_differ(self):
        b0 = next(token_stream(0, 4, 16, 97, shard_id=0))[1]
        b1 = next(token_stream(0, 4, 16, 97, shard_id=1))[1]
        assert not np.array_equal(np.array(b0["tokens"]), np.array(b1["tokens"]))

    def test_prefetcher(self):
        it = ((i, synth_batch(jax.random.PRNGKey(i), 2, 8, 13)) for i in range(5))
        out = list(Prefetcher(it, depth=2))
        assert [i for i, _ in out] == list(range(5))

    def test_labels_shifted(self):
        b = synth_batch(jax.random.PRNGKey(0), 2, 16, 97)
        np.testing.assert_array_equal(
            np.array(b["labels"][:, :-1]), np.array(b["tokens"][:, 1:])
        )


class TestPipeline:
    def test_pipeline_matches_sequential(self):
        """GPipe over a 2-stage mesh == running blocks sequentially."""
        from repro.parallel import pipeline_forward

        if jax.device_count() < 2:
            pytest.skip("needs >=2 devices (run via dryrun path)")
        mesh = jax.make_mesh((2,), ("stage",))
        n_stages, n_micro, mb, d = 2, 4, 8, 16
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (n_stages, d, d)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

        def stage_fn(wp, xx, stage):
            return jnp.tanh(xx @ wp)

        out = pipeline_forward(mesh, "stage", stage_fn, w, x)
        ref = x
        for s in range(n_stages):
            ref = jnp.tanh(ref @ w[s])
        np.testing.assert_allclose(np.array(out), np.array(ref), rtol=2e-5, atol=2e-5)
