"""Pipelined accelerator tests: staged sub-artifacts, the ExecutionPolicy
pipeline knob, the two-stage schedule, and mixed pipelined/sequential
serving.

The contract under test (ISSUE 4's tentpole):
  * `feature_stage(params, x, preprocess_stage(x))` is bitwise-equal to the
    fused `infer` — across policies, shapes and tasks — because the fused
    forward IS that composition;
  * `PipelinedExecutor` / `infer_pipelined` return the same bits for a
    whole micro-batch stream, in order;
  * the `pipeline` knob participates in ExecutionPolicy hashing and the
    accelerator cache key (pipelined and sequential traffic can never
    collide on one artifact);
  * the serving runtime executes pipelined and sequential batch groups side
    by side, each bitwise-equal to the direct sequential path.
"""

import concurrent.futures
import dataclasses
import time

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.accelerator import (
    PipelinedExecutor,
    cache_stats,
    clear_cache,
    get_accelerator,
)
from repro.core.policy import ExecutionPolicy, resolve_policy
from repro.data.pointclouds import sample_batch
from repro.parallel.pipeline import two_stage_schedule
from repro.serve import (
    MicroBatch,
    ReplicaPool,
    RuntimeConfig,
    ServeMetrics,
    ServingRuntime,
    assemble_batch,
)
from repro.serve.queue import Request

jax.config.update("jax_platform_name", "cpu")

WAIT_S = 60  # bound on every future wait: fail, never hang


@pytest.fixture(scope="module")
def cfg():
    return get_config("pointnet2-cls", smoke=True)  # n_points=256


@pytest.fixture(scope="module")
def params(cfg):
    return get_accelerator(cfg).init(jax.random.PRNGKey(0))


def _batches(cfg, k, b=4, seed=0, n=None):
    n = n or cfg.n_points
    return [
        np.asarray(sample_batch(jax.random.PRNGKey(seed + i), b, n)[0])
        for i in range(k)
    ]


class TestPipelineKnob:
    def test_validation(self):
        with pytest.raises(ValueError, match="pipeline"):
            ExecutionPolicy(pipeline="overlapped")

    def test_hash_identity(self):
        seq = ExecutionPolicy()
        pipe = ExecutionPolicy(pipeline="pipelined")
        assert seq != pipe
        assert len({seq, pipe, ExecutionPolicy(pipeline="sequential")}) == 2

    def test_resolve_preserves_pipeline(self, cfg):
        pol = ExecutionPolicy(quant="sc_w16a16", pipeline="pipelined")
        assert resolve_policy(cfg, pol).pipeline == "pipelined"
        assert resolve_policy(cfg, None).pipeline == "sequential"

    def test_cache_keys_never_collide(self, cfg):
        """Round-trip through the artifact cache: same (config, quant,
        backend) but different pipeline modes -> two distinct artifacts,
        and the stats keys name both."""
        clear_cache()
        a = get_accelerator(cfg, ExecutionPolicy(backend="xla"))
        b = get_accelerator(cfg, ExecutionPolicy(backend="xla", pipeline="pipelined"))
        assert a is not b
        stats = cache_stats()
        assert stats.size == 2 and stats.misses == 2
        assert {key[3] for key in stats.keys} == {"sequential", "pipelined"}
        # identical policies still share one artifact
        assert b is get_accelerator(
            cfg, ExecutionPolicy(backend="xla", pipeline="pipelined")
        )


class TestStagedParity:
    @pytest.mark.parametrize("quant", ["none", "sc_w16a16", "sc_w8a8"])
    def test_staged_equals_fused_per_policy(self, cfg, params, quant):
        accel = get_accelerator(cfg, ExecutionPolicy(quant=quant, backend="xla"))
        pts = _batches(cfg, 1, b=2, seed=7)[0]
        fused = np.asarray(accel.infer(params, pts))
        pre = accel.preprocess_stage(pts)
        staged = np.asarray(accel.feature_stage(params, pts, pre))
        np.testing.assert_array_equal(fused, staged, err_msg=quant)

    def test_staged_equals_fused_across_buckets(self, cfg, params):
        """Both serving buckets (192 and 256 rows) stay bitwise-equal —
        every static shape gets its own pair of sub-artifact traces."""
        accel = get_accelerator(cfg)
        for n in (192, 256):
            pts = _batches(cfg, 1, b=4, seed=11, n=n)[0]
            fused = np.asarray(accel.infer(params, pts))
            pre = accel.preprocess_stage(pts)
            np.testing.assert_array_equal(
                fused, np.asarray(accel.feature_stage(params, pts, pre)), err_msg=str(n)
            )

    def test_staged_equals_fused_segmentation(self):
        """The FP (feature-propagation) tail also composes: seg logits from
        the staged path match the fused artifact bit for bit."""
        seg = get_config("pointnet2-seg", smoke=True)
        accel = get_accelerator(seg, ExecutionPolicy(backend="xla"))
        params = accel.init(jax.random.PRNGKey(2))
        pts = _batches(seg, 1, b=2, seed=13, n=seg.n_points)[0]
        fused = np.asarray(accel.infer(params, pts))
        pre = accel.preprocess_stage(pts)
        np.testing.assert_array_equal(
            fused, np.asarray(accel.feature_stage(params, pts, pre))
        )

    def test_preprocess_stage_is_params_free(self, cfg, params):
        """The preprocess sub-artifact reads only coordinates: different
        params, same neighborhoods (what makes the overlap legal)."""
        accel = get_accelerator(cfg)
        pts = _batches(cfg, 1, b=2, seed=17)[0]
        pre = accel.preprocess_stage(pts)
        other = get_accelerator(cfg).init(jax.random.PRNGKey(99))
        out_a = np.asarray(accel.feature_stage(params, pts, pre))
        out_b = np.asarray(accel.feature_stage(other, pts, pre))
        assert not np.array_equal(out_a, out_b)  # params DID matter downstream
        for got, want in zip(
            jax.tree.leaves(pre), jax.tree.leaves(accel.preprocess_stage(pts))
        ):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestPipelinedExecutor:
    def test_stream_parity_and_order(self, cfg, params):
        """A stream of distinct micro-batches comes back in order, each
        bitwise-equal to the sequential fused infer."""
        accel = get_accelerator(cfg, ExecutionPolicy(pipeline="pipelined"))
        batches = _batches(cfg, 6, b=4, seed=23)
        outs = accel.infer_pipelined(params, batches)
        assert len(outs) == len(batches)
        ref = get_accelerator(cfg)  # sequential artifact, same resolved numerics
        for i, (out, x) in enumerate(zip(outs, batches)):
            np.testing.assert_array_equal(
                np.asarray(out), np.asarray(ref.infer(params, x)), err_msg=str(i)
            )

    def test_quantized_stream_parity(self, cfg, params):
        pol = ExecutionPolicy(quant="sc_w16a16", backend="xla", pipeline="pipelined")
        accel = get_accelerator(cfg, pol)
        seq = get_accelerator(cfg, dataclasses.replace(pol, pipeline="sequential"))
        batches = _batches(cfg, 3, b=2, seed=29)
        for out, x in zip(accel.infer_pipelined(params, batches), batches):
            np.testing.assert_array_equal(
                np.asarray(out), np.asarray(seq.infer(params, x))
            )

    def test_executor_empty_stream(self, cfg, params):
        assert PipelinedExecutor(get_accelerator(cfg)).run(params, []) == []


class TestTwoStageSchedule:
    def test_order_and_composition(self):
        out = two_stage_schedule(lambda x: x * 10, lambda y: y + 1, range(20), depth=2)
        assert out == [i * 10 + 1 for i in range(20)]

    def test_stage_a_exception_propagates(self):
        def bad(x):
            if x == 3:
                raise RuntimeError("stage a boom")
            return x

        with pytest.raises(RuntimeError, match="stage a boom"):
            two_stage_schedule(bad, lambda y: y, range(8), depth=1)

    def test_stage_b_exception_propagates(self):
        def bad(y):
            if y == 2:
                raise RuntimeError("stage b boom")
            return y

        # depth=1 forces the producer to block on a full hand-off queue while
        # the consumer dies — the drain path must still unblock and join it
        with pytest.raises(RuntimeError, match="stage b boom"):
            two_stage_schedule(lambda x: x, bad, range(8), depth=1)

    def test_empty(self):
        assert two_stage_schedule(lambda x: x, lambda y: y, []) == []


class TestServeMixedSchedules:
    def test_mixed_pipelined_and_sequential_groups(self, cfg, params):
        """Interleaved pipelined/sequential submissions: the scheduler keys
        batch groups by the full policy (pipeline included), every request
        completes, and each result is bitwise-equal to the direct sequential
        path on the same padded batch."""
        clear_cache()
        pipe = ExecutionPolicy(pipeline="pipelined")
        clouds = [
            np.asarray(sample_batch(jax.random.PRNGKey(41 + i), 1, 256)[0][0])
            for i in range(16)
        ]
        rt = ServingRuntime(
            cfg, params,
            RuntimeConfig(max_batch=4, max_wait_s=0.005, max_queue=64, buckets=(256,)),
        )
        with rt:
            futs = [
                rt.submit(c, policy=pipe if i % 2 else None)
                for i, c in enumerate(clouds)
            ]
            outs = [f.result(timeout=WAIT_S) for f in futs]

        accel = get_accelerator(cfg)
        for i, (cloud, out) in enumerate(zip(clouds, outs)):
            req = Request(id=i, cloud=cloud, n_orig=256, bucket=256, policy=None,
                          deadline_t=None, submit_t=0.0, future=None)
            direct = np.asarray(accel.infer(params, assemble_batch([req], 256, 3, 4)))[0]
            np.testing.assert_array_equal(out, direct, err_msg=str(i))

        stats = cache_stats()
        assert {key[3] for key in stats.keys} == {"sequential", "pipelined"}
        records = [b for b in rt.metrics.batch_records if b.n_real]
        assert sum(b.n_real for b in records) == len(clouds)
        # metrics separate the two schedules too (per-schedule durations)
        assert {b.policy_key[2] for b in records} == {"sequential", "pipelined"}

    def test_concurrent_threads_mixed_schedules(self, cfg, params):
        """8 threads hammering both schedules at once: all complete, all
        bitwise-correct (no cross-talk between the two artifact kinds)."""
        pipe = ExecutionPolicy(pipeline="pipelined")
        clouds = [
            np.asarray(sample_batch(jax.random.PRNGKey(71 + i), 1, 256)[0][0])
            for i in range(24)
        ]
        rt = ServingRuntime(
            cfg, params,
            RuntimeConfig(max_batch=4, max_wait_s=0.005, max_queue=128, buckets=(256,)),
        )
        with rt:
            with concurrent.futures.ThreadPoolExecutor(max_workers=8) as ex:
                futs = list(ex.map(
                    lambda i: rt.submit(clouds[i], policy=pipe if i % 2 else None),
                    range(len(clouds)),
                ))
            outs = [f.result(timeout=WAIT_S) for f in futs]

        accel = get_accelerator(cfg)
        for i, (cloud, out) in enumerate(zip(clouds, outs)):
            req = Request(id=i, cloud=cloud, n_orig=256, bucket=256, policy=None,
                          deadline_t=None, submit_t=0.0, future=None)
            direct = np.asarray(accel.infer(params, assemble_batch([req], 256, 3, 4)))[0]
            np.testing.assert_array_equal(out, direct, err_msg=str(i))

    def test_wedged_feature_stage_evicts_replica(self, cfg, params):
        """Feature-thread liveness: a hung feature stage stalls the feature
        executor's heartbeat pump, the replica is evicted, and the batch is
        re-dispatched to a survivor (same coverage the sequential path gets
        from the worker pump)."""
        pol = resolve_policy(cfg, ExecutionPolicy(pipeline="pipelined"))
        accel = get_accelerator(cfg, pol)  # the cached artifact dispatch will use
        orig = accel.feature_stage
        mb = MicroBatch(
            requests=(), bucket=cfg.n_points, policy=pol,
            batch=np.zeros((2, cfg.n_points, 3), np.float32),
        )
        # warm through the pool's OWN path (device-committed params/batch):
        # execution under the heartbeat pool must be compile-free, or
        # compilation itself (seconds) stalls the beats and evicts healthy
        # replicas — that's also why the prod docstring says the timeout must
        # exceed worst-case batch latency
        warm_pool = ReplicaPool(cfg, params, n_replicas=1)
        warm_pool.warmup(mb)
        warm_pool.shutdown()

        state = {"calls": 0}

        def wedge_first_call(p, pts, pre):
            state["calls"] += 1
            if state["calls"] == 1:
                time.sleep(3.0)  # >> heartbeat timeout: beats stall behind us
            return orig(p, pts, pre)

        accel.feature_stage = wedge_first_call
        metrics = ServeMetrics()
        pool = ReplicaPool(
            cfg, params, n_replicas=2, heartbeat_timeout_s=0.6,
            max_retries=2, metrics=metrics,
        )
        try:
            out = pool.submit(mb).result(timeout=WAIT_S)
            assert out.shape[0] == 2
            assert state["calls"] >= 2  # the wedged call plus the retry
            assert metrics.evictions >= 1 and metrics.retries >= 1
        finally:
            accel.feature_stage = orig  # un-wedge the cached artifact
            pool.shutdown()
            time.sleep(0.2)  # let the wedged sleeper drain before other tests

    def test_warmup_pretraces_pipelined_artifacts(self, cfg, params):
        """warmup() with a pipelined policy drives the replica's two-stage
        path end to end (both sub-artifacts traced before traffic)."""
        rt = ServingRuntime(
            cfg, params,
            RuntimeConfig(max_batch=4, max_wait_s=0.005, buckets=(256,)),
        )
        try:
            rt.warmup(policies=(ExecutionPolicy(pipeline="pipelined"),))
            stats = cache_stats()
            assert "pipelined" in {key[3] for key in stats.keys}
        finally:
            rt.stop()
