"""Int8 KV cache (paper C1 bit-shrink transplanted to decode — §Perf cell C)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.families import get_family_api
from repro.models.layers import dequantize_kv, quantize_kv

jax.config.update("jax_platform_name", "cpu")


class TestQuantizeKV:
    def test_roundtrip_error(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 32))
        q, s = quantize_kv(x)
        rec = dequantize_kv(q, s, jnp.float32)
        rel = float(jnp.linalg.norm(rec - x) / jnp.linalg.norm(x))
        assert rel < 0.01
        assert q.dtype == jnp.int8 and s.shape == (2, 16, 4, 1)

    def test_scale_factors_out_exactly(self):
        """scores computed on int8 then scaled == scores on dequantised floats."""
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 2, 16))
        q, s = quantize_kv(k)
        deq = dequantize_kv(q, s, jnp.float32)
        qry = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 3, 16))
        a = jnp.einsum("bhgd,bshd->bhgs", qry, deq)
        b = jnp.einsum("bhgd,bshd->bhgs", qry, q.astype(jnp.float32))
        b = b * s[..., 0].transpose(0, 2, 1)[:, :, None, :]
        np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-5, atol=1e-5)


class TestInt8KVDecode:
    @pytest.mark.parametrize("arch", ["stablelm-1.6b", "gemma3-12b"])
    def test_decode_close_to_fp(self, arch):
        cfg = get_config(arch, smoke=True)
        api = get_family_api(cfg)
        params = api["init"](jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
        outs = {}
        for kvq in ["none", "int8"]:
            c = dataclasses.replace(cfg, kv_quant=kvq)
            _, st = api["prefill"](params, c, {"tokens": toks[:, :-1]}, s_max=24)
            ld, st2 = api["decode_step"](params, c, st, {"token": toks[:, -1:]})
            # a second step exercises quantised writes
            ld2, _ = api["decode_step"](params, c, st2, {"token": toks[:, :1]})
            outs[kvq] = (ld, ld2)
        for i in range(2):
            a, b = outs["none"][i], outs["int8"][i]
            rel = float(jnp.max(jnp.abs(a - b)) / jnp.max(jnp.abs(a)))
            assert rel < 0.05, f"{arch} step{i}: rel={rel}"
            # greedy tokens should (almost always) agree at smoke scale
            agree = float((jnp.argmax(a, -1) == jnp.argmax(b, -1)).mean())
            assert agree >= 0.5

    def test_cache_dtype_and_size(self):
        from repro.models.transformer import init_decode_state

        cfg = dataclasses.replace(get_config("stablelm-1.6b", smoke=True), kv_quant="int8")
        st = init_decode_state(cfg, batch=2, s_max=32)
        c = st.caches[0]
        assert c.k.dtype == jnp.int8 and c.ks.dtype == jnp.float32
        bytes_q = c.k.size + c.ks.size * 4
        bytes_fp = c.k.size * 2  # bf16 baseline
        # smoke head_dim=16 -> (16+4)/32 = 0.625; full dh=128 -> 0.52
        assert bytes_q < bytes_fp * 0.7
