"""ExecutionPolicy + PC2IMAccelerator: the explicit config->artifact API.

Covers the redesign's contract:
  * policies are hashable, validated, and passed functionally — NO
    thread-local/module-global quant state anywhere in src/ (grep-enforced);
  * the policy-quantized `nn.linear` is bitwise-identical to the former
    `quant_mode` path (core.quant.quantized_linear);
  * PC2IMAccelerator compiles one artifact per (config, policy), its infer
    matches a hand-jitted policy forward bitwise, and serve_batch runs
    through the accelerator artifact;
  * two threads under DIFFERENT policies produce independent, correct
    results — the exact failure mode the thread-local API allowed.
"""

import concurrent.futures
import dataclasses
import pathlib
import re

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.accelerator import get_accelerator
from repro.core.policy import ExecutionPolicy, policy_for
from repro.core.quant import quantized_linear
from repro.data.pointclouds import sample_batch
from repro.models import nn
from repro.models import pointnet2 as PN

jax.config.update("jax_platform_name", "cpu")

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"


class TestExecutionPolicy:
    def test_hashable_and_cache_key(self):
        a = ExecutionPolicy(quant="sc_w16a16", backend="xla")
        b = ExecutionPolicy(quant="sc_w16a16", backend="xla")
        assert a == b and hash(a) == hash(b)
        assert len({a, b, ExecutionPolicy()}) == 2

    def test_validation(self):
        with pytest.raises(ValueError, match="quant"):
            ExecutionPolicy(quant="w4a4")
        with pytest.raises(ValueError, match="backend"):
            ExecutionPolicy(backend="cuda")

    def test_quant_bits(self):
        assert ExecutionPolicy().quant_bits is None
        assert ExecutionPolicy(quant="sc_w16a16").quant_bits == 16
        assert ExecutionPolicy(quant="sc_w8a8").quant_bits == 8

    def test_policy_for_reads_config(self):
        cfg = get_config("pointnet2-cls", smoke=True)
        cfg = dataclasses.replace(cfg, quant="sc_w16a16", preproc_backend="xla")
        pol = policy_for(cfg)
        assert pol.quant == "sc_w16a16" and pol.backend == "xla"

    def test_quant_mode_shim_deprecated(self):
        """The one-release compatibility shim yields the equivalent policy,
        warning loudly (FutureWarning shows by default) that quantization is
        no longer applied implicitly."""
        with pytest.warns(FutureWarning, match="no longer applies"):
            with nn.quant_mode("sc_w16a16") as pol:
                assert pol == ExecutionPolicy(quant="sc_w16a16")

    def test_backend_none_defers_to_config(self):
        """A policy that only sets quant must NOT discard the config's pinned
        preproc_backend: backend=None resolves against the config ONCE, so
        BOTH halves (engines and SC feature path) get the same backend."""
        from repro.core.policy import resolve_policy
        from repro.models.pointnet2 import stage_engine

        cfg = get_config("pointnet2-cls", smoke=True)
        cfg = dataclasses.replace(cfg, preproc_backend="xla")
        pol = ExecutionPolicy(quant="sc_w16a16")  # backend unspecified
        assert pol.backend is None
        assert resolve_policy(cfg, pol).backend == "xla"
        eng = stage_engine(cfg, cfg.sa[0], cfg.n_points, pol)
        assert eng.config.backend == "xla"
        # the accelerator resolves at construction (feature path included)
        # and the cache treats the unresolved and resolved forms as one
        accel = get_accelerator(cfg, pol)
        assert accel.policy.backend == "xla"
        assert accel is get_accelerator(cfg, dataclasses.replace(pol, backend="xla"))


class TestNoHiddenState:
    # The only threading.locals allowed in src/: the kernel registry's
    # documented trace-time backend override (tests-only escape hatch) and
    # the launcher's activation-sharding hint context.  Neither carries
    # quant state; the quant decision travels ONLY inside ExecutionPolicy.
    ALLOWED_THREAD_LOCALS = {
        "repro/kernels/registry.py",
        "repro/sharding/hints.py",
    }

    def test_no_thread_local_quant_state_in_src(self):
        """Grep-enforced: no thread-local/module-global quant state in src/;
        models/ and the quant path hold no mutable execution state."""
        offenders = []
        for path in sorted(SRC.rglob("*.py")):
            text = path.read_text()
            rel = str(path.relative_to(SRC))
            if re.search(r"threading\.local\(\)", text) and rel not in self.ALLOWED_THREAD_LOCALS:
                offenders.append(rel)
            if "models/" in rel and re.search(r"\bthreading\b", text):
                offenders.append(rel + " (threading in models/)")
        assert offenders == [], offenders

    def test_nn_has_no_module_state(self):
        assert not hasattr(nn, "_STATE")
        assert not hasattr(nn, "current_quant_mode")


class TestQuantizedLinearParity:
    def test_bitwise_vs_former_quant_mode_path(self):
        """nn.linear under an SC policy == the old thread-local path's math
        (core.quant.quantized_linear, f32 combine) bit for bit."""
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 4, 32))
        p = nn.linear_init(jax.random.PRNGKey(1), 32, 16)
        for bits, mode in ((16, "sc_w16a16"), (8, "sc_w8a8")):
            new = nn.linear(p, x, policy=ExecutionPolicy(quant=mode, backend="xla"))
            old = quantized_linear(x, p["w"], bits=bits).astype(x.dtype) + p["b"]
            np.testing.assert_array_equal(np.asarray(new), np.asarray(old), err_msg=mode)

    def test_none_policy_is_float_path(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 8))
        p = nn.linear_init(jax.random.PRNGKey(1), 8, 8)
        np.testing.assert_array_equal(
            np.asarray(nn.linear(p, x)),
            np.asarray(nn.linear(p, x, policy=ExecutionPolicy())),
        )


def _smoke_setup(quant="none", batch=2):
    cfg = get_config("pointnet2-cls", smoke=True)
    policy = ExecutionPolicy(quant=quant, backend="xla")
    accel = get_accelerator(cfg, policy)
    params = accel.init(jax.random.PRNGKey(0))
    pts, cls, _ = sample_batch(jax.random.PRNGKey(1), batch, cfg.n_points)
    return cfg, policy, accel, params, pts, cls


class TestAccelerator:
    def test_cache_one_artifact_per_config_policy(self):
        cfg = get_config("pointnet2-cls", smoke=True)
        assert get_accelerator(cfg) is get_accelerator(cfg)
        # default policy resolves before keying: explicit default == implicit
        assert get_accelerator(cfg) is get_accelerator(cfg, policy_for(cfg))
        other = get_accelerator(cfg, ExecutionPolicy(quant="sc_w16a16"))
        assert other is not get_accelerator(cfg)

    def test_engines_follow_sa_pyramid(self):
        cfg, _, accel, *_ = _smoke_setup()
        assert len(accel.engines) == len(cfg.sa)
        for eng, sa in zip(accel.engines, cfg.sa):
            assert eng.config.n_centroids == sa.n_centroids

    def test_infer_bitwise_matches_policy_forward(self):
        """Acceptance: the accelerator artifact == jitting the policy-threaded
        forward by hand (the rewired quant path changes no numerics)."""
        cfg, policy, accel, params, pts, _ = _smoke_setup(quant="sc_w16a16")
        got = accel.infer(params, pts)
        ref = jax.jit(lambda p, x: PN.forward(p, cfg, x, policy=policy))(params, pts)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_quant_close_to_float(self):
        cfg, _, accel_q, params, pts, _ = _smoke_setup(quant="sc_w16a16")
        accel_f = get_accelerator(cfg, ExecutionPolicy(backend="xla"))
        lq = np.asarray(accel_q.infer(params, pts))
        lf = np.asarray(accel_f.infer(params, pts))
        assert not np.array_equal(lq, lf)  # quant actually engaged
        assert np.abs(lq - lf).max() / (np.abs(lf).max() + 1e-9) < 1e-2

    def test_loss_artifact_and_grads(self):
        _, _, accel, params, pts, cls = _smoke_setup(quant="sc_w16a16")
        loss, metrics = accel.loss(params, pts, cls)
        assert np.isfinite(float(loss)) and "accuracy" in metrics
        grads = jax.grad(lambda p: accel.loss_fn(p, pts, cls)[0])(params)
        assert all(np.isfinite(np.asarray(g)).all() for g in jax.tree.leaves(grads))

    def test_serve_batch_runs_through_accelerator(self):
        """serve_batch consumes the accelerator artifact (not an ad-hoc jit)."""
        from repro.serve import make_pointcloud_serve_fns

        cfg, policy, accel, params, _, _ = _smoke_setup(quant="sc_w16a16")
        fns = make_pointcloud_serve_fns(cfg, policy=policy)
        assert fns["accelerator"] is accel
        assert fns["infer"] == accel.infer
        clouds = [
            np.asarray(sample_batch(jax.random.PRNGKey(7 + i), 1, 200)[0][0])
            for i in range(3)
        ]
        out = fns["serve_batch"](params, clouds)
        assert len(out) == 3 and all(o.shape == (cfg.n_classes,) for o in out)


class TestConcurrentPolicies:
    def test_two_threads_two_policies_independent(self):
        """Regression for the thread-local API's failure mode: concurrent
        callers under different quant policies must each get exactly the
        result their own policy produces."""
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 64))
        p = nn.linear_init(jax.random.PRNGKey(1), 64, 32)
        policies = {
            "none": None,
            "sc_w16a16": ExecutionPolicy(quant="sc_w16a16", backend="xla"),
            "sc_w8a8": ExecutionPolicy(quant="sc_w8a8", backend="xla"),
        }
        expected = {
            name: np.asarray(nn.linear(p, x, policy=pol))
            for name, pol in policies.items()
        }

        def worker(name):
            outs = []
            for _ in range(20):
                outs.append(np.asarray(nn.linear(p, x, policy=policies[name])))
            return name, outs

        with concurrent.futures.ThreadPoolExecutor(max_workers=3) as ex:
            results = list(ex.map(worker, ["none", "sc_w16a16", "sc_w8a8"] * 2))
        for name, outs in results:
            for o in outs:
                np.testing.assert_array_equal(o, expected[name], err_msg=name)
        # the three modes genuinely differ (the interleaving proved something)
        assert not np.array_equal(expected["none"], expected["sc_w16a16"])
        assert not np.array_equal(expected["sc_w16a16"], expected["sc_w8a8"])

    def test_two_threads_two_accelerators(self):
        """Full-pipeline variant: float and quantized accelerators served from
        different threads stay bitwise equal to their single-threaded runs."""
        cfg, _, accel_q, params, pts, _ = _smoke_setup(quant="sc_w16a16")
        accel_f = get_accelerator(cfg, ExecutionPolicy(backend="xla"))
        expect = {
            "q": np.asarray(accel_q.infer(params, pts)),
            "f": np.asarray(accel_f.infer(params, pts)),
        }

        def worker(tag):
            accel = accel_q if tag == "q" else accel_f
            return tag, [np.asarray(accel.infer(params, pts)) for _ in range(5)]

        with concurrent.futures.ThreadPoolExecutor(max_workers=2) as ex:
            for tag, outs in ex.map(worker, ["q", "f", "q", "f"]):
                for o in outs:
                    np.testing.assert_array_equal(o, expect[tag], err_msg=tag)
