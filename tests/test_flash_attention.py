"""Flash attention (static block pairs + FA2 custom-vjp bwd) vs dense oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis import given, settings, st

from repro.models.layers import _block_pairs, flash_attention

jax.config.update("jax_platform_name", "cpu")


def dense_ref(q, k, v, causal, window):
    b, s, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / jnp.sqrt(dh)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((s, k.shape[1]), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


CASES = [
    dict(causal=True, window=None, s=64, sk=64, hq=4, hkv=2),
    dict(causal=True, window=16, s=64, sk=64, hq=4, hkv=4),
    dict(causal=True, window=8, s=48, sk=48, hq=2, hkv=1),
    dict(causal=False, window=None, s=32, sk=48, hq=4, hkv=1),
    dict(causal=True, window=None, s=96, sk=96, hq=8, hkv=2),
]


@pytest.mark.parametrize("case", CASES)
def test_forward_matches_dense(case):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(jax.random.fold_in(key, 1), (2, case["s"], case["hq"], 16))
    k = jax.random.normal(jax.random.fold_in(key, 2), (2, case["sk"], case["hkv"], 16))
    v = jax.random.normal(jax.random.fold_in(key, 3), (2, case["sk"], case["hkv"], 16))
    out = flash_attention(q, k, v, causal=case["causal"], window=case["window"], block=16)
    ref = dense_ref(q, k, v, case["causal"], case["window"])
    np.testing.assert_allclose(np.array(out), np.array(ref), rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("case", CASES)
def test_backward_matches_dense(case):
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, case["s"], case["hq"], 16))
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, case["sk"], case["hkv"], 16))
    v = jax.random.normal(jax.random.fold_in(key, 3), (1, case["sk"], case["hkv"], 16))
    def f(*a):
        return flash_attention(*a, causal=case["causal"], window=case["window"], block=16).sum()

    def r(*a):
        return dense_ref(*a, case["causal"], case["window"]).sum()

    gf = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.array(a), np.array(b), rtol=5e-4, atol=5e-5)


def test_block_pair_count_causal():
    """Exact-FLOPs property: causal pairs = nb(nb+1)/2, window bounds them."""
    assert len(_block_pairs(8, True, None)) == 36  # 8*9/2
    assert len(_block_pairs(8, False, None)) == 64  # full bidirectional
    pairs_w = _block_pairs(8, True, 2)
    assert all(i - j <= 2 for i, j in pairs_w)


def test_bf16_inputs_supported():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 32, 2, 16)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 1, 16)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 1, 16)).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, block=16)
    assert out.dtype == jnp.bfloat16
    ref = dense_ref(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), True, None)
    np.testing.assert_allclose(
        np.array(out, np.float32), np.array(ref), rtol=2e-2, atol=2e-2
    )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_block_size_invariance(seed):
    """Property: flash output is independent of the block size."""
    q = jax.random.normal(jax.random.PRNGKey(seed), (1, 32, 2, 8))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 32, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (1, 32, 2, 8))
    a = flash_attention(q, k, v, block=8)
    b = flash_attention(q, k, v, block=32)
    np.testing.assert_allclose(np.array(a), np.array(b), rtol=2e-5, atol=2e-6)
