"""Pipeline-parallel numerics on a real multi-device (host-platform) mesh.

Runs in a SUBPROCESS with xla_force_host_platform_device_count=4 so the main
test process keeps its single-device view (per the dry-run isolation rule).
"""

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.parallel import pipeline_forward

mesh = jax.make_mesh((4,), ("stage",))
n_stages, n_micro, mb, d = 4, 8, 4, 16
w = jax.random.normal(jax.random.PRNGKey(0), (n_stages, d, d)) * 0.3
x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

def stage_fn(wp, xx, stage):
    return jnp.tanh(xx @ wp)

out = pipeline_forward(mesh, "stage", stage_fn, w, x)
ref = x
for s in range(n_stages):
    ref = jnp.tanh(ref @ w[s])
np.testing.assert_allclose(np.array(out), np.array(ref), rtol=2e-5, atol=2e-5)
print("PIPELINE_OK")
"""


def test_pipeline_4stage_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        timeout=300,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert "PIPELINE_OK" in res.stdout, res.stderr[-2000:]
