"""Pipeline-parallel numerics on a real multi-device (host-platform) mesh.

Runs in a SUBPROCESS with xla_force_host_platform_device_count=N via the
shared tests/_multidev.py substrate, so the main test process keeps its
single-device view (per the dry-run isolation rule).  The children emit
their outputs and references back to the parent, which asserts here.
"""

import numpy as np

from _multidev import assert_bitwise, run_in_child


def test_pipeline_4stage_subprocess():
    payload = run_in_child(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel import pipeline_forward

        mesh = jax.make_mesh((4,), ("stage",))
        n_stages, n_micro, mb, d = 4, 8, 4, 16
        w = jax.random.normal(jax.random.PRNGKey(0), (n_stages, d, d)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

        def stage_fn(wp, xx, stage):
            return jnp.tanh(xx @ wp)

        out = pipeline_forward(mesh, "stage", stage_fn, w, x)
        ref = x
        for s in range(n_stages):
            ref = jnp.tanh(ref @ w[s])
        emit("out", out)
        emit("ref", ref)
        """,
        n_devices=4,
    )
    np.testing.assert_allclose(
        payload["out"], payload["ref"], rtol=2e-5, atol=2e-5
    )


def test_pipelined_executor_two_devices_subprocess():
    """The >=2-device branch of PipelinedExecutor: preprocess pinned to
    device 0, feature stage + params to device 1, hand-off transferred —
    still bitwise-equal to the sequential fused infer."""
    payload = run_in_child(
        """
        import jax, numpy as np
        from repro.configs.base import get_config
        from repro.core.accelerator import PipelinedExecutor, get_accelerator
        from repro.data.pointclouds import sample_batch

        cfg = get_config("pointnet2-cls", smoke=True)
        accel = get_accelerator(cfg)
        params = accel.init(jax.random.PRNGKey(0))
        batches = [
            np.asarray(sample_batch(jax.random.PRNGKey(3 + i), 2, cfg.n_points)[0])
            for i in range(4)
        ]
        ex = PipelinedExecutor(accel)  # stage A on device 0, stage B on device 1
        assert len(ex.devices) == 2, ex.devices
        outs = ex.run(params, batches)
        for i, (out, x) in enumerate(zip(outs, batches)):
            emit(f"out{i}", out)
            emit(f"ref{i}", accel.infer(params, x))
        """,
        n_devices=2,
    )
    for i in range(4):
        assert_bitwise(payload, f"out{i}", f"ref{i}")
