"""Pipeline-parallel numerics on a real multi-device (host-platform) mesh.

Runs in a SUBPROCESS with xla_force_host_platform_device_count=4 so the main
test process keeps its single-device view (per the dry-run isolation rule).
"""

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.parallel import pipeline_forward

mesh = jax.make_mesh((4,), ("stage",))
n_stages, n_micro, mb, d = 4, 8, 4, 16
w = jax.random.normal(jax.random.PRNGKey(0), (n_stages, d, d)) * 0.3
x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

def stage_fn(wp, xx, stage):
    return jnp.tanh(xx @ wp)

out = pipeline_forward(mesh, "stage", stage_fn, w, x)
ref = x
for s in range(n_stages):
    ref = jnp.tanh(ref @ w[s])
np.testing.assert_allclose(np.array(out), np.array(ref), rtol=2e-5, atol=2e-5)
print("PIPELINE_OK")
"""


EXECUTOR_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, numpy as np
from repro.configs.base import get_config
from repro.core.accelerator import PipelinedExecutor, get_accelerator
from repro.data.pointclouds import sample_batch

cfg = get_config("pointnet2-cls", smoke=True)
accel = get_accelerator(cfg)
params = accel.init(jax.random.PRNGKey(0))
batches = [np.asarray(sample_batch(jax.random.PRNGKey(3 + i), 2, cfg.n_points)[0])
           for i in range(4)]
ex = PipelinedExecutor(accel)  # stage A on device 0, stage B + params on device 1
assert len(ex.devices) == 2, ex.devices
outs = ex.run(params, batches)
for out, x in zip(outs, batches):
    np.testing.assert_array_equal(np.asarray(out), np.asarray(accel.infer(params, x)))
print("EXECUTOR_OK")
"""


def _run_subprocess(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        timeout=300,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )


def test_pipeline_4stage_subprocess():
    res = _run_subprocess(SCRIPT)
    assert "PIPELINE_OK" in res.stdout, res.stderr[-2000:]


def test_pipelined_executor_two_devices_subprocess():
    """The >=2-device branch of PipelinedExecutor: preprocess pinned to
    device 0, feature stage + params to device 1, hand-off transferred —
    still bitwise-equal to the sequential fused infer."""
    res = _run_subprocess(EXECUTOR_SCRIPT)
    assert "EXECUTOR_OK" in res.stdout, res.stderr[-2000:]
