"""Property tests for serve/hashing.py — the content-address contract.

The cache key must be TOLERANT of float noise below the quantization step
(repeat sweeps of a static scene collide on purpose) and SENSITIVE to
everything that changes the preprocessing answer: point permutation (results
index by row), translation/scale (neighborhoods live in absolute
coordinates), shape and feature columns.  See the hashing module docstring
for why each invariance is intentional.
"""

from __future__ import annotations

import numpy as np
import pytest
from _hypothesis import HAVE_HYPOTHESIS, given, settings, st

from repro.serve.hashing import DEFAULT_QUANT_STEP, content_key, quantize_cloud

STEP = 1e-3


def _cloud(n=32, width=3, seed=0):
    rng = np.random.default_rng(seed)
    # snap to the lattice so sub-step jitter provably stays inside the cell
    base = rng.standard_normal((n, width)).astype(np.float64)
    return (np.round(base / STEP) * STEP).astype(np.float32)


if HAVE_HYPOTHESIS:
    seeds = st.integers(min_value=0, max_value=2**31 - 1)
    sizes = st.integers(min_value=1, max_value=64)
else:  # placeholders; @given skips the tests anyway
    seeds = sizes = None


class TestNoiseTolerance:
    @settings(max_examples=50, deadline=None)
    @given(seed=seeds, n=sizes)
    def test_sub_step_noise_collides(self, seed, n):
        # noise < step/2 around lattice-cell centres never changes the key —
        # the static-scene / consecutive-sweep case the cache exists for
        cloud = _cloud(n, seed=seed)
        rng = np.random.default_rng(seed + 1)
        noise = (rng.uniform(-0.49, 0.49, cloud.shape) * STEP).astype(np.float32)
        assert content_key(cloud, STEP) == content_key(cloud + noise, STEP)

    @settings(max_examples=50, deadline=None)
    @given(seed=seeds)
    def test_key_is_deterministic(self, seed):
        cloud = _cloud(seed=seed)
        assert content_key(cloud, STEP) == content_key(cloud.copy(), STEP)

    def test_super_step_perturbation_changes_key(self):
        cloud = _cloud(seed=7)
        moved = cloud.copy()
        moved[0, 0] += 10 * STEP  # clearly a different lattice cell
        assert content_key(cloud, STEP) != content_key(moved, STEP)


class TestIntentionalSensitivity:
    @settings(max_examples=50, deadline=None)
    @given(seed=seeds)
    def test_permutation_changes_key(self, seed):
        # preprocessing indexes the cloud by ROW: a permutation-invariant key
        # would serve row-misaligned cached neighborhoods
        cloud = _cloud(n=16, seed=seed)
        rng = np.random.default_rng(seed + 2)
        perm = rng.permutation(cloud.shape[0])
        if np.array_equal(perm, np.arange(cloud.shape[0])):
            return  # identity permutation drawn — nothing to distinguish
        permuted = cloud[perm]
        if np.array_equal(quantize_cloud(cloud, STEP), quantize_cloud(permuted, STEP)):
            return  # all permuted rows landed in identical cells (dup points)
        assert content_key(cloud, STEP) != content_key(permuted, STEP)

    @settings(max_examples=50, deadline=None)
    @given(seed=seeds)
    def test_translation_changes_key(self, seed):
        # absolute coordinates are part of the neighborhood structure;
        # rigid-motion reuse is a documented follow-on, not a hash property
        cloud = _cloud(seed=seed)
        assert content_key(cloud, STEP) != content_key(cloud + np.float32(0.5), STEP)

    def test_scale_changes_key(self):
        cloud = _cloud(seed=3)
        assert content_key(cloud, STEP) != content_key(cloud * np.float32(2.0), STEP)

    def test_shape_and_feature_columns_matter(self):
        cloud = _cloud(n=16, width=5, seed=4)
        assert content_key(cloud, STEP) != content_key(cloud[:8], STEP)
        withf = cloud.copy()
        withf[:, 3] += 10 * STEP  # feature column change, xyz identical
        assert content_key(cloud, STEP) != content_key(withf, STEP)

    def test_step_is_part_of_the_key(self):
        cloud = _cloud(seed=5)
        assert content_key(cloud, STEP) != content_key(cloud, STEP * 2)


class TestQuantizeCloud:
    def test_lattice_cells(self):
        cloud = np.array([[0.0, 1e-3, -1e-3], [2.4e-3, 2.6e-3, 0.49e-3]], np.float32)
        cells = quantize_cloud(cloud, 1e-3)
        np.testing.assert_array_equal(cells, [[0, 1, -1], [2, 3, 0]])

    def test_non_finite_values_hash_deterministically(self):
        cloud = np.array([[np.nan, np.inf, -np.inf]], np.float32)
        a = content_key(cloud, STEP)
        b = content_key(cloud.copy(), STEP)
        assert a == b
        # each sentinel is distinct from a zero cell
        assert a != content_key(np.zeros((1, 3), np.float32), STEP)

    def test_rejects_non_positive_step(self):
        with pytest.raises(ValueError):
            quantize_cloud(np.zeros((2, 3), np.float32), 0.0)

    def test_default_step_exported(self):
        assert DEFAULT_QUANT_STEP == 1e-3
