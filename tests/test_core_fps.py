"""Unit + property tests for core/fps.py (C1, C3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis import given, settings, st

from repro.core import fps as F

jax.config.update("jax_platform_name", "cpu")


def _cloud(n, seed=0):
    return jax.random.uniform(jax.random.PRNGKey(seed), (n, 3), minval=-1.0, maxval=1.0)


class TestPairwise:
    def test_l2_matches_numpy(self):
        a, b = np.array(_cloud(16)), np.array(_cloud(8, 1))
        d = np.array(F.pairwise_distance(jnp.array(a), jnp.array(b), "l2"))
        ref = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
        np.testing.assert_allclose(d, ref, rtol=1e-5)

    def test_l1_matches_numpy(self):
        a, b = np.array(_cloud(16)), np.array(_cloud(8, 1))
        d = np.array(F.pairwise_distance(jnp.array(a), jnp.array(b), "l1"))
        ref = np.abs(a[:, None, :] - b[None, :, :]).sum(-1)
        np.testing.assert_allclose(d, ref, rtol=1e-5)

    def test_l1_upper_bounds_l2(self):
        # ||x||2 <= ||x||1 — the geometric fact behind the 1.6R lattice factor
        a, b = _cloud(32), _cloud(32, 1)
        l1 = F.pairwise_distance(a, b, "l1")
        l2 = jnp.sqrt(F.pairwise_distance(a, b, "l2"))
        assert bool(jnp.all(l1 >= l2 - 1e-6))


class TestFPS:
    @pytest.mark.parametrize("metric", ["l1", "l2"])
    def test_indices_unique_and_start(self, metric):
        pts = _cloud(64)
        idx = np.array(F.fps(pts, 16, metric=metric))
        assert idx[0] == 0
        assert len(np.unique(idx)) == 16

    def test_matches_naive_l2(self):
        pts = _cloud(40)
        got = np.array(F.fps(pts, 10, metric="l2"))
        # naive reference
        p = np.array(pts)
        dmin = np.full(40, np.inf)
        ref = [0]
        for _ in range(9):
            d = ((p - p[ref[-1]]) ** 2).sum(-1)
            dmin = np.minimum(dmin, d)
            ref.append(int(np.argmax(dmin)))
        np.testing.assert_array_equal(got, np.array(ref))

    def test_l1_close_to_l2_quality(self):
        # paper Fig 5a: approximate sampling preserves coverage
        pts = _cloud(256)
        k = 64
        cov_l2 = float(F.coverage_radius(pts, F.fps(pts, k, metric="l2")))
        cov_l1 = float(F.coverage_radius(pts, F.fps(pts, k, metric="l1")))
        assert cov_l1 <= cov_l2 * 1.25  # L1 sample covers nearly as well

    def test_batched_matches_loop(self):
        pts = jnp.stack([_cloud(32, s) for s in range(3)])
        got = np.array(F.fps_batched(pts, 8))
        for b in range(3):
            np.testing.assert_array_equal(got[b], np.array(F.fps(pts[b], 8)))

    def test_valid_mask_excludes_padding(self):
        pts = _cloud(32)
        pts = pts.at[20:].set(100.0)  # far-away "padding" points
        valid = jnp.arange(32) < 20
        idx = np.array(F.fps(pts, 10, valid=valid))
        assert (idx < 20).all()

    def test_fused_step_equals_two_phase(self):
        pts = _cloud(50)
        dmin = jnp.full((50,), 1e30)
        new_dmin, nxt = F.fused_fps_step(pts, dmin, jnp.int32(0), "l2")
        d = F.point_distance(pts, pts[0], "l2")
        np.testing.assert_allclose(np.array(new_dmin), np.minimum(np.array(dmin), np.array(d)), rtol=1e-6)
        assert int(nxt) == int(jnp.argmax(new_dmin))


class TestQuantizedL1:
    def test_roundtrip_scale(self):
        pts = _cloud(128)
        q, scale, off = F.quantize_coords(pts, bits=16)
        rec = np.array(q) * np.array(scale) + np.array(off)
        np.testing.assert_allclose(rec, np.array(pts), atol=2e-4)

    def test_distance_fits_19_bits(self):
        pts = _cloud(256, 3)
        q, _, _ = F.quantize_coords(pts, bits=16)
        d = jnp.abs(q[:, None, :] - q[None, :, :]).sum(-1)
        assert int(jnp.max(d)) < (1 << 19)  # paper: 19-bit TDs

    def test_quantized_fps_close_to_float_l1(self):
        pts = _cloud(128, 7)
        q, _, _ = F.quantize_coords(pts, bits=16)
        qi = np.array(F.fps_l1_quantized(q, 32))
        fi = np.array(F.fps(pts, 32, metric="l1"))
        # 16-bit grid rarely flips argmax ties; demand high agreement
        assert (qi == fi).mean() > 0.9


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=8, max_value=64),
    k=st.integers(min_value=2, max_value=8),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_property_fps_2approx_coverage(n, k, seed):
    """Property: greedy FPS is a 2-approximation to k-center — its covering
    radius is <= 2x that of ANY k-subset, in particular a random one."""
    pts = jax.random.normal(jax.random.PRNGKey(seed), (n, 3))
    idx = F.fps(pts, k)
    rand_idx = jax.random.choice(jax.random.PRNGKey(seed + 1), n, (k,), replace=False)
    cov_fps = float(F.coverage_radius(pts, idx))
    cov_rand = float(F.coverage_radius(pts, rand_idx))
    assert cov_fps <= 2.0 * cov_rand + 1e-6


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_property_fps_unique(seed):
    pts = jax.random.normal(jax.random.PRNGKey(seed), (32, 3))
    idx = np.array(F.fps(pts, 12, metric="l1"))
    assert len(np.unique(idx)) == 12
