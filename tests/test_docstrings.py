"""Docstring-presence enforcement for the public API surface.

CI enforces the full ruff pydocstyle ("D") rule set on these modules (see
ruff.toml); this test mirrors the missing-docstring half (D100-D104) inside
tier-1 so environments without ruff — like a bare `pytest` run — still fail
loudly when a public module/class/function in the documented surface loses
its docstring.  The scoped file list MUST stay in sync with the per-file
ignore list in ruff.toml.
"""

import ast
import pathlib

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"

# keep in sync with ruff.toml: everything NOT D-ignored there
PUBLIC_MODULES = sorted(SRC.glob("repro/serve/*.py")) + [
    SRC / "repro/core/accelerator.py",
    SRC / "repro/core/engine.py",
    SRC / "repro/core/policy.py",
]


def _has_doc(node) -> bool:
    return (
        bool(node.body)
        and isinstance(node.body[0], ast.Expr)
        and isinstance(node.body[0].value, ast.Constant)
        and isinstance(node.body[0].value.value, str)
        and bool(node.body[0].value.value.strip())
    )


def _public(name: str) -> bool:
    return not name.startswith("_")


def _magic(name: str) -> bool:
    return name.startswith("__") and name.endswith("__")


def _missing(path: pathlib.Path) -> list[str]:
    tree = ast.parse(path.read_text())
    rel = path.relative_to(SRC)
    out = []
    if not _has_doc(tree):
        out.append(f"{rel}: module docstring")
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and _public(node.name):
            if not _has_doc(node):
                out.append(f"{rel}:{node.lineno}: class {node.name}")
            for item in node.body:
                if (
                    isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and _public(item.name)
                    and not _magic(item.name)
                    and not _has_doc(item)
                ):
                    out.append(f"{rel}:{item.lineno}: method {node.name}.{item.name}")
    for node in tree.body:  # top-level functions only
        if (
            isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
            and _public(node.name)
            and not _has_doc(node)
        ):
            out.append(f"{rel}:{node.lineno}: function {node.name}")
    return out


def test_scoped_files_exist():
    assert len(PUBLIC_MODULES) >= 11, PUBLIC_MODULES
    for path in PUBLIC_MODULES:
        assert path.is_file(), path


def test_public_api_docstrings_present():
    missing = [m for path in PUBLIC_MODULES for m in _missing(path)]
    assert missing == [], "public API items missing docstrings:\n" + "\n".join(missing)
