"""Tests for core/energy.py model consistency and core/preprocess.py pipelines."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import energy as E
from repro.core import grouping as G
from repro.core import preprocess as PP
from repro.core.query import NeighborSet

jax.config.update("jax_platform_name", "cpu")


def _cloud(n, seed=0):
    return jax.random.uniform(jax.random.PRNGKey(seed), (n, 3), minval=-1.0, maxval=1.0)


class TestEnergyModel:
    def test_td_bitwidth_derivation(self):
        # Challenge-I consistency: 48 : 2d == 41 : 58 -> d ~ 34
        d = E.POINT_BITS * 58 / (2 * 41)
        assert abs(d - E.TD_BITS_L2) < 1.0

    def test_l1_vs_l2_td_saving(self):
        assert E.TD_BITS_L1 == 19
        assert E.TD_BITS_L1 < E.TD_BITS_L2

    def test_ordering_b1_b2_pc2im(self):
        """energy(PC2IM) < energy(TiPU) < energy(baseline1) on every dataset."""
        for w in E.WORKLOADS.values():
            e1 = E.preproc_energy_baseline1(w)["total_pj"]
            e2 = E.preproc_energy_baseline2(w)["total_pj"]
            ep = E.preproc_energy_pc2im(w)["total_pj"]
            assert ep < e2 < e1

    def test_calibration_hits_claims(self):
        _, rep = E.calibrate_cim()
        assert abs(rep["reduction_vs_baseline2"] - 0.734) < 0.02
        assert abs(rep["reduction_vs_baseline1"] - 0.979) < 0.02

    def test_reduction_grows_with_scale(self):
        """paper: 'up to 97.9% ... for large-scale PCs' — monotone in N."""
        c, _ = E.calibrate_cim()
        reds = []
        for name in ["modelnet_1k", "s3dis_4k", "semantickitti_16k"]:
            w = E.WORKLOADS[name]
            e1 = E.preproc_energy_baseline1(w)["total_pj"]
            ep = E.preproc_energy_pc2im(w, c)["total_pj"]
            reds.append(1 - ep / e1)
        assert reds[0] < reds[1] < reds[2]

    def test_fom_ratios(self):
        def f(scr, s):
            return E.sccim_fom(scr, s)["fom2"]

        r_bs_8 = f(8, "sc_cim") / f(8, "bs_cim")
        r_bt_8 = f(8, "sc_cim") / f(8, "bt_cim")
        assert abs(r_bs_8 - 5.2) < 0.3 and abs(r_bt_8 - 2.0) < 0.2
        # monotone amortisation toward the 9.9x / 2.8x asymptotes
        assert f(256, "sc_cim") / f(256, "bs_cim") > 9.0
        assert f(256, "sc_cim") / f(256, "bt_cim") > 2.6

    def test_system_speedups(self):
        sc, rep = E.calibrate_system()
        assert abs(rep["speedup_vs_baseline2_tipu"] - 1.5) < 0.2
        assert abs(rep["speedup_vs_gpu"] - 3.5) < 0.5
        assert rep["speedup_vs_baseline1"] > 3.0
        assert 1.8 < rep["energy_eff_vs_baseline2_tipu"] < 3.5  # paper: 2.7x
        assert 1000 < rep["energy_eff_vs_gpu"] < 2200  # paper: 1518.9x


class TestPreprocessPipelines:
    @pytest.mark.parametrize("name", ["baseline1", "baseline2", "pc2im"])
    def test_pipeline_shapes_and_validity(self, name):
        pts = _cloud(256)
        fn = PP.PIPELINES[name]
        res = fn(pts, n_centroids=32, radius=0.4, nsample=8)
        assert res.centroid_idx.shape == (32,)
        assert res.centroid_xyz.shape == (32, 3)
        assert res.neighbors.idx.shape == (32, 8)
        ci = np.array(res.centroid_idx)
        assert (ci >= 0).all() and (ci < 256).all()
        # centroid coords consistent with indices
        np.testing.assert_allclose(
            np.array(res.centroid_xyz), np.array(pts)[ci], rtol=1e-6
        )

    def test_pc2im_neighbors_within_lattice(self):
        pts = _cloud(256)
        res = PP.preprocess_pc2im(pts, 32, radius=0.4, nsample=8, depth=2)
        p = np.array(pts)
        idx, mask = np.array(res.neighbors.idx), np.array(res.neighbors.mask)
        c = np.array(res.centroid_xyz)
        for m in range(32):
            for s in range(8):
                if mask[m, s]:
                    l1 = np.abs(p[idx[m, s]] - c[m]).sum()
                    assert l1 <= 0.4 * 1.6 + 1e-5

    def test_pc2im_centroids_unique(self):
        pts = _cloud(512)
        res = PP.preprocess_pc2im(pts, 64, radius=0.4, nsample=8, depth=3)
        ci = np.array(res.centroid_idx)
        assert len(np.unique(ci)) == 64  # tiles disjoint + per-tile FPS unique

    def test_baseline2_handles_ragged_tiles(self):
        pts = _cloud(300)  # not power-of-two, ragged grid occupancy
        res = PP.preprocess_baseline2(pts, 32, radius=0.5, nsample=8, grid=2)
        assert res.centroid_idx.shape == (32,)


class TestGrouping:
    def _nbrs(self):
        idx = jnp.array([[0, 1, 2, 0], [3, 4, 0, 0]], jnp.int32)
        mask = jnp.array([[1, 1, 1, 0], [1, 1, 0, 0]], bool)
        return NeighborSet(idx=idx, mask=mask)

    def test_masked_maxpool_ignores_padding(self):
        feats = jnp.arange(10.0).reshape(5, 2)
        nbrs = self._nbrs()
        grouped = G.group_features(feats, nbrs)
        out = np.array(G.masked_maxpool(grouped, nbrs.mask))
        np.testing.assert_allclose(out[0], np.array(feats)[[0, 1, 2]].max(0))
        np.testing.assert_allclose(out[1], np.array(feats)[[3, 4]].max(0))

    def test_delayed_equals_standard_for_linear_mlp(self):
        """C5 exactness: with a LINEAR mlp, delayed aggregation == standard."""
        w = jax.random.normal(jax.random.PRNGKey(0), (2, 4))
        def mlp(x):
            return x @ w

        feats = jax.random.normal(jax.random.PRNGKey(1), (5, 2))
        nbrs = self._nbrs()
        a = G.aggregate_standard(feats, nbrs, mlp)
        b = G.aggregate_delayed(feats, nbrs, mlp)
        # max and linear don't commute in general, but gather does: results
        # use the same per-point values -> pooled outputs must match exactly
        np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-6)

    def test_interpolate_features(self):
        feats = jnp.eye(3)
        idx = jnp.array([[0, 1, 2]])
        w = jnp.array([[0.5, 0.3, 0.2]])
        out = np.array(G.interpolate_features(feats, idx, w))
        np.testing.assert_allclose(out[0], [0.5, 0.3, 0.2], rtol=1e-6)

    def test_delayed_cheaper_flops(self):
        """C5's point: per-point MLP work N*C*C' vs M*nsample*C*C'."""
        n, m, nsample, c, cp = 1024, 256, 32, 64, 128
        assert n * c * cp < m * nsample * c * cp