"""Deep correctness tests for the sequence-mixing recurrences:
chunked SSD (mamba2) and RG-LRU vs naive sequential oracles."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis import given, settings, st

from repro.models.mamba2 import ssd_forward
from repro.models.rglru import _lru_scan

jax.config.update("jax_platform_name", "cpu")


def ssd_naive(x, dt, A, B, C):
    """Sequential SSM recurrence: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t;
    y_t = C_t h_t.  x: (b,s,h,p), dt: (b,s,h), A: (h,), B,C: (b,s,n)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    state = np.zeros((b, h, p, n))
    ys = np.zeros((b, s, h, p))
    xx, dtt, BB, CC = map(np.asarray, (x, dt, B, C))
    AA = np.asarray(A)
    for t in range(s):
        decay = np.exp(dtt[:, t] * AA[None, :])  # (b,h)
        inject = np.einsum("bh,bn,bhp->bhpn", dtt[:, t], BB[:, t], xx[:, t])
        state = state * decay[..., None, None] + inject
        ys[:, t] = np.einsum("bn,bhpn->bhp", CC[:, t], state)
    return ys, state


class TestSSD:
    def _inputs(self, b=2, s=32, h=3, p=4, n=8, seed=0):
        k = jax.random.PRNGKey(seed)
        ks = jax.random.split(k, 5)
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
        B = jax.random.normal(ks[3], (b, s, n))
        C = jax.random.normal(ks[4], (b, s, n))
        return x, dt, A, B, C

    def test_chunked_matches_naive(self):
        x, dt, A, B, C = self._inputs()
        for chunk in [4, 8, 16, 32]:
            y, final = ssd_forward(x, dt, A, B, C, chunk=chunk)
            y_ref, state_ref = ssd_naive(x, dt, A, B, C)
            np.testing.assert_allclose(np.array(y), y_ref, rtol=2e-4, atol=2e-4)
            np.testing.assert_allclose(np.array(final), state_ref, rtol=2e-4, atol=2e-4)

    def test_chunk_size_invariance(self):
        x, dt, A, B, C = self._inputs(seed=3)
        y1, f1 = ssd_forward(x, dt, A, B, C, chunk=4)
        y2, f2 = ssd_forward(x, dt, A, B, C, chunk=16)
        np.testing.assert_allclose(np.array(y1), np.array(y2), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.array(f1), np.array(f2), rtol=1e-4, atol=1e-4)

    def test_final_state_feeds_decode(self):
        """prefill final state + one recurrent step == naive over s+1 steps."""
        x, dt, A, B, C = self._inputs(s=16, seed=5)
        x2, dt2, _, B2, C2 = self._inputs(s=17, seed=5 + 100)
        # concatenate a new step
        xa = jnp.concatenate([x, x2[:, :1]], axis=1)
        dta = jnp.concatenate([dt, dt2[:, :1]], axis=1)
        Ba = jnp.concatenate([B, B2[:, :1]], axis=1)
        Ca = jnp.concatenate([C, C2[:, :1]], axis=1)
        y_ref, _ = ssd_naive(xa, dta, A, Ba, Ca)
        _, state = ssd_forward(x, dt, A, B, C, chunk=8)
        decay = jnp.exp(dta[:, -1] * A[None])
        inject = jnp.einsum("bh,bn,bhp->bhpn", dta[:, -1], Ba[:, -1], xa[:, -1])
        state2 = state * decay[..., None, None] + inject
        y_last = jnp.einsum("bn,bhpn->bhp", Ca[:, -1], state2)
        np.testing.assert_allclose(np.array(y_last), y_ref[:, -1], rtol=2e-4, atol=2e-4)


class TestRGLRU:
    def test_associative_scan_matches_loop(self):
        b, s, w = 2, 24, 8
        a = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(0), (b, s, w)))
        x = jax.random.normal(jax.random.PRNGKey(1), (b, s, w))
        got = np.array(_lru_scan(x, a))
        h = np.zeros((b, w))
        ref = np.zeros((b, s, w))
        aa, xx = np.array(a), np.array(x)
        for t in range(s):
            h = aa[:, t] * h + xx[:, t]
            ref[:, t] = h
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), s=st.sampled_from([8, 16, 24]))
def test_property_ssd_chunk_invariance(seed, s):
    """Property: SSD output is independent of the chunking (exact algorithm)."""
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 5)
    b, h, p, n = 1, 2, 4, 4
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    y1, _ = ssd_forward(x, dt, A, B, C, chunk=4)
    y2, _ = ssd_forward(x, dt, A, B, C, chunk=s)
    np.testing.assert_allclose(np.array(y1), np.array(y2), rtol=5e-4, atol=5e-4)
