"""Batched LM serving: prefill a prompt batch, decode greedily with KV caches.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-12b --tokens 16
    PYTHONPATH=src python examples/serve_lm.py --quant sc_w16a16

Uses the reduced (smoke) config so it runs on CPU; the same prefill/decode
functions are what the decode_32k / long_500k dry-run cells lower at scale.
--quant pins an ExecutionPolicy on the serve fns — every linear runs the
SC-CIM integer path, with no config edit and no global state."""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core.policy import ExecutionPolicy
from repro.models.families import get_family_api
from repro.serve import make_serve_fns


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--quant", default=None, choices=["none", "sc_w16a16", "sc_w8a8"])
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    api = get_family_api(cfg)
    policy = ExecutionPolicy(quant=args.quant) if args.quant else None
    fns = make_serve_fns(cfg, policy=policy)
    params = api["init"](jax.random.PRNGKey(0), cfg)

    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, args.prompt_len, cfg.d_model))
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.n_patches, cfg.d_model))

    s_max = args.prompt_len + cfg.n_patches + args.tokens + 8
    t0 = time.time()
    logits, state = fns["prefill"](params, batch, s_max)
    print(f"prefill: batch={args.batch} len={args.prompt_len} -> "
          f"logits {logits.shape} in {time.time()-t0:.2f}s")

    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    t0 = time.time()
    for _ in range(args.tokens - 1):
        _, tok, state = fns["decode"](params, state, {"token": tok})
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {args.tokens} tokens/seq in {dt:.2f}s "
          f"({args.tokens*args.batch/dt:.1f} tok/s)")
    print("sample:", gen[0].tolist())


if __name__ == "__main__":
    main()
