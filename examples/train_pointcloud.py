"""End-to-end driver: train PointNet2 segmentation with checkpoint/restart.

    PYTHONPATH=src python examples/train_pointcloud.py --steps 100
    PYTHONPATH=src python examples/train_pointcloud.py --quant sc_w16a16

Thin wrapper over the production driver (repro.launch.train), which builds
a PC2IMAccelerator from the config + ExecutionPolicy; --quant selects the
SC-CIM feature path without touching the config."""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "pointnet2-seg", "--smoke",
                "--ckpt-dir", "/tmp/repro_ckpt_pn2"] + sys.argv[1:]
    main()
