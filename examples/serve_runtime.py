"""Serving-runtime quickstart: ragged traffic -> bucketed micro-batches.

    PYTHONPATH=src python examples/serve_runtime.py
    PYTHONPATH=src python examples/serve_runtime.py --requests 48 --replicas 2 --mix-quant

Submits a stream of mixed-size clouds (some padded up, some stride-
subsampled down to a bucket) through the full queue -> scheduler ->
replica-pool path, optionally interleaving fp32 and SC W16A16 requests,
then prints the latency/throughput/occupancy snapshot and the executed
micro-batches — each one a single (bucket, policy) key, i.e. exactly one
compiled artifact."""

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.accelerator import cache_stats, get_accelerator
from repro.core.policy import ExecutionPolicy
from repro.serve import RuntimeConfig, ServingRuntime


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--replicas", type=int, default=None)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--mix-quant", action="store_true",
                    help="alternate fp32 / sc_w16a16 per request")
    args = ap.parse_args()

    cfg = get_config("pointnet2-cls", smoke=True)  # n_points=256, CPU-friendly
    params = get_accelerator(cfg).init(jax.random.PRNGKey(0))
    rt = ServingRuntime(
        cfg,
        params,
        RuntimeConfig(
            max_batch=args.max_batch,
            max_wait_s=0.01,
            buckets=(192, 256),
            n_replicas=args.replicas,
        ),
    )
    policies = [None, ExecutionPolicy(quant="sc_w16a16")] if args.mix_quant else [None]
    print(rt)
    print("warming up (one jit trace per bucket x policy x replica)...")
    rt.warmup(policies=tuple(policies))

    rng = np.random.default_rng(0)
    sizes = [150, 256, 320]  # pad / exact / subsample
    t0 = time.perf_counter()
    with rt:
        futs = [
            rt.submit(
                rng.standard_normal((sizes[i % 3], 3)).astype(np.float32),
                policy=policies[i % len(policies)],
            )
            for i in range(args.requests)
        ]
        outs = [f.result(timeout=300) for f in futs]
    wall = time.perf_counter() - t0

    print(f"served {len(outs)} clouds in {wall:.2f}s; logits shape {outs[0].shape}")
    print("metrics:", rt.metrics.snapshot().format_row())
    print("micro-batches (bucket, policy, n_real/B, replica):")
    for b in rt.metrics.batch_records:
        if b.n_real:
            print(f"  n={b.bucket:<4} {b.policy_key[0]:<10} {b.n_real}/{b.batch_size}"
                  f"  replica {b.replica_id}  {b.duration_s * 1e3:.1f}ms")
    print("artifact cache:", cache_stats())


if __name__ == "__main__":
    main()
