"""Request-lifecycle tracing demo: trace a serve run, export for Perfetto.

    PYTHONPATH=src python examples/serve_trace.py
    PYTHONPATH=src python examples/serve_trace.py --requests 96 --out my.json

Runs a traced `ServingRuntime` (TraceConfig attached, periodic Reporter
printing one metrics line per interval) over a small open-loop trace of
mixed-size clouds, then shows every consumer of the trace stream:

  * the per-SLO-class stage breakdown (`stage_breakdown.format_rows()`) —
    p50/p95 of where each request's latency went, queue wait through the
    execute stage, cross-checked so the stages sum to measured e2e;
  * the batch cross-check (`batch_crosscheck`) tying batch-span durations
    back to the `BatchRecord` totals the metrics layer recorded;
  * a Chrome-trace JSON written via `write_chrome_trace` — open it at
    https://ui.perfetto.dev (or chrome://tracing) to see request spans,
    batch stage slices and control-plane instants on a shared timeline;
  * the Prometheus text exposition of the final metrics snapshot.
"""

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.accelerator import get_accelerator
from repro.serve import (
    RuntimeConfig,
    ServingRuntime,
    TraceConfig,
    batch_crosscheck,
    prometheus_text,
    request_timelines,
    stage_breakdown,
    trace_problems,
    write_chrome_trace,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--rate", type=float, default=150.0,
                    help="open-loop arrival rate, requests/s")
    ap.add_argument("--out", default="pc2im_trace.json",
                    help="Chrome-trace JSON output path (load in Perfetto)")
    args = ap.parse_args()

    cfg = get_config("pointnet2-cls", smoke=True)  # n_points=256, CPU-friendly
    params = get_accelerator(cfg).init(jax.random.PRNGKey(0))
    rt = ServingRuntime(cfg, params, RuntimeConfig(
        max_batch=4,
        max_wait_s=0.01,
        max_queue=max(64, args.requests),
        trace=TraceConfig(sample=1.0),  # trace every request
        report_interval_s=0.5,          # Reporter prints to stderr
    ))
    print(rt)
    print("warming up (one jit trace per bucket x policy)...")
    rt.warmup()

    rng = np.random.default_rng(0)
    clouds = [rng.standard_normal((n, 3)).astype(np.float32)
              for n in (160, 256, 320)]
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, size=args.requests))
    futs = []
    t0 = time.perf_counter()
    with rt:
        for i in range(args.requests):
            time.sleep(max(0.0, t0 + arrivals[i] - time.perf_counter()))
            futs.append(rt.submit(clouds[i % len(clouds)]))
        for f in futs:
            f.result(timeout=300)
    wall = time.perf_counter() - t0

    events = rt.tracer.events()
    problems = trace_problems(events)
    timelines = request_timelines(events)
    print(f"\nserved {args.requests} requests in {wall:.2f}s — "
          f"{len(events)} trace events ({rt.tracer.dropped} dropped), "
          f"{len(timelines)} request spans, "
          f"{len(problems)} malformed")

    print("\nper-class stage breakdown (p50/p95 seconds per stage):")
    for line in stage_breakdown(events).format_rows().splitlines():
        print(" ", line)

    checks = batch_crosscheck(events, rt.metrics.batch_records)
    if checks:
        worst = max(checks, key=lambda c: c.rel_err)
        print(f"\nbatch span vs BatchRecord cross-check: {len(checks)} batches,"
              f" worst rel_err {worst.rel_err:.1%} (batch {worst.batch_id})")

    n = write_chrome_trace(args.out, events)
    print(f"\nwrote {n} Chrome-trace events to {args.out} — "
          f"load it at https://ui.perfetto.dev")

    print("\nPrometheus exposition of the final snapshot:")
    for line in prometheus_text(rt.metrics.snapshot()).splitlines():
        print(" ", line)


if __name__ == "__main__":
    main()
