"""SLO control plane demo: two classes under overload + a mid-run kill.

    PYTHONPATH=src python examples/serve_slo.py
    PYTHONPATH=src python examples/serve_slo.py --requests 300 --no-kill

Offers a mixed trace — one third non-sheddable "interactive" requests with
a deadline, two thirds sheddable "bulk" — at well above what the runtime
can sustain, so the control plane has to choose: interactive requests jump
the queue (priority + earliest-deadline-first drain) while bulk absorbs
all the load shedding (`Shed` at submit time once the backlog crosses
`shed_threshold`).  Halfway through, the chaos injector kills replica 1;
the autoscaler notices the dead slot and rejoins it warm (params re-pinned,
every bucket x policy artifact re-traced, hot cache entries pre-staged)
while traffic keeps flowing on the survivor.  The final per-class metrics
breakdown shows the contract: interactive shed=0 with a low p95, bulk
carrying every shed, and the rejoin event in the autoscaler log."""

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.accelerator import get_accelerator
from repro.serve import (
    BULK,
    INTERACTIVE,
    AutoscalerConfig,
    ChaosInjector,
    Fault,
    RuntimeConfig,
    ServingRuntime,
    Shed,
    SLOClass,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=240)
    ap.add_argument("--no-kill", action="store_true",
                    help="skip the chaos kill / rejoin half of the demo")
    args = ap.parse_args()

    cfg = get_config("pointnet2-cls", smoke=True)  # n_points=256, CPU-friendly
    params = get_accelerator(cfg).init(jax.random.PRNGKey(0))
    # a relaxed interactive class for a shared demo host: same priority and
    # shed exemption as serve.INTERACTIVE, roomier deadline
    interactive = SLOClass(
        "interactive",
        priority=INTERACTIVE.priority,
        deadline_s=5.0,
        sheddable=False,
        max_wait_s=0.002,
    )
    rt = ServingRuntime(cfg, params, RuntimeConfig(
        max_batch=4,
        max_wait_s=0.01,
        max_queue=max(64, args.requests // 2),
        n_replicas=2,
        shed_threshold=24,  # backlog past this sheds BULK, never interactive
        autoscaler=AutoscalerConfig(  # rejoin-only: no depth-driven scaling
            poll_interval_s=0.02, rejoin_delay_s=0.1,
            scale_up_depth=1e9, scale_down_ticks=10**9,
        ),
    ))
    print(rt)
    print("warming up (one jit trace per bucket x policy x replica)...")
    rt.warmup()
    if not args.no_kill:
        chaos = ChaosInjector([Fault(replica_id=1, at_batch=5, kind="kill")])
        chaos.attach(rt.pool)

    rng = np.random.default_rng(0)
    clouds = [rng.standard_normal((cfg.n_points, 3)).astype(np.float32)
              for _ in range(8)]
    futs, shed = [], {"interactive": 0, "bulk": 0}
    t0 = time.perf_counter()
    with rt:
        for i in range(args.requests):
            slo = interactive if i % 3 == 0 else BULK
            try:
                futs.append(rt.submit(clouds[i % len(clouds)], slo=slo))
            except Shed:
                shed[slo.name] += 1
        for f in futs:
            try:
                f.result(timeout=300)
            except Exception:  # noqa: BLE001 — expired under overload
                pass
        if not args.no_kill:  # hold the pool open until the rejoin lands
            deadline = time.perf_counter() + 15
            while rt.metrics.rejoins < 1 and time.perf_counter() < deadline:
                time.sleep(0.02)
    wall = time.perf_counter() - t0

    snap = rt.metrics.snapshot()
    print(f"\noffered {args.requests} requests in {wall:.2f}s "
          f"(shed at submit: {shed})")
    print("aggregate:", snap.format_row())
    print("per-class breakdown:")
    for line in snap.format_class_rows().splitlines():
        print(" ", line)
    if not args.no_kill:
        print("autoscaler log:")
        for ev in rt.autoscaler.events:
            print(f"  t+{ev.t - t0:5.2f}s {ev.action:<8} replica {ev.replica_id}"
                  f" (queue depth {ev.depth:.1f})")


if __name__ == "__main__":
    main()
