"""Quickstart: the paper's pipeline end-to-end in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds procedural point clouds, runs batched PC2IM preprocessing (median
partition -> L1 FPS -> lattice query) through the PreprocessEngine, then
trains a small PointNet2 classifier through a `PC2IMAccelerator` — ONE
(config, ExecutionPolicy) pair compiles the whole pipeline: preprocessing
engines AND the (optionally SC-quantized) feature path — and prints the
preprocessing-energy model numbers."""

import jax

from repro.configs.base import get_config
from repro.core import energy as E
from repro.core.accelerator import get_accelerator
from repro.core.engine import EngineConfig, PreprocessEngine
from repro.core.policy import ExecutionPolicy
from repro.data.pointclouds import sample_batch
from repro.optim import adamw_init, adamw_update

# --- 1. data + batched PC2IM preprocessing ----------------------------------
pts, cls, seg = sample_batch(jax.random.PRNGKey(0), batch=4, n_points=512)
engine = PreprocessEngine(EngineConfig(
    pipeline="pc2im", n_centroids=128, radius=0.3, nsample=16, depth=2))
res = engine(pts)  # all 4 clouds in one launch
print(f"sampled {res.centroid_idx.shape[0]}x{res.centroid_idx.shape[1]} centroids; "
      f"neighbour fill-rate {float(res.neighbors.mask.mean()):.2f}")

# --- 2. train a small PointNet2 through the accelerator ----------------------
# swap quant="sc_w16a16" to train under the paper's C4 SC-CIM feature path
accel = get_accelerator(get_config("pointnet2-cls", smoke=True),
                        ExecutionPolicy(quant="none"))
params = accel.init(jax.random.PRNGKey(1))
state = adamw_init(params)


@jax.jit
def step(params, state, pts, labels):
    (loss, aux), grads = jax.value_and_grad(accel.loss_fn, has_aux=True)(params, pts, labels)
    params, state, _ = adamw_update(grads, state, params, lr=2e-3)
    return params, state, aux


for i in range(20):
    pts, cls, _ = sample_batch(jax.random.PRNGKey(100 + i), 16, accel.config.n_points)
    params, state, aux = step(params, state, pts, cls)
    if i % 5 == 0:
        print(f"step {i}: loss={float(aux['loss']):.4f} acc={float(aux['accuracy']):.3f}")

# quantized inference from the SAME params: a second accelerator artifact
accel_q = get_accelerator(accel.config, ExecutionPolicy(quant="sc_w16a16"))
logits_q = accel_q.infer(params, pts)
print(f"SC W16A16 inference: logits {tuple(logits_q.shape)} via {accel_q!r}")

# --- 3. the paper's energy story --------------------------------------------
const, rep = E.calibrate_cim()
print(f"\npreprocessing energy (SemanticKITTI 16k): "
      f"-{rep['reduction_vs_baseline1']*100:.1f}% vs baseline-1 (paper: 97.9%), "
      f"-{rep['reduction_vs_baseline2']*100:.1f}% vs TiPU (paper: 73.4%)")
