"""PC2IM preprocessing anatomy: partition -> FPS -> lattice query, with the
Pallas kernels (interpret mode on CPU), the batched PreprocessEngine, and
the utilisation/energy story.

    PYTHONPATH=src python examples/preprocess_pipeline.py"""

import jax
import jax.numpy as jnp

from repro.core import energy as E
from repro.core import fps as F
from repro.core import partition as P
from repro.core.engine import EngineConfig, PreprocessEngine
from repro.core.preprocess import preprocess_pc2im
from repro.data.pointclouds import sample_batch
from repro.kernels import registry
from repro.kernels.fps.ops import fps_tiles
from repro.kernels.lattice.ops import lattice_query_fused

batch, _, _ = sample_batch(jax.random.PRNGKey(0), 4, 2048)
pts = batch[0]

# --- C2: median spatial partitioning vs fixed-grid tiles --------------------
msp = P.median_partition(pts, depth=3)
grid = P.grid_partition(pts, grid=2, capacity=512)
print(f"MSP   : {msp.n_tiles} tiles x {msp.tile_size} pts, utilisation {float(msp.utilization()):.2f}")
print(f"grid  : {grid.n_tiles} tiles x {grid.tile_size} cap, utilisation {float(grid.utilization()):.2f}"
      f"  <- the padding waste MSP removes (paper: +15%)")

# --- C1+C3: in-VMEM tiled L1 FPS (the APD-CIM/Ping-Pong-MAX kernel) ---------
tiled = jnp.take(pts, msp.tiles, axis=0)  # (8, 256, 3) zero padding
idx_kernel = fps_tiles(tiled, 64, metric="l1", backend="pallas", interpret=True)
idx_xla = fps_tiles(tiled, 64, metric="l1", backend="xla")
print(f"tiled FPS kernel == oracle: {bool((idx_kernel == idx_xla).all())}")

# --- C1: fused lattice query -------------------------------------------------
centroids = jnp.take(pts, jnp.take(msp.tiles[0], idx_kernel[0]), axis=0)
nbrs = lattice_query_fused(pts, centroids, radius=0.3, nsample=16,
                           backend="pallas", interpret=True)
print(f"lattice query: fill-rate {float(nbrs.mask.mean()):.2f} (L = 1.6R)")

# --- the batched PreprocessEngine (B clouds -> ONE kernel grid) --------------
engine = PreprocessEngine(EngineConfig(
    pipeline="pc2im", n_centroids=512, radius=0.3, nsample=16, depth=3))
res = engine(batch)  # (4, 2048, 3) -> centroid_idx (4, 512), neighbors (4, 512, 16)
per_cloud = preprocess_pc2im(batch[0], 512, 0.3, 16, depth=3)
print(f"engine: {batch.shape[0]} clouds x {res.centroid_idx.shape[1]} centroids in one "
      f"launch ({registry.names()} registered); "
      f"batched == per-cloud: {bool((res.centroid_idx[0] == per_cloud.centroid_idx).all())}")

# --- quality: L1 sampling vs exact L2 ----------------------------------------
i2 = F.fps(pts, 256, metric="l2")
i1 = F.fps(pts, 256, metric="l1")
print(f"coverage radius L1/L2: "
      f"{float(F.coverage_radius(pts, i1)/F.coverage_radius(pts, i2)):.3f} (paper: ~1, Fig 5a)")

# --- the memory-traffic ledger (Challenge I) ---------------------------------
w = E.WORKLOADS["semantickitti_16k"]
b2 = E.preproc_energy_baseline2(w)
print("\nTiPU-style tiled FPS energy split (paper: 41% points / 58% TDs):")
tot = b2["fps_point"] + b2["fps_td"]
print(f"  point reads {b2['fps_point']/tot*100:.0f}%  TD update {b2['fps_td']/tot*100:.0f}%")
