from repro.parallel.pipeline import pipeline_forward  # noqa: F401
