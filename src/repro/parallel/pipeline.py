"""GPipe-style pipeline parallelism over a mesh axis (optional alternative to
pure DP across pods, for deeper-than-HBM models).

shard_map over the 'stage' axis: each device group holds one contiguous
layer block; microbatches stream through with collective_permute between
stages.  Schedule: standard GPipe fill-drain over M microbatches and P
stages — M + P - 1 ticks; each tick every stage runs its block on its
current microbatch and permutes activations forward.

Numerics match the single-device stack exactly (test-asserted): only the
execution order changes.
"""

from __future__ import annotations

import queue as queue_mod
import threading
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def two_stage_schedule(
    stage_a: Callable,
    stage_b: Callable,
    items: Sequence,
    *,
    depth: int = 2,
) -> list:
    """GPipe's fill-drain schedule for two stages, expressed at the host level.

    A producer thread runs ``stage_a`` over ``items`` in order, feeding a
    bounded hand-off queue of ``depth`` slots (double buffering by default);
    the caller's thread drains it and runs ``stage_b``.  While item k sits in
    stage B, item k+1 is already inside stage A — with jax's asynchronous
    dispatch this overlaps the two stages' device work even on ONE device
    (neither thread calls ``block_until_ready``), and when the stage
    callables pin their computations to different devices it is true
    two-device pipeline parallelism, the software analogue of
    ``pipeline_forward``'s collective-permute schedule.

    Returns ``[stage_b(stage_a(item)) for item in items]`` in item order.
    The first exception from either stage propagates to the caller; the
    bounded queue caps live stage-A output at ``depth + 2`` items (``depth``
    queued, one being produced, one being consumed), so a long stream never
    accumulates unbounded intermediates.
    """
    items = list(items)
    if not items:
        return []
    handoff: queue_mod.Queue = queue_mod.Queue(maxsize=max(1, depth))
    stop = threading.Event()

    def produce():
        for i, item in enumerate(items):
            if stop.is_set():
                return
            try:
                out = stage_a(item)
            except Exception as e:  # noqa: BLE001 — relayed to the consumer
                handoff.put((i, None, e))
                return
            handoff.put((i, out, None))

    producer = threading.Thread(
        target=produce, name="two-stage-pipeline-a", daemon=True
    )
    producer.start()

    results: list = [None] * len(items)
    error: Exception | None = None
    for _ in range(len(items)):
        i, val, err = handoff.get()
        if err is not None:
            error = err
            break
        try:
            results[i] = stage_b(val)
        except Exception as e:  # noqa: BLE001 — drain the producer, then raise
            error = e
            break
    if error is not None:
        stop.set()
        while producer.is_alive():  # unblock a producer stuck on a full queue
            try:
                handoff.get(timeout=0.01)
            except queue_mod.Empty:
                pass
        producer.join()
        raise error
    producer.join()
    return results


def pipeline_forward(
    mesh: Mesh,
    axis: str,
    stage_fn: Callable,  # (stage_params, x, stage_idx) -> x
    params_stacked,  # pytree with leading dim = n_stages
    x: jax.Array,  # (n_micro, mb, ...) microbatched input
):
    """Run x through n_stages sequential blocks laid out on `axis`.

    params_stacked leaves: (n_stages, ...) — stage s's slice lives on its
    own shard.  x: (n_micro, mb, D...) replicated; output identical layout.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]

    def per_stage(params_local, x_all):
        # params_local: (1, ...) this stage's block; x_all: (n_micro, mb, ...)
        stage = jax.lax.axis_index(axis)
        params_here = jax.tree.map(lambda a: a[0], params_local)
        ticks = n_micro + n_stages - 1

        buf = jnp.zeros_like(x_all[0])  # current activation holding slot
        outs = jnp.zeros_like(x_all)

        def tick(carry, t):
            buf, outs = carry
            micro_idx = t - stage  # which microbatch this stage sees at tick t
            # stage 0 ingests fresh microbatches while available
            fresh = jax.lax.dynamic_index_in_dim(
                x_all, jnp.clip(t, 0, n_micro - 1), axis=0, keepdims=False
            )
            inp = jnp.where(stage == 0, fresh, buf)
            active = (micro_idx >= 0) & (micro_idx < n_micro)
            y = stage_fn(params_here, inp, stage)
            y = jnp.where(active, y, inp)
            # last stage writes its completed microbatch
            outs = jax.lax.cond(
                active & (stage == n_stages - 1),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(micro_idx, 0, n_micro - 1), axis=0
                ),
                lambda o: o,
                outs,
            )
            # permute activations forward one stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # only the last stage filled `outs` (zeros elsewhere): psum collects it
        outs = jax.lax.psum(outs, axis)
        return outs

    pspec_params = jax.tree.map(lambda _: P(axis), params_stacked)
    fn = shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(pspec_params, P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(params_stacked, x)
