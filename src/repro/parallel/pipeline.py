"""GPipe-style pipeline parallelism over a mesh axis (optional alternative to
pure DP across pods, for deeper-than-HBM models).

shard_map over the 'stage' axis: each device group holds one contiguous
layer block; microbatches stream through with collective_permute between
stages.  Schedule: standard GPipe fill-drain over M microbatches and P
stages — M + P - 1 ticks; each tick every stage runs its block on its
current microbatch and permutes activations forward.

Numerics match the single-device stack exactly (test-asserted): only the
execution order changes.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(
    mesh: Mesh,
    axis: str,
    stage_fn: Callable,  # (stage_params, x, stage_idx) -> x
    params_stacked,  # pytree with leading dim = n_stages
    x: jax.Array,  # (n_micro, mb, ...) microbatched input
):
    """Run x through n_stages sequential blocks laid out on `axis`.

    params_stacked leaves: (n_stages, ...) — stage s's slice lives on its
    own shard.  x: (n_micro, mb, D...) replicated; output identical layout.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]

    def per_stage(params_local, x_all):
        # params_local: (1, ...) this stage's block; x_all: (n_micro, mb, ...)
        stage = jax.lax.axis_index(axis)
        params_here = jax.tree.map(lambda a: a[0], params_local)
        ticks = n_micro + n_stages - 1

        buf = jnp.zeros_like(x_all[0])  # current activation holding slot
        outs = jnp.zeros_like(x_all)

        def tick(carry, t):
            buf, outs = carry
            micro_idx = t - stage  # which microbatch this stage sees at tick t
            # stage 0 ingests fresh microbatches while available
            fresh = jax.lax.dynamic_index_in_dim(
                x_all, jnp.clip(t, 0, n_micro - 1), axis=0, keepdims=False
            )
            inp = jnp.where(stage == 0, fresh, buf)
            active = (micro_idx >= 0) & (micro_idx < n_micro)
            y = stage_fn(params_here, inp, stage)
            y = jnp.where(active, y, inp)
            # last stage writes its completed microbatch
            outs = jax.lax.cond(
                active & (stage == n_stages - 1),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(micro_idx, 0, n_micro - 1), axis=0
                ),
                lambda o: o,
                outs,
            )
            # permute activations forward one stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # only the last stage filled `outs` (zeros elsewhere): psum collects it
        outs = jax.lax.psum(outs, axis)
        return outs

    pspec_params = jax.tree.map(lambda _: P(axis), params_stacked)
    fn = shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(pspec_params, P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(params_stacked, x)
