"""PointNet2 semantic segmentation (S3DIS/SemanticKITTI-style, the paper's (s) model)."""

from repro.models.pointnet2 import PointNet2Config, SAConfig

CONFIG = PointNet2Config(
    name="pointnet2-seg",
    task="seg",
    n_points=4096,
    n_classes=8,
    sa=(
        SAConfig(1024, 0.2, 32, (64, 64, 128)),
        SAConfig(256, 0.4, 32, (128, 128, 256)),
    ),
    fp_mlp=(256, 128),
    head=(128,),
    preproc="pc2im",
    aggregation="delayed",
    msp_depth=3,
)


def smoke_config() -> PointNet2Config:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        n_points=256,
        sa=(SAConfig(64, 0.3, 16, (32, 32, 64)), SAConfig(16, 0.6, 16, (64, 64, 128))),
        fp_mlp=(64, 64),
        head=(64,),
        msp_depth=2,
    )
