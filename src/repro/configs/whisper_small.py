"""whisper-small [audio enc-dec] — arXiv:2212.04356 (unverified tier).

12L encoder + 12L decoder, d_model=768 12H (MHA kv=12) d_ff=3072
vocab=51865.  The conv audio frontend is a STUB per the assignment:
input_specs provide precomputed frame embeddings (B, S, d_model).
Absolute sinusoidal positions (rope disabled), dense GELU MLPs with bias.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    rope_theta=0.0,  # absolute positions
    act="gelu",
    mlp_kind="dense",
    use_bias=True,
    norm_kind="ln",
    tie_embeddings=True,
    loss_chunk=2048,
    source="arXiv:2212.04356; hf:openai/whisper-small",
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, encoder_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=256, dtype_str="float32",
        attn_block=16, loss_chunk=32,
    )
