"""PointNet2 classification (the paper's own model, ModelNet-style 1k points)."""

from repro.models.pointnet2 import PointNet2Config, SAConfig

CONFIG = PointNet2Config(
    name="pointnet2-cls",
    task="cls",
    n_points=1024,
    n_classes=8,
    sa=(
        SAConfig(256, 0.2, 32, (64, 64, 128)),
        SAConfig(64, 0.4, 32, (128, 128, 256)),
    ),
    global_mlp=(256, 512, 1024),
    head=(512, 256),
    preproc="pc2im",
    aggregation="delayed",
    msp_depth=2,
)


def smoke_config() -> PointNet2Config:
    import dataclasses

    return dataclasses.replace(
        CONFIG,
        n_points=256,
        sa=(SAConfig(64, 0.3, 16, (32, 32, 64)), SAConfig(16, 0.6, 16, (64, 64, 128))),
        global_mlp=(128, 256),
        head=(128,),
        msp_depth=2,
    )
