"""stablelm-1.6b [dense] — hf:stabilityai/stablelm-2-1_6b (unverified tier).

24L d_model=2048 32H (GQA kv=32, i.e. MHA) d_ff=5632 vocab=100352.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    rope_theta=10000.0,
    act="silu",
    mlp_kind="glu",
    use_bias=False,
    loss_chunk=1024,
    source="hf:stabilityai/stablelm-2-1_6b",
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=176,
        vocab_size=256, dtype_str="float32", attn_block=16, loss_chunk=32,
    )
