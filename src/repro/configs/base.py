"""Unified model configuration + architecture registry (--arch <id>)."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Callable

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads
    # attention pattern: one entry per layer-in-group, cycled over the stack.
    # "global" = full causal; "local" = sliding window; "recurrent" = RG-LRU.
    layer_pattern: tuple[str, ...] = ("global",)
    window: int = 0  # sliding-window size for "local" layers
    rope_theta: float = 10000.0
    use_bias: bool = False
    act: str = "silu"
    mlp_kind: str = "glu"  # glu | dense
    norm_kind: str = "rms"  # rms | ln
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # RG-LRU (hybrid)
    lru_width: int = 0
    # encoder (whisper) / frontend stub (vlm, whisper)
    encoder_layers: int = 0
    n_patches: int = 256  # vlm: image-patch positions in the sequence
    # numerics / execution
    dtype_str: str = "bfloat16"
    attn_block: int = 512
    loss_chunk: int = 2048  # seq-chunked vocab-parallel cross entropy
    quant: str = "none"  # none | sc_w16a16 (C4 hook)
    kv_quant: str = "none"  # none | int8 (C1 bit-shrink applied to KV caches)
    remat: str = "full"  # none | block (save dots) | full (save boundaries only)
    # provenance
    source: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def dtype(self):
        return jnp.dtype(self.dtype_str)

    def pattern_for_layers(self) -> list[str]:
        pat = list(self.layer_pattern)
        return [pat[i % len(pat)] for i in range(self.n_layers)]

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, dh = self.d_model, self.head_dim
        emb = self.vocab_size * d
        per_layer = 0.0
        counts = {"global": 0, "local": 0, "recurrent": 0}
        for t in self.pattern_for_layers():
            counts[t] += 1
        attn = (self.n_heads * dh + 2 * self.n_kv_heads * dh + self.n_heads * dh) * d
        if self.family == "moe":
            mlp = 3 * d * self.d_ff * self.n_experts + d * self.n_experts
        elif self.mlp_kind == "glu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        if self.family == "ssm":
            din = self.ssm_expand * d
            heads = din // self.ssm_headdim
            ssm = d * (2 * din + 2 * self.ssm_state + heads) + din * d
            per_layer = ssm + mlp if self.d_ff else ssm
            total = emb + self.n_layers * per_layer
        elif self.family == "hybrid":
            w = self.lru_width or d
            rec = d * w * 3 + 2 * w  # in/gate/out projections + lru params
            total = emb + counts["recurrent"] * (rec + mlp) + (
                counts["global"] + counts["local"]
            ) * (attn + mlp)
        else:
            total = emb + self.n_layers * (attn + mlp)
        if self.encoder_layers:
            # encoder blocks + the decoder's cross-attention projections
            total += self.encoder_layers * (attn + mlp) + self.n_layers * attn
        if not self.tie_embeddings:
            total += self.vocab_size * d
        return int(total)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = [
    "stablelm-1.6b",
    "gemma3-12b",
    "command-r-plus-104b",
    "starcoder2-3b",
    "dbrx-132b",
    "granite-moe-3b-a800m",
    "mamba2-1.3b",
    "recurrentgemma-2b",
    "whisper-small",
    "internvl2-2b",
    "pointnet2-cls",
    "pointnet2-seg",
]

ARCH_REGISTRY: dict[str, Callable[[], object]] = {}


def register(name: str):
    def deco(fn):
        ARCH_REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str, *, smoke: bool = False):
    """Load `CONFIG` (or `smoke_config()`) from repro.configs.<module>."""
    mod_name = name.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.smoke_config() if smoke else mod.CONFIG
