"""granite-moe-3b-a800m [moe] — hf:ibm-granite/granite-3.0-3b-a800m-base (hf tier).

32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40 experts top-8
(fine-grained experts; the inline assignment spec takes precedence over the
bracketed 32e description).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    n_experts=40,
    top_k=8,
    capacity_factor=1.25,
    rope_theta=10000.0,
    act="silu",
    mlp_kind="glu",
    use_bias=False,
    tie_embeddings=True,
    loss_chunk=2048,
    source="hf:ibm-granite/granite-3.0-3b-a800m-base",
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
        vocab_size=256, n_experts=8, top_k=2, dtype_str="float32",
        attn_block=16, loss_chunk=32,
    )
