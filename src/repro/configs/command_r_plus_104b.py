"""command-r-plus-104b [dense] — hf:CohereForAI/c4ai-command-r-plus (unverified).

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000 — GQA, no-bias,
tied embeddings (Cohere convention).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    rope_theta=75_000_000.0,
    act="silu",
    mlp_kind="glu",
    use_bias=False,
    tie_embeddings=True,
    loss_chunk=512,
    source="hf:CohereForAI/c4ai-command-r-plus",
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=96, n_heads=12, n_kv_heads=2, d_ff=256,
        vocab_size=256, dtype_str="float32", attn_block=16, loss_chunk=32,
    )
