"""mamba2-1.3b [ssm] — arXiv:2405.21060 (SSD / state-space duality).

48L d_model=2048 (attention-free) d_ff=0 vocab=50280, ssm_state=128,
headdim 64, expand 2 (d_inner 4096 -> 64 heads), conv width 4.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,  # attention-free; SSD heads derive from d_inner/headdim
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
    loss_chunk=2048,
    source="arXiv:2405.21060; hf:state-spaces/mamba2-1.3b",
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, ssm_state=16, ssm_headdim=16,
        ssm_chunk=8, vocab_size=256, dtype_str="float32", loss_chunk=32,
    )
