from repro.configs.base import ARCH_REGISTRY, ModelConfig, get_config, register  # noqa: F401
