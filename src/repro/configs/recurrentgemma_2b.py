"""recurrentgemma-2b [hybrid] — arXiv:2402.19427 (Griffin), hf tier.

26L d_model=2560 10H (GQA kv=1, MQA) d_ff=7680 vocab=256000 — RG-LRU +
local attention in a (recurrent, recurrent, local) 1:2 pattern, window 2048,
lru_width 2560, head_dim 256, tied embeddings.  26 = 8 full groups + 2
remainder recurrent layers.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_head=256,
    d_ff=7680,
    vocab_size=256000,
    layer_pattern=("recurrent", "recurrent", "local"),
    window=2048,
    lru_width=2560,
    rope_theta=10000.0,
    act="gelu",
    mlp_kind="glu",
    tie_embeddings=True,
    use_bias=False,
    loss_chunk=512,
    source="arXiv:2402.19427; hf:google/recurrentgemma-2b",
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, d_head=16,
        d_ff=128, lru_width=64, window=8, vocab_size=256,
        dtype_str="float32", attn_block=16, loss_chunk=32,
    )
