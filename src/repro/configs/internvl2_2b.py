"""internvl2-2b [vlm] — arXiv:2404.16821 (hf tier).

LM backbone (InternLM2-1.8B): 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553.  The InternViT frontend is a STUB per the assignment:
input_specs provide precomputed patch embeddings (B, n_patches, d_model);
a learned connector projection stands in for the mlp1 bridge.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    n_patches=256,
    rope_theta=1_000_000.0,
    act="silu",
    mlp_kind="glu",
    use_bias=False,
    loss_chunk=1024,
    source="arXiv:2404.16821; hf:OpenGVLab/InternVL2-2B",
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, n_patches=8, dtype_str="float32",
        attn_block=16, loss_chunk=32,
    )
