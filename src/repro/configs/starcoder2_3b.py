"""starcoder2-3b [dense] — arXiv:2402.19173 + hf:bigcode/starcoder2-3b.

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152 — GQA, RoPE,
dense GELU MLP with bias (starcoder2 convention).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    rope_theta=100_000.0,
    act="gelu",
    mlp_kind="dense",
    use_bias=True,
    norm_kind="ln",
    loss_chunk=2048,
    source="arXiv:2402.19173; hf:bigcode/starcoder2-3b",
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_ff=256,
        vocab_size=256, dtype_str="float32", attn_block=16, loss_chunk=32,
    )
