"""gemma3-12b [dense] — hf:google/gemma-3-* (unverified tier).

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144 — 5:1 local:global
sliding-window pattern (window 1024), 128k context, head_dim 256, tied
embeddings (gemma family convention).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_head=256,
    d_ff=15360,
    vocab_size=262144,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    rope_theta=1_000_000.0,
    act="gelu",
    mlp_kind="glu",
    tie_embeddings=True,
    use_bias=False,
    loss_chunk=512,
    source="hf:google/gemma-3-12b-pt",
)


def smoke_config() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256, window=8, dtype_str="float32",
        attn_block=16, loss_chunk=32,
    )
