"""Train step factory: loss -> grads -> AdamW, with optional gradient
accumulation (microbatching) and int8 cross-pod gradient compression."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import ExecutionPolicy, resolve_policy
from repro.models.families import get_family_api
from repro.optim.adamw import adamw_update
from repro.optim.schedule import cosine_warmup_schedule


def make_train_step(
    cfg: ModelConfig,
    *,
    peak_lr: float = 3e-4,
    warmup_steps: int = 100,
    total_steps: int = 10_000,
    weight_decay: float = 0.1,
    microbatch: int | None = None,
    b1: float = 0.9,
    b2: float = 0.95,
    policy: ExecutionPolicy | None = None,
):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    microbatch: split the batch into `microbatch` sequential chunks and
    accumulate grads (memory/throughput knob for §Perf).
    policy: ExecutionPolicy pinning quant mode / kernel backend for the whole
    step (None -> the config's default)."""
    api = get_family_api(cfg)
    policy = resolve_policy(cfg, policy)

    def loss_fn(params, batch):
        return api["train_loss"](params, cfg, batch, policy=policy)

    def compute_grads(params, batch):
        if microbatch is None or microbatch <= 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        b = jax.tree.leaves(batch)[0].shape[0]
        assert b % microbatch == 0
        mb = b // microbatch
        split = jax.tree.map(lambda x: x.reshape((microbatch, mb) + x.shape[1:]), batch)

        def body(carry, micro):
            loss_acc, grads_acc = carry
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, micro)
            grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
            return (loss_acc + loss, grads_acc), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), _ = jax.lax.scan(body, (jnp.float32(0), zeros), split)
        loss = loss_sum / microbatch
        grads = jax.tree.map(lambda g: g / microbatch, grads)
        return loss, {"loss": loss}, grads

    def train_step(params, opt_state, batch):
        loss, metrics, grads = compute_grads(params, batch)
        lr = cosine_warmup_schedule(
            opt_state.step, peak_lr=peak_lr, warmup_steps=warmup_steps, total_steps=total_steps
        )
        params, opt_state, om = adamw_update(
            grads, opt_state, params, lr=lr, b1=b1, b2=b2, weight_decay=weight_decay
        )
        metrics = dict(metrics)
        metrics.update(om)
        metrics["lr"] = lr
        return params, opt_state, metrics

    return train_step
