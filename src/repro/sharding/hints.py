"""Activation-sharding hints (Megatron-style sequence parallelism).

Models are mesh-agnostic; the launcher activates a hint context and the
model calls `hint_residual(h)` at block boundaries.  Inside the context,
residual-stream activations (B, S, D) are constrained to
P(data_axes, 'model', None): the sequence dim shards over the TP axis
between blocks, which divides saved-for-backward activation memory by the
TP degree (the difference between 205 GB and ~13 GB per device for the
104B train cell).  GSPMD inserts the matching all-gather/reduce-scatter
pairs at attention/MLP boundaries — same collective volume as plain TP
all-reduces, lower live memory.

Without an active context every hint is a no-op, so smoke tests and
single-device examples run untouched.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

# The one mesh axis a serving replica's device group is laid out over
# (launch.mesh.make_replica_mesh).  Model code never names the axis
# directly: `nn.linear` asks `replica_axis_active()` and the accelerator's
# sharded artifacts map over it — keeping the axis name a single shared
# constant is what lets the ExecutionPolicy.sharding knob stay inert under
# plain jit (the axis is simply unbound there).
REPLICA_AXIS = "shard"


def replica_axis_active() -> bool:
    """True iff tracing inside a computation mapped over REPLICA_AXIS.

    Inside `shard_map(..., mesh=make_replica_mesh(devs))` the axis is bound
    and policy-driven sharded code paths activate; under plain jit (or
    eager) the axis is unbound and every sharding knob is a no-op, so one
    policy object is safe to thread through both worlds.
    """
    try:
        jax.core.axis_frame(REPLICA_AXIS)
        return True
    except NameError:
        return False


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, *, mode: str = "sp"):
    """mode: 'sp' (Megatron sequence parallel: batch->data, seq->model) |
    'fsdp2d' (batch over BOTH axes, weights gathered per layer: no
    activation collectives at all) | 'off'."""
    axes = tuple(mesh.axis_names)
    daxes = ("pod", "data") if "pod" in axes else ("data",)
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = {"mesh": mesh, "daxes": daxes, "mode": mode}
    try:
        yield
    finally:
        _STATE.ctx = prev


def _ctx():
    return getattr(_STATE, "ctx", None)


def hint_residual(x: jax.Array) -> jax.Array:
    """(B, S, D) residual-stream constraint per the active mode."""
    c = _ctx()
    if c is None or c["mode"] == "off" or x.ndim != 3:
        return x
    mesh = c["mesh"]
    b, s, _ = x.shape
    daxes = c["daxes"]
    dtotal = 1
    for a in daxes:
        dtotal *= mesh.shape[a]
    msize = mesh.shape["model"]
    if c["mode"] == "fsdp2d":
        all_axes = daxes + ("model",)
        if b % (dtotal * msize) == 0:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(all_axes, None, None))
            )
        # batch too small for 2D: fall through to SP
    bspec = daxes if b % dtotal == 0 else None
    sspec = "model" if (s % msize == 0 and s >= msize) else None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(bspec, sspec, None))
    )


def hint_batch_only(x: jax.Array) -> jax.Array:
    """Constrain only the leading batch dim (decode-path activations)."""
    c = _ctx()
    if c is None or x.ndim < 1:
        return x
    mesh = c["mesh"]
    daxes = c["daxes"]
    dtotal = 1
    for a in daxes:
        dtotal *= mesh.shape[a]
    if x.shape[0] % dtotal != 0:
        return x
    spec = [None] * x.ndim
    spec[0] = daxes
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
