"""Partitioning policy: FSDP('data') x TP('model') x DP('pod').

Correctness note: under GSPMD *any* PartitionSpec compiles to a correct
program — the policy controls only where collectives appear and how much
memory each device holds.  That makes the policy a legitimate perf knob for
§Perf iterations: the default below is the tuned baseline; alternatives
(pure-DP, no-FSDP, 2D-serve) are selectable for comparison.

Default rules (train):
  * 2D+ weight leaf: the most-shardable "output-ish" dim -> 'model' (TP),
    a second divisible dim -> 'data' (FSDP/ZeRO-3; per-layer all-gathers
    happen inside the scan and overlap with compute).
  * Stacked leading scan dims ((n_groups, ...) / (L, ...) / (E, ...)):
    expert dims shard over 'model' (expert parallelism); plain layer-stack
    dims stay unsharded (slicing them per scan step must stay local).
  * 1D leaves (norm gains, biases): replicated.
  * 'pod' axis: pure DP — params replicated across pods, batch sharded;
    the only cross-pod traffic is the gradient all-reduce.

Serve: params shard 2D ('model' x 'data') the same way (weight-gathered
serving); KV caches shard batch->'data' (or seq->'data' when batch==1) and
kv-heads->'model' when divisible, else seq->'model' (flash-decoding-style
partial-softmax combine is left to GSPMD's reduction handling).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    name: str = "fsdp_tp"  # fsdp_tp | tp_only | dp_only
    fsdp: bool = True  # shard a second weight dim over 'data'
    expert_axis: str = "model"
    # batch sharding axes (pod first when present)
    data_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"

    def with_mesh(self, mesh: Mesh) -> "ShardingPolicy":
        axes = tuple(mesh.axis_names)
        data_axes = ("pod", "data") if "pod" in axes else ("data",)
        return dataclasses.replace(self, data_axes=data_axes)


POLICIES = {
    "fsdp_tp": ShardingPolicy("fsdp_tp", fsdp=True),
    "fsdp2d": ShardingPolicy("fsdp2d", fsdp=True),  # batch over both axes, weights gathered
    "tp_only": ShardingPolicy("tp_only", fsdp=False),
    "dp_only": ShardingPolicy("dp_only", fsdp=False),
}


# path keywords that mark a leading STACKED dim (scan over groups/layers)
_STACKED_KEYS = ("blocks", "enc_blocks", "dec_blocks", "rem")
# leaf-name hints: first dim is an expert dim
_EXPERT_KEYS = ("wi", "wg", "wo")


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def _spec_for_weight(
    path: str, shape: tuple[int, ...], mesh: Mesh, pol: ShardingPolicy, cfg: ModelConfig | None
):
    """Choose PartitionSpec for one parameter leaf."""
    if pol.name == "dp_only" or len(shape) < 1:
        return P()
    ndim = len(shape)
    spec: list[Any] = [None] * ndim
    msize = _axis_size(mesh, pol.model_axis)
    dsize = _axis_size(mesh, "data")

    start = 0
    stacked = any(f"{k}" in path for k in _STACKED_KEYS)
    is_expert = (
        cfg is not None
        and cfg.n_experts > 0
        and re.search(r"mlp/(wi|wg|wo)$", path) is not None
        and ndim == 3
    )
    if is_expert:
        # (E, d, f): experts over 'model' (pads if not divisible), fsdp on dim1
        spec[0] = pol.model_axis
        if pol.fsdp and shape[1] % dsize == 0:
            spec[1] = "data"
        return P(*spec)
    if stacked and ndim >= 3:
        start = 1  # leading scan dim stays local
    dims = list(range(start, ndim))
    if len(dims) < 2:
        # 1D (norm/bias) or single free dim: replicate
        return P(*spec)

    # pick TP dim: prefer the LAST dim if divisible, else the largest divisible
    def divisible(i, size):
        return shape[i] % size == 0 and shape[i] >= size

    tp_dim = None
    for i in reversed(dims):
        if divisible(i, msize):
            tp_dim = i
            break
    if tp_dim is None:
        tp_dim = max(dims, key=lambda i: shape[i])  # pad-shard the largest
    spec[tp_dim] = pol.model_axis

    if pol.fsdp:
        for i in dims:
            if i != tp_dim and divisible(i, dsize):
                spec[i] = "data"
                break
    return P(*spec)


def param_pspecs(params_shape: Any, mesh: Mesh, pol: ShardingPolicy, cfg=None):
    """Map a pytree of ShapeDtypeStructs/arrays -> pytree of PartitionSpec."""
    pol = pol.with_mesh(mesh)

    def fn(path, leaf):
        return _spec_for_weight(_path_str(path), tuple(leaf.shape), mesh, pol, cfg)

    return jax.tree_util.tree_map_with_path(fn, params_shape)


def state_pspecs(opt_state_shape: Any, param_specs: Any, mesh: Mesh):
    """Optimizer state mirrors the param sharding (ZeRO-style: moments and
    master weights inherit the FSDP/TP layout); the step scalar replicates."""
    from repro.optim.adamw import AdamWState

    master = param_specs if opt_state_shape.master is not None else None
    return AdamWState(step=P(), mu=param_specs, nu=param_specs, master=master)


def batch_pspecs(cfg: ModelConfig, batch_shape: Any, mesh: Mesh, pol: ShardingPolicy):
    """Batch dict: batch dim over (pod, data); seq/feature dims local."""
    pol = pol.with_mesh(mesh)
    daxes = pol.data_axes

    def fn(path, leaf):
        if leaf.ndim == 0:
            return P()
        b = leaf.shape[0]
        total = 1
        for a in daxes:
            total *= _axis_size(mesh, a)
        if pol.name == "fsdp2d":
            both = total * _axis_size(mesh, pol.model_axis)
            if b % both == 0:
                return P(daxes + (pol.model_axis,))
        if b % total == 0:
            return P(daxes) if leaf.ndim >= 1 else P()
        return P()  # unshardable batch (e.g. batch=1 long-context)

    return jax.tree_util.tree_map_with_path(fn, batch_shape)


def decode_state_pspecs(cfg: ModelConfig, state_shape: Any, mesh: Mesh, pol: ShardingPolicy):
    """KV caches / recurrent states.

    Stacked KV leaves are (L, B, S, Hkv, Dh): batch over (pod,data) when
    divisible else seq over 'data'; kv-heads over 'model' when divisible
    else seq over 'model' (sequence-sharded decode)."""
    pol = pol.with_mesh(mesh)
    daxes = pol.data_axes
    dtotal = 1
    for a in daxes:
        dtotal *= _axis_size(mesh, a)
    msize = _axis_size(mesh, pol.model_axis)

    def fn(path, leaf):
        if leaf.ndim == 0:
            return P()
        shape = leaf.shape
        p = _path_str(path)
        spec: list[Any] = [None] * leaf.ndim
        if leaf.ndim >= 4:  # (L, B, S, H, D) or (B, S, H, D) or ssm (L,B,H,P,N)
            off = 1 if leaf.ndim == 5 else 0
            bdim, sdim, hdim = off, off + 1, off + 2
            if "state" in p and leaf.ndim == 5:  # ssm state (L,B,H,P,N)
                if shape[1] % dtotal == 0:
                    spec[1] = daxes
                if shape[2] % msize == 0:
                    spec[2] = pol.model_axis
                return P(*spec)
            if shape[bdim] % dtotal == 0:
                spec[bdim] = daxes
            elif shape[sdim] % _axis_size(mesh, "data") == 0:
                spec[sdim] = "data"
            if shape[hdim] % msize == 0:
                spec[hdim] = pol.model_axis
            elif spec[sdim] is None and shape[sdim] % msize == 0:
                spec[sdim] = pol.model_axis
            return P(*spec)
        if leaf.ndim >= 2:
            # recurrent/conv states (L,B,W) / (B,W) etc: batch over data, width over model
            bdim = 1 if leaf.ndim >= 3 and "rem" not in p else 0
            # find a batch-sized dim heuristically: first dim divisible by dtotal
            for i in range(leaf.ndim - 1):
                if shape[i] % dtotal == 0:
                    spec[i] = daxes
                    break
            if shape[-1] % msize == 0:
                spec[-1] = pol.model_axis
            return P(*spec)
        return P()

    return jax.tree_util.tree_map_with_path(fn, state_shape)


def to_shardings(spec_tree: Any, mesh: Mesh):
    """PartitionSpec leaves -> NamedShardings (idempotent on Shardings)."""
    return jax.tree.map(
        lambda s: s if isinstance(s, NamedSharding) else NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, (P, NamedSharding)),
    )


# -- PC2IM serving: one replica spanning a device group ----------------------

REPLICA_SHARDING_MODES = ("batch", "tensor")


def replica_specs(mode: str) -> tuple[P, P, P]:
    """(params, points, logits) PartitionSpecs for one mesh-sharded replica.

    Resolution for `ExecutionPolicy.sharding` over the 1-D replica mesh
    (launch.mesh.make_replica_mesh, axis hints.REPLICA_AXIS):

      * params always replicate — each device of the group holds the full
        weight copy, exactly like a single-device replica pins one.
      * the points batch dim shards over the group in BOTH modes: "batch"
        keeps it sharded end to end (each device runs the full pipeline on
        its rows), while "tensor" preprocesses the local rows then
        all-gathers the neighborhoods so the feature MLPs can column-split
        each weight across the group and concatenate the partial products
        (the paper's split-concatenate dataflow) — the gather/slice happen
        INSIDE the mapped function, so the boundary spec is the same.
      * logits leave batch-sharded; jit reassembles the global batch.
    """
    if mode not in REPLICA_SHARDING_MODES:
        raise ValueError(
            f"sharding mode must be one of {REPLICA_SHARDING_MODES}, got {mode!r}"
        )
    from repro.sharding.hints import REPLICA_AXIS

    return P(), P(REPLICA_AXIS), P(REPLICA_AXIS)
