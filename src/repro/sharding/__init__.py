from repro.sharding.policy import (  # noqa: F401
    ShardingPolicy,
    batch_pspecs,
    param_pspecs,
    state_pspecs,
)
