"""Int8 gradient compression with error feedback (cross-pod all-reduce path).

At multi-pod scale the gradient all-reduce crosses the slow DCN link; int8
quantization cuts that traffic 4x (bf16->int8 halves, f32->int8 quarters).
Error feedback (residual carried between steps) keeps the quantization
noise unbiased-in-the-limit — SGD/Adam converge with the same schedule
(1-bit Adam / PowerSGD literature).

Usage inside a train step:
    cgrads, new_err = compress_grads(grads, err)        # int8 + scales
    # all-reduce / accumulate cgrads (int32-safe)
    grads = decompress_grads(cgrads)
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressedTree(NamedTuple):
    q: Any  # int8 pytree
    scale: Any  # f32 per-leaf scale


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, err_feedback) -> tuple[CompressedTree, Any]:
    """Quantize (g + err) to int8 per-leaf symmetric; return new residual."""

    def one(g, e):
        x = g.astype(jnp.float32) + e
        amax = jnp.max(jnp.abs(x))
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        new_e = x - q.astype(jnp.float32) * scale
        return q, scale, new_e

    flat, treedef = jax.tree_util.tree_flatten(grads)
    eflat = treedef.flatten_up_to(err_feedback)
    out = [one(g, e) for g, e in zip(flat, eflat)]
    q = treedef.unflatten([o[0] for o in out])
    s = treedef.unflatten([o[1] for o in out])
    new_err = treedef.unflatten([o[2] for o in out])
    return CompressedTree(q=q, scale=s), new_err


def decompress_grads(c: CompressedTree):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, c.q, c.scale)
