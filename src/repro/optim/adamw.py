"""AdamW with fp32 master weights + moments (no optax available: built native).

State layout keeps the ZeRO property for free: every state leaf mirrors the
parameter pytree, so whatever FSDP sharding the params carry applies to the
moments and master copy identically (optimizer-state sharding = ZeRO-1/2/3
depending on the param sharding policy).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # int32 scalar
    mu: Any  # first moment (fp32, param-shaped)
    nu: Any  # second moment (fp32)
    master: Any | None  # fp32 master params (None if params already fp32)


def adamw_init(params, *, keep_master: bool | None = None) -> AdamWState:
    def f32(p):
        return jnp.zeros(p.shape, jnp.float32)

    mu = jax.tree.map(f32, params)
    nu = jax.tree.map(f32, params)
    if keep_master is None:
        keep_master = any(p.dtype != jnp.float32 for p in jax.tree.leaves(params))
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params) if keep_master else None
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=mu, nu=nu, master=master)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr: float | jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    max_grad_norm: float | None = 1.0,
):
    """Returns (new_params, new_state, metrics)."""
    metrics = {}
    if max_grad_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        metrics["grad_norm"] = gnorm
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p, pm):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mhat = m / bc1
        vhat = v / bc2
        p32 = pm if pm is not None else p.astype(jnp.float32)
        p32 = p32 - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p32)
        return m, v, p32

    leaves_p, treedef = jax.tree.flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_m = treedef.flatten_up_to(state.mu)
    leaves_v = treedef.flatten_up_to(state.nu)
    leaves_pm = (
        treedef.flatten_up_to(state.master) if state.master is not None else [None] * len(leaves_p)
    )
    out = [upd(g, m, v, p, pm) for g, m, v, p, pm in zip(leaves_g, leaves_m, leaves_v, leaves_p, leaves_pm)]
    new_mu = treedef.unflatten([o[0] for o in out])
    new_nu = treedef.unflatten([o[1] for o in out])
    new_p32 = [o[2] for o in out]
    new_params = treedef.unflatten(
        [p32.astype(p.dtype) for p32, p in zip(new_p32, leaves_p)]
    )
    new_master = treedef.unflatten(new_p32) if state.master is not None else None
    return new_params, AdamWState(step, new_mu, new_nu, new_master), metrics
