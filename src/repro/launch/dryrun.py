import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init) — this file is the only place the 512-device trick is
applied; tests and benches see the single real CPU device.

Per cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds abstract params / optimizer state / batch (ShapeDtypeStructs),
  3. assigns shardings from repro.sharding.policy,
  4. jit(...).lower(...).compile() — proving the distribution config is
     coherent (sharding mismatches / unsupported collectives fail here),
  5. records memory_analysis(), cost_analysis(), and the collective-byte
     census parsed from the optimized HLO, into a JSON for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k \
      --mesh single --out results/dryrun/gemma3_train4k_single.json
  python -m repro.launch.dryrun --all --mesh both --out-dir results/dryrun
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.configs.base import get_config
from repro.launch import shapes as SH
from repro.launch.mesh import make_production_mesh
from repro.models.families import get_family_api
from repro.sharding import policy as POL

LM_ARCHS = [
    "stablelm-1.6b",
    "gemma3-12b",
    "command-r-plus-104b",
    "starcoder2-3b",
    "dbrx-132b",
    "granite-moe-3b-a800m",
    "mamba2-1.3b",
    "recurrentgemma-2b",
    "whisper-small",
    "internvl2-2b",
]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([\d,]*)\]")


def _bytes_of(dt: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_census(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the optimized HLO.

    Counted once per op instance (HLO is SPMD: one program for all devices,
    so bytes are per-device).  Ops inside while/scan bodies appear once in
    the text; we scale by the enclosing trip count when derivable from the
    loop bound pattern — XLA names scan loops with known trip counts, but
    robustly extracting them is fragile, so we ALSO report the raw count;
    scan-carried collectives dominate in our models via the layer scan whose
    trip count we know from the config (applied by the caller)."""
    census = {c: {"count": 0, "operand_bytes": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w\.\-]+ = .*? (all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(-start)?\(", s)
        if not m:
            continue
        op = m.group(1)
        # operand shapes: everything inside the call parens
        call = s.split(m.group(1) + (m.group(2) or "") + "(", 1)[1]
        depth, i = 1, 0
        while i < len(call) and depth:
            if call[i] == "(":
                depth += 1
            elif call[i] == ")":
                depth -= 1
            i += 1
        operands = call[: i - 1]
        total = sum(_bytes_of(dt, dims) for dt, dims in _SHAPE_RE.findall(operands))
        census[op]["count"] += 1
        census[op]["operand_bytes"] += total
    return census


def while_trip_counts(hlo_text: str) -> list[int]:
    """Best-effort extraction of while-loop trip counts (scan bounds)."""
    # XLA annotates: while(...), condition=..., body=... ; trip count often in
    # backend_config or via constant comparison — fall back to scan lengths
    # reported by the caller.
    return [int(x) for x in re.findall(r'"known_trip_count":\{"n":"(\d+)"\}', hlo_text)]


def apply_overrides(cfg, overrides: dict):
    import dataclasses
    if not overrides:
        return cfg
    typed = {}
    for k, v in overrides.items():
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            typed[k] = v in ("1", "true", "True")
        elif isinstance(cur, int):
            typed[k] = int(v)
        elif isinstance(cur, float):
            typed[k] = float(v)
        else:
            typed[k] = v
    return dataclasses.replace(cfg, **typed)


def build_cell(arch: str, shape_name: str, mesh, policy_name: str = "fsdp_tp",
               overrides: dict | None = None, microbatch: int | None = None):
    """Returns (jitted_fn, abstract_args) for one cell."""
    cfg = apply_overrides(get_config(arch), overrides or {})
    api = get_family_api(cfg)
    pol = POL.POLICIES[policy_name].with_mesh(mesh)
    info = SH.SHAPES[shape_name]
    kind = info["kind"]

    params_shape = SH.abstract_params(cfg)
    pspecs = POL.to_shardings(POL.param_pspecs(params_shape, mesh, pol, cfg), mesh)

    if kind == "train":
        from repro.train.step import make_train_step

        opt_shape = jax.eval_shape(lambda: SH.adamw_init_from_shapes(params_shape))
        sspecs = POL.to_shardings(POL.state_pspecs(opt_shape, pspecs, mesh), mesh)
        batch_shape = SH.input_specs(cfg, shape_name)
        bspecs = POL.to_shardings(POL.batch_pspecs(cfg, batch_shape, mesh, pol), mesh)
        step = make_train_step(cfg, microbatch=microbatch)
        fn = jax.jit(
            step,
            in_shardings=(pspecs, sspecs, bspecs),
            out_shardings=(pspecs, sspecs, None),
            donate_argnums=(0, 1),  # alias params/opt-state in place
        )
        return fn, (params_shape, opt_shape, batch_shape), cfg

    if kind == "prefill":
        batch_shape = SH.input_specs(cfg, shape_name)
        bspecs = POL.to_shardings(POL.batch_pspecs(cfg, batch_shape, mesh, pol), mesh)
        s_max = info["seq"]

        def prefill_fn(params, batch):
            return api["prefill"](params, cfg, batch, s_max)

        fn = jax.jit(prefill_fn, in_shardings=(pspecs, bspecs))
        return fn, (params_shape, batch_shape), cfg

    # decode
    state_shape = SH.decode_state_specs(cfg, shape_name)
    stspecs = POL.to_shardings(POL.decode_state_pspecs(cfg, state_shape, mesh, pol), mesh)
    batch_shape = SH.input_specs(cfg, shape_name)
    bspecs = POL.to_shardings(POL.batch_pspecs(cfg, batch_shape, mesh, pol), mesh)

    def decode_fn(params, state, batch):
        return api["decode_step"](params, cfg, state, batch)

    fn = jax.jit(
        decode_fn,
        in_shardings=(pspecs, stspecs, bspecs),
        out_shardings=(None, stspecs),
        donate_argnums=(1,),  # alias the KV/recurrent state in place
    )
    return fn, (params_shape, state_shape, batch_shape), cfg


def run_cell(arch: str, shape_name: str, mesh_kind: str, policy_name: str = "fsdp_tp",
             overrides: dict | None = None, microbatch: int | None = None) -> dict:
    t0 = time.time()
    reason = SH.skip_reason(arch, shape_name)
    if reason:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "policy": policy_name,
        "n_devices": mesh.devices.size,
        "overrides": overrides or {}, "microbatch": microbatch,
    }
    try:
        from repro.sharding.hints import activation_sharding

        # SP hints measured WORSE here (§Perf A.iter4: resharding churn per block)
        hint_mode = "fsdp2d" if policy_name == "fsdp2d" else "off"
        with mesh, activation_sharding(mesh, mode=hint_mode):
            fn, args, cfg = build_cell(arch, shape_name, mesh, policy_name, overrides, microbatch)
            lowered = fn.lower(*args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()

            mem = compiled.memory_analysis()
            result["memory_analysis"] = _mem_dict(mem)
            cost = compiled.cost_analysis()
            if not cost:
                cost = lowered.cost_analysis() or {}
            result["cost_analysis"] = {
                k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float)) and k in ("flops", "bytes accessed", "transcendentals")
            }
            hlo = compiled.as_text()
            from repro.launch.hlo_analysis import analyze as hlo_analyze

            result["hlo_analysis"] = hlo_analyze(hlo)  # trip-count-correct
            result["collectives_raw"] = collective_census(hlo)  # body-once census
            result["while_trip_counts"] = while_trip_counts(hlo)
            result["hlo_bytes"] = len(hlo)
            result["model_flops"] = SH.model_flops(cfg, shape_name)
            result["param_count"] = cfg.param_count()
            result["lower_s"] = round(t_lower - t0, 2)
            result["compile_s"] = round(t_compile - t_lower, 2)
            result["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        result["status"] = "failed"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    return result


def _mem_dict(mem) -> dict:
    if mem is None:
        return {"available": False}
    out = {"available": True}
    for k in (
        "generated_code_size_in_bytes",
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "peak_memory_in_bytes",
    ):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SH.SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--policy", default="fsdp_tp", choices=list(POL.POLICIES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--set", action="append", default=[], help="cfg override key=value")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--tag", default=None, help="suffix for the output filename")
    args = ap.parse_args()
    overrides = dict(kv.split("=", 1) for kv in args.set)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch in LM_ARCHS:
            for shape in SH.SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    os.makedirs(args.out_dir, exist_ok=True)
    rc = 0
    for arch, shape in cells:
        for mk in meshes:
            res = run_cell(arch, shape, mk, args.policy, overrides, args.microbatch)
            suffix = f"__{args.tag}" if args.tag else ""
            out_path = args.out or os.path.join(
                args.out_dir, f"{arch}__{shape}__{mk}__{args.policy}{suffix}.json"
            )
            with open(out_path, "w") as f:
                json.dump(res, f, indent=1)
            status = res["status"]
            extra = res.get("error", "") if status == "failed" else (
                f"compile={res.get('compile_s')}s flops={res.get('cost_analysis', {}).get('flops', 0):.3g}"
                if status == "ok" else res.get("reason", "")
            )
            print(f"[{status:7s}] {arch} x {shape} x {mk}: {extra}", flush=True)
            if status == "failed":
                rc = 1
    sys.exit(rc)


if __name__ == "__main__":
    main()
