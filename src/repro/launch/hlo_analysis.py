"""Optimized-HLO text analyzer: FLOPs / HBM-bytes / collective-bytes with
while-loop trip-count rollup.

Why this exists: XLA's `compiled.cost_analysis()` visits every while body
ONCE — a scanned 24-layer transformer reports ~1/24th of its real FLOPs, and
collective bytes inside the layer scan are missed entirely.  Since all our
models scan over layers (by design, for compile speed), the §Roofline terms
must be reconstructed by walking the HLO call graph and scaling each while
body by its `known_trip_count`.

Cost model (mirrors HloCostAnalysis where it matters):
  dot         : 2 * prod(result_dims) * prod(lhs_contracting_sizes)
  reduce      : operand element count
  elementwise : result element count
  fusion      : inner FLOPs counted; BYTES counted only at the fusion
                boundary (operands + result = the op's memory traffic)
  while       : trip_count x (body + condition)
  conditional : max over branches
  collectives : operand bytes (resolved via the per-computation symbol
                table — operands are printed without types in optimized HLO)

Bytes semantics: every top-level op in a non-fused computation contributes
operand+result bytes (one read per operand, one write per result).  This is
the TPU HBM-traffic analogue at XLA's fusion granularity; exact register
reuse is not modelled (documented in EXPERIMENTS.md §Roofline).
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e4m3": 1,
    "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
)

_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "rng-bit-generator",
}


@dataclasses.dataclass
class Shape:
    dtype: str
    dims: tuple[int, ...]

    @property
    def elems(self) -> int:
        return math.prod(self.dims) if self.dims else 1

    @property
    def bytes(self) -> int:
        return self.elems * _DTYPE_BYTES.get(self.dtype, 4)


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    shapes: list[Shape]  # result shapes (tuple flattened)
    operands: list[str]
    attrs: str  # raw attribute tail

    @property
    def result_bytes(self) -> int:
        return sum(s.bytes for s in self.shapes)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: dict = dataclasses.field(
        default_factory=lambda: defaultdict(lambda: {"count": 0.0, "bytes": 0.0})
    )

    def add(self, other: "Cost", scale: float = 1.0):
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        for k, v in other.collectives.items():
            self.collectives[k]["count"] += v["count"] * scale
            self.collectives[k]["bytes"] += v["bytes"] * scale

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collectives": {k: dict(v) for k, v in self.collectives.items()},
            "collective_bytes_total": sum(v["bytes"] for v in self.collectives.values()),
        }


def _parse_shapes(text: str) -> list[Shape]:
    return [
        Shape(dt, tuple(int(x) for x in dims.split(",")) if dims else ())
        for dt, dims in _SHAPE_RE.findall(text)
    ]


def _balanced(s: str, open_idx: int) -> int:
    """Index just past the paren that closes s[open_idx] == '('."""
    depth = 0
    for i in range(open_idx, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


_INSTR_RE = re.compile(r"^(?:ROOT )?%([\w\.\-]+) = ")


def parse_module(text: str) -> tuple[dict[str, list[Instr]], str]:
    """Parse optimized HLO text -> ({computation: [Instr]}, entry_name)."""
    comps: dict[str, list[Instr]] = {}
    entry = None
    cur: list[Instr] | None = None
    cur_name = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("//"):
            continue
        # computation header: [ENTRY] %name (params) -> ret {
        if line.endswith("{") and ("(" in line) and " = " not in line:
            m = re.match(r"(ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
            if m:
                cur_name = m.group(2)
                comps[cur_name] = []
                cur = comps[cur_name]
                if m.group(1):
                    entry = cur_name
            continue
        if line == "}" or line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name = m.group(1)
        rest = line[m.end():]
        # result type: balanced tuple or single shape token
        if rest.startswith("("):
            end = _balanced(rest, 0)
            type_str, rest2 = rest[:end], rest[end:].lstrip()
        else:
            sp = rest.index(" ")
            type_str, rest2 = rest[:sp], rest[sp + 1:]
        om = re.match(r"([\w\-]+)\(", rest2)
        if not om:
            continue
        opcode = om.group(1)
        close = _balanced(rest2, om.end() - 1)
        operand_str = rest2[om.end(): close - 1]
        attrs = rest2[close:]
        operands = re.findall(r"%([\w\.\-]+)", operand_str)
        cur.append(Instr(name, opcode, _parse_shapes(type_str), operands, attrs))
    if entry is None:
        # fall back: the computation referenced by none (or the last one)
        entry = list(comps)[-1]
    return comps, entry


def _attr_comp(attrs: str, key: str) -> str | None:
    m = re.search(rf"{key}=%?([\w\.\-]+)", attrs)
    return m.group(1) if m else None


def _attr_comp_list(attrs: str, key: str) -> list[str]:
    m = re.search(rf"{key}=\{{([^}}]*)\}}", attrs)
    if not m:
        one = _attr_comp(attrs, key)
        return [one] if one else []
    return re.findall(r"%?([\w\.\-]+)", m.group(1))


def _trip_count(attrs: str) -> float:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', attrs)
    return float(m.group(1)) if m else 1.0


def _dot_flops(ins: Instr, symtab: dict[str, list[Shape]]) -> float:
    lhs_shapes = symtab.get(ins.operands[0]) if ins.operands else None
    result_elems = sum(s.elems for s in ins.shapes)
    if not lhs_shapes:
        return 2.0 * result_elems  # can't resolve: degrade gracefully
    lhs = lhs_shapes[0]
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    cdims = [int(x) for x in m.group(1).split(",")] if (m and m.group(1)) else []
    k = math.prod(lhs.dims[c] for c in cdims) if cdims else 1
    return 2.0 * result_elems * k


def analyze(text: str) -> dict:
    comps, entry = parse_module(text)

    # callers: computations reached via fusion stay "fused" (bytes suppressed)
    symtabs: dict[str, dict[str, list[Shape]]] = {
        c: {i.name: i.shapes for i in instrs} for c, instrs in comps.items()
    }

    memo: dict[tuple[str, bool], Cost] = {}

    def _fusion_boundary_bytes(ins: Instr, called: str, symtab) -> float:
        """Effective HBM traffic at a fusion boundary.

        XLA aliases dynamic-update-slice roots (in-place update) and
        dynamic-slice/gather parameter reads touch only the slice — charging
        full operand/result bytes would overstate flash-attention-style
        accumulators by ~the buffer/block ratio."""
        instrs = comps.get(called, [])
        by_name = {i.name: i for i in instrs}
        # consumers per instr name
        consumers: dict[str, list[Instr]] = defaultdict(list)
        for i in instrs:
            for o in i.operands:
                consumers[o].append(i)
        read = 0.0
        # parameter ops appear in index order in printed HLO; pair positionally
        param_instrs = [i for i in instrs if i.opcode == "parameter"]
        for idx, operand_name in enumerate(ins.operands):
            op_bytes = sum(s.bytes for s in symtab.get(operand_name, []))
            if idx < len(param_instrs):
                p = param_instrs[idx]
                cons = consumers.get(p.name, [])
                if cons and all(c.opcode in ("dynamic-slice", "gather") for c in cons):
                    op_bytes = min(
                        op_bytes, sum(sum(s.bytes for s in c.shapes) for c in cons)
                    )
            read += op_bytes
        root = instrs[-1] if instrs else None
        if root is not None and root.opcode == "dynamic-update-slice":
            upd = root.operands[1] if len(root.operands) > 1 else None
            write = sum(s.bytes for s in by_name[upd].shapes) if upd in by_name else (
                sum(s.bytes for s in root.shapes)
            )
            # in-place aliased root: charge the slice write (+ slice read-modify)
            write *= 2.0
        else:
            write = float(ins.result_bytes)
        return read + write

    def cost_of(cname: str, fused: bool) -> Cost:
        key = (cname, fused)
        if key in memo:
            return memo[key]
        total = Cost()
        memo[key] = total  # guard vs cycles (shouldn't happen)
        symtab = symtabs.get(cname, {})
        for ins in comps.get(cname, []):
            op = ins.opcode
            operand_bytes = sum(
                sum(s.bytes for s in symtab.get(o, [])) for o in ins.operands
            )
            if op in _FREE_OPS:
                continue
            if op == "while":
                body = _attr_comp(ins.attrs, "body")
                cond = _attr_comp(ins.attrs, "condition")
                trip = _trip_count(ins.attrs)
                if body:
                    total.add(cost_of(body, fused), trip)
                if cond:
                    total.add(cost_of(cond, fused), trip)
                continue
            if op == "conditional":
                branches = _attr_comp_list(ins.attrs, "branch_computations")
                if not branches:
                    branches = [b for b in (
                        _attr_comp(ins.attrs, "true_computation"),
                        _attr_comp(ins.attrs, "false_computation"),
                    ) if b]
                if branches:
                    worst = None
                    for b in branches:
                        c = cost_of(b, fused)
                        if worst is None or c.flops + c.bytes > worst.flops + worst.bytes:
                            worst = c
                    total.add(worst)
                continue
            if op == "fusion":
                called = _attr_comp(ins.attrs, "calls")
                if called:
                    inner = cost_of(called, True)
                    total.flops += inner.flops
                    for k, v in inner.collectives.items():
                        total.collectives[k]["count"] += v["count"]
                        total.collectives[k]["bytes"] += v["bytes"]
                if not fused:
                    if called:
                        total.bytes += _fusion_boundary_bytes(ins, called, symtab)
                    else:
                        total.bytes += operand_bytes + ins.result_bytes
                continue
            if op in ("call", "custom-call", "async-start"):
                called = _attr_comp(ins.attrs, "to_apply") or _attr_comp(ins.attrs, "calls")
                if called and called in comps:
                    total.add(cost_of(called, fused))
                if not fused:
                    total.bytes += operand_bytes + ins.result_bytes
                continue
            if any(op.startswith(c) for c in COLLECTIVE_OPS):
                base = next(c for c in COLLECTIVE_OPS if op.startswith(c))
                total.collectives[base]["count"] += 1
                total.collectives[base]["bytes"] += operand_bytes
                if not fused:
                    total.bytes += operand_bytes + ins.result_bytes
                continue
            if op == "dot":
                total.flops += _dot_flops(ins, symtab)
            elif op == "convolution":
                # not used by the LM stacks; approximate as result elems
                total.flops += 2.0 * sum(s.elems for s in ins.shapes)
            elif op in ("reduce", "reduce-window"):
                total.flops += float(operand_bytes) / 4.0  # ~operand elems
            else:
                total.flops += float(sum(s.elems for s in ins.shapes))
            if not fused:
                # aliased / slice-touching ops move only the slice, not the buffer
                if op == "dynamic-update-slice":
                    upd = (
                        sum(s.bytes for s in symtab.get(ins.operands[1], []))
                        if len(ins.operands) > 1
                        else ins.result_bytes
                    )
                    total.bytes += 2.0 * upd
                elif op in ("dynamic-slice", "gather"):
                    total.bytes += 2.0 * ins.result_bytes
                else:
                    total.bytes += operand_bytes + ins.result_bytes
        memo[key] = total
        return total

    return cost_of(entry, False).as_dict()
