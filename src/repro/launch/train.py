"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch pointnet2-cls --steps 200
    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Wires together: config registry, synthetic data streams (host-sharded,
restart-exact), AdamW + schedule, async checkpointing, straggler monitor,
restart supervision.  On a real cluster the same driver runs under
multi-host jax.distributed initialisation; here it exercises identical code
paths on the local device (or the host-platform mesh for dry-runs)."""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.base import get_config
from repro.core.policy import ExecutionPolicy, resolve_policy
from repro.data.tokens import Prefetcher, token_stream
from repro.optim import adamw_init
from repro.runtime import StragglerMonitor, run_with_restarts


def _policy_override(cfg, args) -> ExecutionPolicy:
    """Config default policy, with --quant applied on top when given."""
    policy = resolve_policy(cfg, None)
    if getattr(args, "quant", None):
        policy = dataclasses.replace(policy, quant=args.quant)
    return policy


def train_pointcloud(cfg, args):
    from repro.core.accelerator import get_accelerator
    from repro.data.pointclouds import sample_batch
    from repro.optim import adamw_update

    # one accelerator = preprocessing engines + policy-driven feature path
    # (quant/backend from the config; --quant overrides without a new config)
    accel = get_accelerator(cfg, _policy_override(cfg, args))
    params = accel.init(jax.random.PRNGKey(args.seed))
    state = adamw_init(params)

    @jax.jit
    def step_fn(params, state, pts, labels):
        (loss, aux), grads = jax.value_and_grad(accel.loss_fn, has_aux=True)(
            params, pts, labels
        )
        params, state, m = adamw_update(
            grads, state, params, lr=args.lr, weight_decay=1e-4
        )
        return params, state, {**aux, **m}

    mgr = CheckpointManager(args.ckpt_dir, every=args.ckpt_every) if args.ckpt_dir else None
    mon = StragglerMonitor()
    t0 = time.time()
    for i in range(args.steps):
        pts, cls, seg = sample_batch(
            jax.random.fold_in(jax.random.PRNGKey(args.seed), 10_000 + i),
            args.batch, cfg.n_points,
        )
        labels = cls if cfg.task == "cls" else seg
        mon.step_start()
        params, state, aux = step_fn(params, state, pts, labels)
        dt = mon.step_end(i)
        if mgr:
            mgr.maybe_save(i + 1, {"params": params, "opt": state})
        if i % args.log_every == 0 or i == args.steps - 1:
            print(
                f"step {i}: loss={float(aux['loss']):.4f} acc={float(aux['accuracy']):.3f} "
                f"({dt*1e3:.0f}ms, {time.time()-t0:.0f}s)",
                flush=True,
            )
    if mgr:
        mgr.maybe_save(args.steps, {"params": params, "opt": state}, force=True)
        mgr.wait()
    return params


def train_lm(cfg, args):
    from repro.models.families import get_family_api
    from repro.train.step import make_train_step

    api = get_family_api(cfg)
    step_raw = make_train_step(
        cfg, peak_lr=args.lr, warmup_steps=max(args.steps // 10, 1),
        total_steps=args.steps, policy=_policy_override(cfg, args),
    )
    step_fn = jax.jit(step_raw, donate_argnums=(0, 1))
    mgr = CheckpointManager(args.ckpt_dir, every=args.ckpt_every) if args.ckpt_dir else None
    mon = StragglerMonitor()

    def make_state():
        params = api["init"](jax.random.PRNGKey(args.seed), cfg)
        return {"params": params, "opt": adamw_init(params)}

    def loop(state, start_step):
        stream = Prefetcher(
            token_stream(args.seed, args.batch, args.seq, cfg.vocab_size, start_step=start_step)
        )
        t0 = time.time()
        params, opt = state["params"], state["opt"]
        for step, batch in stream:
            if step >= args.steps:
                break
            if cfg.family == "encdec":
                batch = dict(batch)
                batch["enc_embeds"] = jnp.zeros((args.batch, args.seq, cfg.d_model), cfg.dtype)
            if cfg.family == "vlm":
                batch = dict(batch)
                batch["patch_embeds"] = jnp.zeros(
                    (args.batch, cfg.n_patches, cfg.d_model), cfg.dtype
                )
            mon.step_start()
            params, opt, metrics = step_fn(params, opt, batch)
            dt = mon.step_end(step)
            if mgr:
                mgr.maybe_save(step + 1, {"params": params, "opt": opt})
            if step % args.log_every == 0 or step == args.steps - 1:
                print(
                    f"step {step}: loss={float(metrics['loss']):.4f} "
                    f"lr={float(metrics['lr']):.2e} ({dt*1e3:.0f}ms, {time.time()-t0:.0f}s)",
                    flush=True,
                )
        return {"params": params, "opt": opt}, args.steps

    if mgr:
        state, last, n_restarts = run_with_restarts(make_state, loop, ckpt_manager=mgr)
        mgr.maybe_save(last, state, force=True)
        mgr.wait()
    else:
        state, _ = loop(make_state(), 0)
    if mon.events:
        print(f"stragglers detected: {len(mon.events)}")
    return state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quant", default=None, choices=["none", "sc_w16a16", "sc_w8a8"],
                    help="override the config's quant mode (ExecutionPolicy)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if getattr(cfg, "family", None) == "pointcloud" or args.arch.startswith("pointnet2"):
        train_pointcloud(cfg, args)
    else:
        train_lm(cfg, args)


if __name__ == "__main__":
    main()
