"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

No device allocation — these feed jax.jit(...).lower() directly.  Shapes per
the assignment:

    train_4k     seq_len=4096    global_batch=256   (training step)
    prefill_32k  seq_len=32768   global_batch=32    (inference prefill)
    decode_32k   seq_len=32768   global_batch=128   (one token + 32k cache)
    long_500k    seq_len=524288  global_batch=1     (long-context decode)

long_500k applies only to sub-quadratic archs (mamba2, recurrentgemma,
gemma3); pure full-attention archs are skipped with a recorded reason
(DESIGN.md §Arch-applicability).  [audio]/[vlm] frontends are stubs: the
batch carries precomputed frame/patch embeddings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

SDS = jax.ShapeDtypeStruct

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# archs allowed to run long_500k (sub-quadratic / bounded-window decode)
LONG_OK = {"mamba2-1.3b", "recurrentgemma-2b", "gemma3-12b"}


def skip_reason(arch: str, shape: str) -> str | None:
    if shape == "long_500k" and arch not in LONG_OK:
        return "full-attention arch: long_500k skipped per assignment rule"
    return None


def token_batch_specs(cfg: ModelConfig, batch: int, seq: int, *, labels: bool = True):
    d = {"tokens": SDS((batch, seq), jnp.int32)}
    if labels:
        d["labels"] = SDS((batch, seq), jnp.int32)
    return d


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """Abstract batch for train_loss / prefill.  Decode state specs come from
    decode_state_specs()."""
    info = SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    kind = info["kind"]
    if kind == "decode":
        return {"token": SDS((b, 1), jnp.int32)}

    if cfg.family == "encdec":
        d = token_batch_specs(cfg, b, s, labels=(kind == "train"))
        d["enc_embeds"] = SDS((b, s, cfg.d_model), cfg.dtype)
        return d
    if cfg.family == "vlm":
        s_text = s - cfg.n_patches
        d = {"tokens": SDS((b, s_text), jnp.int32)}
        if kind == "train":
            d["labels"] = SDS((b, s_text), jnp.int32)
        d["patch_embeds"] = SDS((b, cfg.n_patches, cfg.d_model), cfg.dtype)
        return d
    return token_batch_specs(cfg, b, s, labels=(kind == "train"))


def decode_state_specs(cfg: ModelConfig, shape_name: str):
    """Abstract decode state via eval_shape over the family's initializer."""
    from repro.models.families import get_family_api

    info = SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    api = get_family_api(cfg)

    def mk():
        return api["init_decode_state"](cfg, b, s)

    return jax.eval_shape(mk)


def abstract_params(cfg: ModelConfig):
    from repro.models.families import get_family_api

    api = get_family_api(cfg)
    return jax.eval_shape(lambda: api["init"](jax.random.PRNGKey(0), cfg))


def abstract_opt_state(params_shape):
    from repro.optim.adamw import adamw_init

    return jax.eval_shape(lambda: adamw_init_from_shapes(params_shape))


def adamw_init_from_shapes(params_shape):
    from repro.optim.adamw import adamw_init

    params = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params_shape)
    return adamw_init(params)


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """MODEL_FLOPS: 6*N*D for train (N = params excl. embeddings read-only
    share; we use total non-embedding params + lm_head), 2*N per generated
    token for decode, 2*N*D for prefill; attention flops added explicitly."""
    info = SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    n = cfg.param_count()
    emb = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    n_active = n - emb
    if cfg.family == "moe":
        # active experts only
        dense_share = cfg.n_experts and (cfg.top_k / cfg.n_experts)
        moe_params = 3 * cfg.d_model * cfg.d_ff * cfg.n_experts * cfg.n_layers
        n_active = n_active - moe_params + moe_params * dense_share
    # attention context flops per token ~ 2*2*Hq*dh*ctx (qk + pv)
    pat = cfg.pattern_for_layers()
    heads_flops = 0.0
    for t in pat:
        if t == "recurrent":
            continue
        ctx = s if t == "global" else min(s, cfg.window or s)
        if info["kind"] == "train" or info["kind"] == "prefill":
            ctx_eff = ctx / 2 if t == "global" else ctx  # causal average
            heads_flops += 4 * cfg.n_heads * cfg.head_dim * ctx_eff
        else:
            heads_flops += 4 * cfg.n_heads * cfg.head_dim * ctx
    # encoder attention context (whisper): params already in n_active, but the
    # non-causal full-context score/value flops are not in `heads_flops`
    # (which walks the decoder pattern); cross-attention adds another S ctx.
    enc_flops_per_token = 0.0
    if cfg.encoder_layers:
        hh, dh = cfg.n_heads, cfg.head_dim
        enc_flops_per_token = cfg.encoder_layers * 4 * hh * dh * s  # self (full)
        enc_flops_per_token += cfg.n_layers * 4 * hh * dh * s  # decoder cross
    # lm head
    head = 2 * cfg.d_model * cfg.vocab_size
    if info["kind"] == "train":
        per_token = 6 * n_active + 3 * heads_flops + 3 * head + 3 * enc_flops_per_token
        return b * s * per_token
    if info["kind"] == "prefill":
        per_token = 2 * n_active + heads_flops + enc_flops_per_token
        return b * s * per_token + b * head
    per_token = 2 * n_active + heads_flops + head
    return b * per_token
