"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — required for the dry-run's
xla_force_host_platform_device_count trick and for tests that must see the
single real CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips, v5e) or 2x16x16 two-pod (512 chips).

    Axes: 'pod' (DP across pods, DCN), 'data' (FSDP/batch, ICI),
    'model' (TP/EP, ICI)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh on the real local device (tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))
