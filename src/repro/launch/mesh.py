"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — required for the dry-run's
xla_force_host_platform_device_count trick and for tests that must see the
single real CPU device.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.sharding.hints import REPLICA_AXIS


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips, v5e) or 2x16x16 two-pod (512 chips).

    Axes: 'pod' (DP across pods, DCN), 'data' (FSDP/batch, ICI),
    'model' (TP/EP, ICI)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh on the real local device (tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_replica_mesh(devices):
    """1-D serving mesh over ONE replica's device group.

    The single axis is `sharding.hints.REPLICA_AXIS`; the accelerator's
    sharded artifacts shard_map over it (specs from
    sharding.policy.replica_specs).  A one-device group is valid and yields
    a degenerate size-1 mesh — the sharded artifact then runs unsharded on
    that device, so policy semantics don't depend on group size.
    """
    devices = tuple(devices)
    if not devices:
        raise ValueError("replica mesh needs at least one device")
    return jax.sharding.Mesh(np.array(devices), (REPLICA_AXIS,))


def carve_device_groups(devices, per_replica: int) -> list[tuple]:
    """Partition a device list into consecutive groups of `per_replica`.

    The serving pool's unit of capacity: each group backs one mesh-sharded
    replica (per_replica=1 reproduces the classic one-device-per-replica
    carving).  Leftover devices that don't fill a whole group are unused —
    a partial mesh would change the shard count and retrace every sharded
    artifact, so uniform groups win.  Raises when per_replica < 1 or
    exceeds the device count (no group could be formed).
    """
    devices = list(devices)
    if per_replica < 1:
        raise ValueError(f"devices_per_replica must be >= 1, got {per_replica}")
    if per_replica > len(devices):
        raise ValueError(
            f"devices_per_replica={per_replica} exceeds the "
            f"{len(devices)} available device(s)"
        )
    n = len(devices) // per_replica
    return [tuple(devices[i * per_replica : (i + 1) * per_replica]) for i in range(n)]
