"""Replica pool — one accelerator replica per device GROUP, least-loaded dispatch.

Each `Replica` pins a copy of the model parameters to one carved group of
`jax.devices()` entries (usually of size one — `devices_per_replica`) and
executes micro-batches on its own single worker thread, so B replicas give
B-way compute overlap while every batch still runs on exactly one group.
Batches under a sharded `ExecutionPolicy` run the accelerator's shard_map
artifact across the group's mesh (`_execute_sharded`); everything else —
dispatch, warmup, heartbeat/wedge eviction, chaos injection, retry,
tracing — is group-size-agnostic.  Health is delegated to
`runtime/fault_tolerance.py`:

  * HeartbeatMonitor — a pump thread feeds a no-op beat through each of the
    replica's executor queues every timeout/4 (worker AND feature thread,
    so pipelined batches are covered too); a wedged thread (hung kernel,
    dead device) stops beating and its monitor evicts the replica.  The
    timeout must therefore exceed the worst-case batch latency.
  * StragglerMonitor — per-batch wall time; slow-but-alive replicas are
    recorded (metrics.straggler_events) for the operator, not evicted.

Eviction re-dispatches the replica's outstanding batches to the surviving
replicas, bounded by `max_retries` per batch; a batch that fails everywhere
fails its future with the last error.  Dispatch is least-loaded (smallest
in-flight count among alive replicas) — with shape buckets in play, queue
depth is a better load proxy than round-robin.

Eviction is two-way: `rejoin()` rebuilds an evicted replica in place — a
fresh params copy pinned to its device, fresh stage executors and heartbeat
pumps, every registered warmup batch replayed so each (bucket, policy)
artifact is traced before real traffic lands on it, and (when the runtime
runs a preprocess cache) the hottest cache entries pre-staged as committed
device trees so the new replica's first all-hit batches skip the host
restack.  `add_replica()`/`retire()` grow and shrink the pool the same way;
`serve/autoscaler.py` drives all three from queue depth and evictions.  The
optional `chaos` hook (serve/chaos.py) observes every real batch at
execution start — the deterministic fault-injection point the recovery
tests and the serve_slo benchmark drive.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accelerator import get_accelerator
from repro.core.engine import (
    result_row,
    result_set_row,
    result_stack,
    result_to_host,
)
from repro.launch.mesh import carve_device_groups, make_replica_mesh
from repro.runtime.fault_tolerance import HeartbeatMonitor, StragglerMonitor
from repro.serve.metrics import BatchRecord, ServeMetrics
from repro.serve.queue import try_set_exception, try_set_result


class NoReplicaAvailable(RuntimeError):
    """Every replica is dead (or was already tried for this batch)."""


class _Entry:
    """One in-flight batch on one replica (retry bookkeeping)."""

    def __init__(self, mb, future: Future, attempts: int, tried: frozenset):
        self.mb = mb
        self.future = future
        self.attempts = attempts
        self.tried = tried
        self.seq = -1  # assigned under the pool lock at registration


class Replica:
    """One device-group-pinned executor: params copy + single worker thread.

    The unit of capacity is a device GROUP (usually of size one): sharded-
    policy batches run the accelerator's shard_map artifact over the
    group's 1-D mesh against `mesh_params` (a replicated pin), while
    unsharded batches keep using the primary device's `params` copy —
    both pins coexist so one replica serves both kinds of traffic (the
    replicated pin aliases the primary one for 1-device groups; sharding
    the tensor-mode weights in MEMORY too is a ROADMAP follow-on).

    Batches under a `pipeline="pipelined"` policy additionally use a second
    single-thread executor: the worker thread dispatches the preprocess
    sub-artifact asynchronously and hands completion to the feature thread,
    so while batch k's feature MLPs run, the worker is already preprocessing
    batch k+1 — per-replica stage overlap.  Both executors are constructed
    eagerly (threads spawn on first use), so shutdown/eviction can never
    race a lazy creation; when liveness is enabled, each executor gets its
    own heartbeat pump, so a wedge in EITHER stage evicts the replica.
    """

    def __init__(self, rid: int, device, params, *, on_straggler=None):
        self.id = rid
        # one device OR a device group (mesh-sharded replica): normalized to
        # a tuple, with `device` the group's primary — every single-device
        # path (batch placement, cache staging, repr) keeps using it, so a
        # 1-device group behaves exactly like the classic replica
        self.devices = tuple(device) if isinstance(device, (tuple, list)) else (device,)
        self.device = self.devices[0]
        self.params = jax.device_put(params, self.device)
        # replicated pin over the group's mesh for sharded-policy batches.
        # For a 1-device group the mesh sharding is equivalent to the
        # primary pin, so device_put aliases the copy above (no duplicate)
        self.mesh = make_replica_mesh(self.devices)
        self.mesh_params = jax.device_put(
            self.params,
            jax.sharding.NamedSharding(self.mesh, jax.sharding.PartitionSpec()),
        )
        self.alive = True
        self.retired = False  # scale-down (don't auto-rejoin) vs fault eviction
        self.evicted_t: float | None = None  # when evict() ran (rejoin delay base)
        self.n_batches = 0
        # pre-staged preprocess-cache entries: key -> (id(entry), committed
        # device tree).  Filled at rejoin/scale-up warmup with the cache's
        # hottest entries so the first all-hit batches skip the host restack;
        # the entry id guards against an entry replaced under the same key.
        self.staged: dict[tuple, tuple[int, object]] = {}
        self.inflight: dict[int, _Entry] = {}
        self.straggler = StragglerMonitor(on_straggler=on_straggler)
        self.heartbeat: HeartbeatMonitor | None = None
        self.feature_heartbeat: HeartbeatMonitor | None = None
        self._executor = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"pc2im-replica-{rid}"
        )
        # constructed eagerly so shutdown()/eviction can never race a lazy
        # creation and leak it.  ThreadPoolExecutor spawns its thread only on
        # first submit, so with liveness DISABLED sequential-only replicas pay
        # nothing; with heartbeats on, the feature pump's beats spawn it (the
        # price of covering a wedge in either stage)
        self._feature_executor = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"pc2im-replica-{rid}-feat"
        )
        # double-buffer bound on preprocessed-but-unconsumed batches: without
        # it a burst would let the worker race arbitrarily far ahead,
        # materializing every batch's device-resident intermediates at once
        self._handoff_slots = threading.BoundedSemaphore(2)

    def acquire_handoff(self):
        """Block until a staged-batch slot frees (double buffering).

        Applies the same backpressure `two_stage_schedule`'s bounded queue
        gives the local executor: at most two batches may sit preprocessed
        but not yet consumed by the feature thread.  Raises RuntimeError if
        the replica dies while waiting, so a blocked worker task converts to
        a retry instead of hanging.
        """
        while not self._handoff_slots.acquire(timeout=0.1):
            if not self.alive:
                raise RuntimeError(f"replica {self.id} shut down during hand-off wait")

    def release_handoff(self):
        """Free a staged-batch slot (feature stage consumed its input)."""
        self._handoff_slots.release()

    def submit(self, fn, *args) -> Future:
        """Run fn on the replica's worker thread (admission order preserved)."""
        return self._executor.submit(fn, *args)

    def submit_feature(self, fn, *args) -> Future:
        """Run fn on the feature-stage thread (pipelined batches only).

        Single-threaded, so feature stages of consecutive batches stay
        ordered per replica.
        """
        return self._feature_executor.submit(fn, *args)

    def stage_entry(self, entry) -> None:
        """Pre-stage one preprocess-cache entry as a committed device tree.

        The per-row payload is transferred to this replica's device up
        front, so an all-hit batch made of staged entries stacks them
        device-side (`ReplicaPool._staged_stack`) instead of restacking on
        the host and paying the transfer on the serving path.
        """
        self.staged[entry.key] = (
            id(entry),
            jax.device_put(entry.pre, self.device),
        )

    def shutdown(self):
        """Stop both stage executors without waiting.

        In-flight work is abandoned; the pool re-dispatches it elsewhere or
        fails its futures.
        """
        self.alive = False
        if self.heartbeat is not None:
            self.heartbeat.stop()
        if self.feature_heartbeat is not None:
            self.feature_heartbeat.stop()
        self._executor.shutdown(wait=False)
        self._feature_executor.shutdown(wait=False)


class ReplicaPool:
    """Least-loaded dispatch over per-device replicas with health tracking."""

    def __init__(
        self,
        model_cfg,
        params,
        *,
        n_replicas: int | None = None,
        devices=None,
        devices_per_replica: int = 1,
        heartbeat_timeout_s: float | None = None,
        max_retries: int = 2,
        metrics: ServeMetrics | None = None,
        cache=None,
        stage_top_k: int = 8,
        tracer=None,
    ):
        devices = list(devices) if devices is not None else jax.devices()
        # the unit of capacity is a device GROUP: per_replica=1 reproduces
        # the classic per-device carving; > 1 backs each replica with a mesh
        # (leftover devices that don't fill a group are unused)
        self._groups = carve_device_groups(devices, devices_per_replica)
        n = n_replicas if n_replicas is not None else len(self._groups)
        if n < 1:
            raise ValueError("need at least one replica")
        self.model_cfg = model_cfg
        self.max_retries = max_retries
        self.metrics = metrics or ServeMetrics()
        self.cache = cache  # PreprocessCache | None — pre-staged on rejoin
        self.stage_top_k = stage_top_k
        self.tracer = tracer  # Tracer | None — None means tracing is off
        self.chaos = None  # serve/chaos.py injector hook (tests/benchmarks)
        self._params = params  # host reference: rejoin re-pins a fresh copy
        self._devices = devices
        self._heartbeat_timeout_s = heartbeat_timeout_s
        self._warmup_mbs: list = []  # registered warmup batches, replayed on rejoin
        self._lock = threading.Lock()
        self._seq = 0
        # round-robin devices when asked for more replicas than devices
        # (useful on CPU: several logical replicas exercise the dispatch path)
        self.replicas = [self._make_replica(i) for i in range(n)]
        # background cache fill for all-miss batches (thread spawns on first
        # submit, so uncached pools pay nothing); single-threaded, so inserts
        # land in batch-completion order and a later duplicate's
        # execution-time lookup observes them deterministically
        self._insert_executor = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="pc2im-cache-insert"
        )
        self._pumps: list[threading.Thread] = []
        for rep in self.replicas:
            self._start_liveness(rep)

    def _make_replica(self, rid: int) -> Replica:
        """Construct one fresh Replica for slot `rid` (params re-pinned).

        Shared by the constructor and `rejoin`/`add_replica`: the replica's
        device group follows the slot (round-robin over the carved groups),
        so a rejoined replica lands back on the group its predecessor used —
        and, for sharded policies, on the exact mesh whose artifacts the
        accelerator already compiled (warm re-trace).  Liveness pumps are
        NOT started here — call `_start_liveness` after the replica is
        visible in `self.replicas`.
        """
        return Replica(
            rid,
            self._groups[rid % len(self._groups)],
            self._params,
            # bind the slot id here: StragglerEvent itself carries no replica
            # attribution, and the monitor is per-replica anyway
            on_straggler=lambda ev, rid=rid: self._on_straggler(rid, ev),
        )

    def _on_straggler(self, rid: int, ev) -> None:
        """Per-replica straggler beat: metrics attribution + trace event."""
        self.metrics.record_straggler(ev, replica_id=rid)
        if self.tracer is not None:
            self.tracer.emit(
                "replica.straggler",
                replica_id=rid,
                args={
                    "duration_s": ev.duration_s,
                    "median_s": ev.median_s,
                    "ratio": ev.ratio,
                },
            )

    def _emit(self, name: str, mb, rep_id: int = -1, args: dict | None = None):
        """Emit one batch-scoped trace event (no-op when untraced).

        Warmup batches carry batch_id == -1 and stay invisible to the trace
        stream, matching their exclusion from metrics.
        """
        tr = self.tracer
        if tr is not None and mb.batch_id != -1:
            tr.emit(name, batch_id=mb.batch_id, replica_id=rep_id, args=args)

    def _start_liveness(self, rep: Replica) -> None:
        """Attach heartbeat monitors + pumps to one replica (when enabled)."""
        if self._heartbeat_timeout_s is None:
            return
        rep.heartbeat = HeartbeatMonitor(
            self._heartbeat_timeout_s,
            on_dead=lambda rid=rep.id: self.evict(rid, reason="heartbeat"),
        ).start()
        rep.feature_heartbeat = HeartbeatMonitor(
            self._heartbeat_timeout_s,
            on_dead=lambda rid=rep.id: self.evict(rid, reason="feature-heartbeat"),
        ).start()
        for tag, submit, monitor in (
            ("", rep.submit, rep.heartbeat),
            ("-feat", rep.submit_feature, rep.feature_heartbeat),
        ):
            pump = threading.Thread(
                target=self._pump, args=(rep, submit, monitor),
                daemon=True, name=f"pc2im-hb-pump-{rep.id}{tag}",
            )
            pump.start()
            self._pumps.append(pump)

    # -- health ---------------------------------------------------------------

    def _pump(self, rep: Replica, submit, monitor):
        """Route beats THROUGH one of the replica's executor queues.

        A wedged thread stops beating, which is exactly the liveness signal
        we want.  Each stage executor gets its own pump + monitor: the
        worker thread never blocks on device work for pipelined batches, so
        a hung feature stage is only observable through the feature
        executor's queue.
        """
        period = monitor.timeout_s / 4
        while rep.alive:
            try:
                submit(monitor.beat)
            except RuntimeError:  # executor shut down under us
                return
            time.sleep(period)

    def alive_replicas(self) -> list[Replica]:
        """Replicas currently considered healthy (dispatch candidates)."""
        with self._lock:
            return [r for r in self.replicas if r.alive]

    def evict(self, rid: int, *, reason: str):
        """Mark a replica dead and re-dispatch its outstanding batches."""
        with self._lock:
            rep = self.replicas[rid]
            if not rep.alive:
                return
            rep.alive = False
            rep.evicted_t = time.monotonic()
            orphans = list(rep.inflight.values())
            rep.inflight.clear()
        self.metrics.record_eviction()
        if self.tracer is not None:
            self.tracer.emit(
                "replica.evicted",
                replica_id=rid,
                args={"reason": reason, "orphans": len(orphans)},
            )
        rep.shutdown()
        for entry in orphans:
            if entry.future.done():
                continue
            self.metrics.record_retry()
            self._emit("batch.retry", entry.mb, rep_id=rid,
                       args={"attempts": entry.attempts + 1, "reason": reason})
            self._dispatch(
                entry.mb, entry.future, entry.attempts + 1,
                entry.tried | {rid},
                error=NoReplicaAvailable(f"replica {rid} evicted ({reason})"),
            )

    def retire(self, rid: int) -> bool:
        """Scale-down eviction: like `evict` but opts out of auto-rejoin.

        The autoscaler retires replicas when the queue runs shallow;
        `retired=True` keeps its rejoin loop from immediately reviving the
        slot (a later scale-up still can, via `rejoin`).  Returns False if
        the replica was already dead.
        """
        with self._lock:
            rep = self.replicas[rid]
            if not rep.alive:
                return False
            rep.retired = True
        self.evict(rid, reason="scale-down")
        return True

    def rejoin(self, rid: int, *, warm: bool = True) -> bool:
        """Re-admit an evicted replica slot with a fresh warm replica.

        The two-way half of eviction: a fresh `Replica` (new params copy on
        the slot's device, new stage executors, new heartbeat pumps)
        replaces the dead one IN PLACE, so in-flight `tried` sets — which
        exclude the slot by id — stay meaningful for batches that failed on
        the predecessor.  With `warm=True` (the default) every registered
        warmup batch is replayed on the new replica before it is marked
        alive for dispatch, so real traffic never pays its compile latency,
        and the preprocess cache's hottest entries are pre-staged on its
        device (`Replica.stage_entry`).  Returns False when the slot is
        still alive (nothing to do).
        """
        with self._lock:
            if self.replicas[rid].alive:
                return False
            rep = self._make_replica(rid)
            # visible to dispatch only after warmup: alive=False gates _pick
            rep.alive = False
            self.replicas[rid] = rep
        try:
            if warm:
                for mb in list(self._warmup_mbs):
                    self._warmup_on(rep, mb)
                self._stage_cache(rep)
        except Exception:
            rep.shutdown()
            raise
        with self._lock:
            rep.alive = True
        self._start_liveness(rep)
        self.metrics.record_rejoin()
        if self.tracer is not None:
            self.tracer.emit(
                "replica.rejoin", replica_id=rid, args={"warm": warm}
            )
        return True

    def add_replica(self, *, warm: bool = True) -> int:
        """Grow the pool by one fresh replica slot; returns its id.

        Scale-up path of the autoscaler once every existing slot is alive.
        The new replica round-robins onto the pool's devices and is warmed
        (and cache-pre-staged) exactly like a rejoin before dispatch sees
        it.
        """
        with self._lock:
            rid = len(self.replicas)
            rep = self._make_replica(rid)
            rep.alive = False  # invisible to _pick until warm
            self.replicas.append(rep)
        try:
            if warm:
                for mb in list(self._warmup_mbs):
                    self._warmup_on(rep, mb)
                self._stage_cache(rep)
        except Exception:
            rep.shutdown()
            raise
        with self._lock:
            rep.alive = True
        self._start_liveness(rep)
        self.metrics.record_rejoin()
        if self.tracer is not None:
            self.tracer.emit(
                "replica.rejoin", replica_id=rid, args={"warm": warm, "grew": True}
            )
        return rid

    def _stage_cache(self, rep: Replica) -> None:
        """Pre-stage the cache's hottest entries on one replica's device.

        Best-effort: a failed transfer only costs the staged fast path, so
        it must never fail a rejoin.
        """
        if self.cache is None:
            return
        try:
            for entry in self.cache.top_entries(self.stage_top_k):
                rep.stage_entry(entry)
        except Exception:  # noqa: BLE001 — staging is an optimization only
            rep.staged.clear()

    def _warmup_on(self, rep: Replica, mb) -> None:
        """Replay one registered warmup batch synchronously on one replica.

        Used by rejoin/add_replica while the replica is still invisible to
        dispatch (alive=False); attempts starts at the retry budget so a
        failure fails THIS future instead of re-dispatching the warmup
        batch to a healthy replica and masking the broken one.
        """
        entry = _Entry(mb, Future(), attempts=self.max_retries, tried=frozenset())
        with self._lock:
            self._seq += 1
            entry.seq = self._seq
            rep.inflight[entry.seq] = entry
        rep.submit(self._execute, rep, entry)
        entry.future.result(timeout=300)

    def _staged_stack(self, rep: Replica, entries, total: int):
        """Device-side restack of an all-hit batch from pre-staged entries.

        Returns the committed device tree when EVERY entry is staged on
        this replica and still current (the recorded entry id must match —
        an entry replaced under the same content address invalidates its
        staged copy); otherwise None, and the caller falls back to the
        host restack + device_put.  Mirrors `result_stack` exactly —
        zeros_like filler rows, then a leaf-wise stack — so the result is
        bitwise-identical to the host path and hits the same executable.
        """
        rows = []
        for e in entries:
            rec = rep.staged.get(e.key)
            if rec is None or rec[0] != id(e):
                return None
            rows.append(rec[1])
        rows.extend([jax.tree.map(jnp.zeros_like, rows[0])] * (total - len(rows)))
        return jax.device_put(
            jax.tree.map(lambda *r: jnp.stack(r), *rows), rep.device
        )

    # -- dispatch -------------------------------------------------------------

    def submit(self, mb) -> Future:
        """Run one MicroBatch somewhere healthy; future yields np logits."""
        future: Future = Future()
        self._dispatch(mb, future, attempts=0, tried=frozenset())
        return future

    def _pick(self, tried: frozenset) -> Replica | None:
        with self._lock:
            candidates = [
                r for r in self.replicas if r.alive and r.id not in tried
            ]
            if not candidates:
                return None
            return min(candidates, key=lambda r: (len(r.inflight), r.id))

    def _dispatch(self, mb, future: Future, attempts: int, tried: frozenset, error=None):
        if attempts > self.max_retries:
            try_set_exception(future, error or NoReplicaAvailable("retry budget exhausted"))
            return
        rep = self._pick(tried)
        if rep is None:
            try_set_exception(
                future, error or NoReplicaAvailable(f"no replica left (tried {sorted(tried)})")
            )
            return
        entry = _Entry(mb, future, attempts, tried)
        with self._lock:
            lost_race = not rep.alive  # evict() won between _pick and here
            if not lost_race:
                self._seq += 1
                entry.seq = self._seq
                rep.inflight[entry.seq] = entry
        if lost_race:
            self._retry(entry, rep.id, NoReplicaAvailable("replica died"))
            return
        self._emit("batch.dispatched", mb, rep_id=rep.id,
                   args={"attempts": attempts})
        try:
            rep.submit(self._execute, rep, entry)
        except RuntimeError as e:  # executor shut down between pick and submit
            with self._lock:
                was_inflight = rep.inflight.pop(entry.seq, None) is not None
            if was_inflight:  # else a concurrent evict() already re-dispatched
                self._retry(entry, rep.id, e)

    def _retry(self, entry: _Entry, rid: int, err: Exception):
        if entry.future.done():
            return
        self.metrics.record_retry()
        self._emit("batch.retry", entry.mb, rep_id=rid,
                   args={"attempts": entry.attempts + 1, "reason": repr(err)})
        self._dispatch(entry.mb, entry.future, entry.attempts + 1,
                       entry.tried | {rid}, error=err)

    def _execute(self, rep: Replica, entry: _Entry):
        if entry.future.done():  # e.g. already re-dispatched after eviction
            with self._lock:
                rep.inflight.pop(entry.seq, None)
            return
        mb = entry.mb
        if self.chaos is not None and mb.n_real > 0:
            # deterministic fault-injection point: every REAL batch passes
            # here on its replica's worker thread before either execution
            # path (warmup batches are invisible to the injector).  A kill
            # fault evicts the replica — eviction re-dispatches this entry,
            # so the raise below must NOT retry it again (was_inflight)
            try:
                self.chaos.on_batch(self, rep, mb)
            except Exception as e:  # noqa: BLE001 — injected fault
                with self._lock:
                    was_inflight = rep.inflight.pop(entry.seq, None) is not None
                if was_inflight:
                    self._retry(entry, rep.id, e)
                return
        if getattr(mb.policy, "sharding", None) is not None:
            self._execute_sharded(rep, entry)
            return
        if getattr(mb.policy, "pipeline", "sequential") == "pipelined":
            self._execute_pipelined(rep, entry)
            return
        try:
            accel = get_accelerator(self.model_cfg, mb.policy)
            rep.straggler.step_start()
            batch = jax.device_put(jnp.asarray(mb.batch), rep.device)
            if mb.cache is not None:
                logits, skipped = self._run_cached(accel, rep, mb, batch)
            else:
                self._emit("batch.execute_start", mb, rep_id=rep.id)
                logits = np.asarray(
                    jax.block_until_ready(accel.infer(rep.params, batch))
                )
                self._emit("batch.execute_end", mb, rep_id=rep.id)
                skipped = False
            dt = rep.straggler.step_end(rep.n_batches)
            if rep.heartbeat is not None:
                rep.heartbeat.beat()
            self._record_success(rep, entry, logits, dt, preprocess_skipped=skipped)
        except Exception as e:  # noqa: BLE001 — any device/kernel failure
            # retry only if the entry was still ours: a concurrent evict()
            # already cleared inflight AND re-dispatched it — retrying here
            # too would run the batch twice
            with self._lock:
                was_inflight = rep.inflight.pop(entry.seq, None) is not None
            if was_inflight:
                self._retry(entry, rep.id, e)

    def _execute_sharded(self, rep: Replica, entry: _Entry):
        """Mesh-sharded execution of one batch over the replica's device group.

        Routes through the accelerator's `mesh_artifacts` for this group —
        a 1-device group gets a degenerate mesh, so the policy's semantics
        never depend on the pool's carving.  Straggler tracking, heartbeat
        beats, retry-on-failure and trace spans behave exactly like the
        sequential path (chaos already ran in `_execute`).  The preprocess
        cache deliberately does not compose with sharded policies yet —
        the scheduler never attaches it to a sharded batch (cached rows
        are single-device host trees, not mesh-laid-out ones; see ROADMAP).
        """
        mb = entry.mb
        try:
            accel = get_accelerator(self.model_cfg, mb.policy)
            arts = accel.mesh_artifacts(rep.devices)
            rep.straggler.step_start()
            self._emit("batch.execute_start", mb, rep_id=rep.id)
            logits = np.asarray(
                jax.block_until_ready(
                    arts.infer(rep.mesh_params, jnp.asarray(mb.batch))
                )
            )
            self._emit("batch.execute_end", mb, rep_id=rep.id)
            dt = rep.straggler.step_end(rep.n_batches)
            if rep.heartbeat is not None:
                rep.heartbeat.beat()
            self._record_success(rep, entry, logits, dt)
        except Exception as e:  # noqa: BLE001 — any device/kernel failure
            with self._lock:
                was_inflight = rep.inflight.pop(entry.seq, None) is not None
            if was_inflight:  # else a concurrent evict() already re-dispatched
                self._retry(entry, rep.id, e)

    # -- preprocess-cache execution -------------------------------------------

    def _resolve_entries(self, mb) -> tuple:
        """Authoritative, counted cache lookups for one batch at execution time.

        The scheduler peeked at assembly time (to substitute canonical rows);
        by the time the batch EXECUTES, every earlier batch on this replica
        has finished inserting, so a request that peek-missed while its
        duplicate's batch was still in flight can upgrade to a hit here —
        under a backlogged cyclic trace this is where most hits come from.
        A late hit is accepted only when the assembled batch row is
        bitwise-equal to the entry's canonical row (always true for exact
        duplicates; a sub-step-noise near-duplicate whose row was NOT
        canonicalized at assembly keeps the miss path, preserving parity).
        Returns one CacheEntry-or-None per request; exactly one counted
        lookup per addressable request.
        """
        entries = []
        hits = misses = 0
        for i, req in enumerate(mb.requests):
            ent = None
            if req.cache_key is not None:
                ent = mb.cache.lookup(req.cache_key)
                if ent is not None and not np.array_equal(mb.batch[i], ent.row):
                    ent = None
                if ent is not None:
                    hits += 1
                else:
                    misses += 1
            entries.append(ent)
        # one metrics-lock round trip per outcome, not per request — the
        # metrics lock is shared with the scheduler's hot path
        if hits:
            self.metrics.record_cache_lookup(True, hits)
        if misses:
            self.metrics.record_cache_lookup(False, misses)
        if self.tracer is not None and mb.batch_id != -1:
            for req, ent in zip(mb.requests, entries):
                if req.trace_id is not None and req.cache_key is not None:
                    self.tracer.emit(
                        "request.cache_lookup",
                        trace_id=req.trace_id,
                        batch_id=mb.batch_id,
                        slo=req.slo.name,
                        args={"hit": ent is not None},
                    )
        return tuple(entries)

    def _run_cached(self, accel, rep, mb, batch):
        """Cache-aware execution of one batch; returns (logits, skipped).

        All-hit: the preprocess stage is skipped outright — the per-row
        cached neighborhoods are restacked (zero filler rows matching the
        zero filler batch rows) and fed straight to `feature_from_cached`.
        All-miss: `infer_with_preprocess` — ONE dispatch at fused-path cost
        whose second output feeds the background cache fill, so the
        0%-duplicate workload pays nothing over the uncached path.
        Mixed: the batch runs `preprocess_stage` (the staged composition is
        bitwise-equal to the fused `infer`, pinned by
        tests/test_pipelined_accelerator.py, so miss parity is preserved),
        hit rows are spliced in on the host, and miss rows populate the
        cache before the feature stage runs.
        """
        if mb.n_real == 0:
            # warmup batch: trace EVERY artifact a cached batch can touch so
            # no variant compiles mid-traffic (a multi-hundred-ms stall)
            fused, _pre = accel.infer_with_preprocess(rep.params, batch)
            pre = accel.preprocess_stage(batch)
            logits = np.asarray(
                jax.block_until_ready(accel.feature_stage(rep.params, batch, pre))
            )
            jax.block_until_ready(fused)
            return logits, False
        self._emit("batch.cache_start", mb, rep_id=rep.id)
        entries = self._resolve_entries(mb)
        n_hits = sum(1 for e in entries if e is not None)
        if n_hits == mb.n_real:
            # device_put: the feature artifact must only ever see COMMITTED
            # device trees — a host-numpy variant would compile a second
            # executable for the same shapes (a one-off multi-hundred-ms
            # stall mid-traffic).  Pre-staged entries (warm rejoin) stack
            # device-side and skip the host restack + transfer entirely
            pre = self._staged_stack(rep, entries, mb.batch.shape[0])
            if pre is None:
                pre = jax.device_put(
                    result_stack([e.pre for e in entries], total=mb.batch.shape[0]),
                    rep.device,
                )
            self._emit("batch.cache_end", mb, rep_id=rep.id,
                       args={"hits": n_hits, "skip": True})
            self._emit("batch.feature_start", mb, rep_id=rep.id)
            logits = np.asarray(
                jax.block_until_ready(
                    accel.feature_from_cached(rep.params, batch, pre)
                )
            )
            self._emit("batch.feature_end", mb, rep_id=rep.id)
            return logits, True
        self._emit("batch.cache_end", mb, rep_id=rep.id, args={"hits": n_hits})
        if n_hits == 0:
            self._emit("batch.execute_start", mb, rep_id=rep.id)
            logits_dev, pre = accel.infer_with_preprocess(rep.params, batch)
            logits = np.asarray(jax.block_until_ready(logits_dev))
            self._emit("batch.execute_end", mb, rep_id=rep.id)
            self._insert_executor.submit(self._insert_misses, mb, pre, entries)
            return logits, False
        # mixed: block on the preprocess result explicitly (result_to_host is
        # a no-op copy on the already-host tree inside _cached_splice), so the
        # preprocess span measures the stage compute and the splice span only
        # the host row surgery + cache fill
        self._emit("batch.preprocess_start", mb, rep_id=rep.id)
        pre_host = result_to_host(accel.preprocess_stage(batch))
        self._emit("batch.preprocess_end", mb, rep_id=rep.id)
        self._emit("batch.splice_start", mb, rep_id=rep.id)
        pre = jax.device_put(
            self._cached_splice(mb, pre_host, entries),
            rep.device,
        )
        self._emit("batch.splice_end", mb, rep_id=rep.id)
        self._emit("batch.feature_start", mb, rep_id=rep.id)
        logits = np.asarray(
            jax.block_until_ready(accel.feature_stage(rep.params, batch, pre))
        )
        self._emit("batch.feature_end", mb, rep_id=rep.id)
        return logits, False

    def _splice_or_insert(self, rep, mb, pre, entries):
        """Route one non-all-hit pipelined cache batch's preprocess output.

        Mixed (some hits): the host splice path — hit rows must replace the
        freshly computed ones before the feature stage consumes them, and
        the spliced tree goes back to the device committed (same executable
        as the miss path, see `_run_cached`).  All-miss: the device tree is
        returned UNTOUCHED (the feature stage runs exactly the uncached
        staged composition, no host round trip on the critical path) and
        miss insertion happens on the pool's background insert thread —
        cache fill is bookkeeping, not part of the response, so it must not
        tax the 0%-duplicate workload.
        """
        if any(e is not None for e in entries):
            return jax.device_put(
                self._cached_splice(mb, pre, entries), rep.device
            )
        self._insert_executor.submit(self._insert_misses, mb, pre, entries)
        return pre

    def _cached_splice(self, mb, pre, entries):
        """Host splice of hits + cache insertion of misses on one batch.

        `pre` is the batched `preprocess_stage` output; `entries` the
        execution-time resolved CacheEntry-or-None per request.  Returns the
        host result tree the feature stage should consume: miss rows exactly
        as the stage computed them (the round trip through the host is
        bitwise-lossless), hit rows replaced by their cached payloads
        (whose canonical clouds already sit in the batch rows).  Miss rows
        with a content address populate the cache before the feature stage
        runs, so a concurrent duplicate can hit as early as possible.
        """
        pre = result_to_host(pre)
        for i, ent in enumerate(entries):
            if ent is not None:
                result_set_row(pre, i, ent.pre)
        self._insert_misses(mb, pre, entries)
        return pre

    def _insert_misses(self, mb, pre, entries):
        """Populate the cache with one batch's miss rows (best effort).

        `pre` may be a device tree (async all-miss path) or the already
        host-resident splice output; `result_to_host` is a no-op copy for
        the latter.  Failures are swallowed: the response already shipped
        (or ships independently), and a lost fill only costs a future hit.
        """
        try:
            pre = result_to_host(pre)
            for i, req in enumerate(mb.requests):
                hit = i < len(entries) and entries[i] is not None
                if not hit and req.cache_key is not None:
                    mb.cache.insert(req.cache_key, mb.batch[i], result_row(pre, i))
        except Exception:  # noqa: BLE001 — cache fill must never fail a batch
            pass

    def _record_success(
        self,
        rep: Replica,
        entry: _Entry,
        logits,
        dt: float,
        *,
        preprocess_skipped: bool = False,
    ):
        """Success bookkeeping shared by the sequential and pipelined paths.

        exactly-one-winner: an evicted-but-still-running replica can race
        its batch's re-dispatched copy to this future — only the completion
        that lands records the batch, so metrics count each logical
        micro-batch once.  n_batches is under the pool lock because the
        worker AND feature threads both count here under mixed schedules.
        """
        mb = entry.mb
        with self._lock:
            rep.n_batches += 1
            rep.inflight.pop(entry.seq, None)
        if try_set_result(entry.future, logits):
            self.metrics.record_batch(BatchRecord(
                bucket=mb.bucket,
                policy_key=(
                    mb.policy.quant,
                    mb.policy.backend,
                    mb.policy.pipeline,
                    getattr(mb.policy, "sharding", None),
                ),
                n_real=mb.n_real,
                batch_size=mb.batch.shape[0],
                replica_id=rep.id,
                duration_s=dt,
                preprocess_skipped=preprocess_skipped,
                batch_id=getattr(mb, "batch_id", -1),
            ))

    def _execute_pipelined(self, rep: Replica, entry: _Entry):
        """Two-stage execution of one batch on the replica.

        Preprocess runs on the worker thread (async dispatch, never blocked
        on), the feature MLPs on the feature thread.
        The worker returns as soon as the feature stage is handed off, so it
        starts preprocessing the NEXT queued batch while this one's feature
        MLPs run — the Mesorasi-style overlap, per replica.  Liveness: each
        stage executor has its own heartbeat pump (when enabled), so a
        wedged feature thread stops the feature beats and the replica is
        evicted, re-dispatching its in-flight batches — the same coverage
        the sequential path gets from the worker pump.  Straggler tracking
        is skipped for pipelined batches (overlapping spans would corrupt
        its single-slot timer); BatchRecord.duration_s is measured directly.
        """
        mb = entry.mb
        try:
            accel = get_accelerator(self.model_cfg, mb.policy)
            rep.acquire_handoff()  # double-buffer bound (released by feature stage)
            try:
                batch = jax.device_put(jnp.asarray(mb.batch), rep.device)
                entries: tuple = ()
                if mb.cache is not None:
                    # resolved on the worker thread: the pipelined worker runs
                    # one batch ahead of the feature thread, so late hits from
                    # the immediately preceding batch's insert may still miss
                    # — correctness is unaffected, only the skip opportunity
                    self._emit("batch.cache_start", mb, rep_id=rep.id)
                    entries = self._resolve_entries(mb)
                if mb.n_real > 0 and entries and all(e is not None for e in entries):
                    # cache skip composes with the pipeline: the worker hands
                    # the restacked payload straight to the feature thread —
                    # no preprocess dispatch at all for this batch
                    # (device_put: committed, same executable as miss batches;
                    # pre-staged entries stack device-side, no host restack)
                    pre = self._staged_stack(rep, entries, mb.batch.shape[0])
                    if pre is None:
                        pre = jax.device_put(
                            result_stack(
                                [e.pre for e in entries], total=mb.batch.shape[0]
                            ),
                            rep.device,
                        )
                    self._emit("batch.cache_end", mb, rep_id=rep.id,
                               args={"skip": True})
                    skipped = True
                else:
                    if mb.cache is not None:
                        self._emit("batch.cache_end", mb, rep_id=rep.id)
                    # async — the span measures the dispatch only; the stage's
                    # device time is charged to the feature span through the
                    # data dependency (block_until_ready)
                    self._emit("batch.preprocess_start", mb, rep_id=rep.id)
                    pre = accel.preprocess_stage(batch)  # async — hand off, don't block
                    self._emit("batch.preprocess_end", mb, rep_id=rep.id)
                    skipped = False
                if rep.heartbeat is not None:
                    rep.heartbeat.beat()
                rep.submit_feature(
                    self._finish_pipelined, rep, entry, accel, batch, pre, skipped,
                    entries,
                )
            except Exception:
                rep.release_handoff()  # the feature stage will never run for us
                raise
        except Exception as e:  # noqa: BLE001 — dispatch/executor failure
            with self._lock:
                was_inflight = rep.inflight.pop(entry.seq, None) is not None
            if was_inflight:  # else a concurrent evict() already re-dispatched
                self._retry(entry, rep.id, e)

    def _finish_pipelined(
        self,
        rep: Replica,
        entry: _Entry,
        accel,
        batch,
        pre,
        skipped: bool = False,
        entries: tuple = (),
    ):
        try:
            if entry.future.done():  # re-dispatched after eviction while queued
                with self._lock:
                    rep.inflight.pop(entry.seq, None)
                return
            # timed from HERE, not worker dispatch: queue wait behind earlier
            # batches' feature stages is pipeline overlap, not this batch's
            # cost (block_until_ready still charges any unfinished preprocess
            # through the data dependency)
            t0 = time.monotonic()
            try:
                mb = entry.mb
                if skipped:
                    feature = accel.feature_from_cached
                else:
                    if mb.cache is not None:
                        # mixed cache batch: host splice on the feature
                        # thread (blocks on the preprocess result through
                        # the transfer, same data dependency); all-miss
                        # batches keep the device tree + async insert
                        mixed = any(e is not None for e in entries)
                        if mixed:
                            self._emit("batch.splice_start", mb, rep_id=rep.id)
                        pre = self._splice_or_insert(rep, mb, pre, entries)
                        if mixed:
                            self._emit("batch.splice_end", mb, rep_id=rep.id)
                    feature = accel.feature_stage
                self._emit("batch.feature_start", mb, rep_id=rep.id)
                logits = np.asarray(
                    jax.block_until_ready(feature(rep.params, batch, pre))
                )
                self._emit("batch.feature_end", mb, rep_id=rep.id)
                dt = time.monotonic() - t0
                if rep.feature_heartbeat is not None:
                    rep.feature_heartbeat.beat()
                self._record_success(
                    rep, entry, logits, dt, preprocess_skipped=skipped
                )
            except Exception as e:  # noqa: BLE001 — any device/kernel failure
                with self._lock:
                    was_inflight = rep.inflight.pop(entry.seq, None) is not None
                if was_inflight:  # else evict() already re-dispatched it
                    self._retry(entry, rep.id, e)
        finally:
            rep.release_handoff()

    # -- lifecycle ------------------------------------------------------------

    def warmup(self, mb):
        """Compile + run one batch synchronously on EVERY alive replica.

        The runtime uses this to pre-trace each (bucket, policy) artifact —
        for pipelined policies this drives the two-stage path, so BOTH
        sub-artifacts are traced before real traffic arrives.  Each distinct
        (bucket, policy) batch is also REGISTERED: rejoin/add_replica replay
        the registered set on a fresh replica so it joins warm.
        """
        with self._lock:
            for i, m in enumerate(self._warmup_mbs):
                if m.bucket == mb.bucket and m.policy == mb.policy:
                    # same key, new static shape (a live max_batch
                    # reconfiguration): rejoins must replay the CURRENT
                    # shape, so the registration is replaced, not dropped
                    if m.batch.shape != mb.batch.shape:
                        self._warmup_mbs[i] = mb
                    break
            else:
                self._warmup_mbs.append(mb)
        futs = []
        for rep in self.alive_replicas():
            entry = _Entry(mb, Future(), attempts=self.max_retries, tried=frozenset())
            with self._lock:
                self._seq += 1
                entry.seq = self._seq
                rep.inflight[entry.seq] = entry
            rep.submit(self._execute, rep, entry)
            futs.append(entry.future)
        for f in futs:
            f.result(timeout=300)

    def shutdown(self):
        """Stop every replica (abandoning in-flight batches and cache fills)."""
        for rep in self.replicas:
            rep.shutdown()
        self._insert_executor.shutdown(wait=False)
