"""Replica pool — one accelerator replica per device, least-loaded dispatch.

Each `Replica` pins a copy of the model parameters to one `jax.devices()`
entry and executes micro-batches on its own single worker thread, so B
replicas give B-way compute overlap while every batch still runs on exactly
one device.  Health is delegated to `runtime/fault_tolerance.py`:

  * HeartbeatMonitor — a pump thread feeds a no-op beat through the
    replica's worker queue every timeout/4; a wedged worker (hung kernel,
    dead device) stops beating and the monitor evicts the replica.  The
    timeout must therefore exceed the worst-case batch latency.
  * StragglerMonitor — per-batch wall time; slow-but-alive replicas are
    recorded (metrics.straggler_events) for the operator, not evicted.

Eviction re-dispatches the replica's outstanding batches to the surviving
replicas, bounded by `max_retries` per batch; a batch that fails everywhere
fails its future with the last error.  Dispatch is least-loaded (smallest
in-flight count among alive replicas) — with shape buckets in play, queue
depth is a better load proxy than round-robin.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accelerator import get_accelerator
from repro.runtime.fault_tolerance import HeartbeatMonitor, StragglerMonitor
from repro.serve.metrics import BatchRecord, ServeMetrics
from repro.serve.queue import try_set_exception, try_set_result


class NoReplicaAvailable(RuntimeError):
    """Every replica is dead (or was already tried for this batch)."""


class _Entry:
    """One in-flight batch on one replica (retry bookkeeping)."""

    def __init__(self, mb, future: Future, attempts: int, tried: frozenset):
        self.mb = mb
        self.future = future
        self.attempts = attempts
        self.tried = tried
        self.seq = -1  # assigned under the pool lock at registration


class Replica:
    """One device-pinned executor: params copy + single worker thread."""

    def __init__(self, rid: int, device, params, *, on_straggler=None):
        self.id = rid
        self.device = device
        self.params = jax.device_put(params, device)
        self.alive = True
        self.n_batches = 0
        self.inflight: dict[int, _Entry] = {}
        self.straggler = StragglerMonitor(on_straggler=on_straggler)
        self.heartbeat: HeartbeatMonitor | None = None
        self._executor = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"pc2im-replica-{rid}"
        )

    def submit(self, fn, *args) -> Future:
        return self._executor.submit(fn, *args)

    def shutdown(self):
        self.alive = False
        if self.heartbeat is not None:
            self.heartbeat.stop()
        self._executor.shutdown(wait=False)


class ReplicaPool:
    """Least-loaded dispatch over per-device replicas with health tracking."""

    def __init__(
        self,
        model_cfg,
        params,
        *,
        n_replicas: int | None = None,
        devices=None,
        heartbeat_timeout_s: float | None = None,
        max_retries: int = 2,
        metrics: ServeMetrics | None = None,
    ):
        devices = list(devices) if devices is not None else jax.devices()
        n = n_replicas if n_replicas is not None else len(devices)
        if n < 1:
            raise ValueError("need at least one replica")
        self.model_cfg = model_cfg
        self.max_retries = max_retries
        self.metrics = metrics or ServeMetrics()
        self._lock = threading.Lock()
        self._seq = 0
        # round-robin devices when asked for more replicas than devices
        # (useful on CPU: several logical replicas exercise the dispatch path)
        self.replicas = [
            Replica(i, devices[i % len(devices)], params,
                    on_straggler=self.metrics.record_straggler)
            for i in range(n)
        ]
        self._pumps: list[threading.Thread] = []
        if heartbeat_timeout_s is not None:
            for rep in self.replicas:
                rep.heartbeat = HeartbeatMonitor(
                    heartbeat_timeout_s,
                    on_dead=lambda rid=rep.id: self.evict(rid, reason="heartbeat"),
                ).start()
                pump = threading.Thread(
                    target=self._pump, args=(rep,), daemon=True,
                    name=f"pc2im-hb-pump-{rep.id}",
                )
                pump.start()
                self._pumps.append(pump)

    # -- health ---------------------------------------------------------------

    def _pump(self, rep: Replica):
        """Route beats THROUGH the worker queue: a wedged worker stops
        beating, which is exactly the liveness signal we want."""
        period = rep.heartbeat.timeout_s / 4
        while rep.alive:
            try:
                rep.submit(rep.heartbeat.beat)
            except RuntimeError:  # executor shut down under us
                return
            time.sleep(period)

    def alive_replicas(self) -> list[Replica]:
        with self._lock:
            return [r for r in self.replicas if r.alive]

    def evict(self, rid: int, *, reason: str):
        """Mark a replica dead and re-dispatch its outstanding batches."""
        with self._lock:
            rep = self.replicas[rid]
            if not rep.alive:
                return
            rep.alive = False
            orphans = list(rep.inflight.values())
            rep.inflight.clear()
        self.metrics.record_eviction()
        rep.shutdown()
        for entry in orphans:
            if entry.future.done():
                continue
            self.metrics.record_retry()
            self._dispatch(
                entry.mb, entry.future, entry.attempts + 1,
                entry.tried | {rid},
                error=NoReplicaAvailable(f"replica {rid} evicted ({reason})"),
            )

    # -- dispatch -------------------------------------------------------------

    def submit(self, mb) -> Future:
        """Run one MicroBatch somewhere healthy; future yields np logits."""
        future: Future = Future()
        self._dispatch(mb, future, attempts=0, tried=frozenset())
        return future

    def _pick(self, tried: frozenset) -> Replica | None:
        with self._lock:
            candidates = [
                r for r in self.replicas if r.alive and r.id not in tried
            ]
            if not candidates:
                return None
            return min(candidates, key=lambda r: (len(r.inflight), r.id))

    def _dispatch(self, mb, future: Future, attempts: int, tried: frozenset, error=None):
        if attempts > self.max_retries:
            try_set_exception(future, error or NoReplicaAvailable("retry budget exhausted"))
            return
        rep = self._pick(tried)
        if rep is None:
            try_set_exception(
                future, error or NoReplicaAvailable(f"no replica left (tried {sorted(tried)})")
            )
            return
        entry = _Entry(mb, future, attempts, tried)
        with self._lock:
            lost_race = not rep.alive  # evict() won between _pick and here
            if not lost_race:
                self._seq += 1
                entry.seq = self._seq
                rep.inflight[entry.seq] = entry
        if lost_race:
            self._retry(entry, rep.id, NoReplicaAvailable("replica died"))
            return
        try:
            rep.submit(self._execute, rep, entry)
        except RuntimeError as e:  # executor shut down between pick and submit
            with self._lock:
                rep.inflight.pop(entry.seq, None)
            self._retry(entry, rep.id, e)

    def _retry(self, entry: _Entry, rid: int, err: Exception):
        if entry.future.done():
            return
        self.metrics.record_retry()
        self._dispatch(entry.mb, entry.future, entry.attempts + 1,
                       entry.tried | {rid}, error=err)

    def _execute(self, rep: Replica, entry: _Entry):
        if entry.future.done():  # e.g. already re-dispatched after eviction
            with self._lock:
                rep.inflight.pop(entry.seq, None)
            return
        mb = entry.mb
        try:
            accel = get_accelerator(self.model_cfg, mb.policy)
            rep.straggler.step_start()
            batch = jax.device_put(jnp.asarray(mb.batch), rep.device)
            logits = np.asarray(jax.block_until_ready(accel.infer(rep.params, batch)))
            dt = rep.straggler.step_end(rep.n_batches)
            rep.n_batches += 1
            if rep.heartbeat is not None:
                rep.heartbeat.beat()
            with self._lock:
                rep.inflight.pop(entry.seq, None)
            # exactly-one-winner: an evicted-but-still-running replica can
            # race its batch's re-dispatched copy to this future — only the
            # completion that lands records the batch, so metrics count each
            # logical micro-batch once
            if try_set_result(entry.future, logits):
                self.metrics.record_batch(BatchRecord(
                    bucket=mb.bucket,
                    policy_key=(mb.policy.quant, mb.policy.backend),
                    n_real=mb.n_real,
                    batch_size=mb.batch.shape[0],
                    replica_id=rep.id,
                    duration_s=dt,
                ))
        except Exception as e:  # noqa: BLE001 — any device/kernel failure
            with self._lock:
                rep.inflight.pop(entry.seq, None)
            self._retry(entry, rep.id, e)

    # -- lifecycle ------------------------------------------------------------

    def warmup(self, mb):
        """Compile + run one batch synchronously on EVERY alive replica (the
        runtime uses this to pre-trace each (bucket, policy) artifact)."""
        futs = []
        for rep in self.alive_replicas():
            entry = _Entry(mb, Future(), attempts=self.max_retries, tried=frozenset())
            with self._lock:
                self._seq += 1
                entry.seq = self._seq
                rep.inflight[entry.seq] = entry
            rep.submit(self._execute, rep, entry)
            futs.append(entry.future)
        for f in futs:
            f.result(timeout=300)

    def shutdown(self):
        for rep in self.replicas:
            rep.shutdown()
