"""SLO classes — named service levels with priority, deadline and shed policy.

PC2IM targets latency-bound perception, but not every request in a serving
mix is latency-bound: an interactive perception query (a vehicle waiting on
an obstacle answer) and a bulk re-indexing sweep can share one runtime, and
treating them identically makes the bulk traffic's backlog the interactive
traffic's tail latency.  An `SLOClass` names one service level and carries
everything the control plane needs to treat it differently:

  * `priority` — drain and batch-assembly order.  The admission queue
    releases higher-priority requests first (`serve/queue.py`), and the
    scheduler flushes higher-priority batch groups first
    (`serve/scheduler.py`).
  * `deadline_s` — the class's default per-request deadline; requests
    submitted without an explicit `timeout_s` inherit it.  Within one
    priority the queue drains earliest-deadline-first, so the classic
    EDF schedule emerges per class.
  * `sheddable` — the load-shedding contract.  Under backlog the queue
    rejects sheddable admissions with `Shed` (serve/queue.py) and, when
    completely full, evicts queued sheddable requests to admit
    higher-priority traffic; a non-sheddable class is only ever refused
    when the queue is full of equal-or-higher-priority work.
  * `max_wait_s` — an optional per-class bound on the scheduler's partial
    batch flush wait, so a latency-bound class never waits the global
    `max_wait_s` for stragglers to fill its batch.

Classes are frozen and hashable: the scheduler keys micro-batches by
`(bucket, policy, slo)`, so a batch never mixes classes — an interactive
batch never waits on a bulk flush timer, and per-batch metrics stay
attributable.  Two presets cover the common split (`INTERACTIVE`, `BULK`);
`DEFAULT` is the implicit class of unclassed traffic, shaped exactly like
the pre-SLO runtime behaved (priority 0, no deadline, sheddable).
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One named service level: priority, default deadline, shed policy.

    Frozen and hashable so it can participate in the scheduler's
    micro-batch key — requests batch together only within one class.
    `priority` is higher-wins (any ints; presets use 0 for default
    traffic); `deadline_s` is the default per-request timeout (None = no
    deadline); `sheddable=False` exempts the class from load shedding;
    `max_wait_s` optionally tightens the scheduler's partial-batch flush
    wait for this class.
    """

    name: str
    priority: int = 0
    deadline_s: float | None = None
    sheddable: bool = True
    max_wait_s: float | None = None

    def __post_init__(self):
        if not self.name:
            raise ValueError("SLOClass needs a non-empty name")
        if self.deadline_s is not None and self.deadline_s < 0:
            raise ValueError(f"deadline_s must be >= 0, got {self.deadline_s}")
        if self.max_wait_s is not None and self.max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got {self.max_wait_s}")


# The implicit class of unclassed traffic — shaped exactly like the pre-SLO
# runtime (priority 0, no default deadline, sheddable), so a runtime that
# never mentions SLO classes behaves as before.
DEFAULT = SLOClass("default")

# Presets for the common two-way split; callers needing different budgets
# construct their own SLOClass (any number of classes works).
INTERACTIVE = SLOClass(
    "interactive", priority=10, deadline_s=0.5, sheddable=False, max_wait_s=0.002
)
BULK = SLOClass("bulk", priority=-10, deadline_s=None, sheddable=True)


def drain_key(priority: int, deadline_t: float | None, seq: int) -> tuple:
    """Total drain order of one queued request — smaller drains first.

    Priority descending, then earliest absolute deadline (None sorts
    last), then admission order.  Shared by the admission queue's release
    loop and the tests that pin the property.
    """
    return (-priority, math.inf if deadline_t is None else deadline_t, seq)
