"""Content addressing for point clouds — quantized, noise-tolerant hashes.

The cross-request preprocess cache (serve/preprocess_cache.py) needs a key
that makes *repeat traffic collide on purpose*: consecutive lidar sweeps of
a static scene differ by sub-millimetre sensor jitter, yet recompute
FPS/kNN/partition from scratch without a content address.  `content_key`
quantizes every coordinate to a configurable grid step and hashes the
integer lattice coordinates, so two clouds whose points sit in the same
lattice cells produce the same digest.

Intentional invariance (and, just as important, intentional SENSITIVITY):

  * TOLERANT of float noise below the quantization step — a cloud whose
    coordinates are perturbed by less than half a `step` around their
    lattice cells keys identically (the static-scene / repeat-sweep case).
  * SENSITIVE to point permutation — preprocessing results index into the
    cloud by ROW, so two clouds with the same point set in different order
    have different neighborhoods.  A permutation-invariant key would serve
    wrong (row-misaligned) cached indices; see test_hashing.py.
  * SENSITIVE to translation, rotation and scale — the neighborhood
    structure the cache stores is expressed in absolute coordinates.
    Rigid-motion-tolerant reuse (delta reuse between consecutive moving
    sweeps) is a documented follow-on, not something to get silently and
    half-wrong from the hash.
  * SENSITIVE to shape and feature columns — (n, 3+F) clouds hash the full
    width, so feature-carrying duplicates only collide when the features
    match too (the cached canonical row is substituted into the batch on a
    hit, and the feature MLPs read every column).

Non-finite coordinates are mapped to fixed sentinels before quantization so
a NaN-carrying cloud still hashes deterministically instead of tripping
undefined float->int casts.
"""

from __future__ import annotations

import hashlib

import numpy as np

#: Default quantization step for content keys.  Clouds in this repo live on
#: the unit sphere (data/pointclouds.py), so 1e-3 is ~0.1% of the scene
#: scale — far above float32 noise, far below any real geometry change.
DEFAULT_QUANT_STEP = 1e-3

# finite sentinels for non-finite coordinates: far outside any real lattice
# cell, distinct per kind, stable across platforms
_NAN_CELL = np.int64(2**62)
_POSINF_CELL = np.int64(2**62 + 1)
_NEGINF_CELL = -np.int64(2**62 + 1)


def quantize_cloud(cloud: np.ndarray, step: float = DEFAULT_QUANT_STEP) -> np.ndarray:
    """Map float coordinates to integer lattice cells (the hashed value).

    Each value becomes `round(value / step)` as int64, so any two values
    within the same lattice cell — in particular, a value and its copy
    perturbed by noise < step/2 away from a cell boundary — quantize
    identically.  Non-finite values map to fixed sentinels.
    """
    if step <= 0:
        raise ValueError(f"quantization step must be > 0, got {step}")
    q = np.divide(cloud, step, dtype=np.float64)
    cells = np.round(q)
    finite = np.isfinite(q)
    if finite.all():
        # fast path: the hash sits on the serving submit path, and real
        # traffic is all-finite — skip the sentinel classification passes
        return cells.astype(np.int64)
    # classify BEFORE casting: float->int of nan/inf is platform-undefined
    out = np.where(np.isnan(q), _NAN_CELL, 0).astype(np.int64)
    out = np.where(q == np.inf, _POSINF_CELL, out)
    out = np.where(q == -np.inf, _NEGINF_CELL, out)
    out[finite] = cells[finite].astype(np.int64)
    return out


def content_key(cloud: np.ndarray, step: float = DEFAULT_QUANT_STEP) -> bytes:
    """Deterministic content address of one (n, 3+F) cloud.

    16-byte truncated SHA-256 digest over the cloud's shape, the
    quantization step and the quantized lattice cells, so the key changes
    whenever the shape, the tolerance or any cell assignment changes — and
    ONLY then.  See the module docstring for which invariances are
    intentional.  SHA-256 over e.g. blake2b because the key sits on the
    serving submit path and CPython's sha256 uses hardware SHA extensions
    (~2.5x faster here); 16 bytes keeps collisions negligible for any
    realistic cache population.
    """
    cells = quantize_cloud(cloud, step)
    # narrow to int32 when every cell fits: same information, half the bytes
    # through the digest (the hashed dtype is part of the key, so a cloud
    # with out-of-range cells can never collide with a narrowed one)
    if -(2**31) <= cells.min() and cells.max() < 2**31:
        cells = cells.astype(np.int32)
    h = hashlib.sha256()
    h.update(cells.dtype.str.encode())
    h.update(repr(cells.shape).encode())
    h.update(np.float64(step).tobytes())
    h.update(np.ascontiguousarray(cells).tobytes())
    return h.digest()[:16]
