"""ServingRuntime — the user-facing facade over queue -> scheduler -> pool.

    cfg = get_config("pointnet2-cls", smoke=True)
    accel = get_accelerator(cfg)
    params = accel.init(jax.random.PRNGKey(0))
    with ServingRuntime(cfg, params, RuntimeConfig(max_batch=8)) as rt:
        fut = rt.submit(cloud)                      # (n, 3+F) numpy, any n
        logits = fut.result()                       # cls: (C,);  seg: (n, C)
        print(rt.metrics.snapshot().format_row())

One runtime owns one model config; per-request `ExecutionPolicy` selects the
numeric path (fp32 vs SC W16A16) AND the execution schedule
(`pipeline="pipelined"` routes the batch group through the replica's
two-stage overlapped path — preprocess batch k+1 while batch k's feature
MLPs run).  The scheduler guarantees a micro-batch never mixes policies or
shape buckets, so every batch resolves to exactly one cached
`PC2IMAccelerator` artifact and one jit trace, and pipelined vs sequential
batch groups never share an artifact.

With `RuntimeConfig(cache_max_bytes=...)` set, a cross-request preprocess
cache sits in front of the scheduler: content-addressed duplicate clouds
skip the FPS/kNN/partition stage on repeat requests and enter the feature
stage directly (serve/preprocess_cache.py; `rt.cache_stats()` reports
residency, `rt.metrics.snapshot()` the hit rate and saved latency).
"""

from __future__ import annotations

import dataclasses
import threading

import jax
import numpy as np

from repro.core.accelerator import get_accelerator
from repro.core.policy import ExecutionPolicy, resolve_policy
from repro.serve.adapt.controller import AdaptiveConfig, AdaptiveController
from repro.serve.autoscaler import Autoscaler, AutoscalerConfig
from repro.serve.dispatch import ReplicaPool
from repro.serve.hashing import DEFAULT_QUANT_STEP
from repro.serve.metrics import ServeMetrics
from repro.serve.obs import MetricsServer, Reporter
from repro.serve.preprocess_cache import CacheConfig, PreprocessCache
from repro.serve.queue import AdmissionError, AdmissionQueue, Shed
from repro.serve.scheduler import BatchScheduler, MicroBatch, SchedulerConfig, bucket_for
from repro.serve.slo import SLOClass
from repro.serve.trace import TraceConfig, Tracer


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """All serving knobs in one hashable bundle.

    buckets=None serves every request at the model config's n_points (one
    static shape); pass e.g. (192, 256) to trade padding waste for a couple
    of extra jit traces.  heartbeat_timeout_s=None disables liveness
    eviction (single-process default); when set it must exceed the
    worst-case batch latency or healthy-but-slow replicas get evicted.
    cache_max_bytes > 0 enables the cross-request preprocess cache
    (serve/preprocess_cache.py): duplicate clouds — within cache_quant_step
    float noise — skip the preprocess stage on repeat requests.
    shed_threshold enables load shedding (serve/slo.py): sheddable classes
    are rejected with `Shed` once the queue backlog reaches it.
    autoscaler attaches the replica autoscaling control loop
    (serve/autoscaler.py): fault-evicted replicas rejoin warm and the pool
    grows/shrinks with queue depth.
    class_weights switches the queue drain from strict priority to
    deficit-round-robin across SLO classes (serve/queue.py): each class gets
    throughput proportional to its weight while EDF order holds within a
    class; None keeps the legacy strict-priority drain.
    oversize picks what happens to clouds larger than the biggest bucket:
    "subsample" (default) serves them at the largest bucket via random
    subsampling in pad_cloud, "reject" refuses them at submit with a
    ValueError naming the bucket set.
    prometheus_port attaches a live scrape endpoint (serve/obs.py
    MetricsServer, GET /metrics + /healthz); 0 binds an ephemeral port
    (read it from `rt.metrics_server.url`), None disables the listener.
    adaptive attaches the feedback control loop (serve/adapt/): observed
    size/arrival/occupancy distributions periodically retune buckets,
    max_batch and per-class batching patience through the pause-free
    warm-then-swap reconfiguration path.
    """

    max_batch: int = 8
    max_wait_s: float = 0.005
    max_queue: int = 256
    buckets: tuple[int, ...] | None = None
    n_replicas: int | None = None  # None -> one per carved device group
    # devices per replica: 1 (default) is the classic one-device replica;
    # > 1 carves jax.devices() into groups and each replica becomes a mesh
    # over its group — sharded ExecutionPolicy batches split across it
    # (must divide max_batch so sharded batches split evenly)
    devices_per_replica: int = 1
    heartbeat_timeout_s: float | None = None
    max_retries: int = 2
    default_timeout_s: float | None = None  # per-request deadline default
    cache_max_bytes: int = 0  # 0 disables the preprocess cache
    cache_quant_step: float = DEFAULT_QUANT_STEP  # content-hash lattice pitch
    shed_threshold: int | None = None  # backlog shed budget (None disables)
    autoscaler: AutoscalerConfig | None = None  # None = no control loop
    trace: TraceConfig | None = None  # None = tracing off (no tracer anywhere)
    report_interval_s: float | None = None  # periodic metrics reporter (None = off)
    class_weights: tuple[tuple[str, float], ...] | None = None  # DRR drain
    oversize: str = "subsample"  # or "reject": refuse clouds past max bucket
    prometheus_port: int | None = None  # scrape endpoint (0 = ephemeral port)
    prometheus_host: str = "127.0.0.1"
    adaptive: AdaptiveConfig | None = None  # None = no feedback loop

    def __post_init__(self):
        if self.buckets is not None:
            b = tuple(self.buckets)
            if not b:
                raise ValueError("buckets must be None or non-empty")
            if any(int(x) != x or x < 1 for x in b):
                raise ValueError(
                    f"buckets must be positive integers, got {b}"
                )
            if any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
                # a silently-sorted or deduplicated bucket list hides a
                # config typo that would otherwise change serving shapes
                raise ValueError(
                    f"buckets must be strictly increasing, got {b} "
                    "(sort them and remove duplicates)"
                )
        if self.oversize not in ("subsample", "reject"):
            raise ValueError(
                f'oversize must be "subsample" or "reject", got {self.oversize!r}'
            )
        if self.class_weights is not None:
            for name, w in self.class_weights:
                if w <= 0:
                    raise ValueError(
                        f"class_weights[{name!r}] must be > 0, got {w}"
                    )
        if self.prometheus_port is not None and self.prometheus_port < 0:
            raise ValueError("prometheus_port must be >= 0 or None")


class ServingRuntime:
    """The user-facing serving facade: queue -> scheduler -> replica pool.

    One instance owns one model config and one params copy per replica;
    `submit` admits ragged clouds and returns per-request futures, with
    the numeric mode and execution schedule chosen per request through an
    ExecutionPolicy.  Use as a context manager (`with ServingRuntime(...)`)
    or call start()/stop() explicitly; see the module docstring for a
    worked example.
    """

    def __init__(
        self,
        model_cfg,
        params,
        config: RuntimeConfig | None = None,
        *,
        policy: ExecutionPolicy | None = None,
        devices=None,
    ):
        self.model_cfg = model_cfg
        self.config = config or RuntimeConfig()
        if self.config.devices_per_replica < 1:
            raise ValueError("devices_per_replica must be >= 1")
        if self.config.max_batch % self.config.devices_per_replica != 0:
            # sharded batches split the static batch dim over the group; a
            # non-dividing group would need padding the mesh axis per batch
            raise ValueError(
                f"max_batch={self.config.max_batch} must be divisible by "
                f"devices_per_replica={self.config.devices_per_replica}"
            )
        self.default_policy = resolve_policy(model_cfg, policy)
        # validated strictly-increasing in RuntimeConfig.__post_init__ — a
        # malformed bucket list fails loudly there instead of being sorted
        self.buckets = tuple(self.config.buckets or (model_cfg.n_points,))
        self.metrics = ServeMetrics()
        self._reconfig_lock = threading.Lock()
        # constructed FIRST: every downstream component takes the tracer (or
        # None — the single-branch off path) at construction
        self.tracer = (
            Tracer(self.config.trace) if self.config.trace is not None else None
        )
        self.cache = (
            PreprocessCache(
                CacheConfig(
                    max_bytes=self.config.cache_max_bytes,
                    quant_step=self.config.cache_quant_step,
                ),
                tracer=self.tracer,
            )
            if self.config.cache_max_bytes > 0
            else None
        )
        self.queue = AdmissionQueue(
            self.config.max_queue,
            shed_threshold=self.config.shed_threshold,
            class_weights=(
                dict(self.config.class_weights)
                if self.config.class_weights is not None
                else None
            ),
            # full-queue evictions happen inside queue.submit, past the
            # runtime's admission accounting — the callback keeps the shed
            # counter (and the victim's class breakdown) truthful
            on_shed=lambda req: self.metrics.record_shed(req.slo.name),
            metrics=self.metrics,
            tracer=self.tracer,
        )
        self.pool = ReplicaPool(
            model_cfg,
            params,
            n_replicas=self.config.n_replicas,
            devices=devices,
            devices_per_replica=self.config.devices_per_replica,
            heartbeat_timeout_s=self.config.heartbeat_timeout_s,
            max_retries=self.config.max_retries,
            metrics=self.metrics,
            cache=self.cache,
            tracer=self.tracer,
        )
        self.autoscaler = (
            Autoscaler(self.pool, self.queue, self.config.autoscaler,
                       tracer=self.tracer, metrics=self.metrics)
            if self.config.autoscaler is not None
            else None
        )
        self.scheduler = BatchScheduler(
            self.queue,
            self.pool.submit,
            task=model_cfg.task,
            width=3 + model_cfg.in_features,
            buckets=self.buckets,
            config=SchedulerConfig(
                max_batch=self.config.max_batch,
                max_wait_s=self.config.max_wait_s,
                # two batches per replica keeps every replica busy (one
                # executing, one queued) while the REST of the backlog stays
                # in the admission queue, where priority/EDF/shedding apply
                max_inflight=2 * len(self.pool.replicas),
            ),
            metrics=self.metrics,
            cache=self.cache,
            tracer=self.tracer,
        )
        self.reporter = (
            Reporter(self.metrics, self.config.report_interval_s,
                     tracer=self.tracer)
            if self.config.report_interval_s is not None
            else None
        )
        self.controller = (
            AdaptiveController(self, self.config.adaptive)
            if self.config.adaptive is not None
            else None
        )
        self.metrics_server = (
            MetricsServer(
                self.metrics,
                host=self.config.prometheus_host,
                port=self.config.prometheus_port,
            )
            if self.config.prometheus_port is not None
            else None
        )
        self._started = False
        self._stopped = False

    # -- lifecycle ------------------------------------------------------------

    def start(self):
        """Start the scheduler thread (idempotent); returns self."""
        if self._stopped:
            # the drain thread is joined and the queue closed; a half-revived
            # runtime would accept submits it can never serve
            raise RuntimeError(
                "ServingRuntime cannot be restarted after stop(); "
                "construct a new instance"
            )
        if not self._started:
            self._started = True
            self.scheduler.start()
            if self.autoscaler is not None:
                self.autoscaler.start()
            if self.controller is not None:
                self.controller.start()
            if self.reporter is not None:
                self.reporter.start()
            if self.metrics_server is not None:
                self.metrics_server.start()
        return self

    def stop(self, drain: bool = True):
        """Stop accepting traffic; drain=True completes everything admitted.

        Safe on a never-started runtime too: the queue still closes (further
        submits raise QueueClosed) and anything admitted is cancelled rather
        than left hanging — without a scheduler nothing could complete it.
        """
        self._stopped = True
        if self.metrics_server is not None:
            self.metrics_server.stop()
        if self.reporter is not None:
            self.reporter.stop()
        if self.controller is not None:
            # stopped before the scheduler: a reconfigure racing shutdown
            # would warm artifacts on a pool the shutdown below tears down
            self.controller.stop()
        if self.autoscaler is not None:
            # stopped before the scheduler: a rejoin racing shutdown would
            # spin up a fresh replica the pool.shutdown() below never sees
            self.autoscaler.stop()
        if self._started:
            self.scheduler.stop(drain=drain)
            self._started = False
        else:
            for req in self.queue.close():
                req.future.cancel()
        self.pool.shutdown()

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    def warmup(self, policies: tuple[ExecutionPolicy | None, ...] = (None,)):
        """Pre-trace every (bucket, policy) artifact on every replica.

        The first real request then never pays compile latency (and load
        benchmarks measure serving, not tracing).  A policy with
        pipeline="pipelined" warms both staged sub-artifacts through the
        replica's two-stage path; with the preprocess cache enabled the
        warmup batch carries the cache too, so the staged
        preprocess/feature sub-artifacts every cache-aware batch uses are
        traced up front as well.
        """
        width = 3 + self.model_cfg.in_features
        for pol in policies:
            resolved = resolve_policy(self.model_cfg, pol)
            get_accelerator(self.model_cfg, resolved)  # build artifact once
            for bucket in self.buckets:
                mb = MicroBatch(
                    requests=(),
                    bucket=bucket,
                    policy=resolved,
                    batch=np.zeros((self.config.max_batch, bucket, width), np.float32),
                    # sharded batches never carry the cache (scheduler parity)
                    cache=self.cache if resolved.sharding is None else None,
                )
                self.pool.warmup(mb)
        return self

    def reconfigure(
        self,
        *,
        buckets: tuple[int, ...] | None = None,
        max_batch: int | None = None,
        max_wait_s: float | None = None,
        class_max_wait: tuple[tuple[str, float], ...] | None = None,
        policies: tuple[ExecutionPolicy | None, ...] = (None,),
    ) -> int:
        """Pause-free knob swap: warm new artifacts, then flip atomically.

        Traffic keeps flowing throughout.  New (bucket x policy) artifacts
        at the new (max_batch, bucket, width) shape are traced on every
        alive replica FIRST (and registered for rejoin replay), then the
        bucket list and a version-bumped `SchedulerConfig` are swapped in:
        the drain loop reads its config exactly once per tick and a
        request's bucket is fixed at admission, so no in-flight batch ever
        mixes old and new shapes — old-bucket requests finish on the still-
        cached old artifacts while new admissions use the new ones.

        Returns the scheduler-config version the swap produced.  Serialized
        by a lock: concurrent reconfigurations apply one at a time.
        """
        with self._reconfig_lock:
            cur = self.scheduler.config
            new_mb = cur.max_batch if max_batch is None else int(max_batch)
            if new_mb < 1:
                raise ValueError(f"max_batch must be >= 1, got {new_mb}")
            if new_mb % self.config.devices_per_replica != 0:
                raise ValueError(
                    f"max_batch={new_mb} must be divisible by "
                    f"devices_per_replica={self.config.devices_per_replica}"
                )
            if max_wait_s is not None and max_wait_s <= 0:
                raise ValueError(f"max_wait_s must be > 0, got {max_wait_s}")
            new_buckets = self.buckets
            if buckets is not None:
                b = tuple(int(x) for x in buckets)
                if not b or any(x < 1 for x in b) or any(
                    b[i] >= b[i + 1] for i in range(len(b) - 1)
                ):
                    raise ValueError(
                        f"buckets must be non-empty, positive and strictly "
                        f"increasing, got {b}"
                    )
                new_buckets = b
            if class_max_wait is not None:
                for name, w in class_max_wait:
                    if w <= 0:
                        raise ValueError(
                            f"class_max_wait for {name!r} must be > 0, got {w}"
                        )
            if new_buckets != self.buckets or new_mb != cur.max_batch:
                # warm BEFORE the swap so the first post-swap batch never
                # pays compile latency; pool.warmup is synchronous on every
                # alive replica and registers the shape for rejoin replay
                width = 3 + self.model_cfg.in_features
                for pol in policies:
                    resolved = resolve_policy(self.model_cfg, pol)
                    for bucket in new_buckets:
                        self.pool.warmup(MicroBatch(
                            requests=(),
                            bucket=bucket,
                            policy=resolved,
                            batch=np.zeros((new_mb, bucket, width), np.float32),
                            cache=self.cache if resolved.sharding is None else None,
                        ))
            # the swap: bucket list first (affects only NEW admissions —
            # already-admitted requests carry their bucket), then the
            # scheduler config in one atomic reference assignment
            self.buckets = new_buckets
            applied = self.scheduler.apply_config(dataclasses.replace(
                cur,
                max_batch=new_mb,
                max_wait_s=cur.max_wait_s if max_wait_s is None else max_wait_s,
                class_max_wait=(
                    cur.class_max_wait if class_max_wait is None
                    else tuple(class_max_wait)
                ),
            ))
            return applied.version

    # -- traffic --------------------------------------------------------------

    def submit(
        self,
        cloud: np.ndarray,
        *,
        policy: ExecutionPolicy | None = None,
        timeout_s: float | None = None,
        slo: SLOClass | None = None,
    ):
        """Admit one (n, 3+F) cloud; returns a Future.

        Raises AdmissionError (reason "queue_full" / "closed" / "shed") as
        synchronous backpressure; the future fails with DeadlineExceeded if
        the request's deadline passes before it is batched.  `slo` selects
        the service class (serve/slo.py) — priority in drain/flush order,
        the default deadline when timeout_s is not given, and whether the
        request may be load-shed under backlog.
        """
        cloud = np.asarray(cloud, np.float32)
        if (
            cloud.ndim != 2
            or cloud.shape[0] < 1  # pad_cloud cannot fit an empty cloud
            or cloud.shape[1] != 3 + self.model_cfg.in_features
        ):
            raise ValueError(
                f"cloud must be (n >= 1, {3 + self.model_cfg.in_features}), "
                f"got {cloud.shape}"
            )
        resolved = (
            self.default_policy
            if policy is None
            else resolve_policy(self.model_cfg, policy)
        )
        if timeout_s is None and (slo is None or slo.deadline_s is None):
            # the class's default deadline wins over the runtime-wide one;
            # queue.submit applies slo.deadline_s itself when timeout_s
            # stays None
            timeout_s = self.config.default_timeout_s
        buckets = self.buckets  # one read: stable across a concurrent swap
        if self.config.oversize == "reject" and cloud.shape[0] > buckets[-1]:
            raise ValueError(
                f"cloud has {cloud.shape[0]} points but the largest bucket "
                f"is {buckets[-1]} (buckets={buckets}); pass "
                'oversize="subsample" to serve it at the largest bucket, '
                "or add a bucket >= the cloud size"
            )
        bucket = bucket_for(cloud.shape[0], buckets)
        slo_name = slo.name if slo is not None else None
        # every request gets its trace id HERE (head sampling decides once;
        # None = untraced and no span event is ever emitted for it)
        trace_id = self.tracer.new_trace() if self.tracer is not None else None
        if trace_id is not None:
            self.tracer.emit(
                "request.submit",
                trace_id=trace_id,
                slo=slo_name or "default",
                args={"n": int(cloud.shape[0]), "bucket": bucket},
            )
        # cache probe material (bucket fit + content hash) is deliberately
        # NOT computed here: admission must stay O(1) per request on the
        # client thread, so the scheduler computes it at assembly, where it
        # overlaps batch execution (scheduler._dispatch)
        try:
            fut = self.queue.submit(
                cloud,
                bucket=bucket,
                policy=resolved,
                timeout_s=timeout_s,
                slo=slo,
                trace_id=trace_id,
            )
        except Shed:
            self.metrics.record_shed(slo_name)
            if trace_id is not None:
                self.tracer.emit(
                    "request.shed",
                    trace_id=trace_id,
                    slo=slo_name or "default",
                    args={"reason": "admission"},
                )
            raise
        except AdmissionError as e:
            self.metrics.record_rejected(slo_name)
            if trace_id is not None:
                self.tracer.emit(
                    "request.rejected",
                    trace_id=trace_id,
                    slo=slo_name or "default",
                    args={"reason": e.reason},
                )
            raise
        self.metrics.record_submitted(slo_name)
        self.metrics.record_arrival(cloud.shape[0], slo_name)
        return fut

    def infer(self, cloud: np.ndarray, **kwargs) -> np.ndarray:
        """Blocking convenience wrapper around submit()."""
        return self.submit(cloud, **kwargs).result()

    def cache_stats(self):
        """PreprocessCacheStats of the runtime's cache, None when disabled.

        Complements `metrics.snapshot()` (which carries hit/miss counters
        and the saved-latency estimate) with residency: entries, resident
        bytes, evictions, oversize refusals.
        """
        return self.cache.stats() if self.cache is not None else None

    def __repr__(self):
        return (
            f"ServingRuntime({self.model_cfg.name}, buckets={self.buckets}, "
            f"replicas={len(self.pool.replicas)}, max_batch={self.config.max_batch}, "
            f"devices={['+'.join(str(d) for d in r.devices) for r in self.pool.replicas]})"
        )


def make_serving_runtime(
    model_cfg,
    params=None,
    config: RuntimeConfig | None = None,
    *,
    policy: ExecutionPolicy | None = None,
    seed: int = 0,
    devices=None,
) -> ServingRuntime:
    """One-call constructor: params default to a fresh init (demo/bench)."""
    if params is None:
        params = get_accelerator(model_cfg, policy).init(jax.random.PRNGKey(seed))
    return ServingRuntime(model_cfg, params, config, policy=policy, devices=devices)
