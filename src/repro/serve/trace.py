"""Request-lifecycle span tracer for the serving stack.

Every request admitted through :meth:`ServingRuntime.submit` is assigned a
trace id, and each lifecycle edge — admission, lane enqueue, drain, batch
assembly, cache probes, dispatch, the preprocess/feature execution stages
and exactly one terminal outcome — emits a typed :class:`TraceEvent` into a
fixed-capacity ring buffer.  Batch-level spans carry their own ids and are
linked to member requests through the ``members`` arg of ``batch.assembled``;
control-plane activity (autoscaler actions, replica eviction/rejoin, chaos
faults, straggler beats, cache churn) folds into the same stream so a single
export shows the request timeline against the events that shaped it.

Design constraints, in order:

* **Off is free.**  Components hold ``tracer: Tracer | None`` and every
  instrumentation site is a single ``if tracer is not None`` branch — no
  event objects, no lock traffic, nothing allocated when tracing is off.
* **On is cheap.**  ``emit`` builds one small frozen dataclass and appends
  it to a ``deque(maxlen=capacity)`` under one uncontended lock; the ring
  silently drops the oldest events instead of growing or blocking.
* **The event namespace is closed.**  Every event name is declared exactly
  once in :data:`EVENTS`; ``emit`` rejects undeclared names and a tier-1
  test greps the serve sources to keep call sites and registry in sync.

Sampling is head-based and per trace id: :meth:`Tracer.new_trace` decides
once, at submit, whether a request is traced (``None`` means sampled out)
and every later hook site skips request-scoped events for untraced requests.
Batch and control-plane events are not sampled — they are few and they are
the frame of reference the sampled requests hang off.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import threading
import time

# --------------------------------------------------------------------------
# Event-name registry.  CLOSED: every name emitted anywhere in repro.serve
# (subpackages included) must be declared here exactly once
# (tests/test_trace.py grep-enforces both directions).  Names are
# "<scope>.<edge>"; scopes are:
#   request.* — events on one request's span (trace_id set)
#   batch.*   — events on one micro-batch's span (batch_id set)
#   replica.* / scale.* / chaos.* / cache.* / adapt.* — control-plane stream
# --------------------------------------------------------------------------
EVENTS: tuple[str, ...] = (
    # request lifecycle
    "request.submit",
    "request.admitted",
    "request.enqueued",
    "request.drained",
    "request.assembled",
    "request.cache_peek",
    "request.cache_lookup",
    # request terminals (exactly one per trace; see TERMINAL_EVENTS)
    "request.completed",
    "request.rejected",
    "request.shed",
    "request.expired",
    "request.failed",
    # micro-batch span
    "batch.assembled",
    "batch.dispatched",
    "batch.retry",
    "batch.execute_start",
    "batch.execute_end",
    "batch.cache_start",
    "batch.cache_end",
    "batch.preprocess_start",
    "batch.preprocess_end",
    "batch.splice_start",
    "batch.splice_end",
    "batch.feature_start",
    "batch.feature_end",
    "batch.completed",
    "batch.failed",
    # control plane
    "replica.evicted",
    "replica.rejoin",
    "replica.straggler",
    "scale.up",
    "scale.down",
    "scale.rejoin",
    "scale.error",
    "chaos.kill",
    "chaos.wedge",
    "chaos.slow",
    "cache.insert",
    "cache.evict",
    # adaptive control plane (serve/adapt): knob proposals and actuations
    "adapt.propose",
    "adapt.apply",
    "adapt.rollback",
)

_EVENT_SET = frozenset(EVENTS)

#: The five mutually-exclusive ways a request span ends.  A well-formed
#: trace contains exactly one of these per trace id (asserted in tests and
#: checked by :func:`repro.serve.obs.request_timelines`).
TERMINAL_EVENTS = frozenset(
    {
        "request.completed",
        "request.rejected",
        "request.shed",
        "request.expired",
        "request.failed",
    }
)


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Knobs for the tracer; absence of a config means tracing is off.

    ``capacity`` bounds the ring buffer (oldest events drop first — sized
    for minutes of serving at default rates).  ``sample`` is the head-
    sampling fraction in [0, 1]: the keep/drop decision is made once per
    trace id at submit, deterministically, so a request is either fully
    traced or fully absent — never a partial span.
    """

    capacity: int = 65536
    sample: float = 1.0


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One typed edge in the trace stream.

    ``t`` is ``time.monotonic()`` seconds.  ``trace_id``/``batch_id``/
    ``replica_id`` are -1 when the event is not scoped to that axis; ``slo``
    is the SLO class name for request-scoped events and ``args`` carries
    small event-specific details (hit flags, member lists, reasons).
    """

    name: str
    t: float
    trace_id: int = -1
    batch_id: int = -1
    replica_id: int = -1
    slo: str = ""
    args: dict | None = None


def _keep(trace_id: int, sample: float) -> bool:
    """Deterministic head-sampling decision for one trace id.

    Fibonacci-hashes the id so bursts of consecutive ids spread uniformly
    over [0, 1) instead of aliasing against the arrival pattern.
    """
    if sample >= 1.0:
        return True
    if sample <= 0.0:
        return False
    return ((trace_id * 2654435761) & 0xFFFFFFFF) / 2**32 < sample


class Tracer:
    """Thread-safe ring-buffered sink for :class:`TraceEvent` records.

    One instance per :class:`~repro.serve.runtime.ServingRuntime`; shared by
    the queue, scheduler, replica pool, cache, autoscaler and chaos injector.
    All methods are safe to call from any thread.
    """

    def __init__(self, config: TraceConfig | None = None):
        self.config = config or TraceConfig()
        self._lock = threading.Lock()
        self._deque = collections.deque(maxlen=max(1, self.config.capacity))
        self._trace_ids = itertools.count(1)
        self._batch_ids = itertools.count(1)
        self._emitted = 0

    def new_trace(self) -> int | None:
        """Allocate a trace id, or ``None`` if head-sampled out.

        Called exactly once per submitted request.  A ``None`` return means
        no event of this request's span will ever be emitted; hook sites
        gate on ``req.trace_id is not None``.
        """
        tid = next(self._trace_ids)
        return tid if _keep(tid, self.config.sample) else None

    def next_batch_id(self) -> int:
        """Allocate a fresh micro-batch span id (batch spans never sample)."""
        return next(self._batch_ids)

    def emit(
        self,
        name: str,
        *,
        trace_id: int = -1,
        batch_id: int = -1,
        replica_id: int = -1,
        slo: str = "",
        args: dict | None = None,
        t: float | None = None,
    ) -> None:
        """Append one event to the ring; ``name`` must be declared in EVENTS.

        ``t`` defaults to ``time.monotonic()`` now; pass it explicitly when
        the edge was observed earlier than the emit (e.g. timestamps taken
        inside a lock and emitted after release).
        """
        if name not in _EVENT_SET:
            raise ValueError(f"undeclared trace event {name!r}")
        ev = TraceEvent(
            name,
            time.monotonic() if t is None else t,
            trace_id,
            batch_id,
            replica_id,
            slo,
            args,
        )
        with self._lock:
            self._deque.append(ev)
            self._emitted += 1

    def events(self) -> list[TraceEvent]:
        """Snapshot the ring contents, oldest first."""
        with self._lock:
            return list(self._deque)

    def clear(self) -> None:
        """Drop all buffered events (ids keep counting up)."""
        with self._lock:
            self._deque.clear()

    @property
    def emitted(self) -> int:
        """Total events emitted since construction (including dropped)."""
        with self._lock:
            return self._emitted

    @property
    def dropped(self) -> int:
        """Events lost to ring overflow so far."""
        with self._lock:
            return max(0, self._emitted - len(self._deque))

    def __len__(self) -> int:
        with self._lock:
            return len(self._deque)
