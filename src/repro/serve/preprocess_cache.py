"""Cross-request preprocess cache — content-addressed neighborhood reuse.

PC2IM's thesis is eliminating *repetitive* work in point-cloud
preprocessing: APD-CIM kills redundant distance reads, the Ping-Pong-MAX CAM
keeps temporary distances in-situ.  This module is the serving-level analog.
Identical and near-identical clouds — static scenes, consecutive lidar
sweeps — used to recompute FPS/kNN/partition from scratch on every request;
here, the first computation of a cloud's neighborhoods is stored under a
content address (serve/hashing.py: a quantized-coordinate hash, tolerant of
float noise below the quantization step, so repeat sweeps of a static scene
collide on purpose) and every later request with the same address skips the
preprocess stage entirely and enters the feature stage directly.

What an entry stores, and why a hit is exact:

  * `row` — the CANONICAL fitted cloud: the (bucket, 3+F) batch row the
    first request was padded to.  On a hit the scheduler substitutes this
    row into the micro-batch, so the feature stage consumes exactly the
    cloud the cached neighborhoods were computed from and the hit response
    is bitwise-equal to an uncached recomputation of that canonical cloud.
    (For exact duplicates — same padded bytes — that IS the request's own
    recomputation; for sub-step-noise near-duplicates it is the static
    scene's response, which is the documented tolerance.)
  * `pre` — the per-row preprocess payload: one host PreprocessResult per
    SA stage (`core.engine.result_row` of the batched
    `accel.preprocess_stage` output), re-stacked per micro-batch by the
    dispatch layer.

The cache is a byte-budgeted LRU: insertions account every array byte of
the payload plus the canonical row (`core.engine.result_nbytes`), and the
least-recently-hit entries are evicted until the budget holds.  Entries are
keyed by `(bucket, resolved ExecutionPolicy, content digest)` — the FULL
policy, so results cached under one (quant, backend, pipeline) artifact are
never served to a different policy (see tests/test_serve_runtime.py).
Everything is thread-safe: the scheduler probes, replica workers insert.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict

import numpy as np

from repro.core.engine import result_nbytes
from repro.serve.hashing import DEFAULT_QUANT_STEP, content_key


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """Knobs of the preprocess cache.

    max_bytes bounds resident payload bytes (canonical rows included);
    quant_step is the content-hash lattice pitch — noise below half a step
    around a lattice cell keys identically (serve/hashing.py documents the
    full invariance contract).
    """

    max_bytes: int = 64 * 2**20
    quant_step: float = DEFAULT_QUANT_STEP


@dataclasses.dataclass(frozen=True)
class PreprocessCacheStats:
    """Snapshot of one PreprocessCache (see `PreprocessCache.stats`).

    hits/misses count lookups; insertions/evictions/oversize count entry
    turnover (oversize = payloads larger than the whole budget, refused);
    entries/bytes describe what is resident right now.
    """

    hits: int
    misses: int
    insertions: int
    evictions: int
    oversize: int
    entries: int
    bytes: int
    max_bytes: int

    @property
    def hit_rate(self) -> float:
        """hits / lookups, 0.0 before any lookup happened."""
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class CacheEntry:
    """One cached cloud: canonical fitted row + per-row preprocess payload.

    Immutable after construction (arrays are read-only copies), so entries
    can be handed to replica threads without copying or locking; `nbytes`
    is the exact retained size the LRU budget accounts.
    """

    __slots__ = ("key", "row", "pre", "nbytes", "hits")

    def __init__(self, key: tuple, row: np.ndarray, pre):
        self.key = key
        self.row = np.array(row, copy=True)
        self.row.setflags(write=False)
        self.pre = _freeze(pre)
        self.nbytes = result_nbytes(self.pre) + self.row.nbytes
        self.hits = 0


def _freeze(tree):
    """Deep-copy a result tree to read-only numpy (detach from batch buffers).

    Cached payloads must not alias the batched preprocess output they were
    sliced from: the splice path mutates those buffers row-wise, and views
    would both see the mutation and pin the whole batch alive.
    """
    import jax

    def one(x):
        arr = np.array(x, copy=True)
        arr.setflags(write=False)
        return arr

    return jax.tree.map(one, tree)


class PreprocessCache:
    """Byte-budgeted, thread-safe LRU over content-addressed preprocess results.

    The serving runtime owns one instance per model config; the scheduler
    calls `key_for` + `peek` while assembling micro-batches, the replica
    pool re-`lookup`s at execution time (catching entries inserted after
    assembly) and calls `insert` after a miss batch finishes its preprocess
    stage.  `evict`/`clear` give operators explicit control; `stats()` is
    the introspection surface benchmarks and tests assert on.
    """

    def __init__(self, config: CacheConfig | None = None, *, tracer=None):
        self.config = config or CacheConfig()
        if self.config.max_bytes < 0:
            raise ValueError(f"max_bytes must be >= 0, got {self.config.max_bytes}")
        self.tracer = tracer  # Tracer | None — insert/evict churn events
        self._lock = threading.Lock()
        self._entries: OrderedDict[tuple, CacheEntry] = OrderedDict()
        self._bytes = 0
        self._hits = 0
        self._misses = 0
        self._insertions = 0
        self._evictions = 0
        self._oversize = 0

    # -- addressing -----------------------------------------------------------

    def key_for(self, bucket: int, policy, row: np.ndarray) -> tuple:
        """Content address of one fitted batch row under one execution policy.

        Pure (no counters, no LRU effect): safe to call on the client thread
        at admission so the hash cost never serializes in the scheduler's
        drain loop.  `policy` must be the RESOLVED ExecutionPolicy — the full
        policy keys the entry, so no cached result can cross policies.
        """
        return (bucket, policy, content_key(row, self.config.quant_step))

    # -- lookup / insert ------------------------------------------------------

    def lookup(self, key: tuple) -> CacheEntry | None:
        """Hit test one key: returns the entry (refreshing LRU) or None.

        Counts exactly one hit or miss — call once per request per
        execution; use `peek` for speculative probes.
        """
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self._misses += 1
                return None
            self._entries.move_to_end(key)
            self._hits += 1
            entry.hits += 1
            return entry

    def peek(self, key: tuple) -> CacheEntry | None:
        """Read one entry with NO side effects (no counters, no LRU refresh).

        The scheduler peeks at assembly time to substitute a hit's canonical
        row into the batch; the dispatch layer's execution-time `lookup` is
        the authoritative, counted probe (it runs after every earlier batch
        on the replica has inserted, so it sees strictly more entries).
        """
        with self._lock:
            return self._entries.get(key)

    def insert(self, key: tuple, row: np.ndarray, pre) -> CacheEntry | None:
        """Store one cloud's preprocess payload under its content address.

        `row` is the fitted batch row the payload was computed from (becomes
        the canonical row substituted on later hits); `pre` is the per-row
        result tree (`core.engine.result_row` of the batched stage output).
        Inserting an existing key replaces the entry (refreshing it); a
        payload larger than the whole budget is refused (counted, returns
        None).  Evicts least-recently-hit entries until the budget holds.
        """
        entry = CacheEntry(key, row, pre)
        n_evicted = 0
        with self._lock:
            if entry.nbytes > self.config.max_bytes:
                self._oversize += 1
                return None
            old = self._entries.pop(key, None)
            if old is not None:
                self._bytes -= old.nbytes
            self._entries[key] = entry
            self._bytes += entry.nbytes
            self._insertions += 1
            while self._bytes > self.config.max_bytes:
                _, evicted = self._entries.popitem(last=False)
                self._bytes -= evicted.nbytes
                self._evictions += 1
                n_evicted += 1
            resident = self._bytes
        # emit outside the cache lock: the tracer has its own
        if self.tracer is not None:
            self.tracer.emit(
                "cache.insert",
                args={"nbytes": entry.nbytes, "resident": resident},
            )
            if n_evicted:
                self.tracer.emit(
                    "cache.evict",
                    args={"n": n_evicted, "reason": "budget"},
                )
        return entry

    def top_entries(self, k: int) -> list[CacheEntry]:
        """The k hottest resident entries (most hits, then most recent).

        The replica pool pre-stages these on a rejoining replica's device
        (`Replica.stage_entry`) so its first all-hit batches skip the host
        restack.  No counters move and LRU order is untouched — this is an
        introspection read, not a use.
        """
        with self._lock:
            ranked = sorted(
                enumerate(self._entries.values()),
                key=lambda ie: (-ie[1].hits, -ie[0]),  # hits desc, then MRU
            )
            return [e for _, e in ranked[: max(0, k)]]

    # -- management -----------------------------------------------------------

    def evict(self, key: tuple) -> bool:
        """Explicitly drop one entry; True if it was resident."""
        with self._lock:
            entry = self._entries.pop(key, None)
            if entry is not None:
                self._bytes -= entry.nbytes
                self._evictions += 1
        if entry is not None and self.tracer is not None:
            self.tracer.emit("cache.evict", args={"n": 1, "reason": "explicit"})
        return entry is not None

    def clear(self) -> None:
        """Drop every entry (counters keep their history)."""
        with self._lock:
            self._evictions += len(self._entries)
            self._entries.clear()
            self._bytes = 0

    def stats(self) -> PreprocessCacheStats:
        """Counters + residency in one immutable snapshot."""
        with self._lock:
            return PreprocessCacheStats(
                hits=self._hits,
                misses=self._misses,
                insertions=self._insertions,
                evictions=self._evictions,
                oversize=self._oversize,
                entries=len(self._entries),
                bytes=self._bytes,
                max_bytes=self.config.max_bytes,
            )

    def __len__(self) -> int:
        return len(self._entries)

    def __repr__(self) -> str:
        s = self.stats()
        return (
            f"PreprocessCache(entries={s.entries}, bytes={s.bytes}/{s.max_bytes}, "
            f"hit_rate={s.hit_rate:.2f})"
        )
