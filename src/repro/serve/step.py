"""Serving steps: prefill + batched greedy/sampled decode."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.families import get_family_api


def make_serve_fns(cfg: ModelConfig):
    api = get_family_api(cfg)

    def prefill_step(params, batch, s_max: int):
        return api["prefill"](params, cfg, batch, s_max)

    def decode_step(params, state, batch):
        """One token for the whole batch; greedy next token included so the
        lowered artifact covers the sampling epilogue."""
        logits, state = api["decode_step"](params, cfg, state, batch)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return logits, next_tok, state

    def generate(params, batch, *, steps: int, s_max: int):
        """Greedy autoregressive generation (examples/serving driver)."""
        logits, state = prefill_step(params, batch, s_max)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out = [tok]
        for _ in range(steps - 1):
            _, tok, state = decode_step(params, state, {"token": tok})
            out.append(tok)
        return jnp.concatenate(out, axis=1)

    return {"prefill": prefill_step, "decode": decode_step, "generate": generate}
