"""Serving steps: prefill + batched greedy/sampled decode.

`make_serve_fns(cfg, policy=...)` pins every step to one ExecutionPolicy
(quant mode / kernel backend); policy=None uses the config's default.
Policies are plain arguments — concurrent servers with different policies
share nothing."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import ExecutionPolicy, resolve_policy
from repro.models.families import get_family_api


def make_serve_fns(cfg: ModelConfig, policy: ExecutionPolicy | None = None):
    """Serving closures {"prefill", "decode", "generate"} for one LM config.

    Every closure is pinned to the resolved ExecutionPolicy, so concurrent
    servers holding different policies (e.g. fp32 next to SC W16A16) share
    no state and can never observe each other's numeric mode.
    """
    api = get_family_api(cfg)
    policy = resolve_policy(cfg, policy)

    def prefill_step(params, batch, s_max: int):
        return api["prefill"](params, cfg, batch, s_max, policy=policy)

    def decode_step(params, state, batch):
        """One token for the whole batch.

        The greedy next token is included so the lowered artifact covers
        the sampling epilogue.
        """
        logits, state = api["decode_step"](params, cfg, state, batch, policy=policy)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return logits, next_tok, state

    def generate(params, batch, *, steps: int, s_max: int):
        """Greedy autoregressive generation (examples/serving driver)."""
        logits, state = prefill_step(params, batch, s_max)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out = [tok]
        for _ in range(steps - 1):
            _, tok, state = decode_step(params, state, {"token": tok})
            out.append(tok)
        return jnp.concatenate(out, axis=1)

    return {"prefill": prefill_step, "decode": decode_step, "generate": generate}
