"""Reductions and exporters over the serving trace stream.

serve/trace.py records *edges*; this module turns them into answers.  The
reductions are pure functions over a ``list[TraceEvent]`` snapshot (grab one
with ``tracer.events()``) so they can run offline, in tests, or inside the
periodic :class:`Reporter` without touching the hot path:

* :func:`request_timelines` — group the stream per trace id into
  :class:`RequestTimeline` records: the ordered span, its terminal outcome,
  end-to-end latency, and a per-stage attribution (queue wait, assembly,
  dispatch, cache, preprocess, splice, feature, execute, finalize) derived
  purely from event timestamps.  The stage edges telescope, so their sum
  approaches the measured e2e latency; the gap is reported as ``residual_s``.
* :func:`trace_problems` — structural lint: every trace must carry exactly
  one terminal event and per-trace timestamps must be monotonic.
* :func:`stage_breakdown` — per-SLO-class p50/p95 of each stage over the
  completed timelines (the operator-facing "where does my latency go").
* :func:`batch_crosscheck` — reconcile batch spans against the
  independently-timed ``BatchRecord.duration_s`` wall-clock, keyed by the
  ``batch_id`` both sides carry.
* :func:`to_chrome_trace` / :func:`write_chrome_trace` — Chrome-trace /
  Perfetto JSON: request lanes, batch lanes with stage slices, and a
  control-plane lane, all on one shared clock.
* :func:`prometheus_text` — Prometheus text exposition of a
  :class:`~repro.serve.metrics.MetricsSnapshot`.

Two stateful exporters live at the end: :class:`Reporter`, a daemon thread
on :class:`~repro.serve.runtime.ServingRuntime` that periodically snapshots
the metrics and hands a one-line summary to a sink, and
:class:`MetricsServer`, an opt-in stdlib HTTP listener serving the live
:func:`prometheus_text` exposition at ``GET /metrics`` (plus ``/healthz``).
"""

from __future__ import annotations

import dataclasses
import json
import sys
import threading

import numpy as np

from repro.serve.metrics import BatchRecord, MetricsSnapshot
from repro.serve.trace import TERMINAL_EVENTS, TraceEvent

#: Stage names of the per-request attribution, in pipeline order.  Edge
#: definitions live in `_stages_for`; every stage is the time between two
#: recorded trace edges, so the stages of one request telescope from submit
#: to terminal (micro-gaps between edges surface as `residual_s`).
STAGES: tuple[str, ...] = (
    "queue",  # request.submit -> request.drained (admission-lane wait)
    "assembly",  # request.drained -> request.assembled (batch formation)
    "dispatch",  # request.assembled -> first execution edge of the batch
    "cache",  # batch.cache_start -> batch.cache_end (probe + restack)
    "preprocess",  # batch.preprocess_start -> batch.preprocess_end
    "splice",  # batch.splice_start -> batch.splice_end (hit-row merge)
    "feature",  # batch.feature_start -> batch.feature_end
    "execute",  # batch.execute_start -> batch.execute_end (fused path)
    "finalize",  # last execution edge -> request terminal (result scatter)
)

_PAIRED = ("cache", "preprocess", "splice", "feature", "execute")


@dataclasses.dataclass(frozen=True)
class RequestTimeline:
    """One request's reconstructed span: ordered events + stage attribution.

    ``events`` is the trace-id's slice of the stream in emission order;
    ``terminal`` is the span's terminal event name (None if the trace was
    truncated by ring overflow); ``e2e_s`` is terminal minus submit.
    ``stages`` maps stage name -> seconds for the stages this request
    actually passed through, and ``residual_s`` is ``e2e_s`` minus their sum
    — the unattributed micro-gaps between recorded edges (close to zero for
    a well-formed sequential trace).
    """

    trace_id: int
    slo: str
    events: tuple[TraceEvent, ...]
    terminal: str | None
    e2e_s: float | None
    batch_id: int
    stages: dict[str, float]
    residual_s: float | None

    @property
    def completed(self) -> bool:
        """True when the span terminated in ``request.completed``."""
        return self.terminal == "request.completed"


def _first(events, name) -> TraceEvent | None:
    """The first event named `name`, or None."""
    for ev in events:
        if ev.name == name:
            return ev
    return None


def _stage_pairs(batch_events: list[TraceEvent]) -> dict[str, tuple[float, float]]:
    """Pair each `batch.<stage>_start` with its next `_end`, keeping the last.

    A retried batch executes its stages more than once; the last complete
    pair is the attempt whose results the requests actually received.
    """
    pairs: dict[str, tuple[float, float]] = {}
    open_t: dict[str, float] = {}
    for ev in batch_events:
        scope, _, edge = ev.name.partition(".")
        if scope != "batch":
            continue
        stage, sep, side = edge.rpartition("_")
        if not sep or stage not in _PAIRED:
            continue
        if side == "start":
            open_t[stage] = ev.t
        elif side == "end" and stage in open_t:
            pairs[stage] = (open_t.pop(stage), ev.t)
    return pairs


def _stages_for(
    req_events: list[TraceEvent],
    batch_events: list[TraceEvent],
    terminal: TraceEvent | None,
) -> dict[str, float]:
    """Per-stage seconds for one request, from its own + its batch's edges."""
    stages: dict[str, float] = {}
    submit = _first(req_events, "request.submit")
    drained = _first(req_events, "request.drained")
    assembled = _first(req_events, "request.assembled")
    if submit is not None and drained is not None:
        stages["queue"] = max(0.0, drained.t - submit.t)
    if drained is not None and assembled is not None:
        stages["assembly"] = max(0.0, assembled.t - drained.t)
    pairs = _stage_pairs(batch_events)
    if pairs:
        first_start = min(t0 for t0, _ in pairs.values())
        last_end = max(t1 for _, t1 in pairs.values())
        if assembled is not None:
            stages["dispatch"] = max(0.0, first_start - assembled.t)
        for stage, (t0, t1) in pairs.items():
            stages[stage] = max(0.0, t1 - t0)
        if terminal is not None:
            stages["finalize"] = max(0.0, terminal.t - last_end)
    return stages


def request_timelines(events: list[TraceEvent]) -> dict[int, RequestTimeline]:
    """Group a trace-stream snapshot into per-request timelines.

    Returns trace id -> :class:`RequestTimeline`, covering every trace id
    that appears in `events`.  Batch-level stage edges are joined to member
    requests through the ``batch_id`` their ``request.assembled`` /
    ``request.completed`` events carry.
    """
    by_trace: dict[int, list[TraceEvent]] = {}
    by_batch: dict[int, list[TraceEvent]] = {}
    for ev in events:
        if ev.trace_id != -1:
            by_trace.setdefault(ev.trace_id, []).append(ev)
        elif ev.batch_id != -1 and ev.name.startswith("batch."):
            by_batch.setdefault(ev.batch_id, []).append(ev)
    out: dict[int, RequestTimeline] = {}
    for tid, revs in by_trace.items():
        terminal = next((e for e in revs if e.name in TERMINAL_EVENTS), None)
        submit = _first(revs, "request.submit")
        batch_id = next((e.batch_id for e in revs if e.batch_id != -1), -1)
        e2e = (
            terminal.t - submit.t
            if terminal is not None and submit is not None
            else None
        )
        stages = _stages_for(revs, by_batch.get(batch_id, []), terminal)
        residual = e2e - sum(stages.values()) if e2e is not None else None
        slo = next((e.slo for e in revs if e.slo), "default")
        out[tid] = RequestTimeline(
            trace_id=tid,
            slo=slo,
            events=tuple(revs),
            terminal=terminal.name if terminal is not None else None,
            e2e_s=e2e,
            batch_id=batch_id,
            stages=stages,
            residual_s=residual,
        )
    return out


def trace_problems(events: list[TraceEvent]) -> list[str]:
    """Structural lint of a trace snapshot; empty list means well-formed.

    Flags traces with zero or multiple terminal events and traces whose
    timestamps regress in emission order (the lifecycle edges of one request
    are causally ordered, so per-trace time must be monotonic).  Traces
    whose ``request.submit`` fell off the ring are skipped — a truncated
    head is a capacity artifact, not a protocol violation.
    """
    problems: list[str] = []
    by_trace: dict[int, list[TraceEvent]] = {}
    for ev in events:
        if ev.trace_id != -1:
            by_trace.setdefault(ev.trace_id, []).append(ev)
    for tid, revs in sorted(by_trace.items()):
        if _first(revs, "request.submit") is None:
            continue  # head truncated by ring overflow
        terminals = [e.name for e in revs if e.name in TERMINAL_EVENTS]
        if not terminals:
            problems.append(f"trace {tid}: no terminal event")
        elif len(terminals) > 1:
            problems.append(f"trace {tid}: multiple terminals {terminals}")
        for a, b in zip(revs, revs[1:]):
            if b.t < a.t:
                problems.append(
                    f"trace {tid}: time regressed {a.name}@{a.t:.6f} -> "
                    f"{b.name}@{b.t:.6f}"
                )
                break
    return problems


@dataclasses.dataclass(frozen=True)
class StageBreakdown:
    """Per-SLO-class latency attribution reduced from completed timelines.

    ``per_class`` maps SLO class name -> stage name -> (p50_s, p95_s) over
    the completed requests of that class; ``counts`` maps class name -> how
    many completed timelines the percentiles were computed from.
    """

    per_class: dict[str, dict[str, tuple[float, float]]]
    counts: dict[str, int]

    def format_rows(self) -> str:
        """Human-readable table: one line per (class, stage) with p50/p95."""
        lines = []
        for slo in sorted(self.per_class):
            lines.append(f"[{slo}] n={self.counts[slo]}")
            for stage in STAGES:
                if stage not in self.per_class[slo]:
                    continue
                p50, p95 = self.per_class[slo][stage]
                lines.append(
                    f"  {stage:<10} p50={p50 * 1e3:8.3f}ms p95={p95 * 1e3:8.3f}ms"
                )
        return "\n".join(lines)


def stage_breakdown(events: list[TraceEvent]) -> StageBreakdown:
    """Reduce a trace snapshot to per-SLO-class stage percentiles.

    Only completed requests contribute — shed/rejected/expired spans never
    reached the stages being attributed.  Stages a class never passed
    through (e.g. ``splice`` without a cache) are absent from its map.
    """
    samples: dict[str, dict[str, list[float]]] = {}
    counts: dict[str, int] = {}
    for tl in request_timelines(events).values():
        if not tl.completed:
            continue
        counts[tl.slo] = counts.get(tl.slo, 0) + 1
        per = samples.setdefault(tl.slo, {})
        for stage, dur in tl.stages.items():
            per.setdefault(stage, []).append(dur)
    per_class = {
        slo: {
            stage: (
                float(np.percentile(vals, 50)),
                float(np.percentile(vals, 95)),
            )
            for stage, vals in stages.items()
        }
        for slo, stages in samples.items()
    }
    return StageBreakdown(per_class=per_class, counts=counts)


@dataclasses.dataclass(frozen=True)
class BatchCheck:
    """One batch's span-vs-record reconciliation (see `batch_crosscheck`).

    ``span_s`` is last execution edge minus first (the trace's view of the
    batch's on-replica time); ``stage_sum_s`` sums the individual stage
    pairs; ``recorded_s`` is the dispatch layer's independently-timed
    ``BatchRecord.duration_s``; ``rel_err`` is |span - recorded| / recorded.
    """

    batch_id: int
    span_s: float
    stage_sum_s: float
    recorded_s: float
    rel_err: float


def batch_crosscheck(
    events: list[TraceEvent], records: tuple[BatchRecord, ...]
) -> list[BatchCheck]:
    """Reconcile trace batch spans against BatchRecord wall-clock timings.

    Joins on the ``batch_id`` both sides carry and returns one
    :class:`BatchCheck` per batch that has BOTH a complete trace span and a
    record.  The two clocks are independent code paths over the same work,
    so a large ``rel_err`` means the instrumentation edges drifted from
    what the dispatch timer actually brackets.  Sequential batches should
    reconcile tightly; pipelined records time only the feature-thread
    portion (splice+feature), so compare against ``stage_sum_s`` there.
    """
    by_batch: dict[int, list[TraceEvent]] = {}
    for ev in events:
        if ev.batch_id != -1 and ev.name.startswith("batch."):
            by_batch.setdefault(ev.batch_id, []).append(ev)
    out: list[BatchCheck] = []
    for rec in records:
        if rec.batch_id == -1:
            continue
        pairs = _stage_pairs(by_batch.get(rec.batch_id, []))
        if not pairs or rec.duration_s <= 0:
            continue
        span = max(t1 for _, t1 in pairs.values()) - min(
            t0 for t0, _ in pairs.values()
        )
        stage_sum = sum(t1 - t0 for t0, t1 in pairs.values())
        out.append(
            BatchCheck(
                batch_id=rec.batch_id,
                span_s=span,
                stage_sum_s=stage_sum,
                recorded_s=rec.duration_s,
                rel_err=abs(span - rec.duration_s) / rec.duration_s,
            )
        )
    return out


# -- Chrome trace / Perfetto export -------------------------------------------

_PID_REQUESTS = 1
_PID_BATCHES = 2
_PID_CONTROL = 3


def to_chrome_trace(events: list[TraceEvent]) -> dict:
    """Render a trace snapshot as a Chrome-trace (Perfetto-loadable) object.

    Three process lanes share one clock: ``requests`` (one thread row per
    trace id — a complete "X" slice from submit to terminal plus instant
    marks for every edge), ``batches`` (one row per batch id — "X" slices
    per execution stage plus assembly/dispatch/retry instants) and
    ``control-plane`` (one row per replica — eviction/rejoin/scale/chaos/
    cache instants).  Timestamps are microseconds of ``time.monotonic``;
    load the JSON in https://ui.perfetto.dev or chrome://tracing.
    """
    out: list[dict] = [
        {
            "ph": "M",
            "pid": pid,
            "name": "process_name",
            "args": {"name": label},
        }
        for pid, label in (
            (_PID_REQUESTS, "requests"),
            (_PID_BATCHES, "batches"),
            (_PID_CONTROL, "control-plane"),
        )
    ]
    timelines = request_timelines(events)
    for tl in timelines.values():
        submit = _first(list(tl.events), "request.submit")
        if submit is not None and tl.e2e_s is not None:
            out.append(
                {
                    "ph": "X",
                    "pid": _PID_REQUESTS,
                    "tid": tl.trace_id,
                    "name": f"{tl.terminal} [{tl.slo}]",
                    "ts": submit.t * 1e6,
                    "dur": tl.e2e_s * 1e6,
                    "args": {"batch_id": tl.batch_id, **tl.stages},
                }
            )
        for ev in tl.events:
            out.append(
                {
                    "ph": "i",
                    "pid": _PID_REQUESTS,
                    "tid": tl.trace_id,
                    "name": ev.name,
                    "ts": ev.t * 1e6,
                    "s": "t",
                    "args": ev.args or {},
                }
            )
    by_batch: dict[int, list[TraceEvent]] = {}
    for ev in events:
        if ev.name.startswith("batch.") and ev.batch_id != -1:
            by_batch.setdefault(ev.batch_id, []).append(ev)
    for bid, bevs in by_batch.items():
        for stage, (t0, t1) in _stage_pairs(bevs).items():
            out.append(
                {
                    "ph": "X",
                    "pid": _PID_BATCHES,
                    "tid": bid,
                    "name": stage,
                    "ts": t0 * 1e6,
                    "dur": (t1 - t0) * 1e6,
                }
            )
        for ev in bevs:
            if ev.name.endswith(("_start", "_end")):
                continue  # already rendered as an "X" slice above
            out.append(
                {
                    "ph": "i",
                    "pid": _PID_BATCHES,
                    "tid": bid,
                    "name": ev.name,
                    "ts": ev.t * 1e6,
                    "s": "t",
                    "args": ev.args or {},
                }
            )
    for ev in events:
        scope = ev.name.partition(".")[0]
        if scope in ("request", "batch"):
            continue
        out.append(
            {
                "ph": "i",
                "pid": _PID_CONTROL,
                "tid": max(0, ev.replica_id),
                "name": ev.name,
                "ts": ev.t * 1e6,
                "s": "p",
                "args": ev.args or {},
            }
        )
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(path, events: list[TraceEvent]) -> int:
    """Write `to_chrome_trace(events)` as JSON at `path`; returns event count."""
    doc = to_chrome_trace(events)
    with open(path, "w") as f:
        json.dump(doc, f)
    return len(doc["traceEvents"])


# -- Prometheus text exposition -----------------------------------------------


def _prom(lines, name, kind, help_text, samples):
    """Append one metric family (# HELP/# TYPE + samples) to `lines`."""
    lines.append(f"# HELP {name} {help_text}")
    lines.append(f"# TYPE {name} {kind}")
    for labels, value in samples:
        label_s = (
            "{" + ",".join(f'{k}="{v}"' for k, v in labels.items()) + "}"
            if labels
            else ""
        )
        lines.append(f"{name}{label_s} {value}")


def prometheus_text(snap: MetricsSnapshot) -> str:
    """Render one MetricsSnapshot in the Prometheus text exposition format.

    Counters become ``pc2im_serve_*_total`` (with a ``slo`` label for the
    per-class breakdown and a ``replica`` label for straggler attribution);
    latency percentiles, throughput, occupancy and the high-water-mark
    gauges come out as gauges.  The string ends with a newline as the
    format requires; scrape adapters can serve it verbatim.
    """
    lines: list[str] = []
    for field, help_text in (
        ("submitted", "Requests admitted"),
        ("completed", "Requests completed"),
        ("rejected", "Requests refused at admission"),
        ("expired", "Requests failed on deadline"),
        ("failed", "Requests failed by execution errors"),
        ("shed", "Requests load-shed"),
        ("retries", "Batch re-dispatches after replica failure"),
        ("evictions", "Replicas evicted"),
        ("rejoins", "Replicas re-admitted"),
        ("batches", "Executed micro-batches with real traffic"),
        ("straggler_events", "Slow-but-alive replica batches"),
        ("cache_hits", "Preprocess-cache lookup hits"),
        ("cache_misses", "Preprocess-cache lookup misses"),
        ("preprocess_skipped", "All-hit batches that skipped preprocess"),
    ):
        _prom(
            lines,
            f"pc2im_serve_{field}_total",
            "counter",
            help_text,
            [({}, getattr(snap, field))],
        )
    _prom(
        lines,
        "pc2im_serve_latency_seconds",
        "gauge",
        "End-to-end latency percentiles",
        [
            ({"quantile": "0.5"}, snap.latency_p50_s),
            ({"quantile": "0.95"}, snap.latency_p95_s),
            ({"quantile": "0.99"}, snap.latency_p99_s),
        ],
    )
    for field, help_text in (
        ("throughput_rps", "Completed requests per second"),
        ("mean_occupancy", "Mean real-request fill of executed batches"),
        ("queue_depth_hwm", "Max total queue depth ever observed"),
        ("inflight_hwm", "Max concurrently-inflight micro-batches"),
        ("cache_saved_s", "Estimated batch seconds saved by cache skips"),
    ):
        _prom(
            lines,
            f"pc2im_serve_{field}",
            "gauge",
            help_text,
            [({}, getattr(snap, field))],
        )
    if snap.stragglers_by_replica:
        _prom(
            lines,
            "pc2im_serve_stragglers_total",
            "counter",
            "Straggler events per replica",
            [({"replica": rid}, n) for rid, n in snap.stragglers_by_replica],
        )
    if snap.per_class:
        for field in ("submitted", "completed", "shed", "expired", "rejected"):
            _prom(
                lines,
                f"pc2im_serve_class_{field}_total",
                "counter",
                f"Per-SLO-class {field} requests",
                [({"slo": cs.name}, getattr(cs, field)) for cs in snap.per_class],
            )
        _prom(
            lines,
            "pc2im_serve_class_latency_seconds",
            "gauge",
            "Per-SLO-class latency percentiles",
            [
                ({"slo": cs.name, "quantile": q}, v)
                for cs in snap.per_class
                for q, v in (("0.5", cs.latency_p50_s), ("0.95", cs.latency_p95_s))
            ],
        )
        _prom(
            lines,
            "pc2im_serve_class_depth_hwm",
            "gauge",
            "Per-SLO-class admission-lane depth high-water mark",
            [({"slo": cs.name}, cs.depth_hwm) for cs in snap.per_class],
        )
    return "\n".join(lines) + "\n"


# -- periodic reporter --------------------------------------------------------


class Reporter:
    """Daemon thread that periodically reports one runtime's metrics.

    Every ``interval_s`` it snapshots the :class:`ServeMetrics`, appends the
    tracer's buffer occupancy when tracing is on, and hands the one-line
    summary to ``sink`` (default: write to stderr).  The latest snapshot
    stays readable at :attr:`last_snapshot` so operators can poll state
    without parsing the sink output.  `report_once()` drives a single tick
    synchronously for tests.
    """

    def __init__(self, metrics, interval_s: float, *, sink=None, tracer=None):
        if interval_s <= 0:
            raise ValueError(f"interval_s must be > 0, got {interval_s}")
        self.metrics = metrics
        self.interval_s = interval_s
        self.sink = sink if sink is not None else self._default_sink
        self.tracer = tracer
        self.last_snapshot: MetricsSnapshot | None = None
        self.ticks = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    @staticmethod
    def _default_sink(line: str) -> None:
        print(line, file=sys.stderr)

    def report_once(self) -> str:
        """One reporting tick: snapshot, format, sink; returns the line."""
        snap = self.metrics.snapshot()
        self.last_snapshot = snap
        self.ticks += 1
        line = f"[serve] {snap.format_row()}"
        if self.tracer is not None:
            line += (
                f" trace={len(self.tracer)}ev"
                f" dropped={self.tracer.dropped}"
            )
        self.sink(line)
        return line

    def start(self) -> "Reporter":
        """Spawn the reporting thread (idempotent); returns self."""
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._run, daemon=True, name="pc2im-reporter"
            )
            self._thread.start()
        return self

    def stop(self) -> None:
        """Stop the reporting thread, emitting one final tick."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
            self.report_once()  # final state, so short runs still report

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.report_once()


class MetricsServer:
    """Opt-in live scrape endpoint over one runtime's ServeMetrics.

    A stdlib ``ThreadingHTTPServer`` (no dependencies) serving
    ``GET /metrics`` — :func:`prometheus_text` of a fresh snapshot — and
    ``GET /healthz`` for liveness probes.  Lifecycle mirrors
    :class:`Reporter`: the runtime starts it in ``start()`` and tears it
    down in ``stop()``.  ``port=0`` binds an ephemeral port; read the
    resolved address from :attr:`url` after :meth:`start`.
    """

    def __init__(self, metrics, *, host: str = "127.0.0.1", port: int = 0):
        self.metrics = metrics
        self.host = host
        self.port = port
        self._server = None
        self._thread: threading.Thread | None = None

    @property
    def url(self) -> str:
        """Base URL of the listener (port resolved after start())."""
        return f"http://{self.host}:{self.port}"

    def start(self) -> "MetricsServer":
        """Bind and serve in a daemon thread (idempotent); returns self."""
        if self._server is not None:
            return self
        import http.server

        metrics = self.metrics

        class Handler(http.server.BaseHTTPRequestHandler):
            """Two-route scrape handler: /metrics (Prometheus) + /healthz."""

            def do_GET(self):  # noqa: N802 — http.server API
                """Serve one GET; unknown paths get 404."""
                if self.path == "/metrics":
                    body = prometheus_text(metrics.snapshot()).encode()
                    ctype = "text/plain; version=0.0.4; charset=utf-8"
                elif self.path == "/healthz":
                    body = b"ok\n"
                    ctype = "text/plain; charset=utf-8"
                else:
                    self.send_error(404)
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):
                """Silenced — periodic scrapes must not spam stderr."""

        self._server = http.server.ThreadingHTTPServer(
            (self.host, self.port), Handler
        )
        self.port = self._server.server_address[1]
        self._thread = threading.Thread(
            target=self._server.serve_forever, daemon=True,
            name="pc2im-metrics-http",
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        """Shut the listener down and join the serving thread."""
        if self._server is None:
            return
        self._server.shutdown()
        self._server.server_close()
        self._server = None
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
