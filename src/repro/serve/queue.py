"""Bounded admission queue — per-request futures, deadlines, backpressure.

The front door of the serving runtime.  Every client request becomes a
`Request` with its own `concurrent.futures.Future`; admission is bounded so
a traffic spike turns into an explicit, reasoned rejection
(`AdmissionError.reason`) instead of unbounded memory growth and collapsing
tail latency.  Deadlines are absolute `time.monotonic()` instants carried on
the request; the scheduler fails expired requests with `DeadlineExceeded`
the moment it sees them, so a queue that fell behind sheds exactly the work
whose answer nobody is still waiting for.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import threading
import time
from concurrent.futures import Future, InvalidStateError

import numpy as np

from repro.core.policy import ExecutionPolicy


def try_set_result(future: Future, result) -> bool:
    """Cancel-safe, exactly-one-winner future completion.

    A client may cancel() a queued future at any moment, and eviction
    re-dispatch can race a slow-but-alive replica to the same future —
    set_result must never raise into (and kill) a scheduler or replica
    thread, and the returned bool arbitrates which completion 'won' (only
    the winner records metrics)."""
    try:
        future.set_result(result)
        return True
    except InvalidStateError:  # cancelled, or the other completion won
        return False


def try_set_exception(future: Future, err: Exception) -> bool:
    """Fail a future if still open; see try_set_result for the race rules."""
    try:
        future.set_exception(err)
        return True
    except InvalidStateError:
        return False


class AdmissionError(RuntimeError):
    """Request rejected at the front door; `.reason` says why."""

    def __init__(self, reason: str, detail: str = ""):
        self.reason = reason
        super().__init__(f"request rejected ({reason})" + (f": {detail}" if detail else ""))


class QueueFull(AdmissionError):
    """Admission bound hit — explicit backpressure, never a silent drop."""

    def __init__(self, depth: int, max_depth: int):
        super().__init__("queue_full", f"depth {depth} >= max_depth {max_depth}")
        self.depth = depth
        self.max_depth = max_depth


class QueueClosed(AdmissionError):
    """The runtime stopped accepting traffic (stop() closed the queue)."""

    def __init__(self):
        super().__init__("closed", "runtime is stopped")


class DeadlineExceeded(TimeoutError):
    """Set on a request's future when its deadline passed before execution."""


@dataclasses.dataclass
class Request:
    """One admitted inference request.

    bucket is the static n_points shape the scheduler chose for this cloud;
    together with the resolved policy it forms the micro-batching key, so a
    batch never mixes shapes or execution policies (each key maps to exactly
    one jitted artifact).
    """

    id: int
    cloud: np.ndarray  # (n, 3 + F) float32
    n_orig: int  # original row count (pre pad/subsample)
    bucket: int  # static n_points shape this request is padded to
    policy: ExecutionPolicy  # RESOLVED policy (hashable batch key)
    deadline_t: float | None  # absolute time.monotonic() instant, None = no deadline
    submit_t: float
    future: Future
    # preprocess-cache probe: the bucket-fitted batch row and its content
    # address.  Computed lazily by the scheduler at assembly when caching is
    # enabled (admission stays O(1) on the client thread); tests may fill
    # them in ahead of time.  Stay None when caching is off — assembly then
    # falls back to pad_cloud and never touches the cache.
    fitted: np.ndarray | None = None  # (bucket, 3 + F) pad_cloud row
    cache_key: tuple | None = None  # PreprocessCache.key_for address

    @property
    def key(self) -> tuple:
        """Micro-batching key — requests batch together iff keys match."""
        return (self.bucket, self.policy)

    def expired(self, now: float | None = None) -> bool:
        """Whether the deadline passed (checked at every scheduling stage)."""
        if self.deadline_t is None:
            return False
        return (time.monotonic() if now is None else now) > self.deadline_t


class AdmissionQueue:
    """Bounded FIFO of Requests with blocking drain for the scheduler."""

    def __init__(self, max_depth: int):
        if max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        self.max_depth = max_depth
        self._items: collections.deque[Request] = collections.deque()
        self._cond = threading.Condition()
        self._closed = False
        self._ids = itertools.count()

    def submit(
        self,
        cloud: np.ndarray,
        *,
        bucket: int,
        policy: ExecutionPolicy,
        timeout_s: float | None = None,
        fitted: np.ndarray | None = None,
        cache_key: tuple | None = None,
    ) -> Future:
        """Admit one cloud; returns its future or raises AdmissionError.

        Backpressure is synchronous: a full queue rejects HERE (QueueFull),
        never silently drops, so open-loop clients observe the shed load.
        `fitted`/`cache_key` carry the preprocess-cache probe when the
        runtime computed one (see Request).
        """
        now = time.monotonic()
        req = Request(
            id=-1,
            cloud=cloud,
            n_orig=cloud.shape[0],
            bucket=bucket,
            policy=policy,
            deadline_t=(now + timeout_s) if timeout_s is not None else None,
            submit_t=now,
            future=Future(),
            fitted=fitted,
            cache_key=cache_key,
        )
        with self._cond:
            if self._closed:
                raise QueueClosed()
            if len(self._items) >= self.max_depth:
                raise QueueFull(len(self._items), self.max_depth)
            req.id = next(self._ids)
            self._items.append(req)
            self._cond.notify()
        return req.future

    def drain(self, max_items: int, timeout_s: float) -> list[Request]:
        """Pop up to max_items requests, blocking up to timeout_s for the first.

        Returns [] on timeout or when the queue is closed and empty.
        """
        deadline = time.monotonic() + timeout_s
        with self._cond:
            while not self._items and not self._closed:
                remaining = deadline - time.monotonic()
                if remaining <= 0 or not self._cond.wait(remaining):
                    break
            out = []
            while self._items and len(out) < max_items:
                out.append(self._items.popleft())
            return out

    def depth(self) -> int:
        """Number of requests currently waiting (the backpressure signal)."""
        with self._cond:
            return len(self._items)

    @property
    def closed(self) -> bool:
        """Whether close() ran — further submits raise QueueClosed."""
        with self._cond:
            return self._closed

    def close(self) -> list[Request]:
        """Refuse new admissions and return whatever was still queued.

        The runtime flushes the returned requests through one final
        scheduling pass (drain=True) or cancels them (drain=False).
        """
        with self._cond:
            self._closed = True
            left = list(self._items)
            self._items.clear()
            self._cond.notify_all()
            return left
