"""Bounded admission queue — per-class lanes, deadlines, backpressure, shedding.

The front door of the serving runtime.  Every client request becomes a
`Request` with its own `concurrent.futures.Future`; admission is bounded so
a traffic spike turns into an explicit, reasoned rejection
(`AdmissionError.reason`) instead of unbounded memory growth and collapsing
tail latency.  Deadlines are absolute `time.monotonic()` instants carried on
the request; the scheduler fails expired requests with `DeadlineExceeded`
the moment it sees them, so a queue that fell behind sheds exactly the work
whose answer nobody is still waiting for.

Requests carry an `SLOClass` (serve/slo.py) and wait in one lane per class.
`drain` releases requests in priority order, earliest-deadline-first within
a priority — so under backlog the interactive lane empties before the bulk
lane is touched.  Passing `class_weights` switches the drain to deficit
round robin (DRR) across the lanes: each backlogged class receives service
proportional to its weight (EDF order preserved within a class), so a
saturated high class can no longer starve lower ones completely — the
weighted-fair alternative to the strict-priority default.  Load shedding is
two-stage and always explicit:

  * over the shed budget (`shed_threshold`) a sheddable admission is
    rejected with `Shed` at the front door, and
  * a completely full queue admits non-sheddable (or higher-priority)
    traffic by evicting the newest queued request of the lowest sheddable
    class — its future fails with `Shed`, never a silent drop.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import math
import threading
import time
from concurrent.futures import Future, InvalidStateError
from typing import Callable

import numpy as np

from repro.core.policy import ExecutionPolicy
from repro.serve.slo import DEFAULT, SLOClass, drain_key
from repro.serve.trace import Tracer


def try_set_result(future: Future, result) -> bool:
    """Cancel-safe, exactly-one-winner future completion.

    A client may cancel() a queued future at any moment, and eviction
    re-dispatch can race a slow-but-alive replica to the same future —
    set_result must never raise into (and kill) a scheduler or replica
    thread, and the returned bool arbitrates which completion 'won' (only
    the winner records metrics)."""
    try:
        future.set_result(result)
        return True
    except InvalidStateError:  # cancelled, or the other completion won
        return False


def try_set_exception(future: Future, err: Exception) -> bool:
    """Fail a future if still open; see try_set_result for the race rules."""
    try:
        future.set_exception(err)
        return True
    except InvalidStateError:
        return False


class AdmissionError(RuntimeError):
    """Request rejected at the front door; `.reason` says why."""

    def __init__(self, reason: str, detail: str = ""):
        self.reason = reason
        super().__init__(f"request rejected ({reason})" + (f": {detail}" if detail else ""))


class QueueFull(AdmissionError):
    """Admission bound hit — explicit backpressure, never a silent drop."""

    def __init__(self, depth: int, max_depth: int):
        super().__init__("queue_full", f"depth {depth} >= max_depth {max_depth}")
        self.depth = depth
        self.max_depth = max_depth


class QueueClosed(AdmissionError):
    """The runtime stopped accepting traffic (stop() closed the queue)."""

    def __init__(self):
        super().__init__("closed", "runtime is stopped")


class Shed(AdmissionError):
    """Load shed — a sheddable request gave way to higher-priority traffic.

    Raised at admission when the backlog exceeds the shed budget, or set on
    a queued sheddable request's future when a full queue must admit
    non-sheddable traffic.  Distinct from QueueFull so clients (and
    per-class metrics) can tell deliberate shedding from plain overflow.
    """

    def __init__(self, slo_name: str, detail: str = ""):
        super().__init__("shed", detail or f"class {slo_name!r} shed under backlog")
        self.slo_name = slo_name


class DeadlineExceeded(TimeoutError):
    """Set on a request's future when its deadline passed before execution."""


@dataclasses.dataclass
class Request:
    """One admitted inference request.

    bucket is the static n_points shape the scheduler chose for this cloud;
    together with the resolved policy it forms the micro-batching key, so a
    batch never mixes shapes or execution policies (each key maps to exactly
    one jitted artifact).
    """

    id: int
    cloud: np.ndarray  # (n, 3 + F) float32
    n_orig: int  # original row count (pre pad/subsample)
    bucket: int  # static n_points shape this request is padded to
    policy: ExecutionPolicy  # RESOLVED policy (hashable batch key)
    deadline_t: float | None  # absolute time.monotonic() instant, None = no deadline
    submit_t: float
    future: Future
    # preprocess-cache probe: the bucket-fitted batch row and its content
    # address.  Computed lazily by the scheduler at assembly when caching is
    # enabled (admission stays O(1) on the client thread); tests may fill
    # them in ahead of time.  Stay None when caching is off — assembly then
    # falls back to pad_cloud and never touches the cache.
    fitted: np.ndarray | None = None  # (bucket, 3 + F) pad_cloud row
    cache_key: tuple | None = None  # PreprocessCache.key_for address
    slo: SLOClass = DEFAULT  # service class: priority, deadline, shed policy
    trace_id: int | None = None  # span id from Tracer.new_trace; None = untraced

    @property
    def key(self) -> tuple:
        """Micro-batching key — requests batch together iff keys match.

        The SLO class participates: a micro-batch never mixes classes, so
        a latency-bound class never waits on another class's flush timer
        and per-batch accounting stays attributable.
        """
        return (self.bucket, self.policy, self.slo)

    def expired(self, now: float | None = None) -> bool:
        """Whether the deadline passed (checked at every scheduling stage)."""
        if self.deadline_t is None:
            return False
        return (time.monotonic() if now is None else now) > self.deadline_t


class AdmissionQueue:
    """Bounded admission with per-SLO-class lanes and priority/EDF drain.

    One deque per SLOClass; `drain` releases requests by `slo.drain_key`
    (priority descending, earliest deadline first within a priority, then
    admission order), so the single-class default degenerates to the FIFO
    the pre-SLO runtime had.  `class_weights` (class name -> weight > 0)
    switches the drain to deficit round robin: lanes are visited in round-
    robin order, each visit grants the lane `weight` credits and one credit
    releases one request (EDF-first within the lane), with the unspent
    deficit carried to the lane's next turn — so over a sustained backlog
    each class's drained share converges to its weight fraction and no
    backlogged class starves.  Classes absent from the mapping drain with
    weight 1.0.  `shed_threshold` is the load-shedding budget:
    above it sheddable admissions raise `Shed`; a completely full queue
    evicts queued sheddable work to admit strictly-higher-priority traffic
    (each victim's future fails with `Shed` and `on_shed` is told).
    """

    def __init__(
        self,
        max_depth: int,
        *,
        shed_threshold: int | None = None,
        on_shed: Callable[[Request], None] | None = None,
        metrics=None,
        tracer: Tracer | None = None,
        class_weights: dict[str, float] | None = None,
    ):
        if max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        if shed_threshold is not None and not (1 <= shed_threshold <= max_depth):
            raise ValueError(
                f"shed_threshold must be in [1, max_depth], got {shed_threshold}"
            )
        if class_weights is not None:
            for name, w in class_weights.items():
                if not (w > 0):
                    raise ValueError(
                        f"class_weights[{name!r}] must be > 0, got {w}"
                    )
        self.max_depth = max_depth
        self.shed_threshold = shed_threshold
        self.on_shed = on_shed
        self.metrics = metrics  # optional ServeMetrics: depth high-water marks
        self.tracer = tracer
        self.class_weights = dict(class_weights) if class_weights else None
        self._lanes: dict[SLOClass, collections.deque[Request]] = {}
        self._depth = 0
        self._cond = threading.Condition()
        self._closed = False
        self._ids = itertools.count()
        # DRR state (only used when class_weights is set): round-robin lane
        # order, per-lane unspent credits, and whether the head lane's turn
        # already received its quantum (a turn interrupted by max_items
        # resumes with its remaining deficit instead of double-granting)
        self._rr: collections.deque[SLOClass] = collections.deque()
        self._deficits: dict[SLOClass, float] = {}
        self._turn_granted = False

    def _shed_victim(self, priority: int) -> Request | None:
        """Pop the newest request of the lowest sheddable class below `priority`.

        Called under the lock by the full-queue admission path.  The newest
        request of the victim lane gives way (it would have been served
        last within its class), preserving FIFO fairness for the survivors.
        Returns None when nothing strictly lower-priority is sheddable —
        the incoming request then takes the plain QueueFull rejection.
        """
        victim_lane = None
        victim_prio = priority
        for slo, lane in self._lanes.items():
            if lane and slo.sheddable and slo.priority < victim_prio:
                victim_lane, victim_prio = lane, slo.priority
        if victim_lane is None:
            return None
        self._depth -= 1
        return victim_lane.pop()

    def submit(
        self,
        cloud: np.ndarray,
        *,
        bucket: int,
        policy: ExecutionPolicy,
        timeout_s: float | None = None,
        fitted: np.ndarray | None = None,
        cache_key: tuple | None = None,
        slo: SLOClass | None = None,
        trace_id: int | None = None,
    ) -> Future:
        """Admit one cloud; returns its future or raises AdmissionError.

        Backpressure is synchronous and explicit: over the shed budget a
        sheddable class is rejected with `Shed`; a full queue either evicts
        a queued lower-priority sheddable request (full lanes, see
        `_shed_victim`) or rejects with `QueueFull` — never a silent drop,
        so open-loop clients observe exactly the load that was shed.
        `fitted`/`cache_key` carry the preprocess-cache probe when the
        runtime computed one (see Request).
        """
        slo = slo if slo is not None else DEFAULT
        now = time.monotonic()
        if timeout_s is None:
            timeout_s = slo.deadline_s
        req = Request(
            id=-1,
            cloud=cloud,
            n_orig=cloud.shape[0],
            bucket=bucket,
            policy=policy,
            deadline_t=(now + timeout_s) if timeout_s is not None else None,
            submit_t=now,
            future=Future(),
            fitted=fitted,
            cache_key=cache_key,
            slo=slo,
            trace_id=trace_id,
        )
        victim = None
        with self._cond:
            if self._closed:
                raise QueueClosed()
            if (
                self.shed_threshold is not None
                and slo.sheddable
                and self._depth >= self.shed_threshold
            ):
                raise Shed(
                    slo.name,
                    f"class {slo.name!r}: depth {self._depth} >= "
                    f"shed budget {self.shed_threshold}",
                )
            if self._depth >= self.max_depth:
                victim = self._shed_victim(slo.priority)
                if victim is None:
                    raise QueueFull(self._depth, self.max_depth)
            req.id = next(self._ids)
            lane = self._lanes.setdefault(slo, collections.deque())
            lane.append(req)
            if self.class_weights is not None and slo not in self._rr:
                self._rr.append(slo)
            self._depth += 1
            depth_after, lane_after = self._depth, len(lane)
            self._cond.notify()
        # outside the lock: metrics/tracer take their own locks, and future
        # callbacks (and on_shed) may re-enter the queue
        if self.metrics is not None:
            self.metrics.record_queue_hwm(depth_after, slo.name, lane_after)
        if self.tracer is not None and req.trace_id is not None:
            self.tracer.emit("request.admitted", trace_id=req.trace_id, slo=slo.name)
            self.tracer.emit(
                "request.enqueued",
                trace_id=req.trace_id,
                slo=slo.name,
                args={"lane_depth": lane_after, "depth": depth_after},
            )
        if victim is not None:
            won = try_set_exception(
                victim.future,
                Shed(victim.slo.name, f"request {victim.id} evicted for "
                                      f"priority-{req.slo.priority} admission"),
            )
            if won and self.tracer is not None and victim.trace_id is not None:
                self.tracer.emit(
                    "request.shed",
                    trace_id=victim.trace_id,
                    slo=victim.slo.name,
                    args={"reason": "evicted"},
                )
            if self.on_shed is not None:
                self.on_shed(victim)
        return req.future

    def _pop_next(self) -> Request | None:
        """Pop the drain-order winner across every lane (under the lock)."""
        best = None
        best_key = None
        for slo, lane in self._lanes.items():
            for req in lane:
                key = drain_key(slo.priority, req.deadline_t, req.id)
                if best_key is None or key < best_key:
                    best, best_key = req, key
        if best is None:
            return None
        self._lanes[best.slo].remove(best)
        self._depth -= 1
        return best

    def _weight(self, slo: SLOClass) -> float:
        """DRR weight of one class; classes not configured weigh 1.0."""
        return self.class_weights.get(slo.name, 1.0)

    def _pop_edf(self, lane: collections.deque[Request]) -> Request:
        """Pop the earliest-deadline (then oldest) request of one lane."""
        best = min(
            lane,
            key=lambda r: (
                math.inf if r.deadline_t is None else r.deadline_t,
                r.id,
            ),
        )
        lane.remove(best)
        self._depth -= 1
        return best

    def _drain_drr(self, max_items: int) -> list[Request]:
        """Deficit-round-robin drain of up to max_items (under the lock).

        Each lane's turn grants it `weight` credits; one credit releases one
        request (EDF order within the lane).  Unspent deficit carries to the
        lane's next turn; a lane drained empty forfeits its deficit (classic
        DRR — credits never hoard while a class is idle).  Work-conserving:
        the loop only stops when max_items is reached or the queue is empty,
        so backlogged lanes always fill the whole allowance.
        """
        out: list[Request] = []
        while self._depth and len(out) < max_items:
            slo = self._rr[0]
            lane = self._lanes.get(slo)
            if not lane:
                # lane went idle: drop it from rotation (re-added on submit)
                self._deficits.pop(slo, None)
                self._turn_granted = False
                self._rr.popleft()
                continue
            if not self._turn_granted:
                self._deficits[slo] = self._deficits.get(slo, 0.0) + self._weight(slo)
                self._turn_granted = True
            while lane and self._deficits[slo] >= 1.0 and len(out) < max_items:
                out.append(self._pop_edf(lane))
                self._deficits[slo] -= 1.0
            if len(out) >= max_items and lane and self._deficits[slo] >= 1.0:
                break  # turn interrupted: keep position + remaining deficit
            if not lane:
                self._deficits.pop(slo, None)
            self._turn_granted = False
            self._rr.rotate(-1)
        return out

    def drain(self, max_items: int, timeout_s: float) -> list[Request]:
        """Pop up to max_items requests, blocking up to timeout_s for the first.

        Requests come out in drain order — priority descending, earliest
        deadline first within a priority, then admission order — or in
        deficit-round-robin order when `class_weights` is set (per-class
        share proportional to weight, EDF within a class).  Returns [] on
        timeout or when the queue is closed and empty.
        """
        deadline = time.monotonic() + timeout_s
        with self._cond:
            while not self._depth and not self._closed:
                remaining = deadline - time.monotonic()
                if remaining <= 0 or not self._cond.wait(remaining):
                    break
            if self.class_weights is not None:
                return self._drain_drr(max_items)
            out = []
            while self._depth and len(out) < max_items:
                out.append(self._pop_next())
            return out

    def depth(self) -> int:
        """Number of requests currently waiting (the backpressure signal)."""
        with self._cond:
            return self._depth

    def depth_by_class(self) -> dict[str, int]:
        """Waiting requests per SLO class name (autoscaler/operator signal)."""
        with self._cond:
            return {slo.name: len(lane) for slo, lane in self._lanes.items() if lane}

    def slack_by_class(self, now: float | None = None) -> dict[str, float]:
        """Tightest remaining deadline headroom per queued SLO class.

        For each class with queued deadline-bearing requests, the minimum
        of (deadline_t - now) over its lane — negative means the class's
        earliest deadline already passed while queued.  Deadline-free
        classes are absent.  The autoscaler's cost signal: shrinking slack
        predicts a budget breach *before* anything expires.
        """
        now = time.monotonic() if now is None else now
        with self._cond:
            out: dict[str, float] = {}
            for slo, lane in self._lanes.items():
                slacks = [r.deadline_t - now for r in lane if r.deadline_t is not None]
                if slacks:
                    out[slo.name] = min(slacks)
            return out

    @property
    def closed(self) -> bool:
        """Whether close() ran — further submits raise QueueClosed."""
        with self._cond:
            return self._closed

    def close(self) -> list[Request]:
        """Refuse new admissions and return whatever was still queued.

        Leftovers come back in drain order.  The runtime flushes them
        through one final scheduling pass (drain=True) or cancels them
        (drain=False).
        """
        with self._cond:
            self._closed = True
            left = []
            while self._depth:
                left.append(self._pop_next())
            self._lanes.clear()
            self._cond.notify_all()
            return left
