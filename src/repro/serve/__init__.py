from repro.serve.pointcloud import (  # noqa: F401
    PointCloudServeConfig,
    make_pointcloud_serve_fns,
)
from repro.serve.step import make_serve_fns  # noqa: F401
