"""Serving subsystem — the public surface of the PC2IM serving runtime.

Layered back to front: `queue` (bounded admission, deadlines, futures),
`scheduler` (shape-bucketed dynamic micro-batching keyed by the full
ExecutionPolicy — pipeline schedule included), `dispatch` (per-device
replica pool with heartbeat eviction and the two-stage pipelined path),
`metrics`, and `runtime` (the `ServingRuntime` facade most callers want).
`hashing` / `preprocess_cache` implement the cross-request preprocess
cache: content-addressed duplicate clouds skip the preprocess stage and
enter the feature stage directly.  The SLO control plane sits on top:
`slo` (service classes with priority/deadline/shed policy), `autoscaler`
(replica rejoin + queue-depth/cost-signal scaling) and `chaos`
(deterministic fault injection for recovery tests).  `trace` / `obs` are
the observability layer: a ring-buffered lifecycle tracer every component
reports into, and the reductions/exporters (stage breakdown, Chrome-trace
JSON, Prometheus text — live via `MetricsServer`) built on it.  `adapt`
closes the loop from observation back to the knobs: the
`AdaptiveController` retunes buckets / max_batch / batching patience
through the runtime's pause-free `reconfigure` path.  `pointcloud` /
`step` are the synchronous per-batch serve functions.  See
docs/ARCHITECTURE.md for the dataflow diagram.
"""

from repro.serve.adapt import (  # noqa: F401
    AdaptiveConfig,
    AdaptiveController,
    Decision,
    DecisionLog,
    Histogram,
    interarrival_mean,
    padding_waste,
    propose_buckets,
    propose_wait,
)

from repro.serve.autoscaler import Autoscaler, AutoscalerConfig, ScaleEvent  # noqa: F401
from repro.serve.chaos import ChaosError, ChaosEvent, ChaosInjector, Fault  # noqa: F401
from repro.serve.dispatch import NoReplicaAvailable, Replica, ReplicaPool  # noqa: F401
from repro.serve.metrics import (  # noqa: F401
    BatchRecord,
    ClassSnapshot,
    MetricsSnapshot,
    ServeMetrics,
)
from repro.serve.pointcloud import (  # noqa: F401
    PointCloudServeConfig,
    inverse_subsample_indices,
    make_pointcloud_serve_fns,
    pad_cloud,
    subsample_indices,
)
from repro.serve.hashing import DEFAULT_QUANT_STEP, content_key, quantize_cloud  # noqa: F401
from repro.serve.preprocess_cache import (  # noqa: F401
    CacheConfig,
    CacheEntry,
    PreprocessCache,
    PreprocessCacheStats,
)
from repro.serve.queue import (  # noqa: F401
    AdmissionError,
    AdmissionQueue,
    DeadlineExceeded,
    QueueClosed,
    QueueFull,
    Request,
    Shed,
)
from repro.serve.obs import (  # noqa: F401
    BatchCheck,
    MetricsServer,
    Reporter,
    RequestTimeline,
    STAGES,
    StageBreakdown,
    batch_crosscheck,
    prometheus_text,
    request_timelines,
    stage_breakdown,
    to_chrome_trace,
    trace_problems,
    write_chrome_trace,
)
from repro.serve.slo import BULK, DEFAULT, INTERACTIVE, SLOClass  # noqa: F401
from repro.serve.trace import (  # noqa: F401
    EVENTS,
    TERMINAL_EVENTS,
    TraceConfig,
    TraceEvent,
    Tracer,
)
from repro.serve.runtime import (  # noqa: F401
    RuntimeConfig,
    ServingRuntime,
    make_serving_runtime,
)
from repro.serve.scheduler import (  # noqa: F401
    BatchScheduler,
    MicroBatch,
    SchedulerConfig,
    assemble_batch,
    bucket_for,
    scatter_results,
)
from repro.serve.step import make_serve_fns  # noqa: F401
