from repro.serve.dispatch import NoReplicaAvailable, Replica, ReplicaPool  # noqa: F401
from repro.serve.metrics import BatchRecord, MetricsSnapshot, ServeMetrics  # noqa: F401
from repro.serve.pointcloud import (  # noqa: F401
    PointCloudServeConfig,
    inverse_subsample_indices,
    make_pointcloud_serve_fns,
    pad_cloud,
    subsample_indices,
)
from repro.serve.queue import (  # noqa: F401
    AdmissionError,
    AdmissionQueue,
    DeadlineExceeded,
    QueueClosed,
    QueueFull,
    Request,
)
from repro.serve.runtime import (  # noqa: F401
    RuntimeConfig,
    ServingRuntime,
    make_serving_runtime,
)
from repro.serve.scheduler import (  # noqa: F401
    BatchScheduler,
    MicroBatch,
    SchedulerConfig,
    assemble_batch,
    bucket_for,
    scatter_results,
)
from repro.serve.step import make_serve_fns  # noqa: F401
