"""Batched point-cloud inference — ragged requests onto static engine shapes.

Serving traffic arrives as clouds of arbitrary size in arbitrary batches;
the PC2IMAccelerator artifact (and everything jitted behind it) wants a
fixed (B, N, 3+F).  This module is the adapter:

  * clouds smaller than cfg.n_points are padded by repeating the last point
    (duplicates collapse to one FPS candidate, the standard convention);
  * clouds larger than cfg.n_points are deterministically strided down —
    the paper's pipelines all assume a fixed-budget input stage;
  * partial batches are zero-padded to `batch_size` and the filler rows
    dropped from the output.

One `PC2IMAccelerator` (config + ExecutionPolicy -> compiled artifact)
serves every request shape; pass a policy to serve quantized (SC W16A16)
without touching the config, safely per-thread.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.accelerator import get_accelerator
from repro.core.policy import ExecutionPolicy
from repro.models import pointnet2 as PN


@dataclasses.dataclass(frozen=True)
class PointCloudServeConfig:
    """Knobs of the synchronous batch-serving path (`make_pointcloud_serve_fns`).

    batch_size is the static batch dim every ragged request chunk is padded
    to — one jit trace regardless of how many clouds a caller hands in.
    """

    batch_size: int = 8  # static serving batch (pad + drop filler rows)


def pad_cloud(points: np.ndarray, n_points: int) -> tuple[np.ndarray, int]:
    """Fit one (n, F>=3) cloud to exactly n_points rows.

    Returns (fitted cloud, n) with the ORIGINAL row count, so callers can
    recover which rows are real (n < n_points: the first n) or reverse the
    deterministic stride subsample (n > n_points: see subsample_indices).
    """
    n = points.shape[0]
    if n == n_points:
        return points, n
    if n > n_points:  # deterministic stride subsample (fixed input budget)
        return points[subsample_indices(n, n_points)], n
    filler = np.broadcast_to(points[-1:], (n_points - n, points.shape[1]))
    return np.concatenate([points, filler], axis=0), n


def subsample_indices(n: int, n_points: int) -> np.ndarray:
    """Rows surviving pad_cloud's stride-subsample of an oversized cloud.

    Deterministic (a rounded linspace over the n input rows); exposed so
    seg callers can map per-point logits back to the original rows.
    """
    return np.linspace(0, n - 1, n_points).round().astype(np.int64)


def inverse_subsample_indices(n: int, n_points: int) -> np.ndarray:
    """Exact inverse of subsample_indices — nearest survivor per original row.

    For each of the n ORIGINAL rows, returns the position (in the n_points
    surviving rows) of its nearest survivor.  Guarantees, for any
    n > n_points >= 1 (property-tested):
      * identity  — a row that survived maps to its own slot, so per-point
        logits round-trip bitwise for surviving rows;
      * nearest   — every dropped row maps to the survivor with the smallest
        row-distance (ties -> the earlier survivor);
      * monotone  — the mapping is non-decreasing in the original row index.

    Built by searching the actual survivor set rather than re-deriving it
    from a second rounded linspace (the old inline approximation), so it can
    never drift off-by-one from whatever subsample_indices produces.
    """
    idx = subsample_indices(n, n_points)
    rows = np.arange(n)
    right = np.clip(np.searchsorted(idx, rows, side="left"), 0, n_points - 1)
    left = np.clip(right - 1, 0, n_points - 1)
    take_left = (rows - idx[left]) <= (idx[right] - rows)
    return np.where(take_left, left, right).astype(np.int64)


def make_pointcloud_serve_fns(
    cfg: PN.PointNet2Config,
    serve_cfg: PointCloudServeConfig | None = None,
    policy: ExecutionPolicy | None = None,
):
    """Serving closures for a PointNet2 config.

    Returns {"infer", "serve_batch", "accelerator"}:
      infer(params, points)       — the accelerator's compiled batched step
                                    on the static (batch_size, n_points, 3+F)
                                    shape.
      serve_batch(params, clouds) — ragged entry point: list of (n_i, 3+F)
                                    numpy clouds -> list of per-cloud logits
                                    (cls: (C,); seg: (n_i, C) — padding rows
                                    dropped, and oversized clouds mapped back
                                    to all n_i points via nearest sampled
                                    point, so row j scores input point j).
      accelerator                 — the underlying PC2IMAccelerator (one
                                    compiled artifact per (cfg, policy)).
    """
    scfg = serve_cfg or PointCloudServeConfig()
    b, n = scfg.batch_size, cfg.n_points
    width = 3 + cfg.in_features
    accel = get_accelerator(cfg, policy)
    infer = accel.infer

    def serve_batch(params, clouds: list[np.ndarray]) -> list[np.ndarray]:
        out: list[np.ndarray] = []
        for lo in range(0, len(clouds), b):
            chunk = clouds[lo : lo + b]
            fitted = [pad_cloud(np.asarray(c, np.float32), n) for c in chunk]
            batch = np.zeros((b, n, width), np.float32)
            for i, (pts, _) in enumerate(fitted):
                batch[i] = pts
            logits = np.asarray(infer(params, jnp.asarray(batch)))
            for i, (_, n_orig) in enumerate(fitted):
                if cfg.task != "seg":
                    out.append(logits[i])
                elif n_orig <= n:  # drop padding rows
                    out.append(logits[i, :n_orig])
                else:  # subsampled: nearest surviving point scores each input row
                    out.append(logits[i, inverse_subsample_indices(n_orig, n)])
        return out

    return {"infer": infer, "serve_batch": serve_batch, "accelerator": accel}
