"""Deterministic fault injection for the serving replica pool.

Recovery behavior — eviction, batch re-dispatch, autoscaler rejoin — is only
trustworthy if it is exercised, and real faults are rare and unreproducible.
A `ChaosInjector` attaches to a `ReplicaPool` and observes every REAL batch
(warmup batches, `n_real == 0`, are invisible) at execution start on the
owning replica's worker thread — the single choke point both the sequential
and pipelined paths pass through.  Faults are declared up front as
`(replica, batch index, kind)` triples, so a test or benchmark states
exactly "kill replica 1 at its 3rd real batch" and gets the same failure on
every run:

  * `kill` — the replica is evicted on the spot (its in-flight batches
    re-dispatch to the survivors) and the executing batch aborts; this is
    the instant-crash fault the autoscaler's rejoin loop recovers from.
  * `wedge` — the worker thread sleeps past the heartbeat timeout, so the
    pump's beats queue behind it and the liveness monitor evicts the
    replica: the hung-kernel fault, detected the same way production would.
  * `slow` — a bounded sleep; the replica stays alive and the straggler
    monitor records it.

Every firing is logged in `events` (kind, replica, per-replica batch index,
monotonic time) for assertions.  Injection is observation-only bookkeeping
plus the declared fault — an injector with no matching fault adds two dict
lookups per batch.
"""

from __future__ import annotations

import dataclasses
import threading
import time


class ChaosError(RuntimeError):
    """Raised into the executing batch when an injected fault aborts it.

    The pool's retry logic treats it like any device failure — except after
    a `kill`, where eviction already re-dispatched the batch and the
    was_inflight guard keeps the abort from dispatching it a second time.
    """


@dataclasses.dataclass(frozen=True)
class Fault:
    """One declared fault: which replica, which batch, what happens.

    `at_batch` counts REAL batches executed by that replica (0-based;
    warmup batches don't count), so the firing point is deterministic for a
    given dispatch order.  `duration_s` is the sleep for wedge/slow faults
    — a wedge must exceed the pool's heartbeat timeout to trip eviction.
    Each fault fires at most once.
    """

    replica_id: int
    at_batch: int
    kind: str = "kill"  # "kill" | "wedge" | "slow"
    duration_s: float = 0.0

    def __post_init__(self):
        if self.kind not in ("kill", "wedge", "slow"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.at_batch < 0:
            raise ValueError(f"at_batch must be >= 0, got {self.at_batch}")
        if self.kind in ("wedge", "slow") and self.duration_s <= 0:
            raise ValueError(f"{self.kind} fault needs duration_s > 0")


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One fault firing, logged for test/benchmark assertions."""

    kind: str
    replica_id: int
    batch_index: int  # the replica's real-batch count when the fault fired
    t: float  # time.monotonic() at firing


class ChaosInjector:
    """Replays declared faults against a live ReplicaPool, deterministically.

    `attach(pool)` installs the injector as the pool's `chaos` hook; the
    pool then calls `on_batch` for every real batch before executing it.
    Thread-safe: replicas fire faults from their own worker threads.
    """

    def __init__(self, faults: list[Fault] | tuple[Fault, ...] = ()):
        self.faults = list(faults)
        self.events: list[ChaosEvent] = []
        self._counts: dict[int, int] = {}
        self._fired: set[int] = set()  # indexes into self.faults
        self._lock = threading.Lock()

    def attach(self, pool) -> "ChaosInjector":
        """Install on one ReplicaPool (returns self for chaining)."""
        pool.chaos = self
        return self

    def add(self, fault: Fault) -> None:
        """Declare one more fault (usable mid-run)."""
        with self._lock:
            self.faults.append(fault)

    def on_batch(self, pool, rep, mb) -> None:
        """Pool hook: one real batch is about to execute on `rep`.

        Counts the batch, fires at most one matching un-fired fault.  Runs
        on the replica's worker thread; sleeps (wedge/slow) therefore block
        exactly the thread a real hang would block.
        """
        with self._lock:
            index = self._counts.get(rep.id, 0)
            self._counts[rep.id] = index + 1
            fault = None
            for i, f in enumerate(self.faults):
                if (
                    i not in self._fired
                    and f.replica_id == rep.id
                    and f.at_batch == index
                ):
                    self._fired.add(i)
                    fault = f
                    break
            if fault is not None:
                self.events.append(
                    ChaosEvent(fault.kind, rep.id, index, time.monotonic())
                )
        if fault is None:
            return
        tracer = getattr(pool, "tracer", None)
        if tracer is not None:
            # literal names so the closed-registry scan sees them
            name = {"kill": "chaos.kill", "wedge": "chaos.wedge",
                    "slow": "chaos.slow"}[fault.kind]
            tracer.emit(
                name,
                replica_id=rep.id,
                batch_id=getattr(mb, "batch_id", -1),
                args={"batch_index": index, "duration_s": fault.duration_s},
            )
        if fault.kind == "kill":
            # eviction re-dispatches every in-flight batch (including this
            # one); the abort below must then NOT retry it again — the
            # pool's was_inflight guard arbitrates
            pool.evict(rep.id, reason="chaos-kill")
            raise ChaosError(f"replica {rep.id} killed at batch {index}")
        if fault.kind == "wedge":
            # block the worker thread past the heartbeat timeout: the pump's
            # beats queue up behind this sleep and the monitor evicts us —
            # the detection path itself is what's under test
            time.sleep(fault.duration_s)
            if not rep.alive:  # the monitor fired, as intended
                raise ChaosError(
                    f"replica {rep.id} wedged at batch {index} and was evicted"
                )
            return  # liveness disabled: the wedge was only a delay
        time.sleep(fault.duration_s)  # "slow": straggle but survive

    def fired(self, kind: str | None = None) -> list[ChaosEvent]:
        """Events so far, optionally filtered by fault kind."""
        with self._lock:
            return [e for e in self.events if kind is None or e.kind == kind]
