"""Replica autoscaling — a control loop over queue depth and pool health.

The replica pool (serve/dispatch.py) detects failures and evicts; this
module closes the loop.  A background thread watches two signals:

  * **health** — replicas evicted by the liveness monitors (or chaos kills)
    are re-admitted via `ReplicaPool.rejoin` after a short delay, warm:
    every registered (bucket, policy) warmup batch replays on the fresh
    replica and the preprocess cache's hottest entries are pre-staged on
    its device before dispatch sees it.  Replicas the autoscaler itself
    retired (`Replica.retired`) are exempt — scale-down must not fight the
    rejoin loop.
  * **load** — admission-queue depth per alive replica.  Sustained depth
    above `scale_up_depth` revives a retired slot (or grows the pool up to
    `max_replicas`); depth at or below `scale_down_depth` for
    `scale_down_ticks` consecutive polls retires the highest-numbered
    replica down to `min_replicas`.  Every scale action starts a cooldown
    so the loop cannot flap; fault rejoins ignore the cooldown — recovery
    is not a scaling decision.
  * **cost** (opt-in) — deadline slack and shed rate.  Queue depth is a
    lagging proxy: a shallow queue of about-to-expire interactive requests,
    or a queue kept artificially short by admission shedding, both look
    healthy to the depth trigger.  With `slack_scale_up_s` set, any class
    whose tightest queued deadline is closer than the threshold triggers
    growth (reason ``"slack:<class>"``); with `shed_scale_up_rate` set, a
    shed rate above the threshold does (reason ``"shed"``).  Every
    `ScaleEvent` carries the `reason` that fired it.

Every action lands in `events` (`ScaleEvent`) for tests and the serve_slo
benchmark to assert on.  The loop never raises: a failed action (e.g. a
rejoin whose warmup replay fails) is recorded as an ``"error"`` event and
retried on a later poll.
"""

from __future__ import annotations

import dataclasses
import threading
import time


@dataclasses.dataclass(frozen=True)
class AutoscalerConfig:
    """Knobs of the autoscaler control loop.

    Depth thresholds are per ALIVE replica — the signal is "how much
    backlog each healthy replica is carrying", so the thresholds keep their
    meaning as the pool grows and shrinks.  `max_replicas=None` caps
    scale-up at the pool's current slot count (only retired slots are
    revived, the pool never grows new slots).
    """

    poll_interval_s: float = 0.05
    rejoin_delay_s: float = 0.2  # dwell after a fault eviction before rejoin
    scale_up_depth: float = 8.0  # queue depth per alive replica that triggers growth
    scale_down_depth: float = 1.0  # depth per replica considered "shallow"
    scale_down_ticks: int = 20  # consecutive shallow polls before retiring one
    min_replicas: int = 1
    max_replicas: int | None = None
    cooldown_s: float = 1.0  # quiet period after any scale action
    # cost signals (None = depth-only triggering, the pre-existing default)
    slack_scale_up_s: float | None = None  # tightest queued deadline slack
    shed_scale_up_rate: float | None = None  # shed requests/s that trigger growth

    def __post_init__(self):
        if self.poll_interval_s <= 0:
            raise ValueError("poll_interval_s must be > 0")
        if self.min_replicas < 1:
            raise ValueError("min_replicas must be >= 1")
        if self.max_replicas is not None and self.max_replicas < self.min_replicas:
            raise ValueError("max_replicas must be >= min_replicas")
        if self.scale_down_depth > self.scale_up_depth:
            raise ValueError("scale_down_depth must be <= scale_up_depth")
        if self.slack_scale_up_s is not None and self.slack_scale_up_s <= 0:
            raise ValueError("slack_scale_up_s must be > 0 or None")
        if self.shed_scale_up_rate is not None and self.shed_scale_up_rate <= 0:
            raise ValueError("shed_scale_up_rate must be > 0 or None")


@dataclasses.dataclass(frozen=True)
class ScaleEvent:
    """One autoscaler action: rejoin / scale_up / scale_down / error."""

    action: str
    replica_id: int  # -1 for errors without a specific replica
    depth: int  # queue depth observed when the action was taken
    t: float  # time.monotonic() at the action
    reason: str = ""  # signal that fired: "depth", "slack:<class>", "shed"


class Autoscaler:
    """Background control loop growing/shrinking one ReplicaPool.

    Owns a daemon thread between `start()` and `stop()`; all state it
    mutates on the pool goes through the pool's public rejoin/retire/
    add_replica surface, so the loop can be driven manually in tests via
    `poll_once()` without starting the thread.
    """

    def __init__(self, pool, queue, config: AutoscalerConfig | None = None,
                 *, tracer=None, metrics=None):
        self.pool = pool
        self.queue = queue
        self.config = config or AutoscalerConfig()
        self.tracer = tracer  # Tracer | None — scale actions fold into the trace
        self.metrics = metrics  # ServeMetrics | None — shed-rate cost signal
        self.events: list[ScaleEvent] = []
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._cooldown_until = 0.0
        self._shallow_ticks = 0
        self._shed_mark: tuple[int, float] | None = None  # (count, t) last poll

    # -- lifecycle ------------------------------------------------------------

    def start(self) -> "Autoscaler":
        """Spawn the polling thread (idempotent); returns self."""
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._run, daemon=True, name="pc2im-autoscaler"
            )
            self._thread.start()
        return self

    def stop(self) -> None:
        """Stop the polling thread and wait for it to exit."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None

    def _run(self) -> None:
        while not self._stop.wait(self.config.poll_interval_s):
            self.poll_once()

    # -- one control step -----------------------------------------------------

    # trace-event names per action, literal so the registry scan sees them
    _TRACE_EVENTS = {
        "rejoin": "scale.rejoin",
        "scale_up": "scale.up",
        "scale_down": "scale.down",
        "error": "scale.error",
    }

    def _record(self, action: str, rid: int, depth: int, reason: str = "") -> None:
        with self._lock:
            self.events.append(
                ScaleEvent(action, rid, depth, time.monotonic(), reason)
            )
        if self.tracer is not None:
            self.tracer.emit(
                self._TRACE_EVENTS[action],
                replica_id=rid,
                args={"depth": depth, "reason": reason},
            )

    def poll_once(self) -> None:
        """One control step: rejoin the dead, then scale on depth + cost.

        Public so tests can drive the loop deterministically; the polling
        thread calls it every `poll_interval_s`.  Never raises.
        """
        try:
            depth = self.queue.depth()
        except Exception:  # noqa: BLE001 — queue closed mid-shutdown
            return
        now = time.monotonic()
        pressure = self._cost_pressure(now)  # sampled every poll: the shed
        # rate window must keep moving even through the cooldown
        self._rejoin_dead(now, depth)
        if now >= self._cooldown_until:
            self._scale(now, depth, pressure)

    def _cost_pressure(self, now: float) -> str | None:
        """Cost-signal scale-up reason, or None when no signal fires."""
        cfg = self.config
        if cfg.slack_scale_up_s is not None:
            try:
                slack = self.queue.slack_by_class(now)
            except Exception:  # noqa: BLE001 — queue closed mid-shutdown
                slack = {}
            for name in sorted(slack, key=lambda n: slack[n]):
                if slack[name] < cfg.slack_scale_up_s:
                    return f"slack:{name}"
        if cfg.shed_scale_up_rate is not None and self.metrics is not None:
            count = self.metrics.shed
            mark = self._shed_mark
            self._shed_mark = (count, now)
            if mark is not None and now > mark[1]:
                rate = (count - mark[0]) / (now - mark[1])
                if rate > cfg.shed_scale_up_rate:
                    return "shed"
        return None

    def _rejoin_dead(self, now: float, depth: int) -> None:
        """Re-admit fault-evicted replicas once their dwell elapsed.

        Outside the cooldown on purpose: a rejoin restores capacity the
        load signal already assumed — deferring it would double the outage.
        """
        for rep in list(self.pool.replicas):
            if rep.alive or rep.retired:
                continue
            if rep.evicted_t is None or now - rep.evicted_t < self.config.rejoin_delay_s:
                continue
            try:
                if self.pool.rejoin(rep.id):
                    self._record("rejoin", rep.id, depth)
            except Exception:  # noqa: BLE001 — warmup replay failed; retry later
                self._record("error", rep.id, depth)

    def _scale(self, now: float, depth: int, pressure: str | None = None) -> None:
        alive = self.pool.alive_replicas()
        if not alive:
            return  # nothing to scale against; rejoin handles recovery
        per_replica = depth / len(alive)
        if per_replica >= self.config.scale_up_depth:
            self._shallow_ticks = 0
            self._scale_up(now, depth, n_alive=len(alive), reason="depth")
            return
        if pressure is not None:
            # a cost signal overrides the shallow-depth read: the queue may
            # be short precisely BECAUSE requests are being shed or expiring
            self._shallow_ticks = 0
            self._scale_up(now, depth, n_alive=len(alive), reason=pressure)
            return
        if per_replica > self.config.scale_down_depth:
            self._shallow_ticks = 0
            return
        self._shallow_ticks += 1
        if (
            self._shallow_ticks >= self.config.scale_down_ticks
            and len(alive) > self.config.min_replicas
        ):
            self._shallow_ticks = 0
            victim = max(alive, key=lambda r: r.id)
            if self.pool.retire(victim.id):
                self._record("scale_down", victim.id, depth, "depth")
                self._cooldown_until = now + self.config.cooldown_s

    def _scale_up(self, now: float, depth: int, *, n_alive: int,
                  reason: str = "depth") -> None:
        cap = (
            self.config.max_replicas
            if self.config.max_replicas is not None
            else len(self.pool.replicas)
        )
        if n_alive >= cap:
            return
        try:
            # a retired slot is the cheap revival; only grow past the
            # existing slots when none is available and the cap allows
            for rep in self.pool.replicas:
                if not rep.alive and rep.retired:
                    if self.pool.rejoin(rep.id):
                        self._record("scale_up", rep.id, depth, reason)
                        self._cooldown_until = now + self.config.cooldown_s
                    return
            if len(self.pool.replicas) < cap:
                rid = self.pool.add_replica()
                self._record("scale_up", rid, depth, reason)
                self._cooldown_until = now + self.config.cooldown_s
        except Exception:  # noqa: BLE001 — warmup failed; retry next poll
            self._record("error", -1, depth, reason)
