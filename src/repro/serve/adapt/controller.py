"""AdaptiveController — the daemon closing the observe -> actuate loop.

A background thread (the `Autoscaler` pattern: start/stop lifecycle,
`poll_once` drivable from tests, never raises) that each tick:

1. **verifies** the last applied swap — if the post-swap windowed p95
   regressed past `rollback_factor` x the pre-swap p95, the previous knobs
   are re-applied (a "rollback" decision) and the controller cools down;
2. **proposes** new knob values from the observed workload —
   quantile-based bucket boundaries minimizing padding waste, `max_batch`
   from measured batch occupancy + backlog, per-class batching patience
   from per-class inter-arrival gaps (all pure math in
   serve/adapt/histograms.py);
3. **actuates** at most one accepted proposal per tick through
   `ServingRuntime.reconfigure` — which background-warms the new
   (bucket x policy x replica) artifacts first and then atomically swaps
   the versioned `SchedulerConfig`, so traffic never pauses and no batch
   mixes shapes.

Hysteresis is explicit: a bucket proposal must improve predicted padding
waste by `waste_improvement`, occupancy must cross the high/low water marks
to move `max_batch`, and a patience override must shift by
`wait_rel_change`; every accepted AND rejected proposal lands in the
`DecisionLog` with its evidence, and every actuation emits `adapt.*` trace
events into the same stream the rest of the control plane reports to.
"""

from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from repro.serve.adapt.decisions import DecisionLog
from repro.serve.adapt.histograms import (
    interarrival_mean,
    padding_waste,
    propose_buckets,
    propose_wait,
)


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Knobs of the adaptive controller itself.

    `min_bucket` / `max_bucket` bound the bucket proposal (None = the
    runtime's current smallest / largest bucket — adaptation then refines
    within the configured envelope and can never make a servable size
    unservable).  `observe_s` is the rollback-verification window after a
    swap: no further actuation happens inside it, and at its end the
    post-swap p95 is judged against `rollback_factor` x the pre-swap p95.
    `cooldown_s` is the quiet period after any actuation or rollback.
    """

    poll_interval_s: float = 0.25
    min_samples: int = 64  # size observations required before any proposal
    # bucket proposal
    tune_buckets: bool = True
    n_buckets: int = 2
    bucket_align: int = 32
    min_bucket: int | None = None
    max_bucket: int | None = None
    waste_improvement: float = 0.05  # required predicted waste reduction
    # max_batch proposal
    tune_max_batch: bool = True
    max_batch_bounds: tuple[int, int] = (2, 16)
    occupancy_high: float = 0.9  # batches this full + backlog -> grow
    occupancy_low: float = 0.3  # batches this empty -> shrink
    min_batch_records: int = 8
    # per-class batching patience proposal
    tune_wait: bool = True
    wait_bounds: tuple[float, float] = (0.001, 0.05)
    wait_rel_change: float = 0.25  # relative shift required to re-apply
    # rollback guard
    observe_s: float = 1.0
    rollback_factor: float = 1.5
    min_window_completions: int = 16
    cooldown_s: float = 1.0

    def __post_init__(self):
        if self.poll_interval_s <= 0:
            raise ValueError("poll_interval_s must be > 0")
        if self.min_samples < 1:
            raise ValueError("min_samples must be >= 1")
        if self.n_buckets < 1:
            raise ValueError("n_buckets must be >= 1")
        if not (0 < self.occupancy_low < self.occupancy_high <= 1.0):
            raise ValueError("need 0 < occupancy_low < occupancy_high <= 1")
        lo, hi = self.max_batch_bounds
        if not (1 <= lo <= hi):
            raise ValueError(f"bad max_batch_bounds {self.max_batch_bounds}")
        wlo, whi = self.wait_bounds
        if not (0 < wlo <= whi):
            raise ValueError(f"bad wait_bounds {self.wait_bounds}")
        if self.rollback_factor <= 1.0:
            raise ValueError("rollback_factor must be > 1")
        if self.observe_s <= 0 or self.cooldown_s < 0:
            raise ValueError("observe_s must be > 0 and cooldown_s >= 0")


class AdaptiveController:
    """Background feedback loop retuning one ServingRuntime's knobs.

    All actuation goes through `runtime.reconfigure` (the pause-free
    warm-then-swap path); every decision — applied, rejected or rolled
    back — is recorded in `decisions` with its evidence.  Drive manually
    in tests via `poll_once()`; the thread only adds periodicity.
    """

    def __init__(self, runtime, config: AdaptiveConfig | None = None):
        self.runtime = runtime
        self.config = config or AdaptiveConfig()
        self.decisions = DecisionLog()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._cooldown_until = 0.0
        # (applied_t, revert kwargs for reconfigure, pre-swap p95 | None)
        self._pending_verify: tuple[float, dict, float | None] | None = None
        self._last_rejected: dict[str, object] = {}  # kind -> last logged value
        self._batch_marker = 0  # batch_records index at the last max_batch swap

    # -- lifecycle ------------------------------------------------------------

    def start(self) -> "AdaptiveController":
        """Spawn the polling thread (idempotent); returns self."""
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._run, daemon=True, name="pc2im-adapt"
            )
            self._thread.start()
        return self

    def stop(self) -> None:
        """Stop the polling thread and wait for it to exit."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None

    def _run(self) -> None:
        while not self._stop.wait(self.config.poll_interval_s):
            self.poll_once()

    # -- one control step -----------------------------------------------------

    def poll_once(self) -> None:
        """One control step: verify the last swap, then propose/actuate.

        Never raises — a failed actuation is recorded as an "error"
        decision and retried from fresh evidence on a later tick.
        """
        try:
            self._step()
        except Exception as e:  # noqa: BLE001 — the loop must survive anything
            self.decisions.record(
                "error",
                value=None,
                previous=None,
                applied=False,
                reason=f"{type(e).__name__}: {e}",
            )

    def _emit(self, name: str, args: dict) -> None:
        tracer = getattr(self.runtime, "tracer", None)
        if tracer is not None:
            tracer.emit(name, args=args)

    def _step(self) -> None:
        now = time.monotonic()
        if self._pending_verify is not None:
            if now < self._pending_verify[0] + self.config.observe_s:
                return  # inside the observation window: no further changes
            self._verify(now)
            return  # verification consumed this tick; propose from fresh state
        if now < self._cooldown_until:
            return
        metrics = self.runtime.metrics
        sizes = metrics.request_sizes()
        if sizes.size < self.config.min_samples:
            return
        # at most ONE actuation per tick, most valuable knob first: buckets
        # move the padded-compute floor, max_batch the amortization, waits
        # only the flush patience
        if self.config.tune_buckets and self._tune_buckets(now, sizes):
            return
        if self.config.tune_max_batch and self._tune_max_batch(now, metrics):
            return
        if self.config.tune_wait:
            self._tune_waits(now, metrics)

    # -- rollback guard -------------------------------------------------------

    def _verify(self, now: float) -> None:
        applied_t, revert, pre_p95 = self._pending_verify
        self._pending_verify = None
        post = self.runtime.metrics.latencies_since(applied_t)
        if (
            pre_p95 is None
            or post.size < self.config.min_window_completions
        ):
            return  # not enough evidence either side: keep the swap
        post_p95 = float(np.percentile(post, 95))
        if post_p95 <= self.config.rollback_factor * pre_p95:
            return
        version = self.runtime.reconfigure(**revert)
        self.decisions.record(
            "rollback",
            value=dict(revert),
            previous=None,
            applied=True,
            reason=(
                f"post-swap p95 {post_p95 * 1e3:.1f}ms > "
                f"{self.config.rollback_factor:g}x pre-swap {pre_p95 * 1e3:.1f}ms"
            ),
            evidence={"pre_p95_s": pre_p95, "post_p95_s": post_p95,
                      "window_n": int(post.size)},
            version=version,
        )
        self._emit("adapt.rollback", {
            "pre_p95_ms": pre_p95 * 1e3, "post_p95_ms": post_p95 * 1e3,
        })
        self._cooldown_until = now + self.config.cooldown_s

    def _actuate(self, kind: str, value, previous, reason: str,
                 evidence: dict, revert: dict, **kwargs) -> None:
        """Apply one accepted proposal and arm the rollback guard."""
        self._emit("adapt.propose", {"kind": kind, "value": str(value)})
        pre = self.runtime.metrics.latencies_since(
            time.monotonic() - self.config.observe_s
        )
        pre_p95 = (
            float(np.percentile(pre, 95))
            if pre.size >= self.config.min_window_completions
            else None
        )
        version = self.runtime.reconfigure(**kwargs)
        self.decisions.record(
            kind, value=value, previous=previous, applied=True,
            reason=reason, evidence=evidence, version=version,
        )
        self._emit("adapt.apply", {
            "kind": kind, "value": str(value), "version": version,
        })
        now = time.monotonic()
        self._pending_verify = (now, revert, pre_p95)
        self._cooldown_until = now + self.config.cooldown_s

    def _reject(self, kind: str, value, previous, reason: str,
                evidence: dict) -> None:
        """Log a proposal the hysteresis guard rejected (deduplicated)."""
        if self._last_rejected.get(kind) == value:
            return
        self._last_rejected[kind] = value
        self.decisions.record(
            kind, value=value, previous=previous, applied=False,
            reason=reason, evidence=evidence,
        )

    # -- knob proposals -------------------------------------------------------

    def _tune_buckets(self, now: float, sizes: np.ndarray) -> bool:
        cur = tuple(self.runtime.buckets)
        min_b = self.config.min_bucket if self.config.min_bucket is not None else cur[0]
        max_b = self.config.max_bucket if self.config.max_bucket is not None else cur[-1]
        proposed = propose_buckets(
            sizes, self.config.n_buckets,
            align=self.config.bucket_align, min_bucket=min_b, max_bucket=max_b,
        )
        if proposed == cur:
            return False
        cur_waste = padding_waste(sizes, cur)
        new_waste = padding_waste(sizes, proposed)
        evidence = {
            "observed_n": int(sizes.size),
            "size_p50": float(np.quantile(sizes, 0.5)),
            "size_p95": float(np.quantile(sizes, 0.95)),
            "waste_current": cur_waste,
            "waste_proposed": new_waste,
        }
        if cur_waste - new_waste < self.config.waste_improvement:
            self._reject(
                "buckets", proposed, cur,
                f"predicted waste gain {cur_waste - new_waste:.3f} < "
                f"hysteresis {self.config.waste_improvement:g}",
                evidence,
            )
            return False
        self._actuate(
            "buckets", proposed, cur,
            f"padding waste {cur_waste:.3f} -> {new_waste:.3f} on "
            f"{sizes.size} observed sizes",
            evidence, revert={"buckets": cur}, buckets=proposed,
        )
        return True

    def _tune_max_batch(self, now: float, metrics) -> bool:
        records = metrics.batch_records
        fresh = [
            b for b in records[self._batch_marker:] if b.n_real
        ]
        if len(fresh) < self.config.min_batch_records:
            return False
        occ = float(np.mean([b.n_real / b.batch_size for b in fresh]))
        cur = self.runtime.scheduler.config.max_batch
        lo, hi = self.config.max_batch_bounds
        depth = self.runtime.queue.depth()
        proposed = None
        if occ >= self.config.occupancy_high and depth >= cur and cur * 2 <= hi:
            proposed, why = cur * 2, (
                f"occupancy {occ:.2f} >= {self.config.occupancy_high:g} with "
                f"backlog {depth}"
            )
        elif occ <= self.config.occupancy_low and cur // 2 >= lo:
            proposed, why = cur // 2, (
                f"occupancy {occ:.2f} <= {self.config.occupancy_low:g}"
            )
        if proposed is None or proposed == cur:
            return False
        evidence = {"occupancy": occ, "queue_depth": depth,
                    "batches_observed": len(fresh)}
        self._batch_marker = len(records)
        self._actuate(
            "max_batch", proposed, cur, why, evidence,
            revert={"max_batch": cur}, max_batch=proposed,
        )
        return True

    def _tune_waits(self, now: float, metrics) -> bool:
        cur_cfg = self.runtime.scheduler.config
        current = dict(cur_cfg.class_max_wait)
        proposed = dict(current)
        evidence: dict[str, object] = {}
        need = max(8, self.config.min_samples // 4)
        for name, arrivals in metrics.arrivals_by_class().items():
            if arrivals.size < need:
                continue
            wait = propose_wait(
                interarrival_mean(arrivals), cur_cfg.max_batch,
                bounds=self.config.wait_bounds,
            )
            if wait is None:
                continue
            old = current.get(name)
            if old is not None and abs(wait - old) / old < self.config.wait_rel_change:
                continue
            proposed[name] = wait
            evidence[name] = {"wait_s": wait, "arrivals": int(arrivals.size)}
        if proposed == current:
            return False
        value = tuple(sorted(proposed.items()))
        self._actuate(
            "max_wait", value, tuple(sorted(current.items())),
            f"batching patience refit for {sorted(evidence)}",
            evidence, revert={"class_max_wait": tuple(sorted(current.items()))},
            class_max_wait=value,
        )
        return True
