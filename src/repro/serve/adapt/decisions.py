"""Decision audit log of the adaptive controller.

Every knob change (and every rollback) the controller performs lands here as
a frozen `Decision` carrying the evidence that justified it — the
`ScaleEvent` pattern from serve/autoscaler.py applied to knob tuning, so
tests and the `serve_adapt` benchmark can assert not just *that* the
controller converged but *why* each actuation happened.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Mapping


@dataclasses.dataclass(frozen=True)
class Decision:
    """One controller decision: a proposed knob change and its disposition.

    `kind` is the knob ("buckets" / "max_batch" / "max_wait"), or
    "rollback" (a reverted swap) or "error" (a failed actuation, never
    raised into the control thread).  `value` is the proposed setting,
    `previous` what it replaced; `applied` is False for proposals the
    hysteresis guard rejected.  `evidence` carries the observed numbers the
    proposal was computed from (quantiles, padding waste, occupancy, p95);
    `version` is the scheduler-config version the actuation produced (-1
    when nothing was applied).
    """

    kind: str
    value: object
    previous: object
    applied: bool
    reason: str
    evidence: Mapping[str, object]
    t: float
    version: int = -1


class DecisionLog:
    """Thread-safe append-only log of controller decisions."""

    def __init__(self):
        self._lock = threading.Lock()
        self._decisions: list[Decision] = []

    def record(
        self,
        kind: str,
        *,
        value: object,
        previous: object,
        applied: bool,
        reason: str,
        evidence: Mapping[str, object] | None = None,
        version: int = -1,
    ) -> Decision:
        """Append one decision (stamped now); returns it."""
        d = Decision(
            kind=kind,
            value=value,
            previous=previous,
            applied=applied,
            reason=reason,
            evidence=dict(evidence or {}),
            t=time.monotonic(),
            version=version,
        )
        with self._lock:
            self._decisions.append(d)
        return d

    def all(self) -> tuple[Decision, ...]:
        """Every recorded decision, in order."""
        with self._lock:
            return tuple(self._decisions)

    def applied(self, kind: str | None = None) -> tuple[Decision, ...]:
        """Actuated decisions only, optionally filtered by kind."""
        with self._lock:
            return tuple(
                d
                for d in self._decisions
                if d.applied and (kind is None or d.kind == kind)
            )

    def __len__(self) -> int:
        with self._lock:
            return len(self._decisions)
