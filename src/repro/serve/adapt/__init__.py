"""Adaptive serving control plane — observe, propose, warm, swap, verify.

The serving stack's knobs (bucket boundaries, ``max_batch``, per-class
batching patience) are static at construction; this package closes the loop
from the observability layer back to them.  `histograms` holds the online
workload summaries and the pure proposal math (quantile buckets, padding
waste, batching patience); `decisions` is the `ScaleEvent`-style audit log
explaining every actuation; `controller` is the `AdaptiveController` daemon
that periodically reads `ServeMetrics`, proposes new knobs, applies them
through `ServingRuntime.reconfigure` (warm-then-atomic-swap, so traffic
never pauses and no batch mixes shapes) and reverts a swap whose post-apply
p95 regresses.  See docs/ARCHITECTURE.md for the control-loop diagram.
"""

from repro.serve.adapt.controller import AdaptiveConfig, AdaptiveController  # noqa: F401
from repro.serve.adapt.decisions import Decision, DecisionLog  # noqa: F401
from repro.serve.adapt.histograms import (  # noqa: F401
    Histogram,
    interarrival_mean,
    padding_waste,
    propose_buckets,
    propose_wait,
)
