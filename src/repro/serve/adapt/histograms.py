"""Online workload summaries + pure knob-proposal math.

Everything here is deterministic and side-effect free: the
`AdaptiveController` feeds it reservoir snapshots from `ServeMetrics` and
gets back proposed knob values plus the evidence (quantiles, padding waste)
that justified them.  Keeping the math pure lets tests pin the proposals on
synthetic distributions without a runtime, and lets the decision log carry
the exact numbers an operator needs to audit an actuation.
"""

from __future__ import annotations

import collections
from typing import Sequence

import numpy as np

from repro.serve.scheduler import bucket_for


class Histogram:
    """Exact online histogram over small positive integers (request sizes).

    Point-cloud request sizes are small ints (hundreds to a few thousand),
    so exact per-value counts stay tiny; `quantile` reads the empirical CDF
    directly.  Used by the controller as the long-lived size summary that
    outlives the metrics reservoir's rotation.
    """

    def __init__(self):
        self._counts: collections.Counter[int] = collections.Counter()
        self._n = 0

    def add(self, value: int, count: int = 1) -> None:
        """Count `count` observations of `value` (must be > 0)."""
        if value <= 0:
            raise ValueError(f"histogram values must be > 0, got {value}")
        self._counts[int(value)] += count
        self._n += count

    def extend(self, values: Sequence[int]) -> None:
        """Count every value in `values`."""
        for v in values:
            self.add(int(v))

    def __len__(self) -> int:
        return self._n

    def quantile(self, q: float) -> int:
        """Smallest observed value v with CDF(v) >= q (empirical quantile)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        if self._n == 0:
            raise ValueError("quantile of an empty histogram")
        target = q * self._n
        acc = 0
        for v in sorted(self._counts):
            acc += self._counts[v]
            if acc >= target:
                return v
        return max(self._counts)

    def mean(self) -> float:
        """Mean of the observed values (0.0 when empty)."""
        if self._n == 0:
            return 0.0
        return sum(v * c for v, c in self._counts.items()) / self._n


def padding_waste(sizes: np.ndarray, buckets: Sequence[int]) -> float:
    """Mean fraction of each padded batch row that is filler, over `sizes`.

    A size-s request served at bucket b computes b rows of which only
    min(s, b) are real — the rest is padding the accelerator still pays
    for.  Oversized clouds subsample down to the largest bucket and waste
    nothing.  This is the objective the bucket proposal minimizes.
    """
    if len(sizes) == 0:
        return 0.0
    waste = []
    for s in np.asarray(sizes, np.int64):
        b = bucket_for(int(s), buckets)
        waste.append((b - min(int(s), b)) / b)
    return float(np.mean(waste))


def propose_buckets(
    sizes: np.ndarray,
    n_buckets: int,
    *,
    align: int = 32,
    min_bucket: int,
    max_bucket: int,
) -> tuple[int, ...]:
    """Quantile-based bucket boundaries over an observed size distribution.

    Boundaries sit at the size quantiles q = i/n_buckets (i = 1..n_buckets),
    rounded UP to `align` (so every cloud at or below the quantile fits) and
    clamped to [min_bucket, max_bucket].  The largest bucket is always
    `max_bucket` — the proposal refines *within* the configured envelope, so
    every size servable before a swap stays servable after it (the
    `oversize="reject"` contract cannot tighten under adaptation).
    Duplicate boundaries collapse; the result is sorted and unique.
    """
    if n_buckets < 1:
        raise ValueError(f"n_buckets must be >= 1, got {n_buckets}")
    if align < 1:
        raise ValueError(f"align must be >= 1, got {align}")
    if not (0 < min_bucket <= max_bucket):
        raise ValueError(
            f"need 0 < min_bucket <= max_bucket, got {min_bucket}, {max_bucket}"
        )
    sizes = np.asarray(sizes, np.int64)
    if sizes.size == 0:
        return (max_bucket,)
    out = set()
    for i in range(1, n_buckets + 1):
        # method="lower": an OBSERVED size, not an interpolated midpoint —
        # on a bimodal distribution the boundary sits on a mode, so the
        # aligned bucket hugs the sizes it will actually serve
        q = float(np.quantile(sizes, i / n_buckets, method="lower"))
        b = int(-(-q // align) * align)  # ceil to alignment
        out.add(max(min_bucket, min(max_bucket, b)))
    out.add(max_bucket)
    return tuple(sorted(out))


def interarrival_mean(arrivals: np.ndarray, window: int = 256) -> float | None:
    """Mean inter-arrival gap (s) over the newest `window` admissions.

    None when fewer than two arrivals are retained — no rate estimate.
    """
    arrivals = np.asarray(arrivals, np.float64)
    if arrivals.size < 2:
        return None
    tail = arrivals[-window:]
    if tail.size < 2:
        return None
    return float(np.mean(np.diff(tail)))


def propose_wait(
    gap_s: float | None,
    max_batch: int,
    *,
    bounds: tuple[float, float],
) -> float | None:
    """Batching patience from the arrival rate: time to fill one batch.

    Waiting much longer than (max_batch - 1) gaps buys no occupancy (the
    batch is already full) and waiting much less flushes half-empty; the
    proposal is that fill time clamped to `bounds`.  None when no rate
    estimate exists.
    """
    if gap_s is None or max_batch < 1:
        return None
    lo, hi = bounds
    return float(min(hi, max(lo, (max_batch - 1) * gap_s)))
