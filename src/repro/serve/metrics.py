"""Serving metrics — thread-safe counters + reservoirs, snapshotted on demand.

Every component of the serving runtime reports here: the admission queue
(rejections), the scheduler (queue depth at drain, batch occupancy, expired
deadlines), the replica pool (retries, evictions, stragglers) and the
result scatter (per-request latency).  `snapshot()` reduces the raw samples
to the numbers tests and benchmarks assert on — p50/p95/p99 latency,
throughput, mean occupancy — without ever blocking the hot path for more
than a lock-protected append.
"""

from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

_RESERVOIR = 65536  # keep the newest N samples per series


@dataclasses.dataclass(frozen=True)
class BatchRecord:
    """One executed micro-batch (who ran it, how full it was)."""

    bucket: int  # static n_points shape the batch was padded to
    policy_key: tuple  # (quant, backend, pipeline, sharding) of the batch's ExecutionPolicy
    n_real: int  # real requests in the batch (rest is filler)
    batch_size: int  # static batch dim
    replica_id: int
    duration_s: float
    preprocess_skipped: bool = False  # all-hit batch: entered the feature stage directly
    batch_id: int = -1  # trace span id of the micro-batch (-1 when tracing is off)


@dataclasses.dataclass(frozen=True)
class ClassSnapshot:
    """Per-SLO-class reduction inside one MetricsSnapshot.

    Counts and latency percentiles attributed to one class name — the
    load-shedding contract is asserted against these (a non-sheddable
    class must show shed == 0 while the sheddable class absorbs it all).
    """

    name: str
    submitted: int
    completed: int
    shed: int
    expired: int
    rejected: int
    latency_p50_s: float
    latency_p95_s: float
    depth_hwm: int = 0  # max depth this class's admission lane ever reached

    def format_row(self) -> str:
        """One-line human summary of this class (serve_slo prints these)."""
        return (
            f"[{self.name}] submitted={self.submitted} completed={self.completed} "
            f"shed={self.shed} expired={self.expired} rejected={self.rejected} "
            f"p50={self.latency_p50_s * 1e3:.1f}ms p95={self.latency_p95_s * 1e3:.1f}ms"
        )


@dataclasses.dataclass(frozen=True)
class MetricsSnapshot:
    """Immutable reduction of one runtime's metrics at a point in time.

    Counters (submitted..straggler_events) are totals since construction;
    latency percentiles, throughput and occupancy are computed over the
    retained reservoirs — exactly the numbers benchmarks and tests assert
    on (see snapshot() for the definitions).  `per_class` breaks the
    request counters and latency percentiles down by SLO class; the
    aggregate fields keep their pre-SLO definitions (shed requests are NOT
    counted as rejected — each outcome is exactly one counter).
    """

    submitted: int
    completed: int
    rejected: int
    expired: int
    failed: int
    retries: int
    evictions: int
    batches: int  # executed micro-batches that carried real traffic
    straggler_events: int
    latency_p50_s: float
    latency_p95_s: float
    latency_p99_s: float
    throughput_rps: float  # completed requests / observed serving window
    mean_occupancy: float  # mean(n_real / batch_size) over executed batches
    queue_depth_mean: float
    queue_depth_max: int
    cache_hits: int = 0  # preprocess-cache lookups that hit
    cache_misses: int = 0  # preprocess-cache lookups that missed
    preprocess_skipped: int = 0  # all-hit batches that skipped the preprocess stage
    cache_saved_s: float = 0.0  # estimated batch latency the skips avoided
    shed: int = 0  # requests load-shed (admission Shed + full-queue eviction)
    rejoins: int = 0  # replicas re-admitted to the pool (warm rejoin / scale-up)
    per_class: tuple[ClassSnapshot, ...] = ()  # per-SLO-class breakdown
    # true high-water marks, updated at every admission / dispatch (the
    # *_mean/_max fields above are point samples taken at scheduler drains
    # and miss bursts between drains)
    queue_depth_hwm: int = 0  # max total queued depth ever observed
    inflight_hwm: int = 0  # max concurrently-inflight micro-batches
    stragglers_by_replica: tuple[tuple[int, int], ...] = ()  # (replica_id, count)

    @property
    def cache_hit_rate(self) -> float:
        """hits / lookups of the preprocess cache, 0.0 with no lookups."""
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    def for_class(self, name: str) -> ClassSnapshot | None:
        """The ClassSnapshot of one SLO class name, None if never seen."""
        for cs in self.per_class:
            if cs.name == name:
                return cs
        return None

    def format_class_rows(self) -> str:
        """Multi-line per-class summary (one ClassSnapshot.format_row each)."""
        return "\n".join(cs.format_row() for cs in self.per_class)

    def format_row(self) -> str:
        """One-line human summary (the serve benchmarks print this)."""
        row = (
            f"completed={self.completed} rejected={self.rejected} "
            f"expired={self.expired} thr={self.throughput_rps:.1f}/s "
            f"p50={self.latency_p50_s * 1e3:.1f}ms p95={self.latency_p95_s * 1e3:.1f}ms "
            f"p99={self.latency_p99_s * 1e3:.1f}ms occ={self.mean_occupancy:.2f}"
        )
        if self.cache_hits or self.cache_misses:
            row += (
                f" hit={self.cache_hit_rate:.2f}"
                f" skip={self.preprocess_skipped}"
                f" saved={self.cache_saved_s * 1e3:.1f}ms"
            )
        return row


class _ClassStats:
    """Mutable per-SLO-class tallies inside ServeMetrics (lock owned there)."""

    __slots__ = (
        "submitted",
        "completed",
        "shed",
        "expired",
        "rejected",
        "latencies",
        "depth_hwm",
    )

    def __init__(self):
        self.submitted = 0
        self.completed = 0
        self.shed = 0
        self.expired = 0
        self.rejected = 0
        self.latencies: list[float] = []
        self.depth_hwm = 0


class ServeMetrics:
    """Mutable, thread-safe metrics hub for one runtime instance.

    Request-outcome recorders take an optional SLO class name; aggregate
    counters always move, and the named class's breakdown moves with them
    (the per-class view in `snapshot().per_class`).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self.submitted = 0
        self.completed = 0
        self.rejected = 0
        self.expired = 0
        self.failed = 0
        self.retries = 0
        self.evictions = 0
        self.rejoins = 0
        self.shed = 0
        self.straggler_events = 0
        self.cache_hits = 0
        self.cache_misses = 0
        self.queue_depth_hwm = 0
        self.inflight_hwm = 0
        self._straggler_by_replica: dict[int, int] = {}
        self._latencies: list[float] = []
        self._latency_ts: list[float] = []  # completion stamps, parallel to _latencies
        self._sizes: list[int] = []  # admitted request sizes (n_points)
        self._arrivals: list[float] = []  # admission stamps, parallel to _sizes
        self._arrival_names: list[str] = []  # SLO class names, parallel to _sizes
        self._depths: list[int] = []
        self._batches: list[BatchRecord] = []
        self._by_class: dict[str, _ClassStats] = {}
        self._first_t: float | None = None
        self._last_t: float | None = None

    def _cls(self, name: str | None) -> _ClassStats:
        """Per-class tally for `name` (call under the lock); None -> default."""
        return self._by_class.setdefault(name or "default", _ClassStats())

    # -- recording (one lock-protected append each) --------------------------

    def record_submitted(self, slo_name: str | None = None):
        """Count one admitted request (starts the observation window)."""
        with self._lock:
            self.submitted += 1
            self._cls(slo_name).submitted += 1
            if self._first_t is None:
                self._first_t = time.monotonic()

    def record_arrival(self, n_points: int, slo_name: str | None = None):
        """Record one admitted request's cloud size and arrival instant.

        The adaptive controller's raw material: `request_sizes()` feeds the
        bucket-boundary proposal, `arrival_times()` / `arrivals_by_class()`
        the inter-arrival and batching-patience estimates.  Reservoir-
        bounded like every series.
        """
        with self._lock:
            self._sizes.append(int(n_points))
            self._arrivals.append(time.monotonic())
            self._arrival_names.append(slo_name or "default")
            del self._sizes[:-_RESERVOIR]
            del self._arrivals[:-_RESERVOIR]
            del self._arrival_names[:-_RESERVOIR]

    def record_rejected(self, slo_name: str | None = None):
        """Count one request refused at admission (QueueFull/QueueClosed)."""
        with self._lock:
            self.rejected += 1
            self._cls(slo_name).rejected += 1

    def record_shed(self, slo_name: str | None = None):
        """Count one request load-shed (admission Shed or queued eviction)."""
        with self._lock:
            self.shed += 1
            self._cls(slo_name).shed += 1

    def record_expired(self, slo_name: str | None = None):
        """Count one request failed because its deadline passed."""
        with self._lock:
            self.expired += 1
            self._cls(slo_name).expired += 1

    def record_failed(self, n: int = 1):
        """Count n requests failed by execution errors (not deadlines)."""
        with self._lock:
            self.failed += n

    def record_retry(self):
        """Count one batch re-dispatch after a replica failure."""
        with self._lock:
            self.retries += 1

    def record_eviction(self):
        """Count one replica evicted by the heartbeat monitor."""
        with self._lock:
            self.evictions += 1

    def record_rejoin(self):
        """Count one replica re-admitted to the pool (warm rejoin/scale-up)."""
        with self._lock:
            self.rejoins += 1

    def record_straggler(self, event=None, replica_id: int | None = None):
        """Count one straggler event (slow-but-alive replica batch).

        `event` is the StragglerMonitor's StragglerEvent (duration/median/
        ratio); `replica_id` attributes it to the replica whose monitor
        fired, feeding the `stragglers_by_replica` snapshot breakdown.
        """
        del event  # durations flow to the trace stream (ReplicaPool hook)
        with self._lock:
            self.straggler_events += 1
            if replica_id is not None:
                self._straggler_by_replica[replica_id] = (
                    self._straggler_by_replica.get(replica_id, 0) + 1
                )

    def record_queue_hwm(self, depth: int, slo_name: str | None = None,
                         class_depth: int | None = None):
        """Raise the queue-depth high-water marks after one admission.

        Called by the admission queue with the post-append total depth and
        the admitted request's lane depth — unlike record_queue_depth this
        sees every enqueue, so bursts between scheduler drains register.
        """
        with self._lock:
            if depth > self.queue_depth_hwm:
                self.queue_depth_hwm = depth
            if class_depth is not None:
                cls = self._cls(slo_name)
                if class_depth > cls.depth_hwm:
                    cls.depth_hwm = class_depth

    def record_inflight(self, n: int):
        """Raise the inflight-micro-batch high-water mark after a dispatch."""
        with self._lock:
            if n > self.inflight_hwm:
                self.inflight_hwm = n

    def record_cache_lookup(self, hit: bool, n: int = 1):
        """Count n preprocess-cache probes resolved at batch execution."""
        with self._lock:
            if hit:
                self.cache_hits += n
            else:
                self.cache_misses += n

    def record_completed(self, latency_s: float, slo_name: str | None = None):
        """Record one completed request and its end-to-end latency."""
        with self._lock:
            self.completed += 1
            self._last_t = time.monotonic()
            self._latencies.append(latency_s)
            self._latency_ts.append(self._last_t)
            del self._latencies[:-_RESERVOIR]
            del self._latency_ts[:-_RESERVOIR]
            cls = self._cls(slo_name)
            cls.completed += 1
            cls.latencies.append(latency_s)
            del cls.latencies[:-_RESERVOIR]

    def record_queue_depth(self, depth: int):
        """Sample the admission-queue depth at a scheduler drain."""
        with self._lock:
            self._depths.append(depth)
            del self._depths[:-_RESERVOIR]

    def record_batch(self, record: BatchRecord):
        """Log one executed micro-batch (occupancy/duration source)."""
        with self._lock:
            self._batches.append(record)
            del self._batches[:-_RESERVOIR]

    # -- reading --------------------------------------------------------------

    def request_sizes(self) -> np.ndarray:
        """Retained admitted-request sizes (newest _RESERVOIR), int64 array."""
        with self._lock:
            return np.asarray(self._sizes, np.int64)

    def arrival_times(self) -> np.ndarray:
        """Retained admission instants (time.monotonic), float64 array."""
        with self._lock:
            return np.asarray(self._arrivals, np.float64)

    def arrivals_by_class(self) -> dict[str, np.ndarray]:
        """Admission instants split per SLO class name (per-class patience)."""
        with self._lock:
            out: dict[str, list[float]] = {}
            for t, name in zip(self._arrivals, self._arrival_names):
                out.setdefault(name, []).append(t)
            return {name: np.asarray(ts, np.float64) for name, ts in out.items()}

    def latencies_since(self, t: float) -> np.ndarray:
        """Latencies of requests completed at or after monotonic instant `t`.

        The rollback guard's window: percentiles over only the completions
        observed since a reconfiguration, so a swap's effect is judged
        against fresh evidence rather than the whole reservoir.
        """
        with self._lock:
            return np.asarray(
                [
                    lat
                    for lat, ts in zip(self._latencies, self._latency_ts)
                    if ts >= t
                ],
                np.float64,
            )

    @property
    def batch_records(self) -> tuple[BatchRecord, ...]:
        """The retained BatchRecord log (newest _RESERVOIR entries)."""
        with self._lock:
            return tuple(self._batches)

    def snapshot(self) -> MetricsSnapshot:
        """Reduce the raw samples to a MetricsSnapshot.

        Throughput is completed requests over the first-submit..last-complete
        window; occupancy averages n_real/batch_size over batches that
        carried real traffic (warmup batches are excluded).
        """
        with self._lock:
            lat = np.asarray(self._latencies, np.float64)
            p50, p95, p99 = (
                (float(np.percentile(lat, q)) for q in (50, 95, 99))
                if lat.size
                else (0.0, 0.0, 0.0)
            )
            window = (
                (self._last_t - self._first_t)
                if self._first_t is not None and self._last_t is not None
                else 0.0
            )
            # warmup batches carry no requests (n_real=0); averaging them in
            # would understate the occupancy real traffic actually saw
            real = [b for b in self._batches if b.n_real]
            occ = (
                float(np.mean([b.n_real / b.batch_size for b in real]))
                if real
                else 0.0
            )
            # saved-latency estimate: what an all-hit batch costs vs what the
            # same traffic costs through the full preprocess+feature path.
            # An estimate, not a measurement — the avoided work never ran
            skipped = [b.duration_s for b in real if b.preprocess_skipped]
            full = [b.duration_s for b in real if not b.preprocess_skipped]
            saved = (
                len(skipped) * max(0.0, float(np.mean(full)) - float(np.mean(skipped)))
                if skipped and full
                else 0.0
            )
            depths = np.asarray(self._depths, np.int64)
            per_class = []
            for name in sorted(self._by_class):
                cls = self._by_class[name]
                clat = np.asarray(cls.latencies, np.float64)
                cp50, cp95 = (
                    (float(np.percentile(clat, q)) for q in (50, 95))
                    if clat.size
                    else (0.0, 0.0)
                )
                per_class.append(ClassSnapshot(
                    name=name,
                    submitted=cls.submitted,
                    completed=cls.completed,
                    shed=cls.shed,
                    expired=cls.expired,
                    rejected=cls.rejected,
                    latency_p50_s=cp50,
                    latency_p95_s=cp95,
                    depth_hwm=cls.depth_hwm,
                ))
            return MetricsSnapshot(
                submitted=self.submitted,
                completed=self.completed,
                rejected=self.rejected,
                expired=self.expired,
                failed=self.failed,
                retries=self.retries,
                evictions=self.evictions,
                batches=len(real),
                straggler_events=self.straggler_events,
                latency_p50_s=p50,
                latency_p95_s=p95,
                latency_p99_s=p99,
                throughput_rps=(self.completed / window) if window > 0 else 0.0,
                mean_occupancy=occ,
                queue_depth_mean=float(depths.mean()) if depths.size else 0.0,
                queue_depth_max=int(depths.max()) if depths.size else 0,
                cache_hits=self.cache_hits,
                cache_misses=self.cache_misses,
                preprocess_skipped=len(skipped),
                cache_saved_s=saved,
                shed=self.shed,
                rejoins=self.rejoins,
                per_class=tuple(per_class),
                queue_depth_hwm=self.queue_depth_hwm,
                inflight_hwm=self.inflight_hwm,
                stragglers_by_replica=tuple(
                    sorted(self._straggler_by_replica.items())
                ),
            )
