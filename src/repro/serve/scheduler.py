"""Dynamic micro-batcher — drain, bucket, batch, dispatch.

The scheduler is the piece that turns ragged open-loop traffic into the
static shapes the compiled artifacts want.  One background thread drains the
admission queue and groups requests by `(bucket, policy)`:

  * bucket — the smallest configured static n_points shape that holds the
    cloud (larger clouds stride-subsample down to the largest bucket), via
    the same `pad_cloud` used by the synchronous serve path.  Each bucket is
    ONE jit trace of the accelerator's forward, so a small bucket set caps
    compilation while keeping padding waste low (the PointAcc "versatile
    mapping" idea applied to shapes).
  * policy — the resolved ExecutionPolicy.  A batch never mixes policies,
    so fp32 and SC W16A16 traffic can interleave at the request level while
    each micro-batch still hits exactly one (config, policy) artifact.  The
    policy's `pipeline` knob participates in the key too: batches under a
    "pipelined" policy run the replica's two-stage overlapped schedule
    (dispatch.py) while "sequential" batches run the fused artifact, and
    the two kinds of traffic NEVER share a micro-batch or an artifact.

  * SLO class — the request's `SLOClass` (serve/slo.py) completes the key,
    so a micro-batch never mixes service classes: an interactive batch
    never waits on a bulk class's flush timer, and a class with
    `max_wait_s` set flushes its partial batches on its own tighter bound.

A key flushes when it holds `max_batch` requests or its oldest request has
waited `max_wait_s` (tightened per class by `SLOClass.max_wait_s`) — the
classic dynamic-batching latency/occupancy knob.  Keys flush in priority
order, so when higher- and lower-class batches are ready in the same drain
tick the higher class is dispatched (and starts executing) first.  Batch
assembly (`assemble_batch`) and result scatter (`scatter_results`)
are pure functions shared with the tests, which pin the scheduler's output
bitwise against a direct `accel.infer` on the same padded batch.

`max_inflight` bounds dispatched-but-unfinished batches.  This is what
makes the SLO policy REAL under overload: without it the drain loop shovels
the whole backlog into the replicas' FIFO executor queues, where priority,
EDF and shedding no longer apply (an interactive batch waits behind every
bulk batch dispatched before it).  With the bound, the scheduler only
drains what the replicas can actually absorb, the backlog stays in the
admission queue — drained priority-first, shed above the budget — and a
later high-class arrival overtakes every bulk request still queued.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Sequence

import numpy as np

from repro.serve.metrics import ServeMetrics
from repro.serve.pointcloud import inverse_subsample_indices, pad_cloud
from repro.serve.queue import (
    AdmissionQueue,
    DeadlineExceeded,
    Request,
    try_set_exception,
    try_set_result,
)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Dynamic-batching knobs: batch size, flush latency, drain granularity.

    The config is VERSIONED and swapped atomically: the drain loop reads
    `scheduler.config` exactly once per tick into a local, so every batch
    of one tick is assembled under one consistent config — a live
    reconfiguration (`BatchScheduler.apply_config`) can never produce a
    batch that mixes the old `max_batch` shape with the new one.
    """

    max_batch: int = 8  # static batch dim of every micro-batch
    max_wait_s: float = 0.005  # flush a partial batch after this long
    drain_tick_s: float = 0.002  # scheduler wake-up granularity
    # dispatched-but-unfinished batch bound (None = unbounded).  Set it to a
    # small multiple of the replica count so overload backlog stays in the
    # admission queue (where priority/EDF/shedding act) instead of the
    # replicas' FIFO executor queues (where nothing does)
    max_inflight: int | None = None
    # monotonically increasing on every live reconfiguration; batches and
    # decision logs reference the version their knobs came from
    version: int = 0
    # per-class partial-flush wait overrides from the adaptive controller,
    # (class name, seconds) pairs — tighter of this and SLOClass.max_wait_s
    # wins; a hashable tuple so the config stays frozen/comparable
    class_max_wait: tuple[tuple[str, float], ...] = ()

    def wait_for_class(self, name: str) -> float | None:
        """The configured per-class wait override for `name`, or None."""
        for cls_name, wait_s in self.class_max_wait:
            if cls_name == name:
                return wait_s
        return None


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash: lives in sets
class MicroBatch:
    """One schedulable unit: same bucket, same policy, static shape.

    When the runtime enables the preprocess cache, `cache` carries it and
    `cache_entries` holds one CacheEntry-or-None per request as PEEKED at
    assembly time (each hit's canonical row was substituted into `batch`,
    so a hit row IS the cloud its cached neighborhoods were computed from).
    The dispatch layer re-probes at execution time — an assembly-time miss
    whose cloud was inserted by an earlier batch upgrades to a hit there —
    then splices hits / inserts misses; a batch whose every request hit
    skips the preprocess stage entirely.
    """

    requests: tuple[Request, ...]
    bucket: int  # n_points of the batch
    policy: object  # resolved ExecutionPolicy
    batch: np.ndarray  # (max_batch, bucket, 3 + F) float32, filler rows zero
    cache: object | None = None  # PreprocessCache, None = caching disabled
    cache_entries: tuple = ()  # per-request CacheEntry | None (when cache is set)
    batch_id: int = -1  # trace span id (-1 = untraced, e.g. warmup batches)

    @property
    def n_real(self) -> int:
        """Real requests in the batch; rows beyond this are zero filler."""
        return len(self.requests)

    @property
    def n_hits(self) -> int:
        """Requests whose preprocess result came from the cache."""
        return sum(1 for e in self.cache_entries if e is not None)

    @property
    def all_hit(self) -> bool:
        """True when EVERY real request hit — preprocess can be skipped."""
        return (
            self.cache is not None
            and self.n_real > 0
            and len(self.cache_entries) == self.n_real
            and all(e is not None for e in self.cache_entries)
        )


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket that holds an n-row cloud.

    Oversized clouds take the largest bucket (and stride-subsample down to
    it, like pad_cloud).
    """
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


def assemble_batch(
    requests: Sequence[Request],
    bucket: int,
    width: int,
    max_batch: int,
    rows: Sequence[np.ndarray | None] | None = None,
) -> np.ndarray:
    """Pure batch assembly onto the static (max_batch, bucket, width) shape.

    Each request's cloud is fitted to `bucket` rows via pad_cloud; filler
    batch rows stay zero.  `rows` optionally supplies pre-fitted
    (bucket, width) rows per request — the runtime's admission-time fit,
    or a cache hit's CANONICAL row (substituting it is what makes hit
    responses bitwise-equal to recomputing the cached cloud); a None entry
    falls back to pad_cloud.  Shared with tests so scheduler batches are
    bitwise-reproducible outside the runtime.
    """
    batch = np.zeros((max_batch, bucket, width), np.float32)
    for i, req in enumerate(requests):
        row = rows[i] if rows is not None else None
        if row is None:
            row = pad_cloud(np.asarray(req.cloud, np.float32), bucket)[0]
        batch[i] = row
    return batch


def scatter_results(task: str, logits: np.ndarray, mb: MicroBatch) -> list[np.ndarray]:
    """Per-request outputs from batched logits.

    cls: row i of the logits.  seg: padding rows dropped; for subsampled
    (oversized) clouds every original row gets its nearest surviving row's
    scores via the exact inverse of subsample_indices.
    """
    out = []
    for i, req in enumerate(mb.requests):
        if task != "seg":
            out.append(np.asarray(logits[i]))
        elif req.n_orig <= mb.bucket:
            out.append(np.asarray(logits[i, : req.n_orig]))
        else:
            inv = inverse_subsample_indices(req.n_orig, mb.bucket)
            out.append(np.asarray(logits[i, inv]))
    return out


class BatchScheduler:
    """Background drain loop: queue -> MicroBatch -> dispatch_fn.

    dispatch_fn(mb) is the replica pool's submit; it returns a future whose
    result is the batched logits (np.ndarray).  The scheduler wires the
    per-request scatter + metrics into the future's done-callback, so result
    fan-out happens on the replica thread and the drain loop never blocks on
    execution (Mesorasi-style stage decoupling: admission, batching and
    compute overlap).
    """

    def __init__(
        self,
        queue: AdmissionQueue,
        dispatch_fn: Callable,
        *,
        task: str,
        width: int,
        buckets: Sequence[int],
        config: SchedulerConfig | None = None,
        metrics: ServeMetrics | None = None,
        cache=None,
        tracer=None,
    ):
        self.queue = queue
        self.dispatch_fn = dispatch_fn
        self.task = task
        self.width = width
        self.buckets = tuple(sorted(buckets))
        self.config = config or SchedulerConfig()
        self.metrics = metrics or ServeMetrics()
        self.cache = cache  # PreprocessCache | None — peeked at _dispatch
        self.tracer = tracer  # Tracer | None — None means tracing is off
        self._pending: dict[tuple, list[Request]] = {}
        self._inflight: set = set()
        self._inflight_cond = threading.Condition()
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run, name="pc2im-scheduler", daemon=True
        )

    # -- lifecycle ------------------------------------------------------------

    def start(self):
        """Start the background drain thread; returns self for chaining."""
        self._thread.start()
        return self

    def apply_config(self, config: SchedulerConfig) -> SchedulerConfig:
        """Atomically swap the scheduler config for the next drain tick.

        The drain loop reads `self.config` once per tick, so the swap is a
        single reference assignment: batches formed before the swap complete
        under the old config, batches formed after use the new one, and no
        batch ever mixes the two (the pause-free reconfiguration path —
        warm the new artifacts first, then call this).  Returns the applied
        config (its `version` is forced past the current one).
        """
        if config.version <= self.config.version:
            config = dataclasses.replace(config, version=self.config.version + 1)
        self.config = config
        return config

    def stop(self, drain: bool = True):
        """Stop the drain loop.

        drain=True flushes queued + pending requests and waits for their
        batches to complete first; drain=False cancels them.
        """
        self._stop.set()
        self._thread.join()
        leftovers = self.queue.close()
        if drain:
            self._admit(leftovers)
            self._flush_all()
            self._wait_inflight()
        else:
            for req in leftovers + [r for lst in self._pending.values() for r in lst]:
                req.future.cancel()
            self._pending.clear()

    def _wait_inflight(self, timeout_s: float = 60.0):
        deadline = time.monotonic() + timeout_s
        with self._inflight_cond:
            while self._inflight:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._inflight_cond.wait(remaining)

    # -- drain loop -----------------------------------------------------------

    def _budget(self, cfg: SchedulerConfig) -> int | None:
        """Batches the scheduler may still dispatch right now (None = ∞)."""
        if cfg.max_inflight is None:
            return None
        with self._inflight_cond:
            return cfg.max_inflight - len(self._inflight)

    def _run(self):
        while not self._stop.is_set():
            # ONE config read per tick: apply_config swaps the reference
            # atomically, so everything this iteration does — drain size,
            # flush thresholds, batch assembly shape — sees one consistent
            # config and never a half-applied reconfiguration
            cfg = self.config
            # the drain thread must survive anything a single bad request can
            # throw (it serves every OTHER request too) — _dispatch already
            # fails the affected batch; this is the last-resort guard
            try:
                budget = self._budget(cfg)
                if budget is not None and budget <= 0:
                    # replicas saturated: leave the backlog in the admission
                    # queue — draining it now would freeze its priority order
                    # into FIFO executor queues.  Wake when a batch finishes
                    with self._inflight_cond:
                        if len(self._inflight) >= cfg.max_inflight:
                            self._inflight_cond.wait(cfg.drain_tick_s)
                    continue
                reqs = self.queue.drain(cfg.max_batch, cfg.drain_tick_s)
                if reqs:
                    self.metrics.record_queue_depth(self.queue.depth() + len(reqs))
                self._admit(reqs)
                self._flush_ready(cfg)
            except Exception:  # noqa: BLE001
                self.metrics.record_failed()

    def _admit(self, reqs: Sequence[Request]):
        now = time.monotonic()
        for req in reqs:
            if self.tracer is not None and req.trace_id is not None:
                self.tracer.emit(
                    "request.drained", trace_id=req.trace_id, slo=req.slo.name, t=now
                )
            if req.future.done():  # client cancelled while queued
                continue
            if req.expired(now):
                self._expire(req)
                continue
            self._pending.setdefault(req.key, []).append(req)

    def _expire(self, req: Request):
        if try_set_exception(
            req.future, DeadlineExceeded(f"request {req.id} deadline passed")
        ):
            self.metrics.record_expired(req.slo.name)
            if self.tracer is not None and req.trace_id is not None:
                self.tracer.emit(
                    "request.expired", trace_id=req.trace_id, slo=req.slo.name
                )

    def _key_order(self, key: tuple) -> tuple:
        """Flush order of pending keys.

        Strict-priority mode: higher-priority classes first.  DRR mode
        (queue has class_weights): oldest drained request first — the
        weighted share is already encoded in the queue's drain order, and
        a priority sort here would hand every scarce dispatch slot back to
        the high class, re-starving the lanes DRR just protected.
        """
        if getattr(self.queue, "class_weights", None) is not None:
            lst = self._pending.get(key)
            return (min(r.id for r in lst) if lst else float("inf"),)
        return (-key[2].priority, key[2].name)

    def _max_wait(self, key: tuple, cfg: SchedulerConfig) -> float:
        """Partial-batch flush wait for one key — per-class bounds applied.

        The tightest of: the global `max_wait_s`, the class's own
        `SLOClass.max_wait_s`, and the adaptive controller's per-class
        override in `cfg.class_max_wait`.
        """
        wait = cfg.max_wait_s
        slo_wait = key[2].max_wait_s
        if slo_wait is not None:
            wait = min(wait, slo_wait)
        override = cfg.wait_for_class(key[2].name)
        if override is not None:
            wait = min(wait, override)
        return wait

    def _flush_ready(self, cfg: SchedulerConfig):
        now = time.monotonic()
        budget = self._budget(cfg)
        for key in sorted(self._pending, key=self._key_order):
            # priority-first AND budget-aware: when capacity is scarce the
            # highest class takes the remaining dispatch slots
            if budget is not None and budget <= 0:
                return
            lst = self._pending[key]
            while len(lst) >= cfg.max_batch and (budget is None or budget > 0):
                chunk, self._pending[key] = lst[: cfg.max_batch], lst[cfg.max_batch :]
                lst = self._pending[key]
                self._dispatch(key, chunk, cfg)
                if budget is not None:
                    budget -= 1
            if (
                lst
                and (budget is None or budget > 0)
                and now - lst[0].submit_t >= self._max_wait(key, cfg)
            ):
                self._pending[key] = []
                self._dispatch(key, lst, cfg)
                if budget is not None:
                    budget -= 1

    def _flush_all(self):
        # stop-time drain: the inflight bound is deliberately ignored — the
        # runtime is closing, the only goal is completing what was admitted
        cfg = self.config
        for key in sorted(self._pending, key=self._key_order):
            lst, self._pending[key] = self._pending[key], []
            for lo in range(0, len(lst), cfg.max_batch):
                self._dispatch(key, lst[lo : lo + cfg.max_batch], cfg)

    def _dispatch(self, key: tuple, requests: list[Request], cfg: SchedulerConfig | None = None):
        if cfg is None:
            cfg = self.config
        # shed what expired (or was cancelled) while waiting in _pending —
        # deadlines are re-checked at every stage, not just admission
        now = time.monotonic()
        live = []
        for req in requests:
            if req.expired(now):
                self._expire(req)
            elif not req.future.done():
                live.append(req)
        if not live:
            return
        bucket, policy, _slo = key
        # the preprocess cache does not compose with sharded policies (their
        # batches run the mesh artifact end to end; cached rows are single-
        # device host trees) — a sharded batch carries no cache at all, so
        # the dispatch layer's cache paths never see it
        cache = (
            self.cache if getattr(policy, "sharding", None) is None else None
        )
        try:
            entries: tuple = ()
            rows = None
            if cache is not None:
                # probe material is computed lazily HERE, on the scheduler
                # thread: admission stays O(1) for clients, and the fit +
                # hash overlap batch execution on the replica workers
                # instead of delaying either (tests may pre-compute keys;
                # those are kept as-is)
                for req in live:
                    if req.cache_key is None:
                        req.fitted = pad_cloud(
                            np.asarray(req.cloud, np.float32), bucket
                        )[0]
                        req.cache_key = cache.key_for(
                            bucket, policy, req.fitted
                        )
                # side-effect-free peek: a hit's canonical row replaces the
                # request's own fitted row in the batch, so the feature stage
                # consumes exactly the cloud the cached neighborhoods were
                # computed from.  The COUNTED lookup happens at execution
                # time (dispatch.py), where inserts from every earlier batch
                # on the replica are already visible — a peek-miss here can
                # still become a hit there.
                probe = [
                    cache.peek(req.cache_key)
                    if req.cache_key is not None
                    else None
                    for req in live
                ]
                entries = tuple(probe)
                if self.tracer is not None:
                    for req, ent in zip(live, entries):
                        if req.trace_id is not None:
                            self.tracer.emit(
                                "request.cache_peek",
                                trace_id=req.trace_id,
                                slo=req.slo.name,
                                args={"hit": ent is not None},
                            )
                rows = [
                    ent.row if ent is not None else req.fitted
                    for req, ent in zip(live, entries)
                ]
            batch = assemble_batch(
                live, bucket, self.width, cfg.max_batch, rows=rows
            )
        except Exception as e:  # noqa: BLE001 — one bad cloud fails ITS batch only
            self.metrics.record_failed(len(live))
            for req in live:
                won = try_set_exception(req.future, e)
                if won and self.tracer is not None and req.trace_id is not None:
                    self.tracer.emit(
                        "request.failed", trace_id=req.trace_id, slo=req.slo.name
                    )
            return
        mb = MicroBatch(
            requests=tuple(live),
            bucket=bucket,
            policy=policy,
            batch=batch,
            cache=cache,
            cache_entries=entries,
            batch_id=self.tracer.next_batch_id() if self.tracer is not None else -1,
        )
        if self.tracer is not None:
            self.tracer.emit(
                "batch.assembled",
                batch_id=mb.batch_id,
                slo=_slo.name,
                args={
                    "members": [r.trace_id for r in live if r.trace_id is not None],
                    "bucket": bucket,
                    "n_real": mb.n_real,
                    "n_hits": mb.n_hits,
                },
            )
            for req in live:
                if req.trace_id is not None:
                    self.tracer.emit(
                        "request.assembled",
                        trace_id=req.trace_id,
                        batch_id=mb.batch_id,
                        slo=req.slo.name,
                    )
        with self._inflight_cond:
            self._inflight.add(mb)
            n_inflight = len(self._inflight)
        self.metrics.record_inflight(n_inflight)
        fut = self.dispatch_fn(mb)
        fut.add_done_callback(lambda f, mb=mb: self._on_batch_done(mb, f))

    def _on_batch_done(self, mb: MicroBatch, fut):
        try:
            err = fut.exception()
            if err is not None:
                self.metrics.record_failed(mb.n_real)
                if self.tracer is not None and mb.batch_id != -1:
                    self.tracer.emit("batch.failed", batch_id=mb.batch_id)
                for req in mb.requests:
                    won = try_set_exception(req.future, err)
                    if won and self.tracer is not None and req.trace_id is not None:
                        self.tracer.emit(
                            "request.failed", trace_id=req.trace_id, slo=req.slo.name
                        )
                return
            outs = scatter_results(self.task, fut.result(), mb)
            now = time.monotonic()
            for req, out in zip(mb.requests, outs):
                if req.expired(now):
                    # executed but too late: an SLO client must NOT count a
                    # deadline-violating response as success
                    self._expire(req)
                elif try_set_result(req.future, out):
                    self.metrics.record_completed(now - req.submit_t, req.slo.name)
                    if self.tracer is not None and req.trace_id is not None:
                        # same `now` as the latency metric: the trace e2e and
                        # the recorded latency agree by construction
                        self.tracer.emit(
                            "request.completed",
                            trace_id=req.trace_id,
                            batch_id=mb.batch_id,
                            slo=req.slo.name,
                            t=now,
                        )
            if self.tracer is not None and mb.batch_id != -1:
                self.tracer.emit("batch.completed", batch_id=mb.batch_id)
        finally:
            with self._inflight_cond:
                self._inflight.discard(mb)
                self._inflight_cond.notify_all()
