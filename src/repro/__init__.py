"""repro — PC2IM (SRAM-CIM point-cloud accelerator) reproduced as a JAX/TPU framework.

Layers:
  core/       the paper's contributions (C1-C5) as composable JAX modules
  kernels/    Pallas TPU kernels for the compute hot-spots
  models/     model zoo (PointNet2 + 10 assigned LM-family architectures)
  configs/    exact published configs + reduced smoke configs
  sharding/   FSDP x TP x pod-DP partitioning policy
  train/serve optimizer-driven train_step, prefill/decode serve steps
  launch/     production mesh, multi-pod dry-run, drivers
"""

__version__ = "1.0.0"
