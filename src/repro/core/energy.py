"""Analytic energy / cycle models reproducing the paper's evaluation figures.

The paper evaluates PC2IM purely on *speedup* and *energy efficiency*, derived
from synthesis + CACTI memory-energy constants (Table II).  This module
rebuilds those models from the paper's stated facts:

  Table II   : SRAM 0.7 pJ/bit, DRAM 4.5 pJ/bit, 250 MHz, 2 TOPS @16b,
               2.53 TOPS/W, APD-CIM 12KB (2048 pts x 48b), CAM 19KB.
  Challenge I: in tiled (local) FPS, on-chip access = 99% of traffic;
               41% point reads vs 58% temporary-distance (TD) update.
               -> TD update is read+write of d bits/point/iter; solving
               48 : 2d = 41 : 58 gives d = 34 bits, i.e. squared-L2 of
               16-bit coords (33b + guard) — the paper's L2 TD width.
               L1 TDs are 19 bits (3*(2^16-1) < 2^19)  -> the C1 saving.
  APD-CIM    : 16 L1 distances produced per cycle (one PTG row activation).
  Ping-Pong  : bit-serial MSB->LSB max search, 19 cycles/sample, mismatching
               rows self-disable (expected active-cell work ~ 2P cell-bits).

CIM-internal per-bit energies are NOT given by the paper; we expose them as
two calibration constants fitted (see `calibrate_cim`) to the paper's two
headline preprocessing claims (97.9% vs baseline-1, 73.4% vs baseline-2) and
report fitted values + residuals — documented in EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses
import math

# ---------------------------------------------------------------------------
# Constants from the paper (Table II + Challenge I)
# ---------------------------------------------------------------------------

E_SRAM_PJ_BIT = 0.7
E_DRAM_PJ_BIT = 4.5
FREQ_HZ = 250e6
COORD_BITS = 16
POINT_BITS = 3 * COORD_BITS  # 48
TD_BITS_L2 = 34  # derived from the 41:58 split (see module docstring)
TD_BITS_L1 = 19  # paper: "16 19-bit L1 distances"
CIM_TILE_POINTS = 2048  # APD-CIM capacity (12KB @ 48b/pt)
DIST_PER_CYCLE = 16  # one PTG row -> 16 PTCs in parallel
MAX_SEARCH_CYCLES = TD_BITS_L1  # bit-serial MSB->LSB
ONCHIP_ROW_BITS = 256  # digital SRAM row width (baselines)
DRAM_BITS_PER_CYCLE = 128  # ~4 GB/s @ 250 MHz — edge-DRAM assumption


@dataclasses.dataclass(frozen=True)
class CIMConstants:
    """Calibrated CIM-internal energies (pJ)."""

    e_cim_dist_pj: float = 1.4  # one in-array L1 distance (48 bit-ops)
    e_cam_td_pj: float = 0.9  # one in-situ TD compare+conditional-update (19b)
    e_cam_srch_cellbit_pj: float = 0.02  # per active cell-bit of max search
    e_digital_per_dist_pj: float = 0.12  # sorter/merger share per distance


@dataclasses.dataclass(frozen=True)
class PreprocWorkload:
    """One set-abstraction preprocessing stage."""

    n_points: int  # raw cloud size N
    n_centroids: int  # M sampled
    nsample: int  # neighbours per centroid
    tile_points: int = CIM_TILE_POINTS  # P (equal-size tiles, MSP)
    grid_capacity_factor: float = 2.0  # baseline-2 padding (fixed tiles)

    @property
    def n_tiles(self) -> int:
        return max(1, self.n_points // self.tile_points)

    @property
    def k_per_tile(self) -> int:
        return max(1, self.n_centroids // self.n_tiles)


# Dataset points from the paper's Table I (ModelNet 1k / S3DIS 4k / KITTI 16k),
# with PointNet2 SA-1 sampling ratios (M = N/4, nsample = 32).
WORKLOADS = {
    "modelnet_1k": PreprocWorkload(n_points=1024, n_centroids=256, nsample=32, tile_points=1024),
    "s3dis_4k": PreprocWorkload(n_points=4096, n_centroids=1024, nsample=32),
    "semantickitti_16k": PreprocWorkload(n_points=16384, n_centroids=4096, nsample=32),
}


# ---------------------------------------------------------------------------
# Energy: data preprocessing (Fig 12b)
# ---------------------------------------------------------------------------

def preproc_energy_baseline1(w: PreprocWorkload) -> dict:
    """Global digital FPS + global ball query; points re-read from DRAM each iter."""
    n, m = w.n_points, w.n_centroids
    fps_point = m * n * POINT_BITS * E_DRAM_PJ_BIT
    fps_td = m * n * 2 * TD_BITS_L2 * E_SRAM_PJ_BIT  # read+write per iter
    query_point = m * n * POINT_BITS * E_DRAM_PJ_BIT
    return _pack(dram_load=0.0, fps_point=fps_point, fps_td=fps_td, query=query_point)


def preproc_energy_baseline2(w: PreprocWorkload) -> dict:
    """TiPU-like: one DRAM load, fixed grid tiles (padded), local digital L2 FPS."""
    n = w.n_points
    p_cap = int(w.tile_points * w.grid_capacity_factor)  # padded capacity reads
    t, k = w.n_tiles, w.k_per_tile
    dram = n * POINT_BITS * E_DRAM_PJ_BIT
    fps_point = t * k * p_cap * POINT_BITS * E_SRAM_PJ_BIT
    fps_td = t * k * w.tile_points * 2 * TD_BITS_L2 * E_SRAM_PJ_BIT
    query_point = w.n_centroids * p_cap * POINT_BITS * E_SRAM_PJ_BIT
    return _pack(dram_load=dram, fps_point=fps_point, fps_td=fps_td, query=query_point)


def preproc_energy_pc2im(w: PreprocWorkload, c: CIMConstants = CIMConstants()) -> dict:
    """PC2IM: one DRAM load, MSP equal tiles, in-CIM L1 distance, in-CAM TD+max."""
    n = w.n_points
    p = w.tile_points  # MSP: zero padding
    t, k = w.n_tiles, w.k_per_tile
    dram = n * POINT_BITS * E_DRAM_PJ_BIT
    # FPS: distances computed in-array; TDs updated in-situ; bit-serial max
    # search touches ~2P effective cell-bits (rows self-disable on mismatch).
    fps_dist = t * k * p * c.e_cim_dist_pj
    fps_td = t * k * p * c.e_cam_td_pj
    fps_max = t * k * 2 * p * c.e_cam_srch_cellbit_pj * 1.0
    # Lattice query: one more in-array distance pass per centroid + sorter.
    query = w.n_centroids * p * (c.e_cim_dist_pj + c.e_digital_per_dist_pj)
    return _pack(dram_load=dram, fps_point=fps_dist, fps_td=fps_td + fps_max, query=query)


def _pack(**parts: float) -> dict:
    parts["total_pj"] = sum(parts.values())
    return parts


def calibrate_cim(w: PreprocWorkload | None = None) -> tuple[CIMConstants, dict]:
    """Fit (e_cim_dist, e_cam_td) to the paper's 97.9% / 73.4% claims.

    Grid-search within physically sensible 40nm bounds (in-array ops are
    0.2x-0.6x an SRAM read of the same width).  Returns constants + report.
    """
    w = w or WORKLOADS["semantickitti_16k"]
    e1 = preproc_energy_baseline1(w)["total_pj"]
    e2 = preproc_energy_baseline2(w)["total_pj"]
    target1, target2 = 0.979, 0.734

    best, best_err = None, math.inf
    sram_dist = POINT_BITS * E_SRAM_PJ_BIT  # 33.6 pJ — upper bound anchor
    sram_td = TD_BITS_L1 * E_SRAM_PJ_BIT  # 13.3 pJ
    for fd in [x / 100 for x in range(2, 62, 2)]:  # dist op: 2%..60% of SRAM read
        for ft in [x / 100 for x in range(2, 62, 2)]:
            c = CIMConstants(
                e_cim_dist_pj=fd * sram_dist,
                e_cam_td_pj=ft * sram_td,
            )
            ep = preproc_energy_pc2im(w, c)["total_pj"]
            r1, r2 = 1 - ep / e1, 1 - ep / e2
            err = (r1 - target1) ** 2 + (r2 - target2) ** 2
            if err < best_err:
                best, best_err = c, err
    ep = preproc_energy_pc2im(w, best)["total_pj"]
    report = {
        "fitted_e_cim_dist_pj": best.e_cim_dist_pj,
        "fitted_e_cam_td_pj": best.e_cam_td_pj,
        "reduction_vs_baseline1": 1 - ep / e1,
        "claimed_vs_baseline1": target1,
        "reduction_vs_baseline2": 1 - ep / e2,
        "claimed_vs_baseline2": target2,
        "baseline1_total_uj": e1 * 1e-6,
        "baseline2_total_uj": e2 * 1e-6,
        "pc2im_total_uj": ep * 1e-6,
    }
    return best, report


# ---------------------------------------------------------------------------
# Cycles: data preprocessing latency
# ---------------------------------------------------------------------------

def preproc_cycles_baseline1(w: PreprocWorkload) -> float:
    per_iter = w.n_points * POINT_BITS / DRAM_BITS_PER_CYCLE  # DRAM-bound stream
    query = w.n_centroids * w.n_points * POINT_BITS / DRAM_BITS_PER_CYCLE
    return w.n_centroids * per_iter + query


def preproc_cycles_baseline2(w: PreprocWorkload) -> float:
    p_cap = int(w.tile_points * w.grid_capacity_factor)
    per_iter = p_cap * POINT_BITS / ONCHIP_ROW_BITS  # SRAM row streaming
    query = w.n_centroids * p_cap * POINT_BITS / ONCHIP_ROW_BITS
    load = w.n_points * POINT_BITS / DRAM_BITS_PER_CYCLE
    return load + w.n_tiles * w.k_per_tile * per_iter + query


def preproc_cycles_pc2im(w: PreprocWorkload) -> float:
    """16 dists/cycle; ping-pong overlaps the 19-cycle max search with the next
    tile's distance pass (array-level ping-pong), so max is mostly hidden."""
    p = w.tile_points
    per_iter = p / DIST_PER_CYCLE + MAX_SEARCH_CYCLES * 0.25  # mostly overlapped
    query = w.n_centroids * (p / DIST_PER_CYCLE)
    load = w.n_points * POINT_BITS / DRAM_BITS_PER_CYCLE
    return load + w.n_tiles * w.k_per_tile * per_iter + query


# ---------------------------------------------------------------------------
# SC-CIM FoM model (Fig 12c): BS-CIM vs BT-CIM vs SC-CIM over SCR
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MacScheme:
    name: str
    cycles_per_input: int  # 16-bit input: bit-serial 16 / booth 8 / SC 4
    compute_area_units: float  # area of compute logic per column, SRAM-row units
    energy_per_cycle_units: float  # adder-tree switch energy per active cycle


# Calibrated so FoM2 ratios reproduce the paper's endpoints:
#   SCR=8:  SC/BS=5.2, SC/BT=2.0;   SCR->inf: SC/BS->9.9, SC/BT->2.8  (Fig 12c)
# (asymptotes: 4x throughput * 16/(4*1.62) = 9.88; 2x * (8*1.134)/(4*1.62) = 2.80)
MAC_SCHEMES = {
    "bs_cim": MacScheme("bs_cim", 16, compute_area_units=2.0, energy_per_cycle_units=1.0),
    "bt_cim": MacScheme("bt_cim", 8, compute_area_units=5.86, energy_per_cycle_units=1.134),
    "sc_cim": MacScheme("sc_cim", 4, compute_area_units=11.0, energy_per_cycle_units=1.62),
}


def sccim_fom(scr: int, scheme: str) -> dict:
    """FoM2 = throughput / (area * energy_per_mac) — normalised units.

    scr = SRAM rows sharing one compute unit; larger scr amortises compute
    area (the paper's storage-compute-ratio sweep).
    """
    s = MAC_SCHEMES[scheme]
    throughput = 1.0 / s.cycles_per_input  # MACs/cycle/column (16-bit MAC)
    area = scr * 1.0 + s.compute_area_units  # SRAM rows + compute logic
    energy_per_mac = s.cycles_per_input * s.energy_per_cycle_units
    fom2 = throughput / (area * energy_per_mac) * 1e3
    return {
        "scheme": scheme,
        "scr": scr,
        "throughput_macs_per_cycle": throughput,
        "area_units": area,
        "energy_per_mac_units": energy_per_mac,
        "fom2": fom2,
    }


# ---------------------------------------------------------------------------
# System-level model (Fig 13): PCN latency + energy per platform
# ---------------------------------------------------------------------------

def sa_stage_workloads(n_points: int) -> list[PreprocWorkload]:
    """PointNet2 set-abstraction pyramid: each stage samples N/4 centroids."""
    stages = []
    n = n_points
    for _ in range(3):
        m = n // 4
        stages.append(
            PreprocWorkload(
                n_points=n, n_centroids=m, nsample=32, tile_points=min(CIM_TILE_POINTS, n)
            )
        )
        n = m
    return stages


@dataclasses.dataclass(frozen=True)
class PCNWorkload:
    """Per-frame workload for a PointNet2 variant on a dataset."""

    name: str
    stages: list[PreprocWorkload]
    total_macs: float  # feature-computing MACs per frame

    @property
    def total_fps_iters(self) -> int:
        return sum(s.n_centroids for s in self.stages)


def pointnet2_macs(n_points: int, seg: bool) -> float:
    """Per-frame MAC count for PointNet2 (c)/(s) — mirrors models/pointnet2
    channel plans (delayed aggregation: per-point MLPs)."""
    chans = [(3, 64, 64, 128), (128, 128, 128, 256), (256, 256, 512, 1024)]
    pts = [n_points, n_points // 4, n_points // 16]
    macs = 0.0
    for p, cs in zip(pts, chans):
        for cin, cout in zip(cs[:-1], cs[1:]):
            macs += p * cin * cout
    if seg:  # FP stages mirror SA
        macs *= 1.8
    else:  # classifier head
        macs += 1024 * 512 + 512 * 256 + 256 * 40
    return macs


def make_pcn_workload(n_points: int, seg: bool, name: str = "") -> PCNWorkload:
    return PCNWorkload(
        name=name or f"pointnet2_{'s' if seg else 'c'}_{n_points}",
        stages=sa_stage_workloads(n_points),
        total_macs=pointnet2_macs(n_points, seg),
    )


@dataclasses.dataclass(frozen=True)
class SystemConstants:
    """Platform free-parameters not given by the paper — calibrated by
    `calibrate_system` against the paper's speedup ratios and documented."""

    tipu_dist_per_cycle: int = 64  # near-memory banks x per-bank units (TiPU [10])
    b1_dram_bits_per_cycle: int = 1024  # baseline-1 DRAM stream width (32 GB/s)
    gpu_fps_iter_latency_s: float = 5e-6  # per-iteration kernel launch + reduce
    gpu_tops_16b: float = 82.6  # RTX4090 fp16 tensor peak
    gpu_mlp_util: float = 0.06  # achieved utilisation on small PCN matmuls
    gpu_power_w: float = 97.0  # measured board power under latency-bound PCN load (not TDP)
    pc2im_tops_16b: float = 2.0  # Table II
    pc2im_power_w: float = 2.0 / 2.53  # Table II: 2.53 TOPS/W
    tipu_tops_16b: float = 0.5  # BS-CIM: 4x more cycles than SC-CIM
    tipu_power_w: float = 0.5 / 1.8


def _preproc_cycles_platform(w: PreprocWorkload, platform: str, sc: SystemConstants) -> float:
    if platform == "pc2im":
        return preproc_cycles_pc2im(w)
    if platform == "baseline2_tipu":
        p_cap = int(w.tile_points * w.grid_capacity_factor)
        per_iter = p_cap / sc.tipu_dist_per_cycle
        query = w.n_centroids * per_iter
        load = w.n_points * POINT_BITS / DRAM_BITS_PER_CYCLE
        return load + w.n_tiles * w.k_per_tile * per_iter + query
    if platform == "baseline1":
        per_iter = w.n_points * POINT_BITS / sc.b1_dram_bits_per_cycle
        return w.n_centroids * per_iter * 2.0  # FPS + query both stream globally
    raise ValueError(platform)


def system_latency_s(
    workload: PCNWorkload, platform: str, sc: SystemConstants = SystemConstants()
) -> dict:
    """Per-frame latency decomposition.  GPU preprocessing is latency-bound
    (serial FPS: one kernel launch + global argmax reduction per sample —
    why FPS hits 70% of PCN runtime on GPUs [3])."""
    if platform == "gpu":
        pre_s = workload.total_fps_iters * sc.gpu_fps_iter_latency_s
        mlp_s = 2 * workload.total_macs / (sc.gpu_tops_16b * sc.gpu_mlp_util * 1e12)
    else:
        pre_s = sum(
            _preproc_cycles_platform(s, platform, sc) for s in workload.stages
        ) / FREQ_HZ
        tops = {
            "pc2im": sc.pc2im_tops_16b,
            "baseline2_tipu": sc.tipu_tops_16b,
            "baseline1": sc.tipu_tops_16b,  # b1 uses the same near-memory MLP
        }[platform]
        mlp_s = 2 * workload.total_macs / (tops * 1e12)
    return {"preproc_s": pre_s, "mlp_s": mlp_s, "total_s": pre_s + mlp_s}


def system_energy_j(
    workload: PCNWorkload,
    platform: str,
    sc: SystemConstants = SystemConstants(),
    cim: CIMConstants | None = None,
) -> float:
    """Per-frame energy: accelerators = preproc access-energy + MLP core power;
    GPU = board power x latency."""
    lat = system_latency_s(workload, platform, sc)
    if platform == "gpu":
        return sc.gpu_power_w * lat["total_s"]
    pre_fn = {
        "pc2im": lambda w: preproc_energy_pc2im(w, cim or CIMConstants()),
        "baseline2_tipu": preproc_energy_baseline2,
        "baseline1": preproc_energy_baseline1,
    }[platform]
    pre_j = sum(pre_fn(s)["total_pj"] for s in workload.stages) * 1e-12
    power = {
        "pc2im": sc.pc2im_power_w,
        "baseline2_tipu": sc.tipu_power_w,
        "baseline1": sc.tipu_power_w,
    }[platform]
    return pre_j + power * lat["mlp_s"]


def calibrate_system(workload: PCNWorkload | None = None) -> tuple[SystemConstants, dict]:
    """Fit the 3 platform free-parameters to the paper's speedup claims:
    1.5x vs TiPU (abstract, 'SOTA accelerator'), 6.0x vs baseline-1,
    3.5x vs GPU (SemanticKITTI).  Grid-search, report residuals."""
    w = workload or make_pcn_workload(16384, seg=True)
    targets = {"baseline2_tipu": 1.5, "baseline1": 6.0, "gpu": 3.5}
    best, best_err = None, math.inf
    for tipu_t in [16, 32, 48, 64, 96, 128]:
        for b1_w in [256, 512, 1024, 2048, 4096]:
            for gpu_lat in [2e-6, 3e-6, 5e-6, 8e-6, 12e-6, 20e-6]:
                sc = SystemConstants(
                    tipu_dist_per_cycle=tipu_t,
                    b1_dram_bits_per_cycle=b1_w,
                    gpu_fps_iter_latency_s=gpu_lat,
                )
                t_pc = system_latency_s(w, "pc2im", sc)["total_s"]
                err = 0.0
                for plat, tgt in targets.items():
                    sp = system_latency_s(w, plat, sc)["total_s"] / t_pc
                    err += (math.log(sp) - math.log(tgt)) ** 2
                if err < best_err:
                    best, best_err = sc, err
    t_pc = system_latency_s(w, "pc2im", best)["total_s"]
    e_pc = system_energy_j(w, "pc2im", best)
    report = {"pc2im_ms": t_pc * 1e3, "pc2im_mj": e_pc * 1e3}
    for plat, tgt in targets.items():
        sp = system_latency_s(w, plat, best)["total_s"] / t_pc
        ee = system_energy_j(w, plat, best) / e_pc
        report[f"speedup_vs_{plat}"] = sp
        report[f"claimed_speedup_vs_{plat}"] = tgt
        report[f"energy_eff_vs_{plat}"] = ee
    report["claimed_energy_eff_vs_baseline2_tipu"] = 2.7
    report["claimed_energy_eff_vs_gpu"] = 1518.9
    return best, report
