"""Core PC2IM algorithms (paper contributions C1-C5).

C1  approximate-distance sampling (L1 FPS) + lattice query   -> fps.py, query.py
C2  median-based spatial partitioning (MSP)                  -> partition.py
C3  Ping-Pong-MAX fused distance-update/argmax dataflow      -> fps.py (fused step), kernels/fps
C4  split-concatenate W16A16 quantized MAC                   -> quant.py, kernels/sc_matmul
C5  delayed aggregation                                      -> grouping.py
Energy/cycle models for the paper's evaluation figures       -> energy.py
End-to-end preprocessing pipelines (baseline1/2, pc2im)      -> preprocess.py
Batched (B, N, 3) PreprocessEngine (batch x tiles -> 1 grid) -> engine.py
ExecutionPolicy (quant/backend/interpret, passed explicitly) -> policy.py
PC2IMAccelerator (config+policy -> compiled forward/infer)   -> accelerator.py
"""
