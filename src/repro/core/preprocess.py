"""End-to-end data-preprocessing pipelines: baseline-1, baseline-2 (TiPU-like), PC2IM.

All three produce the same interface — sampled centroids + neighbour sets —
so the PointNet2 model can swap them (`preproc="pc2im"` etc.):

  baseline1 : global exact-L2 FPS over the full cloud + global ball query.
  baseline2 : fixed-shape spatial grid tiles (padded, ragged occupancy) +
              local exact-L2 FPS + local ball query.            [TiPU 10]
  pc2im     : median partition (equal tiles) + local *L1* FPS +
              local lattice query (L = 1.6R).                   [this paper]

Everything is shape-static and jit/vmap-friendly; tiles vectorise with zero
padding for pc2im (the MSP property) and with `valid` masks for baseline2.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import fps as fps_mod
from repro.core import partition as part_mod
from repro.core import query as query_mod
from repro.core.query import NeighborSet


class PreprocessResult(NamedTuple):
    centroid_idx: jax.Array  # (M,) global indices into the input cloud
    centroid_xyz: jax.Array  # (M, 3)
    neighbors: NeighborSet  # idx (M, nsample) global; mask (M, nsample)
    centroid_valid: jax.Array  # (M,) False for centroids from padded tile slots


def preprocess_baseline1(
    points: jax.Array, n_centroids: int, radius: float, nsample: int
) -> PreprocessResult:
    """Global L2 FPS + global ball query (the costly canonical flow)."""
    cidx = fps_mod.fps(points, n_centroids, metric="l2")
    cxyz = jnp.take(points, cidx, axis=0)
    nbrs = query_mod.ball_query(points, cxyz, radius, nsample)
    return PreprocessResult(cidx, cxyz, nbrs, jnp.ones((n_centroids,), bool))


def _tiled_common(
    points: jax.Array,
    part: part_mod.Partition,
    n_centroids: int,
    radius: float,
    nsample: int,
    metric: str,
    query: str,
) -> PreprocessResult:
    """Shared tiled flow: local FPS per tile + local neighbour query per tile."""
    t, p = part.tiles.shape
    if n_centroids % t != 0:
        raise ValueError(f"n_centroids={n_centroids} not divisible by n_tiles={t}")
    k_per_tile = n_centroids // t

    coords = part_mod.partition_coords(points, part)  # (T, P, 3)

    # Local FPS (vmapped over tiles).  Padded slots (valid=False) are never
    # sampled: they are masked out of the argmax.
    local_c = jax.vmap(
        lambda c, v: fps_mod.fps(c, k_per_tile, metric=metric, valid=v)
    )(coords, part.valid)  # (T, k)
    cidx = jnp.take_along_axis(part.tiles, local_c, axis=1)  # global (T, k)
    cxyz = jnp.take(points, cidx, axis=0)  # (T, k, 3)
    # a centroid is real iff its tile slot was real
    cvalid = jnp.take_along_axis(part.valid, local_c, axis=1)  # (T, k)

    qfn = query_mod.lattice_query if query == "lattice" else query_mod.ball_query

    def tile_query(tile_coords, tile_cxyz, tile_valid):
        return qfn(tile_coords, tile_cxyz, radius, nsample, valid=tile_valid)

    nbrs_local = jax.vmap(tile_query)(coords, cxyz, part.valid)  # idx (T,k,S) local
    # map local neighbour slots back to global point indices
    nidx_global = jnp.take_along_axis(
        part.tiles[:, None, :].repeat(k_per_tile, axis=1).reshape(t * k_per_tile, p),
        nbrs_local.idx.reshape(t * k_per_tile, nsample),
        axis=1,
    )
    m = t * k_per_tile
    return PreprocessResult(
        centroid_idx=cidx.reshape(m),
        centroid_xyz=cxyz.reshape(m, 3),
        neighbors=NeighborSet(
            idx=nidx_global.reshape(m, nsample),
            mask=nbrs_local.mask.reshape(m, nsample) & cvalid.reshape(m)[:, None],
        ),
        centroid_valid=cvalid.reshape(m),
    )


def preprocess_baseline2(
    points: jax.Array,
    n_centroids: int,
    radius: float,
    nsample: int,
    *,
    grid: int = 2,
    capacity: int | None = None,
) -> PreprocessResult:
    """TiPU-like: fixed spatial grid tiles (ragged -> padded) + local L2 FPS + ball query."""
    n = points.shape[0]
    if capacity is None:
        capacity = max(n // (grid**3) * 2, 32)  # 2x mean occupancy, TiPU-style
    part = part_mod.grid_partition(points, grid, capacity)
    return _tiled_common(points, part, n_centroids, radius, nsample, "l2", "ball")


def preprocess_pc2im(
    points: jax.Array,
    n_centroids: int,
    radius: float,
    nsample: int,
    *,
    depth: int = 3,
    axis_mode: str = "widest",
) -> PreprocessResult:
    """PC2IM: MSP equal tiles + local L1 FPS + local lattice query (C1+C2+C3)."""
    part = part_mod.median_partition(points, depth, axis_mode=axis_mode)
    return _tiled_common(points, part, n_centroids, radius, nsample, "l1", "lattice")


PIPELINES = {
    "baseline1": preprocess_baseline1,
    "baseline2": preprocess_baseline2,
    "pc2im": preprocess_pc2im,
}
