"""Spatial partitioning (paper C2) — median splits (MSP) + baselines.

MSP recursively splits the point set at the *median* along an axis, producing
2^depth tiles of exactly equal cardinality but unfixed spatial shape.  Equal
cardinality is the property PC2IM exploits: every tile fills the on-chip CIM
array completely (paper: +15% utilisation) and samples the same number of
centroids, giving a fully uniform access pattern.

On TPU the same property buys *padding-free dense batching*: the partition is
a (n_tiles, tile_size) int32 index tensor — every downstream op (FPS, query,
MLP) vmaps over tiles with zero ragged padding, and tiles shard evenly over
the mesh `data` axis.

Baselines implemented for the utilisation/energy comparison:
  * morton_partition — Morton(Z)-order sort + equal-count chunks ([11][12]).
  * grid_partition   — fixed-shape spatial grid tiles (TiPU [10]): ragged
    occupancy, must be padded to a fixed capacity -> wasted array slots.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Partition(NamedTuple):
    """tiles: (n_tiles, tile_size) indices into the original point array.

    valid: same shape bool — False for padded slots (always True for MSP).
    """

    tiles: jax.Array
    valid: jax.Array

    @property
    def n_tiles(self) -> int:
        return self.tiles.shape[0]

    @property
    def tile_size(self) -> int:
        return self.tiles.shape[1]

    def utilization(self) -> jax.Array:
        return jnp.mean(self.valid.astype(jnp.float32))


def _split_axis(points: jax.Array, tiles: jax.Array, mode: str, level: int) -> jax.Array:
    """Choose the split axis per tile: cycle x/y/z or widest extent."""
    if mode == "cycle":
        return jnp.full((tiles.shape[0],), level % 3, dtype=jnp.int32)
    # widest-extent: per tile, axis with the largest coordinate range
    coords = jnp.take(points, tiles, axis=0)  # (T, P, 3)
    extent = jnp.max(coords, axis=1) - jnp.min(coords, axis=1)  # (T, 3)
    return jnp.argmax(extent, axis=-1).astype(jnp.int32)


def median_partition(
    points: jax.Array, depth: int, *, axis_mode: str = "widest"
) -> Partition:
    """MSP: recursively median-split into 2^depth equal-size tiles.

    points: (N, 3) with N divisible by 2^depth (use pad_points otherwise).
    Implementation: at each level, sort each tile's indices by the chosen
    axis coordinate and split in half — a batched argsort, O(N log N) total,
    the host-CPU K-D-tree step of the paper ([15]) expressed as XLA.
    """
    n = points.shape[0]
    if n % (1 << depth) != 0:
        raise ValueError(f"N={n} not divisible by 2^{depth}; pad first")

    tiles = jnp.arange(n, dtype=jnp.int32)[None, :]  # (1, N)
    for level in range(depth):
        t, p = tiles.shape
        axes = _split_axis(points, tiles, axis_mode, level)  # (t,)
        coords = jnp.take(points, tiles, axis=0)  # (t, p, 3)
        key = jnp.take_along_axis(coords, axes[:, None, None], axis=2)[..., 0]  # (t, p)
        order = jnp.argsort(key, axis=1)
        tiles = jnp.take_along_axis(tiles, order, axis=1)
        tiles = tiles.reshape(t * 2, p // 2)
    return Partition(tiles=tiles, valid=jnp.ones_like(tiles, dtype=bool))


def pad_points(points: jax.Array, multiple: int):
    """Pad N to a multiple by repeating the last point; returns (points, valid)."""
    n = points.shape[0]
    pad = (-n) % multiple
    if pad == 0:
        return points, jnp.ones((n,), bool)
    filler = jnp.broadcast_to(points[-1:], (pad, points.shape[1]))
    out = jnp.concatenate([points, filler], axis=0)
    valid = jnp.concatenate([jnp.ones((n,), bool), jnp.zeros((pad,), bool)])
    return out, valid


# ---------------------------------------------------------------------------
# Baseline partitions
# ---------------------------------------------------------------------------

def morton_codes(points: jax.Array, bits_per_axis: int = 10) -> jax.Array:
    """Interleave quantized coordinate bits into a Morton (Z-order) code."""
    lo = jnp.min(points, axis=0, keepdims=True)
    hi = jnp.max(points, axis=0, keepdims=True)
    levels = (1 << bits_per_axis) - 1
    q = jnp.clip(
        jnp.round((points - lo) / jnp.maximum(hi - lo, 1e-12) * levels), 0, levels
    ).astype(jnp.uint32)
    code = jnp.zeros((points.shape[0],), dtype=jnp.uint32)
    for b in range(bits_per_axis):
        for a in range(3):
            bit = (q[:, a] >> b) & 1
            code = code | (bit << jnp.uint32(3 * b + a))
    return code


def morton_partition(points: jax.Array, depth: int) -> Partition:
    """Morton-sort then chop into 2^depth equal-count chunks ([11][12] style).

    Equal cardinality like MSP, but tile boundaries follow the Z-curve, which
    can straddle spatial discontinuities (worse sampling locality than MSP).
    """
    n = points.shape[0]
    if n % (1 << depth) != 0:
        raise ValueError(f"N={n} not divisible by 2^{depth}; pad first")
    order = jnp.argsort(morton_codes(points)).astype(jnp.int32)
    tiles = order.reshape(1 << depth, n >> depth)
    return Partition(tiles=tiles, valid=jnp.ones_like(tiles, dtype=bool))


def grid_partition(points: jax.Array, grid: int, capacity: int) -> Partition:
    """Fixed-shape spatial tiles (TiPU [10]): grid^3 cells, padded to `capacity`.

    Ragged occupancy -> `valid` mask; overflow beyond capacity is dropped
    (counted by the caller via utilization/overflow stats).  This is the
    padding waste MSP eliminates.
    """
    n = points.shape[0]
    lo = jnp.min(points, axis=0, keepdims=True)
    hi = jnp.max(points, axis=0, keepdims=True)
    cell = jnp.clip(
        jnp.floor((points - lo) / jnp.maximum(hi - lo, 1e-12) * grid), 0, grid - 1
    ).astype(jnp.int32)
    tile_id = cell[:, 0] * grid * grid + cell[:, 1] * grid + cell[:, 2]  # (N,)
    n_tiles = grid**3

    # Stable sort by tile id, then compute within-tile rank.
    order = jnp.argsort(tile_id, stable=True).astype(jnp.int32)
    sorted_tid = jnp.take(tile_id, order)
    # rank within tile = position - first position of this tile id
    first = jnp.searchsorted(sorted_tid, jnp.arange(n_tiles), side="left")
    rank = jnp.arange(n) - jnp.take(first, sorted_tid)

    tiles = jnp.zeros((n_tiles, capacity), dtype=jnp.int32)
    valid = jnp.zeros((n_tiles, capacity), dtype=bool)
    keep = rank < capacity
    scatter_rows = jnp.where(keep, sorted_tid, n_tiles)  # drop overflow
    scatter_cols = jnp.where(keep, rank, 0)
    tiles = tiles.at[scatter_rows, scatter_cols].set(order, mode="drop")
    valid = valid.at[scatter_rows, scatter_cols].set(True, mode="drop")
    return Partition(tiles=tiles, valid=valid)


def partition_coords(points: jax.Array, part: Partition) -> jax.Array:
    """Gather tiled coordinates: (n_tiles, tile_size, 3)."""
    return jnp.take(points, part.tiles, axis=0)
