"""ExecutionPolicy — one explicit, hashable description of HOW to run.

PC2IM is one accelerator with two coupled halves: the CIM preprocessing
dataflow (MSP / FPS / lattice query) and the split-concatenate SC-CIM
feature engine (quantized MLP MACs).  Both halves answer the same three
questions — which numeric mode, which kernel backend, interpret or not —
so both read them from the same object:

    policy = ExecutionPolicy(quant="sc_w16a16", backend="xla")
    y = nn.linear(params, x, policy=policy)          # SC-CIM feature path
    engine = stage_engine(cfg, sa, n, policy)        # preprocessing path

The policy is passed FUNCTIONALLY: plain argument threading, no
thread-local or module-global state.  That makes execution configuration

  * jit-safe     — the policy is static Python data closed over at trace
                   time; two artifacts traced under different policies can
                   never observe each other;
  * thread-safe  — concurrent serving threads each hold their own policy
                   (the exact failure mode of the old `nn.quant_mode`
                   context manager, which leaked a thread-local default
                   under work-stealing executors);
  * hashable     — policies key jit/engine/accelerator caches directly
                   (`PC2IMAccelerator` compiles one artifact per
                   (config, policy) pair).

`core/accelerator.py` builds the whole-pipeline artifact from one
(config, policy) pair; this module holds only the policy itself so the
kernels/, models/ and core/ layers can all import it without cycles.
"""

from __future__ import annotations

import dataclasses

QUANT_MODES = ("none", "sc_w16a16", "sc_w8a8")
PIPELINE_MODES = ("sequential", "pipelined")
SHARDING_MODES = (None, "batch", "tensor")
_QUANT_BITS = {"sc_w16a16": 16, "sc_w8a8": 8}


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    """How to execute — orthogonal to WHAT to execute (the model config).

    quant     : numeric mode for every dense layer routed through
                `nn.linear` — "none" (float) or the paper's C4 SC-CIM
                integer paths "sc_w16a16" / "sc_w8a8".
    backend   : kernel-registry backend ("auto" | "pallas" | "xla") used
                by BOTH halves: preprocessing kernels (FPS, lattice) and
                the SC integer matmul behind quantized linears.  None means
                "unspecified — defer to the config": a policy that only
                sets quant keeps the config's pinned preproc_backend
                instead of silently resetting it to "auto".
    interpret : Pallas interpret-mode flag; None defers to the registry
                default (interpret off-TPU).
    pipeline  : execution schedule of the compiled artifact — "sequential"
                runs preprocessing then the feature MLPs back to back (one
                fused trace per call); "pipelined" executes the accelerator's
                split preprocess/feature sub-artifacts so micro-batch k+1's
                preprocessing (FPS / lattice kernels) overlaps micro-batch
                k's SC-CIM feature MLPs (the paper's Ping-Pong-MAX /
                Mesorasi-style stage decoupling).  Participates in the
                policy's hash, so pipelined and sequential traffic resolve
                to DIFFERENT cached artifacts and a serving micro-batch
                never mixes schedules (see serve/scheduler.py).
    sharding  : mesh-sharded execution of the compiled artifact — None runs
                single-device; "batch" shards the batch dim of BOTH stages
                over the replica's device group; "tensor" batch-shards the
                preprocess stage and column-splits every feature-MLP linear
                across the group, concatenating the partial products (the
                paper's split-concatenate dataflow lifted to a device mesh).
                Participates in the policy's hash exactly like `pipeline`:
                sharded and unsharded traffic resolve to DIFFERENT cached
                artifacts.  The knob is inert outside a replica mesh — the
                same policy object traces identically under plain jit —
                and is mutually exclusive with pipeline="pipelined" (the
                two-stage handoff would break the shard_map boundary).
    precision : reserved knob for a later scaling PR (matmul precision);
                carried now so the policy's hash identity is stable when it
                lands.
    """

    quant: str = "none"
    backend: str | None = None
    interpret: bool | None = None
    precision: str = "default"
    sharding: str | None = None
    pipeline: str = "sequential"

    def __post_init__(self):
        if self.quant not in QUANT_MODES:
            raise ValueError(f"quant must be one of {QUANT_MODES}, got {self.quant!r}")
        if self.backend not in (None, "auto", "pallas", "xla"):
            raise ValueError(
                f"backend must be None, 'auto', 'pallas' or 'xla', got {self.backend!r}"
            )
        if self.pipeline not in PIPELINE_MODES:
            raise ValueError(
                f"pipeline must be one of {PIPELINE_MODES}, got {self.pipeline!r}"
            )
        if self.sharding not in SHARDING_MODES:
            raise ValueError(
                f"sharding must be one of {SHARDING_MODES}, got {self.sharding!r}"
            )
        if self.sharding is not None and self.pipeline == "pipelined":
            raise ValueError(
                "sharding and pipeline='pipelined' are mutually exclusive: "
                "the two-stage handoff would split the shard_map boundary"
            )

    @property
    def quant_bits(self) -> int | None:
        """Operand width of the SC integer path (None in float mode)."""
        return _QUANT_BITS.get(self.quant)

    def resolved_backend(self, default: str = "auto") -> str:
        """backend with the None placeholder resolved (config default wins)."""
        return self.backend if self.backend is not None else default


DEFAULT_POLICY = ExecutionPolicy()


def policy_for(cfg) -> ExecutionPolicy:
    """Default policy of a model config.

    Reads the config's declared numeric mode (`cfg.quant`) and, where the
    config names a preprocessing backend (PointNet2Config.preproc_backend),
    uses it for the whole pipeline — preprocessing AND the SC feature path,
    which the old split API silently decoupled.
    """
    return ExecutionPolicy(
        quant=getattr(cfg, "quant", "none"),
        backend=getattr(cfg, "preproc_backend", "auto"),
    )


def resolve_policy(cfg, policy: ExecutionPolicy | None) -> ExecutionPolicy:
    """Resolve a caller-supplied policy against a config, once, at the entry point.

    None -> the config's default policy.  backend=None -> the config's
    pinned backend (preproc_backend, else "auto"), so BOTH halves —
    preprocessing engines and the SC feature path — see the same concrete
    backend decision; resolving at the entry point is what keeps them from
    drifting apart.
    """
    if policy is None:
        return policy_for(cfg)
    if policy.backend is None:
        return dataclasses.replace(
            policy, backend=getattr(cfg, "preproc_backend", "auto")
        )
    return policy
