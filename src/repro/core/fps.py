"""Farthest Point Sampling (FPS) — exact L2, approximate L1 (paper C1), fused step (C3).

The paper's observation: the FPS inner loop is

    d_tmp  <- min(d_tmp, dist(points, points[last]))     # temporary-distance update
    last   <- argmax(d_tmp)                              # next centroid

Baseline hardware streams `points` and `d_tmp` through memory every
iteration (58% of on-chip traffic is the d_tmp update, 41% the point reads).
PC2IM's APD-CIM + Ping-Pong-MAX CAM keep both pinned next to compute and
fuse the min-update with the max-search.  `fused_fps_step` below is the
software statement of that fusion (a single XLA fusion / one Pallas kernel
in kernels/fps — points and d_tmp stay in VMEM for the whole loop).

Distances:
  * metric="l2"  : squared Euclidean (no sqrt — monotone, what baselines use)
  * metric="l1"  : Manhattan (paper C1).  With 16-bit quantized coordinates
    the L1 distance fits in 19 bits (3 * (2^16 - 1) < 2^18), vs ~33 bits for
    squared L2 — the bit-width saving that shrinks the paper's CAM.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

Metric = Literal["l1", "l2"]

_BIG = jnp.float32(1e30)


def pairwise_distance(a: jax.Array, b: jax.Array, metric: Metric = "l2") -> jax.Array:
    """Distance matrix between point sets.  a: (N, 3), b: (M, 3) -> (N, M).

    L2 returns *squared* distance (monotone equivalent, avoids sqrt);
    L1 returns the Manhattan distance (paper eq. 2).
    """
    diff = a[:, None, :] - b[None, :, :]
    if metric == "l1":
        return jnp.sum(jnp.abs(diff), axis=-1)
    return jnp.sum(diff * diff, axis=-1)


def point_distance(points: jax.Array, ref: jax.Array, metric: Metric = "l2") -> jax.Array:
    """Distance of every point to a single reference point.  (N, 3), (3,) -> (N,)."""
    diff = points - ref[None, :]
    if metric == "l1":
        return jnp.sum(jnp.abs(diff), axis=-1)
    return jnp.sum(diff * diff, axis=-1)


def fused_fps_step(
    points: jax.Array,
    dmin: jax.Array,
    last_idx: jax.Array,
    metric: Metric = "l2",
    valid: jax.Array | None = None,
):
    """One Ping-Pong-MAX step: distance + min-update + argmax in one fusion (C3).

    Returns (new_dmin, next_idx).  `valid` masks padded points out of the
    argmax (they keep dmin = -inf so they are never sampled).
    """
    ref = jnp.take(points, last_idx, axis=0)
    d = point_distance(points, ref, metric)
    new_dmin = jnp.minimum(dmin, d)
    score = new_dmin if valid is None else jnp.where(valid, new_dmin, -_BIG)
    next_idx = jnp.argmax(score)
    return new_dmin, next_idx


def fps(
    points: jax.Array,
    k: int,
    *,
    metric: Metric = "l2",
    start_idx: int | None = None,
    valid: jax.Array | None = None,
) -> jax.Array:
    """Sequential farthest point sampling.  points: (N, 3) -> indices (k,).

    The first sampled index defaults to the first VALID index — index 0 when
    no mask is given (PointNet++ convention), else argmax(valid), so a tile
    whose slot 0 is padding never seeds the sample with a fake point.  Pass
    `start_idx` to override.
    """
    n = points.shape[0]
    if k > n:
        raise ValueError(f"cannot sample {k} from {n} points")

    dmin0 = jnp.full((n,), _BIG, dtype=points.dtype)
    if start_idx is not None:
        idx0 = jnp.asarray(start_idx, dtype=jnp.int32)
    elif valid is not None:
        idx0 = jnp.argmax(valid).astype(jnp.int32)  # first valid slot
    else:
        idx0 = jnp.int32(0)

    def body(carry, _):
        dmin, last = carry
        new_dmin, nxt = fused_fps_step(points, dmin, last, metric, valid)
        return (new_dmin, jnp.asarray(nxt, jnp.int32)), last

    (_, _), sampled = jax.lax.scan(body, (dmin0, idx0), None, length=k)
    return sampled


def fps_batched(
    points: jax.Array,
    k: int,
    *,
    metric: Metric = "l2",
    valid: jax.Array | None = None,
) -> jax.Array:
    """FPS vmapped over any number of leading batch/tile dims.

    points: (..., N, 3) -> (..., k) int32 indices local to each tile.
    """
    batch_shape = points.shape[:-2]
    flat = points.reshape((-1,) + points.shape[-2:])
    if valid is not None:
        vflat = valid.reshape((-1, valid.shape[-1]))
        out = jax.vmap(lambda p, v: fps(p, k, metric=metric, valid=v))(flat, vflat)
    else:
        out = jax.vmap(lambda p: fps(p, k, metric=metric))(flat)
    return out.reshape(batch_shape + (k,))


# ---------------------------------------------------------------------------
# Quantized-coordinate L1 FPS (the faithful APD-CIM datapath: int16 coords,
# 19-bit distances).  Used by the energy model and the Pallas kernel oracle.
# ---------------------------------------------------------------------------

def quantize_coords(points: jax.Array, bits: int = 16):
    """Quantize float coords to signed ints on a uniform grid (paper: 16-bit PTQ).

    Returns (q_points int32 in [-2^(b-1), 2^(b-1)-1], scale, offset) such that
    points ~= q * scale + offset.
    """
    lo = jnp.min(points, axis=tuple(range(points.ndim - 1)), keepdims=True)
    hi = jnp.max(points, axis=tuple(range(points.ndim - 1)), keepdims=True)
    span = jnp.maximum(hi - lo, 1e-12)
    levels = (1 << bits) - 1
    scale = span / levels
    half = 1 << (bits - 1)
    q = jnp.clip(jnp.round((points - lo) / scale) - half, -half, half - 1)
    return q.astype(jnp.int32), scale, lo + half * scale


def fps_l1_quantized(points_q: jax.Array, k: int, *, start_idx: int = 0) -> jax.Array:
    """Integer L1 FPS over pre-quantized coords — exact APD-CIM arithmetic.

    points_q: (N, 3) int32 (16-bit range).  Distances are exact 19-bit ints.
    """
    n = points_q.shape[0]
    big = jnp.int32(2**30)

    def body(carry, _):
        dmin, last = carry
        ref = jnp.take(points_q, last, axis=0)
        d = jnp.sum(jnp.abs(points_q - ref[None, :]), axis=-1)  # <= 3*(2^16-1): 19 bits
        new_dmin = jnp.minimum(dmin, d.astype(dmin.dtype))
        nxt = jnp.argmax(new_dmin).astype(jnp.int32)
        return (new_dmin, nxt), last

    (_, _), sampled = jax.lax.scan(
        body, (jnp.full((n,), big, jnp.int32), jnp.asarray(start_idx, jnp.int32)), None, length=k
    )
    return sampled


# ---------------------------------------------------------------------------
# Sampling-quality metrics (used for the Fig 12a analogue: how good is the
# L1-approximate sample compared to exact-L2 FPS?)
# ---------------------------------------------------------------------------

def coverage_radius(points: jax.Array, sample_idx: jax.Array) -> jax.Array:
    """max_p min_s ||p - s||2 — the covering radius of the sampled subset (lower=better)."""
    centroids = jnp.take(points, sample_idx, axis=0)
    d = pairwise_distance(points, centroids, "l2")
    return jnp.sqrt(jnp.max(jnp.min(d, axis=1)))


def min_pairwise_separation(points: jax.Array, sample_idx: jax.Array) -> jax.Array:
    """min_{i!=j} ||s_i - s_j||2 — FPS maximises spread (higher=better)."""
    c = jnp.take(points, sample_idx, axis=0)
    d = pairwise_distance(c, c, "l2")
    k = c.shape[0]
    d = d + jnp.eye(k, dtype=d.dtype) * _BIG
    return jnp.sqrt(jnp.min(d))


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def fps_jit(points: jax.Array, k: int, metric: Metric = "l2") -> jax.Array:
    return fps(points, k, metric=metric)
