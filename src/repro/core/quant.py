"""Split-concatenate quantized MACs (paper C4 — SC-CIM), as exact integer math.

The paper computes 16b x 16b MACs by splitting weights into 4-bit *blocks*
(consecutive nibbles) and inputs into 4-bit *clusters* (nibble-interleaved),
then forming cluster-block products by concatenation/shift-add and merging
partial sums in a fused dense/sparse adder tree.

Arithmetic identity (two's-complement nibble decomposition):

    q = n0 + 16*n1 + 256*n2 + 4096*n3s,   n0..n2 in [0,15], n3s in [-8,7]

    x @ w = sum_{i,j} (X_i @ W_j) << 4*(i+j)

Each plane-pair dot is a small-integer matmul — on TPU it rides the int8 MXU
path (exact int32 accumulation, 4x bf16 byte-throughput); the (i+j) diagonal
grouping of the shift-accumulate is the software image of the paper's fused
adder.  kernels/sc_matmul implements the Pallas version; this module is the
oracle + the pure-XLA production path.

Accumulation widths (documented, asserted in tests):
  plane-pair dot:  |sum| <= 15*15*K  -> int32 exact for K <= 9.5M
  final combine:   needs up to 32 + 2*bits-8 bits -> int64 (exact mode) or
                   f64/f32 (fast mode; f32 relerr ~2^-24, fine after dequant)
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

PLANE_BITS = 4
N_PLANES_16 = 4  # 16-bit operands -> 4 nibbles


class Quantized(NamedTuple):
    q: jax.Array  # int32-held integer values
    scale: jax.Array  # per-tensor (or per-channel) float scale


def quantize_symmetric(
    x: jax.Array, bits: int = 16, axis=None, *, axis_name: str | None = None
) -> Quantized:
    """Symmetric signed quantization: q = round(x / s), s = max|x| / (2^(b-1)-1).

    axis_name: optional mapped mesh axis (shard_map) to pmax the amax over,
    so every shard quantizes with the GLOBAL scale.  max is exact under
    pmax, which is what keeps a batch-sharded quantized linear bitwise-equal
    to its unsharded trace.
    """
    qmax = (1 << (bits - 1)) - 1
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    if axis_name is not None:
        amax = jax.lax.pmax(amax, axis_name)
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(jnp.int32)
    return Quantized(q=q, scale=scale)


def dequantize(t: Quantized) -> jax.Array:
    return t.q.astype(jnp.float32) * t.scale


def split_planes(q: jax.Array, n_planes: int = N_PLANES_16) -> jax.Array:
    """Nibble-decompose signed ints: (..., ) int32 -> (n_planes, ...) int32.

    Planes 0..n-2 are unsigned nibbles in [0,15]; the top plane is the
    arithmetic-shift remainder in [-8,7] (two's-complement sign handling —
    the paper's 'separately concatenate signed and unsigned parts').
    """
    q = q.astype(jnp.int32)
    planes = []
    for i in range(n_planes - 1):
        planes.append((q >> (PLANE_BITS * i)) & 0xF)
    planes.append(q >> (PLANE_BITS * (n_planes - 1)))  # arithmetic shift: signed top
    return jnp.stack(planes, axis=0)


def combine_planes(planes: jax.Array) -> jax.Array:
    """Inverse of split_planes (sanity/tests)."""
    n = planes.shape[0]
    out = jnp.zeros_like(planes[0])
    for i in range(n):
        out = out + (planes[i] << (PLANE_BITS * i))
    return out


def sc_matmul(
    x_q: jax.Array,
    w_q: jax.Array,
    *,
    n_planes: int = N_PLANES_16,
    combine: str = "int64",
) -> jax.Array:
    """Split-concatenate integer matmul: exact x_q @ w_q via 4-bit planes.

    x_q: (M, K) int32 (16-bit range), w_q: (K, N) int32 -> (M, N).

    combine="int64": exact (test oracle / CPU).
    combine="f32"  : TPU-fast shift-merge in float32 (bounded rounding error,
                     irrelevant after dequantization to bf16 activations).
    """
    xp = split_planes(x_q, n_planes)  # (P, M, K) int32, small magnitude
    wp = split_planes(w_q, n_planes)  # (P, K, N)

    # Group plane-pairs by diagonal d = i + j (the fused-adder schedule):
    # all pairs on a diagonal share one shift -> sum them *before* shifting.
    diag_dots: dict[int, jax.Array] = {}
    for i in range(n_planes):
        for j in range(n_planes):
            # int8-range operands, int32 accumulation — the MXU int path.
            dot = jax.lax.dot_general(
                xp[i],
                wp[j],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            d = i + j
            diag_dots[d] = dot if d not in diag_dots else diag_dots[d] + dot

    if combine == "int64":
        out = jnp.zeros(diag_dots[0].shape, jnp.int64)
        for d, dot in diag_dots.items():
            out = out + (dot.astype(jnp.int64) << (PLANE_BITS * d))
        return out
    elif combine == "f32":
        out = jnp.zeros(diag_dots[0].shape, jnp.float32)
        for d, dot in diag_dots.items():
            out = out + dot.astype(jnp.float32) * float(1 << (PLANE_BITS * d))
        return out
    raise ValueError(f"unknown combine mode {combine!r}")


def quantized_linear(
    x: jax.Array,
    w: jax.Array,
    *,
    bits: int = 16,
    combine: str = "f32",
) -> jax.Array:
    """W16A16 linear layer via SC decomposition: quantize -> sc_matmul -> dequant.

    x: (..., K) float, w: (K, N) float -> (..., N) float32.  This is the
    XLA oracle behind the `ExecutionPolicy(quant="sc_w16a16")` path usable
    by any architecture's MLP (production goes through kernels/sc_matmul).
    """
    n_planes = bits // PLANE_BITS
    lead = x.shape[:-1]
    xq = quantize_symmetric(x.reshape(-1, x.shape[-1]), bits)
    wq = quantize_symmetric(w, bits)
    y = sc_matmul(xq.q, wq.q, n_planes=n_planes, combine=combine)
    y = y.astype(jnp.float32) * (xq.scale * wq.scale)
    return y.reshape(lead + (w.shape[-1],))


def ptq_error(x: jax.Array, bits: int = 16) -> jax.Array:
    """Relative RMS round-trip error of symmetric PTQ (Fig 12a's <0.3% claim)."""
    t = quantize_symmetric(x, bits)
    err = dequantize(t) - x
    return jnp.sqrt(jnp.mean(err**2)) / jnp.maximum(jnp.sqrt(jnp.mean(x**2)), 1e-12)
