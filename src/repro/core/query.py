"""Neighbour queries — ball query (baseline), lattice query (paper C1), kNN-3.

Ball query (PointNet++): the *first* `nsample` points with ||p-c||2 <= R,
padded with the first hit (standard convention).

Lattice query (PC2IM): same first-k semantics but with the L1 (Manhattan)
metric and an adaptive range L = 1.6 * R (paper's empirical factor chosen so
the L1 ball covers the original L2 ball with no explicit information loss —
worst case would need sqrt(3) ~ 1.73).

kNN-3: the 3 nearest neighbours + inverse-distance weights, used by the
point-feature-propagation (up-sampling) layers.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.fps import Metric, pairwise_distance

LATTICE_RANGE_FACTOR = 1.6  # paper: L = 1.6 * R


class NeighborSet(NamedTuple):
    idx: jax.Array  # (M, nsample) indices into the point set
    mask: jax.Array  # (M, nsample) True where a real (in-range) neighbour


def _first_k_in_range(
    d: jax.Array, thresh: jax.Array | float, nsample: int, valid: jax.Array | None
) -> NeighborSet:
    """First-k selection per row of a distance matrix d: (M, N)."""
    hit = d <= thresh
    if valid is not None:
        hit = hit & valid[None, :]
    # slot for each hit = number of prior hits in the row
    slot = jnp.cumsum(hit, axis=1) - 1  # (M, N)
    rows = jnp.broadcast_to(jnp.arange(d.shape[0])[:, None], d.shape)
    cols = jnp.broadcast_to(jnp.arange(d.shape[1])[None, :], d.shape)
    slot_ok = hit & (slot < nsample)
    out = jnp.zeros((d.shape[0], nsample), jnp.int32)
    msk = jnp.zeros((d.shape[0], nsample), bool)
    out = out.at[jnp.where(slot_ok, rows, d.shape[0]), jnp.where(slot_ok, slot, 0)].set(
        cols.astype(jnp.int32), mode="drop"
    )
    msk = msk.at[jnp.where(slot_ok, rows, d.shape[0]), jnp.where(slot_ok, slot, 0)].set(
        True, mode="drop"
    )
    # pad empty slots with the first hit (PointNet++ convention); if a row has
    # no hit at all, fall back to index 0 (callers aggregate with the mask).
    first = out[:, :1]
    out = jnp.where(msk, out, first)
    return NeighborSet(idx=out, mask=msk)


def ball_query(
    points: jax.Array,
    centroids: jax.Array,
    radius: float,
    nsample: int,
    *,
    valid: jax.Array | None = None,
) -> NeighborSet:
    """L2 ball query.  points: (N,3), centroids: (M,3) -> (M, nsample)."""
    d = pairwise_distance(centroids, points, "l2")  # squared
    return _first_k_in_range(d, radius * radius, nsample, valid)


def lattice_query(
    points: jax.Array,
    centroids: jax.Array,
    radius: float,
    nsample: int,
    *,
    range_factor: float = LATTICE_RANGE_FACTOR,
    valid: jax.Array | None = None,
) -> NeighborSet:
    """PC2IM lattice query: L1 metric, range L = range_factor * radius (C1)."""
    d = pairwise_distance(centroids, points, "l1")
    return _first_k_in_range(d, range_factor * radius, nsample, valid)


def knn(
    query_xyz: jax.Array,
    ref_xyz: jax.Array,
    k: int,
    *,
    metric: Metric = "l2",
    valid: jax.Array | None = None,
):
    """k nearest neighbours of each query point among ref points.

    Returns (idx (M,k) int32, dist (M,k) — squared for l2).  Implemented as
    k successive min-extractions (k is tiny: 3 in PointNet++ FP layers),
    which is exactly the dataflow of the fused kernels/knn3 kernel.
    """
    d = pairwise_distance(query_xyz, ref_xyz, metric)  # (M, N)
    if valid is not None:
        d = jnp.where(valid[None, :], d, jnp.inf)
    idxs, dists = [], []
    for _ in range(k):
        j = jnp.argmin(d, axis=1)
        dj = jnp.take_along_axis(d, j[:, None], axis=1)[:, 0]
        idxs.append(j.astype(jnp.int32))
        dists.append(dj)
        d = d.at[jnp.arange(d.shape[0]), j].set(jnp.inf)
    return jnp.stack(idxs, axis=1), jnp.stack(dists, axis=1)


def three_nn_interpolate_weights(dist_sq: jax.Array, eps: float = 1e-8) -> jax.Array:
    """Inverse-distance weights for 3-NN feature interpolation (FP layer).

    dist_sq: (..., k) — normalised over the trailing k axis, so batched
    (B, M, k) inputs work unchanged.
    """
    w = 1.0 / (dist_sq + eps)
    return w / jnp.sum(w, axis=-1, keepdims=True)
