"""Batched PreprocessEngine — end-to-end (B, N, 3) preprocessing in one launch.

The per-cloud pipelines in core/preprocess.py are the semantic oracles; this
module is how production traffic runs them.  A `PreprocessEngine` is built
once from an `EngineConfig` (pipeline name, partition depth, metric, query
type, backend) and maps a whole batch of clouds to a batched
`PreprocessResult`:

    engine = PreprocessEngine(EngineConfig(pipeline="pc2im", n_centroids=128,
                                           radius=0.3, nsample=16, depth=3))
    res = engine(points)          # points (B, N, 3) -> fields lead with B

The key dataflow move (the reason this is faster than `vmap` over the
per-cloud functions): batch and MSP tiles are FOLDED INTO ONE TILE AXIS.
After partitioning, the B clouds' 2^depth tiles each become a (B·T, P, 3)
tensor, and the Pallas FPS / lattice kernels see a single grid of B·T
programs instead of B separate launches — exactly the paper's C2 story
(equal-size tiles -> a perfectly uniform grid) extended to the batch dim.

Backend handling goes through kernels/registry: "auto" resolves to the
Pallas kernels on TPU (interpret mode elsewhere) and the XLA reference path
otherwise.  Ops with no kernel counterpart (masked ball query, the ragged
grid partition of baseline2) always take the XLA path — the registry's
documented fallback — so every pipeline works on every backend and is
bitwise identical to its per-cloud oracle.
"""

from __future__ import annotations

import dataclasses
import functools
import io
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import partition as part_mod
from repro.core import preprocess as pp_mod
from repro.core import query as query_mod
from repro.core.preprocess import PreprocessResult
from repro.core.query import NeighborSet
from repro.kernels.fps.ops import fps_tiles
from repro.kernels.lattice.ops import lattice_query_tiles

Pipeline = Literal["baseline1", "baseline2", "pc2im"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static description of one preprocessing pipeline instance.

    metric/query default to the pipeline's canonical choice (pc2im: L1 +
    lattice; baselines: L2 + ball) and can be overridden to mix, e.g. MSP
    tiles with an L2 ball query for ablations.
    """

    pipeline: Pipeline = "pc2im"
    n_centroids: int = 128
    radius: float = 0.3
    nsample: int = 16
    depth: int = 3  # MSP: tiles = 2^depth (pc2im only)
    axis_mode: str = "widest"
    metric: str | None = None  # None -> pipeline default
    query: str | None = None  # None -> pipeline default
    grid: int = 2  # baseline2 spatial grid
    capacity: int | None = None  # baseline2 tile capacity (None -> 2x mean)
    backend: str = "auto"  # "auto" | "pallas" | "xla"  (kernels/registry)
    interpret: bool | None = None  # None -> interpret off-TPU

    @property
    def resolved_metric(self) -> str:
        """FPS distance metric with the None placeholder resolved."""
        if self.metric is not None:
            return self.metric
        return "l1" if self.pipeline == "pc2im" else "l2"

    @property
    def resolved_query(self) -> str:
        """Neighbour-query kind with the None placeholder resolved."""
        if self.query is not None:
            return self.query
        return "lattice" if self.pipeline == "pc2im" else "ball"

    @property
    def n_tiles(self) -> int:
        """Tiles per cloud seen by the kernels (1 for the global baseline1)."""
        if self.pipeline == "pc2im":
            return 1 << self.depth
        if self.pipeline == "baseline2":
            return self.grid**3
        return 1


def clamp_depth(n_points: int, n_centroids: int, depth: int) -> int:
    """Largest usable MSP depth <= `depth` for a given cloud/sample size.

    Keeps tiles no smaller than 4x the per-tile sample count and requires
    both N and n_centroids to split evenly (the MSP equal-tile property).
    Shared by models/ and serve/ so every consumer agrees on the shape.
    """
    while depth > 0 and (n_points >> depth) < 4 * max(1, n_centroids >> depth):
        depth -= 1
    while depth > 0 and (n_points % (1 << depth) or n_centroids % (1 << depth)):
        depth -= 1
    return depth


class PreprocessEngine:
    """jit-compiled batched preprocessing: (B, N, 3) -> PreprocessResult.

    Output fields lead with the batch dim: centroid_idx (B, M),
    centroid_xyz (B, M, 3), neighbors.idx/mask (B, M, nsample),
    centroid_valid (B, M), with M = n_centroids and indices global per cloud.
    A single (N, 3) cloud is accepted and returns unbatched fields.
    """

    def __init__(self, config: EngineConfig):
        if config.pipeline not in ("baseline1", "baseline2", "pc2im"):
            raise ValueError(f"unknown pipeline {config.pipeline!r}")
        if config.pipeline == "pc2im" and config.n_centroids % config.n_tiles:
            raise ValueError(
                f"n_centroids={config.n_centroids} not divisible by "
                f"2^depth={config.n_tiles} tiles"
            )
        self.config = config
        self._raw_fn = {
            "baseline1": self._baseline1,
            "baseline2": self._baseline2,
            "pc2im": self._pc2im,
        }[config.pipeline]
        self._fn = jax.jit(self._raw_fn)

    def __call__(self, points: jax.Array) -> PreprocessResult:
        """Run the jit-compiled pipeline on (B, N, 3) or single (N, 3) coords.

        See the class docstring for the output layout.
        """
        return self._dispatch(points, self._fn)

    def raw(self, points: jax.Array) -> PreprocessResult:
        """Un-jitted equivalent of calling the engine, for composition.

        Same validation and shape handling as `__call__`.
        `PC2IMAccelerator` builds its preprocess-stage sub-artifact by
        chaining the per-SA-stage engines inside ONE enclosing jit; tracing
        the raw pipeline keeps that artifact a single jaxpr instead of a
        nest of engine dispatches.  Outside a trace, prefer `__call__`.
        """
        return self._dispatch(points, self._raw_fn)

    def _dispatch(self, points: jax.Array, fn) -> PreprocessResult:
        if points.ndim == 2:
            if points.shape[-1] != 3:
                raise ValueError(f"expected (B, N, 3) or (N, 3), got {points.shape}")
            res = fn(points[None])
            return jax.tree.map(lambda x: x[0], res)
        if points.ndim != 3 or points.shape[-1] != 3:
            raise ValueError(f"expected (B, N, 3) or (N, 3), got {points.shape}")
        cfg = self.config
        if cfg.pipeline == "pc2im" and points.shape[1] % cfg.n_tiles:
            raise ValueError(
                f"N={points.shape[1]} not divisible by 2^depth={cfg.n_tiles}; "
                f"pad the clouds or lower depth (see clamp_depth)"
            )
        return fn(points)

    # -- pipelines -----------------------------------------------------------

    def _baseline1(self, points: jax.Array) -> PreprocessResult:
        """Global FPS + global ball query; the B clouds ARE the kernel tiles."""
        cfg = self.config
        b = points.shape[0]
        cidx = fps_tiles(
            points, cfg.n_centroids, metric=cfg.resolved_metric,
            backend=cfg.backend, interpret=cfg.interpret,
        )  # (B, M)
        cxyz = jnp.take_along_axis(points, cidx[..., None], axis=1)  # (B, M, 3)
        nbrs = jax.vmap(
            lambda p, c: query_mod.ball_query(p, c, cfg.radius, cfg.nsample)
        )(points, cxyz)
        return PreprocessResult(
            cidx, cxyz, nbrs, jnp.ones((b, cfg.n_centroids), bool)
        )

    def _baseline2(self, points: jax.Array) -> PreprocessResult:
        """TiPU-like ragged grid tiles: masked flow, always the XLA path.

        No kernel has valid-mask support — the registry's documented
        fallback.
        """
        cfg = self.config
        return jax.vmap(
            lambda p: pp_mod.preprocess_baseline2(
                p, cfg.n_centroids, cfg.radius, cfg.nsample,
                grid=cfg.grid, capacity=cfg.capacity,
            )
        )(points)

    def _pc2im(self, points: jax.Array) -> PreprocessResult:
        """MSP tiles + local FPS + local query.

        Batch x tiles fold into one (B·T, P) kernel grid axis.
        """
        cfg = self.config
        b, n, _ = points.shape
        t = cfg.n_tiles
        p = n // t
        k = cfg.n_centroids // t

        # per-cloud MSP (batched argsorts); tiles (B, T, P) global-per-cloud
        tiles = jax.vmap(
            lambda pts: part_mod.median_partition(
                pts, cfg.depth, axis_mode=cfg.axis_mode
            ).tiles
        )(points)

        # FOLD: (B, T, P, 3) -> (B·T, P, 3); one kernel grid for all clouds
        coords = jnp.take_along_axis(points[:, None], tiles[..., None], axis=2)
        flat_tiles = tiles.reshape(b * t, p)
        flat_coords = coords.reshape(b * t, p, 3)

        local_c = fps_tiles(
            flat_coords, k, metric=cfg.resolved_metric,
            backend=cfg.backend, interpret=cfg.interpret,
        )  # (B·T, k) local
        cidx = jnp.take_along_axis(flat_tiles, local_c, axis=1)  # global
        cxyz = jnp.take_along_axis(flat_coords, local_c[..., None], axis=1)

        if cfg.resolved_query == "lattice":
            nbrs_local = lattice_query_tiles(
                flat_coords, cxyz, cfg.radius, cfg.nsample,
                backend=cfg.backend, interpret=cfg.interpret,
            )
        else:  # per-tile ball query: no kernel counterpart, XLA path
            nbrs_local = jax.vmap(
                lambda c, cx: query_mod.ball_query(c, cx, cfg.radius, cfg.nsample)
            )(flat_coords, cxyz)

        # local tile slots -> global point indices
        nidx = jnp.take_along_axis(flat_tiles[:, None, :], nbrs_local.idx, axis=2)

        m = t * k
        return PreprocessResult(
            centroid_idx=cidx.reshape(b, m),
            centroid_xyz=cxyz.reshape(b, m, 3),
            neighbors=NeighborSet(
                idx=nidx.reshape(b, m, cfg.nsample),
                mask=nbrs_local.mask.reshape(b, m, cfg.nsample),
            ),
            centroid_valid=jnp.ones((b, m), bool),  # MSP: zero padding
        )


@functools.lru_cache(maxsize=None)
def get_engine(config: EngineConfig) -> PreprocessEngine:
    """Engine cache: one jitted engine per distinct config.

    models/ and serve/ build engines per SA stage; the cache makes that free.
    """
    return PreprocessEngine(config)


# -- result trees: size accounting, per-row access, serialization -------------
#
# A "result tree" is any pytree of arrays built from PreprocessResults — one
# batched result, or the tuple-per-SA-stage the accelerator's
# preprocess_stage emits.  The cross-request preprocess cache
# (serve/preprocess_cache.py) stores these per request row and re-assembles
# them per micro-batch, so the row/stack/byte helpers live HERE, next to the
# engine that defines the layout, and stay pure tree manipulation.


def result_nbytes(res) -> int:
    """Total bytes of every array leaf in a result tree.

    Works on host (numpy) and device (jax.Array) leaves alike — both expose
    `.nbytes` — so the cache's byte budget accounts exactly what it retains.
    """
    return int(sum(x.nbytes for x in jax.tree_util.tree_leaves(res)))


def result_to_host(res):
    """Materialize every leaf of a result tree as a WRITABLE host numpy array.

    Blocks on (and transfers) device leaves.  Writability matters: on the
    CPU backend `np.asarray(jax_array)` can be a read-only view of the
    device buffer, which would make the cache-hit splice
    (`result_set_row`) raise — so read-only leaves are copied.
    """

    def one(x):
        arr = np.asarray(x)
        return arr if arr.flags.writeable else arr.copy()

    return jax.tree.map(one, res)


def result_row(res, i: int):
    """Slice row `i` off every leaf's leading (batch) dim of a result tree.

    The per-request payload the preprocess cache stores: one cloud's
    centroids/neighborhoods out of a batched PreprocessResult.
    """
    return jax.tree.map(lambda x: x[i], res)


def result_stack(rows, total: int | None = None):
    """Stack per-row result trees back into one batched tree.

    `rows` are `result_row`-shaped trees (all the same structure);
    `total` > len(rows) appends zero filler rows so the stacked batch hits a
    static batch dim — filler rows mirror assemble_batch's zero batch rows,
    whose outputs the scatter step drops.
    """
    rows = list(rows)
    if not rows:
        raise ValueError("need at least one row to stack")
    if total is not None and total > len(rows):
        filler = jax.tree.map(np.zeros_like, rows[0])
        rows.extend([filler] * (total - len(rows)))
    return jax.tree.map(lambda *xs: np.stack(xs), *rows)


def result_set_row(res, i: int, row) -> None:
    """Write a per-row tree into row `i` of a batched HOST result tree.

    In-place: `res` leaves must be writable numpy arrays (use
    `result_to_host` first).  This is the cache-hit splice — a hit row's
    cached neighborhoods replace whatever the batched preprocess computed
    for that row before the feature stage consumes the tree.
    """
    dst_leaves, treedef = jax.tree_util.tree_flatten(res)
    src_leaves = treedef.flatten_up_to(row)
    for dst, src in zip(dst_leaves, src_leaves):
        dst[i] = src


def serialize_result(res) -> bytes:
    """Pack a result tree's leaves into one portable npz byte blob.

    Leaves are stored in tree-flatten order; the tree STRUCTURE is not
    encoded — pass a structurally identical template to
    `deserialize_result` to rebuild (every cache entry of one runtime
    shares a single structure, so shipping it per blob would be waste).
    """
    leaves = [np.asarray(x) for x in jax.tree_util.tree_leaves(res)]
    buf = io.BytesIO()
    np.savez(buf, *leaves)
    return buf.getvalue()


def deserialize_result(blob: bytes, like):
    """Rebuild a result tree from `serialize_result` bytes.

    `like` supplies the tree structure (any tree with the same topology,
    e.g. a live entry's payload); leaf arrays come from the blob, dtype and
    shape preserved bitwise.
    """
    with np.load(io.BytesIO(blob)) as data:
        leaves = [data[k] for k in data.files]
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)
