"""PC2IMAccelerator — one (config, policy) pair -> compiled whole-pipeline artifacts.

The paper's accelerator is ONE device: the CIM preprocessing dataflow
(MSP -> L1 FPS -> lattice query) and the SC-CIM feature engine (quantized
per-point MLPs) are co-scheduled halves of the same chip.  This module is
the software image of that: a `PC2IMAccelerator` owns

  * the per-SA-stage `PreprocessEngine`s (batch x MSP tiles folded into one
    kernel grid, backend chosen by the policy), and
  * the policy-driven feature path (every `nn.linear` under the same
    `ExecutionPolicy` — float or SC W16A16/W8A8 through the kernel registry),

and exposes cached, jit-compiled `forward` / `infer` / `loss` artifacts:

    accel = get_accelerator(get_config("pointnet2-cls"),
                            ExecutionPolicy(quant="sc_w16a16"))
    params = accel.init(jax.random.PRNGKey(0))
    logits = accel.infer(params, points)        # (B, N, 3+F) -> (B, C)
    loss, metrics = accel.loss(params, points, labels)

Because `ExecutionPolicy` and `PointNet2Config` are frozen/hashable, the
accelerator cache gives exactly one compiled artifact per distinct
(config, policy) — concurrent serving threads with different policies get
different accelerators and can never interfere (the failure mode of the
removed thread-local `nn.quant_mode`).
"""

from __future__ import annotations

import dataclasses
import threading

import jax
from jax.experimental.shard_map import shard_map

from repro.core.policy import ExecutionPolicy, resolve_policy
from repro.launch.mesh import make_replica_mesh
from repro.models import pointnet2 as PN
from repro.parallel.pipeline import two_stage_schedule
from repro.sharding.hints import REPLICA_AXIS
from repro.sharding.policy import replica_specs


class PC2IMAccelerator:
    """Compiled PC2IM pipeline for one (PointNet2Config, ExecutionPolicy).

    Attributes:
        config  : the model/architecture description (WHAT to run).
        policy  : the execution description (HOW to run) — quant mode,
                  kernel backend, interpret flag.
        engines : per-SA-stage PreprocessEngines, stage i consuming stage
                  i-1's centroid count (shared with the forward trace via
                  the global engine cache, so nothing compiles twice).
    """

    def __init__(self, config: PN.PointNet2Config, policy: ExecutionPolicy | None = None):
        self.config = config
        # resolve once: backend=None picks up the config's pinned backend for
        # BOTH halves (engines and feature path) before anything is traced
        self.policy = resolve_policy(config, policy)

        engines = []
        n = config.n_points
        for sa in config.sa:
            engines.append(PN.stage_engine(config, sa, n, self.policy))
            n = sa.n_centroids
        self.engines = tuple(engines)

        cfg, pol = self.config, self.policy
        # jit closes over the static (config, policy) pair: one artifact per
        # accelerator, retraced only per input shape/dtype.
        self._forward = jax.jit(
            lambda params, points: PN.forward(params, cfg, points, policy=pol)
        )
        self._loss = jax.jit(
            lambda params, points, labels: PN.loss_fn(
                params, cfg, points, labels, policy=pol
            )
        )
        # the fused forward IS feature_stage(preprocess_stage(...)) — these
        # sub-artifacts run the same code behind separate jit boundaries, so
        # a pipelined schedule can overlap micro-batch k+1's preprocessing
        # with micro-batch k's feature MLPs without changing one output bit
        self._preprocess_stage = jax.jit(
            lambda points: PN.preprocess_stage(cfg, points, policy=pol)
        )
        self._feature_stage = jax.jit(
            lambda params, points, pre: PN.feature_stage(
                params, cfg, points, pre, policy=pol
            )
        )

        # fused forward that ALSO materializes the preprocess intermediates:
        # one dispatch at fused-path cost, with the neighborhoods coming out
        # as a second output.  `forward` IS feature_stage(preprocess_stage),
        # so the logits here are the same composition with an extra output —
        # the serving cache's all-miss path uses this to fill the cache
        # without paying a separate preprocess dispatch.
        def _fused_with_pre(params, points):
            pre = PN.preprocess_stage(cfg, points, policy=pol)
            return PN.feature_stage(params, cfg, points, pre, policy=pol), pre

        self._infer_with_pre = jax.jit(_fused_with_pre)
        # PipelinedExecutor cache for infer_pipelined (keyed by devices/depth)
        self._executors: dict = {}
        self._executors_lock = threading.Lock()
        # MeshArtifacts cache for sharded policies (keyed by device group):
        # the global accelerator cache keys on (config, policy) only, but a
        # sharded artifact is additionally pinned to ONE replica's mesh —
        # same lazy per-devices pattern as the PipelinedExecutor cache above
        self._mesh_artifacts: dict = {}
        self._mesh_lock = threading.Lock()

    # -- artifacts -----------------------------------------------------------

    def init(self, key):
        """Fresh parameters for this accelerator's config."""
        return PN.init_params(key, self.config)

    def forward(self, params, points: jax.Array) -> jax.Array:
        """jit-compiled batched forward: (B, N, 3+F) -> logits."""
        return self._forward(params, points)

    def infer(self, params, points: jax.Array) -> jax.Array:
        """Inference entry point — same compiled artifact as `forward`.

        Serving call-sites read better as `accel.infer`.
        """
        return self._forward(params, points)

    def loss(self, params, points: jax.Array, labels: jax.Array):
        """jit-compiled (loss, metrics) under this accelerator's policy."""
        return self._loss(params, points, labels)

    def loss_fn(self, params, points: jax.Array, labels: jax.Array):
        """Un-jitted loss for jax.grad / custom training loops.

        Still pinned to this accelerator's policy.
        """
        return PN.loss_fn(params, self.config, points, labels, policy=self.policy)

    # -- staged sub-artifacts (the pipelined execution path) -----------------

    def preprocess_stage(self, points: jax.Array) -> tuple:
        """Params-free preprocessing sub-artifact, one PreprocessResult per SA stage.

        Chains MSP partition + FPS + neighbour query stage after stage.
        This is the half of `infer` that never reads the model parameters —
        only coordinates — which is what makes it safe to run for micro-batch
        k+1 while micro-batch k is still inside `feature_stage`.
        """
        return self._preprocess_stage(points)

    def feature_stage(self, params, points: jax.Array, preproc: tuple) -> jax.Array:
        """Feature sub-artifact: SC-CIM per-point MLPs + aggregation.

        Consumes the neighborhoods `preprocess_stage` computed.
        `feature_stage(params, pts, preprocess_stage(pts))` is bitwise-equal
        to `infer(params, pts)` (pinned by tests/test_pipelined_accelerator.py).
        """
        return self._feature_stage(params, points, preproc)

    def feature_from_cached(self, params, points: jax.Array, preproc) -> jax.Array:
        """Feature stage over CACHE-RESTACKED neighborhoods — the hit fast path.

        Entry point for the cross-request preprocess cache
        (serve/preprocess_cache.py): `preproc` is a host-resident result
        tree reassembled from per-row cache entries
        (`core.engine.result_stack`) instead of a live `preprocess_stage`
        output.  It deliberately runs the SAME compiled artifact as
        `feature_stage` — a cache-hit batch whose rows are the cached
        canonical clouds therefore produces logits bitwise-equal to an
        uncached recomputation of those clouds, with the whole preprocess
        half of the chip skipped.
        """
        return self._feature_stage(params, points, preproc)

    def infer_with_preprocess(self, params, points: jax.Array) -> tuple:
        """Fused forward returning (logits, preprocess payload) in one dispatch.

        The cross-request preprocess cache's all-miss path: the batch pays
        exactly one artifact call (same composition as `infer`, so the
        logits are bitwise-equal — pinned by tests/test_preprocess_cache.py)
        while the preprocess intermediates come out as a second output for
        the cache-fill thread to store.
        """
        return self._infer_with_pre(params, points)

    def infer_pipelined(self, params, batches, *, devices=None, depth: int = 2) -> list:
        """Run a stream of micro-batches through the two-stage pipeline.

        Convenience wrapper over `PipelinedExecutor`: batch k+1's
        preprocessing overlaps batch k's feature MLPs.  Returns one logits
        array per input batch, in order, each bitwise-equal to
        `infer(params, batch)`.  The executor is cached per (devices,
        depth), so repeated calls on a multi-device host reuse the placed
        parameters instead of re-transferring them every call.
        """
        key = (tuple(devices) if devices is not None else None, depth)
        with self._executors_lock:
            ex = self._executors.get(key)
            if ex is None:
                ex = self._executors[key] = PipelinedExecutor(
                    self, devices=devices, depth=depth
                )
        return ex.run(params, batches)

    def mesh_artifacts(self, devices) -> "MeshArtifacts":
        """Sharded infer/forward artifacts over one replica's device group.

        Requires a policy with `sharding` set (the mode picks the
        shard_map body — see MeshArtifacts).  Artifacts are built lazily
        and cached per device tuple, so a pool of mesh replicas sharing one
        accelerator compiles each group's artifact exactly once and a
        rejoined replica on the same group re-traces nothing.
        """
        if self.policy.sharding is None:
            raise ValueError(
                "mesh_artifacts needs a policy with sharding set; "
                "use infer/forward for unsharded execution"
            )
        key = tuple(devices)
        with self._mesh_lock:
            arts = self._mesh_artifacts.get(key)
            if arts is None:
                arts = self._mesh_artifacts[key] = MeshArtifacts(self, key)
        return arts

    def __repr__(self) -> str:
        return (
            f"PC2IMAccelerator({self.config.name}, quant={self.policy.quant!r}, "
            f"backend={self.policy.backend!r}, stages={len(self.engines)})"
        )


class PipelinedExecutor:
    """Double-buffered two-stage executor over one accelerator's sub-artifacts.

    Streams micro-batches through `preprocess_stage` -> `feature_stage` so
    batch k+1's preprocessing (FPS / lattice kernels — the paper's APD-CIM
    and Ping-Pong-MAX CAM half) overlaps batch k's SC-CIM feature MLPs,
    mirroring how the hardware's CAM updates temporary distances while
    search proceeds:

        ex = PipelinedExecutor(get_accelerator(cfg, policy))
        logits = ex.run(params, batches)     # list, one per batch, in order

    On ONE device the overlap comes from jax's asynchronous dispatch: the
    producer thread enqueues preprocessing without ever calling
    `block_until_ready`, so the device schedules it behind/alongside the
    feature computation already in flight.  With >= 2 devices the stages are
    pinned to different devices (preprocess on `devices[0]`, features on
    `devices[1]`, parameters resident there) and the hand-off transfers the
    intermediate neighborhoods — true two-stage pipeline parallelism via
    `parallel.pipeline.two_stage_schedule`.

    Results are bitwise-equal to sequential `infer` calls: both paths run
    the same compiled sub-artifact composition (pinned test).
    """

    def __init__(self, accel: PC2IMAccelerator, *, devices=None, depth: int = 2):
        self.accel = accel
        self.devices = tuple(devices) if devices is not None else tuple(jax.devices())
        self.depth = depth
        # last (params, placed-on-feature-device copy) pair, reused across
        # run() calls so a serving loop doesn't re-transfer the weights every
        # stream (identity check: params pytrees are treated as immutable).
        # NOTE the latest generation stays referenced until the next swap or
        # clear_cache() — the same lifetime replica params already have in
        # serve/dispatch.py, where each Replica pins a device copy for good
        self._placed: tuple = (None, None)

    def _params_on(self, params, device):
        cached_key, cached_placed = self._placed
        if cached_key is params:
            return cached_placed
        # return the LOCAL, never re-read self._placed: a concurrent run()
        # with different params may overwrite the cache between assignment
        # and return, and this stream must keep ITS weights either way
        placed = jax.device_put(params, device)
        self._placed = (params, placed)
        return placed

    def run(self, params, batches) -> list:
        """Execute every (B, N, 3+F) batch; returns per-batch logits in order.

        The returned arrays are still asynchronous jax values — block (or
        `np.asarray` them) when the wall-clock matters.
        """
        accel = self.accel
        if len(self.devices) >= 2:
            dev_pre, dev_feat = self.devices[0], self.devices[1]
            params_feat = self._params_on(params, dev_feat)

            def stage_a(batch):
                batch = jax.device_put(batch, dev_pre)
                return batch, accel.preprocess_stage(batch)

            def stage_b(handoff):
                batch, pre = jax.device_put(handoff, dev_feat)
                return accel.feature_stage(params_feat, batch, pre)

        else:

            def stage_a(batch):
                # async dispatch: enqueue and hand off, never block
                return batch, accel.preprocess_stage(batch)

            def stage_b(handoff):
                batch, pre = handoff
                return accel.feature_stage(params, batch, pre)

        return two_stage_schedule(stage_a, stage_b, batches, depth=self.depth)


class MeshArtifacts:
    """Sharded whole-pipeline artifact of one accelerator over one device group.

    The serving analog of the paper's split-concatenate engine spanning
    subarrays: one replica owns a 1-D `Mesh` (launch.mesh.make_replica_mesh)
    and the fused preprocess+feature composition runs under `shard_map`
    with specs resolved by `sharding.policy.replica_specs`:

      * "batch"  — every stage runs on its local batch rows; the only
        cross-device term is the exact pmax globalizing the activation
        quant scale (core.quant), so each row's math is untouched.
      * "tensor" — preprocess runs batch-sharded, then the neighborhoods
        are all-gathered and the feature MLPs column-split every weight
        across the group, concatenating partial products (nn.linear's
        tensor path); each device finally returns its row slice of the
        replicated logits.

    Both modes are bitwise-equal to the accelerator's single-device
    `infer` on the same batch (pinned by tests/test_sharded_replica.py).
    `check_rep=False` matches the repo's shard_map precedent
    (parallel/pipeline.py) — the tensor mode's gathered intermediates are
    replicated values the replication checker can't see through.
    """

    def __init__(self, accel: PC2IMAccelerator, devices):
        self.mesh = make_replica_mesh(devices)
        cfg, pol = accel.config, accel.policy
        mode = pol.sharding
        p_params, p_points, p_logits = replica_specs(mode)

        def mapped(params, points):
            pre = PN.preprocess_stage(cfg, points, policy=pol)
            if mode == "batch":
                return PN.feature_stage(params, cfg, points, pre, policy=pol)
            # tensor: globalize the batch-sharded neighborhoods, run the
            # feature stage replicated (its linears column-split across the
            # group internally), then keep only this device's rows so the
            # out_spec can reassemble the global batch
            pts = jax.lax.all_gather(points, REPLICA_AXIS, axis=0, tiled=True)
            pre = jax.tree.map(
                lambda t: jax.lax.all_gather(t, REPLICA_AXIS, axis=0, tiled=True),
                pre,
            )
            logits = PN.feature_stage(params, cfg, pts, pre, policy=pol)
            idx = jax.lax.axis_index(REPLICA_AXIS)
            rows = points.shape[0]
            return jax.lax.dynamic_slice_in_dim(logits, idx * rows, rows, axis=0)

        self._infer = jax.jit(
            shard_map(
                mapped,
                mesh=self.mesh,
                in_specs=(p_params, p_points),
                out_specs=p_logits,
                check_rep=False,
            )
        )

    def infer(self, params, points: jax.Array) -> jax.Array:
        """Sharded batched forward: (B, N, 3+F) -> logits, B % mesh.size == 0."""
        if points.shape[0] % self.mesh.size != 0:
            raise ValueError(
                f"batch dim {points.shape[0]} must divide over the replica "
                f"mesh of {self.mesh.size} device(s)"
            )
        return self._infer(params, points)

    def forward(self, params, points: jax.Array) -> jax.Array:
        """Alias of `infer` — same compiled artifact, training-style name."""
        return self.infer(params, points)


@dataclasses.dataclass(frozen=True)
class CacheStats:
    """Snapshot of the accelerator cache (see `cache_stats`).

    hits/misses count `get_accelerator` calls; size is the number of live
    artifacts; keys names each artifact as (config.name, quant, backend,
    pipeline, sharding) so tests and the serving runtime can assert
    one-artifact-per-(config, policy) — pipelined vs sequential and sharded
    vs unsharded traffic all resolve to DIFFERENT keys — and detect compile
    storms under concurrent traffic.
    """

    hits: int
    misses: int
    size: int
    keys: tuple[tuple[str, str, str | None, str, str | None], ...]


# Explicit dict cache (not lru_cache): the serving runtime calls
# get_accelerator from many replica/scheduler threads at once, and a bare
# lru_cache lets two concurrent misses BOTH construct (and later jit) an
# accelerator — a compile storm under traffic.  The lock serialises
# construction only; compiled infer/forward calls never take it.
_lock = threading.Lock()
_artifacts: dict[tuple, PC2IMAccelerator] = {}
_hits = 0
_misses = 0


def get_accelerator(
    config: PN.PointNet2Config, policy: ExecutionPolicy | None = None
) -> PC2IMAccelerator:
    """Accelerator cache: one compiled pipeline per (config, policy) pair.

    The policy is resolved against the config BEFORE keying the cache, so
    `get_accelerator(cfg)`, `get_accelerator(cfg, policy_for(cfg))` and a
    backend=None policy that resolves to the same concrete backend all share
    one artifact.  Thread-safe: concurrent callers with the same key always
    receive the same instance.
    """
    global _hits, _misses
    key = (config, resolve_policy(config, policy))
    with _lock:
        accel = _artifacts.get(key)
        if accel is None:
            _misses += 1
            accel = _artifacts[key] = PC2IMAccelerator(*key)
        else:
            _hits += 1
        return accel


def cache_stats() -> CacheStats:
    """Introspect the accelerator cache (hit/miss counters + live keys)."""
    with _lock:
        keys = tuple(
            (cfg.name, pol.quant, pol.backend, pol.pipeline, pol.sharding)
            for cfg, pol in _artifacts
        )
        return CacheStats(hits=_hits, misses=_misses, size=len(_artifacts), keys=keys)


def clear_cache() -> None:
    """Drop every cached accelerator and reset the hit/miss counters.

    Compiled engines keep their own cache (core.engine.get_engine); only the
    accelerator artifacts and counters are cleared here.
    """
    global _hits, _misses
    with _lock:
        _artifacts.clear()
        _hits = 0
        _misses = 0
