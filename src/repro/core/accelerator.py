"""PC2IMAccelerator — one (config, policy) pair -> compiled whole-pipeline artifacts.

The paper's accelerator is ONE device: the CIM preprocessing dataflow
(MSP -> L1 FPS -> lattice query) and the SC-CIM feature engine (quantized
per-point MLPs) are co-scheduled halves of the same chip.  This module is
the software image of that: a `PC2IMAccelerator` owns

  * the per-SA-stage `PreprocessEngine`s (batch x MSP tiles folded into one
    kernel grid, backend chosen by the policy), and
  * the policy-driven feature path (every `nn.linear` under the same
    `ExecutionPolicy` — float or SC W16A16/W8A8 through the kernel registry),

and exposes cached, jit-compiled `forward` / `infer` / `loss` artifacts:

    accel = get_accelerator(get_config("pointnet2-cls"),
                            ExecutionPolicy(quant="sc_w16a16"))
    params = accel.init(jax.random.PRNGKey(0))
    logits = accel.infer(params, points)        # (B, N, 3+F) -> (B, C)
    loss, metrics = accel.loss(params, points, labels)

Because `ExecutionPolicy` and `PointNet2Config` are frozen/hashable, the
accelerator cache gives exactly one compiled artifact per distinct
(config, policy) — concurrent serving threads with different policies get
different accelerators and can never interfere (the failure mode of the
removed thread-local `nn.quant_mode`).
"""

from __future__ import annotations

import functools

import jax

from repro.core.policy import ExecutionPolicy, resolve_policy
from repro.models import pointnet2 as PN


class PC2IMAccelerator:
    """Compiled PC2IM pipeline for one (PointNet2Config, ExecutionPolicy).

    Attributes:
        config  : the model/architecture description (WHAT to run).
        policy  : the execution description (HOW to run) — quant mode,
                  kernel backend, interpret flag.
        engines : per-SA-stage PreprocessEngines, stage i consuming stage
                  i-1's centroid count (shared with the forward trace via
                  the global engine cache, so nothing compiles twice).
    """

    def __init__(self, config: PN.PointNet2Config, policy: ExecutionPolicy | None = None):
        self.config = config
        # resolve once: backend=None picks up the config's pinned backend for
        # BOTH halves (engines and feature path) before anything is traced
        self.policy = resolve_policy(config, policy)

        engines = []
        n = config.n_points
        for sa in config.sa:
            engines.append(PN.stage_engine(config, sa, n, self.policy))
            n = sa.n_centroids
        self.engines = tuple(engines)

        cfg, pol = self.config, self.policy
        # jit closes over the static (config, policy) pair: one artifact per
        # accelerator, retraced only per input shape/dtype.
        self._forward = jax.jit(
            lambda params, points: PN.forward(params, cfg, points, policy=pol)
        )
        self._loss = jax.jit(
            lambda params, points, labels: PN.loss_fn(
                params, cfg, points, labels, policy=pol
            )
        )

    # -- artifacts -----------------------------------------------------------

    def init(self, key):
        """Fresh parameters for this accelerator's config."""
        return PN.init_params(key, self.config)

    def forward(self, params, points: jax.Array) -> jax.Array:
        """jit-compiled batched forward: (B, N, 3+F) -> logits."""
        return self._forward(params, points)

    def infer(self, params, points: jax.Array) -> jax.Array:
        """Inference entry point — same compiled artifact as `forward`
        (serving call-sites read better as `accel.infer`)."""
        return self._forward(params, points)

    def loss(self, params, points: jax.Array, labels: jax.Array):
        """jit-compiled (loss, metrics) under this accelerator's policy."""
        return self._loss(params, points, labels)

    def loss_fn(self, params, points: jax.Array, labels: jax.Array):
        """Un-jitted loss for use under jax.grad / custom training loops
        (still pinned to this accelerator's policy)."""
        return PN.loss_fn(params, self.config, points, labels, policy=self.policy)

    def __repr__(self) -> str:
        return (
            f"PC2IMAccelerator({self.config.name}, quant={self.policy.quant!r}, "
            f"backend={self.policy.backend!r}, stages={len(self.engines)})"
        )


@functools.lru_cache(maxsize=None)
def _cached_accelerator(config, policy) -> PC2IMAccelerator:
    return PC2IMAccelerator(config, policy)


def get_accelerator(
    config: PN.PointNet2Config, policy: ExecutionPolicy | None = None
) -> PC2IMAccelerator:
    """Accelerator cache: one compiled pipeline per (config, policy) pair.

    The policy is resolved against the config BEFORE keying the cache, so
    `get_accelerator(cfg)`, `get_accelerator(cfg, policy_for(cfg))` and a
    backend=None policy that resolves to the same concrete backend all share
    one artifact.
    """
    return _cached_accelerator(config, resolve_policy(config, policy))
