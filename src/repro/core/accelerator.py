"""PC2IMAccelerator — one (config, policy) pair -> compiled whole-pipeline artifacts.

The paper's accelerator is ONE device: the CIM preprocessing dataflow
(MSP -> L1 FPS -> lattice query) and the SC-CIM feature engine (quantized
per-point MLPs) are co-scheduled halves of the same chip.  This module is
the software image of that: a `PC2IMAccelerator` owns

  * the per-SA-stage `PreprocessEngine`s (batch x MSP tiles folded into one
    kernel grid, backend chosen by the policy), and
  * the policy-driven feature path (every `nn.linear` under the same
    `ExecutionPolicy` — float or SC W16A16/W8A8 through the kernel registry),

and exposes cached, jit-compiled `forward` / `infer` / `loss` artifacts:

    accel = get_accelerator(get_config("pointnet2-cls"),
                            ExecutionPolicy(quant="sc_w16a16"))
    params = accel.init(jax.random.PRNGKey(0))
    logits = accel.infer(params, points)        # (B, N, 3+F) -> (B, C)
    loss, metrics = accel.loss(params, points, labels)

Because `ExecutionPolicy` and `PointNet2Config` are frozen/hashable, the
accelerator cache gives exactly one compiled artifact per distinct
(config, policy) — concurrent serving threads with different policies get
different accelerators and can never interfere (the failure mode of the
removed thread-local `nn.quant_mode`).
"""

from __future__ import annotations

import dataclasses
import threading

import jax

from repro.core.policy import ExecutionPolicy, resolve_policy
from repro.models import pointnet2 as PN


class PC2IMAccelerator:
    """Compiled PC2IM pipeline for one (PointNet2Config, ExecutionPolicy).

    Attributes:
        config  : the model/architecture description (WHAT to run).
        policy  : the execution description (HOW to run) — quant mode,
                  kernel backend, interpret flag.
        engines : per-SA-stage PreprocessEngines, stage i consuming stage
                  i-1's centroid count (shared with the forward trace via
                  the global engine cache, so nothing compiles twice).
    """

    def __init__(self, config: PN.PointNet2Config, policy: ExecutionPolicy | None = None):
        self.config = config
        # resolve once: backend=None picks up the config's pinned backend for
        # BOTH halves (engines and feature path) before anything is traced
        self.policy = resolve_policy(config, policy)

        engines = []
        n = config.n_points
        for sa in config.sa:
            engines.append(PN.stage_engine(config, sa, n, self.policy))
            n = sa.n_centroids
        self.engines = tuple(engines)

        cfg, pol = self.config, self.policy
        # jit closes over the static (config, policy) pair: one artifact per
        # accelerator, retraced only per input shape/dtype.
        self._forward = jax.jit(
            lambda params, points: PN.forward(params, cfg, points, policy=pol)
        )
        self._loss = jax.jit(
            lambda params, points, labels: PN.loss_fn(
                params, cfg, points, labels, policy=pol
            )
        )

    # -- artifacts -----------------------------------------------------------

    def init(self, key):
        """Fresh parameters for this accelerator's config."""
        return PN.init_params(key, self.config)

    def forward(self, params, points: jax.Array) -> jax.Array:
        """jit-compiled batched forward: (B, N, 3+F) -> logits."""
        return self._forward(params, points)

    def infer(self, params, points: jax.Array) -> jax.Array:
        """Inference entry point — same compiled artifact as `forward`
        (serving call-sites read better as `accel.infer`)."""
        return self._forward(params, points)

    def loss(self, params, points: jax.Array, labels: jax.Array):
        """jit-compiled (loss, metrics) under this accelerator's policy."""
        return self._loss(params, points, labels)

    def loss_fn(self, params, points: jax.Array, labels: jax.Array):
        """Un-jitted loss for use under jax.grad / custom training loops
        (still pinned to this accelerator's policy)."""
        return PN.loss_fn(params, self.config, points, labels, policy=self.policy)

    def __repr__(self) -> str:
        return (
            f"PC2IMAccelerator({self.config.name}, quant={self.policy.quant!r}, "
            f"backend={self.policy.backend!r}, stages={len(self.engines)})"
        )


@dataclasses.dataclass(frozen=True)
class CacheStats:
    """Snapshot of the accelerator cache (see `cache_stats`).

    hits/misses count `get_accelerator` calls; size is the number of live
    artifacts; keys names each artifact as (config.name, quant, backend) so
    tests and the serving runtime can assert one-artifact-per-(config,
    policy) and detect compile storms under concurrent traffic.
    """

    hits: int
    misses: int
    size: int
    keys: tuple[tuple[str, str, str | None], ...]


# Explicit dict cache (not lru_cache): the serving runtime calls
# get_accelerator from many replica/scheduler threads at once, and a bare
# lru_cache lets two concurrent misses BOTH construct (and later jit) an
# accelerator — a compile storm under traffic.  The lock serialises
# construction only; compiled infer/forward calls never take it.
_lock = threading.Lock()
_artifacts: dict[tuple, PC2IMAccelerator] = {}
_hits = 0
_misses = 0


def get_accelerator(
    config: PN.PointNet2Config, policy: ExecutionPolicy | None = None
) -> PC2IMAccelerator:
    """Accelerator cache: one compiled pipeline per (config, policy) pair.

    The policy is resolved against the config BEFORE keying the cache, so
    `get_accelerator(cfg)`, `get_accelerator(cfg, policy_for(cfg))` and a
    backend=None policy that resolves to the same concrete backend all share
    one artifact.  Thread-safe: concurrent callers with the same key always
    receive the same instance.
    """
    global _hits, _misses
    key = (config, resolve_policy(config, policy))
    with _lock:
        accel = _artifacts.get(key)
        if accel is None:
            _misses += 1
            accel = _artifacts[key] = PC2IMAccelerator(*key)
        else:
            _hits += 1
        return accel


def cache_stats() -> CacheStats:
    """Introspect the accelerator cache (hit/miss counters + live keys)."""
    with _lock:
        keys = tuple(
            (cfg.name, pol.quant, pol.backend) for cfg, pol in _artifacts
        )
        return CacheStats(hits=_hits, misses=_misses, size=len(_artifacts), keys=keys)


def clear_cache() -> None:
    """Drop every cached accelerator and reset the hit/miss counters.

    Compiled engines keep their own cache (core.engine.get_engine); only the
    accelerator artifacts and counters are cleared here.
    """
    global _hits, _misses
    with _lock:
        _artifacts.clear()
        _hits = 0
        _misses = 0
