"""Grouping / aggregation — including delayed aggregation (paper C5, from Mesorasi [8]).

Standard PointNet++ set-abstraction dataflow:
    group:   (M, nsample) idx -> neighbour features (M, nsample, C)
    mlp:     per *grouped* point                    (M, nsample, C')
    pool:    max over nsample                       (M, C')
MLP cost scales with M * nsample — neighbourhoods overlap, so each point is
pushed through the MLP many times.

Delayed aggregation reorders to:
    mlp:     per *point*                            (N, C')
    group:   gather                                 (M, nsample, C')
    pool:    max                                    (M, C')
MLP cost scales with N (each point computed once).  Only the final maxpool
sees grouped data.  Exactness: for the linear part of an MLP layer,
max-pool(linear(x)) == linear applied before grouping; with nonlinearities
it is the Mesorasi approximation, which PointNet++-style nets tolerate
(paper adopts it wholesale — we follow, and quantify in benchmarks).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.query import NeighborSet

_NEG = -1e30


def group_features(features: jax.Array, nbrs: NeighborSet) -> jax.Array:
    """Gather neighbour features: (N, C), (M, nsample) -> (M, nsample, C)."""
    return jnp.take(features, nbrs.idx, axis=0)


def group_relative_coords(
    xyz: jax.Array, centroids_xyz: jax.Array, nbrs: NeighborSet
) -> jax.Array:
    """Neighbour coords relative to their centroid: (M, nsample, 3)."""
    g = jnp.take(xyz, nbrs.idx, axis=0)
    return g - centroids_xyz[:, None, :]


def masked_maxpool(grouped: jax.Array, mask: jax.Array) -> jax.Array:
    """Max over the nsample axis, ignoring padded slots.  (M, S, C) -> (M, C)."""
    neg = jnp.asarray(_NEG, grouped.dtype)
    x = jnp.where(mask[..., None], grouped, neg)
    out = jnp.max(x, axis=-2)
    # centroids with zero neighbours -> 0 features
    any_valid = jnp.any(mask, axis=-1)[..., None]
    return jnp.where(any_valid, out, jnp.zeros_like(out))


def aggregate_standard(features, nbrs, mlp_fn):
    """group -> mlp -> pool (the un-delayed baseline)."""
    grouped = group_features(features, nbrs)  # (M, S, C)
    out = mlp_fn(grouped)  # (M, S, C')
    return masked_maxpool(out, nbrs.mask)


def aggregate_delayed(features, nbrs, mlp_fn):
    """mlp -> group -> pool (paper C5)."""
    pointwise = mlp_fn(features)  # (N, C')
    grouped = group_features(pointwise, nbrs)  # (M, S, C')
    return masked_maxpool(grouped, nbrs.mask)


def interpolate_features(features: jax.Array, idx: jax.Array, weights: jax.Array) -> jax.Array:
    """3-NN inverse-distance interpolation (FP layer up-sampling).

    features: (N, C) at the coarse level; idx/weights: (M, k) -> (M, C).
    """
    gathered = jnp.take(features, idx, axis=0)  # (M, k, C)
    return jnp.sum(gathered * weights[..., None], axis=1)
