"""Fault-tolerant checkpointing (no orbax offline — built native).

Properties required at 1000-node scale, all implemented here:
  * ATOMIC: write to <dir>.tmp-<uuid>, fsync, os.rename — a crash mid-save
    never corrupts the latest checkpoint; restore scans for the newest
    COMPLETE step directory (marker file).
  * ASYNC: save_checkpoint(..., blocking=False) snapshots to host memory
    and streams to disk on a background thread — the train loop resumes
    immediately (one step of jitter, not a full serialisation stall).
  * ELASTIC: arrays are stored UNSHARDED (gathered) with dtype/shape
    metadata; restore re-shards onto WHATEVER mesh/sharding the new job
    passes — a 512-chip checkpoint restores onto 256 chips (or 1 CPU) by
    construction.  (At true 100B scale one would write per-shard files;
    the single-file layout keeps the same interface and is what the tests
    exercise.)
  * Payload: msgpack + zstd (fast, no pickle, version-tagged).
"""

from __future__ import annotations

import os
import threading
import uuid
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # optional fast path; bare envs fall back to stdlib zlib
    import zstandard
except ImportError:
    zstandard = None
import zlib

FORMAT_VERSION = 1
_MARKER = "COMPLETE"

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(payload: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(payload)
    return zlib.compress(payload, 3)


def _decompress(blob: bytes) -> bytes:
    """Sniff the container magic so either writer's files restore anywhere."""
    if blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError(
                "checkpoint is zstd-compressed but zstandard is not installed "
                "(pip install -r requirements-dev.txt)"
            )
        return zstandard.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def _tree_to_records(tree: Any) -> list:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    recs = []
    for leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            recs.append(
                {"dtype": "bfloat16", "shape": list(arr.shape), "data": arr.view(np.uint16).tobytes()}
            )
        else:
            recs.append(
                {"dtype": arr.dtype.str, "shape": list(arr.shape), "data": arr.tobytes()}
            )
    return recs, treedef


def _records_to_arrays(recs: list) -> list[np.ndarray]:
    out = []
    for r in recs:
        if r["dtype"] == "bfloat16":
            a = np.frombuffer(r["data"], np.uint16).reshape(r["shape"]).view(jnp.bfloat16)
        else:
            a = np.frombuffer(r["data"], np.dtype(r["dtype"])).reshape(r["shape"])
        out.append(a)
    return out


def save_checkpoint(
    directory: str,
    step: int,
    tree: Any,
    *,
    blocking: bool = True,
    extra: dict | None = None,
) -> threading.Thread | None:
    """Save `tree` at `step` under directory/step_<N>/ atomically."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]  # snapshot NOW

    def _write():
        recs = []
        for arr in host_leaves:
            if arr.dtype == jnp.bfloat16:
                recs.append({"dtype": "bfloat16", "shape": list(arr.shape),
                             "data": arr.view(np.uint16).tobytes()})
            else:
                recs.append({"dtype": arr.dtype.str, "shape": list(arr.shape),
                             "data": arr.tobytes()})
        payload = msgpack.packb(
            {"version": FORMAT_VERSION, "step": step, "extra": extra or {}, "leaves": recs},
            use_bin_type=True,
        )
        comp = _compress(payload)
        final = os.path.join(directory, f"step_{step:012d}")
        tmp = final + f".tmp-{uuid.uuid4().hex[:8]}"
        os.makedirs(tmp, exist_ok=True)
        with open(os.path.join(tmp, "data.msgpack.zst"), "wb") as f:
            f.write(comp)
            f.flush()
            os.fsync(f.fileno())
        with open(os.path.join(tmp, _MARKER), "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            return  # concurrent save of the same step
        os.rename(tmp, final)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and ".tmp" not in name:
            if os.path.exists(os.path.join(directory, name, _MARKER)):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def load_checkpoint(
    directory: str,
    tree_like: Any,
    *,
    step: int | None = None,
    shardings: Any | None = None,
):
    """Restore into the structure of `tree_like`; reshard onto `shardings`
    (a pytree of NamedSharding/None) for elastic restore.  Returns
    (tree, step, extra)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:012d}", "data.msgpack.zst")
    with open(path, "rb") as f:
        payload = _decompress(f.read())
    obj = msgpack.unpackb(payload, raw=False)
    assert obj["version"] == FORMAT_VERSION
    arrays = _records_to_arrays(obj["leaves"])
    leaves_like, treedef = jax.tree_util.tree_flatten(tree_like)
    assert len(arrays) == len(leaves_like), (
        f"checkpoint has {len(arrays)} leaves, expected {len(leaves_like)}"
    )
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_flatten(shardings)[0]
    else:
        shard_leaves = [None] * len(arrays)
    out = []
    for arr, like, sh in zip(arrays, leaves_like, shard_leaves):
        a = jnp.asarray(arr)
        if sh is not None:
            a = jax.device_put(a, sh)
        out.append(a)
    return jax.tree_util.tree_unflatten(treedef, out), obj["step"], obj["extra"]


class CheckpointManager:
    """Keeps the last `keep` checkpoints; async saves; restart-aware."""

    def __init__(self, directory: str, *, keep: int = 3, every: int = 100):
        self.directory = directory
        self.keep = keep
        self.every = every
        self._pending: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def maybe_save(self, step: int, tree: Any, *, force: bool = False, extra=None):
        if not force and (self.every <= 0 or step % self.every != 0):
            return False
        self.wait()
        self._pending = save_checkpoint(
            self.directory, step, tree, blocking=False, extra=extra
        )
        self._gc()
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore_or_none(self, tree_like, *, shardings=None):
        step = latest_step(self.directory)
        if step is None:
            return None
        return load_checkpoint(self.directory, tree_like, step=step, shardings=shardings)

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and ".tmp" not in n
            and os.path.exists(os.path.join(self.directory, n, _MARKER))
        )
        import shutil

        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:012d}"), ignore_errors=True)
