"""Model zoo.

pointnet2.py       the paper's model (PointNet++ cls/seg) with swappable
                   PC2IM preprocessing + SC-quantized MLPs
layers.py          shared transformer primitives (RMSNorm, RoPE, GQA, SwiGLU)
transformer.py     dense decoder LMs (incl. local:global sliding-window mixes)
moe.py             top-k routed mixture-of-experts FFN
mamba2.py          SSD (state-space duality) blocks
rglru.py           Griffin RG-LRU recurrent blocks
whisper.py         encoder-decoder (audio frontend stubbed per assignment)
vlm.py             ViT-frontend-stub + LM backbone
nn.py              param-dict linear/mlp/init utilities (ExecutionPolicy-aware)
"""
