"""RG-LRU (Real-Gated Linear Recurrent Unit) — Griffin / recurrentgemma
(arXiv:2402.19427).

    r_t = sigmoid(W_a x_t)                 (recurrence gate)
    i_t = sigmoid(W_x x_t)                 (input gate)
    a_t = a^(c * r_t)       a = sigmoid(Lambda), c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill: associative scan over the sequence (exact, parallel).
Decode: O(1) state update.  The recurrent block wraps the RG-LRU with a
linear in-proj + short causal conv + gated output, per the Griffin paper.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import nn

_C = 8.0


class LRUCache(NamedTuple):
    h: jax.Array  # (B, W) recurrent state f32
    conv: jax.Array  # (B, conv-1, W) rolling conv inputs


def rglru_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    w = cfg.lru_width or d
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "in_x": nn.linear_init(k1, d, w, bias=False, dtype=dtype),
        "in_y": nn.linear_init(k2, d, w, bias=False, dtype=dtype),
        "conv_w": (jax.random.normal(k3, (4, w)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "gate_a": nn.linear_init(k4, w, w, bias=True, dtype=dtype),
        "gate_x": nn.linear_init(k5, w, w, bias=True, dtype=dtype),
        # Lambda init so that a = sigmoid(L)^c is in ~(0.9, 0.999)
        "lam": jnp.log(jnp.linspace(0.9, 0.999, w) ** (1 / _C)
                       / (1 - jnp.linspace(0.9, 0.999, w) ** (1 / _C))).astype(jnp.float32),
        "out": nn.linear_init(jax.random.fold_in(key, 9), w, d, bias=False, dtype=dtype),
    }


def _lru_scan(x: jax.Array, a: jax.Array) -> jax.Array:
    """h_t = a_t h_{t-1} + x_t via associative scan.  (B, S, W) f32."""

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_s, b_s = jax.lax.associative_scan(combine, (a, x), axis=1)
    return b_s


def rglru_apply(p, cfg, x: jax.Array, cache: LRUCache | None = None, policy=None):
    """x: (B, S, d_model) -> (out, new_cache).  Griffin recurrent block."""
    b, s, _ = x.shape

    gate_branch = jax.nn.gelu(nn.linear(p["in_y"], x, policy=policy))  # (B, S, W)
    u = nn.linear(p["in_x"], x, policy=policy)  # (B, S, W)

    # short causal conv (width 4, depthwise)
    if cache is None:
        width = p["conv_w"].shape[0]
        up = jnp.pad(u, ((0, 0), (width - 1, 0), (0, 0)))
        uc = jnp.zeros_like(u)
        for i in range(width):
            uc = uc + up[:, i : i + s] * p["conv_w"][i][None, None]
        uc = uc + p["conv_b"][None, None]
        conv_tail = u[:, -(width - 1) :] if s >= width - 1 else jnp.pad(
            u, ((0, 0), (width - 1 - s, 0), (0, 0))
        )
    else:
        hist = jnp.concatenate([cache.conv, u], axis=1)  # (B, W, C)
        uc = (jnp.einsum("bwc,wc->bc", hist, p["conv_w"]) + p["conv_b"])[:, None]
        conv_tail = hist[:, 1:]

    # RG-LRU core (f32 for the recurrence)
    ucf = uc.astype(jnp.float32)
    r = jax.nn.sigmoid(nn.linear(p["gate_a"], uc, policy=policy).astype(jnp.float32))
    i = jax.nn.sigmoid(nn.linear(p["gate_x"], uc, policy=policy).astype(jnp.float32))
    log_a_base = jax.nn.log_sigmoid(p["lam"])[None, None, :]  # (1,1,W)
    log_a = _C * r * log_a_base
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * ucf)

    if cache is None:
        h = _lru_scan(gated_in, a)  # (B, S, W)
        new_cache = LRUCache(h=h[:, -1], conv=conv_tail)
    else:
        h = a[:, 0] * cache.h + gated_in[:, 0]  # (B, W)
        new_cache = LRUCache(h=h, conv=conv_tail)
        h = h[:, None]

    out = nn.linear(p["out"], (h.astype(x.dtype) * gate_branch), policy=policy)
    return out, new_cache
