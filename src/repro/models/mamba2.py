"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) blocks.

Train/prefill uses the chunked SSD algorithm: quadratic attention-like math
*within* chunks (Q=ssm_chunk) + a linear recurrence over chunk states:

  per chunk c:   L = exp(segsum(dtA))            (intra-chunk decay, Q x Q)
                 Y_diag = (C B^T . L) X           (intra-chunk)
                 S_c    = (decay . B)^T X         (chunk state contribution)
  across chunks: S'_{c} = exp(sum dtA_c) S'_{c-1} + S_c   (lax.scan)
                 Y_off  = C S'_{c-1} with in-chunk decay

Decode is the O(1) recurrent update  s = exp(dtA) s + dt B x;  y = C s + D x.

Layout: x (B, S, H, P) with H = expand*d_model / headdim heads, state N.
The chunk scan keeps HLO compact and the state pass is exact (no window
approximation) — this is why mamba2 runs the long_500k cell.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import nn
from repro.models.layers import rmsnorm, rmsnorm_init


class SSMCache(NamedTuple):
    state: jax.Array  # (B, H, P, N) f32
    conv: jax.Array  # (B, W-1, conv_dim) rolling conv inputs


def mamba2_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_headdim
    conv_dim = d_inner + 2 * cfg.ssm_state  # x, B, C all convolved
    return d_inner, n_heads, conv_dim


def mamba2_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    d_inner, n_heads, conv_dim = mamba2_dims(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # in_proj -> [z (gate), x, B, C, dt]
    d_in_proj = 2 * d_inner + 2 * cfg.ssm_state + n_heads
    return {
        "in_proj": nn.linear_init(k1, d, d_in_proj, bias=False, dtype=dtype),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv, conv_dim)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": rmsnorm_init(d_inner, dtype),
        "out_proj": nn.linear_init(k3, d_inner, d, bias=False, dtype=dtype),
    }


def _causal_conv_train(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv.  x: (B, S, C), w: (W, C) -> (B, S, C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):  # W=4: unrolled shift-mul-add (depthwise)
        out = out + xp[:, i : i + x.shape[1]] * w[i][None, None, :]
    return out + b[None, None, :]


def _segsum(dta: jax.Array) -> jax.Array:
    """dta: (..., Q) -> (..., Q, Q) lower-tri cumulative sums: sum_{j<m<=i} dta_m."""
    q = dta.shape[-1]
    cum = jnp.cumsum(dta, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]  # (..., Q, Q): sum over (j, i]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_forward(x, dt, A, B, C, *, chunk: int):
    """Chunked SSD.  x: (b, s, h, p); dt: (b, s, h); A: (h,) (negative);
    B, C: (b, s, n).  Returns (y (b,s,h,p), final_state (b,h,p,n))."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    while s % q:
        q //= 2
    nc = s // q

    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    Bc = B.reshape(b, nc, q, n)
    Cc = C.reshape(b, nc, q, n)
    dta = dtc * A[None, None, None, :]  # (b, nc, q, h) negative decays

    # intra-chunk ("diagonal") term
    L = jnp.exp(_segsum(dta.transpose(0, 1, 3, 2)))  # (b, nc, h, q, q)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # (b, nc, q, q)
    # weight by dt at the source position j
    y_diag = jnp.einsum(
        "bchij,bcij,bcjh,bcjhp->bcihp", L, scores, dtc, xc
    )

    # chunk state contributions: S_c = sum_j decay_to_end_j * dt_j * B_j x_j^T
    decay_end = jnp.exp(
        jnp.cumsum(dta[..., ::-1, :], axis=2)[..., ::-1, :] - dta
    )  # (b, nc, q, h): product of decays AFTER position j within chunk
    states = jnp.einsum("bcjh,bcjh,bcjn,bcjhp->bchpn", decay_end, dtc, Bc, xc)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(jnp.sum(dta, axis=2))  # (b, nc, h)

    def scan_fn(s_prev, inp):
        st, dec = inp  # (b, h, p, n), (b, h)
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev  # emit the state BEFORE this chunk

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_fn,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b, nc, h, p, n)

    # off-diagonal (cross-chunk) term: decay from chunk start to position i
    decay_in = jnp.exp(jnp.cumsum(dta, axis=2))  # (b, nc, q, h)
    y_off = jnp.einsum("bcin,bcih,bchpn->bcihp", Cc, decay_in, prev_states)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final_state


def mamba2_apply(p, cfg, x: jax.Array, cache: SSMCache | None = None, policy=None):
    """x: (B, S, d_model).  Train/prefill (cache None) or decode (S == 1)."""
    bsz, s, _ = x.shape
    d_inner, n_heads, conv_dim = mamba2_dims(cfg)
    n = cfg.ssm_state

    zxbcdt = nn.linear(p["in_proj"], x, policy=policy)  # (B, S, 2*d_inner + 2n + H)
    z = zxbcdt[..., :d_inner]  # gate
    xbc = zxbcdt[..., d_inner : d_inner + conv_dim]  # x, B, C (convolved)
    dt_raw = zxbcdt[..., d_inner + conv_dim :]  # (B, S, H)

    new_cache = None
    xbc_raw = xbc
    if cache is None:
        xbc = _causal_conv_train(xbc, p["conv_w"], p["conv_b"])
    else:
        # decode: rolling conv state (B, W-1, conv_dim)
        width = cfg.ssm_conv
        hist = jnp.concatenate([cache.conv, xbc], axis=1)  # (B, W, C)
        xbc = (
            jnp.einsum("bwc,wc->bc", hist, p["conv_w"]) + p["conv_b"]
        )[:, None, :]
        new_conv = hist[:, 1:]
    xbc = jax.nn.silu(xbc)

    xs = xbc[..., :d_inner].reshape(bsz, s, n_heads, cfg.ssm_headdim)
    B = xbc[..., d_inner : d_inner + n]
    C = xbc[..., d_inner + n :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,) negative

    if cache is None:
        y, final_state = ssd_forward(
            xs.astype(jnp.float32), dt, A, B.astype(jnp.float32), C.astype(jnp.float32),
            chunk=cfg.ssm_chunk,
        )
        # full prefill cache: ssm state + rolling conv tail (raw, pre-conv)
        width = cfg.ssm_conv
        tail = xbc_raw[:, -(width - 1) :] if s >= width - 1 else jnp.pad(
            xbc_raw, ((0, 0), (width - 1 - s, 0), (0, 0))
        )
        new_cache = SSMCache(state=final_state, conv=tail)
        aux_state = final_state
    else:
        # O(1) recurrent step
        dta = jnp.exp(dt[:, 0] * A[None, :])  # (B, H)
        sx = xs[:, 0].astype(jnp.float32)  # (B, H, P)
        dbx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0], B[:, 0].astype(jnp.float32), sx)
        state = cache.state * dta[..., None, None] + dbx
        y = jnp.einsum("bn,bhpn->bhp", C[:, 0].astype(jnp.float32), state)[:, None]
        new_cache = SSMCache(state=state, conv=new_conv)
        aux_state = state

    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    return nn.linear(p["out_proj"], y, policy=policy), new_cache, aux_state
