"""Minimal functional NN utilities (no flax): params are plain dicts of arrays.

Every dense layer routes through `linear(...)`, which takes the numeric /
backend decision as an explicit `ExecutionPolicy` — the paper's C4 (SC
W16A16) exposed to all architectures with no hidden state:

    policy = ExecutionPolicy(quant="sc_w16a16")
    y = nn.linear(params, x, policy=policy)

`policy=None` (the default) is the float path.  The quantized path goes
through the kernel registry (`kernels/sc_matmul`) exactly like the FPS and
lattice kernels, honouring `policy.backend` / `policy.interpret`.
"""

from __future__ import annotations

import contextlib
import warnings

import jax
import jax.numpy as jnp

from repro.core.policy import ExecutionPolicy
from repro.core.quant import quantize_symmetric
from repro.kernels.sc_matmul.ops import sc_matmul_op, sc_quantized_linear
from repro.sharding.hints import REPLICA_AXIS, replica_axis_active


@contextlib.contextmanager
def quant_mode(mode: str):
    """DEPRECATED, BEHAVIOR-CHANGING shim for the removed thread-local API.

    This shim keeps legacy `with nn.quant_mode(...)` code importable and
    callable for one release, but it CANNOT preserve the old semantics:
    quantization is no longer applied implicitly, so a caller that ignores
    the yielded value now gets FLOAT results where it used to get SC-CIM
    quantized ones.  The yielded `ExecutionPolicy` must be passed onward:

        with nn.quant_mode("sc_w16a16") as policy:   # deprecated
            y = nn.linear(params, x, policy=policy)

    New code should construct an `ExecutionPolicy` directly (or use
    `PC2IMAccelerator`, which owns one policy for the whole pipeline).
    Will be removed one release after the ExecutionPolicy API landed.
    """
    # FutureWarning (shown by default, unlike DeprecationWarning): legacy
    # callers that ignore the yielded policy now get FLOAT math — that
    # numeric change must be loud, not filtered.
    warnings.warn(
        "nn.quant_mode no longer applies quantization implicitly: linears "
        "run the SC path ONLY where the yielded ExecutionPolicy is passed, "
        "e.g. `with nn.quant_mode(m) as pol: nn.linear(p, x, policy=pol)`. "
        "Callers that ignore the yielded value get float results. Construct "
        "an ExecutionPolicy explicitly instead (repro.core.policy).",
        FutureWarning,
        stacklevel=3,
    )
    yield ExecutionPolicy(quant=mode)


def linear_init(key, d_in: int, d_out: int, *, bias: bool = True, scale: float | None = None, dtype=jnp.float32):
    wkey, _ = jax.random.split(key)
    std = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    p = {"w": (jax.random.normal(wkey, (d_in, d_out)) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def _shard_mode(policy: ExecutionPolicy | None) -> str | None:
    """The policy's sharding mode, but ONLY inside a mapped replica mesh.

    Outside `shard_map` over REPLICA_AXIS the axis is unbound and every
    sharded code path deactivates, so a sharded policy traces identically
    to its unsharded twin under plain jit — the knob selects a different
    cached artifact, never different single-device math.
    """
    mode = getattr(policy, "sharding", None) if policy is not None else None
    if mode is None:
        return None
    return mode if replica_axis_active() else None


def _linear_tensor_sharded(p, x: jax.Array, policy: ExecutionPolicy) -> jax.Array:
    """Column-split linear across the replica mesh (split-concatenate).

    Each device multiplies against its slice of the weight columns and the
    partial products are concatenated with a tiled all_gather — the paper's
    SC dataflow lifted to a device group.  Bitwise-equal to the replicated
    linear: fp32 columns are independent; the quantized path quantizes the
    FULL weight first (global per-tensor scale) and slices the integer
    planes, whose matmul is exact, so column subsets match the unsharded
    product exactly.  N is zero-padded up to a multiple of the group size;
    the pad columns are dropped after the gather.
    """
    w = p["w"]
    k, n = w.shape
    group = int(jax.core.axis_frame(REPLICA_AXIS))  # static axis size
    idx = jax.lax.axis_index(REPLICA_AXIS)
    cols = -(-n // group)  # ceil: last shard may hold zero-pad columns
    bits = policy.quant_bits
    if bits is None:
        wp = jnp.pad(w, ((0, 0), (0, cols * group - n)))
        wl = jax.lax.dynamic_slice_in_dim(wp, idx * cols, cols, axis=1)
        y = x @ wl
    else:
        lead = x.shape[:-1]
        xq = quantize_symmetric(x.reshape(-1, k), bits)
        wq = quantize_symmetric(w, bits)  # full-tensor scale: replicated, global
        wqp = jnp.pad(wq.q, ((0, 0), (0, cols * group - n)))
        wl = jax.lax.dynamic_slice_in_dim(wqp, idx * cols, cols, axis=1)
        y = sc_matmul_op(
            xq.q, wl, bits=bits,
            backend=policy.resolved_backend(), interpret=policy.interpret,
        )
        y = (y * (xq.scale * wq.scale)).reshape(lead + (cols,)).astype(x.dtype)
    y = jax.lax.all_gather(y, REPLICA_AXIS, axis=-1, tiled=True)[..., :n]
    if "b" in p:
        y = y + p["b"]
    return y


def linear(p, x: jax.Array, policy: ExecutionPolicy | None = None) -> jax.Array:
    """Dense layer.  policy=None or policy.quant="none": float matmul;
    otherwise the SC-CIM integer path via the kernel registry.  Under an
    active replica mesh (accelerator sharded artifacts), policy.sharding
    routes to the split-concatenate column sharding ("tensor") or
    globalizes the activation quant scale over the batch shards ("batch")."""
    mode = _shard_mode(policy)
    if mode == "tensor":
        return _linear_tensor_sharded(p, x, policy)
    bits = None if policy is None else policy.quant_bits
    if bits is None:
        y = x @ p["w"]
    else:
        y = sc_quantized_linear(
            x, p["w"], bits=bits,
            backend=policy.resolved_backend(), interpret=policy.interpret,
            amax_axis=REPLICA_AXIS if mode == "batch" else None,
        ).astype(x.dtype)
    if "b" in p:
        y = y + p["b"]
    return y


def layernorm_init(d: int, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    # stats in f32, normalisation applied in the input dtype (see rmsnorm)
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x - mu.astype(x.dtype)) * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y * p["g"] + p["b"]


def mlp_init(key, channels: list[int], *, bias: bool = True, norm: bool = True, dtype=jnp.float32):
    """Per-point MLP stack: [linear -> LN -> relu] per layer (LN in place of
    the original BatchNorm — documented deviation, stats-free)."""
    keys = jax.random.split(key, len(channels) - 1)
    layers = []
    for i, (cin, cout) in enumerate(zip(channels[:-1], channels[1:])):
        lay = {"lin": linear_init(keys[i], cin, cout, bias=bias, dtype=dtype)}
        if norm:
            lay["ln"] = layernorm_init(cout, dtype)
        layers.append(lay)
    return {"layers": layers}


def mlp_apply(
    p, x: jax.Array, *, final_act: bool = True, policy: ExecutionPolicy | None = None
) -> jax.Array:
    n = len(p["layers"])
    for i, lay in enumerate(p["layers"]):
        x = linear(lay["lin"], x, policy=policy)
        if "ln" in lay:
            x = layernorm(lay["ln"], x)
        if final_act or i < n - 1:
            x = jax.nn.relu(x)
    return x


def count_params(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))
