"""Minimal functional NN utilities (no flax): params are plain dicts of arrays.

Every dense layer routes through `linear(...)`, which takes the numeric /
backend decision as an explicit `ExecutionPolicy` — the paper's C4 (SC
W16A16) exposed to all architectures with no hidden state:

    policy = ExecutionPolicy(quant="sc_w16a16")
    y = nn.linear(params, x, policy=policy)

`policy=None` (the default) is the float path.  The quantized path goes
through the kernel registry (`kernels/sc_matmul`) exactly like the FPS and
lattice kernels, honouring `policy.backend` / `policy.interpret`.
"""

from __future__ import annotations

import contextlib
import warnings

import jax
import jax.numpy as jnp

from repro.core.policy import ExecutionPolicy
from repro.kernels.sc_matmul.ops import sc_quantized_linear


@contextlib.contextmanager
def quant_mode(mode: str):
    """DEPRECATED, BEHAVIOR-CHANGING shim for the removed thread-local API.

    This shim keeps legacy `with nn.quant_mode(...)` code importable and
    callable for one release, but it CANNOT preserve the old semantics:
    quantization is no longer applied implicitly, so a caller that ignores
    the yielded value now gets FLOAT results where it used to get SC-CIM
    quantized ones.  The yielded `ExecutionPolicy` must be passed onward:

        with nn.quant_mode("sc_w16a16") as policy:   # deprecated
            y = nn.linear(params, x, policy=policy)

    New code should construct an `ExecutionPolicy` directly (or use
    `PC2IMAccelerator`, which owns one policy for the whole pipeline).
    Will be removed one release after the ExecutionPolicy API landed.
    """
    # FutureWarning (shown by default, unlike DeprecationWarning): legacy
    # callers that ignore the yielded policy now get FLOAT math — that
    # numeric change must be loud, not filtered.
    warnings.warn(
        "nn.quant_mode no longer applies quantization implicitly: linears "
        "run the SC path ONLY where the yielded ExecutionPolicy is passed, "
        "e.g. `with nn.quant_mode(m) as pol: nn.linear(p, x, policy=pol)`. "
        "Callers that ignore the yielded value get float results. Construct "
        "an ExecutionPolicy explicitly instead (repro.core.policy).",
        FutureWarning,
        stacklevel=3,
    )
    yield ExecutionPolicy(quant=mode)


def linear_init(key, d_in: int, d_out: int, *, bias: bool = True, scale: float | None = None, dtype=jnp.float32):
    wkey, _ = jax.random.split(key)
    std = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    p = {"w": (jax.random.normal(wkey, (d_in, d_out)) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x: jax.Array, policy: ExecutionPolicy | None = None) -> jax.Array:
    """Dense layer.  policy=None or policy.quant="none": float matmul;
    otherwise the SC-CIM integer path via the kernel registry."""
    bits = None if policy is None else policy.quant_bits
    if bits is None:
        y = x @ p["w"]
    else:
        y = sc_quantized_linear(
            x, p["w"], bits=bits,
            backend=policy.resolved_backend(), interpret=policy.interpret,
        ).astype(x.dtype)
    if "b" in p:
        y = y + p["b"]
    return y


def layernorm_init(d: int, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    # stats in f32, normalisation applied in the input dtype (see rmsnorm)
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x - mu.astype(x.dtype)) * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y * p["g"] + p["b"]


def mlp_init(key, channels: list[int], *, bias: bool = True, norm: bool = True, dtype=jnp.float32):
    """Per-point MLP stack: [linear -> LN -> relu] per layer (LN in place of
    the original BatchNorm — documented deviation, stats-free)."""
    keys = jax.random.split(key, len(channels) - 1)
    layers = []
    for i, (cin, cout) in enumerate(zip(channels[:-1], channels[1:])):
        lay = {"lin": linear_init(keys[i], cin, cout, bias=bias, dtype=dtype)}
        if norm:
            lay["ln"] = layernorm_init(cout, dtype)
        layers.append(lay)
    return {"layers": layers}


def mlp_apply(
    p, x: jax.Array, *, final_act: bool = True, policy: ExecutionPolicy | None = None
) -> jax.Array:
    n = len(p["layers"])
    for i, lay in enumerate(p["layers"]):
        x = linear(lay["lin"], x, policy=policy)
        if "ln" in lay:
            x = layernorm(lay["ln"], x)
        if final_act or i < n - 1:
            x = jax.nn.relu(x)
    return x


def count_params(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))
