"""Minimal functional NN utilities (no flax): params are plain dicts of arrays.

Every dense layer routes through `linear(...)`, which honours the module-level
quant mode — the paper's C4 (SC W16A16) exposed to all architectures:

    with quant_mode("sc_w16a16"):  # or configure per-model
        y = nn.linear(params, x)
"""

from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp

from repro.core.quant import quantized_linear

_STATE = threading.local()


def current_quant_mode() -> str:
    return getattr(_STATE, "mode", "none")


@contextlib.contextmanager
def quant_mode(mode: str):
    """'none' | 'sc_w16a16' | 'sc_w8a8' — applies to every linear() inside."""
    prev = current_quant_mode()
    _STATE.mode = mode
    try:
        yield
    finally:
        _STATE.mode = prev


def linear_init(key, d_in: int, d_out: int, *, bias: bool = True, scale: float | None = None, dtype=jnp.float32):
    wkey, _ = jax.random.split(key)
    std = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    p = {"w": (jax.random.normal(wkey, (d_in, d_out)) * std).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x: jax.Array) -> jax.Array:
    mode = current_quant_mode()
    if mode == "none":
        y = x @ p["w"]
    elif mode == "sc_w16a16":
        y = quantized_linear(x, p["w"], bits=16).astype(x.dtype)
    elif mode == "sc_w8a8":
        y = quantized_linear(x, p["w"], bits=8).astype(x.dtype)
    else:
        raise ValueError(f"unknown quant mode {mode!r}")
    if "b" in p:
        y = y + p["b"]
    return y


def layernorm_init(d: int, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    # stats in f32, normalisation applied in the input dtype (see rmsnorm)
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x - mu.astype(x.dtype)) * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y * p["g"] + p["b"]


def mlp_init(key, channels: list[int], *, bias: bool = True, norm: bool = True, dtype=jnp.float32):
    """Per-point MLP stack: [linear -> LN -> relu] per layer (LN in place of
    the original BatchNorm — documented deviation, stats-free)."""
    keys = jax.random.split(key, len(channels) - 1)
    layers = []
    for i, (cin, cout) in enumerate(zip(channels[:-1], channels[1:])):
        lay = {"lin": linear_init(keys[i], cin, cout, bias=bias, dtype=dtype)}
        if norm:
            lay["ln"] = layernorm_init(cout, dtype)
        layers.append(lay)
    return {"layers": layers}


def mlp_apply(p, x: jax.Array, *, final_act: bool = True) -> jax.Array:
    n = len(p["layers"])
    for i, lay in enumerate(p["layers"]):
        x = linear(lay["lin"], x)
        if "ln" in lay:
            x = layernorm(lay["ln"], x)
        if final_act or i < n - 1:
            x = jax.nn.relu(x)
    return x


def count_params(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))
