"""Shared transformer primitives: RMSNorm, RoPE, GQA attention (train/decode),
block-pair flash attention, gated MLPs.

Attention design notes (these drive the dry-run memory + roofline quality):

* `flash_attention`: blockwise online-softmax attention implemented as a
  lax.scan over a STATICALLY-ENUMERATED list of (q_block, kv_block) pairs.
  - memory: never materialises (S, S) scores — required for the 32k cells
    (dense scores for command-r prefill_32k would be ~2.2 PB global).
  - FLOPs honesty: causal/windowed patterns enumerate only the needed
    block pairs at trace time, so compiled HLO FLOPs match the true
    mathematical work (a masked-dense implementation would double-count
    causal FLOPs and corrupt the §Roofline compute term).
  - pairs are ordered row-major per q block; running (max, denom, acc)
    stats live in the scan carry, updated via dynamic slices.

* decode: single-token q against the KV cache — dense O(S) row attention
  (no flash needed; memory is the cache itself).

* GQA: kv heads broadcast to q heads via reshape-free einsum grouping.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import nn

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    # f32 stats + f32 normalise (bf16-applied variant measured WORSE on the
    # 104B cell: the product-rule backward adds full-size intermediates —
    # §Perf cell-A iteration 3, refuted)
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * p["g"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh), positions: (..., S) int32."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)  # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    sin = jnp.sin(ang)[..., None, :]  # (..., S, 1, Dh/2)
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash attention over static block pairs
# ---------------------------------------------------------------------------

_NEG_INF = -2.0e38


def _block_pairs(nb: int, causal: bool, window_blocks: int | None) -> list[tuple[int, int]]:
    """Statically enumerate needed (q_block, kv_block) pairs, row-major."""
    pairs = []
    for i in range(nb):
        lo = 0 if window_blocks is None else max(0, i - window_blocks)
        hi = i if causal else nb - 1
        for j in range(lo, hi + 1):
            pairs.append((i, j))
    return pairs


def _pick_block(n: int, want: int) -> int:
    blk = min(want, n)
    if n % blk:
        for cand in (256, 128, 64, 32, 16, 8, 4, 2, 1):
            if n % cand == 0:
                return cand
    return blk


def _flash_geometry(s: int, sk: int, causal: bool, window, block: int):
    blk = _pick_block(math.gcd(s, sk), block)
    nb, nkb = s // blk, sk // blk
    wb = None if window is None else max(1, (window + blk - 1) // blk)
    if causal:
        pairs = _block_pairs(nb, True, wb)
    else:
        pairs = [(i, j) for i in range(nb) for j in range(nkb)]
    return blk, pairs


def _pair_mask(i, j, blk, causal, window):
    span = jnp.arange(blk)
    qpos = i * blk + span[:, None]
    kpos = j * blk + span[None, :]
    mask = jnp.ones((blk, blk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    return mask


def _flash_fwd_impl(cfg, q, k, v):
    """Returns (out (B,Hq,S,Dh) f32, lse (B,Hq,S,1) f32).  Layout (B,H,S,D)."""
    causal, window, blk, pairs, scale = cfg
    b, hq, s, dh = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    pi = jnp.asarray([p[0] for p in pairs], jnp.int32)
    pj = jnp.asarray([p[1] for p in pairs], jnp.int32)

    acc0 = jnp.zeros((b, hq, s, dh), jnp.float32)
    m0 = jnp.full((b, hq, s, 1), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, s, 1), jnp.float32)

    def body(carry, ij):
        acc, m, den = carry
        i, j = ij
        qi = jax.lax.dynamic_slice_in_dim(q, i * blk, blk, axis=2)
        kj = jax.lax.dynamic_slice_in_dim(k, j * blk, blk, axis=2)
        vj = jax.lax.dynamic_slice_in_dim(v, j * blk, blk, axis=2)
        qi_g = (qi * scale).reshape(b, hkv, g, blk, dh)
        scores = jnp.einsum(
            "bhgqd,bhkd->bhgqk", qi_g, kj, preferred_element_type=jnp.float32
        )
        mask = _pair_mask(i, j, blk, causal, window)
        scores = jnp.where(mask[None, None, None], scores, _NEG_INF)
        scores = scores.reshape(b, hq, blk, blk)

        mi = jax.lax.dynamic_slice_in_dim(m, i * blk, blk, axis=2)
        li = jax.lax.dynamic_slice_in_dim(den, i * blk, blk, axis=2)
        acci = jax.lax.dynamic_slice_in_dim(acc, i * blk, blk, axis=2)

        m_new = jnp.maximum(mi, scores.max(-1, keepdims=True))
        safe_m = jnp.where(m_new <= _NEG_INF / 2, 0.0, m_new)
        # masked scores are -NEG_INF: exp underflows to exactly 0 — no second
        # mask pass needed (one less full-block buffer per pair, §Perf A.3)
        p = jnp.exp(scores - safe_m)
        corr = jnp.where(mi <= _NEG_INF / 2, 0.0, jnp.exp(mi - safe_m))
        l_new = corr * li + p.sum(-1, keepdims=True)
        pv = jnp.einsum(
            "bhgqk,bhkd->bhgqd",
            p.reshape(b, hkv, g, blk, blk).astype(v.dtype),
            vj,
            preferred_element_type=jnp.float32,
        ).reshape(b, hq, blk, dh)
        acc_new = corr * acci + pv

        acc = jax.lax.dynamic_update_slice_in_dim(acc, acc_new, i * blk, axis=2)
        m = jax.lax.dynamic_update_slice_in_dim(m, m_new, i * blk, axis=2)
        den = jax.lax.dynamic_update_slice_in_dim(den, l_new, i * blk, axis=2)
        return (acc, m, den), None

    (acc, m, den), _ = jax.lax.scan(body, (acc0, m0, l0), (pi, pj))
    out = acc / jnp.maximum(den, 1e-20)
    lse = jnp.where(den > 0, m + jnp.log(jnp.maximum(den, 1e-20)), _NEG_INF)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash_core(cfg, q, k, v):
    out, _ = _flash_fwd_impl(cfg, q, k, v)
    return out


def _flash_core_fwd(cfg, q, k, v):
    out, lse = _flash_fwd_impl(cfg, q, k, v)
    return out, (q, k, v, out, lse)


def _flash_core_bwd(cfg, res, dout):
    """FA2-style backward: recompute p per block pair from (q, k, lse) —
    residuals are O(S*Dh), never the (S, S) score matrix.  This is the
    memory-roofline-critical path for every 4k/32k train/prefill cell."""
    causal, window, blk, pairs, scale = cfg
    q, k, v, out, lse = res
    b, hq, s, dh = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    dout = dout.astype(jnp.float32)
    # D_i = rowsum(dO * O)  (B,Hq,S,1)
    dvec = jnp.sum(dout * out, axis=-1, keepdims=True)
    pi = jnp.asarray([p[0] for p in pairs], jnp.int32)
    pj = jnp.asarray([p[1] for p in pairs], jnp.int32)

    dq0 = jnp.zeros((b, hq, s, dh), jnp.float32)
    dk0 = jnp.zeros((b, hkv, k.shape[2], dh), jnp.float32)
    dv0 = jnp.zeros_like(dk0)

    def body(carry, ij):
        dq, dk, dv = carry
        i, j = ij
        qi = jax.lax.dynamic_slice_in_dim(q, i * blk, blk, axis=2)
        kj = jax.lax.dynamic_slice_in_dim(k, j * blk, blk, axis=2)
        vj = jax.lax.dynamic_slice_in_dim(v, j * blk, blk, axis=2)
        lsei = jax.lax.dynamic_slice_in_dim(lse, i * blk, blk, axis=2)
        di = jax.lax.dynamic_slice_in_dim(dvec, i * blk, blk, axis=2)
        doi = jax.lax.dynamic_slice_in_dim(dout, i * blk, blk, axis=2)

        qi_g = (qi * scale).reshape(b, hkv, g, blk, dh)
        scores = jnp.einsum(
            "bhgqd,bhkd->bhgqk", qi_g, kj, preferred_element_type=jnp.float32
        ).reshape(b, hq, blk, blk)
        mask = _pair_mask(i, j, blk, causal, window)
        scores = jnp.where(mask[None, None], scores, _NEG_INF)  # single mask pass
        safe_lse = jnp.where(lsei <= _NEG_INF / 2, 0.0, lsei)
        p = jnp.exp(scores - safe_lse)  # masked -> exp underflow -> exactly 0

        doi_g = doi.reshape(b, hkv, g, blk, dh)
        p_g = p.reshape(b, hkv, g, blk, blk)
        # dV_j += P^T dO   (sum over q block and group)
        dvj = jnp.einsum("bhgqk,bhgqd->bhkd", p_g, doi_g)
        # dP = dO V^T
        dp = jnp.einsum("bhgqd,bhkd->bhgqk", doi_g, vj.astype(jnp.float32))
        ds = p_g * (dp - di.reshape(b, hkv, g, blk, 1))
        # dQ_i += dS K * scale ; dK_j += dS^T Q * scale
        dqi = jnp.einsum("bhgqk,bhkd->bhgqd", ds, kj.astype(jnp.float32)) * scale
        dkj = jnp.einsum("bhgqk,bhgqd->bhkd", ds, qi.reshape(b, hkv, g, blk, dh).astype(jnp.float32)) * scale

        upd_q = jax.lax.dynamic_slice_in_dim(dq, i * blk, blk, axis=2) + dqi.reshape(b, hq, blk, dh)
        dq = jax.lax.dynamic_update_slice_in_dim(dq, upd_q, i * blk, axis=2)
        upd_k = jax.lax.dynamic_slice_in_dim(dk, j * blk, blk, axis=2) + dkj
        dk = jax.lax.dynamic_update_slice_in_dim(dk, upd_k, j * blk, axis=2)
        upd_v = jax.lax.dynamic_slice_in_dim(dv, j * blk, blk, axis=2) + dvj
        dv = jax.lax.dynamic_update_slice_in_dim(dv, upd_v, j * blk, axis=2)
        return (dq, dk, dv), None

    (dq, dk, dv), _ = jax.lax.scan(body, (dq0, dk0, dv0), (pi, pj))
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block: int = 512,
    scale: float | None = None,
) -> jax.Array:
    """q: (B, S, Hq, Dh), k/v: (B, Skv, Hkv, Dh) -> (B, S, Hq, Dh).

    Blockwise online-softmax attention over a STATIC list of (q, kv) block
    pairs (causal/window pairs enumerated at trace time: exact FLOPs, no
    masked-dense waste) with a hand-written FA2-style custom_vjp backward
    (residuals O(S*Dh); p recomputed per pair — never an (S,S) buffer).
    """
    b, s, hq, dh = q.shape
    sk = k.shape[1]
    hkv = k.shape[2]
    assert hq % hkv == 0
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    if causal and s != sk:
        raise ValueError("causal flash attention requires q_len == kv_len")
    blk, pairs = _flash_geometry(s, sk, causal, window, block)
    cfg = (causal, window, blk, tuple(pairs), scale)
    qh = q.transpose(0, 2, 1, 3)  # (B,Hq,S,Dh)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    out = _flash_core(cfg, qh, kh, vh)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    *,
    cache_len: jax.Array | int,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Single-position attention against a cache.

    q: (B, 1, Hq, Dh); k/v_cache: (B, S, Hkv, Dh); positions >= cache_len
    are masked.  Returns (B, 1, Hq, Dh).
    """
    b, s, hkv, dh = k_cache.shape
    hq = q.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    qg = (q[:, 0] * scale).reshape(b, hkv, g, dh)
    scores = jnp.einsum(
        "bhgd,bshd->bhgs", qg, k_cache, preferred_element_type=jnp.float32
    )
    pos = jnp.arange(s)
    valid = pos[None] < jnp.asarray(cache_len).reshape(-1, 1)  # (B, S) or (1, S)
    if window is not None:
        valid = valid & (pos[None] >= jnp.asarray(cache_len).reshape(-1, 1) - window)
    scores = jnp.where(valid[:, None, None], scores, _NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, hq, dh).astype(q.dtype)


def decode_attention_quant(
    q: jax.Array,
    cache: "QuantKVCache",
    *,
    cache_len: jax.Array | int,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Decode attention over an int8 cache — scales factor OUT of both
    contractions (exact algebra, no dequantised cache copy):

        scores[s] = (q . k_q[s]) * ks[s]          (per-token-head scale)
        out[d]    = sum_s (p[s] * vs[s]) * v_q[s,d]
    """
    b, s, hkv, dh = cache.k.shape
    hq = q.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    qg = (q[:, 0].astype(jnp.float32) * scale).reshape(b, hkv, g, dh)
    scores = jnp.einsum(
        "bhgd,bshd->bhgs", qg, cache.k.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    scores = scores * cache.ks[..., 0].transpose(0, 2, 1)[:, :, None, :]  # (B,Hkv,1,S)
    pos = jnp.arange(s)
    valid = pos[None] < jnp.asarray(cache_len).reshape(-1, 1)
    if window is not None:
        valid = valid & (pos[None] >= jnp.asarray(cache_len).reshape(-1, 1) - window)
    scores = jnp.where(valid[:, None, None], scores, _NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)  # (B,Hkv,g,S)
    p_scaled = p * cache.vs[..., 0].transpose(0, 2, 1)[:, :, None, :]
    out = jnp.einsum(
        "bhgs,bshd->bhgd", p_scaled.astype(jnp.float32), cache.v.astype(jnp.float32)
    )
    return out.reshape(b, 1, hq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (params + apply for train/prefill/decode)
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array  # (B, S_max, Hkv, Dh)
    v: jax.Array


class QuantKVCache(NamedTuple):
    """Int8 KV cache (paper C1 transplanted: shrink the temporaries'
    bit-width to cut the memory-bound decode's cache traffic ~2x).

    Per-(token, head) symmetric scales; dequantisation happens inside the
    attention reads, so HBM only ever sees int8 values + tiny scales."""

    k: jax.Array  # (B, S_max, Hkv, Dh) int8
    v: jax.Array  # int8
    ks: jax.Array  # (B, S_max, Hkv, 1) f32
    vs: jax.Array


def quantize_kv(x: jax.Array):
    """(B, S, H, D) float -> (int8 values, f32 per-(token,head) scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    rope_theta: float = 10000.0
    window: int | None = None  # sliding window (None = global)
    causal: bool = True
    use_bias: bool = False
    qk_norm: bool = False


def attn_init(key, cfg: AttnConfig, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p = {
        "wq": nn.linear_init(kq, d, h * dh, bias=cfg.use_bias, dtype=dtype),
        "wk": nn.linear_init(kk, d, hk * dh, bias=cfg.use_bias, dtype=dtype),
        "wv": nn.linear_init(kv, d, hk * dh, bias=cfg.use_bias, dtype=dtype),
        "wo": nn.linear_init(ko, h * dh, d, bias=cfg.use_bias, dtype=dtype),
    }
    if cfg.qk_norm:
        p["qnorm"] = rmsnorm_init(dh, dtype)
        p["knorm"] = rmsnorm_init(dh, dtype)
    return p


def attn_apply(
    p,
    cfg: AttnConfig,
    x: jax.Array,
    *,
    positions: jax.Array,
    cache: KVCache | None = None,
    write_idx: jax.Array | int | None = None,
    attend_len: jax.Array | int | None = None,
    kv_override: tuple[jax.Array, jax.Array] | None = None,
    collect_kv: bool = False,
    decode_window: int | None = None,
    attn_block: int = 512,
    policy=None,
):
    """x: (B, S, D).  Train/prefill when cache is None; decode (S==1) writes
    new K/V at `write_idx` and attends over `attend_len` entries (rolling
    local-window caches pass write_idx = pos % window, attend_len =
    min(pos+1, window), decode_window=None since the buffer is pre-bounded).
    kv_override supplies cross-attention K/V source.
    Returns (out (B,S,D), aux) — aux is the new KVCache in decode, the fresh
    (k, v) when collect_kv, else None."""
    b, s, _ = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = nn.linear(p["wq"], x, policy=policy).reshape(b, s, h, dh)
    if kv_override is None:
        k = nn.linear(p["wk"], x, policy=policy).reshape(b, s, hk, dh)
        v = nn.linear(p["wv"], x, policy=policy).reshape(b, s, hk, dh)
    else:
        xkv = kv_override[0]
        sk = xkv.shape[1]
        k = nn.linear(p["wk"], xkv, policy=policy).reshape(b, sk, hk, dh)
        v = nn.linear(p["wv"], xkv, policy=policy).reshape(b, sk, hk, dh)
    if cfg.qk_norm:
        q = rmsnorm(p["qnorm"], q)
        k = rmsnorm(p["knorm"], k)
    if cfg.rope_theta > 0 and kv_override is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    aux = None
    if cache is not None and kv_override is None:
        # decode: write the new K/V, attend over the valid prefix
        idx = jnp.asarray(write_idx, jnp.int32).reshape(())
        if isinstance(cache, QuantKVCache):
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            ck = jax.lax.dynamic_update_slice_in_dim(cache.k, kq, idx, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache.v, vq, idx, axis=1)
            cks = jax.lax.dynamic_update_slice_in_dim(cache.ks, ks, idx, axis=1)
            cvs = jax.lax.dynamic_update_slice_in_dim(cache.vs, vs, idx, axis=1)
            aux = QuantKVCache(ck, cv, cks, cvs)
            out = decode_attention_quant(
                q, aux, cache_len=attend_len, window=decode_window
            )
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), idx, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), idx, axis=1)
            aux = KVCache(ck, cv)
            out = decode_attention(q, ck, cv, cache_len=attend_len, window=decode_window)
    elif kv_override is not None and s == 1:
        out = decode_attention(q, k, v, cache_len=k.shape[1], window=None)
    elif kv_override is not None:
        out = flash_attention(q, k, v, causal=False, window=None, block=attn_block)
    else:
        out = flash_attention(
            q, k, v, causal=cfg.causal, window=cfg.window, block=attn_block
        )
        if collect_kv:
            aux = (k, v)
    out = nn.linear(p["wo"], out.reshape(b, s, h * dh), policy=policy)
    return out, aux


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def glu_mlp_init(key, d_model: int, d_ff: int, *, bias: bool = False, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": nn.linear_init(k1, d_model, d_ff, bias=bias, dtype=dtype),
        "wg": nn.linear_init(k2, d_model, d_ff, bias=bias, dtype=dtype),
        "wo": nn.linear_init(k3, d_ff, d_model, bias=bias, dtype=dtype),
    }


def glu_mlp_apply(p, x: jax.Array, act: str = "silu", policy=None) -> jax.Array:
    a = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[act]
    return nn.linear(
        p["wo"],
        a(nn.linear(p["wg"], x, policy=policy)) * nn.linear(p["wi"], x, policy=policy),
        policy=policy,
    )


def dense_mlp_init(key, d_model: int, d_ff: int, *, bias: bool = True, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {
        "wi": nn.linear_init(k1, d_model, d_ff, bias=bias, dtype=dtype),
        "wo": nn.linear_init(k2, d_ff, d_model, bias=bias, dtype=dtype),
    }


def dense_mlp_apply(p, x: jax.Array, act: str = "gelu", policy=None) -> jax.Array:
    a = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[act]
    return nn.linear(p["wo"], a(nn.linear(p["wi"], x, policy=policy)), policy=policy)
