"""PointNet++ (PointNet2) — the paper's evaluation model — with PC2IM preprocessing.

Set-abstraction (SA) stages: sample centroids (FPS), query neighbours, learn
per-point features (MLP), max-pool per neighbourhood.  Feature-propagation
(FP) stages (segmentation): 3-NN inverse-distance interpolation + unit MLPs.

PC2IM switches, all config-selectable (benchmarked in fig12a/fig13):
  preproc    : "baseline1" (global L2 FPS + ball)  |  "baseline2" (grid tiles)
               | "pc2im" (MSP + L1 FPS + lattice query)
  aggregation: "standard" (group->mlp->pool) | "delayed" (mlp->group->pool, C5)
  quant      : "none" | "sc_w16a16" (C4; applies to every MLP linear via the
               ExecutionPolicy threaded through forward — see core/policy.py)

Note on delayed aggregation: standard SA feeds the MLP relative coordinates
(neighbour - centroid), which cannot be precomputed per point.  Following
Mesorasi [8] (which the paper adopts), the delayed path feeds *absolute*
coords + features through the per-point MLP and aggregates afterwards.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import grouping as G
from repro.core import query as Q
from repro.core.engine import EngineConfig, clamp_depth, get_engine
from repro.core.policy import ExecutionPolicy, resolve_policy
from repro.models import nn


@dataclasses.dataclass(frozen=True)
class SAConfig:
    n_centroids: int
    radius: float
    nsample: int
    mlp: tuple[int, ...]  # hidden/out channels (input inferred)


@dataclasses.dataclass(frozen=True)
class PointNet2Config:
    name: str = "pointnet2"
    task: Literal["cls", "seg"] = "cls"
    n_points: int = 1024
    n_classes: int = 8
    in_features: int = 0  # extra per-point features beyond xyz
    sa: tuple[SAConfig, ...] = (
        SAConfig(256, 0.2, 32, (64, 64, 128)),
        SAConfig(64, 0.4, 32, (128, 128, 256)),
    )
    global_mlp: tuple[int, ...] = (256, 512, 1024)  # final global SA (cls)
    fp_mlp: tuple[int, ...] = (256, 128)  # per-FP-stage out channels (seg)
    head: tuple[int, ...] = (512, 256)
    preproc: Literal["baseline1", "baseline2", "pc2im"] = "pc2im"
    aggregation: Literal["standard", "delayed"] = "delayed"
    quant: Literal["none", "sc_w16a16", "sc_w8a8"] = "none"
    msp_depth: int = 2  # MSP tiles = 2^depth (pc2im preproc)
    preproc_backend: str = "auto"  # kernel registry backend for preprocessing

    @property
    def family(self) -> str:
        return "pointcloud"


def init_params(key, cfg: PointNet2Config):
    keys = iter(jax.random.split(key, 64))
    params: dict = {"sa": []}
    c_in = 3 + cfg.in_features
    for sa in cfg.sa:
        chans = [c_in] + list(sa.mlp)
        params["sa"].append(nn.mlp_init(next(keys), chans))
        c_in = sa.mlp[-1] + 3  # next stage consumes features + xyz
    sa_out = cfg.sa[-1].mlp[-1]

    if cfg.task == "cls":
        params["global"] = nn.mlp_init(next(keys), [sa_out + 3] + list(cfg.global_mlp))
        h = [cfg.global_mlp[-1]] + list(cfg.head) + [cfg.n_classes]
        params["head"] = nn.mlp_init(next(keys), h, norm=False)
    else:
        # FP stages walk back up the SA pyramid
        params["fp"] = []
        skips = [3 + cfg.in_features] + [sa.mlp[-1] for sa in cfg.sa[:-1]]
        c_coarse = sa_out
        for i, skip_c in enumerate(reversed(skips)):
            cout = cfg.fp_mlp[min(i, len(cfg.fp_mlp) - 1)]
            params["fp"].append(nn.mlp_init(next(keys), [c_coarse + skip_c, cout, cout]))
            c_coarse = cout
        h = [c_coarse] + list(cfg.head) + [cfg.n_classes]
        params["head"] = nn.mlp_init(next(keys), h, norm=False)
    return params


def stage_engine(
    cfg: PointNet2Config, sa: SAConfig, n_points: int,
    policy: ExecutionPolicy | None = None,
):
    """Batched PreprocessEngine for one SA stage (cached per distinct config).

    The policy's backend/interpret flags participate in the engine identity,
    so preprocessing and the SC feature path always run under the SAME
    backend decision (the old API let them drift apart).  A policy with
    backend=None defers to the config's pinned preproc_backend."""
    policy = resolve_policy(cfg, policy)
    backend = policy.backend
    if cfg.preproc == "pc2im":
        ec = EngineConfig(
            pipeline="pc2im",
            n_centroids=sa.n_centroids,
            radius=sa.radius,
            nsample=sa.nsample,
            depth=clamp_depth(n_points, sa.n_centroids, cfg.msp_depth),
            backend=backend,
            interpret=policy.interpret,
        )
    else:
        ec = EngineConfig(
            pipeline=cfg.preproc,
            n_centroids=sa.n_centroids,
            radius=sa.radius,
            nsample=sa.nsample,
            backend=backend,
            interpret=policy.interpret,
        )
    return get_engine(ec)


def preprocess_stage(
    cfg: PointNet2Config, points: jax.Array,
    policy: ExecutionPolicy | None = None,
) -> tuple:
    """Params-free preprocessing half: points (B, N, 3+F) -> per-SA results.

    The whole preprocessing chain — MSP partition, FPS, lattice/ball query,
    stage after stage — consumes only coordinates: stage i samples from
    stage i-1's *centroid_xyz*, never from learned features.  That is the
    paper's decoupling (and Mesorasi's delayed-aggregation observation)
    made explicit: this function is the "preprocess sub-artifact" the
    pipelined accelerator runs for micro-batch k+1 while micro-batch k is
    still inside the feature MLPs.  Returns one PreprocessResult per SA
    stage; feed them to `feature_stage` to finish the forward pass.
    """
    policy = resolve_policy(cfg, policy)
    xyz = points[..., :3]
    # under an enclosing jit (the accelerator's sub-artifact), the raw engine
    # pipelines trace into ONE jaxpr; eager callers (e.g. un-jitted loss_fn
    # under jax.grad-less loops) keep each stage's own compiled engine
    traced = isinstance(xyz, jax.core.Tracer)
    results = []
    for sa_cfg in cfg.sa:
        engine = stage_engine(cfg, sa_cfg, xyz.shape[-2], policy)
        res = engine.raw(xyz) if traced else engine(xyz)
        results.append(res)
        xyz = res.centroid_xyz
    return tuple(results)


def feature_stage(
    params, cfg: PointNet2Config, points: jax.Array, preproc: tuple,
    policy: ExecutionPolicy | None = None,
) -> jax.Array:
    """Feature half: per-point MLPs + aggregation over precomputed neighborhoods.

    `preproc` is `preprocess_stage`'s output (one PreprocessResult per SA
    stage).  Composing the two stages is bitwise-identical to the fused
    forward — `forward` IS this composition — which is what lets the
    pipelined executor overlap the halves of consecutive micro-batches
    without changing a single output bit (pinned by
    tests/test_pipelined_accelerator.py).
    """
    policy = resolve_policy(cfg, policy)
    xyz = points[..., :3]
    feats = points[..., 3:] if cfg.in_features else None

    levels = [(xyz, feats)]
    for sa_cfg, mlp_p, res in zip(cfg.sa, params["sa"], preproc):
        xyz_i, feats_i = levels[-1]
        levels.append(_sa_stage(cfg, sa_cfg, mlp_p, xyz_i, feats_i, policy, res=res))

    if cfg.task == "cls":
        xyz_l, feats_l = levels[-1]
        x = jnp.concatenate([xyz_l, feats_l], axis=-1)  # (B, M, C)
        x = nn.mlp_apply(params["global"], x, policy=policy)
        x = jnp.max(x, axis=1)  # global max pool per cloud
        return nn.mlp_apply(params["head"], x, final_act=False, policy=policy)

    # segmentation: FP stages walk the pyramid back from coarse to fine.
    # Skip channels (mirrors init_params): intermediate levels contribute
    # their SA features; the finest level contributes raw xyz(+input feats).
    coarse_xyz, coarse_f = levels[-1]
    n_fp = len(params["fp"])
    for i, fp_p in enumerate(params["fp"]):
        fine_xyz, fine_f = levels[n_fp - 1 - i]
        idx, dist = jax.vmap(lambda q, r: Q.knn(q, r, 3))(fine_xyz, coarse_xyz)
        w = Q.three_nn_interpolate_weights(dist)
        interp = jax.vmap(G.interpolate_features)(coarse_f, idx, w)  # (B, Nf, Cc)
        if i == n_fp - 1:  # finest level: raw inputs as skip
            skip = fine_xyz if fine_f is None else jnp.concatenate([fine_xyz, fine_f], -1)
        else:
            skip = fine_f
        x = jnp.concatenate([interp, skip], axis=-1)
        coarse_f = nn.mlp_apply(fp_p, x, policy=policy)
        coarse_xyz = fine_xyz
    return nn.mlp_apply(params["head"], coarse_f, final_act=False, policy=policy)


def _sa_stage(cfg, sa_cfg, mlp_params, xyz, feats, policy, res=None):
    """One BATCHED set-abstraction stage.  xyz (B, N, 3), feats (B, N, C)|None.

    Preprocessing runs through the PreprocessEngine (batch and MSP tiles fold
    into one kernel grid); the per-point MLP applies batch-wide (it is
    leading-dim agnostic); only the index gathers vmap over clouds.  Passing
    a precomputed `res` (from `preprocess_stage`) skips the engine call —
    the feature-stage sub-artifact consumes neighborhoods computed earlier.
    """
    if res is None:
        res = stage_engine(cfg, sa_cfg, xyz.shape[-2], policy)(xyz)
    nbrs = res.neighbors
    if cfg.aggregation == "delayed":
        # C5: per-POINT mlp on [abs-xyz, feats], then gather + masked maxpool
        x = xyz if feats is None else jnp.concatenate([xyz, feats], axis=-1)
        pointwise = nn.mlp_apply(mlp_params, x, policy=policy)  # (B, N, C')
        grouped = jax.vmap(G.group_features)(pointwise, nbrs)  # (B, M, S, C')
        new_feats = G.masked_maxpool(grouped, nbrs.mask)
    else:
        rel = jax.vmap(G.group_relative_coords)(xyz, res.centroid_xyz, nbrs)
        if feats is None:
            grouped = rel
        else:
            gf = jax.vmap(G.group_features)(feats, nbrs)  # (B, M, S, C)
            grouped = jnp.concatenate([rel, gf], axis=-1)
        new_feats = G.masked_maxpool(
            nn.mlp_apply(mlp_params, grouped, policy=policy), nbrs.mask
        )
    return res.centroid_xyz, new_feats


def forward(
    params, cfg: PointNet2Config, points: jax.Array,
    policy: ExecutionPolicy | None = None,
) -> jax.Array:
    """Batched forward.  points: (B, N, 3+F) -> (B, C) or (B, N, C).

    policy=None derives the config's default ExecutionPolicy; pass one
    explicitly (or use core.accelerator.PC2IMAccelerator) to select the
    quant mode / kernel backend without touching the config.  Resolution
    happens HERE, once: a backend=None policy picks up the config's pinned
    backend for the preprocessing engines AND the SC feature path."""
    policy = resolve_policy(cfg, policy)
    return _forward_batched(params, cfg, points, policy)


def _forward_batched(params, cfg: PointNet2Config, points: jax.Array, policy):
    """points: (B, N, 3 + in_features) -> logits (cls: (B,C), seg: (B,N,C)).

    Literally the composition of the two stage functions — the sequential
    path and the pipelined path run the SAME code, so their bitwise
    equality is true by construction, not by accident of XLA scheduling.
    """
    return feature_stage(
        params, cfg, points, preprocess_stage(cfg, points, policy), policy
    )


def loss_fn(
    params, cfg: PointNet2Config, points: jax.Array, labels: jax.Array,
    policy: ExecutionPolicy | None = None,
):
    logits = forward(params, cfg, points, policy=policy)
    logp = jax.nn.log_softmax(logits, axis=-1)
    if cfg.task == "cls":
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    else:
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1).mean()
    acc = (jnp.argmax(logits, -1) == labels).mean()
    return nll, {"loss": nll, "accuracy": acc}
