"""Decoder-only transformer LM — the dense backbone for 7 of the 10 assigned archs.

Compile-efficiency design (1-core CPU dry-runs of 104B-scale models):
  * layers execute as a lax.scan over GROUPS of `len(cfg.layer_pattern)`
    layers; params are stacked (n_groups, ...) — HLO contains ONE group body
    regardless of depth (command-r's 64 layers lower as an 8-line scan).
  * mixed local/global patterns (gemma3 5:1) unroll INSIDE the group body,
    so each slot's sliding-window block-pair set stays static (exact FLOPs).
  * cross-entropy is seq-chunked + vocab-parallel (never materialises the
    full (B, S, V) logits — command-r train_4k would need 1M x 256k x 4B).

Decode: per-slot KV caches stacked as (n_groups, B, S_max, Hkv, Dh), carried
through the group scan as xs/ys.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import ExecutionPolicy, resolve_policy
from repro.models import nn
from repro.models.layers import (
    AttnConfig,
    KVCache,
    QuantKVCache,
    quantize_kv,
    attn_apply,
    attn_init,
    dense_mlp_apply,
    dense_mlp_init,
    glu_mlp_apply,
    glu_mlp_init,
    rmsnorm,
    rmsnorm_init,
)
from repro.models.moe import moe_init, moe_apply
from repro.sharding.hints import hint_residual


# ---------------------------------------------------------------------------
# Param construction
# ---------------------------------------------------------------------------

def _norm_init(cfg: ModelConfig, d: int, dtype):
    if cfg.norm_kind == "ln":
        return nn.layernorm_init(d, dtype)
    return rmsnorm_init(d, dtype)


def _norm_apply(cfg: ModelConfig, p, x):
    if cfg.norm_kind == "ln":
        return nn.layernorm(p, x)
    return rmsnorm(p, x)


def attn_cfg_for(cfg: ModelConfig, slot_type: str, causal: bool = True) -> AttnConfig:
    return AttnConfig(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        d_head=cfg.head_dim,
        rope_theta=cfg.rope_theta,
        window=cfg.window if slot_type == "local" else None,
        causal=causal,
        use_bias=cfg.use_bias,
    )


def _mlp_init(cfg: ModelConfig, key, dtype):
    if cfg.family == "moe":
        return moe_init(key, cfg, dtype)
    if cfg.mlp_kind == "glu":
        return glu_mlp_init(key, cfg.d_model, cfg.d_ff, bias=cfg.use_bias, dtype=dtype)
    return dense_mlp_init(key, cfg.d_model, cfg.d_ff, bias=cfg.use_bias, dtype=dtype)


def _mlp_apply(cfg: ModelConfig, p, x, policy: ExecutionPolicy | None = None):
    if cfg.family == "moe":
        return moe_apply(p, cfg, x, policy=policy)
    if cfg.mlp_kind == "glu":
        return glu_mlp_apply(p, x, act=cfg.act, policy=policy)
    return dense_mlp_apply(p, x, act=cfg.act, policy=policy)


def _slot_init(cfg: ModelConfig, key, slot_type: str, dtype):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": _norm_init(cfg, cfg.d_model, dtype),
        "attn": attn_init(k1, attn_cfg_for(cfg, slot_type), dtype),
        "ln2": _norm_init(cfg, cfg.d_model, dtype),
        "mlp": _mlp_init(cfg, k2, dtype),
    }
    return p


def group_geometry(cfg: ModelConfig) -> tuple[int, int]:
    g = len(cfg.layer_pattern)
    if cfg.n_layers % g:
        raise ValueError(f"{cfg.name}: n_layers={cfg.n_layers} not divisible by pattern {g}")
    return cfg.n_layers // g, g


def init_lm(key, cfg: ModelConfig):
    dtype = cfg.dtype
    n_groups, g = group_geometry(cfg)
    keys = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype),
        "final_norm": _norm_init(cfg, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[1], (cfg.d_model, cfg.vocab_size)) / jnp.sqrt(cfg.d_model)
        ).astype(dtype)

    # stacked per-slot params: vmap init over groups
    slot_params = []
    for s, slot_type in enumerate(cfg.layer_pattern):
        gkeys = jax.random.split(jax.random.fold_in(keys[2], s), n_groups)
        slot_params.append(jax.vmap(lambda k: _slot_init(cfg, k, slot_type, dtype))(gkeys))
    params["blocks"] = slot_params
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _block_apply(
    cfg, slot_type, p, h, *, positions,
    cache=None, write_idx=None, attend_len=None, decode_window=None, collect_kv=False,
    policy: ExecutionPolicy | None = None,
):
    a, aux = attn_apply(
        p["attn"],
        attn_cfg_for(cfg, slot_type),
        _norm_apply(cfg, p["ln1"], h),
        positions=positions,
        cache=cache,
        write_idx=write_idx,
        attend_len=attend_len,
        decode_window=decode_window,
        collect_kv=collect_kv,
        attn_block=cfg.attn_block,
        policy=policy,
    )
    # constrain the row-parallel partial-sum OUTPUTS to the seq-sharded
    # layout: GSPMD emits reduce-scatter instead of all-reduce (half the
    # collective volume — §Perf cell-A iteration 4)
    h = h + hint_residual(a)
    h = h + hint_residual(
        _mlp_apply(cfg, p["mlp"], _norm_apply(cfg, p["ln2"], h), policy=policy)
    )
    return h, aux


def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    policy = None
    if cfg.remat == "block":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint(fn, policy=policy)


def backbone(
    params, cfg: ModelConfig, h: jax.Array, positions: jax.Array,
    policy: ExecutionPolicy | None = None,
) -> jax.Array:
    """Run the layer stack (train/prefill without cache).  h: (B, S, D)."""

    def group_body(hh, group_params):
        for s, slot_type in enumerate(cfg.layer_pattern):
            hh, _ = _block_apply(
                cfg, slot_type, group_params[s], hh, positions=positions, policy=policy
            )
            hh = hint_residual(hh)
        return hh, None

    h, _ = jax.lax.scan(_maybe_remat(cfg, group_body), h, tuple(params["blocks"]))
    return _norm_apply(cfg, params["final_norm"], h)


def embed_tokens(params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["embed"], tokens, axis=0)


def lm_head_weights(params, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def chunked_cross_entropy(
    h: jax.Array, w_out: jax.Array, labels: jax.Array, *, chunk: int, mask: jax.Array | None = None
):
    """Seq-chunked CE.  h: (B, S, D), w_out: (D, V), labels: (B, S) -> scalar.

    Never materialises (B, S, V); per chunk the (B, c, V) logits live briefly
    (vocab stays shardable over 'model', giving vocab-parallel CE with one
    small collective per chunk)."""
    b, s, d = h.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    n_chunks = s // chunk
    hc = h.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    mc = mask.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xs):
        nll_sum, cnt = carry
        hh, ll, mm = xs
        logits = (hh @ w_out).astype(jnp.float32)  # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mm
        return (nll_sum + nll.sum(), cnt + mm.sum()), None

    (nll_sum, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (hc, lc, mc))
    return nll_sum / jnp.maximum(cnt, 1.0)


def lm_loss(
    params, cfg: ModelConfig, batch: dict, policy: ExecutionPolicy | None = None
) -> tuple[jax.Array, dict]:
    """batch: {tokens (B,S), labels (B,S)} -> (loss, metrics)."""
    policy = resolve_policy(cfg, policy)
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = embed_tokens(params, cfg, tokens)
    h = backbone(params, cfg, h, jnp.arange(s)[None, :], policy=policy)
    loss = chunked_cross_entropy(
        h, lm_head_weights(params, cfg), batch["labels"], chunk=cfg.loss_chunk
    )
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# Serving: prefill + decode with stacked caches
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    caches: Any  # tuple per slot: KVCache with (n_groups, B, S_max, Hkv, Dh)
    cache_len: jax.Array  # scalar int32


def init_decode_state(cfg: ModelConfig, batch: int, s_max: int) -> DecodeState:
    n_groups, _ = group_geometry(cfg)
    dtype = cfg.dtype
    caches = []
    for slot_type in cfg.layer_pattern:
        s_eff = min(s_max, cfg.window) if (slot_type == "local" and cfg.window) else s_max
        shape = (n_groups, batch, s_eff, cfg.n_kv_heads, cfg.head_dim)
        if cfg.kv_quant == "int8":
            sshape = shape[:-1] + (1,)
            caches.append(QuantKVCache(
                jnp.zeros(shape, jnp.int8), jnp.zeros(shape, jnp.int8),
                jnp.zeros(sshape, jnp.float32), jnp.zeros(sshape, jnp.float32),
            ))
        else:
            caches.append(KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)))
    return DecodeState(caches=tuple(caches), cache_len=jnp.zeros((), jnp.int32))


def prefill(
    params, cfg: ModelConfig, tokens: jax.Array, s_max: int | None = None,
    policy: ExecutionPolicy | None = None,
):
    """Prefill: run the stack, return (last-position logits, DecodeState)."""
    policy = resolve_policy(cfg, policy)
    b, s = tokens.shape
    s_max = s_max or s
    positions = jnp.arange(s)[None, :]
    h = embed_tokens(params, cfg, tokens)

    def group_body(hh, group_params):
        kvs = []
        for slot, slot_type in enumerate(cfg.layer_pattern):
            hh, kv = _block_apply(
                cfg, slot_type, group_params[slot], hh,
                positions=positions, collect_kv=True, policy=policy,
            )
            hh = hint_residual(hh)
            kvs.append(KVCache(*kv))
        return hh, tuple(kvs)

    h, kv_stacked = jax.lax.scan(_maybe_remat(cfg, group_body), h, tuple(params["blocks"]))
    h = _norm_apply(cfg, params["final_norm"], h)
    logits = (h[:, -1:] @ lm_head_weights(params, cfg)).astype(jnp.float32)

    # pad caches out to s_max; rolling local windows keep the last `window`
    # entries, rolled so position p sits at slot p % s_eff (decode invariant)
    caches = []
    for slot, slot_type in enumerate(cfg.layer_pattern):
        k, v = kv_stacked[slot]
        s_eff = min(s_max, cfg.window) if (slot_type == "local" and cfg.window) else s_max
        if s_eff > s:
            pad = [(0, 0), (0, 0), (0, s_eff - s), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        elif s_eff < s:
            k, v = k[:, :, -s_eff:], v[:, :, -s_eff:]
            shift = s % s_eff
            if shift:
                k, v = jnp.roll(k, shift, axis=2), jnp.roll(v, shift, axis=2)
        if cfg.kv_quant == "int8":
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            caches.append(QuantKVCache(kq, vq, ks, vs))
        else:
            caches.append(KVCache(k, v))
    return logits, DecodeState(caches=tuple(caches), cache_len=jnp.full((), s, jnp.int32))


def decode_step(
    params, cfg: ModelConfig, state: DecodeState, token: jax.Array,
    policy: ExecutionPolicy | None = None,
):
    """One decode step.  token: (B, 1) int32 -> (logits (B,1,V) f32, new state)."""
    policy = resolve_policy(cfg, policy)
    pos = state.cache_len.reshape(1, 1).astype(jnp.int32)
    h = embed_tokens(params, cfg, token)

    def group_body(hh, xs):
        group_params = xs[0]
        caches = xs[1:]
        new_caches = []
        cl = state.cache_len
        for slot, slot_type in enumerate(cfg.layer_pattern):
            cache = caches[slot]
            if slot_type == "local" and cfg.window:
                # rolling window buffer: write at pos % w; all min(pos+1, w)
                # entries valid (window bound enforced by buffer size)
                s_eff = cache.k.shape[1]
                hh, nc = _block_apply(
                    cfg, slot_type, group_params[slot], hh, positions=pos,
                    cache=cache, write_idx=jnp.mod(cl, s_eff),
                    attend_len=jnp.minimum(cl + 1, s_eff), decode_window=None,
                    policy=policy,
                )
            else:
                hh, nc = _block_apply(
                    cfg, slot_type, group_params[slot], hh, positions=pos,
                    cache=cache, write_idx=cl, attend_len=cl + 1, policy=policy,
                )
            new_caches.append(nc)
        return hh, tuple(new_caches)

    h, new_caches = jax.lax.scan(
        group_body, h, (tuple(params["blocks"]), *state.caches)
    )
    h = _norm_apply(cfg, params["final_norm"], h)
    logits = (h @ lm_head_weights(params, cfg)).astype(jnp.float32)
    return logits, DecodeState(caches=tuple(new_caches), cache_len=state.cache_len + 1)
