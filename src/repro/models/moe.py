"""Top-k routed mixture-of-experts FFN (dbrx 16e/top-4, granite 40e/top-8).

Dispatch is capacity-based gather/scatter (GShard-style semantics without the
giant one-hot dispatch einsum):

  router logits -> top-k experts per token -> per-(expert, k-slot) priority
  rank via cumsum -> tokens beyond capacity C = ceil(T*k/E * cf) are DROPPED
  (standard capacity overflow) -> gather (E, C, d) -> batched expert GLU
  (einsum over stacked (E, d, ff) weights) -> weighted scatter-add back.

Sharding: tokens arrive (B, S, d) sharded batch-over-'data'; expert weights
(E, d, ff) shard E over 'model' -> the gather/scatter becomes an all-to-all
over the mesh (visible in the §Roofline collective term — MoE cells are the
collective-bound candidates).  Router compute stays replicated-small.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import nn


def moe_init(key, cfg, dtype=jnp.float32):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    kr, ki, kg, ko = jax.random.split(key, 4)
    s_in = 1.0 / jnp.sqrt(d)
    s_out = 1.0 / jnp.sqrt(f)
    return {
        "router": nn.linear_init(kr, d, e, bias=False, dtype=jnp.float32),
        "wi": (jax.random.normal(ki, (e, d, f)) * s_in).astype(dtype),
        "wg": (jax.random.normal(kg, (e, d, f)) * s_in).astype(dtype),
        "wo": (jax.random.normal(ko, (e, f, d)) * s_out).astype(dtype),
    }


def moe_apply(p, cfg, x: jax.Array, policy=None) -> jax.Array:
    """x: (B, S, d) -> (B, S, d).  Capacity-dropped top-k routing.

    Each batch row is a routing GROUP (GShard grouping): the capacity-rank
    cumsum stays local to the 'data' shard; only the expert-buffer einsums
    cross the mesh (all-to-all when experts shard over 'model')."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(max(1, round(s * k / e * cfg.capacity_factor)))

    def route_group(xt):  # (S, d) -> (E, C, d), (S*k meta)
        logits = nn.linear(p["router"], xt.astype(jnp.float32), policy=policy)  # (S, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)  # (S, k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        flat_e = top_e.reshape(-1)  # (S*k,) ordered by (token, slot)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        rank_in_e = jnp.cumsum(onehot, axis=0) - 1
        my_rank = jnp.take_along_axis(rank_in_e, flat_e[:, None], axis=1)[:, 0]
        keep = my_rank < cap
        buf_row = jnp.where(keep, flat_e, e)  # dropped -> scratch row e
        buf_col = jnp.where(keep, my_rank, 0)
        token_of = jnp.repeat(jnp.arange(s), k)
        expert_in = jnp.zeros((e + 1, cap, d), x.dtype)
        expert_in = expert_in.at[buf_row, buf_col].set(xt[token_of], mode="drop")
        return expert_in[:e], (buf_row, buf_col, token_of, top_p.reshape(-1), keep)

    expert_in, meta = jax.vmap(route_group)(x)  # (B, E, C, d)

    # --- batched expert GLU over stacked weights (E shards over 'model') ---
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[cfg.act]
    hidden = act(jnp.einsum("becd,edf->becf", expert_in, p["wg"])) * jnp.einsum(
        "becd,edf->becf", expert_in, p["wi"]
    )
    expert_out = jnp.einsum("becf,efd->becd", hidden, p["wo"])  # (B, E, C, d)

    def unroute_group(eo, m):  # (E, C, d) -> (S, d)
        buf_row, buf_col, token_of, w_flat, keep = m
        gathered = eo[buf_row.clip(0, e - 1), buf_col]  # (S*k, d)
        w = (w_flat * keep).astype(x.dtype)
        return jnp.zeros((s, d), x.dtype).at[token_of].add(gathered * w[:, None])

    return jax.vmap(unroute_group)(expert_out, meta)


def moe_aux_loss(p, cfg, x: jax.Array) -> jax.Array:
    """Load-balance auxiliary loss (Switch-style): E * sum_e f_e * p_e."""
    t = x.shape[0] * x.shape[1]
    logits = nn.linear(p["router"], x.reshape(t, -1).astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_e = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top_e, cfg.n_experts), axis=0)
    imp = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac * imp)
