"""Uniform per-family model API.

Every family exposes:
    init(key, cfg)                          -> params
    train_loss(params, cfg, batch)          -> (loss, metrics)
    prefill(params, cfg, batch, s_max)      -> (logits, decode state)
    decode_step(params, cfg, state, batch)  -> (logits, new state)
    init_decode_state(cfg, batch, s_max)    -> zeroed decode state (dry-run)

Batches (input_specs in launch/shapes.py mirror these):
    dense/moe : {tokens, labels}                     | decode: {token}
    ssm/hybrid: same
    encdec    : {enc_embeds, tokens, labels}         | decode: {token} (+cross cache)
    vlm       : {patch_embeds, tokens, labels}       | decode: {token}
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import ExecutionPolicy, resolve_policy
from repro.models import nn
from repro.models import transformer as T
from repro.models.layers import (
    KVCache,
    attn_apply,
    attn_init,
    decode_attention,
    glu_mlp_apply,
    glu_mlp_init,
    dense_mlp_apply,
    dense_mlp_init,
    rmsnorm_init,
)
from repro.models.mamba2 import SSMCache, mamba2_apply, mamba2_dims, mamba2_init
from repro.models.rglru import LRUCache, rglru_apply, rglru_init
from repro.sharding.hints import hint_residual


# ===========================================================================
# SSM family (mamba2)
# ===========================================================================

def ssm_init(key, cfg: ModelConfig):
    dtype = cfg.dtype
    keys = jax.random.split(key, 3)
    layer_keys = jax.random.split(keys[2], cfg.n_layers)

    def one(k):
        return {
            "norm": rmsnorm_init(cfg.d_model, dtype),
            "mixer": mamba2_init(k, cfg, dtype),
        }

    return {
        "embed": (jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype),
        "blocks": jax.vmap(one)(layer_keys),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }


class SSMState(NamedTuple):
    caches: Any  # SSMCache stacked (L, ...)
    cache_len: jax.Array


def _ssm_backbone(params, cfg, h, collect_cache: bool, policy=None):
    def body(hh, lp):
        out, new_cache, _ = mamba2_apply(
            lp["mixer"], cfg, T._norm_apply(cfg, lp["norm"], hh), policy=policy
        )
        hh = hint_residual(hh + out)
        return hh, (new_cache if collect_cache else None)

    body = T._maybe_remat(cfg, body) if not collect_cache else body
    h, caches = jax.lax.scan(body, h, params["blocks"])
    return T._norm_apply(cfg, params["final_norm"], h), caches


def ssm_train_loss(params, cfg, batch, policy: ExecutionPolicy | None = None):
    policy = resolve_policy(cfg, policy)
    tokens = batch["tokens"]
    h = jnp.take(params["embed"], tokens, axis=0)
    h, _ = _ssm_backbone(params, cfg, h, collect_cache=False, policy=policy)
    loss = T.chunked_cross_entropy(
        h, params["embed"].T, batch["labels"], chunk=cfg.loss_chunk
    )
    return loss, {"loss": loss}


def ssm_init_decode_state(cfg, batch: int, s_max: int) -> SSMState:
    d_inner, n_heads, conv_dim = mamba2_dims(cfg)
    cache = SSMCache(
        state=jnp.zeros((cfg.n_layers, batch, n_heads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_dim), cfg.dtype),
    )
    return SSMState(caches=cache, cache_len=jnp.zeros((), jnp.int32))


def ssm_prefill(params, cfg, batch, s_max: int | None = None,
                policy: ExecutionPolicy | None = None):
    policy = resolve_policy(cfg, policy)
    tokens = batch["tokens"]
    s = tokens.shape[1]
    h = jnp.take(params["embed"], tokens, axis=0)

    def body(hh, lp):
        out, new_cache, _ = mamba2_apply(
            lp["mixer"], cfg, T._norm_apply(cfg, lp["norm"], hh), policy=policy
        )
        return hh + out, new_cache

    h, caches = jax.lax.scan(body, h, params["blocks"])
    h = T._norm_apply(cfg, params["final_norm"], h)
    logits = (h[:, -1:] @ params["embed"].T).astype(jnp.float32)
    return logits, SSMState(caches=caches, cache_len=jnp.full((), s, jnp.int32))


def ssm_decode_step(params, cfg, state: SSMState, batch,
                    policy: ExecutionPolicy | None = None):
    policy = resolve_policy(cfg, policy)
    token = batch["token"]
    h = jnp.take(params["embed"], token, axis=0)

    def body(hh, xs):
        lp, cache = xs
        out, new_cache, _ = mamba2_apply(
            lp["mixer"], cfg, T._norm_apply(cfg, lp["norm"], hh), cache=cache,
            policy=policy,
        )
        return hh + out, new_cache

    h, caches = jax.lax.scan(body, h, (params["blocks"], state.caches))
    h = T._norm_apply(cfg, params["final_norm"], h)
    logits = (h @ params["embed"].T).astype(jnp.float32)
    return logits, SSMState(caches=caches, cache_len=state.cache_len + 1)


# ===========================================================================
# Hybrid family (recurrentgemma: pattern recurrent/recurrent/local-attn)
# ===========================================================================

def _hybrid_slot_init(cfg, key, slot_type, dtype):
    k1, k2 = jax.random.split(key)
    p = {"ln1": rmsnorm_init(cfg.d_model, dtype), "ln2": rmsnorm_init(cfg.d_model, dtype)}
    if slot_type == "recurrent":
        p["mixer"] = rglru_init(k1, cfg, dtype)
    else:
        p["mixer"] = attn_init(k1, T.attn_cfg_for(cfg, slot_type), dtype)
    p["mlp"] = glu_mlp_init(k2, cfg.d_model, cfg.d_ff, bias=cfg.use_bias, dtype=dtype)
    return p


def hybrid_geometry(cfg: ModelConfig) -> tuple[int, int, int]:
    g = len(cfg.layer_pattern)
    return cfg.n_layers // g, g, cfg.n_layers % g


def hybrid_init(key, cfg: ModelConfig):
    dtype = cfg.dtype
    n_groups, g, rem = hybrid_geometry(cfg)
    keys = jax.random.split(key, 3)
    slot_params = []
    for s, slot_type in enumerate(cfg.layer_pattern):
        gkeys = jax.random.split(jax.random.fold_in(keys[1], s), n_groups)
        slot_params.append(
            jax.vmap(lambda k: _hybrid_slot_init(cfg, k, slot_type, dtype))(gkeys)
        )
    rem_params = [
        _hybrid_slot_init(cfg, jax.random.fold_in(keys[2], r), cfg.layer_pattern[r], dtype)
        for r in range(rem)
    ]
    return {
        "embed": (jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype),
        "blocks": slot_params,
        "rem": rem_params,
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }


def _hybrid_slot_apply(cfg, slot_type, p, h, *, positions, cache=None, cache_len=None,
                       policy=None):
    x = T._norm_apply(cfg, p["ln1"], h)
    if slot_type == "recurrent":
        out, new_cache = rglru_apply(p["mixer"], cfg, x, cache=cache, policy=policy)
    else:
        acfg = T.attn_cfg_for(cfg, slot_type)
        if cache is None:
            out, kv = attn_apply(
                p["mixer"], acfg, x, positions=positions,
                collect_kv=True, attn_block=cfg.attn_block, policy=policy,
            )
            new_cache = KVCache(*kv)
        else:
            s_eff = cache.k.shape[1]
            out, new_cache = attn_apply(
                p["mixer"], acfg, x, positions=positions, cache=cache,
                write_idx=jnp.mod(cache_len, s_eff),
                attend_len=jnp.minimum(cache_len + 1, s_eff),
                decode_window=None, attn_block=cfg.attn_block, policy=policy,
            )
    h = h + out
    h = h + glu_mlp_apply(
        p["mlp"], T._norm_apply(cfg, p["ln2"], h), act=cfg.act, policy=policy
    )
    return h, new_cache


class HybridState(NamedTuple):
    group_caches: Any  # tuple per slot (stacked over groups)
    rem_caches: Any  # tuple per remainder layer
    cache_len: jax.Array


def _hybrid_zero_cache(cfg, slot_type, batch, s_max, stack: int | None):
    if slot_type == "recurrent":
        w = cfg.lru_width or cfg.d_model
        shape_h = (batch, w)
        shape_c = (batch, 3, w)
        c = LRUCache(h=jnp.zeros(shape_h, jnp.float32), conv=jnp.zeros(shape_c, cfg.dtype))
    else:
        s_eff = min(s_max, cfg.window) if cfg.window else s_max
        shape = (batch, s_eff, cfg.n_kv_heads, cfg.head_dim)
        c = KVCache(jnp.zeros(shape, cfg.dtype), jnp.zeros(shape, cfg.dtype))
    if stack is None:
        return c
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (stack,) + a.shape), c)


def hybrid_init_decode_state(cfg, batch: int, s_max: int) -> HybridState:
    n_groups, g, rem = hybrid_geometry(cfg)
    group_caches = tuple(
        _hybrid_zero_cache(cfg, st, batch, s_max, n_groups) for st in cfg.layer_pattern
    )
    rem_caches = tuple(
        _hybrid_zero_cache(cfg, cfg.layer_pattern[r], batch, s_max, None) for r in range(rem)
    )
    return HybridState(group_caches, rem_caches, jnp.zeros((), jnp.int32))


def _hybrid_run(params, cfg, h, positions, *, state: HybridState | None, collect: bool,
                policy=None):
    """Shared stack runner.  state=None: train; collect: gather prefill caches."""
    decode = state is not None and h.shape[1] == 1

    def group_body(hh, xs):
        group_params = xs[0]
        caches = xs[1:] if decode else (None,) * len(cfg.layer_pattern)
        outs = []
        for s, slot_type in enumerate(cfg.layer_pattern):
            hh, aux = _hybrid_slot_apply(
                cfg, slot_type, group_params[s], hh, positions=positions,
                cache=caches[s] if decode else None,
                cache_len=state.cache_len if decode else None,
                policy=policy,
            )
            hh = hint_residual(hh)
            outs.append(aux)
        return hh, tuple(outs)

    body = group_body if (decode or collect) else T._maybe_remat(cfg, group_body)
    if decode:
        xs = (tuple(params["blocks"]), *state.group_caches)
    else:
        xs = (tuple(params["blocks"]),)
    h, group_out = jax.lax.scan(body, h, xs)

    rem_out = []
    for r, rp in enumerate(params["rem"]):
        slot_type = cfg.layer_pattern[r]
        hh_cache = state.rem_caches[r] if decode else None
        h, aux = _hybrid_slot_apply(
            cfg, slot_type, rp, h, positions=positions,
            cache=hh_cache, cache_len=state.cache_len if decode else None,
            policy=policy,
        )
        rem_out.append(aux)
    h = T._norm_apply(cfg, params["final_norm"], h)
    return h, group_out, tuple(rem_out)


def hybrid_train_loss(params, cfg, batch, policy: ExecutionPolicy | None = None):
    policy = resolve_policy(cfg, policy)
    tokens = batch["tokens"]
    s = tokens.shape[1]
    h = jnp.take(params["embed"], tokens, axis=0)
    h, _, _ = _hybrid_run(
        params, cfg, h, jnp.arange(s)[None], state=None, collect=False, policy=policy
    )
    loss = T.chunked_cross_entropy(h, params["embed"].T, batch["labels"], chunk=cfg.loss_chunk)
    return loss, {"loss": loss}


def hybrid_prefill(params, cfg, batch, s_max: int | None = None,
                   policy: ExecutionPolicy | None = None):
    policy = resolve_policy(cfg, policy)
    tokens = batch["tokens"]
    b, s = tokens.shape
    s_max = s_max or s
    h = jnp.take(params["embed"], tokens, axis=0)
    h, group_out, rem_out = _hybrid_run(
        params, cfg, h, jnp.arange(s)[None], state=None, collect=True, policy=policy
    )
    logits = (h[:, -1:] @ params["embed"].T).astype(jnp.float32)

    def fit_kv(kv: KVCache, stacked: bool):
        """Truncate to the rolling-window size and ALIGN slots so that
        position p lives at slot p % s_eff (the decode write invariant)."""
        s_eff = min(s_max, cfg.window) if cfg.window else s_max
        k, v = kv
        ax = 2 if stacked else 1
        cur = k.shape[ax]
        if cur > s_eff:
            sl = [slice(None)] * k.ndim
            sl[ax] = slice(cur - s_eff, cur)
            k, v = k[tuple(sl)], v[tuple(sl)]
            shift = s % s_eff
            if shift:
                k, v = jnp.roll(k, shift, axis=ax), jnp.roll(v, shift, axis=ax)
        elif cur < s_eff:
            pad = [(0, 0)] * k.ndim
            pad[ax] = (0, s_eff - cur)
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        return KVCache(k, v)

    group_caches = tuple(
        fit_kv(c, True) if hasattr(c, "k") else c for c in group_out
    )
    rem_caches = tuple(
        fit_kv(c, False) if hasattr(c, "k") else c for c in rem_out
    )
    return logits, HybridState(group_caches, rem_caches, jnp.full((), s, jnp.int32))


def hybrid_decode_step(params, cfg, state: HybridState, batch,
                       policy: ExecutionPolicy | None = None):
    policy = resolve_policy(cfg, policy)
    token = batch["token"]
    pos = state.cache_len.reshape(1, 1)
    h = jnp.take(params["embed"], token, axis=0)
    h, group_out, rem_out = _hybrid_run(
        params, cfg, h, pos, state=state, collect=False, policy=policy
    )
    logits = (h @ params["embed"].T).astype(jnp.float32)
    return logits, HybridState(group_out, rem_out, state.cache_len + 1)


# ===========================================================================
# Encoder-decoder family (whisper — audio frontend stubbed per assignment)
# ===========================================================================

def _sinusoidal_pos(s: int, d: int) -> jax.Array:
    pos = jnp.arange(s)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    inv = jnp.exp(-jnp.log(10000.0) * dim / (d // 2))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_slot_init(cfg, key, dtype):
    k1, k2 = jax.random.split(key)
    acfg = T.attn_cfg_for(cfg, "global")
    return {
        "ln1": T._norm_init(cfg, cfg.d_model, dtype),
        "attn": attn_init(k1, acfg, dtype),
        "ln2": T._norm_init(cfg, cfg.d_model, dtype),
        "mlp": dense_mlp_init(k2, cfg.d_model, cfg.d_ff, bias=cfg.use_bias, dtype=dtype),
    }


def _dec_slot_init(cfg, key, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    acfg = T.attn_cfg_for(cfg, "global")
    return {
        "ln1": T._norm_init(cfg, cfg.d_model, dtype),
        "self_attn": attn_init(k1, acfg, dtype),
        "ln_x": T._norm_init(cfg, cfg.d_model, dtype),
        "cross_attn": attn_init(k2, acfg, dtype),
        "ln2": T._norm_init(cfg, cfg.d_model, dtype),
        "mlp": dense_mlp_init(k3, cfg.d_model, cfg.d_ff, bias=cfg.use_bias, dtype=dtype),
    }


def encdec_init(key, cfg: ModelConfig):
    dtype = cfg.dtype
    ke, kd, kt = jax.random.split(key, 3)
    enc_keys = jax.random.split(ke, cfg.encoder_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "embed": (jax.random.normal(kt, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype),
        "enc_blocks": jax.vmap(lambda k: _enc_slot_init(cfg, k, dtype))(enc_keys),
        "dec_blocks": jax.vmap(lambda k: _dec_slot_init(cfg, k, dtype))(dec_keys),
        "enc_norm": T._norm_init(cfg, cfg.d_model, dtype),
        "final_norm": T._norm_init(cfg, cfg.d_model, dtype),
    }


def _encode(params, cfg, enc_embeds, policy=None):
    """enc_embeds: (B, S_enc, D) — the stubbed conv-frontend output."""
    s = enc_embeds.shape[1]
    h = enc_embeds + _sinusoidal_pos(s, cfg.d_model)[None].astype(enc_embeds.dtype)
    positions = jnp.arange(s)[None]
    acfg = T.attn_cfg_for(cfg, "global", causal=False)

    def body(hh, lp):
        x = T._norm_apply(cfg, lp["ln1"], hh)
        a, _ = attn_apply(
            lp["attn"], acfg, x, positions=positions, attn_block=cfg.attn_block,
            policy=policy,
        )
        hh = hh + a
        hh = hh + dense_mlp_apply(
            lp["mlp"], T._norm_apply(cfg, lp["ln2"], hh), act="gelu", policy=policy
        )
        return hint_residual(hh), None

    h, _ = jax.lax.scan(T._maybe_remat(cfg, body), h, params["enc_blocks"])
    return T._norm_apply(cfg, params["enc_norm"], h)


def _dec_slot_apply(cfg, p, h, enc_out, *, positions, self_cache=None, cache_len=None,
                    cross_kv=None, collect=False, policy=None):
    acfg = T.attn_cfg_for(cfg, "global")
    x = T._norm_apply(cfg, p["ln1"], h)
    if self_cache is None:
        a, kv = attn_apply(p["self_attn"], acfg, x, positions=positions,
                           collect_kv=collect, attn_block=cfg.attn_block, policy=policy)
        new_self = KVCache(*kv) if collect else None
    else:
        a, new_self = attn_apply(
            p["self_attn"], acfg, x, positions=positions, cache=self_cache,
            write_idx=cache_len, attend_len=cache_len + 1, attn_block=cfg.attn_block,
            policy=policy,
        )
    h = h + a
    xq = T._norm_apply(cfg, p["ln_x"], h)
    if cross_kv is None:
        # train/prefill: compute cross K/V from encoder output
        c, ckv = attn_apply(
            p["cross_attn"], T.attn_cfg_for(cfg, "global", causal=False), xq,
            positions=positions, kv_override=(enc_out, enc_out),
            collect_kv=False, attn_block=cfg.attn_block, policy=policy,
        )
        b, se, _ = enc_out.shape
        hk, dh = cfg.n_kv_heads, cfg.head_dim
        k = nn.linear(p["cross_attn"]["wk"], enc_out, policy=policy).reshape(b, se, hk, dh)
        v = nn.linear(p["cross_attn"]["wv"], enc_out, policy=policy).reshape(b, se, hk, dh)
        new_cross = KVCache(k, v) if collect else None
    else:
        # decode: attend over cached cross K/V
        b = xq.shape[0]
        hq, dh = cfg.n_heads, cfg.head_dim
        q = nn.linear(p["cross_attn"]["wq"], xq, policy=policy).reshape(b, 1, hq, dh)
        o = decode_attention(q, cross_kv.k, cross_kv.v, cache_len=cross_kv.k.shape[1])
        c = nn.linear(p["cross_attn"]["wo"], o.reshape(b, 1, hq * dh), policy=policy)
        new_cross = cross_kv
    h = h + c
    h = h + dense_mlp_apply(
        p["mlp"], T._norm_apply(cfg, p["ln2"], h), act="gelu", policy=policy
    )
    return h, new_self, new_cross


class EncDecState(NamedTuple):
    self_caches: Any  # KVCache stacked (L, B, S_max, Hkv, Dh)
    cross_caches: Any  # KVCache stacked (L, B, S_enc, Hkv, Dh)
    cache_len: jax.Array


def encdec_train_loss(params, cfg, batch, policy: ExecutionPolicy | None = None):
    policy = resolve_policy(cfg, policy)
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.arange(s)[None]
    enc_out = _encode(params, cfg, batch["enc_embeds"], policy=policy)
    h = jnp.take(params["embed"], tokens, axis=0)
    h = h + _sinusoidal_pos(s, cfg.d_model)[None].astype(h.dtype)

    def body(hh, lp):
        hh, _, _ = _dec_slot_apply(
            cfg, lp, hh, enc_out, positions=positions, policy=policy
        )
        return hint_residual(hh), None

    h, _ = jax.lax.scan(T._maybe_remat(cfg, body), h, params["dec_blocks"])
    h = T._norm_apply(cfg, params["final_norm"], h)
    loss = T.chunked_cross_entropy(h, params["embed"].T, batch["labels"], chunk=cfg.loss_chunk)
    return loss, {"loss": loss}


def encdec_init_decode_state(cfg, batch: int, s_max: int, s_enc: int | None = None) -> EncDecState:
    s_enc = s_enc or s_max
    nl = cfg.n_layers
    shape_s = (nl, batch, s_max, cfg.n_kv_heads, cfg.head_dim)
    shape_x = (nl, batch, s_enc, cfg.n_kv_heads, cfg.head_dim)

    def z(sh):
        return jnp.zeros(sh, cfg.dtype)

    return EncDecState(
        self_caches=KVCache(z(shape_s), z(shape_s)),
        cross_caches=KVCache(z(shape_x), z(shape_x)),
        cache_len=jnp.zeros((), jnp.int32),
    )


def encdec_prefill(params, cfg, batch, s_max: int | None = None,
                   policy: ExecutionPolicy | None = None):
    policy = resolve_policy(cfg, policy)
    tokens = batch["tokens"]
    b, s = tokens.shape
    s_max = s_max or s
    positions = jnp.arange(s)[None]
    enc_out = _encode(params, cfg, batch["enc_embeds"], policy=policy)
    h = jnp.take(params["embed"], tokens, axis=0)
    h = h + _sinusoidal_pos(s, cfg.d_model)[None].astype(h.dtype)

    def body(hh, lp):
        hh, sc, cc = _dec_slot_apply(
            cfg, lp, hh, enc_out, positions=positions, collect=True, policy=policy
        )
        return hh, (sc, cc)

    h, (self_kv, cross_kv) = jax.lax.scan(body, h, params["dec_blocks"])
    h = T._norm_apply(cfg, params["final_norm"], h)
    logits = (h[:, -1:] @ params["embed"].T).astype(jnp.float32)
    if s_max > s:
        pad = [(0, 0), (0, 0), (0, s_max - s), (0, 0), (0, 0)]
        self_kv = KVCache(jnp.pad(self_kv.k, pad), jnp.pad(self_kv.v, pad))
    return logits, EncDecState(self_kv, cross_kv, jnp.full((), s, jnp.int32))


def encdec_decode_step(params, cfg, state: EncDecState, batch,
                       policy: ExecutionPolicy | None = None):
    policy = resolve_policy(cfg, policy)
    token = batch["token"]
    pos = state.cache_len.reshape(1, 1)
    h = jnp.take(params["embed"], token, axis=0)
    # absolute (sinusoidal) decoder position, gathered at the current index
    table = _sinusoidal_pos(state.self_caches.k.shape[2], cfg.d_model)
    h = h + jnp.take(table, pos, axis=0).astype(h.dtype)

    def body(hh, xs):
        lp, sc, cc = xs
        hh, new_sc, new_cc = _dec_slot_apply(
            cfg, lp, hh, None, positions=pos,
            self_cache=sc, cache_len=state.cache_len, cross_kv=cc, policy=policy,
        )
        return hh, (new_sc, new_cc)

    h, (self_kv, cross_kv) = jax.lax.scan(
        body, h, (params["dec_blocks"], state.self_caches, state.cross_caches)
    )
    h = T._norm_apply(cfg, params["final_norm"], h)
    logits = (h @ params["embed"].T).astype(jnp.float32)
    return logits, EncDecState(self_kv, cross_kv, state.cache_len + 1)


# ===========================================================================
# VLM family (internvl2: ViT-frontend stub + dense LM backbone)
# ===========================================================================

def vlm_init(key, cfg: ModelConfig):
    params = T.init_lm(key, cfg)
    # stub frontend projection: patch embeds arrive at d_model (assignment),
    # a single learned projection models the mlp1 connector
    params["patch_proj"] = nn.linear_init(
        jax.random.fold_in(key, 7), cfg.d_model, cfg.d_model, bias=True, dtype=cfg.dtype
    )
    return params


def vlm_embed(params, cfg, batch, policy=None):
    """concat(projected patch embeds, token embeds) -> (B, P + S_text, D)."""
    patches = nn.linear(
        params["patch_proj"], batch["patch_embeds"].astype(cfg.dtype), policy=policy
    )
    tok = jnp.take(params["embed"], batch["tokens"], axis=0)
    return jnp.concatenate([patches, tok], axis=1)


def vlm_train_loss(params, cfg, batch, policy: ExecutionPolicy | None = None):
    policy = resolve_policy(cfg, policy)
    h = vlm_embed(params, cfg, batch, policy=policy)
    s = h.shape[1]
    h = T.backbone(params, cfg, h, jnp.arange(s)[None], policy=policy)
    n_p = batch["patch_embeds"].shape[1]
    h_text = h[:, n_p:]
    loss = T.chunked_cross_entropy(
        h_text, T.lm_head_weights(params, cfg), batch["labels"], chunk=cfg.loss_chunk
    )
    return loss, {"loss": loss}


def vlm_prefill(params, cfg, batch, s_max: int | None = None,
                policy: ExecutionPolicy | None = None):
    """Prefill over [patches; prompt tokens].  Reuses the dense-LM cache path
    by running the group scan with collect_kv on the combined embedding."""
    policy = resolve_policy(cfg, policy)
    h = vlm_embed(params, cfg, batch, policy=policy)
    b, s, _ = h.shape
    s_max = s_max or s
    positions = jnp.arange(s)[None]

    def group_body(hh, group_params):
        kvs = []
        for slot, slot_type in enumerate(cfg.layer_pattern):
            hh, kv = T._block_apply(
                cfg, slot_type, group_params[slot], hh,
                positions=positions, collect_kv=True, policy=policy,
            )
            kvs.append(KVCache(*kv))
        return hh, tuple(kvs)

    h, kv_stacked = jax.lax.scan(group_body, h, tuple(params["blocks"]))
    h = T._norm_apply(cfg, params["final_norm"], h)
    logits = (h[:, -1:] @ T.lm_head_weights(params, cfg)).astype(jnp.float32)
    caches = []
    for slot in range(len(cfg.layer_pattern)):
        k, v = kv_stacked[slot]
        if s_max > s:
            pad = [(0, 0), (0, 0), (0, s_max - s), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        caches.append(KVCache(k, v))
    return logits, T.DecodeState(caches=tuple(caches), cache_len=jnp.full((), s, jnp.int32))


def vlm_decode_step(params, cfg, state, batch, policy: ExecutionPolicy | None = None):
    return T.decode_step(params, cfg, state, batch["token"], policy=policy)


# ===========================================================================
# Dispatch
# ===========================================================================

def get_family_api(cfg: ModelConfig) -> dict:
    """Uniform per-family API.  Every forward-path entry accepts an optional
    `policy=` ExecutionPolicy (None -> the config's default via policy_for)."""
    fam = cfg.family
    if fam in ("dense", "moe"):
        return {
            "init": T.init_lm,
            "train_loss": T.lm_loss,
            "prefill": lambda p, c, b, s_max=None, policy=None: T.prefill(
                p, c, b["tokens"], s_max, policy=policy
            ),
            "decode_step": lambda p, c, st, b, policy=None: T.decode_step(
                p, c, st, b["token"], policy=policy
            ),
            "init_decode_state": T.init_decode_state,
        }
    if fam == "ssm":
        return {
            "init": ssm_init,
            "train_loss": ssm_train_loss,
            "prefill": ssm_prefill,
            "decode_step": ssm_decode_step,
            "init_decode_state": ssm_init_decode_state,
        }
    if fam == "hybrid":
        return {
            "init": hybrid_init,
            "train_loss": hybrid_train_loss,
            "prefill": hybrid_prefill,
            "decode_step": hybrid_decode_step,
            "init_decode_state": hybrid_init_decode_state,
        }
    if fam == "encdec":
        return {
            "init": encdec_init,
            "train_loss": encdec_train_loss,
            "prefill": encdec_prefill,
            "decode_step": encdec_decode_step,
            "init_decode_state": encdec_init_decode_state,
        }
    if fam == "vlm":
        return {
            "init": vlm_init,
            "train_loss": vlm_train_loss,
            "prefill": vlm_prefill,
            "decode_step": vlm_decode_step,
            "init_decode_state": T.init_decode_state,
        }
    raise ValueError(f"unknown family {fam}")
