from repro.runtime.fault_tolerance import (  # noqa: F401
    HeartbeatMonitor,
    StragglerMonitor,
    run_with_restarts,
)
