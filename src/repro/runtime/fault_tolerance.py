"""Fault-tolerance runtime: restart driver, heartbeat, straggler detection.

On a real cluster these hooks attach to the coordinator (JobSet/GKE/Borg
events, jax.monitoring); here they are the same code paths driven by
in-process signals so the tests exercise the real logic:

  run_with_restarts  — supervises a train loop; on ANY exception (simulated
      preemption / device loss) it resumes from the newest complete
      checkpoint, up to max_restarts.  The data stream is step-keyed, so a
      restart replays the exact schedule.
  StragglerMonitor   — per-step wall-time EWMA + robust z-score; flags steps
      slower than `threshold` x the running median (at pod scale: feeds the
      scheduler to evict/replace the slow host; here: records + callback).
  HeartbeatMonitor   — background liveness thread; a missed deadline invokes
      the on_dead callback (the restart driver or an external supervisor).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    median_s: float
    ratio: float


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, window: int = 64, on_straggler=None):
        self.threshold = threshold
        self.window = window
        self.on_straggler = on_straggler
        self.durations: list[float] = []
        self.events: list[StragglerEvent] = []
        self._t0: float | None = None

    def step_start(self):
        self._t0 = time.monotonic()

    def step_end(self, step: int):
        assert self._t0 is not None
        dt = time.monotonic() - self._t0
        hist = self.durations[-self.window:]
        self.durations.append(dt)
        if len(hist) >= 8:
            med = sorted(hist)[len(hist) // 2]
            if med > 0 and dt > self.threshold * med:
                ev = StragglerEvent(step, dt, med, dt / med)
                self.events.append(ev)
                if self.on_straggler:
                    self.on_straggler(ev)
        return dt


class HeartbeatMonitor:
    def __init__(self, timeout_s: float, on_dead: Callable[[], None]):
        self.timeout_s = timeout_s
        self.on_dead = on_dead
        self._last = time.monotonic()
        self._stop = threading.Event()
        self._fired = False
        self._thread = threading.Thread(target=self._run, daemon=True)

    def start(self):
        self._thread.start()
        return self

    def beat(self):
        self._last = time.monotonic()

    def stop(self):
        self._stop.set()

    def _run(self):
        while not self._stop.wait(self.timeout_s / 4):
            if time.monotonic() - self._last > self.timeout_s and not self._fired:
                self._fired = True
                self.on_dead()


def run_with_restarts(
    make_state,
    train_loop,
    *,
    ckpt_manager,
    max_restarts: int = 3,
    restore_shardings=None,
):
    """Supervise `train_loop(state, start_step) -> (state, last_step)`.

    make_state() builds fresh (params, opt, ...) state; on restart the newest
    complete checkpoint replaces it.  Returns (state, steps_run, n_restarts).
    """
    n_restarts = 0
    while True:
        state = make_state()
        start_step = 0
        restored = ckpt_manager.restore_or_none(state, shardings=restore_shardings)
        if restored is not None:
            state, start_step, _extra = restored
        try:
            state, last = train_loop(state, start_step)
            ckpt_manager.wait()
            return state, last, n_restarts
        except Exception:  # noqa: BLE001 — simulated preemption/hardware loss
            n_restarts += 1
            if n_restarts > max_restarts:
                raise
            ckpt_manager.wait()
