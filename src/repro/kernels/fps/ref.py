"""Pure-jnp oracle for the FPS tile kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fps_tiles_ref(points: jax.Array, k: int, *, metric: str = "l1") -> jax.Array:
    """points: (T, 3, P) -> (T, k) int32.  Matches the kernel's tie-breaking
    (first index of the max) and start convention (index 0)."""

    def one_tile(pts):  # (3, P)
        p = pts.shape[-1]

        def body(carry, _):
            dmin, last = carry
            ref = jax.lax.dynamic_slice(pts, (0, last), (3, 1))
            diff = pts - ref
            if metric == "l1":
                d = jnp.sum(jnp.abs(diff), axis=0)
            else:
                d = jnp.sum(diff * diff, axis=0)
            new_dmin = jnp.minimum(dmin, d)
            nxt = jnp.argmax(new_dmin).astype(jnp.int32)  # first max index
            return (new_dmin, nxt), last

        init = (jnp.full((p,), 1e30, jnp.float32), jnp.int32(0))
        _, sampled = jax.lax.scan(body, init, None, length=k)
        return sampled

    return jax.vmap(one_tile)(points)
