"""Public op: tiled FPS dispatched through the kernel registry.

`fps_tiles(points_tiled, k)` accepts MSP-layout tiles (T, P, 3) (the
natural output of core.partition) and handles the TPU-native (T, 3, P)
transposition + lane padding internally.  The tile axis is the pallas grid
axis — callers fold any batch dims into it (the PreprocessEngine folds
(B, T, P) -> (B·T, P) so B clouds launch as ONE grid).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.fps.kernel import fps_tiles_pallas
from repro.kernels.fps.ref import fps_tiles_ref

registry.register("fps_tiles", xla=fps_tiles_ref, pallas=fps_tiles_pallas)


def fps_tiles(
    points_tiled: jax.Array,
    k: int,
    *,
    metric: str = "l1",
    backend: str = "auto",
    interpret: bool | None = None,
) -> jax.Array:
    """Batched per-tile FPS.  points_tiled: (T, P, 3) -> (T, k) local indices.

    backend: "pallas" (TPU kernel; interpret on CPU), "xla" (reference path),
    "auto" (pallas on TPU, xla elsewhere).
    """
    t, p, three = points_tiled.shape
    assert three == 3
    resolved, impl = registry.dispatch("fps_tiles", backend, interpret)
    pts = points_tiled.transpose(0, 2, 1)  # (T, 3, P)
    if resolved == "xla":
        return impl(pts, k, metric=metric)

    # pad with copies of the first point: dmin stays 0 there after step 1;
    # duplicates are never selected before any real point
    pts, pad = registry.pad_to_multiple(pts, axis=-1, multiple=registry.LANE)
    idx = impl(pts.astype(jnp.float32), k, metric=metric)
    if pad:
        idx = jnp.minimum(idx, p - 1)  # paranoia: padded lanes can't win, but clamp
    return idx
