"""Public op: tiled FPS with kernel/XLA backend selection.

`fps_tiles(points_tiled, k)` accepts MSP-layout tiles (T, P, 3) (the
natural output of core.partition) and handles the TPU-native (T, 3, P)
transposition + lane padding internally.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fps.kernel import fps_tiles_pallas
from repro.kernels.fps.ref import fps_tiles_ref


def fps_tiles(
    points_tiled: jax.Array,
    k: int,
    *,
    metric: str = "l1",
    backend: str = "auto",
    interpret: bool | None = None,
) -> jax.Array:
    """Batched per-tile FPS.  points_tiled: (T, P, 3) -> (T, k) local indices.

    backend: "pallas" (TPU kernel; interpret on CPU), "xla" (reference path),
    "auto" (pallas on TPU, xla elsewhere).
    """
    t, p, three = points_tiled.shape
    assert three == 3
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"

    if backend == "xla":
        return fps_tiles_ref(points_tiled.transpose(0, 2, 1), k, metric=metric)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    pts = points_tiled.transpose(0, 2, 1)  # (T, 3, P)
    pad = (-p) % 128
    if pad:
        # pad with copies of the first point: dmin stays 0 there after step 1;
        # duplicates are never selected before any real point
        filler = jnp.broadcast_to(pts[:, :, :1], (t, 3, pad))
        pts = jnp.concatenate([pts, filler], axis=-1)
    idx = fps_tiles_pallas(pts.astype(jnp.float32), k, metric=metric, interpret=interpret)
    if pad:
        idx = jnp.minimum(idx, p - 1)  # paranoia: padded lanes can't win, but clamp
    return idx
