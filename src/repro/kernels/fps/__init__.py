from repro.kernels.fps.ops import fps_tiles  # noqa: F401
