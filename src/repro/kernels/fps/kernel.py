"""Pallas kernel: in-VMEM farthest point sampling (APD-CIM + Ping-Pong-MAX, C1+C3).

Hardware mapping (paper -> TPU v5e):

  APD-CIM array holds one 2048-point tile (12 KB @ 16b)   -> the (3, P) tile
      lives in a VMEM block for the whole kernel; HBM sees ONE read.
  Ping-Pong-MAX CAM holds temporary distances in-situ     -> dmin lives in a
      VMEM scratch (never written to HBM); the min-update and the max-search
      happen in-register/VMEM each iteration (VPU tree reduction plays the
      role of the bit-serial CAM search).
  16 distances/cycle via PTG row activation               -> the VPU computes
      all P lane-parallel distances per iteration; the K-step loop is a
      lax.fori_loop INSIDE the kernel, so nothing round-trips to HBM.

Layout choices (TPU-native):
  * points as (3, P) with P a multiple of 128 — coordinates on the sublane
    axis, points on the lane axis, so |x - x_ref| is a full-width VPU op.
  * dmin scratch as (1, P) f32.
  * argmax via iota+select (Mosaic-safe; avoids 1D argmax lowering).

Grid: one program per tile -> batched FPS over (T, 3, P) with zero padding
(equal-size MSP tiles map 1:1 onto grid steps — the C2 utilisation story).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_BIG = 1e30


def _fps_kernel(points_ref, out_idx_ref, dmin_ref, *, k: int, metric: str):
    """One tile: points_ref (1, 3, P) f32 -> out_idx_ref (1, k) int32."""
    p = points_ref.shape[-1]
    pts = points_ref[0]  # (3, P)
    dmin_ref[...] = jnp.full((1, p), _BIG, jnp.float32)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, p), 1)

    def body(t, last):
        # gather the reference point's coords: dynamic slice on the lane axis
        ref = jax.lax.dynamic_slice(pts, (0, last), (3, 1))  # (3, 1)
        diff = pts - ref
        if metric == "l1":
            d = jnp.sum(jnp.abs(diff), axis=0, keepdims=True)  # (1, P)
        else:
            d = jnp.sum(diff * diff, axis=0, keepdims=True)
        new_dmin = jnp.minimum(dmin_ref[...], d)
        dmin_ref[...] = new_dmin
        # in-situ max search (the CAM role): max + first-index-of-max
        m = jnp.max(new_dmin)
        nxt = jnp.min(jnp.where(new_dmin == m, lane, p)).astype(jnp.int32)
        out_idx_ref[0, t - 1] = last
        return nxt

    last = jax.lax.fori_loop(1, k, body, jnp.int32(0), unroll=False)
    # the loop wrote indices 0..k-2; write the final sampled index
    out_idx_ref[0, k - 1] = last


@functools.partial(jax.jit, static_argnames=("k", "metric", "interpret"))
def fps_tiles_pallas(
    points: jax.Array, k: int, *, metric: str = "l1", interpret: bool = False
) -> jax.Array:
    """Batched tile FPS.  points: (T, 3, P) f32 -> (T, k) int32 local indices.

    P must be a multiple of 128 (lane width).  VMEM footprint per program:
    3*P*4 (tile) + P*4 (dmin) + k*4 — for P=2048 that is ~33 KB, far under
    the v5e 16MB VMEM: plenty of room for double-buffered grid pipelining.
    """
    t, three, p = points.shape
    assert three == 3, "points must be (T, 3, P)"
    if p % 128 != 0:
        raise ValueError(f"P={p} must be a multiple of 128 (TPU lane width)")

    kernel = functools.partial(_fps_kernel, k=k, metric=metric)
    return pl.pallas_call(
        kernel,
        grid=(t,),
        in_specs=[pl.BlockSpec((1, 3, p), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, k), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, p), jnp.float32)],
        interpret=interpret,
        name="pc2im_fps_tile",
    )(points)
