"""Pallas kernel: fused 3-nearest-neighbour search (FP-layer up-sampling).

For each query point, the 3 smallest distances + indices among P reference
points, computed as 3 successive (min, first-argmin, mask) extractions over
a VMEM-resident distance row — the same never-leave-VMEM dataflow as the
FPS kernel (the paper's kNN runs on the same APD-CIM + sorter).

Layout: queries block (bq, 3) on sublanes? No — distances are (bq, P):
queries on sublanes (bq multiple of 8), reference points on lanes (P
multiple of 128).  VMEM per program: bq*P*4 (dist) + 2 small outputs; for
bq=256, P=2048 that is 2 MB — double-bufferable on v5e.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import registry

_INF = 3.0e38  # python float: jnp scalars would be captured consts in the kernel


def _knn3_kernel(q_ref, p_ref, idx_ref, dist_ref, *, metric: str, k: int):
    """q_ref (bq, 3), p_ref (3, P) -> idx_ref (bq, k) int32, dist_ref (bq, k) f32."""
    q = q_ref[...]  # (bq, 3)
    p = p_ref[...]  # (3, P)
    diff = q[:, :, None] - p[None, :, :]  # (bq, 3, P)
    if metric == "l1":
        d = jnp.sum(jnp.abs(diff), axis=1)  # (bq, P)
    else:
        d = jnp.sum(diff * diff, axis=1)
    bq, pp = d.shape
    lane = jax.lax.broadcasted_iota(jnp.int32, (bq, pp), 1)
    for t in range(k):
        m = jnp.min(d, axis=1, keepdims=True)  # (bq, 1)
        j = jnp.min(jnp.where(d == m, lane, pp), axis=1)  # first argmin
        idx_ref[:, t] = j.astype(jnp.int32)
        dist_ref[:, t] = m[:, 0]
        d = jnp.where(lane == j[:, None], _INF, d)  # mask out the extracted one


@functools.partial(jax.jit, static_argnames=("k", "metric", "bq", "interpret"))
def knn3_pallas(
    queries: jax.Array,
    points: jax.Array,
    *,
    k: int = 3,
    metric: str = "l2",
    bq: int = 256,
    interpret: bool = False,
):
    """queries: (Q, 3), points: (3, P) -> (idx (Q,k) int32, dist (Q,k) f32).

    Q needs no alignment: the query block is clamped to Q, sublane-aligned
    (multiple of 8 — queries live on sublanes), and the queries are padded
    internally up to a whole number of blocks with first-row copies, the
    same way fps_tiles pads lanes.  Padded rows compute real neighbours of
    the duplicated query and are sliced off before returning.
    """
    qn, three = queries.shape
    assert three == 3 and points.shape[0] == 3
    if qn < 1:
        raise ValueError(f"need at least one query, got Q={qn}")
    p = points.shape[1]
    if p % 128 != 0:
        raise ValueError(f"P={p} must be a multiple of 128")
    # clamp then sublane-align: bq > qn after clamping is fine (the whole
    # query set is one block), the padding below makes Q divide
    bq = min(bq, qn)
    bq += (-bq) % registry.SUBLANE
    queries, _ = registry.pad_to_multiple(queries, axis=0, multiple=bq)
    total = queries.shape[0]

    kernel = functools.partial(_knn3_kernel, metric=metric, k=k)
    idx, dist = pl.pallas_call(
        kernel,
        grid=(total // bq,),
        in_specs=[
            pl.BlockSpec((bq, 3), lambda i: (i, 0)),
            pl.BlockSpec((3, p), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i: (i, 0)),
            pl.BlockSpec((bq, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((total, k), jnp.int32),
            jax.ShapeDtypeStruct((total, k), jnp.float32),
        ],
        interpret=interpret,
        name="pc2im_knn3",
    )(queries, points)
    return idx[:qn], dist[:qn]
