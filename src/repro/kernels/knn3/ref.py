"""Pure-jnp oracle for knn3 — reuses core.query.knn (tested vs numpy argsort)."""

from __future__ import annotations

import jax

from repro.core.query import knn


def knn3_ref(queries: jax.Array, points_t: jax.Array, *, k: int = 3, metric: str = "l2"):
    """queries: (Q, 3), points_t: (3, P) -> (idx, dist) matching the kernel."""
    return knn(queries, points_t.T, k, metric=metric)
