"""Public op: fused kNN dispatched through the kernel registry."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.knn3.kernel import knn3_pallas
from repro.kernels.knn3.ref import knn3_ref

registry.register("knn3", xla=knn3_ref, pallas=knn3_pallas)


def knn3(
    queries: jax.Array,
    points: jax.Array,
    *,
    k: int = 3,
    metric: str = "l2",
    backend: str = "auto",
    interpret: bool | None = None,
):
    """queries: (Q, 3), points: (P, 3) -> (idx (Q,k), dist (Q,k))."""
    resolved, impl = registry.dispatch("knn3", backend, interpret)
    pts_t = points.T  # (3, P)
    if resolved == "xla":
        return impl(queries, pts_t, k=k, metric=metric)

    # huge-but-finite offset padding: +inf coordinates would NaN the distance
    # math, the FAR_OFFSET filler just never wins.  Query alignment is the
    # kernel's own job: knn3_pallas sublane-aligns its block and pads Q
    # internally, so any Q >= 1 goes straight through
    pts_t, _ = registry.pad_to_multiple(
        pts_t, axis=1, multiple=registry.LANE, offset=registry.FAR_OFFSET
    )
    return impl(queries.astype(jnp.float32), pts_t.astype(jnp.float32), k=k, metric=metric)
