"""Public op: fused kNN with backend selection + lane padding."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.knn3.kernel import knn3_pallas
from repro.kernels.knn3.ref import knn3_ref


def knn3(
    queries: jax.Array,
    points: jax.Array,
    *,
    k: int = 3,
    metric: str = "l2",
    backend: str = "auto",
    interpret: bool | None = None,
):
    """queries: (Q, 3), points: (P, 3) -> (idx (Q,k), dist (Q,k))."""
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    pts_t = points.T  # (3, P)
    if backend == "xla":
        return knn3_ref(queries, pts_t, k=k, metric=metric)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    q, p = queries.shape[0], points.shape[0]
    pad_p = (-p) % 128
    if pad_p:
        # +inf-coordinate padding can NaN the distance math; instead pad with a
        # huge-but-finite offset of the first point so padded cols never win.
        filler = pts_t[:, :1] + 1e15
        pts_t = jnp.concatenate([pts_t, jnp.broadcast_to(filler, (3, pad_p))], axis=1)
    bq = 256
    pad_q = (-q) % min(bq, max(q, 8))
    if q < bq:
        bq = q + ((-q) % 8 if q % 8 else 0) or q
    pad_q = (-q) % bq
    if pad_q:
        queries = jnp.concatenate(
            [queries, jnp.broadcast_to(queries[:1], (pad_q, 3))], axis=0
        )
    idx, dist = knn3_pallas(
        queries.astype(jnp.float32), pts_t.astype(jnp.float32),
        k=k, metric=metric, bq=bq, interpret=interpret,
    )
    return idx[:q], dist[:q]
