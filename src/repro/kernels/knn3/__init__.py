from repro.kernels.knn3.ops import knn3  # noqa: F401
