from repro.kernels.sc_matmul.ops import sc_matmul_op, sc_quantized_linear  # noqa: F401
