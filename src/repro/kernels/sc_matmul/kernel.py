"""Pallas kernel: split-concatenate W16A16 integer matmul (paper C4, SC-CIM).

The paper splits 16-bit weights into 4-bit *blocks* and 16-bit inputs into
4-bit *clusters*; cluster-block products become concatenations (shift-adds)
merged by a fused dense/sparse adder tree.  TPU mapping:

  4-bit planes in int8 containers  -> the MXU int8 path (4x bf16 byte-
                                      throughput, exact int32 accumulation)
  cluster-block product            -> one int8 x int8 -> int32 dot_general
  fused adder tree                 -> diagonal grouping: all plane pairs with
                                      i+j = d share one shift; sum the int32
                                      dots per diagonal FIRST, shift once
                                      (this is the dense/sparse tree fusion)
  periphery sign merge             -> top plane is the signed two's-complement
                                      remainder; handled by arithmetic shift

Why this matters on TPU: bf16 MXU matmuls have an 8-bit mantissa — a 16-bit
*integer* MAC cannot ride them exactly.  SC decomposition gives exact 16-bit
integer GEMM at 16 int8-dots ≈ 4 bf16-equivalent passes, mirroring the
paper's 4-cycle-per-input (vs 16 for bit-serial) trade.  W8A8 needs only
4 dots (= 1 pass) — paper's scheme generalises by plane count.

Grid: (M/bm, N/bn, K/bk), K innermost; per-diagonal int32 accumulators in
VMEM scratch; the f32 combine happens once on the last K step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

PLANE_BITS = 4


def _split_planes_kernel(q: jax.Array, n_planes: int) -> list[jax.Array]:
    """Nibble-split int32 values (16-bit range): low planes in [0,15], top signed."""
    planes = []
    for i in range(n_planes - 1):
        planes.append((q >> (PLANE_BITS * i)) & 0xF)
    planes.append(q >> (PLANE_BITS * (n_planes - 1)))  # arithmetic: signed top
    return planes


def _sc_matmul_kernel(
    x_ref, w_ref, out_ref, *accs, n_planes_x: int, n_planes_w: int, k_steps: int
):
    """One (bm, bn) tile; K-accumulation across grid axis 2.

    accs: one int32 VMEM scratch (bm, bn) per diagonal d in [0, nx+nw-2].
    """
    kidx = pl.program_id(2)
    n_diags = n_planes_x + n_planes_w - 1

    @pl.when(kidx == 0)
    def _init():
        for d in range(n_diags):
            accs[d][...] = jnp.zeros_like(accs[d])

    xp = _split_planes_kernel(x_ref[...], n_planes_x)  # each (bm, bk) int32
    wp = _split_planes_kernel(w_ref[...], n_planes_w)  # each (bk, bn) int32
    for i in range(n_planes_x):
        for j in range(n_planes_w):
            # int8-range operands -> MXU int path, exact int32 accumulation
            dot = jax.lax.dot_general(
                xp[i].astype(jnp.int8),
                wp[j].astype(jnp.int8),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            accs[i + j][...] += dot

    @pl.when(kidx == k_steps - 1)
    def _combine():
        # periphery merge: one shift per diagonal (the fused adder tree)
        out = jnp.zeros(out_ref.shape, jnp.float32)
        for d in range(n_diags):
            out = out + accs[d][...].astype(jnp.float32) * float(1 << (PLANE_BITS * d))
        out_ref[...] = out


@functools.partial(
    jax.jit,
    static_argnames=("n_planes_x", "n_planes_w", "bm", "bn", "bk", "interpret"),
)
def sc_matmul_pallas(
    x_q: jax.Array,
    w_q: jax.Array,
    *,
    n_planes_x: int = 4,
    n_planes_w: int = 4,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """x_q: (M, K) int32 (16-bit range), w_q: (K, N) int32 -> (M, N) f32.

    Result is the exact integer product whenever each diagonal partial sum
    stays within f32's 24-bit exact-integer window after the shift; the
    int32 per-diagonal accumulation itself is always exact (|plane| <= 15,
    so |diag dot| <= 4 * 225 * K -> exact for K up to ~2.3M).

    VMEM per program: bm*bk + bk*bn int32 operands + 7 * bm*bn int32 accs.
    Defaults (128,128,512): 64KB + 256KB + 448KB ~ 0.77MB — fits v5e VMEM
    with double buffering.
    """
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2
    if m % bm or n % bn or k % bk:
        bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
        if m % bm or n % bn or k % bk:
            raise ValueError(f"shapes ({m},{k},{n}) not tileable by ({bm},{bn},{bk})")
    k_steps = k // bk
    n_diags = n_planes_x + n_planes_w - 1

    kernel = functools.partial(
        _sc_matmul_kernel,
        n_planes_x=n_planes_x,
        n_planes_w=n_planes_w,
        k_steps=k_steps,
    )
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32) for _ in range(n_diags)],
        interpret=interpret,
        name="pc2im_sc_matmul",
    )(x_q, w_q)
